"""Quickstart: the full STEP pipeline at laptop scale, end to end.

    PYTHONPATH=src python examples/quickstart.py

1. trains a tiny reasoning LM on the synthetic verifiable task;
2. samples solutions from it, verifies them, trains the hidden-state
   step scorer (paper §4.1);
3. serves one problem with self-consistency (baseline) vs STEP under a
   tight KV-pool budget and prints the latency/waiting comparison
   (paper §4.2/§5.3.4).
"""
import random

import jax

from repro.configs.registry import serving_config
from repro.core.pipeline import build_step_scorer
from repro.core.pruning import make_policy
from repro.data.arithmetic import gen_problem, make_prompt
from repro.data.tokenizer import get_tokenizer
from repro.serving import Engine, EngineConfig, SamplingParams
from repro.training.trainer import TrainConfig, train_lm


def main():
    cfg = serving_config()

    print("== 1. train the reasoning LM (tiny, synthetic task) ==")
    params, _ = train_lm(cfg, TrainConfig(steps=300, seq_len=128,
                                          batch_size=16, log_every=50))

    print("== 2. sample -> verify -> train the step scorer ==")
    scorer, info = build_step_scorer(params, cfg, n_problems=16,
                                     n_samples=4, per_class=24, verbose=True)
    print(f"   scorer trained on {info['num_steps']} boundary states "
          f"(sampled correct-rate {info['sampled_correct_rate']:.2f})")

    print("== 3. SC vs STEP under a tight KV pool ==")
    tok = get_tokenizer()
    problem = gen_problem(random.Random(7), (4, 6))
    prompt = tok.encode(make_prompt(problem), add_bos=True)
    ecfg = EngineConfig(max_batch=8, num_blocks=12, capacity=128,
                        max_new_tokens=96,
                        sampling=SamplingParams(max_new_tokens=96))
    for method in ("sc", "step"):
        policy = make_policy(method)
        eng = Engine(params, cfg, ecfg, policy,
                     scorer_params=scorer if policy.uses_scorer else None)
        res = eng.serve(prompt, 8)
        ok = res.answer is not None and int(res.answer) == problem.answer
        print(f"   {method:4s}: answer={res.answer} (gold={problem.answer}, "
              f"{'OK' if ok else 'WRONG'})  latency={res.latency_s:.2f}s  "
              f"wait={res.wait_s:.2f}s  pruned={res.num_pruned}  "
              f"preemptions={res.num_preemptions}")


if __name__ == "__main__":
    main()
