"""End-to-end serving driver: evaluate all five methods (CoT / SC /
Slim-SC / DeepConf / STEP) on a batch of problems with the cached
artifacts, reproducing the paper's Table-1 metric triple
(accuracy / tokens / latency) at laptop scale.

    PYTHONPATH=src python examples/serve_parallel_scaling.py
"""
import sys

sys.path.insert(0, ".")  # for benchmarks.common when run from repo root

from benchmarks.common import load_artifacts  # noqa: E402
from repro.serving import EngineConfig, SamplingParams, evaluate_method, \
    make_problems  # noqa: E402

N_PROBLEMS = 6
N_TRACES = 16


def main():
    params, scorer, cfg = load_artifacts()
    problems = make_problems(N_PROBLEMS, seed=7, n_steps=(5, 8))
    ecfg = EngineConfig(max_batch=N_TRACES, num_blocks=40, capacity=256,
                        max_new_tokens=120,
                        sampling=SamplingParams(max_new_tokens=120))
    print(f"{'method':10s} {'acc':>5s} {'tokens':>8s} {'lat(s)':>7s} "
          f"{'wait(s)':>8s} {'pruned':>6s} {'preempt':>7s}")
    for method in ("cot", "sc", "slimsc", "deepconf", "step"):
        pkw = {"warmup": 4} if method == "deepconf" else {}
        res = evaluate_method(method, params, cfg, problems, N_TRACES, ecfg,
                              scorer_params=scorer, policy_kwargs=pkw)
        print(f"{method:10s} {res.accuracy:5.2f} {res.avg_tokens:8.0f} "
              f"{res.avg_latency_s:7.2f} {res.total_wait_s:8.2f} "
              f"{res.num_pruned:6d} {res.num_preemptions:7d}")


if __name__ == "__main__":
    main()
