"""Train a few hundred steps of ANY assigned architecture (reduced
config) on the synthetic task — the end-to-end training driver.

    PYTHONPATH=src python examples/train_multiarch.py --arch mamba2-2.7b \
        --steps 120
"""
import argparse
import time

import jax
import jax.numpy as jnp

from repro.configs.registry import ALL_ARCHS, serving_config
from repro.data.dataset import lm_batches
from repro.launch.steps import make_train_step
from repro.models.init import count_params, init_params


def main():
    ap = argparse.ArgumentParser()
    ap.add_argument("--arch", default="qwen3-1.7b", choices=ALL_ARCHS)
    ap.add_argument("--steps", type=int, default=120)
    ap.add_argument("--batch", type=int, default=16)
    ap.add_argument("--seq", type=int, default=128)
    args = ap.parse_args()

    cfg = serving_config(args.arch)  # reduced config, task tokenizer
    params = init_params(cfg, jax.random.PRNGKey(0))
    print(f"arch={cfg.name} ({cfg.arch_type}) "
          f"params={count_params(params):,}")

    step_fn, opt = make_train_step(cfg, lr=1e-3)
    opt_state = opt.init(params)
    step_fn = jax.jit(step_fn, donate_argnums=(0, 1))
    batches = lm_batches(args.seq, args.batch)

    t0 = time.time()
    first = last = None
    for step in range(args.steps):
        arr = next(batches)
        batch = {"tokens": jnp.asarray(arr[:, :-1]),
                 "labels": jnp.asarray(arr[:, 1:])}
        params, opt_state, loss = step_fn(params, opt_state, batch)
        if first is None:
            first = float(loss)
        last = float(loss)
        if step % 20 == 0 or step == args.steps - 1:
            print(f"  step {step:4d} loss {float(loss):.4f} "
                  f"({time.time() - t0:.1f}s)")
    assert last < first, "loss did not decrease"
    print(f"loss {first:.3f} -> {last:.3f} over {args.steps} steps")


if __name__ == "__main__":
    main()
