"""Compare freshly produced BENCH_*.json artifacts against the
checked-in reference numbers (``benchmarks/reference/``) and fail on
regression — the gate behind the CI benchmark-smoke job.

    python -m benchmarks.check_regression BENCH_serving.json \
        BENCH_prefill_sharing.json [--ref-dir benchmarks/reference]

Timing fields are compared with generous ratio bounds (CI runners are
noisy and share cores); structural fields (completion counts, identical
greedy outputs having run at all) are compared tightly. Reference files
are refreshed by copying a blessed run's artifact over the reference and
committing it — the diff IS the perf trajectory.
"""
from __future__ import annotations

import argparse
import json
import os
import sys

REF_DIR = os.path.join(os.path.dirname(__file__), "reference")

# (dotted path, kind, bound) per benchmark.
#   max_ratio r: new <= ref * r   (lower is better: latencies)
#   min_ratio r: new >= ref * r   (higher is better: throughput, speedups)
#   equal:       new == ref       (structural)
#   min_frac f:  new >= ref * f   (counts that must not collapse)
#   min_abs b:   new >= b         (reference-independent floor)
RULES = {
    "serving_load": [
        ("num_completed", "equal", None),
        ("num_requests", "equal", None),
        ("total_output_tokens", "min_frac", 0.8),
        ("ttft_s.p50", "max_ratio", 5.0),
        ("ttft_s.p99", "max_ratio", 5.0),
        ("tpot_s.p50", "max_ratio", 5.0),
        ("e2e_s.p50", "max_ratio", 5.0),
        ("e2e_s.p99", "max_ratio", 5.0),
        ("throughput_tok_per_s", "min_ratio", 0.2),
    ],
    "prefill_sharing": [
        ("prefill_speedup_x", "min_ratio", 0.3),
        ("peak_blocks_saved", "min_frac", 1.0),
        ("shared.prefill_s", "max_ratio", 5.0),
    ],
    "decode_throughput": [
        # identical greedy outputs at every horizon, full-length runs
        ("outputs_identical", "equal", None),
        ("horizons.1.tokens", "equal", None),
        ("horizons.8.tokens", "equal", None),
        # the decode-horizon acceptance floor: >= 1.5x tokens/s at K=8
        ("speedup_8x", "min_abs", 1.5),
        ("speedup_4x", "min_ratio", 0.3),
        ("horizons.8.tok_per_s", "min_ratio", 0.2),
    ],
}


def _get(d: dict, path: str):
    for part in path.split("."):
        d = d[part]
    return d


def check(new_path: str, ref_path: str) -> list:
    with open(new_path) as f:
        new = json.load(f)
    with open(ref_path) as f:
        ref = json.load(f)
    bench = new.get("benchmark")
    rules = RULES.get(bench)
    if rules is None:
        return [f"{new_path}: unknown benchmark {bench!r}"]
    problems = []
    for path, kind, bound in rules:
        try:
            nv, rv = _get(new, path), _get(ref, path)
        except KeyError as e:
            problems.append(f"{bench}.{path}: missing key {e}")
            continue
        if kind == "equal" and nv != rv:
            problems.append(f"{bench}.{path}: {nv!r} != reference {rv!r}")
        elif kind == "max_ratio" and rv > 0 and nv > rv * bound:
            problems.append(
                f"{bench}.{path}: {nv:.4g} exceeds reference "
                f"{rv:.4g} x{bound} (regression)")
        elif kind == "min_ratio" and nv < rv * bound:
            problems.append(
                f"{bench}.{path}: {nv:.4g} below reference "
                f"{rv:.4g} x{bound} (regression)")
        elif kind == "min_frac" and nv < rv * bound:
            problems.append(
                f"{bench}.{path}: {nv:.4g} below reference "
                f"{rv:.4g} x{bound}")
        elif kind == "min_abs" and nv < bound:
            problems.append(
                f"{bench}.{path}: {nv:.4g} below absolute floor "
                f"{bound} (regression)")
    return problems


def main() -> int:
    ap = argparse.ArgumentParser()
    ap.add_argument("artifacts", nargs="+", help="BENCH_*.json files")
    ap.add_argument("--ref-dir", default=REF_DIR)
    args = ap.parse_args()
    failures = []
    for art in args.artifacts:
        ref = os.path.join(args.ref_dir, os.path.basename(art))
        if not os.path.exists(ref):
            failures.append(f"{art}: no reference at {ref} "
                            f"(commit one to start the trajectory)")
            continue
        probs = check(art, ref)
        tag = "OK" if not probs else "REGRESSION"
        print(f"[{tag}] {os.path.basename(art)} vs {ref}")
        for p in probs:
            print(f"    {p}")
        failures.extend(probs)
    return 1 if failures else 0


if __name__ == "__main__":
    sys.exit(main())
