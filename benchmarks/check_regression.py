"""Compare freshly produced BENCH_*.json artifacts against the
checked-in reference numbers (``benchmarks/reference/``) and fail on
regression — the gate behind the CI benchmark-smoke job.

    python -m benchmarks.check_regression BENCH_serving.json \
        BENCH_prefill_sharing.json [--ref-dir benchmarks/reference]

Timing fields are compared with generous ratio bounds (CI runners are
noisy and share cores); structural fields (completion counts, identical
greedy outputs having run at all) are compared tightly. Reference files
are refreshed by copying a blessed run's artifact over the reference and
committing it — the diff IS the perf trajectory.
"""
from __future__ import annotations

import argparse
import json
import math
import os
import sys

REF_DIR = os.path.join(os.path.dirname(__file__), "reference")

# (dotted path, kind, bound) per benchmark.
#   max_ratio r: new <= ref * r   (lower is better: latencies)
#   min_ratio r: new >= ref * r   (higher is better: throughput, speedups)
#   equal:       new == ref       (structural)
#   min_frac f:  new >= ref * f   (counts that must not collapse)
#   min_abs b:   new >= b         (reference-independent floor)
#   max_abs b:   new <= b         (reference-independent ceiling: drift)
RULES = {
    "serving_load": [
        ("num_completed", "equal", None),
        ("num_requests", "equal", None),
        ("total_output_tokens", "min_frac", 0.8),
        ("ttft_s.p50", "max_ratio", 5.0),
        ("ttft_s.p99", "max_ratio", 5.0),
        ("tpot_s.p50", "max_ratio", 5.0),
        ("e2e_s.p50", "max_ratio", 5.0),
        ("e2e_s.p99", "max_ratio", 5.0),
        ("throughput_tok_per_s", "min_ratio", 0.2),
    ],
    "prefill_sharing": [
        ("prefill_speedup_x", "min_ratio", 0.3),
        ("peak_blocks_saved", "min_frac", 1.0),
        ("shared.prefill_s", "max_ratio", 5.0),
    ],
    "decode_throughput": [
        # identical greedy outputs at every horizon, full-length runs
        ("outputs_identical", "equal", None),
        ("horizons.1.tokens", "equal", None),
        ("horizons.8.tokens", "equal", None),
        # the decode-horizon acceptance floor: >= 1.5x tokens/s at K=8
        ("speedup_8x", "min_abs", 1.5),
        ("speedup_4x", "min_ratio", 0.3),
        ("horizons.8.tok_per_s", "min_ratio", 0.2),
    ],
    "paged_kernel": [
        # kernel must agree with the dense path before timing counts
        ("outputs_close", "equal", None),
        # the kernel-path acceptance floor: chunked prefill through the
        # multi-query Pallas kernel beats the dense score-tensor path
        ("prefill.speedup_x", "min_abs", 1.5),
        ("prefill.speedup_x", "min_ratio", 0.3),
        ("prefill.kernel_ms", "max_ratio", 5.0),
        # decode is collapse-guarded only (interpret-mode grid overhead
        # on CPU; the HBM-traffic win is a TPU property)
        ("decode.speedup_x", "min_ratio", 0.3),
        ("decode.kernel_ms", "max_ratio", 5.0),
    ],
    "prefix_cache": [
        # the prefix-cache contract: cache on/off generate identical
        # tokens, every turn completes, and reuse actually happened
        ("outputs_identical", "equal", None),
        ("num_completed", "equal", None),
        # acceptance floor: >= 0.8 of prompt tokens served from cache
        ("prefix_hit_rate", "min_abs", 0.8),
        ("total_cached_tokens", "min_frac", 1.0),
        # measurable TTFT win over cache-off on the same seed (local
        # runs show ~3.5x; 1.3 absorbs CI-runner noise)
        ("ttft_speedup_x", "min_abs", 1.3),
        ("ttft_speedup_x", "min_ratio", 0.3),
        ("cache_on.mean_ttft_s", "max_ratio", 5.0),
    ],
    "slo_serving": [
        # multi-tenant scenario suite: everything completes (batch-tier
        # SLOs degrade fan-out, they never shed whole requests here)
        ("num_completed", "equal", None),
        ("num_requests", "equal", None),
        # the acceptance floor: premium (weight 3, priority 1) p99 TTFT
        # >= 2x better than batch under the bursty mixed-tenant load
        ("ttft_p99_ratio_low_over_high", "min_abs", 2.0),
        # premium tier keeps its TTFT objective (local runs: 1.0)
        ("tenants.premium.slo.ttft_attainment", "min_abs", 0.9),
        # SLO admission control actually acted on the batch tier
        ("degraded_traces", "min_abs", 1),
        ("tenants.premium.ttft_s.p99", "max_ratio", 5.0),
        ("tenants.batch.e2e_s.p99", "max_ratio", 5.0),
        ("throughput_tok_per_s", "min_ratio", 0.2),
    ],
    "fault_serving": [
        # the fault-tolerance contract: recovery is invisible — the
        # faulted replay's tokens match the fault-free replay exactly,
        # every injected transient fault was retried to recovery (no
        # degrade rung taken), and the chaos leg quarantined / cancelled
        # exactly what the seeded plan dictates
        ("outputs_identical", "equal", None),
        ("step_faults", "equal", None),
        ("recovered_steps", "equal", None),
        ("alloc_stalls", "equal", None),
        ("degraded_to_dense", "equal", None),
        ("degraded_horizon", "equal", None),
        ("chaos.num_deadline_exceeded", "equal", None),
        ("chaos.nan_quarantined", "equal", None),
        ("chaos.num_completed", "equal", None),
        # goodput under faults: retry backoff is milliseconds against a
        # multi-second replay, so faulted goodput stays close to
        # fault-free (local runs ~1.0; 0.5 absorbs CI-runner noise)
        ("goodput_ratio", "min_abs", 0.5),
        ("goodput_ratio", "min_ratio", 0.3),
        ("faulted.wall_s", "max_ratio", 5.0),
    ],
    "kv_quant": [
        # the quantized-pool contract: bf16 at equal blocks is
        # token-identical to f32 (same bf16 values, wider storage), and
        # int8's cheaper blocks buy real capacity — >= 1.8x the f32
        # sustained traces at the SAME HBM byte budget
        ("tokens_identical_bf16_f32", "equal", None),
        ("traces_per_byte_ratio_int8_over_f32", "min_abs", 1.8),
        # deterministic workload (seeded engine RNG): capacity results
        # and the static budget->blocks math must reproduce exactly
        ("dtypes.f32.sustained", "equal", None),
        ("dtypes.int8.sustained", "equal", None),
        ("dtypes.int8.num_blocks", "equal", None),
        ("dtypes.f32.num_blocks", "equal", None),
        # scorer quality under quantization, measured on the
        # equal-blocks legs (comparable trace populations — the
        # fixed-budget legs differ by capacity/selection, not numerics):
        # bf16 drift is exactly 0.0 (identical tokens => identical
        # scores), int8 stays above chance-ish and inside the drift band
        # (local runs: drift 0.088, rank_acc 0.487 vs f32's 0.575)
        ("rank_acc_drift.bf16", "max_abs", 0.0),
        ("rank_acc_drift.int8", "max_abs", 0.15),
        ("equal_blocks.int8.rank_acc", "min_abs", 0.4),
        ("wall_s", "max_ratio", 5.0),
    ],
    "sharded_serving": [
        # the sharded-engine contract: token-identical generations on
        # the (data=2, model=2) mesh, full-length runs on both engines
        ("outputs_identical", "equal", None),
        ("single.tokens", "equal", None),
        ("sharded.tokens", "equal", None),
        # throughput on SIMULATED devices measures collective overhead,
        # not scaling — loose collapse guards only
        ("sharded.tok_per_s", "min_ratio", 0.2),
        ("sharded_over_single_x", "min_ratio", 0.25),
    ],
}


def _get(d: dict, path: str):
    for part in path.split("."):
        d = d[part]
    return d


def _fmt(v) -> str:
    if isinstance(v, bool) or not isinstance(v, (int, float)):
        return repr(v)
    if isinstance(v, int):
        return str(v)
    return f"{v:.4g}"


def _rule_label(kind: str, bound) -> str:
    return {"equal": "==", "max_ratio": f"<= ref x{bound}",
            "min_ratio": f">= ref x{bound}", "min_frac": f">= ref x{bound}",
            "min_abs": f">= {bound}", "max_abs": f"<= {bound}"}[kind]


def _non_finite(v) -> str | None:
    """Why ``v`` can't be gated, or ``None`` if it can. A gated metric
    that is ``None`` (the summarizer's empty-population marker) or NaN
    must fail LOUDLY: ``NaN > x`` and ``NaN < x`` are both False, so a
    NaN that slipped into a reference would sail through every ratio
    rule and silently disable the gate forever."""
    if v is None:
        return "None (empty-population marker)"
    if isinstance(v, float) and not math.isfinite(v):
        return f"non-finite ({v!r})"
    return None


def check(new_path: str, ref_path: str):
    """Returns (problems, rows): failure strings plus one comparison row
    per rule — (benchmark, metric, new, ref, rule, ok) — for the
    markdown summary table."""
    with open(new_path) as f:
        new = json.load(f)
    with open(ref_path) as f:
        ref = json.load(f)
    bench = new.get("benchmark")
    rules = RULES.get(bench)
    if rules is None:
        return [f"{new_path}: unknown benchmark {bench!r}"], []
    problems = []
    rows = []
    for path, kind, bound in rules:
        try:
            nv, rv = _get(new, path), _get(ref, path)
        except KeyError as e:
            problems.append(f"{bench}.{path}: missing key {e}")
            rows.append((bench, path, "missing", "missing",
                         _rule_label(kind, bound), False))
            continue
        bad = [(side, reason)
               for side, v in (("current", nv), ("reference", rv))
               if (reason := _non_finite(v))]
        if bad:
            for side, reason in bad:
                problems.append(
                    f"{bench}.{path}: {side} value is {reason} — gated "
                    f"metrics must be finite"
                    + ("; re-bless the reference"
                       if side == "reference" else ""))
            rows.append((bench, path, _fmt(nv), _fmt(rv),
                         _rule_label(kind, bound), False))
            continue
        problem = None
        if kind == "equal" and nv != rv:
            problem = f"{bench}.{path}: {nv!r} != reference {rv!r}"
        elif kind == "max_ratio" and rv > 0 and nv > rv * bound:
            problem = (f"{bench}.{path}: {nv:.4g} exceeds reference "
                       f"{rv:.4g} x{bound} (regression)")
        elif kind == "min_ratio" and nv < rv * bound:
            problem = (f"{bench}.{path}: {nv:.4g} below reference "
                       f"{rv:.4g} x{bound} (regression)")
        elif kind == "min_frac" and nv < rv * bound:
            problem = (f"{bench}.{path}: {nv:.4g} below reference "
                       f"{rv:.4g} x{bound}")
        elif kind == "min_abs" and nv < bound:
            problem = (f"{bench}.{path}: {nv:.4g} below absolute floor "
                       f"{bound} (regression)")
        elif kind == "max_abs" and nv > bound:
            problem = (f"{bench}.{path}: {nv:.4g} exceeds absolute "
                       f"ceiling {bound} (regression)")
        if problem is not None:
            problems.append(problem)
        rows.append((bench, path, _fmt(nv), _fmt(rv),
                     _rule_label(kind, bound), problem is None))
    return problems, rows


def render_markdown(rows, failures) -> str:
    """Current-vs-reference comparison as a GitHub markdown table (the
    bench-smoke job appends it to $GITHUB_STEP_SUMMARY so regressions
    are readable without downloading artifacts)."""
    lines = ["## Benchmark trajectory (current vs `benchmarks/reference/`)",
             "",
             "| benchmark | metric | current | reference | gate | status |",
             "|---|---|---:|---:|---|---|"]
    for bench, path, nv, rv, rule, ok in rows:
        status = "ok" if ok else "**REGRESSION**"
        lines.append(f"| {bench} | `{path}` | {nv} | {rv} | {rule} "
                     f"| {status} |")
    lines.append("")
    lines.append("All gates passed." if not failures
                 else f"**{len(failures)} gate(s) failed.**")
    lines.append("")
    return "\n".join(lines)


def main() -> int:
    ap = argparse.ArgumentParser()
    ap.add_argument("artifacts", nargs="+", help="BENCH_*.json files")
    ap.add_argument("--ref-dir", default=REF_DIR)
    ap.add_argument("--summary", default=os.environ.get(
        "GITHUB_STEP_SUMMARY"),
        help="append the markdown comparison table to this file "
             "(defaults to $GITHUB_STEP_SUMMARY when set)")
    args = ap.parse_args()
    failures = []
    all_rows = []
    for art in args.artifacts:
        ref = os.path.join(args.ref_dir, os.path.basename(art))
        if not os.path.exists(ref):
            msg = (f"{art}: no reference at {ref} "
                   f"(commit one to start the trajectory)")
            print(f"[REGRESSION] {msg}")
            failures.append(msg)
            all_rows.append((os.path.basename(art), "(reference file)",
                             "present", "MISSING", "exists", False))
            continue
        probs, rows = check(art, ref)
        all_rows.extend(rows)
        tag = "OK" if not probs else "REGRESSION"
        print(f"[{tag}] {os.path.basename(art)} vs {ref}")
        for p in probs:
            print(f"    {p}")
        failures.extend(probs)
    if args.summary:
        with open(args.summary, "a") as f:
            f.write(render_markdown(all_rows, failures))
    return 1 if failures else 0


if __name__ == "__main__":
    sys.exit(main())
