"""Table 2: answer-aggregation strategies over the SAME trace set —
majority voting vs STEP-scorer-weighted voting (the paper also compares
a 7B PRM; our stand-in for an external reward model is an oracle-free
confidence weighting)."""
from __future__ import annotations

import random

import numpy as np

from benchmarks.common import load_artifacts
from repro.core.pipeline import sample_traces
from repro.core.scorer import scorer_score
from repro.core.voting import majority_vote, weighted_vote
from repro.data.arithmetic import gen_problem
from repro.data.tokenizer import get_tokenizer
from repro.models.model import forward_full

import jax.numpy as jnp

N_PROBLEMS = 12
N_SAMPLES = 8


def run(verbose: bool = False):
    params, scorer, cfg = load_artifacts()
    tok = get_tokenizer()
    rng = random.Random(57)
    problems = [gen_problem(rng, (6, 9)) for _ in range(N_PROBLEMS)]
    traces = sample_traces(params, cfg, problems, N_SAMPLES, seed=57)

    by_problem: dict = {}
    for t in traces:
        by_problem.setdefault(id(t.problem), (t.problem, []))[1].append(t)

    n_major = n_weighted = n_conf = 0
    for _, (p, ts) in by_problem.items():
        answers, scores, confs = [], [], []
        for t in ts:
            ids = t.token_ids
            toks = jnp.asarray(np.array(ids, np.int32)[None])
            out = forward_full(params, cfg, toks)
            hidden = np.asarray(out["hidden"][0], np.float32)
            stop = ids.index(tok.think_close_id) \
                if tok.think_close_id in ids else len(ids)
            bpos = [i for i in range(t.prompt_len, stop)
                    if ids[i] == tok.step_id]
            s = float(np.mean(np.asarray(scorer_score(
                scorer, jnp.asarray(hidden[bpos]))))) if bpos else 0.5
            logits = np.asarray(out["logits"][0], np.float32)
            lp = logits - np.log(
                np.exp(logits).sum(-1, keepdims=True))
            conf = float(np.exp(np.mean(
                [lp[i, ids[i + 1]] for i in range(t.prompt_len - 1,
                                                  len(ids) - 1)])))
            answers.append(t.answer)
            scores.append(s)
            confs.append(conf)
        gold = str(p.answer)
        a_m = majority_vote(answers)
        a_w = weighted_vote(answers, scores)
        a_c = weighted_vote(answers, confs)
        n_major += (a_m == gold)
        n_weighted += (a_w == gold)
        n_conf += (a_c == gold)
    n = len(by_problem)
    return [{"voting": "majority", "accuracy": n_major / n},
            {"voting": "confidence_weighted", "accuracy": n_conf / n},
            {"voting": "step_weighted", "accuracy": n_weighted / n}]


def main():
    rows = run()
    print("table2_voting: voting, accuracy")
    for r in rows:
        print(f"{r['voting']},{r['accuracy']:.3f}")
    return rows


if __name__ == "__main__":
    main()
