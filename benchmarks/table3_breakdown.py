"""Table 3: wait vs decode time breakdown per method (paper §5.3.4).

Reproduces the paper's key system finding: pruning methods reduce
decode time by generating fewer tokens, but only STEP's memory-aware
trigger drives WAIT to exactly zero (no preemption queue ever forms)."""
from __future__ import annotations

from benchmarks.common import load_artifacts
from repro.serving import EngineConfig, SamplingParams, evaluate_method, \
    make_problems

N_PROBLEMS = 6
N_TRACES = 16
NUM_BLOCKS = 56   # tight pool: heavy preemption pressure for baselines
MAX_NEW = 120


def run(verbose: bool = False):
    params, scorer, cfg = load_artifacts()
    problems = make_problems(N_PROBLEMS, seed=23, n_steps=(6, 9))
    # per-trace prefill: the paper's Table-3 accounting baseline predates
    # prefix sharing — keep its phase breakdown reproducible
    # (docs/ENGINE.md)
    ecfg = EngineConfig(max_batch=N_TRACES, num_blocks=NUM_BLOCKS,
                        capacity=256, max_new_tokens=MAX_NEW,
                        sampling=SamplingParams(max_new_tokens=MAX_NEW),
                        share_prompt_prefix=False)
    rows = []
    for method in ("sc", "slimsc", "deepconf", "step"):
        pkw = {"warmup": 4} if method == "deepconf" else {}
        res = evaluate_method(method, params, cfg, problems, N_TRACES,
                              ecfg, scorer_params=scorer, policy_kwargs=pkw,
                              verbose=verbose)
        rows.append({"method": method,
                     "wait_s": res.total_wait_s,
                     "decode_s": res.total_decode_s,
                     "prefill_s": res.total_prefill_s,
                     "preemptions": res.num_preemptions})
    return rows


def main():
    rows = run()
    print("table3_breakdown: method, wait_s, decode_s, prefill_s, "
          "preemptions")
    for r in rows:
        print(f"{r['method']},{r['wait_s']:.2f},{r['decode_s']:.2f},"
              f"{r['prefill_s']:.2f},{r['preemptions']}")
    st = next(r for r in rows if r["method"] == "step")
    sc = next(r for r in rows if r["method"] == "sc")
    assert st["wait_s"] == 0.0, "STEP must eliminate waiting entirely"
    print(f"# STEP wait=0 (paper Table 3); SC wait={sc['wait_s']:.1f}s "
          f"with {sc['preemptions']} preemptions")
    return rows


if __name__ == "__main__":
    main()
