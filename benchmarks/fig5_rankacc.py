"""Fig. 5: pairwise ranking accuracy of the hidden-state step scorer vs
token-level confidence, as a function of the trace prefix fraction k%.

The paper's claim: the scorer separates correct from incorrect traces
EARLY (RankAcc well above 0.5 from 25% of steps) and beats mean token
confidence at every prefix."""
from __future__ import annotations

import numpy as np

from benchmarks.common import load_artifacts
from repro.core.pipeline import sample_traces
from repro.core.scorer import rank_accuracy, scorer_score
from repro.data.arithmetic import gen_problem
from repro.data.tokenizer import get_tokenizer
from repro.models.model import forward_full

import jax.numpy as jnp
import random

N_PROBLEMS = 12
N_SAMPLES = 8
PREFIXES = (0.25, 0.5, 0.75, 1.0)


def run(verbose: bool = False):
    params, scorer, cfg = load_artifacts()
    tok = get_tokenizer()
    rng = random.Random(41)
    problems = [gen_problem(rng, (6, 9)) for _ in range(N_PROBLEMS)]
    traces = sample_traces(params, cfg, problems, N_SAMPLES, seed=41)

    # per-trace: step-boundary hidden scores + token confidences by prefix
    per_q: dict = {}
    for t in traces:
        ids = t.token_ids
        stop = ids.index(tok.think_close_id) if tok.think_close_id in ids \
            else len(ids)
        toks = jnp.asarray(np.array(ids, np.int32)[None])
        out = forward_full(params, cfg, toks)
        hidden = np.asarray(out["hidden"][0], np.float32)
        logits = np.asarray(out["logits"][0], np.float32)
        bpos = [i for i in range(t.prompt_len, stop)
                if ids[i] == tok.step_id]
        if not bpos:
            continue
        sscores = np.asarray(scorer_score(scorer, jnp.asarray(hidden[bpos])))
        # token confidence: prob of the realised next token
        lp = logits[:-1] - np.log(np.exp(logits[:-1]).sum(-1, keepdims=True))
        conf = np.exp([lp[i, ids[i + 1]]
                       for i in range(t.prompt_len - 1, stop - 1)])
        key = id(t.problem)
        per_q.setdefault(key, {"pos": [], "neg": []})
        bucket = "pos" if t.correct else "neg"
        per_q[key][bucket].append((sscores, conf))

    rows = []
    for frac in PREFIXES:
        accs_s, accs_c = [], []
        for q in per_q.values():
            if not q["pos"] or not q["neg"]:
                continue

            def prefix_mean(arrs, f):
                return np.array([a[:max(1, int(len(a) * f))].mean()
                                 for a in arrs])

            sp = prefix_mean([p[0] for p in q["pos"]], frac)
            sn = prefix_mean([p[0] for p in q["neg"]], frac)
            cp = prefix_mean([p[1] for p in q["pos"]], frac)
            cn = prefix_mean([p[1] for p in q["neg"]], frac)
            accs_s.append(rank_accuracy(sp, sn))
            accs_c.append(rank_accuracy(cp, cn))
        rows.append({"prefix": frac,
                     "rankacc_scorer": float(np.nanmean(accs_s)),
                     "rankacc_confidence": float(np.nanmean(accs_c))})
    return rows


def main():
    rows = run()
    print("fig5_rankacc: prefix_frac, rankacc_scorer, rankacc_confidence")
    for r in rows:
        print(f"{r['prefix']},{r['rankacc_scorer']:.3f},"
              f"{r['rankacc_confidence']:.3f}")
    return rows


if __name__ == "__main__":
    main()
