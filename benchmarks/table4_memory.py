"""Table 4: GPU-memory sensitivity — STEP accuracy as the KV pool budget
varies (paper sweeps utilisation 0.5-0.9; smaller pools trigger pruning
earlier). The claim: accuracy is stable across budgets because the
scorer identifies promising traces early."""
from __future__ import annotations

from benchmarks.common import load_artifacts
from repro.serving import EngineConfig, SamplingParams, evaluate_method, \
    make_problems

N_PROBLEMS = 6
N_TRACES = 16
MAX_NEW = 120
# num_blocks fractions of the "full" pool (16 traces x 9 blocks each)
FRACTIONS = (0.5, 0.6, 0.7, 0.8, 0.9)
FULL_BLOCKS = 16 * 9


def run(verbose: bool = False):
    params, scorer, cfg = load_artifacts()
    problems = make_problems(N_PROBLEMS, seed=67, n_steps=(6, 9))
    rows = []
    for frac in FRACTIONS:
        blocks = max(8, int(FULL_BLOCKS * frac))
        # per-trace prefill: the sweep's "memory full" thresholds assume
        # every trace owns private prompt blocks; sharing would shift the
        # pruning onset per budget (docs/ENGINE.md, memory accounting)
        ecfg = EngineConfig(max_batch=N_TRACES, num_blocks=blocks,
                            capacity=256, max_new_tokens=MAX_NEW,
                            sampling=SamplingParams(max_new_tokens=MAX_NEW),
                            share_prompt_prefix=False)
        res = evaluate_method("step", params, cfg, problems, N_TRACES,
                              ecfg, scorer_params=scorer, verbose=verbose)
        rows.append({"memory_fraction": frac, "num_blocks": blocks,
                     "accuracy": res.accuracy,
                     "pruned": res.num_pruned,
                     "wait_s": res.total_wait_s})
    return rows


def main():
    rows = run()
    print("table4_memory: memory_fraction, num_blocks, accuracy, pruned, "
          "wait_s")
    for r in rows:
        print(f"{r['memory_fraction']},{r['num_blocks']},"
              f"{r['accuracy']:.3f},{r['pruned']},{r['wait_s']:.2f}")
    accs = [r["accuracy"] for r in rows]
    print(f"# accuracy spread: {max(accs) - min(accs):.3f} "
          f"(paper: stable within ~2 points); wait=0 at every budget")
    return rows


if __name__ == "__main__":
    main()
