"""Table 4: GPU-memory sensitivity — STEP accuracy as the KV pool budget
varies (paper sweeps utilisation 0.5-0.9; smaller pools trigger pruning
earlier). The claim: accuracy is stable across budgets because the
scorer identifies promising traces early.

``--kv-quant`` runs the quantized-pool leg instead: a FIXED HBM byte
budget is converted to ``num_blocks`` per ``kv_dtype`` via
``kv_quant.pool_block_bytes``, so cheaper pool dtypes literally buy more
blocks, and the engine serves the same STEP workload under each dtype.
Reported per dtype: blocks afforded, traces sustained to completion
(unpruned), accuracy, and the scorer's pooled pairwise rank accuracy
(the Fig. 5 metric, computed from engine step scores) — emitted as
``BENCH_kv_quant.json`` and gated by ``check_regression`` (int8 must
sustain >= 1.8x the f32 trace count at the same byte budget with rank
accuracy within the drift bound; bf16 must stay token-identical to
f32)."""
from __future__ import annotations

import argparse
import json
import os
import time

import numpy as np

from benchmarks.common import load_artifacts
from repro.core.pruning import make_policy
from repro.core.scorer import rank_accuracy
from repro.core.trace import TraceStatus
from repro.data.arithmetic import make_prompt
from repro.data.tokenizer import get_tokenizer
from repro.models import kv_quant
from repro.serving import Engine, EngineConfig, SamplingParams, \
    evaluate_method, make_problems

N_PROBLEMS = 6
N_TRACES = 16
MAX_NEW = 120
# num_blocks fractions of the "full" pool (16 traces x 9 blocks each)
FRACTIONS = (0.5, 0.6, 0.7, 0.8, 0.9)
FULL_BLOCKS = 16 * 9

# --kv-quant leg: the byte budget every dtype must fit in, expressed as
# the f32 pool size that makes STEP prune hard (the regime where extra
# blocks translate into sustained traces). Problems are a notch easier
# than the fraction sweep's (4-6 steps vs 6-9) so the sustained traces
# populate BOTH answer classes — the pooled rank-accuracy metric needs
# correct and incorrect finished traces (the tiny artifact model's
# sampled-correct rate is ~9%, see benchmarks/artifacts/info.json)
KVQ_PROBLEMS = 6
KVQ_N_STEPS = (4, 6)
KVQ_F32_BLOCKS = 14


def run(verbose: bool = False):
    params, scorer, cfg = load_artifacts()
    problems = make_problems(N_PROBLEMS, seed=67, n_steps=(6, 9))
    rows = []
    for frac in FRACTIONS:
        blocks = max(8, int(FULL_BLOCKS * frac))
        # per-trace prefill: the sweep's "memory full" thresholds assume
        # every trace owns private prompt blocks; sharing would shift the
        # pruning onset per budget (docs/ENGINE.md, memory accounting)
        ecfg = EngineConfig(max_batch=N_TRACES, num_blocks=blocks,
                            capacity=256, max_new_tokens=MAX_NEW,
                            sampling=SamplingParams(max_new_tokens=MAX_NEW),
                            share_prompt_prefix=False)
        res = evaluate_method("step", params, cfg, problems, N_TRACES,
                              ecfg, scorer_params=scorer, verbose=verbose)
        rows.append({"memory_fraction": frac, "num_blocks": blocks,
                     "accuracy": res.accuracy,
                     "pruned": res.num_pruned,
                     "wait_s": res.total_wait_s})
    return rows


def run_kv_quant(verbose: bool = False):
    """Fixed-HBM sweep over ``kv_dtype``: every dtype gets
    ``budget // pool_block_bytes(dtype)`` blocks and serves the same
    STEP workload. Sustained = traces finishing unpruned (deterministic:
    the engine RNG is seeded per serve). A separate equal-blocks bf16
    leg checks token identity against f32 — at the shared byte budget
    bf16 affords 2x the blocks, which changes the pruning schedule and
    thus the tokens by construction, so identity is only meaningful when
    nothing but the pool dtype differs."""
    params, scorer, cfg = load_artifacts()
    tok = get_tokenizer()
    problems = make_problems(KVQ_PROBLEMS, seed=67, n_steps=KVQ_N_STEPS)
    budget = KVQ_F32_BLOCKS * kv_quant.pool_block_bytes(cfg, "f32")

    dtypes = ["f32", "bf16", "int8"]
    if kv_quant.fp8_dtype() is not None:
        dtypes.append("fp8")  # informational; gates cover int8 only

    def serve_leg(dt, nb):
        ecfg = EngineConfig(
            max_batch=N_TRACES, num_blocks=nb, capacity=256,
            max_new_tokens=MAX_NEW, kv_dtype=dt,
            sampling=SamplingParams(max_new_tokens=MAX_NEW),
            share_prompt_prefix=False)
        sustained = pruned = correct_q = 0
        pos, neg = [], []
        toks = []
        for qid, p in enumerate(problems):
            eng = Engine(params, cfg, ecfg, make_policy("step"),
                         scorer_params=scorer)
            res = eng.serve(tok.encode(make_prompt(p), add_bos=True),
                            N_TRACES, request_id=qid)
            assert eng.pool_drained()
            pruned += res.num_pruned
            correct_q += int(res.answer is not None
                             and int(res.answer) == p.answer)
            for t in res.traces:
                toks.append(t.output_tokens)
                if t.status != TraceStatus.FINISHED:
                    continue
                sustained += 1
                ok = (t.answer is not None
                      and t.answer == str(p.answer))
                (pos if ok else neg).append(t.score)
        return sustained, pruned, correct_q, pos, neg, toks

    t0 = time.perf_counter()
    per_dtype = {}
    tokens_by_dtype = {}
    for dt in dtypes:
        nb = max(6, budget // kv_quant.pool_block_bytes(cfg, dt))
        sustained, pruned, correct_q, pos, neg, toks = serve_leg(dt, nb)
        # pooled Fig. 5 metric over the engine's own step scores; 0.5
        # (chance) if a class is empty — the blessed reference run must
        # have both (check when re-blessing)
        ra = (rank_accuracy(np.asarray(pos), np.asarray(neg))
              if pos and neg else 0.5)
        per_dtype[dt] = {
            "num_blocks": int(nb),
            "bytes_per_block": kv_quant.pool_block_bytes(cfg, dt),
            "sustained": int(sustained),
            "pruned": int(pruned),
            "accuracy": correct_q / len(problems),
            "rank_acc": float(ra),
            "pos_traces": len(pos),
            "neg_traces": len(neg),
        }
        tokens_by_dtype[dt] = toks
        if verbose:
            d = per_dtype[dt]
            print(f"  [{dt}] blocks={nb} sustained={sustained} "
                  f"pruned={pruned} acc={d['accuracy']:.2f} "
                  f"rank_acc={ra:.3f} (pos={len(pos)} neg={len(neg)})")

    # equal-blocks legs: every dtype at f32's block count, so only the
    # pool dtype differs. Two contracts live here. (1) bf16 tokens must
    # match f32 exactly — activations are bf16, so the f32 pool stores
    # identical values. (2) rank-accuracy drift is only a NUMERICS
    # statement on a comparable trace population: at the shared byte
    # budget each dtype sustains a different trace set (a capacity /
    # selection effect, the point of the sweep), so scorer drift is
    # measured here instead, where schedules coincide up to
    # quantization noise.
    f32_ra = per_dtype["f32"]["rank_acc"]
    nb_f32 = per_dtype["f32"]["num_blocks"]
    equal_blocks = {}
    for dt in dtypes:
        if dt == "f32":
            continue
        sustained, _, _, pos, neg, toks = serve_leg(dt, nb_f32)
        ra = (rank_accuracy(np.asarray(pos), np.asarray(neg))
              if pos and neg else 0.5)
        equal_blocks[dt] = {
            "sustained": int(sustained),
            "rank_acc": float(ra),
            "pos_traces": len(pos),
            "neg_traces": len(neg),
            "tokens_identical_f32": toks == tokens_by_dtype["f32"],
        }
        if verbose:
            print(f"  [{dt}@f32-blocks] rank_acc={ra:.3f} "
                  f"identical={equal_blocks[dt]['tokens_identical_f32']}")

    payload = {
        "benchmark": "kv_quant",
        "config": {"problems": KVQ_PROBLEMS, "traces": N_TRACES,
                   "max_new": MAX_NEW, "budget_bytes": budget},
        "dtypes": per_dtype,
        "equal_blocks": equal_blocks,
        "tokens_identical_bf16_f32":
            equal_blocks["bf16"]["tokens_identical_f32"],
        "traces_per_byte_ratio_int8_over_f32":
            per_dtype["int8"]["sustained"]
            / max(per_dtype["f32"]["sustained"], 1),
        "rank_acc_drift": {
            dt: abs(equal_blocks[dt]["rank_acc"] - f32_ra)
            for dt in equal_blocks},
        "wall_s": time.perf_counter() - t0,
    }
    return payload


def main():
    ap = argparse.ArgumentParser()
    ap.add_argument("--kv-quant", action="store_true",
                    help="run the fixed-HBM kv_dtype sweep instead of "
                         "the Table 4 fraction sweep")
    ap.add_argument("--out", default=None,
                    help="write the kv-quant payload to this JSON path "
                         "(default ../BENCH_kv_quant.json)")
    args = ap.parse_args()
    if args.kv_quant:
        payload = run_kv_quant(verbose=True)
        out = os.path.abspath(args.out or os.path.join(
            os.path.dirname(__file__), "..", "BENCH_kv_quant.json"))
        with open(out, "w") as f:
            json.dump(payload, f, indent=2)
        r = payload["traces_per_byte_ratio_int8_over_f32"]
        print(f"# int8 sustains x{r:.2f} the f32 traces at "
              f"{payload['config']['budget_bytes']} pool bytes "
              f"(gate: >= 1.8)")
        print(f"# wrote {out}")
        return payload

    rows = run()
    print("table4_memory: memory_fraction, num_blocks, accuracy, pruned, "
          "wait_s")
    for r in rows:
        print(f"{r['memory_fraction']},{r['num_blocks']},"
              f"{r['accuracy']:.3f},{r['pruned']},{r['wait_s']:.2f}")
    accs = [r["accuracy"] for r in rows]
    print(f"# accuracy spread: {max(accs) - min(accs):.3f} "
          f"(paper: stable within ~2 points); wait=0 at every budget")
    return rows


if __name__ == "__main__":
    main()
