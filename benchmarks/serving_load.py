"""Online-serving load benchmark: Poisson arrivals through the
continuous-batching scheduler.

Replays a seeded Poisson arrival trace against one engine (chunked
prefill + per-tick token budget on), records per-request TTFT / TPOT /
end-to-end latency, and writes the percentile summary to
``BENCH_serving.json`` — the artifact the CI benchmark-smoke job uploads
and regression-checks, starting the repo's perf trajectory.

``--multiturn`` runs the cross-request prefix-cache workload instead:
two multi-turn conversations over one shared system prompt, replayed
turn-by-turn with the cache off and on. Each turn's prompt extends the
previous one, so with the cache on every turn after the first forks the
parked blocks and prefills only the fresh suffix. Emits
``BENCH_prefix_cache.json`` (hit rate, prefill tokens saved, TTFT
on/off) and asserts the generated tokens are identical either way.

``--faults`` runs the fault-tolerance workload: the Poisson replay
served twice on identical requests — once fault-free, once under a
seeded transient fault plan (device-step failures retried with backoff,
a one-tick allocation stall) — asserting the faulted run recovers to
bit-identical outputs, then a chaos leg (NaN lane poisoning + an
impossible deadline) exercising quarantine and cancellation. Emits
``BENCH_faults.json`` (recovered steps, stalls, quarantines,
cancellations, and goodput under faults vs fault-free).

``--slo`` runs the multi-tenant SLO scenario suite: a 10x larger
workload (bursty arrival waves, heavy-tail prompt lengths, mixed
single-trace "chat" and 4-trace "reasoning" requests) served to a
premium tenant (weight 3, priority 1) and a batch tenant (weight 1,
priority 0, degradable SLO) through the weighted-fair TenantScheduler.
Emits ``BENCH_slo.json`` with the per-tenant TTFT/TPOT percentile and
SLO-attainment breakdown; the regression gate requires the premium
tenant's p99 TTFT to stay >= 2x better than the batch tenant's.

Uses randomly-initialised weights (perf numbers don't need a trained
model) so it runs in seconds on the CI CPU runners:

    PYTHONPATH=src python -m benchmarks.serving_load [--multiturn|--slo]
        [--out path.json]
"""
from __future__ import annotations

import argparse
import json
import os
import time

import jax
import numpy as np

from repro.configs.registry import serving_config
from repro.core.pruning import make_policy
from repro.core.trace import TraceStatus
from repro.data.tokenizer import get_tokenizer
from repro.data.arithmetic import make_prompt
from repro.models.init import init_params
from repro.serving import (SLO, CacheStats, Engine, EngineConfig, FaultPlan,
                           Request, SamplingParams, TenantScheduler,
                           make_problems, poisson_arrivals, summarize,
                           summarize_by_tenant)

N_REQUESTS = 6
N_TRACES = 4
MAX_NEW = 24
NUM_BLOCKS = 96
CAPACITY = 128
ARRIVAL_RATE = 4.0      # requests / second (open-loop Poisson)
PREFILL_CHUNK = 16
MAX_TOKENS_PER_STEP = 64
SEED = 1234


def build_requests(tok):
    problems = make_problems(N_REQUESTS, seed=SEED, n_steps=(8, 12))
    arrivals = poisson_arrivals(N_REQUESTS, ARRIVAL_RATE, seed=SEED)
    return [
        Request(request_id=i,
                prompt_tokens=tok.encode(make_prompt(p), add_bos=True),
                n_traces=N_TRACES, policy=make_policy("sc"),
                arrival_time=at)
        for i, (p, at) in enumerate(zip(problems, arrivals))
    ]


def run(verbose: bool = False) -> dict:
    cfg = serving_config()
    params = init_params(cfg, jax.random.PRNGKey(0))
    tok = get_tokenizer()
    ecfg = EngineConfig(
        max_batch=N_REQUESTS * N_TRACES, num_blocks=NUM_BLOCKS,
        capacity=CAPACITY, max_new_tokens=MAX_NEW,
        sampling=SamplingParams(temperature=0.0, top_k=0, top_p=1.0,
                                max_new_tokens=MAX_NEW),
        prefill_chunk_size=PREFILL_CHUNK,
        max_tokens_per_step=MAX_TOKENS_PER_STEP,
        # cache off: the warmup replays request 0's prompt — a warm hit
        # would skip its prefill and shift the blessed latency numbers.
        # The cache gets its own workload (run_multiturn) below.
        prefix_cache=False)
    engine = Engine(params, cfg, ecfg, make_policy("sc"))

    # warm the jit caches (prefill, chunk prefill, decode) so the timed
    # replay measures scheduling, not compilation
    warm = build_requests(tok)[0]
    warm.arrival_time = 0.0
    engine.serve_batch([warm])

    requests = build_requests(tok)
    t0 = time.perf_counter()
    completions = []
    results = engine.serve_batch(
        requests, on_complete=lambda r: completions.append(r.request_id))
    wall = time.perf_counter() - t0

    assert len(completions) == len(requests), "streaming callback missed"
    for r in results:
        assert all(t.status == TraceStatus.FINISHED for t in r.traces)
        assert r.metrics is not None and r.metrics.ttft_s is not None
        assert r.metrics.first_token_s >= r.metrics.arrival_s
    assert engine.pool_drained()
    engine.block_mgr.check_invariants()

    summary = summarize([r.metrics for r in results])
    payload = {
        "benchmark": "serving_load",
        "config": {
            "n_requests": N_REQUESTS, "n_traces": N_TRACES,
            "max_new_tokens": MAX_NEW, "num_blocks": NUM_BLOCKS,
            "capacity": CAPACITY, "arrival_rate_per_s": ARRIVAL_RATE,
            "prefill_chunk_size": PREFILL_CHUNK,
            "max_tokens_per_step": MAX_TOKENS_PER_STEP, "seed": SEED,
        },
        "wall_s": wall,
        **summary,
    }
    if verbose:
        print(f"serving_load: {summary['num_completed']}/{N_REQUESTS} "
              f"requests, {summary['total_output_tokens']} tokens "
              f"in {wall:.2f}s "
              f"({summary['throughput_tok_per_s']:.1f} tok/s)")
        print(f"  ttft  p50={summary['ttft_s']['p50']:.3f}s "
              f"p99={summary['ttft_s']['p99']:.3f}s")
        print(f"  tpot  p50={summary['tpot_s']['p50'] * 1e3:.1f}ms "
              f"p99={summary['tpot_s']['p99'] * 1e3:.1f}ms")
        print(f"  e2e   p50={summary['e2e_s']['p50']:.3f}s "
              f"p99={summary['e2e_s']['p99']:.3f}s")
    return payload


# ---------------------------------------------------------------------------
# multi-turn / shared-template workload (cross-request prefix cache)
# ---------------------------------------------------------------------------

MT_TURNS = 10
MT_CONVS = 2
MT_MAX_NEW = 8
MT_NUM_BLOCKS = 128
MT_CAPACITY = 320
# the shared "system prompt": ~169 tokens of template every conversation
# starts from (10+ full KV blocks reusable across every turn)
SYS_TEXT = "".join(f"{i % 10}+{(i + 3) % 10}-{(i + 7) % 10}= "
                   for i in range(24))


def _mt_engine(params, cfg, prefix_cache: bool) -> Engine:
    ecfg = EngineConfig(
        max_batch=2 * MT_CONVS, num_blocks=MT_NUM_BLOCKS,
        capacity=MT_CAPACITY, max_new_tokens=MT_MAX_NEW,
        sampling=SamplingParams(temperature=0.0, top_k=0, top_p=1.0,
                                max_new_tokens=MT_MAX_NEW),
        prefill_chunk_size=PREFILL_CHUNK,
        prefix_cache=prefix_cache)
    return Engine(params, cfg, ecfg, make_policy("sc"))


def _mt_replay(engine: Engine, tok):
    """Drive the conversations turn-by-turn; each turn's prompt is the
    full history (system prompt + prior turns + responses)."""
    sys_ids = tok.encode(SYS_TEXT, add_bos=True)
    histories = [list(sys_ids) for _ in range(MT_CONVS)]
    responses = [[] for _ in range(MT_CONVS)]
    metrics = []
    t0 = time.perf_counter()
    for turn in range(MT_TURNS):
        reqs = []
        for c in range(MT_CONVS):
            user = tok.encode(
                f"{(2 * turn + c) % 10}+{(turn + 3 * c) % 10}=",
                add_bos=False)
            histories[c] = histories[c] + user
            reqs.append(Request(request_id=turn * MT_CONVS + c,
                                prompt_tokens=list(histories[c]),
                                n_traces=1, policy=make_policy("sc")))
        results = engine.serve_batch(reqs)
        for c, r in enumerate(results):
            out = [t for t in r.traces[0].output_tokens
                   if t != tok.eos_id]
            histories[c] = histories[c] + out
            responses[c].append(out)
            metrics.append(r.metrics)
    wall = time.perf_counter() - t0
    assert all(m.first_token_s is not None for m in metrics)
    assert engine.pool_drained()
    engine.block_mgr.check_invariants()
    return responses, metrics, wall


def run_multiturn(verbose: bool = False) -> dict:
    cfg = serving_config()
    params = init_params(cfg, jax.random.PRNGKey(0))
    tok = get_tokenizer()
    sides = {}
    for mode in ("off", "on"):
        engine = _mt_engine(params, cfg, prefix_cache=(mode == "on"))
        # jit warmup on an unrelated prompt, then forget its KV so the
        # timed replay starts from a cold cache
        engine.serve_batch([Request(
            request_id=0, n_traces=1, policy=make_policy("sc"),
            prompt_tokens=tok.encode("9*9-8+7-6+5= " * 4, add_bos=True))])
        if engine.prefix_cache is not None:
            engine.prefix_cache.clear()
            engine.prefix_cache.stats = CacheStats()
        responses, metrics, wall = _mt_replay(engine, tok)
        sides[mode] = (responses, summarize(metrics), wall)
    identical = sides["on"][0] == sides["off"][0]
    assert identical, "prefix cache changed the generated tokens"
    (_, on, wall_on), (_, off, wall_off) = sides["on"], sides["off"]
    payload = {
        "benchmark": "prefix_cache",
        "config": {
            "turns": MT_TURNS, "conversations": MT_CONVS,
            "max_new_tokens": MT_MAX_NEW, "num_blocks": MT_NUM_BLOCKS,
            "capacity": MT_CAPACITY, "prefill_chunk_size": PREFILL_CHUNK,
            "system_prompt_tokens": len(tok.encode(SYS_TEXT,
                                                   add_bos=True)),
        },
        "outputs_identical": identical,
        "num_completed": on["num_completed"],
        "prefix_hit_rate": on["prefix_hit_rate"],
        "total_prompt_tokens": on["total_prompt_tokens"],
        "total_cached_tokens": on["total_cached_tokens"],
        "prefill_tokens_saved": on["total_cached_tokens"],
        "ttft_speedup_x": off["mean_ttft_s"] / on["mean_ttft_s"],
        "cache_on": {"mean_ttft_s": on["mean_ttft_s"],
                     "total_prefill_s": on["total_prefill_s"],
                     "wall_s": wall_on},
        "cache_off": {"mean_ttft_s": off["mean_ttft_s"],
                      "total_prefill_s": off["total_prefill_s"],
                      "wall_s": wall_off},
    }
    if verbose:
        print(f"prefix_cache: {on['num_completed']} turns, "
              f"hit_rate={payload['prefix_hit_rate']:.3f} "
              f"({on['total_cached_tokens']}/{on['total_prompt_tokens']} "
              f"prompt tokens from cache)")
        print(f"  ttft  on={on['mean_ttft_s'] * 1e3:.1f}ms "
              f"off={off['mean_ttft_s'] * 1e3:.1f}ms "
              f"speedup={payload['ttft_speedup_x']:.2f}x")
        print(f"  prefill  on={on['total_prefill_s']:.3f}s "
              f"off={off['total_prefill_s']:.3f}s")
    return payload


# ---------------------------------------------------------------------------
# multi-tenant SLO scenario suite (bursty waves, heavy tails, tenant mix)
# ---------------------------------------------------------------------------

SLO_WAVES = 6            # bursty arrivals: WAVES x WAVE_SIZE requests
SLO_WAVE_SIZE = 10       # (10x the Poisson replay's request count)
SLO_PERIOD_S = 1.2       # wave spacing — each wave lands as a burst
SLO_SPREAD_S = 0.25      # intra-wave arrival jitter
SLO_MAX_BATCH = 16       # decode slots: each wave oversubscribes them
SLO_NUM_BLOCKS = 192
SLO_CAPACITY = 256
SLO_CHAT_MAX_NEW = 8     # per-request max_new_tokens overrides
SLO_REASON_MAX_NEW = 16
SLO_TENANTS = {"premium": 3.0, "batch": 1.0}
# premium: interactive tier — strict-ish TTFT it should comfortably make
# because priority-1 admission jumps every burst's queue. batch: best
# effort — a tight TTFT objective it will miss under bursts, which is
# what drives SLO admission to degrade its reasoning fan-out.
SLO_PREMIUM = SLO(ttft_s=2.5, tpot_s=1.0)
SLO_BATCH = SLO(ttft_s=0.8, tpot_s=1.0, min_traces=1)


def bursty_arrivals(n: int, wave_size: int, period_s: float,
                    spread_s: float, seed: int) -> list:
    """Arrival offsets for bursty waves: request i lands in wave
    i // wave_size at the wave instant plus uniform jitter — the
    flash-crowd load shape (vs. the smooth Poisson trace)."""
    rng = np.random.default_rng(seed)
    return [(i // wave_size) * period_s + float(rng.uniform(0.0, spread_s))
            for i in range(n)]


def heavy_tail_lengths(n: int, seed: int, median: float = 24.0,
                       sigma: float = 0.9, cap: int = 120) -> list:
    """Log-normal filler-token counts: most prompts short, a heavy tail
    of long-context stragglers (capped so prompts fit ``SLO_CAPACITY``)."""
    rng = np.random.default_rng(seed)
    return [int(min(rng.lognormal(np.log(median), sigma), cap))
            for _ in range(n)]


def build_slo_requests(tok):
    n = SLO_WAVES * SLO_WAVE_SIZE
    problems = make_problems(n, seed=SEED, n_steps=(4, 10))
    arrivals = bursty_arrivals(n, SLO_WAVE_SIZE, SLO_PERIOD_S,
                               SLO_SPREAD_S, seed=SEED)
    fillers = heavy_tail_lengths(n, seed=SEED + 1)
    # ~digit soup a char-level tokenizer maps ~1:1 to tokens; sliced per
    # request to the sampled heavy-tail length
    filler_ids = tok.encode("".join(f"{i % 10}+{(i + 3) % 10}= "
                                    for i in range(64)), add_bos=False)
    reqs = []
    for i, (p, at, fill) in enumerate(zip(problems, arrivals, fillers)):
        chat = i % 2 == 0           # single-trace interactive request
        premium = i % 3 == 0        # 1/3 premium, 2/3 batch
        prompt = tok.encode(make_prompt(p), add_bos=True)
        prompt = prompt[:1] + filler_ids[:fill] + prompt[1:]
        reqs.append(Request(
            request_id=i, prompt_tokens=prompt,
            n_traces=1 if chat else 4,
            policy=make_policy("sc"),
            arrival_time=at,
            max_new_tokens=(SLO_CHAT_MAX_NEW if chat
                            else SLO_REASON_MAX_NEW),
            tenant="premium" if premium else "batch",
            priority=1 if premium else 0,
            slo=SLO_PREMIUM if premium else SLO_BATCH))
    return reqs


def run_slo(verbose: bool = False) -> dict:
    cfg = serving_config()
    params = init_params(cfg, jax.random.PRNGKey(0))
    tok = get_tokenizer()
    ecfg = EngineConfig(
        max_batch=SLO_MAX_BATCH, num_blocks=SLO_NUM_BLOCKS,
        capacity=SLO_CAPACITY, max_new_tokens=SLO_REASON_MAX_NEW,
        sampling=SamplingParams(temperature=0.0, top_k=0, top_p=1.0,
                                max_new_tokens=SLO_REASON_MAX_NEW),
        prefill_chunk_size=PREFILL_CHUNK,
        max_tokens_per_step=MAX_TOKENS_PER_STEP,
        prefix_cache=False)
    engine = Engine(params, cfg, ecfg, make_policy("sc"),
                    scheduler=TenantScheduler(weights=SLO_TENANTS))

    # jit warmup outside the timed replay
    warm = build_slo_requests(tok)[0]
    warm.arrival_time = 0.0
    engine.serve_batch([warm])

    requests = build_slo_requests(tok)
    t0 = time.perf_counter()
    results = engine.serve_batch(requests)
    wall = time.perf_counter() - t0

    assert engine.pool_drained()
    engine.block_mgr.check_invariants()
    metrics = [r.metrics for r in results]
    assert all(m is not None and m.finished_s is not None for m in metrics)

    overall = summarize(metrics)
    tenants = summarize_by_tenant(metrics)
    ratio = (tenants["batch"]["ttft_s"]["p99"]
             / max(tenants["premium"]["ttft_s"]["p99"], 1e-9))
    payload = {
        "benchmark": "slo_serving",
        "config": {
            "n_requests": len(requests), "waves": SLO_WAVES,
            "wave_size": SLO_WAVE_SIZE, "period_s": SLO_PERIOD_S,
            "max_batch": SLO_MAX_BATCH, "num_blocks": SLO_NUM_BLOCKS,
            "capacity": SLO_CAPACITY,
            "max_tokens_per_step": MAX_TOKENS_PER_STEP,
            "prefill_chunk_size": PREFILL_CHUNK,
            "tenant_weights": SLO_TENANTS,
            "premium_slo_ttft_s": SLO_PREMIUM.ttft_s,
            "batch_slo_ttft_s": SLO_BATCH.ttft_s, "seed": SEED,
        },
        "wall_s": wall,
        "num_requests": overall["num_requests"],
        "num_completed": overall["num_completed"],
        "total_output_tokens": overall["total_output_tokens"],
        "throughput_tok_per_s": overall["throughput_tok_per_s"],
        "degraded_traces": overall["degraded_traces"],
        "num_pruned": overall["num_pruned"],
        "ttft_p99_ratio_low_over_high": ratio,
        "tenants": tenants,
    }
    if verbose:
        print(f"slo_serving: {overall['num_completed']}"
              f"/{overall['num_requests']} requests, "
              f"{overall['total_output_tokens']} tokens in {wall:.2f}s "
              f"({overall['throughput_tok_per_s']:.1f} tok/s), "
              f"degraded_traces={overall['degraded_traces']}")
        for name, t in tenants.items():
            att = t["slo"]["ttft_attainment"]
            print(f"  [{name}] n={t['num_requests']} "
                  f"ttft p50={t['ttft_s']['p50']:.3f}s "
                  f"p99={t['ttft_s']['p99']:.3f}s "
                  f"ttft_slo={'n/a' if att is None else f'{att:.2f}'} "
                  f"degraded={t['degraded_traces']}")
        print(f"  ttft p99 batch/premium = {ratio:.2f}x")
    return payload


# ---------------------------------------------------------------------------
# fault-tolerance workload (retry/degrade recovery, quarantine, deadlines)
# ---------------------------------------------------------------------------

# transient-only plan: two consecutive device-step failures at tick 2
# (retried within the retry_limit=3 budget -> recovered, no degrade
# rung), a one-tick allocation stall at tick 5 (below shed_after), and
# one more step failure at tick 9. Recovery must be invisible: the
# faulted replay produces bit-identical tokens to the fault-free one.
FAULT_TRANSIENT_PLAN = "step@2x2,alloc@5,step@9"
# chaos leg adds a NaN burst poisoning decode lane 1 at tick 6 — that
# lane is quarantined (FAILED) while its siblings finish untouched.
FAULT_CHAOS_PLAN = FAULT_TRANSIENT_PLAN + ",nan@6:slot=1"


def _fault_engine(params, cfg, plan: str | None) -> Engine:
    # faults=None explicitly: the engine default reads REPRO_FAULTS, and
    # a CI chaos env leaking into the fault-free baseline would break
    # the identity comparison. The plan is attached after jit warmup.
    ecfg = EngineConfig(
        max_batch=N_REQUESTS * N_TRACES, num_blocks=NUM_BLOCKS,
        capacity=CAPACITY, max_new_tokens=MAX_NEW,
        sampling=SamplingParams(temperature=0.0, top_k=0, top_p=1.0,
                                max_new_tokens=MAX_NEW),
        prefill_chunk_size=PREFILL_CHUNK,
        max_tokens_per_step=MAX_TOKENS_PER_STEP,
        prefix_cache=False, faults=None)
    engine = Engine(params, cfg, ecfg, make_policy("sc"))
    tok = get_tokenizer()
    warm = build_requests(tok)[0]
    warm.arrival_time = 0.0
    engine.serve_batch([warm])
    if plan is not None:
        engine.fault_plan = FaultPlan.parse(plan, seed=ecfg.seed)
    return engine


def _fault_snapshot(results):
    return [[(list(t.output_tokens), t.status.name) for t in r.traces]
            for r in results]


def run_faults(verbose: bool = False) -> dict:
    cfg = serving_config()
    params = init_params(cfg, jax.random.PRNGKey(0))
    tok = get_tokenizer()

    # identity leg: fault-free vs transient-fault replay of the same
    # Poisson trace. Greedy decode + recovery that consumes no RNG means
    # the snapshots must match token-for-token.
    sides = {}
    for mode, plan in (("clean", None), ("faulted", FAULT_TRANSIENT_PLAN)):
        engine = _fault_engine(params, cfg, plan)
        requests = build_requests(tok)
        t0 = time.perf_counter()
        results = engine.serve_batch(requests)
        wall = time.perf_counter() - t0
        assert engine.pool_drained()
        engine.check_integrity()
        tokens = sum(r.metrics.output_tokens for r in results
                     if r.status == "completed")
        sides[mode] = (_fault_snapshot(results), tokens, wall,
                       engine.fault_stats)
    identical = sides["faulted"][0] == sides["clean"][0]
    assert identical, "fault recovery changed the generated tokens"
    stats = sides["faulted"][3]
    assert stats.recovered_steps == 2 and stats.degraded_horizon == 0, \
        "transient plan was expected to recover without degrading"
    goodput = {m: sides[m][1] / sides[m][2] for m in sides}
    ratio = goodput["faulted"] / goodput["clean"]

    # chaos leg: NaN lane poisoning + an unmeetable deadline on the last
    # request — quarantine and cancellation on top of the retry path.
    engine = _fault_engine(params, cfg, FAULT_CHAOS_PLAN)
    requests = build_requests(tok)
    requests[-1].deadline = 0.0
    t0 = time.perf_counter()
    results = engine.serve_batch(requests)
    chaos_wall = time.perf_counter() - t0
    assert engine.pool_drained()
    engine.check_integrity()
    chaos = summarize([r.metrics for r in results])
    cstats = engine.fault_stats
    assert cstats.nan_quarantined == 1, "NaN burst missed its lane"
    assert chaos["num_deadline_exceeded"] == 1

    payload = {
        "benchmark": "fault_serving",
        "config": {
            "n_requests": N_REQUESTS, "n_traces": N_TRACES,
            "max_new_tokens": MAX_NEW, "num_blocks": NUM_BLOCKS,
            "capacity": CAPACITY, "arrival_rate_per_s": ARRIVAL_RATE,
            "prefill_chunk_size": PREFILL_CHUNK,
            "max_tokens_per_step": MAX_TOKENS_PER_STEP, "seed": SEED,
            "transient_plan": FAULT_TRANSIENT_PLAN,
            "chaos_plan": FAULT_CHAOS_PLAN,
        },
        "outputs_identical": identical,
        "step_faults": stats.step_faults,
        "step_retries": stats.step_retries,
        "recovered_steps": stats.recovered_steps,
        "alloc_stalls": stats.alloc_faults,
        "degraded_to_dense": stats.degraded_to_dense,
        "degraded_horizon": stats.degraded_horizon,
        "goodput_ratio": ratio,
        "clean": {"wall_s": sides["clean"][2],
                  "goodput_tok_per_s": goodput["clean"]},
        "faulted": {"wall_s": sides["faulted"][2],
                    "goodput_tok_per_s": goodput["faulted"]},
        "chaos": {
            "wall_s": chaos_wall,
            "num_completed": chaos["num_completed"],
            "num_deadline_exceeded": chaos["num_deadline_exceeded"],
            "num_cancelled": chaos["num_cancelled"],
            "nan_quarantined": cstats.nan_quarantined,
            "failed_traces": chaos["failed_traces"],
        },
    }
    if verbose:
        print(f"fault_serving: outputs_identical={identical} "
              f"({stats.step_faults} step faults, "
              f"{stats.recovered_steps} recovered, "
              f"{stats.alloc_faults} alloc stalls)")
        print(f"  goodput  clean={goodput['clean']:.1f} tok/s "
              f"faulted={goodput['faulted']:.1f} tok/s "
              f"ratio={ratio:.2f}")
        print(f"  chaos    completed={chaos['num_completed']}"
              f"/{N_REQUESTS} "
              f"deadline_exceeded={chaos['num_deadline_exceeded']} "
              f"quarantined={cstats.nan_quarantined}")
    return payload


def main():
    ap = argparse.ArgumentParser()
    ap.add_argument("--multiturn", action="store_true",
                    help="run the prefix-cache conversation workload "
                         "instead of the Poisson load replay")
    ap.add_argument("--slo", action="store_true",
                    help="run the multi-tenant SLO scenario suite "
                         "(bursty waves, heavy-tail prompts, tenant mix) "
                         "instead of the Poisson load replay")
    ap.add_argument("--faults", action="store_true",
                    help="run the fault-tolerance workload (seeded "
                         "transient faults vs fault-free identity, plus "
                         "a NaN-quarantine / deadline chaos leg) instead "
                         "of the Poisson load replay")
    ap.add_argument("--out", default=None)
    args = ap.parse_args()
    if args.multiturn:
        payload, default_out = run_multiturn(verbose=True), \
            "BENCH_prefix_cache.json"
    elif args.slo:
        payload, default_out = run_slo(verbose=True), "BENCH_slo.json"
    elif args.faults:
        payload, default_out = run_faults(verbose=True), "BENCH_faults.json"
    else:
        payload, default_out = run(verbose=True), "BENCH_serving.json"
    out = os.path.abspath(args.out or os.path.join(
        os.path.dirname(__file__), "..", default_out))
    with open(out, "w") as f:
        json.dump(payload, f, indent=2)
    print(f"# wrote {out}")
    return payload


if __name__ == "__main__":
    main()
