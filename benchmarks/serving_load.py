"""Online-serving load benchmark: Poisson arrivals through the
continuous-batching scheduler.

Replays a seeded Poisson arrival trace against one engine (chunked
prefill + per-tick token budget on), records per-request TTFT / TPOT /
end-to-end latency, and writes the percentile summary to
``BENCH_serving.json`` — the artifact the CI benchmark-smoke job uploads
and regression-checks, starting the repo's perf trajectory.

Uses randomly-initialised weights (perf numbers don't need a trained
model) so it runs in seconds on the CI CPU runners:

    PYTHONPATH=src python -m benchmarks.serving_load [--out path.json]
"""
from __future__ import annotations

import argparse
import json
import os
import time

import jax

from repro.configs.registry import serving_config
from repro.core.pruning import make_policy
from repro.core.trace import TraceStatus
from repro.data.tokenizer import get_tokenizer
from repro.data.arithmetic import make_prompt
from repro.models.init import init_params
from repro.serving import (Engine, EngineConfig, Request, SamplingParams,
                           make_problems, poisson_arrivals, summarize)

N_REQUESTS = 6
N_TRACES = 4
MAX_NEW = 24
NUM_BLOCKS = 96
CAPACITY = 128
ARRIVAL_RATE = 4.0      # requests / second (open-loop Poisson)
PREFILL_CHUNK = 16
MAX_TOKENS_PER_STEP = 64
SEED = 1234


def build_requests(tok):
    problems = make_problems(N_REQUESTS, seed=SEED, n_steps=(8, 12))
    arrivals = poisson_arrivals(N_REQUESTS, ARRIVAL_RATE, seed=SEED)
    return [
        Request(request_id=i,
                prompt_tokens=tok.encode(make_prompt(p), add_bos=True),
                n_traces=N_TRACES, policy=make_policy("sc"),
                arrival_time=at)
        for i, (p, at) in enumerate(zip(problems, arrivals))
    ]


def run(verbose: bool = False) -> dict:
    cfg = serving_config()
    params = init_params(cfg, jax.random.PRNGKey(0))
    tok = get_tokenizer()
    ecfg = EngineConfig(
        max_batch=N_REQUESTS * N_TRACES, num_blocks=NUM_BLOCKS,
        capacity=CAPACITY, max_new_tokens=MAX_NEW,
        sampling=SamplingParams(temperature=0.0, top_k=0, top_p=1.0,
                                max_new_tokens=MAX_NEW),
        prefill_chunk_size=PREFILL_CHUNK,
        max_tokens_per_step=MAX_TOKENS_PER_STEP)
    engine = Engine(params, cfg, ecfg, make_policy("sc"))

    # warm the jit caches (prefill, chunk prefill, decode) so the timed
    # replay measures scheduling, not compilation
    warm = build_requests(tok)[0]
    warm.arrival_time = 0.0
    engine.serve_batch([warm])

    requests = build_requests(tok)
    t0 = time.perf_counter()
    completions = []
    results = engine.serve_batch(
        requests, on_complete=lambda r: completions.append(r.request_id))
    wall = time.perf_counter() - t0

    assert len(completions) == len(requests), "streaming callback missed"
    for r in results:
        assert all(t.status == TraceStatus.FINISHED for t in r.traces)
        assert r.metrics is not None and r.metrics.ttft_s is not None
        assert r.metrics.first_token_s >= r.metrics.arrival_s
    assert engine.block_mgr.free_blocks == engine.block_mgr.num_blocks - 1
    engine.block_mgr.check_invariants()

    summary = summarize([r.metrics for r in results])
    payload = {
        "benchmark": "serving_load",
        "config": {
            "n_requests": N_REQUESTS, "n_traces": N_TRACES,
            "max_new_tokens": MAX_NEW, "num_blocks": NUM_BLOCKS,
            "capacity": CAPACITY, "arrival_rate_per_s": ARRIVAL_RATE,
            "prefill_chunk_size": PREFILL_CHUNK,
            "max_tokens_per_step": MAX_TOKENS_PER_STEP, "seed": SEED,
        },
        "wall_s": wall,
        **summary,
    }
    if verbose:
        print(f"serving_load: {summary['num_completed']}/{N_REQUESTS} "
              f"requests, {summary['total_output_tokens']} tokens "
              f"in {wall:.2f}s "
              f"({summary['throughput_tok_per_s']:.1f} tok/s)")
        print(f"  ttft  p50={summary['ttft_s']['p50']:.3f}s "
              f"p99={summary['ttft_s']['p99']:.3f}s")
        print(f"  tpot  p50={summary['tpot_s']['p50'] * 1e3:.1f}ms "
              f"p99={summary['tpot_s']['p99'] * 1e3:.1f}ms")
        print(f"  e2e   p50={summary['e2e_s']['p50']:.3f}s "
              f"p99={summary['e2e_s']['p99']:.3f}s")
    return payload


def main():
    ap = argparse.ArgumentParser()
    ap.add_argument("--out", default=os.path.join(
        os.path.dirname(__file__), "..", "BENCH_serving.json"))
    args = ap.parse_args()
    payload = run(verbose=True)
    out = os.path.abspath(args.out)
    with open(out, "w") as f:
        json.dump(payload, f, indent=2)
    print(f"# wrote {out}")
    return payload


if __name__ == "__main__":
    main()
