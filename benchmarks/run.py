"""Run every benchmark (one per paper table/figure) and print results.

    PYTHONPATH=src python -m benchmarks.run [--only table1_main,...]

The serving benchmarks need the cached artifacts (built automatically on
first use: `python -m benchmarks.common`). The roofline table needs the
dry-run sweep (`python -m repro.launch.dryrun --all --both-meshes`).
"""
from __future__ import annotations

import argparse
import time
import traceback

BENCHES = [
    ("table1_main", "Table 1: acc/tokens/latency across methods"),
    ("table2_voting", "Table 2: voting strategies"),
    ("table3_breakdown", "Table 3: wait vs decode breakdown"),
    ("table4_memory", "Table 4: memory sensitivity"),
    ("fig4_scaling", "Fig 4: latency scaling with trace budget"),
    ("fig5_rankacc", "Fig 5: scorer vs confidence RankAcc"),
    ("overhead", "Appendix D: scorer overhead"),
    ("roofline", "Roofline table from the dry-run sweep"),
]


def main() -> int:
    ap = argparse.ArgumentParser()
    ap.add_argument("--only", default=None,
                    help="comma-separated benchmark names")
    args = ap.parse_args()
    only = set(args.only.split(",")) if args.only else None

    failures = 0
    for name, desc in BENCHES:
        if only and name not in only:
            continue
        print(f"\n=== {name}: {desc} ===", flush=True)
        t0 = time.time()
        try:
            mod = __import__(f"benchmarks.{name}", fromlist=["main"])
            mod.main()
            print(f"=== {name} done in {time.time() - t0:.1f}s ===",
                  flush=True)
        except Exception:
            failures += 1
            print(f"=== {name} FAILED ===")
            traceback.print_exc()
    print(f"\nbenchmarks: {'ALL OK' if not failures else f'{failures} FAILED'}")
    return 1 if failures else 0


if __name__ == "__main__":
    raise SystemExit(main())
