"""Decode-throughput benchmark: tokens/s vs ``decode_horizon``.

Serves the same greedy workload at decode_horizon 1, 4 and 8 and
measures end-to-end decode throughput. The horizon fuses K decode
iterations (model step + sampling + confidence + step-boundary scoring)
into one jitted ``lax.scan`` call, so the per-token host cost — jit
dispatch, device->host sync, the Python tick — amortizes over K tokens.
Outputs are asserted token-identical across horizons (greedy), so the
speedup is pure scheduling, not different generations.

Writes ``BENCH_decode.json`` — uploaded and regression-checked by the CI
benchmark-smoke job against ``benchmarks/reference/`` (the ``min_abs``
rule pins the acceptance floor: >= 1.5x tokens/s at horizon 8).

Uses randomly-initialised weights (perf numbers don't need a trained
model) on a deliberately small model variant: per-token model compute is
the same work at every horizon (the scan runs the full step per
iteration), so on the CI CPU runners — where XLA's per-op overhead makes
even the smoke model's step several ms — a larger model would only bury
the scheduling overhead this benchmark exists to measure. On a real
accelerator the step is orders of magnitude faster and the horizon's
amortization applies at full model scale.

    PYTHONPATH=src python -m benchmarks.decode_throughput [--out path.json]
"""
from __future__ import annotations

import argparse
import dataclasses
import json
import os
import time

import jax

from benchmarks.common import bench_requests
from repro.configs.registry import serving_config
from repro.core.pruning import make_policy
from repro.core.trace import TraceStatus
from repro.data.tokenizer import get_tokenizer
from repro.models.init import init_params
from repro.serving import Engine, EngineConfig, SamplingParams

HORIZONS = (1, 4, 8)
N_REQUESTS = 2
N_TRACES = 4
MAX_NEW = 96
NUM_BLOCKS = 96
CAPACITY = 128
SEED = 1234
# init seed chosen so the random-init model's greedy generations run to
# the token cap (several seeds emit EOS after ~10 tokens, leaving too
# few decode ticks to measure)
PARAMS_SEED = 1


def bench_config():
    """Small-batch decode-bound regime (see module docstring). Sized so
    the per-iteration model step leaves the per-tick host overhead as
    the dominant cost at horizon 1 — the quantity the horizon
    amortizes — with enough headroom over the CI gate's 1.5x floor to
    absorb shared-runner timing noise."""
    return dataclasses.replace(
        serving_config(), num_layers=1, d_model=32, d_ff=64,
        num_heads=2, num_kv_heads=2, head_dim=16)


def _requests(tok):
    return bench_requests(tok, N_REQUESTS, N_TRACES, seed=SEED)


def run(verbose: bool = False) -> dict:
    cfg = bench_config()
    params = init_params(cfg, jax.random.PRNGKey(PARAMS_SEED))
    tok = get_tokenizer()

    per_horizon = {}
    outputs = {}
    for K in HORIZONS:
        ecfg = EngineConfig(
            max_batch=N_REQUESTS * N_TRACES, num_blocks=NUM_BLOCKS,
            capacity=CAPACITY, max_new_tokens=MAX_NEW,
            sampling=SamplingParams(temperature=0.0, top_k=0, top_p=1.0,
                                    max_new_tokens=MAX_NEW),
            decode_horizon=K,
            # cache off: the warmup pass replays the timed prompts — warm
            # hits would skip prefill and distort the horizon comparison
            prefix_cache=False)
        engine = Engine(params, cfg, ecfg, make_policy("sc"))
        # warm the jit caches with the full request set (prefill has one
        # compile per prompt length, first-token flush one per admission
        # wave width) so the timed pass measures steady-state scheduling
        engine.serve_batch(_requests(tok))

        # best of 5 timed replays (CI runners are noisy; the scheduler
        # is deterministic so every replay generates identical traces)
        wall = float("inf")
        for _ in range(5):
            requests = _requests(tok)
            fallbacks_before = engine.horizon_fallbacks
            jax.block_until_ready(params)  # nothing in flight before t0
            t0 = time.perf_counter()
            results = engine.serve_batch(requests)
            # every timed quantity below is host data, so the device
            # work is fully drained here; block_until_ready pins t0
            wall = min(wall, time.perf_counter() - t0)

            for r in results:
                assert all(t.status == TraceStatus.FINISHED
                           for t in r.traces)
            assert (engine.block_mgr.free_blocks
                    == engine.block_mgr.num_blocks - 1)
            engine.block_mgr.check_invariants()

        tokens = sum(r.total_tokens for r in results)
        decode_s = sum(r.decode_s for r in results)
        outputs[K] = [
            [t.output_tokens for t in r.traces] for r in results]
        per_horizon[str(K)] = {
            "tokens": tokens,
            "wall_s": wall,
            "decode_s": decode_s,
            "tok_per_s": tokens / wall,
            # per-replay count (the schedule is deterministic, so every
            # replay falls back identically)
            "horizon_fallbacks": engine.horizon_fallbacks - fallbacks_before,
        }
        if verbose:
            print(f"decode_horizon={K}: {tokens} tokens in {wall:.2f}s "
                  f"({tokens / wall:.1f} tok/s, "
                  f"decode {decode_s:.2f}s)")

    # greedy outputs must be identical at every horizon — the speedup is
    # scheduling, not different generations
    for K in HORIZONS[1:]:
        assert outputs[K] == outputs[HORIZONS[0]], (
            f"horizon {K} diverged from horizon {HORIZONS[0]}")

    base = per_horizon["1"]["tok_per_s"]
    payload = {
        "benchmark": "decode_throughput",
        "config": {
            "n_requests": N_REQUESTS, "n_traces": N_TRACES,
            "max_new_tokens": MAX_NEW, "num_blocks": NUM_BLOCKS,
            "capacity": CAPACITY, "horizons": list(HORIZONS),
            "seed": SEED,
        },
        "horizons": per_horizon,
        "outputs_identical": True,
        "speedup_4x": per_horizon["4"]["tok_per_s"] / base,
        "speedup_8x": per_horizon["8"]["tok_per_s"] / base,
    }
    if verbose:
        print(f"speedup: x{payload['speedup_4x']:.2f} @K=4, "
              f"x{payload['speedup_8x']:.2f} @K=8")
    return payload


def main():
    ap = argparse.ArgumentParser()
    ap.add_argument("--out", default=os.path.join(
        os.path.dirname(__file__), "..", "BENCH_decode.json"))
    args = ap.parse_args()
    payload = run(verbose=True)
    out = os.path.abspath(args.out)
    with open(out, "w") as f:
        json.dump(payload, f, indent=2)
    print(f"# wrote {out}")
    return payload


if __name__ == "__main__":
    main()
