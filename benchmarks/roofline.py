"""Roofline report (deliverable g): read the dry-run JSONs and print the
three-term roofline table per (arch x shape x mesh) with the dominant
bottleneck and MODEL_FLOPS / HLO_FLOPS usefulness ratio.

Run the sweep first:
    PYTHONPATH=src python -m repro.launch.dryrun --all --both-meshes
"""
from __future__ import annotations

import glob
import json
import os

DRYRUN_DIR = os.path.join(os.path.dirname(__file__), "..", "experiments",
                          "dryrun")


def load_records():
    recs = []
    for path in sorted(glob.glob(os.path.join(DRYRUN_DIR, "*.json"))):
        with open(path) as f:
            recs.append(json.load(f))
    return recs


def run(verbose: bool = False):
    recs = load_records()
    return [r for r in recs if r.get("status") == "ok"]


def main():
    recs = load_records()
    if not recs:
        print("roofline: no dry-run records found — run "
              "`python -m repro.launch.dryrun --all --both-meshes` first")
        return []
    ok = [r for r in recs if r["status"] == "ok"]
    print("roofline: arch, shape, mesh, t_compute_s, t_memory_s, "
          "t_collective_s, dominant, useful_flops_ratio, hbm_gib_tpu_adj")
    for r in sorted(ok, key=lambda r: (r["mesh"], r["arch"], r["shape"])):
        ufr = r.get("useful_flops_ratio")
        print(f"{r['arch']},{r['shape']},{r['mesh']},"
              f"{r['t_compute_s']:.3e},{r['t_memory_s']:.3e},"
              f"{r['t_collective_s']:.3e},{r['dominant']},"
              f"{ufr if ufr is None else round(ufr, 3)},"
              f"{r.get('per_device_hbm_gib_tpu_adj', '?')}")
    skip = [r for r in recs if r["status"] == "skip"]
    fail = [r for r in recs if r["status"] == "fail"]
    for r in skip:
        print(f"# skip: {r['arch']} {r['shape']} {r['mesh']}: {r['reason']}")
    for r in fail:
        print(f"# FAIL: {r['arch']} {r['shape']} {r['mesh']}: {r['reason']}")
    print(f"# {len(ok)} ok / {len(skip)} skip / {len(fail)} fail")
    return ok


if __name__ == "__main__":
    main()
