"""Prefix-sharing benchmark: prompt prefill cost, shared vs per-trace.

The STEP paper's serving engine fans one prompt out into N traces. Without
prefix sharing the engine prefills the identical prompt N times (N
sequential full-sequence forwards) and each trace owns private copies of
the prompt's KV blocks. With ``EngineConfig.share_prompt_prefix`` the
prompt is prefilled ONCE, its blocks are forked (refcount++) into every
trace's block table, and each trace copy-on-writes only the prompt's tail
block when its first generated token lands there.

Reported per mode: prefill seconds, peak pool blocks in use, and the
generated tokens (greedy), which must be identical across modes.
"""
from __future__ import annotations

import argparse
import json
import os
import random
import time

import jax

from repro.configs.registry import serving_config
from repro.core.pruning import make_policy
from repro.data.arithmetic import gen_problem, make_prompt
from repro.data.tokenizer import get_tokenizer
from repro.models.init import init_params
from repro.serving import Engine, EngineConfig, SamplingParams

N_TRACES = 16
MAX_NEW = 32
NUM_BLOCKS = 160   # roomy pool: isolate prefill cost from contention
CAPACITY = 128
MIN_SPEEDUP = 5.0  # acceptance floor at N=16


def _build_engine(params, cfg, share: bool) -> Engine:
    ecfg = EngineConfig(
        max_batch=N_TRACES, num_blocks=NUM_BLOCKS, capacity=CAPACITY,
        max_new_tokens=MAX_NEW,
        sampling=SamplingParams(temperature=0.0, top_k=0, top_p=1.0,
                                max_new_tokens=MAX_NEW),
        share_prompt_prefix=share,
        # cache off: this benchmark isolates WITHIN-request sharing; a
        # cross-request hit would zero the very prefill being measured
        prefix_cache=False)
    return Engine(params, cfg, ecfg, make_policy("sc"))


def run(verbose: bool = False):
    cfg = serving_config()
    params = init_params(cfg, jax.random.PRNGKey(0))
    tok = get_tokenizer()
    # a multi-block prompt (> 2 full KV blocks) so full blocks are shared,
    # not just COW-duplicated tail blocks
    problem = gen_problem(random.Random(7), n_steps=(14, 16))
    prompt = tok.encode(make_prompt(problem), add_bos=True)
    if verbose:
        print(f"prompt: {len(prompt)} tokens "
              f"({-(-len(prompt) // cfg.kv_block_size)} blocks)")

    rows = []
    for share in (True, False):
        eng = _build_engine(params, cfg, share)
        eng.serve(prompt, 1)  # warm the jit caches outside the timed run
        t0 = time.perf_counter()
        res = eng.serve(prompt, N_TRACES)
        wall = time.perf_counter() - t0
        assert eng.block_mgr.free_blocks == eng.block_mgr.num_blocks - 1
        eng.block_mgr.check_invariants()
        rows.append({
            "mode": "shared" if share else "per-trace",
            "prefill_s": res.prefill_s,
            "wall_s": wall,
            "peak_blocks": res.peak_blocks_used,
            "tokens": [t.output_tokens for t in res.traces],
        })
        if verbose:
            print(f"  {rows[-1]['mode']}: prefill={res.prefill_s:.3f}s "
                  f"wall={wall:.2f}s peak_blocks={res.peak_blocks_used}")
    return rows


def main():
    ap = argparse.ArgumentParser()
    ap.add_argument("--out", default=os.path.join(
        os.path.dirname(__file__), "..", "BENCH_prefill_sharing.json"))
    args, _ = ap.parse_known_args()
    rows = run(verbose=True)
    shared = next(r for r in rows if r["mode"] == "shared")
    private = next(r for r in rows if r["mode"] == "per-trace")
    print("prefill_sharing: mode, prefill_s, wall_s, peak_blocks")
    for r in rows:
        print(f"{r['mode']},{r['prefill_s']:.3f},{r['wall_s']:.2f},"
              f"{r['peak_blocks']}")

    assert shared["tokens"] == private["tokens"], \
        "greedy outputs must be identical across prefill modes"
    speedup = private["prefill_s"] / max(shared["prefill_s"], 1e-9)
    saved = private["peak_blocks"] - shared["peak_blocks"]
    print(f"# prefill speedup {speedup:.1f}x at N={N_TRACES} "
          f"(identical greedy outputs); {saved} fewer peak blocks")
    assert speedup >= MIN_SPEEDUP, \
        f"expected >= {MIN_SPEEDUP}x prefill reduction, got {speedup:.1f}x"

    out = os.path.abspath(args.out)
    payload = {
        "benchmark": "prefill_sharing",
        "config": {"n_traces": N_TRACES, "max_new_tokens": MAX_NEW,
                   "num_blocks": NUM_BLOCKS, "capacity": CAPACITY},
        "prefill_speedup_x": speedup,
        "peak_blocks_saved": saved,
        "shared": {k: shared[k] for k in
                   ("prefill_s", "wall_s", "peak_blocks")},
        "per_trace": {k: private[k] for k in
                      ("prefill_s", "wall_s", "peak_blocks")},
    }
    with open(out, "w") as f:
        json.dump(payload, f, indent=2)
    print(f"# wrote {out}")
    return rows


if __name__ == "__main__":
    main()
