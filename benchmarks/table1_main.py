"""Table 1: accuracy / avg tokens / latency for CoT, SC, Slim-SC,
DeepConf, STEP across the synthetic reasoning benchmark (the paper's
main result, laptop scale)."""
from __future__ import annotations

from benchmarks.common import load_artifacts
from repro.serving import EngineConfig, SamplingParams, evaluate_method, \
    make_problems

N_PROBLEMS = 8
N_TRACES = 16
# pool sized so the FULL trace set cannot fit — the paper's regime where
# SC queues (Fig. 2c) and STEP prunes
NUM_BLOCKS = 56
MAX_NEW = 120

METHODS = ("cot", "sc", "slimsc", "deepconf", "step")


def run(verbose: bool = False):
    params, scorer, cfg = load_artifacts()
    problems = make_problems(N_PROBLEMS, seed=11, n_steps=(6, 9))
    # per-trace prefill: keep the paper-regime wait/preemption columns
    # comparable with table3_breakdown (docs/ENGINE.md)
    ecfg = EngineConfig(max_batch=N_TRACES, num_blocks=NUM_BLOCKS,
                        capacity=256, max_new_tokens=MAX_NEW,
                        sampling=SamplingParams(max_new_tokens=MAX_NEW),
                        share_prompt_prefix=False)
    rows = []
    for method in METHODS:
        pkw = {"warmup": 4} if method == "deepconf" else {}
        res = evaluate_method(method, params, cfg, problems, N_TRACES,
                              ecfg, scorer_params=scorer,
                              policy_kwargs=pkw, verbose=verbose)
        rows.append({
            "method": method, "accuracy": res.accuracy,
            "avg_tokens": res.avg_tokens,
            "avg_latency_s": res.avg_latency_s,
            "wait_s": res.total_wait_s,
            "pruned": res.num_pruned, "preemptions": res.num_preemptions,
        })
    return rows


def main():
    rows = run()
    print("table1_main: method, accuracy, avg_tokens, avg_latency_s, "
          "wait_s, pruned, preemptions")
    for r in rows:
        print(f"{r['method']},{r['accuracy']:.3f},{r['avg_tokens']:.0f},"
              f"{r['avg_latency_s']:.2f},{r['wait_s']:.2f},"
              f"{r['pruned']},{r['preemptions']}")
    sc = next(r for r in rows if r["method"] == "sc")
    st = next(r for r in rows if r["method"] == "step")
    speedup = sc["avg_latency_s"] / max(st["avg_latency_s"], 1e-9)
    print(f"# STEP vs SC: {speedup:.2f}x latency speedup "
          f"(paper claims 1.8x-3.3x), accuracy "
          f"{st['accuracy'] - sc['accuracy']:+.3f}")
    return rows


if __name__ == "__main__":
    main()
