"""Appendix D: step-scorer computational overhead.

Paper formula: relative FLOPs per generated step
    2 m (d + 1) / (2 N t)
with m = 512 scorer hidden, d = model hidden, N = non-embedding params,
t = tokens per step. We report (a) the paper's analytic ratio for each
FULL config and (b) the measured XLA-FLOPs ratio (scorer vs decode step)
from cost_analysis on the serving model."""
from __future__ import annotations

import jax
import jax.numpy as jnp

from repro.configs.registry import ASSIGNED_ARCHS, get_config, \
    serving_config
from repro.core.scorer import SCORER_HIDDEN, init_scorer, scorer_score
from repro.models.init import count_params, init_params, padded_vocab

# paper setting: t ~ 1e2 tokens per reasoning step (App. D); the synthetic
# task's steps are ~12 tokens, which only matters for the tiny serving
# model where the scorer is deliberately outsized relative to 1M params
AVG_TOKENS_PER_STEP = 100


def analytic_ratio(cfg) -> float:
    d = cfg.d_model
    V = padded_vocab(cfg)
    # shapes only — granite/deepseek full configs are 20-236B params
    shapes = jax.eval_shape(lambda: init_params(cfg, jax.random.PRNGKey(0)))
    import numpy as np
    n_all = sum(int(np.prod(x.shape))
                for x in jax.tree_util.tree_leaves(shapes))
    n = n_all - V * d * (1 if cfg.tie_embeddings else 2)
    return (2 * SCORER_HIDDEN * (d + 1)) / (2 * n * AVG_TOKENS_PER_STEP)


def measured_ratio() -> float:
    cfg = serving_config()
    params = init_params(cfg, jax.random.PRNGKey(0))
    scorer = init_scorer(jax.random.PRNGKey(1), cfg.d_model)

    from repro.models.model import decode_step, init_decode_cache
    B = 16
    cache = init_decode_cache(cfg, B, 256)
    toks = jnp.zeros((B, 1), jnp.int32)
    pos = jnp.zeros((B,), jnp.int32)

    dec = jax.jit(lambda p, c: decode_step(p, cfg, toks, pos, c,
                                           window_len=256)).lower(
        params, cache).compile()
    sc = jax.jit(lambda sp, h: scorer_score(sp, h)).lower(
        scorer, jnp.zeros((B, cfg.d_model))).compile()
    f_dec = float(dec.cost_analysis().get("flops", 0.0))
    f_sc = float(sc.cost_analysis().get("flops", 0.0))
    return f_sc / max(f_dec, 1.0)


def run(verbose: bool = False):
    rows = []
    for arch in ("qwen3-1.7b", "granite-20b", "deepseek-v2-236b",
                 "phi4-mini-3.8b"):
        cfg = get_config(arch)
        rows.append({"arch": arch, "kind": "analytic_full_cfg",
                     "ratio": analytic_ratio(cfg)})
    rows.append({"arch": "serving-model", "kind": "measured_xla",
                 "ratio": measured_ratio()})
    return rows


def main():
    rows = run()
    print("overhead: arch, kind, scorer_flops_ratio")
    for r in rows:
        print(f"{r['arch']},{r['kind']},{r['ratio']:.2e}")
    full = [r for r in rows if r["kind"] == "analytic_full_cfg"]
    # paper: <1e-6 for 4-14B models; our smallest assigned arch is 1.7B
    # so the bound relaxes proportionally
    assert all(r["ratio"] < 1e-5 for r in full), \
        "scorer overhead must be negligible"
    return rows


if __name__ == "__main__":
    main()
