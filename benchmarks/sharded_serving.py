"""Sharded-serving benchmark: the Engine over a simulated 4-device mesh.

Runs the same greedy workload on a single-device engine and on a
``(data=2, model=2)`` mesh engine (4 simulated CPU devices via
``--xla_force_host_platform_device_count``), asserts the generations are
token-identical — the exactness-preserving TP layout's contract, see
docs/ENGINE.md "Sharded serving" — and reports throughput for both.

On simulated CPU devices the mesh path pays real collective overhead
for no real parallelism (all "devices" share the host), so the sharded
throughput is EXPECTED to trail the single-device engine here; the
structural fields (identity, completion, token counts) are the tight CI
gate, the throughput ratio only a collapse guard. On a real accelerator
mesh the same code path is where the >1-chip memory and compute scaling
comes from.

Writes ``BENCH_sharded.json`` — uploaded and regression-checked by the
CI benchmark-smoke job against ``benchmarks/reference/``.

    python -m benchmarks.sharded_serving [--out path.json]
"""
from __future__ import annotations

import os

# must happen before jax initializes: simulate 4 host devices unless the
# caller already forced a device count
if "--xla_force_host_platform_device_count" not in os.environ.get(
        "XLA_FLAGS", ""):
    os.environ["XLA_FLAGS"] = (os.environ.get("XLA_FLAGS", "")
                               + " --xla_force_host_platform_device_count=4"
                               ).strip()
os.environ.setdefault("JAX_PLATFORMS", "cpu")

import argparse  # noqa: E402  (env must be set before jax imports)
import dataclasses  # noqa: E402
import json  # noqa: E402
import time  # noqa: E402

import jax  # noqa: E402

from benchmarks.common import bench_requests  # noqa: E402
from repro.configs.registry import serving_config  # noqa: E402
from repro.core.pruning import make_policy  # noqa: E402
from repro.core.trace import TraceStatus  # noqa: E402
from repro.data.tokenizer import get_tokenizer  # noqa: E402
from repro.launch.mesh import make_host_mesh  # noqa: E402
from repro.models.init import init_params  # noqa: E402
from repro.serving import Engine, EngineConfig, SamplingParams  # noqa: E402

MESH_SHAPE = (2, 2)  # (data, model)
N_REQUESTS = 2
N_TRACES = 4
MAX_NEW = 64
NUM_BLOCKS = 96
CAPACITY = 128
DECODE_HORIZON = 4
SEED = 1234
# init seed chosen so the random-init model's greedy generations run to
# the token cap under partitionable-threefry init (the flag is flipped
# before init in run()); early-EOS seeds leave too few decode ticks
PARAMS_SEED = 0


def bench_config():
    """Small serving-smoke variant (random init: identity and relative
    throughput need no trained weights). Sized so the mesh engine's
    per-tick collectives are visible but the run stays CI-friendly."""
    return dataclasses.replace(
        serving_config(), num_layers=2, d_model=64, d_ff=128,
        num_heads=4, num_kv_heads=2, head_dim=16)


def _requests(tok):
    return bench_requests(tok, N_REQUESTS, N_TRACES, seed=SEED)


def _run_engine(engine, tok):
    engine.serve_batch(_requests(tok))  # warm the jit caches
    wall = float("inf")
    results = None
    for _ in range(3):
        requests = _requests(tok)
        jax.block_until_ready(engine.params)
        t0 = time.perf_counter()
        results = engine.serve_batch(requests)
        wall = min(wall, time.perf_counter() - t0)
        for r in results:
            assert all(t.status == TraceStatus.FINISHED for t in r.traces)
        assert (engine.block_mgr.free_blocks
                == engine.block_mgr.num_blocks - 1)
        engine.block_mgr.check_invariants()
    tokens = sum(r.total_tokens for r in results)
    outputs = [[t.output_tokens for t in r.traces] for r in results]
    return {"tokens": tokens, "wall_s": wall,
            "tok_per_s": tokens / wall}, outputs


def run(verbose: bool = False) -> dict:
    if jax.device_count() < MESH_SHAPE[0] * MESH_SHAPE[1]:
        raise SystemExit(
            f"needs {MESH_SHAPE[0] * MESH_SHAPE[1]} devices, have "
            f"{jax.device_count()}; run with XLA_FLAGS="
            f"--xla_force_host_platform_device_count=4")
    cfg = bench_config()
    # both engines must sample the same threefry implementation; the
    # mesh engine flips this anyway — flip it before the single-device
    # baseline so engine build order can't matter (greedy today, but
    # don't let a future temperature>0 variant diverge for RNG reasons)
    jax.config.update("jax_threefry_partitionable", True)
    params = init_params(cfg, jax.random.PRNGKey(PARAMS_SEED))
    tok = get_tokenizer()
    ecfg = EngineConfig(
        max_batch=N_REQUESTS * N_TRACES, num_blocks=NUM_BLOCKS,
        capacity=CAPACITY, max_new_tokens=MAX_NEW,
        sampling=SamplingParams(temperature=0.0, top_k=0, top_p=1.0,
                                max_new_tokens=MAX_NEW),
        decode_horizon=DECODE_HORIZON,
        # cache off: single vs mesh replays the same prompts — warm hits
        # would skip prefill work and invalidate the blessed timings
        prefix_cache=False)

    single = Engine(params, cfg, ecfg, make_policy("sc"))
    stats_single, out_single = _run_engine(single, tok)
    if verbose:
        print(f"single-device: {stats_single['tokens']} tokens, "
              f"{stats_single['tok_per_s']:.1f} tok/s")

    mesh = make_host_mesh(*MESH_SHAPE)
    sharded = Engine(params, cfg, ecfg, make_policy("sc"), mesh=mesh)
    stats_sharded, out_sharded = _run_engine(sharded, tok)
    if verbose:
        print(f"mesh {MESH_SHAPE}: {stats_sharded['tokens']} tokens, "
              f"{stats_sharded['tok_per_s']:.1f} tok/s")

    # the contract: sharding must not change a single generated token
    assert out_sharded == out_single, "mesh generations diverged"

    payload = {
        "benchmark": "sharded_serving",
        "config": {
            "devices": jax.device_count(),
            "mesh": {"data": MESH_SHAPE[0], "model": MESH_SHAPE[1]},
            "n_requests": N_REQUESTS, "n_traces": N_TRACES,
            "max_new_tokens": MAX_NEW, "num_blocks": NUM_BLOCKS,
            "capacity": CAPACITY, "decode_horizon": DECODE_HORIZON,
            "seed": SEED,
        },
        "outputs_identical": True,
        "single": stats_single,
        "sharded": stats_sharded,
        "sharded_over_single_x": (stats_sharded["tok_per_s"]
                                  / stats_single["tok_per_s"]),
    }
    if verbose:
        print(f"sharded/single throughput: "
              f"x{payload['sharded_over_single_x']:.2f} "
              f"(simulated devices: overhead-only, see docstring)")
    return payload


def main():
    ap = argparse.ArgumentParser()
    ap.add_argument("--out", default=os.path.join(
        os.path.dirname(__file__), "..", "BENCH_sharded.json"))
    args = ap.parse_args()
    payload = run(verbose=True)
    out = os.path.abspath(args.out)
    with open(out, "w") as f:
        json.dump(payload, f, indent=2)
    print(f"# wrote {out}")
    return payload


if __name__ == "__main__":
    main()
