"""Paged-attention kernel benchmark: Pallas vs dense, decode + chunked
prefill.

Times the two engine-facing paged-attention ops — the fused-decode
single-query op and the chunked-prefill multi-query op — through both
the dense jnp fallback and the Pallas kernel (interpret mode on this
CPU container; compiled Mosaic on TPU), at a serving-shaped config:
a large paged pool (the per-device HBM budget) holding a short live
prefix, i.e. the steady-state regime where most of the block table is
ahead of the write frontier.

What the kernel structurally eliminates, visible even in interpret mode:

  * chunked prefill: the dense path materializes a
    ``[B, KVH, G, C, bp*bs + C]`` score tensor per layer — every pool
    slot is scored and masked, live or not. The kernel's online-softmax
    grid touches only pages that hold visible tokens and never
    materializes the score tensor. This is the gated win
    (``prefill.speedup_x``, ``min_abs`` floor in check_regression).
  * decode: the dense path gathers the ENTIRE block table
    (``pool_k[block_tables]`` -> [B, bp*bs, KVH, hd]) per layer per
    token. The kernel reads only live pages. On CPU the per-grid-step
    interpret overhead (one Python-traced body per page) masks the
    saved bytes, so decode numbers are collapse-guarded only; on TPU
    the grid loop is hardware-sequenced and the saved HBM traffic is
    the win.

Numerics are asserted (kernel vs dense allclose) before timing, so the
speedup is never measured against a diverged implementation. Writes
``BENCH_paged_kernel.json``, regression-checked by the CI bench-smoke
job against ``benchmarks/reference/``.

    PYTHONPATH=src python -m benchmarks.paged_kernel [--out path.json]
"""
from __future__ import annotations

import argparse
import json
import math
import os
import time

import jax
import jax.numpy as jnp

from repro.kernels import ops as kops
from repro.kernels import ref
from repro.models.layers import paged_attention_decode

# Serving-shaped op config: big pool, short live prefix. Page size 64
# (vs the engine-test default 16) is the TPU-tuned tile — it also keeps
# the interpret-mode grid short enough that CPU timings reflect the
# structural work saved, not per-step Python overhead.
BATCH = 2
HEADS = 8
KV_HEADS = 2
HEAD_DIM = 64
PAGE = 64
CAPACITY = 4096
CHUNK = 64          # prefill chunk width (tokens)
PREFIX = 128        # live pooled tokens ahead of the chunk
DECODE_LEN = 192    # live cache length at the decode step
REPEATS = 10
SEED = 0


def _timeit(fn, *args, n=REPEATS):
    jax.block_until_ready(fn(*args))  # warm the jit cache
    best = float("inf")
    for _ in range(3):  # best-of-3 batches of n (CI runners are noisy)
        t0 = time.perf_counter()
        for _ in range(n):
            out = fn(*args)
        jax.block_until_ready(out)
        best = min(best, (time.perf_counter() - t0) / n)
    return best


def _pool(key, nb):
    return jax.random.normal(key, (nb, PAGE, KV_HEADS, HEAD_DIM),
                             jnp.bfloat16)


def run(verbose: bool = False) -> dict:
    scale = 1.0 / math.sqrt(HEAD_DIM)
    bp = CAPACITY // PAGE
    nb = BATCH * bp + 1
    ks = jax.random.split(jax.random.PRNGKey(SEED), 6)
    k_pool, v_pool = _pool(ks[0], nb), _pool(ks[1], nb)
    bt = jnp.arange(1, BATCH * bp + 1, dtype=jnp.int32).reshape(BATCH, bp)

    # ---- chunked prefill ------------------------------------------------
    q = jax.random.normal(ks[2], (BATCH, CHUNK, HEADS, HEAD_DIM),
                          jnp.bfloat16)
    own_k = jax.random.normal(ks[3], (BATCH, CHUNK, KV_HEADS, HEAD_DIM),
                              jnp.bfloat16)
    own_v = jax.random.normal(ks[4], (BATCH, CHUNK, KV_HEADS, HEAD_DIM),
                              jnp.bfloat16)
    prefix_lens = jnp.full((BATCH,), PREFIX, jnp.int32)
    num_valid = jnp.full((BATCH,), CHUNK, jnp.int32)
    pf_args = (q, k_pool, v_pool, bt, prefix_lens, num_valid, own_k, own_v)

    pf_dense = jax.jit(
        lambda *a: ref.paged_attention_prefill_ref(*a, scale=scale))
    pf_kernel = jax.jit(
        lambda *a: kops.paged_attention_prefill(*a, scale=scale))

    diff_pf = float(jnp.max(jnp.abs(
        pf_kernel(*pf_args).astype(jnp.float32)
        - pf_dense(*pf_args).astype(jnp.float32))))
    t_pf_dense = _timeit(pf_dense, *pf_args)
    t_pf_kernel = _timeit(pf_kernel, *pf_args)

    # ---- decode ---------------------------------------------------------
    qd = jax.random.normal(ks[5], (BATCH * 4, HEADS, HEAD_DIM),
                           jnp.bfloat16)
    btd = jnp.tile(bt, (4, 1))[:BATCH * 4]
    lens = jnp.full((BATCH * 4,), DECODE_LEN, jnp.int32)
    de_args = (qd, k_pool, v_pool, btd, lens)

    de_dense = jax.jit(lambda q_, kp, vp, t, ln: paged_attention_decode(
        kp, vp, q_, t, ln, scale=scale))
    de_kernel = jax.jit(
        lambda *a: kops.paged_attention(*a, scale=scale))

    diff_de = float(jnp.max(jnp.abs(
        de_kernel(*de_args).astype(jnp.float32)
        - de_dense(*de_args).astype(jnp.float32))))
    t_de_dense = _timeit(de_dense, *de_args)
    t_de_kernel = _timeit(de_kernel, *de_args)

    outputs_close = bool(diff_pf < 2e-2 and diff_de < 2e-2)
    payload = {
        "benchmark": "paged_kernel",
        "config": {
            "batch": BATCH, "heads": HEADS, "kv_heads": KV_HEADS,
            "head_dim": HEAD_DIM, "page": PAGE, "capacity": CAPACITY,
            "chunk": CHUNK, "prefix": PREFIX, "decode_len": DECODE_LEN,
            "interpret": jax.default_backend() == "cpu",
        },
        "prefill": {
            "dense_ms": t_pf_dense * 1e3,
            "kernel_ms": t_pf_kernel * 1e3,
            "speedup_x": t_pf_dense / t_pf_kernel,
        },
        "decode": {
            "dense_ms": t_de_dense * 1e3,
            "kernel_ms": t_de_kernel * 1e3,
            "speedup_x": t_de_dense / t_de_kernel,
        },
        "max_abs_diff": {"prefill": diff_pf, "decode": diff_de},
        "outputs_close": outputs_close,
    }
    if verbose:
        print(f"chunked prefill: dense {t_pf_dense * 1e3:.2f}ms  "
              f"kernel {t_pf_kernel * 1e3:.2f}ms  "
              f"x{payload['prefill']['speedup_x']:.2f} "
              f"(max diff {diff_pf:.2e})")
        print(f"decode:          dense {t_de_dense * 1e3:.2f}ms  "
              f"kernel {t_de_kernel * 1e3:.2f}ms  "
              f"x{payload['decode']['speedup_x']:.2f} "
              f"(max diff {diff_de:.2e})")
    assert outputs_close, "kernel diverged from dense — timing meaningless"
    return payload


def main():
    ap = argparse.ArgumentParser()
    ap.add_argument("--out", default=os.path.join(
        os.path.dirname(__file__), "..", "BENCH_paged_kernel.json"))
    args = ap.parse_args()
    payload = run(verbose=True)
    out = os.path.abspath(args.out)
    with open(out, "w") as f:
        json.dump(payload, f, indent=2)
    print(f"# wrote {out}")
    return payload


if __name__ == "__main__":
    main()
