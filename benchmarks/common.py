"""Shared benchmark artifacts: the trained reasoning LM + step scorer.

Built once (``python -m benchmarks.common``) and cached under
``benchmarks/artifacts/``; every table/figure benchmark loads from here so
results are comparable across benchmarks.
"""
from __future__ import annotations

import json
import os
from typing import Optional, Tuple

import jax
import numpy as np

from repro.configs.registry import serving_config
from repro.core.pipeline import build_step_scorer
from repro.core.scorer import init_scorer
from repro.models.init import init_params
from repro.training.checkpoint import load_pytree, save_pytree
from repro.training.trainer import TrainConfig, train_lm

ART_DIR = os.path.join(os.path.dirname(__file__), "artifacts")
MODEL_PATH = os.path.join(ART_DIR, "model.npz")
SCORER_PATH = os.path.join(ART_DIR, "scorer.npz")
INFO_PATH = os.path.join(ART_DIR, "info.json")

TRAIN_STEPS = int(os.environ.get("REPRO_TRAIN_STEPS", "4000"))


def build_artifacts(verbose: bool = True) -> None:
    cfg = serving_config()
    os.makedirs(ART_DIR, exist_ok=True)
    tcfg = TrainConfig(steps=TRAIN_STEPS, seq_len=128, batch_size=32,
                       peak_lr=2e-3, warmup=100, log_every=100)
    if verbose:
        print(f"[artifacts] training LM for {tcfg.steps} steps ...")
    params, history = train_lm(cfg, tcfg, verbose=verbose)
    save_pytree(MODEL_PATH, params)

    if verbose:
        print("[artifacts] building step scorer (sample -> verify -> train)")
    scorer, info = build_step_scorer(params, cfg, n_problems=96,
                                     n_samples=8, per_class=160,
                                     verbose=verbose)
    save_pytree(SCORER_PATH, scorer)
    with open(INFO_PATH, "w") as f:
        json.dump({"train_final_loss": history[-1]["loss"],
                   "scorer_info": {k: v for k, v in info.items()
                                   if k != "history"}}, f, indent=2)
    if verbose:
        print(f"[artifacts] done: correct-rate="
              f"{info['sampled_correct_rate']:.2f} "
              f"steps={info['num_steps']} "
              f"fallback={info['fallback_rendered']}")


def bench_requests(tok, n_requests: int, n_traces: int, seed: int,
                   n_steps=(8, 12), method: str = "sc") -> list:
    """The shared synthetic request workload of the engine perf
    benchmarks (decode_throughput, sharded_serving): deterministic
    problems rendered to prompts, one fresh policy per request."""
    from repro.core.pruning import make_policy
    from repro.data.arithmetic import make_prompt
    from repro.serving import Request, make_problems

    problems = make_problems(n_requests, seed=seed, n_steps=n_steps)
    return [
        Request(request_id=i,
                prompt_tokens=tok.encode(make_prompt(p), add_bos=True),
                n_traces=n_traces, policy=make_policy(method))
        for i, p in enumerate(problems)
    ]


def load_artifacts() -> Tuple[dict, dict, dict]:
    """Returns (params, scorer_params, cfg). Builds on first use."""
    cfg = serving_config()
    if not (os.path.exists(MODEL_PATH) and os.path.exists(SCORER_PATH)):
        build_artifacts()
    like_params = init_params(cfg, jax.random.PRNGKey(0))
    params = load_pytree(MODEL_PATH, like_params)
    like_scorer = init_scorer(jax.random.PRNGKey(0), cfg.d_model)
    scorer = load_pytree(SCORER_PATH, like_scorer)
    return params, scorer, cfg


if __name__ == "__main__":
    build_artifacts()
