"""Fig. 4: latency-scaling — accuracy vs latency at trace budgets
N in {1, 8, 16} for SC and STEP (paper uses {1, 16, 32, 64})."""
from __future__ import annotations

from benchmarks.common import load_artifacts
from repro.serving import EngineConfig, SamplingParams, evaluate_method, \
    make_problems

N_PROBLEMS = 6
BUDGETS = (1, 8, 16)
MAX_NEW = 120


def run(verbose: bool = False):
    params, scorer, cfg = load_artifacts()
    problems = make_problems(N_PROBLEMS, seed=31, n_steps=(6, 9))
    rows = []
    for n in BUDGETS:
        # pool scales with budget but stays undersized (paper setting)
        blocks = max(12, int(n * 1.6) + 4)
        # per-trace prefill: undersized-pool pressure assumes private
        # prompt blocks per trace (docs/ENGINE.md)
        ecfg = EngineConfig(max_batch=max(n, 1), num_blocks=blocks,
                            capacity=256, max_new_tokens=MAX_NEW,
                            sampling=SamplingParams(max_new_tokens=MAX_NEW),
                            share_prompt_prefix=False)
        for method in ("sc", "step"):
            if n == 1 and method == "step":
                continue  # single trace: no pruning possible
            res = evaluate_method(method, params, cfg, problems, n, ecfg,
                                  scorer_params=scorer, verbose=verbose)
            rows.append({"n": n, "method": method,
                         "accuracy": res.accuracy,
                         "avg_latency_s": res.avg_latency_s})
    return rows


def main():
    rows = run()
    print("fig4_scaling: n, method, accuracy, avg_latency_s")
    for r in rows:
        print(f"{r['n']},{r['method']},{r['accuracy']:.3f},"
              f"{r['avg_latency_s']:.2f}")
    return rows


if __name__ == "__main__":
    main()
