"""Minimal, dependency-free fallback for the slice of the ``hypothesis``
API this repo's property tests use.

Loaded by ``tests/conftest.py`` ONLY when the real ``hypothesis`` package
is not installed (e.g. a hermetic container without network access). It
is not a shrinker — just a seeded random-example runner with the same
decorator surface — so failures reproduce deterministically but are not
minimized. CI installs the real package via ``pip install -e .[test]``
and never sees this module.

Supported: ``given``, ``settings(max_examples=, deadline=)``, and the
strategies ``integers``, ``booleans``, ``sampled_from``, ``lists``,
``tuples``, ``composite``.
"""
from __future__ import annotations

import random
import zlib

DEFAULT_MAX_EXAMPLES = 50


class _Strategy:
    def __init__(self, draw_fn):
        self._draw_fn = draw_fn

    def example_with(self, rng: random.Random):
        return self._draw_fn(rng)


def integers(min_value: int, max_value: int) -> _Strategy:
    return _Strategy(lambda rng: rng.randint(min_value, max_value))


def booleans() -> _Strategy:
    return _Strategy(lambda rng: rng.random() < 0.5)


def sampled_from(elements) -> _Strategy:
    elements = list(elements)
    return _Strategy(lambda rng: rng.choice(elements))


def lists(elements: _Strategy, min_size: int = 0,
          max_size: int = 10) -> _Strategy:
    def draw(rng):
        n = rng.randint(min_size, max_size)
        return [elements.example_with(rng) for _ in range(n)]
    return _Strategy(draw)


def tuples(*strategies: _Strategy) -> _Strategy:
    return _Strategy(
        lambda rng: tuple(s.example_with(rng) for s in strategies))


def composite(fn):
    """``@st.composite`` — ``fn(draw, *args)`` builder."""
    def make(*args, **kwargs):
        def draw_value(rng):
            def draw(strategy):
                return strategy.example_with(rng)
            return fn(draw, *args, **kwargs)
        return _Strategy(draw_value)
    return make


def given(*strategies: _Strategy):
    """Run the test body over seeded random examples of the strategies."""
    def deco(fn):
        def wrapper(*args, **kwargs):
            n = getattr(wrapper, "_stub_max_examples", DEFAULT_MAX_EXAMPLES)
            # deterministic per-test seed so failures reproduce
            seed = zlib.crc32(fn.__qualname__.encode())
            rng = random.Random(seed)
            for _ in range(n):
                vals = [s.example_with(rng) for s in strategies]
                fn(*args, *vals, **kwargs)
        # no functools.wraps: pytest must see (*args, **kwargs), not the
        # original signature, or it would treat drawn params as fixtures
        wrapper.__name__ = fn.__name__
        wrapper.__doc__ = fn.__doc__
        wrapper.__module__ = fn.__module__
        return wrapper
    return deco


def settings(max_examples: int = None, deadline=None, **_ignored):
    def deco(fn):
        if max_examples is not None:
            fn._stub_max_examples = max_examples
        return fn
    return deco
