"""Allocator semantics for prefix sharing: refcounts, fork, COW, and the
free-list invariants under adversarial interleavings."""
import jax.numpy as jnp
import numpy as np
import pytest
from hypothesis import given, settings
from hypothesis import strategies as st

from repro.configs.registry import serving_config
from repro.models.model import copy_kv_block
from repro.serving.kv_manager import BlockManager
from repro.serving.prefix_cache import PrefixCache


def test_fork_increments_refcounts():
    mgr = BlockManager(num_blocks=8, block_size=16)
    blocks = mgr.allocate(3)
    assert all(mgr.ref_count(b) == 1 for b in blocks)
    assert not any(mgr.is_shared(b) for b in blocks)
    forked = mgr.fork(blocks)
    assert forked == blocks  # same physical blocks
    assert forked is not blocks  # fresh list: callers mutate independently
    assert all(mgr.ref_count(b) == 2 for b in blocks)
    assert all(mgr.is_shared(b) for b in blocks)
    # forking holds no new physical memory
    assert mgr.used_blocks == 3


def test_free_releases_only_at_refcount_zero():
    mgr = BlockManager(num_blocks=8, block_size=16)
    blocks = mgr.allocate(2)
    forked = mgr.fork(blocks)
    mgr.free(forked)
    # still held by the original owner
    assert mgr.used_blocks == 2
    assert all(mgr.ref_count(b) == 1 for b in blocks)
    mgr.free(blocks)
    assert mgr.used_blocks == 0
    assert mgr.free_blocks == 7
    mgr.check_invariants()


def test_double_free_still_asserts():
    mgr = BlockManager(num_blocks=8, block_size=16)
    blocks = mgr.allocate(1)
    mgr.free(blocks)
    with pytest.raises(AssertionError, match="double free"):
        mgr.free(blocks)


def test_free_of_scratch_or_unallocated_asserts():
    mgr = BlockManager(num_blocks=8, block_size=16)
    with pytest.raises(AssertionError):
        mgr.free([0])  # scratch is never owned
    mgr.allocate(7)  # empty the free list so membership can't catch it
    with pytest.raises(AssertionError):
        mgr.fork([99])


def test_cow_protocol_releases_only_writer_ref():
    """The engine's COW step at allocator level: the writer allocates a
    private block and drops its ref on the shared one; other holders keep
    reading the original."""
    mgr = BlockManager(num_blocks=8, block_size=16)
    prompt = mgr.allocate(2)      # holder (shared-prefix owner)
    t1 = mgr.fork(prompt)
    t2 = mgr.fork(prompt)
    assert mgr.ref_count(prompt[-1]) == 3
    # t1 writes into the shared tail block -> COW
    new = mgr.allocate(1)[0]
    mgr.free([t1[-1]])
    t1[-1] = new
    assert mgr.ref_count(prompt[-1]) == 2  # holder + t2, untouched
    assert mgr.ref_count(new) == 1
    for owned in (t1, t2, prompt):
        mgr.free(owned)
    assert mgr.free_blocks == 7
    mgr.check_invariants()


def test_copy_kv_block_never_mutates_source():
    """Device-level COW: dst gets a copy, src (the shared block) and every
    other block are bit-identical afterwards."""
    cfg = serving_config()
    L, NB, bs, H, hd = 2, 4, 2, 1, 2
    k = jnp.arange(L * NB * bs * H * hd, dtype=jnp.float32).reshape(
        L, NB, bs, H, hd)
    v = -k
    cache = {"k_pool": k, "v_pool": v}
    out = copy_kv_block(cfg, dict(cache), 1, 3)
    for key, pool in (("k_pool", k), ("v_pool", v)):
        got = np.asarray(out[key])
        ref = np.asarray(pool)
        np.testing.assert_array_equal(got[:, 3], ref[:, 1])  # copied
        np.testing.assert_array_equal(got[:, :3], ref[:, :3])  # untouched


@settings(max_examples=100, deadline=None)
@given(st.integers(2, 32),
       st.lists(st.tuples(st.integers(0, 2), st.integers(1, 4)),
                max_size=60))
def test_invariants_under_random_alloc_fork_free(num_blocks, ops):
    """Randomized alloc/fork/free interleaving: no double allocation, the
    free list and refcounts always partition the pool, and releasing every
    reference drains back to a full free list."""
    mgr = BlockManager(num_blocks=num_blocks, block_size=16)
    held = []  # independently owned reference lists
    for op, n in ops:
        if op == 0:
            blocks = mgr.allocate(n)
            if blocks is not None:
                assert len(blocks) == n
                for b in blocks:
                    assert b != mgr.scratch_block
                    assert mgr.ref_count(b) == 1  # fresh, not recycled-live
                held.append(blocks)
        elif op == 1 and held:
            held.append(mgr.fork(held[n % len(held)]))
        elif op == 2 and held:
            mgr.free(held.pop(n % len(held)))
        mgr.check_invariants()
    # physical usage counts unique blocks, not references
    assert mgr.used_blocks == len({b for h in held for b in h})
    for h in held:
        mgr.free(h)
    assert mgr.free_blocks == num_blocks - 1
    mgr.check_invariants()


@settings(max_examples=60, deadline=None)
@given(st.integers(4, 24),
       st.lists(st.tuples(st.integers(0, 4), st.integers(0, 7)),
                max_size=50))
def test_invariants_with_prefix_cache_interleaving(num_blocks, ops):
    """Prefix-cache insert/match(+fork)/evict interleaved with plain
    alloc/fork/free: refcounts never go negative (``free`` would assert),
    an evicted block returns to the free list exactly once (a second
    return would trip the free-list partition check), and releasing every
    outside reference plus clearing the cache drains the pool."""
    bs = 4
    base = list(range(40))
    # nested prefixes (shared chunks) + a disjoint prompt: inserts
    # exercise both the new-node and the duplicate-drop path
    prompts = [base[:5], base[:9], base[:13], [99] * 7]
    mgr = BlockManager(num_blocks=num_blocks, block_size=bs)
    cache = PrefixCache(mgr)
    held = []  # references owned outside the cache
    for op, n in ops:
        if op == 0:  # complete a request: park a prompt's full blocks
            p = prompts[n % len(prompts)]
            blocks = mgr.allocate(len(p) // bs)
            if blocks is not None:
                cache.insert(p, blocks)  # ownership moves to the cache
        elif op == 1:  # new request: match + COW-fork the hit
            got, n_tok = cache.match(prompts[n % len(prompts)])
            assert n_tok == len(got) * bs
            if got:
                held.append(mgr.fork(got))
        elif op == 2 and held:  # request finishes: drop its references
            mgr.free(held.pop(n % len(held)))
        elif op == 3:  # memory pressure
            cache.evict(n % 3 + 1)
        elif op == 4:  # unrelated private allocation
            blocks = mgr.allocate(n % 2 + 1)
            if blocks is not None:
                held.append(blocks)
        mgr.check_invariants()
        cache.check_integrity()
    for h in held:
        mgr.free(h)
    cache.clear()
    assert mgr.free_blocks == num_blocks - 1
    mgr.check_invariants()
    cache.check_integrity()


# ---------------------------------------------------------------------------
# chunk-granular reservations (chunked prefill)
# ---------------------------------------------------------------------------

def test_reservation_take_commit():
    mgr = BlockManager(num_blocks=8, block_size=16)
    res = mgr.reserve(4)
    assert res.remaining == 4 and res.num_taken == 0
    first = res.take(2)
    assert len(first) == 2 and res.remaining == 2
    assert mgr.used_blocks == 2
    second = res.take(2)
    assert len(second) == 2 and res.remaining == 0
    blocks = res.commit()
    assert blocks == first + second
    # committed blocks are owned by the caller, with one reference each
    assert all(mgr.ref_count(b) == 1 for b in blocks)
    mgr.free(blocks)
    assert mgr.free_blocks == 7
    mgr.check_invariants()


def test_reservation_take_is_all_or_nothing():
    mgr = BlockManager(num_blocks=5, block_size=16)  # 4 usable
    other = mgr.allocate(3)
    res = mgr.reserve(4)
    assert res.take(2) is None  # only 1 free: nothing taken
    assert res.num_taken == 0 and mgr.free_blocks == 1
    assert len(res.take(1)) == 1
    mgr.free(other)
    assert len(res.take(3)) == 3
    blocks = res.commit()
    mgr.free(blocks)
    mgr.check_invariants()


def test_reservation_abort_returns_blocks():
    mgr = BlockManager(num_blocks=8, block_size=16)
    res = mgr.reserve(3)
    res.take(3)
    assert mgr.free_blocks == 4
    res.abort()
    assert mgr.free_blocks == 7
    mgr.check_invariants()
    with pytest.raises(AssertionError):
        res.take(1)  # closed


def test_reservation_overdraw_asserts():
    mgr = BlockManager(num_blocks=8, block_size=16)
    res = mgr.reserve(2)
    res.take(2)
    with pytest.raises(AssertionError):
        res.take(1)
