"""Scheduler-core tests: the FIFO reduction pin (TenantScheduler with a
single tenant must be op-identical to the default FIFO policy, fixed
RNG), weighted-fair DRR budgets, SLO admission control, per-request
sampling / max_new_tokens overrides, EngineConfig.from_env, and the
event-stream contract."""
import functools

import jax
import pytest
from hypothesis import given, settings
from hypothesis import strategies as st

from repro.configs.registry import serving_config
from repro.core.pruning import make_policy
from repro.core.trace import TraceStatus
from repro.data.tokenizer import get_tokenizer
from repro.models.init import init_params
from repro.serving import (SLO, Arrival, BudgetReplenish, BurstDone,
                           Completion, DeficitRoundRobin, Engine,
                           EngineConfig, FIFOPolicy, Request, SamplingParams,
                           SchedulingPolicy, TenantScheduler, TokenBudget,
                           WeightedTokenBudget, default_scheduler,
                           parse_tenant_weights)


@functools.lru_cache(maxsize=1)
def _setup():
    """Module-level cache instead of a fixture: the hypothesis property
    tests can't receive pytest fixtures under the dependency-free stub
    runner (tests/_hypothesis_stub.py)."""
    cfg = serving_config()
    params = init_params(cfg, jax.random.PRNGKey(0))
    tok = get_tokenizer()
    prompts = [tok.encode("3+5-2=", add_bos=True),
               tok.encode("7*2+1=", add_bos=True),
               tok.encode("9-4+6=", add_bos=True)]
    return cfg, params, prompts


@pytest.fixture(scope="module")
def setup():
    return _setup()


def _ecfg(num_blocks=64, max_new=12, batch=8, chunk=None, budget=None,
          temperature=0.0, seed=1234):
    return EngineConfig(
        max_batch=batch, num_blocks=num_blocks, capacity=128,
        max_new_tokens=max_new, seed=seed,
        sampling=SamplingParams(temperature=temperature, top_k=0,
                                top_p=1.0, max_new_tokens=max_new),
        prefill_chunk_size=chunk, max_tokens_per_step=budget)


def _reqs(prompts, n=2, arrivals=None, **extra):
    arrivals = arrivals or [0.0] * len(prompts)
    return [Request(request_id=i, prompt_tokens=p, n_traces=n,
                    policy=make_policy("sc"), arrival_time=a, **extra)
            for i, (p, a) in enumerate(zip(prompts, arrivals))]


def _snapshot(results):
    """Everything the reduction pin compares: tokens, statuses, scores
    and prune counts per request."""
    return {r.request_id: ([(t.output_tokens, t.status, t.score)
                            for t in r.traces], r.num_pruned)
            for r in results}


# ---------------------------------------------------------------------------
# policy plumbing units
# ---------------------------------------------------------------------------

def test_parse_tenant_weights():
    assert parse_tenant_weights("premium:3,batch:1") == \
        {"premium": 3.0, "batch": 1.0}
    assert parse_tenant_weights(" a : 2.5 ") == {"a": 2.5}
    with pytest.raises(ValueError):
        parse_tenant_weights("premium=3")
    with pytest.raises(ValueError):
        parse_tenant_weights("a:0")


def test_default_scheduler_env(monkeypatch):
    # unset / "fifo" -> None: the engine builds a FIFOPolicy per run
    monkeypatch.delenv("REPRO_SCHED", raising=False)
    assert default_scheduler() is None
    monkeypatch.setenv("REPRO_SCHED", "fifo")
    assert default_scheduler() is None
    monkeypatch.setenv("REPRO_SCHED", "tenant")
    sched = default_scheduler()
    assert isinstance(sched, TenantScheduler)
    assert isinstance(sched, SchedulingPolicy)
    monkeypatch.setenv("REPRO_SCHED", "bogus")
    with pytest.raises(ValueError):
        default_scheduler()


def test_engine_config_from_env(monkeypatch):
    monkeypatch.setenv("REPRO_MAX_BATCH", "7")
    monkeypatch.setenv("REPRO_DECODE_HORIZON", "3")
    monkeypatch.setenv("REPRO_MAX_TOKENS_PER_STEP", "48")
    ecfg = EngineConfig.from_env()
    assert ecfg.max_batch == 7
    assert ecfg.decode_horizon == 3
    assert ecfg.max_tokens_per_step == 48
    # explicit overrides beat the environment
    ecfg = EngineConfig.from_env(max_batch=3)
    assert ecfg.max_batch == 3 and ecfg.decode_horizon == 3
    monkeypatch.delenv("REPRO_MAX_BATCH")
    monkeypatch.delenv("REPRO_DECODE_HORIZON")
    monkeypatch.delenv("REPRO_MAX_TOKENS_PER_STEP")
    assert EngineConfig.from_env().max_batch == EngineConfig().max_batch


def test_engine_config_from_env_rejects_malformed_values(monkeypatch):
    """Malformed REPRO_* values fail loudly with the variable name and
    the accepted range in the message — not a bare int() traceback."""
    monkeypatch.setenv("REPRO_MAX_BATCH", "eight")
    with pytest.raises(ValueError,
                       match=r"REPRO_MAX_BATCH='eight'.*integer >= 1"):
        EngineConfig.from_env()
    monkeypatch.setenv("REPRO_MAX_BATCH", "0")  # parses, below the floor
    with pytest.raises(ValueError,
                       match=r"REPRO_MAX_BATCH='0'.*integer >= 1"):
        EngineConfig.from_env()
    monkeypatch.delenv("REPRO_MAX_BATCH")
    monkeypatch.setenv("REPRO_NUM_BLOCKS", "1")  # block 0 is scratch
    with pytest.raises(ValueError,
                       match=r"REPRO_NUM_BLOCKS='1'.*integer >= 2"):
        EngineConfig.from_env()
    monkeypatch.setenv("REPRO_NUM_BLOCKS", "-3")
    with pytest.raises(ValueError, match="REPRO_NUM_BLOCKS"):
        EngineConfig.from_env()
    monkeypatch.delenv("REPRO_NUM_BLOCKS")
    monkeypatch.setenv("REPRO_SEED", "0")  # seed floor is 0, not 1
    assert EngineConfig.from_env().seed == 0
    monkeypatch.delenv("REPRO_SEED")


def test_token_budget_semantics():
    assert TokenBudget(None).can(10**9)          # unlimited
    b = TokenBudget(5)
    assert b.can(5) and not b.can(6)
    assert b.can(6, force=True)                  # first-prefill escape hatch
    b.spend(5)
    assert not b.can(1)


def test_drr_weighted_split_two_to_one():
    """2:1 weights -> 2:1 token split when both tenants stay backlogged
    (the weighted-fairness acceptance criterion, engine-free)."""
    drr = DeficitRoundRobin(weights={"a": 2.0, "b": 1.0})
    drr.reset()
    got = {"a": 0, "b": 0}
    for _ in range(20):
        drr.replenish(["a", "b"], 30)
        budget = WeightedTokenBudget(30, drr)
        progressed = True
        while progressed:
            progressed = False
            for tenant in ("a", "b"):
                if budget.can(1, tenant=tenant):
                    budget.spend(1, tenant=tenant)
                    got[tenant] += 1
                    progressed = True
    assert got["a"] + got["b"] == 20 * 30
    assert got["a"] == pytest.approx(2 * got["b"], rel=0.05)


def test_weighted_budget_requires_both_pool_and_deficit():
    drr = DeficitRoundRobin(weights={"a": 1.0, "b": 1.0})
    drr.reset()
    drr.replenish(["a", "b"], 10)                # 5 deficit each
    budget = WeightedTokenBudget(10, drr)
    # force admits past the deficit only while nothing has been spent
    # (the first-prefill escape hatch; it drives the deficit negative)
    assert budget.can(10**6, tenant="a", force=True)
    assert budget.can(5, tenant="a") and not budget.can(6, tenant="a")
    budget.spend(5, tenant="a")
    assert not budget.can(6, tenant="b")         # global pool: 5 left
    assert budget.can(5, tenant="b")
    assert not budget.can(10**6, tenant="a", force=True)
    assert drr.balance("a") == 0.0


# ---------------------------------------------------------------------------
# the reduction pin: single tenant == FIFO, fixed RNG
# ---------------------------------------------------------------------------

@functools.lru_cache(maxsize=1)
def _pinned_engines():
    """One FIFO engine and one TenantScheduler engine, identical seeds:
    reused across property examples (jit caches are per-engine)."""
    cfg, params, _ = _setup()
    ecfg = _ecfg(chunk=4, budget=16, temperature=0.8, max_new=10)
    fifo = Engine(params, cfg, ecfg, make_policy("sc"),
                  scheduler=FIFOPolicy())
    tenant = Engine(params, cfg, ecfg, make_policy("sc"),
                    scheduler=TenantScheduler(weights={}))
    return fifo, tenant


@settings(max_examples=5, deadline=None)
@given(st.integers(1, 3), st.integers(1, 3),
       st.lists(st.integers(0, 2), min_size=1, max_size=3))
def test_tenant_scheduler_reduces_to_fifo(n_reqs, n_traces, order):
    """Property: for single-tenant workloads the TenantScheduler must be
    operation-identical to the FIFO policy — same tokens, same trace
    scores, same prune counts — under stochastic sampling with the same
    engine seed (i.e. the schedulers consume the RNG stream in the same
    order). This pins the redesign contract: the event core with default
    policies reproduces the old tick loop exactly."""
    cfg, params, prompts = _setup()
    fifo, tenant = _pinned_engines()
    chosen = [prompts[i] for i in order][:n_reqs] or [prompts[0]]
    snaps = []
    for eng in (fifo, tenant):
        eng._rng = jax.random.PRNGKey(eng.ecfg.seed)   # fixed RNG
        results = eng.serve_batch(_reqs(chosen, n=n_traces))
        assert eng.pool_drained()
        eng.block_mgr.check_invariants()
        snaps.append(_snapshot(results))
    assert snaps[0] == snaps[1]


def test_reduction_holds_with_staggered_arrivals(setup):
    """Greedy + roomy pool: the reduction also holds for online arrivals
    (timing jitter moves tick boundaries, never the argmax tokens)."""
    cfg, params, prompts = setup
    snaps = []
    for sched in (None, TenantScheduler(weights={})):
        eng = Engine(params, cfg, _ecfg(chunk=4), make_policy("sc"),
                     scheduler=sched)
        results = eng.serve_batch(
            _reqs(prompts, n=2, arrivals=[0.0, 0.05, 0.1]))
        assert eng.pool_drained()
        snaps.append({rid: [t for t, _, _ in traces]
                      for rid, (traces, _) in _snapshot(results).items()})
    assert snaps[0] == snaps[1]


# ---------------------------------------------------------------------------
# multi-tenant behaviour
# ---------------------------------------------------------------------------

def test_priority_admission_order(setup):
    """With one decode slot pair, the priority-1 tenant's request jumps
    the queue even though it was submitted second."""
    cfg, params, prompts = setup
    eng = Engine(params, cfg, _ecfg(batch=2), make_policy("sc"),
                 scheduler=TenantScheduler(
                     weights={"premium": 3.0, "batch": 1.0}))
    reqs = [
        Request(request_id=0, prompt_tokens=prompts[0], n_traces=2,
                policy=make_policy("sc"), tenant="batch", priority=0),
        Request(request_id=1, prompt_tokens=prompts[1], n_traces=2,
                policy=make_policy("sc"), tenant="premium", priority=1),
    ]
    results = eng.serve_batch(reqs)
    assert eng.pool_drained()
    m_batch, m_premium = results[0].metrics, results[1].metrics
    assert m_premium.first_token_s <= m_batch.first_token_s
    assert m_premium.tenant == "premium" and m_premium.priority == 1
    for r in results:
        assert all(t.status == TraceStatus.FINISHED for t in r.traces)


def test_tenant_pressure_published_to_policies(setup):
    """Under a TenantScheduler, AdmissionPressure carries the per-tenant
    demand/deficit views (None under FIFO)."""
    cfg, params, prompts = setup
    seen = []

    class Spy(type(make_policy("sc"))):
        def observe_pressure(self, pressure):
            super().observe_pressure(pressure)
            seen.append(pressure)

    eng = Engine(params, cfg, _ecfg(batch=2, budget=16),
                 make_policy("sc"),
                 scheduler=TenantScheduler(weights={"t0": 1.0}))
    eng.serve_batch([Request(request_id=0, prompt_tokens=prompts[0],
                             n_traces=4, policy=Spy(), tenant="t0")])
    assert seen
    assert any(p.demand_by_tenant is not None for p in seen)
    assert any("t0" in (p.deficit_by_tenant or {}) for p in seen)


def test_slo_degrades_trace_fanout(setup):
    """An unmeetable TTFT objective degrades the request's fan-out to
    min_traces at admission (quality-for-latency, the paper's dial)."""
    cfg, params, prompts = setup
    eng = Engine(params, cfg, _ecfg(), make_policy("sc"),
                 scheduler=TenantScheduler(weights={}))
    res = eng.serve_batch(_reqs(prompts[:1], n=4,
                                slo=SLO(ttft_s=0.0, min_traces=1)))[0]
    assert eng.pool_drained()
    assert res.metrics.degraded_traces == 3
    assert sum(t.status == TraceStatus.FINISHED for t in res.traces) == 1
    assert sum(t.status == TraceStatus.PRUNED for t in res.traces) == 3
    survivor = next(t for t in res.traces
                    if t.status == TraceStatus.FINISHED)
    assert survivor.num_tokens > 0


def test_slo_meetable_keeps_all_traces(setup):
    """A generous objective must not degrade anything."""
    cfg, params, prompts = setup
    eng = Engine(params, cfg, _ecfg(), make_policy("sc"),
                 scheduler=TenantScheduler(weights={}))
    res = eng.serve_batch(_reqs(prompts[:1], n=4,
                                slo=SLO(ttft_s=60.0)))[0]
    assert res.metrics.degraded_traces == 0
    assert all(t.status == TraceStatus.FINISHED for t in res.traces)
    assert res.metrics.ttft_attained is True


def test_slo_shed_rejects_request(setup):
    """shed=True + a hopeless projection rejects the request outright:
    every trace is pruned at admission, answer None."""
    cfg, params, prompts = setup
    eng = Engine(params, cfg, _ecfg(), make_policy("sc"),
                 scheduler=TenantScheduler(weights={}))
    res = eng.serve_batch(_reqs(
        prompts[:1], n=4,
        slo=SLO(ttft_s=1e-9, shed=True, shed_factor=1.0)))[0]
    assert eng.pool_drained()
    assert res.answer is None
    assert all(t.status == TraceStatus.PRUNED for t in res.traces)
    assert res.metrics.degraded_traces == 4
    assert res.metrics.ttft_attained is False  # shed counts as a miss


def test_slo_ignored_under_fifo(setup):
    """The default FIFO policy never degrades: SLOs are reported, not
    enforced (back-compat for existing callers)."""
    cfg, params, prompts = setup
    eng = Engine(params, cfg, _ecfg(), make_policy("sc"))
    res = eng.serve_batch(_reqs(prompts[:1], n=4,
                                slo=SLO(ttft_s=0.0)))[0]
    assert res.metrics.degraded_traces == 0
    assert all(t.status == TraceStatus.FINISHED for t in res.traces)


# ---------------------------------------------------------------------------
# per-request overrides
# ---------------------------------------------------------------------------

def test_per_request_max_new_tokens_override(setup):
    """A request-level max_new_tokens caps only that request; greedy
    sampling makes the capped output a prefix of the uncapped one."""
    cfg, params, prompts = setup
    eng = Engine(params, cfg, _ecfg(max_new=12), make_policy("sc"))
    reqs = [Request(request_id=0, prompt_tokens=prompts[0], n_traces=1,
                    policy=make_policy("sc"), max_new_tokens=4),
            Request(request_id=1, prompt_tokens=prompts[0], n_traces=1,
                    policy=make_policy("sc"))]
    results = eng.serve_batch(reqs)
    assert eng.pool_drained()
    short = results[0].traces[0].output_tokens
    long = results[1].traces[0].output_tokens
    assert len(short) <= 4 and len(long) <= 12
    assert long[:len(short)] == short


def test_per_request_sampling_override_lanewise(setup):
    """A mixed batch (one request overrides SamplingParams) runs the
    lane-wise sampling path; a greedy-override lane must produce exactly
    the scalar greedy engine's tokens (argmax ignores the RNG lane)."""
    cfg, params, prompts = setup
    greedy = SamplingParams(temperature=0.0, top_k=0, top_p=1.0,
                            max_new_tokens=10)
    # reference: engine whose global sampling is greedy (scalar path)
    ref = Engine(params, cfg, _ecfg(max_new=10), make_policy("sc"))
    want = [t.output_tokens
            for t in ref.serve_batch(_reqs(prompts[:1], n=1))[0].traces]

    # mixed batch: request 0 overrides to greedy, request 1 inherits the
    # stochastic engine default -> lane-wise decode for the whole batch
    eng = Engine(params, cfg, _ecfg(max_new=10, temperature=0.8),
                 make_policy("sc"))
    reqs = [Request(request_id=0, prompt_tokens=prompts[0], n_traces=1,
                    policy=make_policy("sc"), sampling=greedy),
            Request(request_id=1, prompt_tokens=prompts[1], n_traces=2,
                    policy=make_policy("sc"))]
    results = eng.serve_batch(reqs)
    assert eng.pool_drained()
    eng.block_mgr.check_invariants()
    assert [t.output_tokens for t in results[0].traces] == want
    for r in results:
        assert all(t.status == TraceStatus.FINISHED for t in r.traces)


def test_uniform_override_matches_engine_default(setup):
    """Every request overriding to the engine's own params is NOT a
    mixed batch: outputs are identical to no-override submission."""
    cfg, params, prompts = setup
    outs = []
    for extra in ({}, {"sampling": SamplingParams(
            temperature=0.0, top_k=0, top_p=1.0, max_new_tokens=12)}):
        eng = Engine(params, cfg, _ecfg(max_new=12), make_policy("sc"))
        results = eng.serve_batch(_reqs(prompts, n=2, **extra))
        outs.append({r.request_id: [t.output_tokens for t in r.traces]
                     for r in results})
    assert outs[0] == outs[1]


# ---------------------------------------------------------------------------
# event stream
# ---------------------------------------------------------------------------

def test_event_stream_contract(setup):
    """serve_batch leaves the dispatched event tail on the engine:
    arrivals precede everything for their request, one Completion per
    request, timestamps non-decreasing."""
    cfg, params, prompts = setup
    eng = Engine(params, cfg, _ecfg(chunk=4, budget=16),
                 make_policy("sc"))
    results = eng.serve_batch(_reqs(prompts, n=2))
    log = eng.last_event_log
    assert log and isinstance(log[0], Arrival)
    times = [ev.t for ev in log]
    assert times == sorted(times)
    completions = [ev for ev in log if isinstance(ev, Completion)]
    assert sorted(ev.request_id for ev in completions) == [0, 1, 2]
    assert any(isinstance(ev, BurstDone) for ev in log)
    assert any(isinstance(ev, BudgetReplenish) for ev in log)
    arrival_at = {ev.request_id: i for i, ev in enumerate(log)
                  if isinstance(ev, Arrival)}
    for i, ev in enumerate(log):
        if isinstance(ev, Completion):
            assert arrival_at[ev.request_id] < i
    assert len(results) == 3
