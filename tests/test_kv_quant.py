"""Quantized paged-KV pool: round-trip bounds, read-path alignment,
engine-level dtype contracts, byte accounting, and the fused scorer.

The exactness contract (module docstring of ``repro.models.kv_quant``):

- ``bf16`` vs ``f32`` pools are ENGINE-IDENTICAL (tokens, step scores,
  confidences, prune decisions) — activations are bf16, so an f32 pool
  stores the same values a bf16 pool does, just wider.
- ``int8``/``fp8`` pools get BOUNDED-DRIFT guarantees: per-element
  round-trip error within the scale-derived bound, attention outputs
  within a small relative drift of the float-pool result, and the
  engine still serves/prunes/drains correctly.
- The Pallas kernel's in-loop dequant matches the dense fallback's
  gathered dequant (same codes, same bf16-grid scales, same f32 math).
"""
import dataclasses

import jax
import jax.numpy as jnp
import numpy as np
import pytest
from hypothesis import given, settings
from hypothesis import strategies as st

from repro.configs.registry import serving_config
from repro.core.pruning import make_policy
from repro.core.scorer import init_scorer, scorer_score
from repro.data.tokenizer import get_tokenizer
from repro.kernels import ops as kops
from repro.models import kv_quant
from repro.models.init import init_params
from repro.serving import Engine, EngineConfig, SamplingParams

# ---------------------------------------------------------------------------
# quantize/dequantize properties
# ---------------------------------------------------------------------------

# int-grid floats keep the stub-compatible strategy surface (no floats()):
# value = mantissa * 2^exp spans several binades with exact inputs
_mantissa = st.integers(min_value=-4096, max_value=4096)
_exp = st.integers(min_value=-4, max_value=4)


@st.composite
def _vectors(draw):
    hd = draw(st.sampled_from([4, 8, 16]))
    rows = draw(st.integers(min_value=1, max_value=5))
    e = draw(_exp)
    vals = [draw(_mantissa) for _ in range(rows * hd)]
    x = np.asarray(vals, np.float32).reshape(rows, hd) * (2.0 ** e)
    return x


@settings(max_examples=25, deadline=None)
@given(_vectors(), st.booleans())
def test_quantize_roundtrip_bounded(x, use_int8):
    """Per-element round-trip error stays under the scale-derived bound:
    ~scale/2 (+ bf16-scale-grid slack) for int8, ~2^-4 relative for
    fp8's 3-bit mantissa. Zero vectors stay exactly zero at scale 1."""
    if not use_int8 and kv_quant.fp8_dtype() is None:
        return  # this jax lacks float8; int8 half still runs
    qdtype = jnp.int8 if use_int8 else kv_quant.fp8_dtype()
    q, scale = kv_quant.quantize_pages(jnp.asarray(x), qdtype)
    rt = np.asarray(kv_quant.dequantize_pages(q, scale))
    absmax = np.max(np.abs(x), axis=-1, keepdims=True)
    s = np.asarray(scale)[..., None]
    if use_int8:
        bound = 1.5 * s  # round-to-nearest + bf16 scale grid + clip edge
    else:
        bound = 0.07 * absmax + 1e-7
    assert np.all(np.abs(rt - x) <= bound)
    zero_rows = absmax[..., 0] == 0.0
    assert np.all(np.asarray(scale)[zero_rows] == 1.0)
    assert np.all(rt[zero_rows] == 0.0)


@settings(max_examples=25, deadline=None)
@given(_vectors())
def test_quantize_is_per_slot_pure(x):
    """A slot's codes and scale depend only on its own vector: quantizing
    row-by-row matches quantizing the batch — the property that makes
    every pool write path (one-shot, chunked, decode, COW) commute."""
    q_all, s_all = kv_quant.quantize_pages(jnp.asarray(x), jnp.int8)
    for i in range(x.shape[0]):
        q_i, s_i = kv_quant.quantize_pages(jnp.asarray(x[i:i + 1]),
                                           jnp.int8)
        assert np.array_equal(np.asarray(q_all[i:i + 1]), np.asarray(q_i))
        assert np.array_equal(np.asarray(s_all[i:i + 1]), np.asarray(s_i))


def test_scales_live_on_bf16_grid():
    """Stored scales are bf16-representable f32 — the property that keeps
    ``code * scale`` exact in f32 and the kernel/dense read paths
    bit-aligned (see quantize_pages docstring)."""
    x = jax.random.normal(jax.random.PRNGKey(0), (32, 64), jnp.float32)
    _, scale = kv_quant.quantize_pages(x, jnp.int8)
    assert scale.dtype == jnp.float32
    assert np.array_equal(
        np.asarray(scale),
        np.asarray(scale.astype(jnp.bfloat16).astype(jnp.float32)))


# ---------------------------------------------------------------------------
# dtype registry / gating / byte accounting
# ---------------------------------------------------------------------------

def test_resolve_kv_dtype_gating():
    cfg = serving_config()
    for dt in ("f32", "bf16"):
        assert kv_quant.resolve_kv_dtype(dt, cfg, False) == dt
    assert kv_quant.resolve_kv_dtype("int8", cfg, True) == "int8"
    with pytest.raises(NotImplementedError, match="SUPPORT_MATRIX"):
        kv_quant.resolve_kv_dtype("int8", cfg, False)
    with pytest.raises(ValueError, match="kv_dtype"):
        kv_quant.resolve_kv_dtype("int4", cfg, True)
    if kv_quant.fp8_dtype() is None:
        with pytest.raises(NotImplementedError, match="float8"):
            kv_quant.resolve_kv_dtype("fp8", cfg, True)
    else:
        assert kv_quant.resolve_kv_dtype("fp8", cfg, True) == "fp8"


def test_pool_block_bytes_ordering():
    cfg = serving_config()
    b = {dt: kv_quant.pool_block_bytes(cfg, dt)
         for dt in ("f32", "bf16", "int8")}
    assert b["f32"] == 2 * b["bf16"]
    # int8 pays half of bf16 plus the per-slot f32 scales (1/head_dim
    # of the f32 pool bytes per K/V)
    la = len(cfg.attention_layer_ids())
    scales = la * 2 * cfg.kv_block_size * cfg.num_kv_heads * 4
    assert b["int8"] == b["bf16"] // 2 + scales
    assert b["int8"] < b["bf16"] < b["f32"]


def test_engine_byte_accounting():
    """BlockManager carries pool_block_bytes into AdmissionPressure so
    the scheduler's admission math can reason in HBM bytes."""
    cfg = serving_config()
    params = init_params(cfg, jax.random.PRNGKey(0))
    eng = Engine(params, cfg, _ecfg(kv_dtype="int8"), make_policy("sc"))
    from repro.core.pruning import AdmissionPressure
    expect = kv_quant.pool_block_bytes(cfg, "int8")
    assert eng.kv_block_bytes == expect
    assert eng.block_mgr.bytes_per_block == expect
    assert eng.block_mgr.free_bytes \
        == eng.block_mgr.free_blocks * expect
    p = AdmissionPressure(waiting_traces=0, queued_requests=0,
                          free_blocks=eng.block_mgr.free_blocks,
                          total_blocks=10, cached_blocks=2,
                          evictable_blocks=2, bytes_per_block=expect)
    assert p.total_bytes == 10 * expect
    assert p.free_bytes == eng.block_mgr.free_blocks * expect
    assert p.reclaimable_bytes == (eng.block_mgr.free_blocks + 2) * expect


# ---------------------------------------------------------------------------
# kernel-vs-dense read-path alignment (op level)
# ---------------------------------------------------------------------------

def _quantized_pool(key, nb, page, kvh, hd, qdtype):
    x = jax.random.normal(key, (nb, page, kvh, hd), jnp.float32)
    q, s = kv_quant.quantize_pages(x, qdtype)
    return x, q, s


def test_kernel_decode_matches_dense_dequant():
    """The kernel's in-loop dequant reproduces the dense path's gathered
    dequant: same codes * same scales -> same f32 operands, outputs
    equal to reduction-order noise."""
    k0, k1, k2 = jax.random.split(jax.random.PRNGKey(3), 3)
    nb, page, kvh, hd, B, H = 8, 4, 2, 16, 3, 4
    _, kq, ks = _quantized_pool(k0, nb, page, kvh, hd, jnp.int8)
    _, vq, vs = _quantized_pool(k1, nb, page, kvh, hd, jnp.int8)
    q = jax.random.normal(k2, (B, H, hd), jnp.float32)
    bt = jnp.arange(B * 2, dtype=jnp.int32).reshape(B, 2)
    lens = jnp.array([3, 8, 5], jnp.int32)
    scale = 1.0 / np.sqrt(hd)

    out = kops.paged_attention(q, kq, vq, bt, lens, scale=scale,
                               k_scale=ks, v_scale=vs)

    kf = kv_quant.dequantize_pages(kq, ks)[bt].reshape(B, -1, kvh, hd)
    vf = kv_quant.dequantize_pages(vq, vs)[bt].reshape(B, -1, kvh, hd)
    G = H // kvh
    qg = q.reshape(B, kvh, G, hd)
    s = jnp.einsum("bkgh,bskh->bkgs", qg, kf,
                   preferred_element_type=jnp.float32) * scale
    mask = jnp.arange(kf.shape[1])[None, :] < lens[:, None]
    s = jnp.where(mask[:, None, None], s, -1e30)
    p = jax.nn.softmax(s, axis=-1)
    ref = jnp.einsum("bkgs,bskh->bkgh", p, vf).reshape(B, H, hd)
    np.testing.assert_allclose(np.asarray(out), np.asarray(ref),
                               atol=1e-6, rtol=1e-6)


def test_quantized_attention_drift_bounded():
    """int8 pool attention stays within a small relative drift of the
    float-pool result — the op-level bound behind the engine-level
    bounded-drift contract."""
    k0, k1, k2 = jax.random.split(jax.random.PRNGKey(4), 3)
    nb, page, kvh, hd, B, H = 8, 4, 2, 16, 3, 4
    kx, kq, ks = _quantized_pool(k0, nb, page, kvh, hd, jnp.int8)
    vx, vq, vs = _quantized_pool(k1, nb, page, kvh, hd, jnp.int8)
    q = jax.random.normal(k2, (B, H, hd), jnp.float32)
    bt = jnp.arange(B * 2, dtype=jnp.int32).reshape(B, 2)
    lens = jnp.array([3, 8, 5], jnp.int32)
    scale = 1.0 / np.sqrt(hd)
    out_q = kops.paged_attention(q, kq, vq, bt, lens, scale=scale,
                                 k_scale=ks, v_scale=vs)
    out_f = kops.paged_attention(q, kx, vx, bt, lens, scale=scale)
    diff = np.abs(np.asarray(out_q) - np.asarray(out_f)).max()
    assert diff < 0.05 * np.abs(np.asarray(out_f)).max()


# ---------------------------------------------------------------------------
# engine-level dtype contracts
# ---------------------------------------------------------------------------

@pytest.fixture(scope="module")
def setup():
    cfg = serving_config()
    params = init_params(cfg, jax.random.PRNGKey(0))
    scorer = init_scorer(jax.random.PRNGKey(1), cfg.d_model)
    tok = get_tokenizer()
    return cfg, params, scorer, tok


def _ecfg(kv_dtype="bf16", num_blocks=40, max_new=24, chunk=None,
          K=1, temperature=0.0, use_kernel=False, prefix_cache=False):
    return EngineConfig(
        max_batch=8, num_blocks=num_blocks, capacity=128,
        max_new_tokens=max_new,
        sampling=SamplingParams(
            temperature=temperature,
            top_k=0 if temperature == 0.0 else 20,
            top_p=1.0 if temperature == 0.0 else 0.95,
            max_new_tokens=max_new),
        prefill_chunk_size=chunk, decode_horizon=K,
        use_kernel=use_kernel, kv_dtype=kv_dtype,
        share_prompt_prefix=prefix_cache, prefix_cache=prefix_cache)


def _serve(setup, seed=7, n=4, prompt="3+5-2=", **kw):
    cfg, params, scorer, tok = setup
    eng = Engine(params, cfg, _ecfg(**kw), make_policy("step"),
                 scorer_params=scorer)
    eng._rng = jax.random.PRNGKey(seed)
    res = eng.serve(tok.encode(prompt, add_bos=True), n)
    assert eng.pool_drained()
    eng.block_mgr.check_invariants()
    return eng, res


def test_engine_bf16_f32_identical(setup):
    """bf16 and f32 pools serve IDENTICAL results: activations are bf16,
    so the f32 pool stores exactly the values the bf16 pool does."""
    runs = {}
    for dt in ("bf16", "f32"):
        _, res = _serve(setup, kv_dtype=dt, temperature=0.8, chunk=4, K=2)
        runs[dt] = [(t.output_tokens, t.step_scores, t.token_confidences,
                     t.status) for t in res.traces]
    assert runs["bf16"] == runs["f32"]


@pytest.mark.parametrize("kv_dtype", [
    "int8",
    pytest.param("fp8", marks=pytest.mark.skipif(
        kv_quant.fp8_dtype() is None, reason="no float8 in this jax")),
])
def test_engine_quantized_bounded_drift(setup, kv_dtype):
    """Quantized pools: the engine still serves end-to-end (greedy decode,
    chunked prefill, scorer, pruning bookkeeping) and its step scores
    stay within a loose drift band of the float-pool run — the engine
    face of the op-level 5% attention bound."""
    _, res_f = _serve(setup, kv_dtype="f32")
    _, res_q = _serve(setup, kv_dtype=kv_dtype)
    assert len(res_q.traces) == len(res_f.traces)
    for tq, tf in zip(res_q.traces, res_f.traces):
        assert len(tq.output_tokens) > 0
        for sq, sf in zip(tq.step_scores, tf.step_scores):
            assert abs(sq - sf) < 0.25


def test_engine_int8_kernel_path_smoke(setup):
    """Quantized pool + Pallas kernel path (in-kernel dequant) + chunked
    prefill + decode horizon all compose; tokens match the quantized
    dense path exactly (decode face is bit-aligned, greedy sampling)."""
    _, res_d = _serve(setup, kv_dtype="int8", use_kernel=False, K=2,
                      chunk=4)
    _, res_k = _serve(setup, kv_dtype="int8", use_kernel=True, K=2,
                      chunk=4)
    assert [t.output_tokens for t in res_d.traces] \
        == [t.output_tokens for t in res_k.traces]
    assert [t.status for t in res_d.traces] \
        == [t.status for t in res_k.traces]


def test_prefix_cache_serves_quantized_blocks(setup):
    """Scales travel with parked blocks: a warm-cache replay under int8
    hits the radix tree, serves from quantized parked KV, and drains
    cleanly with allocator integrity intact."""
    cfg, params, scorer, tok = setup
    eng = Engine(params, cfg,
                 _ecfg(kv_dtype="int8", num_blocks=24, prefix_cache=True),
                 make_policy("step"), scorer_params=scorer)
    prompt = tok.encode("1+2-3+4-5+6-7+8=" * 2, add_bos=True)
    rounds = []
    for _ in range(2):
        res = eng.serve(prompt, 4)
        rounds.append([t.output_tokens for t in res.traces])
    assert eng.prefix_cache is not None
    assert eng.prefix_cache.stats.hits > 0
    # warm replay reads the same quantized prefix KV -> same greedy tokens
    assert rounds[0] == rounds[1]
    assert eng.pool_drained()
    eng.block_mgr.check_invariants()
    eng.prefix_cache.check_integrity()


# ---------------------------------------------------------------------------
# fused step scorer
# ---------------------------------------------------------------------------

def test_fused_scorer_matches_dense_scorer(setup):
    """The Pallas step_score kernel computes the scorer_score graph (f32
    matmuls, ReLU, sigmoid); only matmul reduction order differs, so
    outputs agree to f32 ulps on arbitrary hiddens (the engine-level
    test below pins exact equality on real decode hiddens)."""
    cfg, _, scorer, _ = setup
    h = jax.random.normal(jax.random.PRNGKey(5), (16, cfg.d_model),
                          jnp.bfloat16)
    fused = kops.step_score_params(h, scorer)
    dense = scorer_score(scorer, h)
    np.testing.assert_allclose(np.asarray(fused), np.asarray(dense),
                               atol=2e-6, rtol=0)


def test_fused_scorer_engages_on_kernel_path(setup):
    """use_kernel=True fuses the scorer into the decode burst; the dense
    engine keeps the separate pass. Scores stay identical either way
    (the engine-level fused-vs-separate identity pin)."""
    cfg, params, scorer, tok = setup
    engines = {}
    for uk in (False, True):
        eng = Engine(params, cfg, _ecfg(use_kernel=uk, K=2),
                     make_policy("step"), scorer_params=scorer)
        engines[uk] = eng
    assert engines[False].fused_scorer is False
    assert engines[True].fused_scorer is True
    results = {}
    for uk, eng in engines.items():
        eng._rng = jax.random.PRNGKey(11)
        res = eng.serve(tok.encode("3+5-2=", add_bos=True), 4)
        results[uk] = [t.step_scores for t in res.traces]
    assert results[False] == results[True]


def test_no_scorer_no_fusion(setup):
    cfg, params, _, _ = setup
    eng = Engine(params, cfg, _ecfg(use_kernel=True), make_policy("sc"))
    assert eng.fused_scorer is False
