"""Launcher train-step semantics: gradient accumulation and low-precision
moments must preserve training math."""
import jax
import jax.numpy as jnp
import numpy as np
import pytest

from repro.configs.registry import serving_config
from repro.launch.steps import make_train_step
from repro.models.init import init_params


@pytest.fixture(scope="module")
def setup():
    cfg = serving_config()
    params = init_params(cfg, jax.random.PRNGKey(0))
    rng = jax.random.PRNGKey(1)
    batch = {
        "tokens": jax.random.randint(rng, (8, 64), 0, cfg.vocab_size),
        "labels": jax.random.randint(rng, (8, 64), 0, cfg.vocab_size),
    }
    return cfg, params, batch


def test_microbatch_equals_full_batch_loss(setup):
    """mean microbatch loss == full-batch loss (same data, fixed params)."""
    cfg, params, batch = setup
    step1, opt1 = make_train_step(cfg, lr=0.0)   # lr=0: params unchanged
    step2, opt2 = make_train_step(cfg, lr=0.0, microbatches=2)
    s1 = opt1.init(params)
    s2 = opt2.init(params)
    _, _, loss1 = jax.jit(step1)(params, s1, batch)
    _, _, loss2 = jax.jit(step2)(params, s2, batch)
    # microbatch losses average over sub-batches of equal size
    np.testing.assert_allclose(float(loss1), float(loss2),
                               rtol=2e-2, atol=2e-2)


def test_microbatch_updates_close_to_full(setup):
    """One real update step: accumulated grads ~ full-batch grads."""
    cfg, params, batch = setup
    step1, opt1 = make_train_step(cfg, lr=1e-3)
    step2, opt2 = make_train_step(cfg, lr=1e-3, microbatches=4)
    p1, _, _ = jax.jit(step1)(params, opt1.init(params), batch)
    p2, _, _ = jax.jit(step2)(params, opt2.init(params), batch)
    l1 = jax.tree_util.tree_leaves(p1)
    l2 = jax.tree_util.tree_leaves(p2)
    for a, b in zip(l1, l2):
        np.testing.assert_allclose(np.asarray(a, np.float32),
                                   np.asarray(b, np.float32),
                                   rtol=0.1, atol=1e-2)


def test_bf16_moments_still_learn(setup):
    cfg, params, batch = setup
    step, opt = make_train_step(cfg, lr=1e-3, moment_dtype="bfloat16",
                                accum_dtype="bfloat16", microbatches=2)
    state = opt.init(params)
    assert all(x.dtype == jnp.bfloat16
               for x in jax.tree_util.tree_leaves(state.mu))
    step = jax.jit(step)
    losses = []
    p = params
    for _ in range(4):
        p, state, loss = step(p, state, batch)
        losses.append(float(loss))
    assert losses[-1] < losses[0], losses
    assert all(np.isfinite(l) for l in losses)
