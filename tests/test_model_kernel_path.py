"""Model forward with use_kernel=True (Pallas, interpret on CPU) must match
the pure-jnp path for every arch family that has a kernelized hot spot."""
import jax
import jax.numpy as jnp
import numpy as np
import pytest

from repro.configs.registry import get_config
from repro.models.init import init_params
from repro.models.model import forward_full


@pytest.mark.parametrize("arch", [
    "qwen3-1.7b",      # dense GQA -> flash_attention
    "starcoder2-3b",   # GQA kv=2
    "mixtral-8x7b",    # SWA + MoE -> windowed flash
    "mamba2-2.7b",     # SSD -> ssd_scan kernel
    "zamba2-2.7b",     # hybrid -> ssd_scan + flash
])
def test_forward_kernel_matches_jnp(arch):
    cfg = get_config(arch, smoke=True)
    params = init_params(cfg, jax.random.PRNGKey(0))
    toks = jax.random.randint(jax.random.PRNGKey(1), (2, 128), 0,
                              cfg.vocab_size)
    a = np.asarray(forward_full(params, cfg, toks,
                                use_kernel=False)["logits"], np.float32)
    b = np.asarray(forward_full(params, cfg, toks,
                                use_kernel=True)["logits"], np.float32)
    if cfg.uses_moe:
        # bf16 attention-path noise can flip borderline top-k router
        # picks for ~1% of tokens, changing their whole FFN output —
        # assert elementwise agreement instead of strict allclose
        close = np.isclose(a, b, rtol=0.05, atol=0.05)
        assert close.mean() > 0.97, f"{arch}: only {close.mean():.3f} close"
    else:
        np.testing.assert_allclose(a, b, rtol=0.05, atol=0.05)


def test_prefill_chunk_step_kernel_matches_jnp():
    """The full chunked-prefill model step through the multi-query paged
    kernel == the dense path: logits at valid positions and the written
    KV pools (the bytes decode reads later) agree."""
    from repro.configs.registry import serving_config
    from repro.models.model import init_decode_cache, prefill_chunk_step

    cfg = serving_config()
    params = init_params(cfg, jax.random.PRNGKey(0))
    B, C, cap = 1, 6, 64
    cache0 = init_decode_cache(cfg, B, cap)
    start, n_real = 19, 4  # chunk boundary mid-page, right-padded tail
    toks = jax.random.randint(jax.random.PRNGKey(1), (B, C), 0,
                              cfg.vocab_size)
    positions = start + jnp.arange(C, dtype=jnp.int32)[None, :]
    valid = jnp.arange(C)[None, :] < n_real
    outs = {}
    for uk in (False, True):
        out = prefill_chunk_step(params, cfg, toks, positions, valid,
                                 dict(cache0), window_len=cap,
                                 use_kernel=uk)
        outs[uk] = out
    a = np.asarray(outs[False]["logits"][:, :n_real], np.float32)
    b = np.asarray(outs[True]["logits"][:, :n_real], np.float32)
    np.testing.assert_allclose(a, b, rtol=0.05, atol=0.05)
    for key in ("k_pool", "v_pool"):
        np.testing.assert_array_equal(
            np.asarray(outs[False]["cache"][key], np.float32),
            np.asarray(outs[True]["cache"][key], np.float32))
