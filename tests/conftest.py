"""Test-session bootstrap.

If the real ``hypothesis`` package is unavailable (hermetic environments
without network access), register ``tests/_hypothesis_stub.py`` under the
``hypothesis`` / ``hypothesis.strategies`` module names BEFORE collection
imports the property-test modules. Environments built with
``pip install -e .[test]`` (CI, dev machines) get the real package and the
stub is never loaded.
"""
import importlib.util
import os
import sys


def _install_hypothesis_stub() -> None:
    try:
        import hypothesis  # noqa: F401  (real package wins)
        return
    except ImportError:
        pass
    path = os.path.join(os.path.dirname(__file__), "_hypothesis_stub.py")
    spec = importlib.util.spec_from_file_location("hypothesis", path)
    mod = importlib.util.module_from_spec(spec)
    spec.loader.exec_module(mod)
    mod.strategies = mod  # `from hypothesis import strategies as st`
    sys.modules["hypothesis"] = mod
    sys.modules["hypothesis.strategies"] = mod


_install_hypothesis_stub()
