"""Cross-request prefix cache: trie match/insert/evict semantics, the
partial-tail-block boundary rule, and engine integration (a cache hit
forks parked KV with zero recompute and is invisible to generation)."""
import jax
import pytest

from repro.configs.registry import serving_config
from repro.core.pruning import make_policy
from repro.core.scorer import init_scorer
from repro.core.trace import TraceStatus
from repro.data.tokenizer import get_tokenizer
from repro.models.init import init_params
from repro.serving import (Engine, EngineConfig, PrefixCache, Request,
                           SamplingParams)
from repro.serving.kv_manager import BlockManager

BS = 16  # cfg.kv_block_size for serving_config


# ---------------------------------------------------------------------------
# trie-level semantics (no model)
# ---------------------------------------------------------------------------

def _mgr(n=32):
    return BlockManager(num_blocks=n, block_size=BS)


def _toks(n, base=100):
    return list(range(base, base + n))


def test_insert_parks_only_full_blocks():
    mgr = _mgr()
    pc = PrefixCache(mgr)
    t = _toks(3 * BS + 5)
    blocks = mgr.allocate(3)  # the engine passes blocks[:len(t) // BS]
    assert pc.insert(t, blocks) == 3
    assert pc.cached_blocks == 3
    assert mgr.used_blocks == 3  # cache now owns them
    pc.check_integrity()
    mgr.check_invariants()


def test_match_is_strict_prefix_at_block_boundaries():
    """Boundary +/-1 regression: a query never matches its own last
    block-aligned chunk in full — at least one token is always left to
    prefill (its logits seed the first sampled token)."""
    mgr = _mgr()
    pc = PrefixCache(mgr)
    t = _toks(2 * BS)
    pc.insert(t, mgr.allocate(2))
    # exact multiple: strict prefix only -> one block, not two
    got, n = pc.match(t)
    assert (len(got), n) == (1, BS)
    # one short of the boundary: the second chunk is partial -> one block
    got, n = pc.match(t[: 2 * BS - 1])
    assert (len(got), n) == (1, BS)
    # one past: both cached chunks are strict-prefix -> two blocks
    got, n = pc.match(_toks(2 * BS + 1))
    assert (len(got), n) == (2, 2 * BS)
    # shorter than one block: never matches anything
    got, n = pc.match(t[: BS - 1])
    assert (len(got), n) == (0, 0)
    assert pc.stats.lookups == 4 and pc.stats.misses == 1


def test_match_stops_at_divergence():
    mgr = _mgr()
    pc = PrefixCache(mgr)
    t = _toks(2 * BS)
    pc.insert(t, mgr.allocate(2))
    diverged = t[:BS] + _toks(BS + 1, base=999)
    got, n = pc.match(diverged)
    assert (len(got), n) == (1, BS)


def test_insert_duplicate_chunk_drops_callers_reference():
    """Re-inserting a cached prefix must not leak: the caller's duplicate
    references go back to the free list, the cache keeps its originals."""
    mgr = _mgr(8)
    pc = PrefixCache(mgr)
    t = _toks(2 * BS + 3)
    first = mgr.allocate(2)
    pc.insert(t, first)
    second = mgr.allocate(2)
    assert pc.insert(t, second) == 0  # nothing new
    assert pc.cached_blocks == 2
    assert mgr.used_blocks == 2  # duplicates freed
    assert sorted(pc.blocks()) == sorted(first)
    pc.check_integrity()
    mgr.check_invariants()


def test_evict_is_lru_and_leaf_first():
    mgr = _mgr(8)
    pc = PrefixCache(mgr)
    chain = _toks(2 * BS)  # two-block chain a1 -> a2
    other = _toks(BS, base=500)  # one-block sibling b1
    a = mgr.allocate(2)
    pc.insert(chain, a)
    b = mgr.allocate(1)
    pc.insert(other, b)
    pc.match(chain + [0])  # refresh the whole chain: b1 is now LRU
    assert pc.evict(1) == 1
    assert sorted(pc.blocks()) == sorted(a)  # b went first
    # leaf-first: the chain unwinds a2 before a1
    assert pc.evict(1) == 1
    assert list(pc.blocks()) == [a[0]]
    assert pc.evict(5) == 1  # only one block left to give
    assert pc.cached_blocks == 0
    assert mgr.free_blocks == 7
    mgr.check_invariants()


def test_evict_skips_pinned_blocks():
    """Blocks a live request forked out of the cache (refcount > 1) are
    pinned: eviction must pass over them."""
    mgr = _mgr(8)
    pc = PrefixCache(mgr)
    t = _toks(BS)
    pc.insert(t, mgr.allocate(1))
    got, n = pc.match(t + [0])
    fork = mgr.fork(got)  # a request now reads this block
    assert pc.evict(1) == 0  # pinned
    assert pc.cached_blocks == 1
    mgr.free(fork)
    assert pc.evict(1) == 1
    assert mgr.free_blocks == 7
    mgr.check_invariants()


def test_clear_returns_cache_only_blocks():
    mgr = _mgr(8)
    pc = PrefixCache(mgr)
    pc.insert(_toks(2 * BS), mgr.allocate(2))
    assert pc.clear() == 2
    assert pc.cached_blocks == 0
    assert mgr.free_blocks == 7
    mgr.check_invariants()


def test_engine_config_env_default(monkeypatch):
    def mk():
        return EngineConfig(max_batch=2, num_blocks=8, capacity=64,
                            max_new_tokens=4,
                            sampling=SamplingParams(max_new_tokens=4))
    monkeypatch.delenv("REPRO_PREFIX_CACHE", raising=False)
    assert mk().prefix_cache is True
    monkeypatch.setenv("REPRO_PREFIX_CACHE", "0")
    assert mk().prefix_cache is False
    monkeypatch.setenv("REPRO_PREFIX_CACHE", "off")
    assert mk().prefix_cache is False
    monkeypatch.setenv("REPRO_PREFIX_CACHE", "1")
    assert mk().prefix_cache is True


# ---------------------------------------------------------------------------
# engine integration
# ---------------------------------------------------------------------------

@pytest.fixture(scope="module")
def setup():
    cfg = serving_config()
    params = init_params(cfg, jax.random.PRNGKey(0))
    scorer = init_scorer(jax.random.PRNGKey(1), cfg.d_model)
    return cfg, params, scorer


def _prompt(tok, n_tokens, body="1+2-3+4-5+6-7+8= "):
    """A prompt of exactly ``n_tokens`` (char-level tokenizer + bos)."""
    ids = tok.encode((body * 8)[: n_tokens - 1], add_bos=True)
    assert len(ids) == n_tokens
    return ids


def _ecfg(num_blocks=48, max_new=16, batch=8, prefix_cache=True, **kw):
    return EngineConfig(
        max_batch=batch, num_blocks=num_blocks, capacity=128,
        max_new_tokens=max_new,
        sampling=SamplingParams(temperature=0.0, top_k=0, top_p=1.0,
                                max_new_tokens=max_new),
        share_prompt_prefix=True, prefix_cache=prefix_cache, **kw)


def test_second_request_served_from_cache(setup):
    """Identical prompt twice: the repeat forks the parked blocks (hit
    metrics recorded) and generates the exact same tokens (the cached KV
    is bit-identical to recomputing the prefix)."""
    cfg, params, _ = setup
    tok = get_tokenizer()
    prompt = _prompt(tok, 40)  # 2 full blocks + an 8-token tail
    eng = Engine(params, cfg, _ecfg(), make_policy("sc"))
    assert eng.prefix_cache is not None
    r1 = eng.serve(prompt, 2)
    assert r1.metrics.cached_tokens == 0
    assert eng.prefix_cache.cached_blocks == 2  # tail block NOT parked
    r2 = eng.serve(prompt, 2)
    assert r2.metrics.cached_tokens == 2 * BS
    assert ([t.output_tokens for t in r2.traces]
            == [t.output_tokens for t in r1.traces])
    s = eng.prefix_cache.stats
    assert (s.hits, s.misses) == (1, 1)
    assert s.hit_tokens == 2 * BS
    assert eng.pool_drained()
    eng.block_mgr.check_invariants()

    from repro.serving import summarize
    agg = summarize([r1.metrics, r2.metrics])
    assert agg["total_prompt_tokens"] == 80
    assert agg["total_cached_tokens"] == 32
    assert agg["prefix_hit_rate"] == pytest.approx(0.4)
    assert agg["requests_with_prefix_hit"] == 1


@pytest.mark.parametrize("delta,expect_cached", [(-1, BS), (0, BS),
                                                 (1, 2 * BS)])
def test_block_boundary_prompt_lengths(setup, delta, expect_cached):
    """Partial-tail regression at 2*BS +/- 1 prompt tokens: the warm run
    reuses exactly the full strict-prefix blocks and still generates the
    cold run's tokens (the tail is always re-prefilled privately)."""
    cfg, params, _ = setup
    tok = get_tokenizer()
    prompt = _prompt(tok, 2 * BS + delta)
    eng = Engine(params, cfg, _ecfg(), make_policy("sc"))
    r1 = eng.serve(prompt, 2)
    r2 = eng.serve(prompt, 2)
    assert r2.metrics.cached_tokens == expect_cached
    assert ([t.output_tokens for t in r2.traces]
            == [t.output_tokens for t in r1.traces])
    assert eng.pool_drained()
    eng.block_mgr.check_invariants()


def test_cache_on_off_identical_outputs(setup):
    """Acceptance pin: engine outputs are identical with the cache on vs
    off under fixed RNG, including the warm (hit-serving) rounds."""
    cfg, params, _ = setup
    tok = get_tokenizer()
    prompts = [_prompt(tok, 33), _prompt(tok, 25, body="9*8-7+6= "),
               _prompt(tok, 33)]  # third repeats the first
    runs = []
    for on in (True, False):
        eng = Engine(params, cfg, _ecfg(prefix_cache=on), make_policy("sc"))
        rounds = []
        for _ in range(2):  # second round replays into a warm cache
            reqs = [Request(request_id=i, prompt_tokens=p, n_traces=2,
                            policy=make_policy("sc"))
                    for i, p in enumerate(prompts)]
            results = eng.serve_batch(reqs)
            rounds.append([[t.output_tokens for t in r.traces]
                           for r in results])
        runs.append(rounds)
        assert eng.pool_drained()
        eng.block_mgr.check_invariants()
    assert runs[0] == runs[1]


def test_chunked_prefill_engine_hits_cache(setup):
    """The chunked-prefill admission path must route hits too: the warm
    suffix job starts past the cached tokens."""
    cfg, params, _ = setup
    tok = get_tokenizer()
    prompt = _prompt(tok, 50)
    eng = Engine(params, cfg, _ecfg(prefill_chunk_size=16),
                 make_policy("sc"))
    r1 = eng.serve(prompt, 2)
    r2 = eng.serve(prompt, 2)
    assert r2.metrics.cached_tokens == 3 * BS
    assert ([t.output_tokens for t in r2.traces]
            == [t.output_tokens for t in r1.traces])
    assert eng.pool_drained()


def test_eviction_under_memory_pressure(setup):
    """A tight pool: parked blocks from an earlier request are evicted
    LRU-first to admit a new one (evict-before-prune), and the new
    request still completes without pruning or preemption."""
    cfg, params, _ = setup
    tok = get_tokenizer()
    eng = Engine(params, cfg, _ecfg(num_blocks=6, max_new=8),
                 make_policy("sc"))
    ra = eng.serve(_prompt(tok, 40), 1)
    assert all(t.status == TraceStatus.FINISHED for t in ra.traces)
    assert eng.prefix_cache.cached_blocks == 2
    # 5 usable blocks, 2 parked: the next 40-token prompt needs 3 + 1
    rb = eng.serve(_prompt(tok, 40, body="9*8-7+6= "), 1)
    assert all(t.status == TraceStatus.FINISHED for t in rb.traces)
    assert rb.num_preemptions == 0 and rb.num_pruned == 0
    assert eng.prefix_cache.stats.evicted_blocks >= 1
    assert eng.pool_drained()
    eng.block_mgr.check_invariants()


def test_cache_disabled_never_parks(setup):
    cfg, params, _ = setup
    tok = get_tokenizer()
    eng = Engine(params, cfg, _ecfg(prefix_cache=False), make_policy("sc"))
    assert eng.prefix_cache is None
    r1 = eng.serve(_prompt(tok, 40), 2)
    r2 = eng.serve(_prompt(tok, 40), 2)
    assert r2.metrics.cached_tokens == 0
    assert ([t.output_tokens for t in r2.traces]
            == [t.output_tokens for t in r1.traces])
    assert eng.pool_drained()
    assert eng.block_mgr.free_blocks == eng.block_mgr.num_blocks - 1
