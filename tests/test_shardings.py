"""Sharding-rule unit tests (no multi-device backend needed: the rules
are pure functions over shapes and an AbstractMesh)."""
import jax
import jax.numpy as jnp
import numpy as np
import pytest
from jax.sharding import AbstractMesh, PartitionSpec as P

from repro.configs.base import SHAPES, kv_cache_specs
from repro.configs.registry import ASSIGNED_ARCHS, get_config
from repro.launch import shardings as shd
from repro.models.init import init_params


def _abstract_mesh(sizes, names):
    """jax <= 0.4.x takes ((name, size), ...); jax >= 0.5 takes
    (sizes, names). Build whichever the installed jax expects."""
    import inspect
    params = inspect.signature(AbstractMesh.__init__).parameters
    if "shape_tuple" in params:
        return AbstractMesh(tuple(zip(names, sizes)))
    return AbstractMesh(tuple(sizes), tuple(names))


def mesh1():
    return _abstract_mesh((16, 16), ("data", "model"))


def mesh2():
    return _abstract_mesh((2, 16, 16), ("pod", "data", "model"))


def _shapes(arch):
    cfg = get_config(arch)
    return cfg, jax.eval_shape(lambda: init_params(cfg,
                                                   jax.random.PRNGKey(0)))


def _check_divisible(shapes_tree, spec_tree, mesh):
    leaves, _ = jax.tree_util.tree_flatten(shapes_tree)
    specs, _ = jax.tree_util.tree_flatten(
        spec_tree, is_leaf=lambda x: isinstance(x, P))
    for leaf, spec in zip(leaves, specs):
        for dim, entry in enumerate(spec):
            if entry is None:
                continue
            axes = entry if isinstance(entry, tuple) else (entry,)
            n = 1
            for a in axes:
                n *= mesh.shape[a]
            assert leaf.shape[dim] % n == 0, \
                f"{leaf.shape} dim {dim} not divisible by {n} ({spec})"


@pytest.mark.parametrize("arch", ASSIGNED_ARCHS)
def test_param_specs_divisible(arch):
    cfg, shapes = _shapes(arch)
    for mesh in (mesh1(), mesh2()):
        specs = shd.partition_params(cfg, mesh, shapes, fsdp=True)
        _check_divisible(shapes, specs, mesh)


@pytest.mark.parametrize("arch", ["qwen3-1.7b", "deepseek-v2-236b",
                                  "mamba2-2.7b", "granite-20b"])
def test_cache_specs_divisible(arch):
    cfg = get_config(arch)
    for mesh in (mesh1(), mesh2()):
        for shape_name in ("decode_32k", "long_500k"):
            if not cfg.supports_shape(SHAPES[shape_name]):
                continue
            shapes = kv_cache_specs(cfg, shape_name)
            specs = shd.partition_cache(cfg, mesh, shape_name)
            assert set(shapes) == set(specs)
            _check_divisible(shapes, specs, mesh)


def test_expert_parallel_when_divisible():
    """deepseek (160 experts) shards E over model; mixtral (8) cannot."""
    cfg, shapes = _shapes("deepseek-v2-236b")
    specs = shd.partition_params(cfg, mesh1(), shapes, fsdp=True)
    wg = specs["layers"]["moe"]["experts"]["w_gate"]
    assert wg[1] == "model", wg  # [L, E, D, F] -> E over model

    cfg, shapes = _shapes("mixtral-8x7b")
    specs = shd.partition_params(cfg, mesh1(), shapes, fsdp=True)
    wg = specs["layers"]["moe"]["experts"]["w_gate"]
    assert wg[-1] == "model", wg  # tensor parallel on F instead


def test_megatron_pairing_dense():
    """Up-projections column-parallel, down-projections row-parallel."""
    cfg, shapes = _shapes("qwen3-1.7b")
    specs = shd.partition_params(cfg, mesh1(), shapes, fsdp=False)
    lyr = specs["layers"]
    assert lyr["attn"]["wq"][-1] == "model"
    assert lyr["attn"]["wo"][-2] == "model"
    assert lyr["mlp"]["w_gate"][-1] == "model"
    assert lyr["mlp"]["w_down"][-2] == "model"


def test_serving_fsdp_threshold():
    """Small model: no FSDP for serving; deepseek: FSDP forced."""
    cfg, shapes = _shapes("qwen3-1.7b")
    specs = shd.partition_params(cfg, mesh1(), shapes)  # auto
    # some large 2D leaf should have exactly one sharded dim (model only)
    wq = specs["layers"]["attn"]["wq"]
    assert sum(x is not None for x in wq) == 1

    cfg, shapes = _shapes("deepseek-v2-236b")
    specs = shd.partition_params(cfg, mesh1(), shapes)  # auto -> fsdp
    wg = specs["layers"]["moe"]["experts"]["w_gate"]
    assert sum(x is not None for x in wg) >= 2


def test_input_specs_batch_sharding():
    cfg = get_config("qwen3-1.7b")
    specs = shd.partition_inputs(cfg, mesh2(), "train_4k")
    assert specs["tokens"] == P(("pod", "data"), None)
    # long_500k batch=1: replicate
    specs = shd.partition_inputs(cfg, mesh2(), "long_500k")
    assert specs["tokens"] == P(None, None)


def test_kv_partition_specs_fallbacks():
    m = mesh1()
    # KVH=1 cannot shard heads -> SEQUENCE-sharded cache (flash-decoding
    # layout; sharding head_dim would force per-step cache all-gathers,
    # see EXPERIMENTS.md #Perf target 2)
    cfg = get_config("granite-20b")
    sp = shd.kv_partition_specs(cfg, m, batch=128)
    assert sp["kv"] == P(("data",), "model", None, None)
    cfg = get_config("seamless-m4t-large-v2")  # KVH=16 -> heads
    sp = shd.kv_partition_specs(cfg, m, batch=128)
    assert sp["kv"] == P(("data",), None, "model", None)
    cfg = get_config("mamba2-2.7b")     # 80 heads % 16 == 0
    sp = shd.kv_partition_specs(cfg, m, batch=128)
    assert sp["ssm"] == P(("data",), "model", None, None)
    cfg = get_config("deepseek-v2-236b")  # MLA latent -> sequence-sharded
    sp = shd.kv_partition_specs(cfg, m, batch=128)
    assert sp["mla"] == P(("data",), "model", None)
