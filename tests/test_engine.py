"""Serving-engine system tests: scheduling, preemption, pruning, accounting."""
import jax
import pytest

from repro.configs.registry import serving_config
from repro.core.pruning import make_policy
from repro.core.scorer import init_scorer
from repro.core.trace import TraceStatus
from repro.data.tokenizer import get_tokenizer
from repro.models.init import init_params
from repro.serving import Engine, EngineConfig, Request, SamplingParams


@pytest.fixture(scope="module")
def setup():
    cfg = serving_config()
    params = init_params(cfg, jax.random.PRNGKey(0))
    scorer = init_scorer(jax.random.PRNGKey(1), cfg.d_model)
    tok = get_tokenizer()
    prompt = tok.encode("3+5-2=", add_bos=True)
    return cfg, params, scorer, prompt


def _ecfg(num_blocks=40, max_new=48, batch=8):
    return EngineConfig(
        max_batch=batch, num_blocks=num_blocks, capacity=128,
        max_new_tokens=max_new,
        sampling=SamplingParams(max_new_tokens=max_new))


def _run(setup, method, num_blocks=40, n=8, max_new=48, **pkw):
    cfg, params, scorer, prompt = setup
    policy = make_policy(method, **pkw)
    eng = Engine(params, cfg, _ecfg(num_blocks, max_new), policy,
                 scorer_params=scorer if policy.uses_scorer else None)
    res = eng.serve(prompt, n)
    return eng, res


def test_sc_completes_all_traces(setup):
    eng, res = _run(setup, "sc")
    assert all(t.status == TraceStatus.FINISHED for t in res.traces)
    assert res.num_pruned == 0
    # allocator clean: every block returned
    assert eng.pool_drained()
    eng.block_mgr.check_invariants()


def test_sc_preempts_under_memory_pressure(setup):
    """The paper's Fig. 2c bottleneck: tight pool => preemption + waiting."""
    eng, res = _run(setup, "sc", num_blocks=12, max_new=100)
    assert res.num_preemptions > 0
    assert res.wait_s > 0
    # discard-and-recompute: preempted traces prefill more than once
    assert any(t.prefill_count > 1 for t in res.traces)
    # SC never prunes: every trace eventually finishes
    assert all(t.status == TraceStatus.FINISHED for t in res.traces)
    assert eng.pool_drained()


def test_step_never_waits(setup):
    """STEP's claim (Table 3): memory-aware pruning => zero waiting."""
    eng, res = _run(setup, "step", num_blocks=12, max_new=100)
    assert res.wait_s == 0.0
    assert res.num_preemptions == 0
    assert res.num_pruned > 0
    # pruned + finished covers every trace
    assert all(t.status in (TraceStatus.FINISHED, TraceStatus.PRUNED)
               for t in res.traces)
    assert eng.pool_drained()


def test_step_prunes_lowest_scored(setup):
    eng, res = _run(setup, "step", num_blocks=12, max_new=100)
    pruned = [t for t in res.traces if t.status == TraceStatus.PRUNED]
    assert pruned
    # every pruned trace recorded step scores or was at the uninformative
    # prior; the engine must have consulted the scorer
    for t in pruned:
        assert 0.0 <= t.score <= 1.0


def test_step_faster_than_sc_under_pressure(setup):
    _, res_sc = _run(setup, "sc", num_blocks=12, max_new=100)
    _, res_step = _run(setup, "step", num_blocks=12, max_new=100)
    assert res_step.latency_s < res_sc.latency_s
    # STEP does zero recompute; SC's preemptions force re-prefills
    assert res_step.num_preemptions == 0 and res_sc.num_preemptions > 0


def test_deepconf_warmup_then_prune(setup):
    eng, res = _run(setup, "deepconf", warmup=4, keep_pct=0.25)
    # the warmup traces must all finish (no early termination before the
    # threshold exists); later traces may be terminated
    assert all(t.status in (TraceStatus.FINISHED, TraceStatus.PRUNED)
               for t in res.traces)
    assert eng.pool_drained()


def test_cot_single_trace(setup):
    _, res = _run(setup, "cot", n=1)
    assert len(res.traces) == 1
    assert res.wait_s == 0.0


def test_weighted_vote_used_by_step(setup):
    _, res = _run(setup, "step")
    finished = [t for t in res.traces if t.status == TraceStatus.FINISHED]
    answered = [t for t in finished if t.answer is not None]
    if answered:
        assert res.answer in {t.answer for t in answered}


def test_trace_budget_respected(setup):
    _, res = _run(setup, "sc", n=4)
    assert len(res.traces) == 4
    assert res.total_tokens <= 4 * 48


# ---------------------------------------------------------------------------
# prefix sharing (COW) + multi-request scheduling
# ---------------------------------------------------------------------------

def _greedy_ecfg(share, num_blocks=64, max_new=32, batch=8):
    return EngineConfig(
        max_batch=batch, num_blocks=num_blocks, capacity=128,
        max_new_tokens=max_new,
        sampling=SamplingParams(temperature=0.0, top_k=0, top_p=1.0,
                                max_new_tokens=max_new),
        share_prompt_prefix=share)


def test_shared_prefix_matches_per_trace_greedy(setup):
    """The COW fork must be invisible to the model: under greedy sampling
    both prefill modes generate token-identical traces."""
    cfg, params, _, prompt = setup
    outs = []
    for share in (True, False):
        eng = Engine(params, cfg, _greedy_ecfg(share), make_policy("sc"))
        res = eng.serve(prompt, 6)
        assert all(t.status == TraceStatus.FINISHED for t in res.traces)
        outs.append([t.output_tokens for t in res.traces])
        assert eng.pool_drained()
        eng.block_mgr.check_invariants()
    assert outs[0] == outs[1]


def test_shared_prefix_prefills_once(setup):
    """N traces of one request => exactly one prompt prefill (vs N)."""
    cfg, params, _, prompt = setup
    for share, expected in ((True, 1), (False, 6)):
        eng = Engine(params, cfg, _greedy_ecfg(share), make_policy("sc"))
        calls = []
        orig = eng._prefill
        eng._prefill = lambda p, t: (calls.append(t.shape) or orig(p, t))
        eng.serve(prompt, 6)
        assert len(calls) == expected


def test_serve_batch_multi_request(setup):
    """Traces of different requests co-exist in the decode batch; results
    aggregate per request and the pool drains clean."""
    cfg, params, _, prompt = setup
    tok = get_tokenizer()
    eng = Engine(params, cfg, _greedy_ecfg(True, max_new=24),
                 make_policy("sc"))
    reqs = [
        Request(request_id=7, prompt_tokens=prompt, n_traces=4,
                policy=make_policy("sc")),
        Request(request_id=9,
                prompt_tokens=tok.encode("7*2+1=", add_bos=True),
                n_traces=4, policy=make_policy("sc")),
    ]
    results = eng.serve_batch(reqs)
    assert [r.request_id for r in results] == [7, 9]
    for r in results:
        assert len(r.traces) == 4
        assert all(t.request_id == r.request_id for t in r.traces)
        assert all(t.status == TraceStatus.FINISHED for t in r.traces)
    assert eng.pool_drained()
    eng.block_mgr.check_invariants()


def test_serve_batch_queues_beyond_max_batch(setup):
    """More total traces than decode slots: surplus waits for a slot and
    still completes (slot waiting is not memory WAIT)."""
    cfg, params, _, prompt = setup
    eng = Engine(params, cfg, _greedy_ecfg(True, max_new=16, batch=4),
                 make_policy("sc"))
    reqs = [Request(request_id=i, prompt_tokens=prompt, n_traces=3,
                    policy=make_policy("sc")) for i in range(3)]
    results = eng.serve_batch(reqs)
    for r in results:
        assert all(t.status == TraceStatus.FINISHED for t in r.traces)
    assert eng.pool_drained()


def test_prefix_cache_on_off_identity(setup):
    """The cross-request prefix cache must be invisible to generation:
    tokens, step scores and prune decisions are identical with the cache
    on vs off under fixed RNG (a hit serves bit-identical KV and the
    engine evicts parked blocks before any pruning decision)."""
    from repro.models import kv_quant
    if kv_quant.is_quantized(EngineConfig().kv_dtype):
        # Exact on/off identity is a float-pool contract: a cache HIT
        # recomputes the suffix reading the quantized prefix KV from the
        # pool, while a MISS one-shot-prefills the whole prompt with
        # exact hidden states — inherently divergent under a lossy
        # dtype. tests/test_kv_quant.py covers prefix-cache correctness
        # (hits occur, drains, bounded drift) for int8/fp8 pools.
        pytest.skip("prefix-cache on/off identity pinned for float "
                    "pools only (lossy kv_dtype hits re-read quantized "
                    "prefix KV)")
    cfg, params, scorer, _ = setup
    tok = get_tokenizer()
    prompt = tok.encode("1+2-3+4-5+6-7+8=" * 2, add_bos=True)  # 33 toks
    runs = []
    for on in (True, False):
        ecfg = EngineConfig(
            max_batch=8, num_blocks=24, capacity=128, max_new_tokens=64,
            sampling=SamplingParams(temperature=0.0, top_k=0, top_p=1.0,
                                    max_new_tokens=64),
            share_prompt_prefix=True, prefix_cache=on)
        eng = Engine(params, cfg, ecfg, make_policy("step"),
                     scorer_params=scorer)
        rounds = []
        for _ in range(2):  # round 2 replays into a warm cache
            res = eng.serve(prompt, 6)
            rounds.append([(t.output_tokens, t.step_scores, t.status)
                           for t in res.traces])
        runs.append(rounds)
        assert eng.pool_drained()
        eng.block_mgr.check_invariants()
    assert runs[0] == runs[1]


def test_serve_batch_step_cross_request_contention(setup):
    """Two STEP requests contending for one tight pool: each request's
    policy prunes its own traces, no request ever waits."""
    cfg, params, scorer, prompt = setup
    ecfg = EngineConfig(max_batch=8, num_blocks=12, capacity=128,
                        max_new_tokens=100,
                        sampling=SamplingParams(max_new_tokens=100))
    eng = Engine(params, cfg, ecfg, make_policy("step"),
                 scorer_params=scorer)
    reqs = [Request(request_id=i, prompt_tokens=prompt, n_traces=4,
                    policy=make_policy("step")) for i in range(2)]
    results = eng.serve_batch(reqs)
    assert sum(r.num_pruned for r in results) > 0
    for r in results:
        assert r.wait_s == 0.0
        assert r.num_preemptions == 0
        assert all(t.status in (TraceStatus.FINISHED, TraceStatus.PRUNED)
                   for t in r.traces)
    assert eng.pool_drained()
    eng.block_mgr.check_invariants()
