"""Serving-engine system tests: scheduling, preemption, pruning, accounting."""
import jax
import pytest

from repro.configs.registry import serving_config
from repro.core.pruning import make_policy
from repro.core.scorer import init_scorer
from repro.core.trace import TraceStatus
from repro.data.tokenizer import get_tokenizer
from repro.models.init import init_params
from repro.serving import Engine, EngineConfig, SamplingParams


@pytest.fixture(scope="module")
def setup():
    cfg = serving_config()
    params = init_params(cfg, jax.random.PRNGKey(0))
    scorer = init_scorer(jax.random.PRNGKey(1), cfg.d_model)
    tok = get_tokenizer()
    prompt = tok.encode("3+5-2=", add_bos=True)
    return cfg, params, scorer, prompt


def _ecfg(num_blocks=40, max_new=48, batch=8):
    return EngineConfig(
        max_batch=batch, num_blocks=num_blocks, capacity=128,
        max_new_tokens=max_new,
        sampling=SamplingParams(max_new_tokens=max_new))


def _run(setup, method, num_blocks=40, n=8, max_new=48, **pkw):
    cfg, params, scorer, prompt = setup
    policy = make_policy(method, **pkw)
    eng = Engine(params, cfg, _ecfg(num_blocks, max_new), policy,
                 scorer_params=scorer if policy.uses_scorer else None)
    res = eng.serve(prompt, n)
    return eng, res


def test_sc_completes_all_traces(setup):
    eng, res = _run(setup, "sc")
    assert all(t.status == TraceStatus.FINISHED for t in res.traces)
    assert res.num_pruned == 0
    # allocator clean: every block returned
    assert eng.block_mgr.free_blocks == eng.block_mgr.num_blocks - 1
    eng.block_mgr.check_invariants()


def test_sc_preempts_under_memory_pressure(setup):
    """The paper's Fig. 2c bottleneck: tight pool => preemption + waiting."""
    eng, res = _run(setup, "sc", num_blocks=12, max_new=100)
    assert res.num_preemptions > 0
    assert res.wait_s > 0
    # discard-and-recompute: preempted traces prefill more than once
    assert any(t.prefill_count > 1 for t in res.traces)
    # SC never prunes: every trace eventually finishes
    assert all(t.status == TraceStatus.FINISHED for t in res.traces)
    assert eng.block_mgr.free_blocks == eng.block_mgr.num_blocks - 1


def test_step_never_waits(setup):
    """STEP's claim (Table 3): memory-aware pruning => zero waiting."""
    eng, res = _run(setup, "step", num_blocks=12, max_new=100)
    assert res.wait_s == 0.0
    assert res.num_preemptions == 0
    assert res.num_pruned > 0
    # pruned + finished covers every trace
    assert all(t.status in (TraceStatus.FINISHED, TraceStatus.PRUNED)
               for t in res.traces)
    assert eng.block_mgr.free_blocks == eng.block_mgr.num_blocks - 1


def test_step_prunes_lowest_scored(setup):
    eng, res = _run(setup, "step", num_blocks=12, max_new=100)
    pruned = [t for t in res.traces if t.status == TraceStatus.PRUNED]
    assert pruned
    # every pruned trace recorded step scores or was at the uninformative
    # prior; the engine must have consulted the scorer
    for t in pruned:
        assert 0.0 <= t.score <= 1.0


def test_step_faster_than_sc_under_pressure(setup):
    _, res_sc = _run(setup, "sc", num_blocks=12, max_new=100)
    _, res_step = _run(setup, "step", num_blocks=12, max_new=100)
    assert res_step.latency_s < res_sc.latency_s
    # STEP does zero recompute; SC's preemptions force re-prefills
    assert res_step.num_preemptions == 0 and res_sc.num_preemptions > 0


def test_deepconf_warmup_then_prune(setup):
    eng, res = _run(setup, "deepconf", warmup=4, keep_pct=0.25)
    # the warmup traces must all finish (no early termination before the
    # threshold exists); later traces may be terminated
    assert all(t.status in (TraceStatus.FINISHED, TraceStatus.PRUNED)
               for t in res.traces)
    assert eng.block_mgr.free_blocks == eng.block_mgr.num_blocks - 1


def test_cot_single_trace(setup):
    _, res = _run(setup, "cot", n=1)
    assert len(res.traces) == 1
    assert res.wait_s == 0.0


def test_weighted_vote_used_by_step(setup):
    _, res = _run(setup, "step")
    finished = [t for t in res.traces if t.status == TraceStatus.FINISHED]
    answered = [t for t in finished if t.answer is not None]
    if answered:
        assert res.answer in {t.answer for t in answered}


def test_trace_budget_respected(setup):
    _, res = _run(setup, "sc", n=4)
    assert len(res.traces) == 4
    assert res.total_tokens <= 4 * 48
