"""Fused multi-token decode horizon (`EngineConfig.decode_horizon`).

Pins the two equivalences the tentpole rests on:

  * model level — `multi_decode_step` (one jitted lax.scan over K
    iterations) emits exactly what K successive `decode_step` +
    `sample_logits` calls emit for the same key stream, including
    per-lane limits, EOS deactivation and step boundaries sitting at
    horizon edges;
  * engine level — `decode_horizon=K` generates token-identical traces
    (and step-score-identical, to float tolerance) to `decode_horizon=1`
    under a fixed RNG, for greedy and temperature sampling, with traces
    hitting EOS mid-horizon.

Plus the scheduling semantics around the horizon: the pressure-triggered
fallback to single-token ticks, STEP pruning in tight pools, chunked
prefill interleaving, and the per-tick decode-burst policy hook.
"""
import dataclasses

import jax
import jax.numpy as jnp
import numpy as np
import pytest
from hypothesis import given, settings
from hypothesis import strategies as st

from repro.configs.registry import serving_config
from repro.core.pruning import make_policy
from repro.core.scorer import init_scorer, scorer_score
from repro.core.trace import TraceStatus
from repro.data.tokenizer import get_tokenizer
from repro.models.init import init_params
from repro.models.model import (decode_step, init_decode_cache,
                                multi_decode_step)
from repro.serving import Engine, EngineConfig, Request, SamplingParams
from repro.serving.sampling import sample_logits

MAX_NEW = 32
BATCH = 8
HORIZONS = (2, 4, 8)


# module-level caches instead of fixtures: the property test below runs
# under @given, which cannot receive pytest fixtures (neither with real
# hypothesis nor with the tests/_hypothesis_stub fallback)
_STATE: dict = {}


def _setup():
    if "cfg" not in _STATE:
        cfg = serving_config()
        _STATE["cfg"] = cfg
        _STATE["params"] = init_params(cfg, jax.random.PRNGKey(0))
        _STATE["scorer"] = init_scorer(jax.random.PRNGKey(1), cfg.d_model)
        tok = get_tokenizer()
        _STATE["tok"] = tok
        _STATE["prompts"] = [tok.encode(p, add_bos=True)
                             for p in ("3+5-2=", "7*2+1=", "9-4+6=")]
    return (_STATE["cfg"], _STATE["params"], _STATE["scorer"],
            _STATE["tok"], _STATE["prompts"])


@pytest.fixture(scope="module")
def setup():
    return _setup()


def _ecfg(K, temperature=0.8, num_blocks=64, max_new=MAX_NEW, batch=BATCH):
    return EngineConfig(
        max_batch=batch, num_blocks=num_blocks, capacity=128,
        max_new_tokens=max_new,
        sampling=SamplingParams(temperature=temperature,
                                top_k=0 if temperature == 0.0 else 20,
                                top_p=1.0 if temperature == 0.0 else 0.95,
                                max_new_tokens=max_new),
        decode_horizon=K)


def _engines():
    """One engine per (horizon, sampling mode), compiled once and reused
    across property examples (the per-example reset is the RNG key)."""
    if "engines" not in _STATE:
        cfg, params, scorer, _, _ = _setup()
        out = {}
        for temp in (0.0, 0.8):
            for K in (1,) + HORIZONS:
                eng = Engine(params, cfg, _ecfg(K, temperature=temp),
                             make_policy("step"), scorer_params=scorer)
                out[(K, temp)] = eng
        _STATE["engines"] = out
    return _STATE["engines"]


def _serve(eng, prompt, n_traces, rng_seed):
    eng._rng = jax.random.PRNGKey(rng_seed)  # align key streams
    res = eng.serve_batch([Request(request_id=0, prompt_tokens=prompt,
                                   n_traces=n_traces,
                                   policy=make_policy("step"))])[0]
    assert eng.pool_drained()
    eng.block_mgr.check_invariants()
    return res


@settings(max_examples=6, deadline=None)
@given(st.sampled_from(HORIZONS), st.integers(0, 2), st.integers(2, 6),
       st.booleans(), st.integers(0, 10**6))
def test_horizon_token_identical_to_single_step(K, prompt_idx, n_traces,
                                                greedy, rng_seed):
    """decode_horizon=K must generate exactly what decode_horizon=1
    generates under a fixed RNG: same tokens, same step scores (traces
    hit EOS mid-horizon under temperature sampling; greedy runs to the
    token cap, placing step boundaries anywhere incl. horizon edges)."""
    engines = _engines()
    _, _, _, _, prompts = _setup()
    temp = 0.0 if greedy else 0.8
    prompt = prompts[prompt_idx]
    ref = _serve(engines[(1, temp)], prompt, n_traces, rng_seed)
    got = _serve(engines[(K, temp)], prompt, n_traces, rng_seed)
    assert [t.output_tokens for t in got.traces] \
        == [t.output_tokens for t in ref.traces]
    for a, b in zip(ref.traces, got.traces):
        assert a.status == b.status
        assert len(a.step_scores) == len(b.step_scores)
        assert np.allclose(a.step_scores, b.step_scores,
                           rtol=1e-4, atol=1e-5)
        assert np.allclose(a.token_confidences, b.token_confidences,
                           rtol=1e-4, atol=1e-5)


def test_eos_mid_horizon(setup):
    """Temperature sampling on the random-init model ends traces at
    scattered lengths — EOS landing inside a fused horizon — and the
    K=8 run must still match K=1 exactly."""
    _, _, _, _, prompts = setup
    engines = _engines()
    ref = _serve(engines[(1, 0.8)], prompts[0], 6, rng_seed=7)
    got = _serve(engines[(8, 0.8)], prompts[0], 6, rng_seed=7)
    lens = [t.num_tokens for t in ref.traces]
    assert min(lens) < MAX_NEW, lens  # at least one early EOS
    assert len(set(lens)) > 1, lens
    assert [t.output_tokens for t in got.traces] \
        == [t.output_tokens for t in ref.traces]


def test_multi_decode_step_matches_decode_step_loop(setup):
    """Model-level pin: the fused scan == a Python loop of single
    decode_step + sample_logits calls over the same key stream, with
    per-lane limits and step boundaries at the horizon edge (lane input
    tokens chosen == step_id at iteration 0)."""
    cfg, params, scorer, tok, _ = setup
    B, K, capacity = 4, 3, 64
    bs = cfg.kv_block_size
    bp = -(-capacity // bs)
    cache = init_decode_cache(cfg, B, capacity, num_blocks=1 + B * bp)
    bt = np.arange(1, 1 + B * bp, dtype=np.int32).reshape(B, bp)
    cache["block_tables"] = jnp.asarray(bt)
    # iteration-0 inputs: two lanes sit exactly on a step boundary
    tokens = jnp.asarray([tok.step_id, 7, tok.step_id, 9], jnp.int32)
    positions = jnp.zeros((B,), jnp.int32)
    limits = jnp.asarray([3, 3, 2, 1], jnp.int32)
    keys, rng = [], jax.random.PRNGKey(42)
    for _ in range(K):
        rng, k = jax.random.split(rng)
        keys.append(k)

    def sample_fn(key, logits):
        logits = logits.at[:, cfg.vocab_size:].set(-jnp.inf)
        return sample_logits(key, logits, temperature=0.8, top_k=20,
                             top_p=0.95)

    out = multi_decode_step(
        params, cfg, tokens, positions, limits, dict(cache),
        window_len=capacity, horizon=K, rng_keys=jnp.stack(keys),
        sample_fn=sample_fn, eos_id=tok.eos_id, step_id=tok.step_id,
        score_fn=lambda h: scorer_score(scorer, h))

    # reference: K sequential single-token decode steps (the old engine
    # inner loop), tracking per-lane active state on the host
    ref_cache = dict(cache)
    ct = np.asarray(tokens).copy()
    pos = np.zeros((B,), np.int32)
    active = np.asarray(limits) > 0
    ref_toks = np.zeros((B, K), np.int32)
    ref_valid = np.zeros((B, K), bool)
    ref_svalid = np.zeros((B, K), bool)
    ref_scores = np.zeros((B, K), np.float32)
    for k in range(K):
        step = decode_step(params, cfg, jnp.asarray(ct[:, None]),
                           jnp.asarray(pos), ref_cache, window_len=capacity)
        ref_cache = step["cache"]
        nt, _ = sample_fn(keys[k], step["logits"])
        nt = np.asarray(nt)
        sc = np.asarray(scorer_score(scorer, step["hidden"]))
        for i in range(B):
            if not active[i]:
                continue
            ref_valid[i, k] = True
            ref_svalid[i, k] = ct[i] == tok.step_id
            ref_scores[i, k] = sc[i]
            ref_toks[i, k] = nt[i]
            pos[i] += 1
            ct[i] = nt[i]
            if nt[i] == tok.eos_id or k + 1 >= int(limits[i]):
                active[i] = False

    got_valid = np.asarray(out["token_valid"])
    assert (got_valid == ref_valid).all()
    assert (np.asarray(out["score_valid"]) == ref_svalid).all()
    assert (np.asarray(out["tokens"])[ref_valid]
            == ref_toks[ref_valid]).all()
    assert np.allclose(np.asarray(out["scores"])[ref_svalid],
                       ref_scores[ref_svalid], rtol=1e-4, atol=1e-5)
    assert (np.asarray(out["positions"]) == pos).all()
    assert (np.asarray(out["final_tokens"]) == ct).all()
    # step boundaries at the horizon edge were actually exercised
    assert ref_svalid[0, 0] and ref_svalid[2, 0]


def test_horizon_pressure_fallback(setup):
    """Waiting traces + a short free list force single-token ticks so
    frontier pre-allocation never starves waiting admissions."""
    cfg, params, scorer, _, prompts = setup
    policy = make_policy("step")
    eng = Engine(params, cfg,
                 _ecfg(8, temperature=0.0, num_blocks=12, max_new=48,
                       batch=4),
                 policy, scorer_params=scorer)
    res = eng.serve_batch([Request(request_id=0, prompt_tokens=prompts[0],
                                   n_traces=8, policy=policy)])[0]
    assert eng.horizon_fallbacks > 0
    assert res.wait_s == 0.0 and res.num_preemptions == 0
    assert eng.pool_drained()
    eng.block_mgr.check_invariants()


def test_step_prunes_in_tight_pool_with_horizon(setup):
    """Memory-triggered STEP pruning still fires with a fused horizon
    (greedy runs to the cap, so the pool must fill)."""
    cfg, params, scorer, _, prompts = setup
    eng = Engine(params, cfg,
                 _ecfg(8, temperature=0.0, num_blocks=12, max_new=100),
                 make_policy("step"), scorer_params=scorer)
    res = eng.serve(prompts[0], 8)
    assert res.num_pruned > 0
    assert res.wait_s == 0.0 and res.num_preemptions == 0
    assert all(t.status in (TraceStatus.FINISHED, TraceStatus.PRUNED)
               for t in res.traces)
    assert eng.pool_drained()
    eng.block_mgr.check_invariants()


def test_sc_preemption_in_tight_pool_with_horizon(setup):
    """Baseline preemption (discard-and-recompute) composes with the
    horizon: every trace still finishes and the pool drains clean."""
    cfg, params, _, _, prompts = setup
    eng = Engine(params, cfg,
                 _ecfg(4, temperature=0.0, num_blocks=12, max_new=64),
                 make_policy("sc"))
    res = eng.serve(prompts[0], 8)
    assert res.num_preemptions > 0
    assert all(t.status == TraceStatus.FINISHED for t in res.traces)
    assert eng.pool_drained()
    eng.block_mgr.check_invariants()


def test_horizon_with_chunked_prefill_multi_request(setup):
    """Chunked prefill + online arrival + horizon>1 interleave; outputs
    match the horizon=1 run of the identical scenario."""
    cfg, params, _, _, prompts = setup
    outs = []
    for K in (1, 4):
        ecfg = dataclasses.replace(_ecfg(K, temperature=0.0, max_new=16),
                                   prefill_chunk_size=4)
        eng = Engine(params, cfg, ecfg, make_policy("sc"))
        reqs = [Request(request_id=i, prompt_tokens=p, n_traces=2,
                        policy=make_policy("sc"))
                for i, p in enumerate(prompts)]
        results = eng.serve_batch(reqs)
        for r in results:
            assert all(t.status == TraceStatus.FINISHED for t in r.traces)
        outs.append({r.request_id: [t.output_tokens for t in r.traces]
                     for r in results})
        assert eng.pool_drained()
        eng.block_mgr.check_invariants()
    assert outs[0] == outs[1]


def test_horizon_respects_token_budget(setup):
    """max_tokens_per_step charges a full horizon per running/admitted
    trace (pessimistic), so a tick can never exceed the budget; every
    trace still completes under a tight budget."""
    cfg, params, _, _, prompts = setup
    ecfg = dataclasses.replace(
        _ecfg(4, temperature=0.0, max_new=16),
        prefill_chunk_size=4, max_tokens_per_step=8)
    eng = Engine(params, cfg, ecfg, make_policy("sc"))
    reqs = [Request(request_id=i, prompt_tokens=p, n_traces=2,
                    policy=make_policy("sc"))
            for i, p in enumerate(prompts)]
    results = eng.serve_batch(reqs)
    for r in results:
        assert all(t.status == TraceStatus.FINISHED for t in r.traces)
    assert eng.pool_drained()
    eng.block_mgr.check_invariants()


def test_policy_observes_decode_bursts(setup):
    """The engine hands each trace's per-tick burst (tokens, confs, step
    scores) to the policy in one call, never longer than the horizon."""
    cfg, params, _, _, prompts = setup
    bursts = []

    class Spy(type(make_policy("sc"))):
        def observe_decode_burst(self, trace, tokens, confidences,
                                 step_scores):
            bursts.append((trace.trace_id, list(tokens),
                           list(confidences)))

    policy = Spy()
    eng = Engine(params, cfg, _ecfg(4, temperature=0.0, max_new=16),
                 policy)
    res = eng.serve_batch([Request(request_id=0,
                                   prompt_tokens=prompts[0],
                                   n_traces=2, policy=policy)])[0]
    assert bursts
    assert all(1 <= len(toks) <= 4 for _, toks, _ in bursts)
    assert all(len(toks) == len(confs) for _, toks, confs in bursts)
    for t in res.traces:
        got = [tk for tid, toks, _ in bursts if tid == t.trace_id
               for tk in toks]
        # bursts reconstruct the decoded suffix (first token comes from
        # the prefill-logit sampling, not from a decode burst)
        assert got == t.output_tokens[1:]


def test_decode_horizon_default_is_one():
    assert EngineConfig().decode_horizon == 1
