"""Property-based tests on the synthetic task + tokenizer + segmentation
invariants the STEP pipeline depends on."""
import random

from hypothesis import given, settings
from hypothesis import strategies as st

from repro.core.segmentation import split_steps
from repro.data.arithmetic import (MOD, Problem, gen_problem, render_trace,
                                   verify)
from repro.data.tokenizer import get_tokenizer


@st.composite
def problems(draw):
    k = draw(st.integers(1, 8))
    return Problem(
        operands=[draw(st.integers(0, 9)) for _ in range(k + 1)],
        ops=[draw(st.sampled_from("+-*")) for _ in range(k)])


@given(problems())
def test_gold_trace_verifies(p):
    text, ok = render_trace(p)
    assert ok
    ans, correct = verify(p, text)
    assert correct and ans == str(p.answer)


@given(problems(), st.integers(0, 7), st.integers(0, 10**6))
def test_corrupt_flag_agrees_with_verifier(p, cfrom, seed):
    """The corruption may cancel downstream (e.g. *0 after the error), so
    the invariant is CONSISTENCY: render's own correctness flag must agree
    with the rule-based verifier on the rendered text."""
    cfrom = min(cfrom, len(p.ops) - 1)
    text, ok = render_trace(p, corrupt_from=cfrom, rng=random.Random(seed))
    ans, correct = verify(p, text)
    assert correct == ok
    assert ans is not None


@given(problems())
def test_steps_equal_ops(p):
    text, _ = render_trace(p)
    assert len(split_steps(text)) == len(p.ops)


@given(problems())
def test_tokenizer_roundtrip(p):
    tok = get_tokenizer()
    text, _ = render_trace(p)
    ids = tok.encode(text)
    assert tok.decode(ids) == text


@given(problems())
def test_answer_in_range(p):
    assert 0 <= p.answer < MOD


@given(st.integers(0, 10**6))
def test_gen_problem_deterministic(seed):
    a = gen_problem(random.Random(seed))
    b = gen_problem(random.Random(seed))
    assert a.operands == b.operands and a.ops == b.ops


@given(problems(), problems())
@settings(max_examples=30)
def test_boundary_token_count_matches_steps(p, q):
    """#("\\n\\n" tokens) inside <think> == #steps — the engine's scorer
    fires exactly once per reasoning step."""
    tok = get_tokenizer()
    text, _ = render_trace(p)
    ids = tok.encode(text)
    stop = ids.index(tok.think_close_id)
    n_boundaries = sum(1 for t in ids[:stop] if t == tok.step_id)
    assert n_boundaries == len(split_steps(text))
