"""Continuous-batching scheduler tests: offline equivalence, arrival
orderings, chunked prefill, serving metrics, streaming callbacks."""
import jax
import pytest

from repro.configs.registry import serving_config
from repro.core.pruning import AdmissionPressure, make_policy
from repro.core.trace import TraceStatus
from repro.data.tokenizer import get_tokenizer
from repro.models.init import init_params
from repro.serving import Engine, EngineConfig, Request, SamplingParams


@pytest.fixture(scope="module")
def setup():
    cfg = serving_config()
    params = init_params(cfg, jax.random.PRNGKey(0))
    tok = get_tokenizer()
    prompts = [tok.encode("3+5-2=", add_bos=True),
               tok.encode("7*2+1=", add_bos=True),
               tok.encode("9-4+6=", add_bos=True)]
    return cfg, params, prompts


def _ecfg(num_blocks=64, max_new=16, batch=8, chunk=None, budget=None):
    return EngineConfig(
        max_batch=batch, num_blocks=num_blocks, capacity=128,
        max_new_tokens=max_new,
        sampling=SamplingParams(temperature=0.0, top_k=0, top_p=1.0,
                                max_new_tokens=max_new),
        prefill_chunk_size=chunk, max_tokens_per_step=budget)


def _reqs(prompts, n=2, arrivals=None, method="sc"):
    arrivals = arrivals or [0.0] * len(prompts)
    return [Request(request_id=i, prompt_tokens=p, n_traces=n,
                    policy=make_policy(method), arrival_time=a)
            for i, (p, a) in enumerate(zip(prompts, arrivals))]


def _token_sets(results):
    return {r.request_id: [t.output_tokens for t in r.traces]
            for r in results}


def test_t0_batch_matches_serial_serve_greedy(setup):
    """All arrivals at t=0, chunking off: the continuous scheduler must
    generate exactly what serving each request alone generates (greedy,
    roomy pool) — the offline-equivalence acceptance criterion."""
    cfg, params, prompts = setup
    eng = Engine(params, cfg, _ecfg(), make_policy("sc"))
    batched = _token_sets(eng.serve_batch(_reqs(prompts)))
    assert eng.pool_drained()
    for i, p in enumerate(prompts):
        eng1 = Engine(params, cfg, _ecfg(), make_policy("sc"))
        solo = eng1.serve(p, 2, request_id=i)
        assert [t.output_tokens for t in solo.traces] == batched[i]


def test_arrival_order_invariance(setup):
    """Order-insensitive policy (sc) + greedy + roomy pool: shuffling the
    submission order of simultaneous arrivals must not change any
    request's generated tokens."""
    cfg, params, prompts = setup
    outs = []
    for order in ([0, 1, 2], [2, 0, 1], [1, 2, 0]):
        eng = Engine(params, cfg, _ecfg(), make_policy("sc"))
        reqs = _reqs(prompts)
        results = eng.serve_batch([reqs[i] for i in order])
        outs.append(_token_sets(results))
        assert eng.pool_drained()
        eng.block_mgr.check_invariants()
    assert outs[0] == outs[1] == outs[2]


def test_chunked_prefill_matches_unchunked(setup):
    """Chunked prefill equivalence: greedy outputs are identical whether
    the prompt prefills in one shot or in 4-token chunks."""
    cfg, params, prompts = setup
    outs = []
    for chunk in (None, 4):
        eng = Engine(params, cfg, _ecfg(chunk=chunk), make_policy("sc"))
        results = eng.serve_batch(_reqs(prompts))
        outs.append(_token_sets(results))
        assert eng.pool_drained()
        eng.block_mgr.check_invariants()
    assert outs[0] == outs[1]


def test_chunked_prefill_token_budget(setup):
    """A tight per-tick token budget throttles admission but every trace
    still completes with correct accounting."""
    cfg, params, prompts = setup
    eng = Engine(params, cfg, _ecfg(chunk=4, budget=8), make_policy("sc"))
    results = eng.serve_batch(_reqs(prompts))
    for r in results:
        assert all(t.status == TraceStatus.FINISHED for t in r.traces)
        assert r.metrics is not None and r.metrics.ttft_s >= 0
    assert eng.pool_drained()


def test_late_arrival_and_completion_stream(setup):
    """A request arriving later must not see tokens before its arrival
    time; completion callbacks stream in completion order."""
    cfg, params, prompts = setup
    eng = Engine(params, cfg, _ecfg(max_new=24), make_policy("sc"))
    done = []
    reqs = _reqs(prompts[:2], arrivals=[0.0, 0.3])
    results = eng.serve_batch(reqs, on_complete=lambda r: done.append(r))
    assert [r.request_id for r in done] == [0, 1]
    m0, m1 = results[0].metrics, results[1].metrics
    assert m0.arrival_s == 0.0 and m1.arrival_s == 0.3
    assert m1.first_token_s >= 0.3
    assert m1.ttft_s >= 0.0
    for r in results:
        assert all(t.status == TraceStatus.FINISHED for t in r.traces)
    # streamed objects are the same results returned at the end
    assert {id(r) for r in done} == {id(r) for r in results}


def test_metrics_under_forced_preemption(setup):
    """TTFT/TPOT accounting stays consistent when a tight pool forces
    preemption (discard-and-recompute) on an sc baseline."""
    cfg, params, prompts = setup
    eng = Engine(params, cfg, _ecfg(num_blocks=12, max_new=100),
                 make_policy("sc"))
    res = eng.serve(prompts[0], 8)
    m = res.metrics
    assert res.num_preemptions > 0 and res.wait_s > 0
    assert m.num_preemptions == res.num_preemptions
    assert m.wait_s == pytest.approx(res.wait_s)
    assert m.first_token_s is not None and m.finished_s is not None
    assert m.arrival_s <= m.first_token_s <= m.finished_s
    assert m.ttft_s >= 0 and m.tpot_s >= 0
    assert m.e2e_s == pytest.approx(res.latency_s, rel=1e-6)
    assert m.output_tokens == res.total_tokens
    assert eng.pool_drained()


def test_policies_observe_admission_pressure(setup):
    """The scheduler publishes an AdmissionPressure snapshot to each
    active request's policy every tick."""
    cfg, params, prompts = setup
    seen = []

    class Spy(type(make_policy("sc"))):
        def observe_pressure(self, pressure):
            super().observe_pressure(pressure)
            seen.append(pressure)

    eng = Engine(params, cfg, _ecfg(), make_policy("sc"))
    reqs = [Request(request_id=0, prompt_tokens=prompts[0], n_traces=2,
                    policy=Spy())]
    eng.serve_batch(reqs)
    assert seen
    assert all(isinstance(p, AdmissionPressure) for p in seen)
    assert all(0.0 <= p.memory_utilization <= 1.0 for p in seen)


def test_step_proactive_pruning_under_pressure(setup):
    """StepPolicy(proactive_free_blocks>0) prunes ahead of OOM when
    traces are waiting and the free pool is low."""
    cfg, params, prompts = setup
    scorer = None
    from repro.core.scorer import init_scorer
    scorer = init_scorer(jax.random.PRNGKey(1), cfg.d_model)
    policy = make_policy("step", proactive_free_blocks=10**6)  # always low
    eng = Engine(params, cfg,
                 EngineConfig(max_batch=2, num_blocks=64, capacity=128,
                              max_new_tokens=64,
                              sampling=SamplingParams(max_new_tokens=64)),
                 policy, scorer_params=scorer)
    # max_batch=2 < n_traces keeps traces waiting => demand > 0
    res = eng.serve_batch([Request(request_id=0,
                                   prompt_tokens=prompts[0],
                                   n_traces=6, policy=policy)])[0]
    assert res.num_pruned > 0
    assert eng.pool_drained()


def test_request_queue_ordering():
    from repro.serving import RequestQueue
    reqs = [Request(request_id=i, prompt_tokens=[1], n_traces=1,
                    arrival_time=a)
            for i, a in enumerate([0.5, 0.0, 0.0, 1.5])]
    q = RequestQueue(reqs)
    assert len(q) == 4
    assert q.next_arrival() == 0.0
    first = q.pop_arrived(0.0)
    assert [r.request_id for r in first] == [1, 2]  # submission order kept
    assert q.next_arrival() == 0.5
    assert [r.request_id for r in q.pop_arrived(0.4)] == []
    assert [r.request_id for r in q.pop_arrived(2.0)] == [0, 3]
    assert not q
    q.push(reqs[0])
    assert len(q) == 1 and q.next_arrival() == 0.5
