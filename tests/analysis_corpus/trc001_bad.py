"""Seeded violation: Python `if` on a traced value (TRC001)."""
import jax
import jax.numpy as jnp


@jax.jit
def f(x):
    if jnp.sum(x) > 0:                   # line 8: traced predicate
        return x
    return -x
