"""Near miss: small resolvable scratch fits the budget; a scratch with
a non-literal dim is skipped (under-report, never guess). Must produce
no findings."""
import jax  # noqa: F401
import jax.numpy as jnp
from jax.experimental import pallas as pl
from jax.experimental.pallas import tpu as pltpu

BLK = 128


def kernel(x_ref, o_ref, acc_ref, big_ref):
    o_ref[...] = x_ref[...]


def run(x, dyn):
    return pl.pallas_call(
        kernel,
        grid=(4,),
        scratch_shapes=[pltpu.VMEM((BLK, BLK), jnp.float32),
                        pltpu.VMEM((dyn, BLK), jnp.float32)],
        out_shape=None,
    )(x)
