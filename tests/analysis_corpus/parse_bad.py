"""Seeded violation: file does not parse (PARSE)."""
def broken(:
    pass
