"""Seeded violation: host escapes inside a traced body (TRC002 x3)."""
import jax
import numpy as np


@jax.jit
def f(x):
    v = x.max().item()                   # line 8: .item() sync
    y = np.tanh(v)                       # line 9: host numpy
    z = float(x[0])                      # line 10: cast on traced value
    return y + z
