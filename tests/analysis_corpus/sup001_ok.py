"""Near miss: the suppression is earned — it silences a real RNG002,
so neither that finding nor SUP001 fires. Must produce no findings."""
import jax


def sample(key):
    x = jax.random.normal(key, (4,))
    y = jax.random.uniform(key, (4,))    # repolint: disable=RNG002
    return x, y
