"""Near miss: a static Python bool predicate inside a jitted body is
how compiled variants specialize — not a tracing hazard. Must produce
no findings."""
import jax
import jax.numpy as jnp


@jax.jit
def f(x, flip=False):
    if flip:
        x = -x
    return jnp.where(x > 0, x, 0.0)
