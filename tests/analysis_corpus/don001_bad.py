"""Seeded violation: donated buffer read after the call (DON001)."""
from functools import partial

import jax


@partial(jax.jit, donate_argnums=(1,))
def step(params, cache):
    return cache


def drive(params, cache):
    new_cache = step(params, cache)
    return cache, new_cache              # line 14: `cache` is dead here
