"""Seeded violation: a suppression comment that silences nothing
(SUP001)."""
import jax


def sample(key):
    x = jax.random.normal(key, (4,))     # repolint: disable=RNG002
    return x
