"""Seeded violation: Python loop bound reads a kernel ref (PLK002)."""
import jax  # noqa: F401
from jax.experimental import pallas as pl


def kernel(lens_ref, x_ref, o_ref):
    for i in range(lens_ref[0]):         # line 7: traced loop bound
        o_ref[i] = x_ref[i]


def run(x, lens):
    return pl.pallas_call(kernel, grid=(1,), out_shape=None)(lens, x)
