"""Near miss: static-range Python loops and lax.fori_loop over the
traced bound are both fine. Must produce no findings."""
import jax
from jax.experimental import pallas as pl


def kernel(lens_ref, x_ref, o_ref):
    for i in range(4):
        o_ref[i] = x_ref[i]

    def body(i, acc):
        return acc + x_ref[i]

    o_ref[0] = jax.lax.fori_loop(0, lens_ref[0], body, 0.0)


def run(x, lens):
    return pl.pallas_call(kernel, grid=(1,), out_shape=None)(lens, x)
