"""Seeded violation: samplers consuming raw PRNGKeys (RNG001 x2)."""
import jax


def sample():
    x = jax.random.normal(jax.random.PRNGKey(0), (4,))   # line 6: inline
    key = jax.random.PRNGKey(1)
    y = jax.random.uniform(key, (4,))                    # line 8: raw var
    return x, y
