"""Seeded violation: grid/BlockSpec disagreement (PLK001 x2)."""
import jax  # noqa: F401  (pass gate: file must import jax)
from jax.experimental import pallas as pl


def kernel(x_ref, o_ref):
    o_ref[...] = x_ref[...]


def run(x):
    return pl.pallas_call(
        kernel,
        grid=(4, 4),
        # line 16: index_map takes 1 arg for a rank-2 grid
        in_specs=[pl.BlockSpec((128, 128), lambda i: (i, 0))],
        # line 18: index_map returns 2 indices for a rank-1 block
        out_specs=pl.BlockSpec((128,), lambda i, j: (i, 0)),
        out_shape=None,
    )(x)
