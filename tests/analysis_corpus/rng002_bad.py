"""Seeded violation: key value consumed twice (RNG002 x2)."""
import jax


def twice(key):
    x = jax.random.normal(key, (4,))
    y = jax.random.uniform(key, (4,))    # line 7: second consumption
    return x, y


def looped(key):
    out = []
    for _ in range(4):
        out.append(jax.random.normal(key, (4,)))   # line 14: loop reuse
    return out
