"""Near miss: raw keys only ever feed split; samplers eat derived
keys. Must produce no findings."""
import jax


def sample():
    key = jax.random.PRNGKey(0)
    k0, k1 = jax.random.split(key)
    x = jax.random.normal(k0, (4,))
    y = jax.random.uniform(k1, (4,))
    return x, y
