"""Stand-in test file: every boolean/enum flag is referenced."""


def test_all_flags():
    assert "use_kernel" and "prefix_cache" and "kv_dtype"
