"""Near-miss engine config: same layout as the drift-seeded tree but
every surface agrees. Must produce no findings."""
import dataclasses
import os


def _default_use_kernel():
    return os.environ.get("REPRO_USE_KERNEL", "") == "1"


def _default_kv_dtype():
    return os.environ.get("REPRO_KV_DTYPE", "").strip() or "bf16"


@dataclasses.dataclass
class EngineConfig:
    max_batch: int = 64
    capacity: int = 512
    use_kernel: "bool | str" = dataclasses.field(
        default_factory=_default_use_kernel)
    prefix_cache: bool = True
    kv_dtype: str = dataclasses.field(default_factory=_default_kv_dtype)

    _ENV_FIELDS = {
        "REPRO_MAX_BATCH": ("max_batch", int, 1),
        "REPRO_CAPACITY": ("capacity", int, 2),
    }
