"""Near-miss launcher: help mentions only vars something reads."""
import argparse


def build_parser():
    p = argparse.ArgumentParser()
    p.add_argument("--use-kernel",
                   help="kernel path; env default REPRO_USE_KERNEL")
    p.add_argument("--kv-dtype",
                   help="pool dtype; env default REPRO_KV_DTYPE")
    return p
