"""Near miss: consume-then-rebind and fold_in derivation — the two
blessed idioms the engine uses. Must produce no findings."""
import jax


def twice(key):
    key, k = jax.random.split(key)
    x = jax.random.normal(k, (4,))
    key, k = jax.random.split(key)
    y = jax.random.uniform(k, (4,))
    return x, y


def looped(key):
    out = []
    for i in range(4):
        k = jax.random.fold_in(key, i)
        out.append(jax.random.normal(k, (4,)))
    return out
