"""Near miss: static-shape casts are fine in traced bodies, and host
escapes outside traced bodies are fine everywhere. Must produce no
findings."""
import jax
import jax.numpy as jnp


@jax.jit
def f(x):
    n = int(x.shape[0])
    return jnp.sum(x) / n


def host_summary(x):
    return x.max().item()
