"""Near miss: arities line up, including the PrefetchScalarGridSpec
idiom where `*_` absorbs the scalar-prefetch refs and a
memory_space-only BlockSpec has no block shape to check. Must produce
no findings."""
import jax  # noqa: F401
from jax.experimental import pallas as pl
from jax.experimental.pallas import tpu as pltpu


def kernel(s_ref, x_ref, y_ref, o_ref):
    o_ref[...] = x_ref[...]


def run(x, y, s):
    spec = pltpu.PrefetchScalarGridSpec(
        num_scalar_prefetch=1,
        grid=(4, 4),
        in_specs=[
            pl.BlockSpec((128, 128), lambda i, j, *_: (i, j)),
            pl.BlockSpec(memory_space=pltpu.ANY),
        ],
        out_specs=pl.BlockSpec((128, 128), lambda i, j, *_: (i, j)),
    )
    return pl.pallas_call(kernel, grid_spec=spec, out_shape=None)(s, x, y)
