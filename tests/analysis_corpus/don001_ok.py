"""Near miss: the swap idiom — the donated arg is rebound from the
call's result in the same statement. Must produce no findings."""
from functools import partial

import jax


@partial(jax.jit, donate_argnums=(1,))
def step(params, cache):
    return cache


def drive(params, cache):
    cache = step(params, cache)
    cache = step(params, cache)
    return cache
