"""Seeded violation: statically-resolvable VMEM scratch over the
16 MiB budget (PLK003)."""
import jax  # noqa: F401
import jax.numpy as jnp
from jax.experimental import pallas as pl
from jax.experimental.pallas import tpu as pltpu

BLK = 4096


def kernel(x_ref, o_ref, acc_ref):
    o_ref[...] = x_ref[...]


def run(x):
    return pl.pallas_call(                          # 64 MiB of f32
        kernel,
        grid=(4,),
        scratch_shapes=[pltpu.VMEM((BLK, BLK), jnp.float32)],
        out_shape=None,
    )(x)
