"""Drift-seeded kv_quant surface."""
KV_DTYPES = ("f32", "bf16", "int8", "fp8")
