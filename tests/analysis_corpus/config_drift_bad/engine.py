"""Drift-seeded engine config mirroring the real EngineConfig layout.

Seeded drift: REPRO_UNDOCUMENTED is read but not in the README table
(CFG001); _ENV_FIELDS maps REPRO_MAX_BATCH to a field that does not
exist (CFG003); the REPRO_CAPACITY floor disagrees with the README
(CFG003); prefix_cache is a bool flag no test references (CFG006).
"""
import dataclasses
import os


def _default_use_kernel():
    return os.environ.get("REPRO_USE_KERNEL", "") == "1"


def _default_kv_dtype():
    return os.environ.get("REPRO_KV_DTYPE", "").strip() or "bf16"


def _undocumented():
    return os.environ.get("REPRO_UNDOCUMENTED", "")


@dataclasses.dataclass
class EngineConfig:
    max_batch: int = 64
    capacity: int = 512
    use_kernel: "bool | str" = dataclasses.field(
        default_factory=_default_use_kernel)
    prefix_cache: bool = True
    kv_dtype: str = dataclasses.field(default_factory=_default_kv_dtype)

    _ENV_FIELDS = {
        "REPRO_MAX_BATCH": ("max_batchz", int, 1),
        "REPRO_CAPACITY": ("capacity", int, 1),
    }
