"""Drift-seeded launcher: the help text mentions REPRO_OLDFLAG, which
nothing reads any more (CFG005)."""
import argparse


def build_parser():
    p = argparse.ArgumentParser()
    p.add_argument("--use-kernel",
                   help="kernel path; env default REPRO_USE_KERNEL")
    p.add_argument("--old-flag",
                   help="removed; was env REPRO_OLDFLAG")
    return p
