"""Stand-in test file: references use_kernel and kv_dtype but not the
cache toggle — seeding the CFG006 unguarded-flag finding."""


def test_kernel_lane():
    assert "use_kernel" and "kv_dtype"
