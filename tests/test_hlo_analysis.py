"""Calibrate the HLO cost walker against programs with known costs."""
import jax
import jax.numpy as jnp
import numpy as np
import pytest

from repro.launch.hlo_analysis import HloCost, hlo_cost, roofline_terms


def _hlo(fn, *args):
    return jax.jit(fn).lower(*args).compile().as_text()


def test_single_matmul_flops():
    x = jnp.zeros((128, 256), jnp.float32)
    w = jnp.zeros((256, 512), jnp.float32)
    cost = hlo_cost(_hlo(lambda a, b: a @ b, x, w))
    expected = 2 * 128 * 256 * 512
    assert abs(cost["flops"] - expected) / expected < 0.01


def test_scan_matmul_trip_count_weighting():
    """The raison d'etre: a 10-trip scanned matmul must count 10x."""
    w = jnp.zeros((128, 128), jnp.float32)
    x = jnp.zeros((128, 128), jnp.float32)

    def f(x, w):
        def body(h, _):
            return h @ w, None
        h, _ = jax.lax.scan(body, x, None, length=10)
        return h

    cost = hlo_cost(_hlo(f, x, w))
    expected = 10 * 2 * 128 ** 3
    assert abs(cost["flops"] - expected) / expected < 0.05, cost["flops"]


def test_nested_scan_multiplies():
    w = jnp.zeros((64, 64), jnp.float32)
    x = jnp.zeros((64, 64), jnp.float32)

    def f(x, w):
        def outer(h, _):
            def inner(g, _):
                return g @ w, None
            g, _ = jax.lax.scan(inner, h, None, length=4)
            return g, None
        h, _ = jax.lax.scan(outer, x, None, length=3)
        return h

    cost = hlo_cost(_hlo(f, x, w))
    expected = 12 * 2 * 64 ** 3
    assert abs(cost["flops"] - expected) / expected < 0.1, cost["flops"]


def test_batched_dot_flops():
    a = jnp.zeros((4, 32, 64), jnp.float32)
    b = jnp.zeros((4, 64, 16), jnp.float32)
    cost = hlo_cost(_hlo(lambda a, b: jnp.einsum("bmk,bkn->bmn", a, b),
                         a, b))
    expected = 2 * 4 * 32 * 64 * 16
    assert abs(cost["flops"] - expected) / expected < 0.01


def test_bytes_scale_with_input():
    x = jnp.zeros((1024, 1024), jnp.float32)
    cost = hlo_cost(_hlo(lambda a: a * 2.0 + 1.0, x))
    # at least read + write of the 4 MiB array
    assert cost["bytes"] >= 2 * x.size * 4 * 0.9


def test_roofline_terms_dominance():
    t = roofline_terms(flops=197e12, hbm_bytes=0, coll_bytes=0, chips=1)
    assert t["dominant"] == "compute"
    assert abs(t["t_compute_s"] - 1.0) < 1e-6
    t = roofline_terms(flops=0, hbm_bytes=819e9, coll_bytes=1, chips=1)
    assert t["dominant"] == "memory"
    t = roofline_terms(flops=0, hbm_bytes=0, coll_bytes=50e9, chips=1)
    assert t["dominant"] == "collective"


def test_collective_bytes_on_sharded_program():
    """An all-reduce over a sharded sum must be detected."""
    if jax.device_count() < 2:
        pytest.skip("needs >1 device (run under dryrun env)")


def test_model_forward_flops_sane():
    """Whole-model check: HLO flops within 2x of 2*N*T analytic."""
    from repro.configs.registry import get_config
    from repro.models.init import init_params, count_params, padded_vocab
    from repro.models.model import forward_full
    cfg = get_config("qwen3-1.7b", smoke=True)
    params = init_params(cfg, jax.random.PRNGKey(0))
    toks = jnp.zeros((2, 64), jnp.int32)
    hlo = jax.jit(
        lambda p, t: forward_full(p, cfg, t)["logits"]).lower(
        params, toks).compile().as_text()
    cost = hlo_cost(hlo)
    n = count_params(params) - padded_vocab(cfg) * cfg.d_model
    analytic = 2 * n * 2 * 64
    assert 0.5 < cost["flops"] / analytic < 3.0, \
        (cost["flops"], analytic)
