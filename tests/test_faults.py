"""Fault-tolerant serving tests: deterministic fault injection
(step/alloc/NaN), retry-with-backoff, the degrade ladder, cancellation +
deadlines, and KV-pool integrity recovery.

The load-bearing property throughout: the engine's determinism pins
(kernel==dense, K==1) double as recovery levers, so every transient
fault and every degrade rung must leave surviving lanes' tokens, scores
and prune decisions BIT-IDENTICAL to the fault-free run under a fixed
RNG — and every fault/cancel path must leave the pool drained and the
engine reusable."""
import functools

import jax
import pytest
from hypothesis import given, settings
from hypothesis import strategies as st

from repro.configs.registry import serving_config
from repro.core.pruning import make_policy
from repro.core.trace import TraceStatus
from repro.data.tokenizer import get_tokenizer
from repro.models.init import init_params
from repro.serving import (DeviceStepFault, Engine, EngineConfig,
                           FatalFaultError, FaultPlan, FaultSpec,
                           RecoveryConfig, Request, SamplingParams)
from repro.serving.kv_manager import BlockManager


@functools.lru_cache(maxsize=1)
def _setup():
    cfg = serving_config()
    params = init_params(cfg, jax.random.PRNGKey(0))
    tok = get_tokenizer()
    prompts = [tok.encode("3+5-2=", add_bos=True),
               tok.encode("7*2+1=", add_bos=True)]
    return cfg, params, prompts


@pytest.fixture(scope="module")
def setup():
    return _setup()


def _ecfg(num_blocks=64, max_new=12, batch=8, horizon=1, faults=None,
          temperature=0.0, seed=1234):
    return EngineConfig(
        max_batch=batch, num_blocks=num_blocks, capacity=128,
        max_new_tokens=max_new, seed=seed, decode_horizon=horizon,
        sampling=SamplingParams(temperature=temperature, top_k=0,
                                top_p=1.0, max_new_tokens=max_new),
        faults=faults)


def _reqs(prompts, n=2, **extra):
    return [Request(request_id=i, prompt_tokens=p, n_traces=n,
                    policy=make_policy("sc"), **extra)
            for i, p in enumerate(prompts)]


def _snapshot(results):
    return {r.request_id: ([(t.output_tokens, t.status, t.score)
                            for t in r.traces], r.num_pruned)
            for r in results}


def _assert_clean(eng):
    """Every fault/cancel path must leave the engine reusable."""
    assert eng.pool_drained()
    eng.check_integrity()


# ---------------------------------------------------------------------------
# plan grammar + recovery policy units
# ---------------------------------------------------------------------------

def test_fault_plan_parse_grammar():
    plan = FaultPlan.parse("step@2x3, alloc@5, nan@7:slot=1, nan@9:req=0",
                           seed=3)
    kinds = [(s.kind, s.tick, s.count) for s in plan.specs]
    assert kinds == [("step", 2, 3), ("alloc", 5, 1),
                     ("nan", 7, 1), ("nan", 9, 1)]
    assert plan.specs[2].slot == 1 and plan.specs[3].request_id == 0
    assert "step@2x3" in repr(plan) and "seed=3" in repr(plan)


@pytest.mark.parametrize("bad", [
    "step", "step@", "step@x2", "bogus@3", "step@-1", "step@2x0",
    "nan@3:lane=0", "nan@3:slot=a", "",
])
def test_fault_plan_parse_errors(bad):
    with pytest.raises(ValueError):
        FaultPlan.parse(bad)


def test_fault_spec_validation():
    with pytest.raises(ValueError, match="unknown fault kind"):
        FaultSpec(kind="oom", tick=1)
    with pytest.raises(ValueError, match="count >= 1"):
        FaultSpec(kind="step", tick=1, count=0)


def test_step_fault_fires_until_count_drains():
    plan = FaultPlan.parse("step@3x2")
    plan.maybe_step_fault(1)  # below the arm tick: no fire
    with pytest.raises(DeviceStepFault):
        plan.maybe_step_fault(3)
    with pytest.raises(DeviceStepFault):
        plan.maybe_step_fault(7)  # armed specs follow the clock
    plan.maybe_step_fault(8)      # drained
    plan.reset()                  # re-armed for the next serve
    with pytest.raises(DeviceStepFault):
        plan.maybe_step_fault(3)


def test_alloc_window_and_nan_victims():
    plan = FaultPlan.parse("alloc@4x2, nan@6:req=1")
    assert [plan.alloc_blocked(t) for t in range(3, 7)] == \
        [False, True, True, False]
    assert plan.nan_victims(6, []) == []            # victim absent: armed
    assert plan.nan_victims(6, [(0, 0), (2, 1)]) == [2]
    assert plan.nan_victims(7, [(0, 0), (2, 1)]) == []  # drained


def test_backoff_is_capped_exponential():
    rc = RecoveryConfig(backoff_base_s=0.001, backoff_cap_s=0.004)
    assert [rc.backoff(a) for a in (1, 2, 3, 4, 9)] == \
        [0.001, 0.002, 0.004, 0.004, 0.004]


# ---------------------------------------------------------------------------
# step faults: retry is bit-identical, degrade rungs are token-identical
# ---------------------------------------------------------------------------

def test_transient_step_fault_retry_consumes_no_rng(setup):
    """Injected step faults raise BEFORE the device call, so retries
    replay the identical call — even under stochastic sampling the
    faulted engine's outputs match the fault-free engine token for
    token."""
    cfg, params, prompts = setup
    snaps, engines = [], []
    for faults in (None, "step@2x2"):
        eng = Engine(params, cfg, _ecfg(temperature=0.8, faults=faults),
                     make_policy("sc"))
        snaps.append(_snapshot(eng.serve_batch(_reqs(prompts, n=2))))
        engines.append(eng)
    assert snaps[0] == snaps[1]
    stats = engines[1].fault_stats
    assert stats.step_faults == 2 and stats.step_retries == 2
    assert stats.recovered_steps == 1
    assert stats.degraded_to_dense == 0 and stats.degraded_horizon == 0
    _assert_clean(engines[1])


def test_persistent_step_fault_takes_horizon_rung(setup):
    """Five consecutive failures exhaust the retry budget (3) and take
    one degrade rung — on a dense-path engine that is the K->1 horizon
    pin, which is token-identical by the decode-horizon equivalence."""
    cfg, params, prompts = setup
    ref = Engine(params, cfg, _ecfg(horizon=3), make_policy("sc"))
    want = _snapshot(ref.serve_batch(_reqs(prompts, n=2)))

    eng = Engine(params, cfg, _ecfg(horizon=3, faults="step@2x5"),
                 make_policy("sc"))
    assert not eng.use_kernel  # CPU host: the dense rung is unavailable
    got = _snapshot(eng.serve_batch(_reqs(prompts, n=2)))
    assert got == want
    stats = eng.fault_stats
    assert stats.step_faults == 5 and stats.recovered_steps == 1
    assert stats.degraded_horizon == 1 and eng.force_horizon1
    _assert_clean(eng)


def test_fatal_step_fault_fails_batch_and_engine_stays_usable(setup):
    """Retries and every rung exhausted: the serve aborts, every
    unfinished request is released as "failed", and the SAME engine
    serves the next batch normally."""
    cfg, params, prompts = setup
    eng = Engine(params, cfg, _ecfg(faults="step@1x50"),
                 make_policy("sc"))
    results = eng.serve_batch(_reqs(prompts, n=2))
    assert [r.status for r in results] == ["failed", "failed"]
    for r in results:
        assert all(t.status == TraceStatus.FAILED for t in r.traces)
        assert r.answer is None and r.metrics.status == "failed"
        assert r.metrics.failed_traces == 2
    assert eng.fault_stats.aborted == 1
    _assert_clean(eng)

    eng.fault_plan = None  # fault cleared: the engine must be reusable
    ref = Engine(params, cfg, _ecfg(), make_policy("sc"))
    want = _snapshot(ref.serve_batch(_reqs(prompts, n=2)))
    got = _snapshot(eng.serve_batch(_reqs(prompts, n=2)))
    assert got == want
    _assert_clean(eng)


def test_fault_plan_replays_identically_across_serves(setup):
    """FaultPlan.reset re-arms per serve: the same plan perturbs every
    serve of an engine identically (replayable chaos)."""
    cfg, params, prompts = setup
    eng = Engine(params, cfg, _ecfg(faults="step@2x2"), make_policy("sc"))
    first = _snapshot(eng.serve_batch(_reqs(prompts, n=2)))
    second = _snapshot(eng.serve_batch(_reqs(prompts, n=2)))
    assert first == second
    assert eng.fault_stats.recovered_steps == 2  # one recovery per serve
    _assert_clean(eng)


# ---------------------------------------------------------------------------
# allocation faults: stall -> shed -> abort
# ---------------------------------------------------------------------------

def test_transient_alloc_stall_preserves_outputs(setup):
    """A short allocator outage stalls whole rounds instead of invoking
    memory-pressure pruning: survivors are bit-identical and nothing is
    shed."""
    cfg, params, prompts = setup
    snaps, engines = [], []
    for faults in (None, "alloc@2"):
        eng = Engine(params, cfg, _ecfg(faults=faults), make_policy("sc"))
        snaps.append(_snapshot(eng.serve_batch(_reqs(prompts, n=2))))
        engines.append(eng)
    assert snaps[0] == snaps[1]
    stats = engines[1].fault_stats
    assert stats.alloc_faults == 1 and stats.shed_traces == 0
    _assert_clean(engines[1])


def test_persistent_alloc_shortage_sheds_fanout_then_recovers(setup):
    """An outage past ``shed_after`` takes the fan-out rung: WAITING
    traces shed down to each request's floor; once the allocator
    returns, the survivors complete normally."""
    cfg, params, prompts = setup
    eng = Engine(params, cfg, _ecfg(faults="alloc@1x3"),
                 make_policy("sc"))
    results = eng.serve_batch(_reqs(prompts, n=3))
    stats = eng.fault_stats
    assert stats.alloc_faults == 3 and stats.shed_traces == 4
    for r in results:
        assert r.status == "completed"
        assert r.metrics.degraded_traces == 2
        assert sum(t.status == TraceStatus.FINISHED for t in r.traces) == 1
        assert sum(t.status == TraceStatus.PRUNED for t in r.traces) == 2
        survivor = next(t for t in r.traces
                        if t.status == TraceStatus.FINISHED)
        assert survivor.num_tokens > 0
    _assert_clean(eng)


def test_unrecoverable_alloc_shortage_aborts(setup):
    """An outage past ``abort_after`` fails the batch through the
    normal release path — drained pool, reusable engine."""
    cfg, params, prompts = setup
    eng = Engine(params, cfg, _ecfg(faults="alloc@1x99"),
                 make_policy("sc"))
    eng.recovery = RecoveryConfig(shed_after=2, abort_after=4,
                                  backoff_base_s=1e-4, backoff_cap_s=1e-3)
    results = eng.serve_batch(_reqs(prompts, n=2))
    assert all(r.status == "failed" for r in results)
    assert eng.fault_stats.aborted == 1
    assert eng.fault_stats.alloc_faults == 4
    _assert_clean(eng)
    eng.fault_plan = None
    ok = eng.serve_batch(_reqs(prompts, n=2))
    assert all(r.status == "completed" for r in ok)
    _assert_clean(eng)


# ---------------------------------------------------------------------------
# NaN quarantine
# ---------------------------------------------------------------------------

def test_nan_burst_quarantines_lane_survivors_identical(setup):
    """A poisoned burst terminates ONLY the victim lane (distinct
    FAILED status); every surviving lane's tokens are bit-identical to
    the fault-free run, and the poisoned prefix never folds into the
    victim's state."""
    cfg, params, prompts = setup
    ref = Engine(params, cfg, _ecfg(), make_policy("sc"))
    want = ref.serve_batch(_reqs(prompts, n=2))

    eng = Engine(params, cfg, _ecfg(faults="nan@4:slot=0"),
                 make_policy("sc"))
    got = eng.serve_batch(_reqs(prompts, n=2))
    assert eng.fault_stats.nan_quarantined == 1
    victim = got[0].traces[0]  # slot 0 = first admitted trace
    assert victim.status == TraceStatus.FAILED
    ref_victim = want[0].traces[0]
    assert victim.output_tokens == \
        ref_victim.output_tokens[:len(victim.output_tokens)]
    assert len(victim.output_tokens) < len(ref_victim.output_tokens)
    for r_got, r_want in zip(got, want):
        for t_got, t_want in zip(r_got.traces, r_want.traces):
            if t_got is victim:
                continue
            assert t_got.output_tokens == t_want.output_tokens
            assert t_got.status == TraceStatus.FINISHED
    assert got[0].metrics.failed_traces == 1
    assert got[0].status == "completed"  # the survivor still completes
    _assert_clean(eng)


# ---------------------------------------------------------------------------
# cancellation + deadlines
# ---------------------------------------------------------------------------

def test_cancel_before_admission_and_unknown_id(setup):
    cfg, params, prompts = setup
    eng = Engine(params, cfg, _ecfg(), make_policy("sc"))
    eng.cancel(1)
    eng.cancel(999)  # unknown ids are ignored
    results = eng.serve_batch(_reqs(prompts, n=2))
    assert results[0].status == "completed"
    assert results[1].status == "cancelled"
    assert all(t.status == TraceStatus.CANCELLED
               for t in results[1].traces)
    assert results[1].metrics.status == "cancelled"
    assert eng.fault_stats.cancelled == 1
    _assert_clean(eng)


def test_cancel_mid_decode_from_completion_callback(setup):
    """Engine.cancel is safe from an on_complete callback: the long
    request is released mid-decode at the next pump sweep."""
    cfg, params, prompts = setup
    eng = Engine(params, cfg, _ecfg(max_new=48), make_policy("sc"))
    reqs = [Request(request_id=0, prompt_tokens=prompts[0], n_traces=1,
                    policy=make_policy("sc"), max_new_tokens=4),
            Request(request_id=1, prompt_tokens=prompts[1], n_traces=2,
                    policy=make_policy("sc"))]

    def on_result(r):
        if r.request_id == 0:
            eng.cancel(1)

    results = eng.serve_batch(reqs, on_complete=on_result)
    assert results[0].status == "completed"
    assert results[1].status == "cancelled"
    assert all(t.status == TraceStatus.CANCELLED
               for t in results[1].traces)
    assert eng.fault_stats.cancelled == 1
    _assert_clean(eng)


def test_deadline_exceeded_releases_request(setup):
    cfg, params, prompts = setup
    eng = Engine(params, cfg, _ecfg(), make_policy("sc"))
    reqs = _reqs(prompts, n=2)
    reqs[1].deadline = 0.0  # expires before it can arrive
    results = eng.serve_batch(reqs)
    assert results[0].status == "completed"
    assert results[1].status == "deadline_exceeded"
    assert results[1].metrics.status == "deadline_exceeded"
    assert all(t.status == TraceStatus.CANCELLED
               for t in results[1].traces)
    assert eng.fault_stats.deadline_exceeded == 1
    _assert_clean(eng)


# ---------------------------------------------------------------------------
# mid-serve crash: emergency drain + engine reuse
# ---------------------------------------------------------------------------

def test_real_exception_drains_pool_and_engine_recovers(setup):
    """A REAL device exception (not an injected DeviceStepFault) is
    never retried — buffer donation makes a blind retry unsafe. It
    propagates, serve_batch drains everything, and the next serve
    starts from a fresh device pool."""
    cfg, params, prompts = setup
    eng = Engine(params, cfg, _ecfg(), make_policy("sc"))
    orig = eng._prefill

    def boom(*a, **k):
        raise RuntimeError("device died")

    eng._prefill = boom
    with pytest.raises(RuntimeError, match="device died"):
        eng.serve_batch(_reqs(prompts, n=2))
    assert eng._kv_cache is None  # donated pool dropped, not stashed
    _assert_clean(eng)

    eng._prefill = orig
    ref = Engine(params, cfg, _ecfg(), make_policy("sc"))
    want = _snapshot(ref.serve_batch(_reqs(prompts, n=2)))
    got = _snapshot(eng.serve_batch(_reqs(prompts, n=2)))
    assert got == want
    _assert_clean(eng)


# ---------------------------------------------------------------------------
# properties: transient plans are invisible; the pool never leaks
# ---------------------------------------------------------------------------

@functools.lru_cache(maxsize=1)
def _pinned_pair():
    cfg, params, _ = _setup()
    plain = Engine(params, cfg, _ecfg(), make_policy("sc"))
    faulty = Engine(params, cfg, _ecfg(), make_policy("sc"))
    return plain, faulty


@settings(max_examples=4, deadline=None)
@given(st.integers(1, 3), st.integers(1, 8))
def test_random_transient_plans_preserve_outputs(count, at):
    """Property: any transient plan (step runs within the retry budget,
    single-round alloc outages) is INVISIBLE in the outputs — same
    tokens, statuses, scores, prune counts — and leaves the pool
    drained."""
    cfg, params, prompts = _setup()
    plain, faulty = _pinned_pair()
    faulty.fault_plan = FaultPlan.parse(
        f"step@{at}x{count},alloc@{at + 1}")
    snaps = []
    for eng in (plain, faulty):
        snaps.append(_snapshot(eng.serve_batch(_reqs(prompts, n=2))))
        _assert_clean(eng)
    assert snaps[0] == snaps[1]
    assert not faulty.force_horizon1  # within budget: no rung taken


@settings(max_examples=60, deadline=None)
@given(st.integers(4, 24),
       st.lists(st.tuples(st.integers(0, 5), st.integers(0, 7)),
                max_size=50),
       st.integers(0, 3))
def test_pool_leak_free_under_injected_alloc_failures(num_blocks, ops,
                                                      fail_mod):
    """Property: take/commit/abort/fork/free interleaved with injected
    allocation failures never leaks a block or orphans a reservation —
    after closing everything the pool is exactly full and the integrity
    audit is clean."""
    mgr = BlockManager(num_blocks=num_blocks, block_size=4)
    calls = [0]

    def hook(n):  # deterministic outage pattern, density set by fail_mod
        calls[0] += 1
        return fail_mod > 0 and calls[0] % (fail_mod + 1) == 0

    mgr.fault_hook = hook
    held, open_res = [], []
    for op, n in ops:
        if op == 0:
            blocks = mgr.allocate(n % 3 + 1)
            if blocks is not None:
                held.append(blocks)
        elif op == 1 and held:
            held.append(mgr.fork(held[n % len(held)]))
        elif op == 2 and held:
            mgr.free(held.pop(n % len(held)))
        elif op == 3:
            open_res.append(mgr.reserve(n % 4 + 1))
        elif op == 4 and open_res:
            res = open_res.pop(n % len(open_res))
            res.take(min(res.remaining, n % 3))  # may fail under the hook
            blocks = res.commit()
            if blocks:
                held.append(blocks)
        elif op == 5 and open_res:
            open_res.pop(n % len(open_res)).abort()
        mgr.check_integrity(expect_open_reservations=len(open_res))
    for res in open_res:
        res.abort()
    for h in held:
        mgr.free(h)
    mgr.fault_hook = None
    assert mgr.free_blocks == num_blocks - 1
    mgr.check_integrity()
