"""Decode path == full forward path, for every architecture family.

Prefill S tokens (forward_full + write_prefill_kv), then decode token S and
compare logits against forward_full run on the full S+1 sequence. This is
the core invariant the serving engine relies on.
"""
import jax
import jax.numpy as jnp
import numpy as np
import pytest

from repro.configs.registry import ALL_ARCHS, get_config
from repro.models.init import init_params
from repro.models.model import (build_cross_cache, decode_step, encode,
                                forward_full, init_decode_cache,
                                write_prefill_kv)

S = 33  # deliberately not a multiple of block size or ssm chunk
B = 2
CAPACITY = 64


def _setup(arch):
    cfg = get_config(arch, smoke=True)
    rng = jax.random.PRNGKey(42)
    params = init_params(cfg, rng)
    tokens = jax.random.randint(rng, (B, S + 5), 0, cfg.vocab_size)
    kw = {}
    if cfg.modality == "vision":
        kw["modality_embeds"] = 0.02 * jax.random.normal(
            rng, (B, cfg.num_modality_tokens, cfg.d_model)).astype(jnp.bfloat16)
    if cfg.is_encoder_decoder:
        kw["encoder_embeds"] = 0.02 * jax.random.normal(
            rng, (B, cfg.encoder_seq_len, cfg.d_model)).astype(jnp.bfloat16)
    return cfg, params, tokens, kw


@pytest.mark.parametrize("arch", ALL_ARCHS)
def test_decode_matches_full_forward(arch):
    cfg, params, tokens, kw = _setup(arch)

    # reference: full forward over S+1 tokens
    ref = forward_full(params, cfg, tokens[:, :S + 1], **kw)
    ref_logits = np.asarray(ref["logits"][:, S].astype(jnp.float32))

    # prefill S tokens, capture kv/state
    out = forward_full(params, cfg, tokens[:, :S], return_kv=True, **kw)
    cache = init_decode_cache(cfg, B, CAPACITY)
    cache = write_prefill_kv(cfg, cache, out["kvs"],
                             jnp.full((B,), S, jnp.int32))
    if cfg.is_encoder_decoder:
        enc_out = encode(params, cfg, kw["encoder_embeds"])
        cache["cross_k"], cache["cross_v"] = build_cross_cache(
            params, cfg, enc_out)

    step = decode_step(params, cfg, tokens[:, S:S + 1],
                       jnp.full((B,), S, jnp.int32), cache,
                       window_len=CAPACITY)
    got = np.asarray(step["logits"].astype(jnp.float32))

    np.testing.assert_allclose(got, ref_logits, rtol=0.08, atol=0.08)
    assert np.all(np.isfinite(got))


@pytest.mark.parametrize("arch", ["qwen3-1.7b", "mamba2-2.7b", "zamba2-2.7b"])
def test_multi_step_decode(arch):
    """Decode 4 consecutive tokens; each must match the full forward."""
    cfg, params, tokens, kw = _setup(arch)
    out = forward_full(params, cfg, tokens[:, :S], return_kv=True, **kw)
    cache = init_decode_cache(cfg, B, CAPACITY)
    cache = write_prefill_kv(cfg, cache, out["kvs"],
                             jnp.full((B,), S, jnp.int32))
    for i in range(4):
        pos = S + i
        ref = forward_full(params, cfg, tokens[:, :pos + 1], **kw)
        step = decode_step(params, cfg, tokens[:, pos:pos + 1],
                           jnp.full((B,), pos, jnp.int32), cache,
                           window_len=CAPACITY)
        cache = step["cache"]
        np.testing.assert_allclose(
            np.asarray(step["logits"].astype(jnp.float32)),
            np.asarray(ref["logits"][:, -1].astype(jnp.float32)),
            rtol=0.08, atol=0.08)
