"""Unit + property tests for the STEP core: scorer, segmentation, voting,
pruning policies, trace aggregation, block manager."""
import jax
import jax.numpy as jnp
import numpy as np
import pytest
from hypothesis import given, settings
from hypothesis import strategies as st

from repro.core.pruning import (DeepConfPolicy, SlimSCPolicy, StepPolicy,
                                make_policy)
from repro.core.scorer import (init_scorer, rank_accuracy, scorer_logits,
                               scorer_score, train_scorer, weighted_bce_loss)
from repro.core.segmentation import (StepBoundaryDetector, extract_think,
                                     split_steps)
from repro.core.trace import Trace, TraceStatus
from repro.core.voting import majority_vote, vote_breakdown, weighted_vote
from repro.serving.kv_manager import BlockManager


# ---------------------------------------------------------------------------
# scorer
# ---------------------------------------------------------------------------

def test_scorer_architecture_matches_paper():
    """Paper Appendix A: Input -> 512 (ReLU) -> 1."""
    p = init_scorer(jax.random.PRNGKey(0), d_model=64)
    assert p["w1"].shape == (64, 512)
    assert p["w2"].shape == (512, 1)
    h = jnp.ones((3, 64))
    s = scorer_score(p, h)
    assert s.shape == (3,)
    assert np.all((np.asarray(s) >= 0) & (np.asarray(s) <= 1))


def test_weighted_bce_alpha_balances_classes():
    """With alpha = K-/K+, a batch skewed negative still pulls positive
    logits up as strongly as negative logits down."""
    p = init_scorer(jax.random.PRNGKey(0), d_model=8)
    h = jnp.ones((10, 8))
    y_pos, y_neg = jnp.ones((10,)), jnp.zeros((10,))
    l_pos = weighted_bce_loss(p, h, y_pos, alpha=3.0)
    l_neg = weighted_bce_loss(p, h, y_neg, alpha=3.0)
    assert np.isfinite(float(l_pos)) and np.isfinite(float(l_neg))


def test_scorer_learns_separable_data():
    rng = np.random.RandomState(0)
    d = 16
    pos = rng.randn(400, d) + 1.5
    neg = rng.randn(400, d) - 1.5
    h = np.concatenate([pos, neg]).astype(np.float32)
    y = np.concatenate([np.ones(400), np.zeros(400)]).astype(np.int32)
    params, info = train_scorer(h, y)
    s_pos = np.asarray(scorer_score(params, jnp.asarray(pos)))
    s_neg = np.asarray(scorer_score(params, jnp.asarray(neg)))
    assert rank_accuracy(s_pos, s_neg) > 0.95


def test_rank_accuracy_extremes():
    assert rank_accuracy(np.array([1.0, 0.9]), np.array([0.1, 0.2])) == 1.0
    assert rank_accuracy(np.array([0.1]), np.array([0.9])) == 0.0
    assert np.isnan(rank_accuracy(np.array([]), np.array([0.5])))


# ---------------------------------------------------------------------------
# segmentation
# ---------------------------------------------------------------------------

def test_extract_think():
    assert extract_think("<think>abc</think>xyz") == "abc"
    assert extract_think("no markers here") == "no markers here"
    assert extract_think("<think>unclosed") == "unclosed"


def test_split_steps():
    text = "<think>s1\n\ns2\n\n\n\ns3\n\n</think>answer"
    assert split_steps(text) == ["s1", "s2", "s3"]


def test_boundary_detector_stops_at_think_close():
    det = StepBoundaryDetector(boundary_ids={5}, think_close_id=9)
    assert det.boundaries([1, 5, 2, 5, 9, 5]) == [1, 3]


# ---------------------------------------------------------------------------
# voting
# ---------------------------------------------------------------------------

def test_majority_vote():
    assert majority_vote(["a", "b", "a", None]) == "a"
    assert majority_vote([None, None]) is None


def test_weighted_vote_flips_majority():
    # 2 votes for "a" at low weight vs 1 vote for "b" at high weight
    assert weighted_vote(["a", "a", "b"], [0.1, 0.1, 0.9]) == "b"


@given(st.lists(st.sampled_from(["a", "b", "c"]), min_size=1, max_size=30))
def test_weighted_vote_uniform_weights_equals_majority(answers):
    assert weighted_vote(answers, [1.0] * len(answers)) \
        == majority_vote(answers)


# ---------------------------------------------------------------------------
# trace aggregation
# ---------------------------------------------------------------------------

def test_trace_running_mean():
    t = Trace(trace_id=0, request_id=0, prompt_tokens=[1])
    assert t.score == 0.5  # uninformative prior
    t.add_step_score(1.0)
    t.add_step_score(0.0)
    assert t.score == 0.5
    t.add_step_score(1.0)
    assert abs(t.score - 2 / 3) < 1e-9


# ---------------------------------------------------------------------------
# policies
# ---------------------------------------------------------------------------

def _mk_trace(i, score=None, conf=None, tokens=64):
    t = Trace(trace_id=i, request_id=0, prompt_tokens=[1])
    t.status = TraceStatus.RUNNING
    t.output_tokens = list(range(tokens))
    if score is not None:
        t.add_step_score(score)
    if conf is not None:
        t.token_confidences = [conf] * tokens
    return t


def test_step_policy_prunes_min_score():
    pol = StepPolicy()
    traces = [_mk_trace(0, score=0.9), _mk_trace(1, score=0.2),
              _mk_trace(2, score=0.6)]
    assert pol.on_memory_full(traces).trace_id == 1


def test_sc_policy_preempts():
    pol = make_policy("sc")
    assert pol.on_memory_full([_mk_trace(0)]) is None


def test_deepconf_threshold():
    pol = DeepConfPolicy(warmup=4, keep_pct=0.25)
    warm = [_mk_trace(i, conf=c) for i, c in enumerate([0.9, 0.8, 0.5, 0.4])]
    pol.record_warmup(warm)
    assert pol.threshold is not None
    low = _mk_trace(9, conf=0.3)
    high = _mk_trace(10, conf=0.95)
    doomed = pol.traces_to_terminate([low, high])
    assert low in doomed and high not in doomed


def test_slimsc_prunes_identical_traces():
    pol = SlimSCPolicy(threshold=0.9, check_every=8)
    a = _mk_trace(0, tokens=32)
    b = _mk_trace(1, tokens=32)
    b.output_tokens = list(a.output_tokens)
    c = _mk_trace(2, tokens=32)
    c.output_tokens = list(reversed(a.output_tokens))
    doomed = pol.traces_to_terminate([a, b, c])
    assert len(doomed) == 1 and doomed[0] in (a, b)


# ---------------------------------------------------------------------------
# block manager (property-based)
# ---------------------------------------------------------------------------

@settings(max_examples=200, deadline=None)
@given(st.integers(2, 64), st.lists(
    st.tuples(st.booleans(), st.integers(1, 8)), max_size=40))
def test_block_manager_never_double_allocates(num_blocks, ops):
    mgr = BlockManager(num_blocks=num_blocks, block_size=16)
    held = []
    for is_alloc, n in ops:
        if is_alloc:
            blocks = mgr.allocate(n)
            if blocks is not None:
                assert len(blocks) == n
                for b in blocks:
                    assert all(b not in h for h in held)
                    assert b != mgr.scratch_block
                held.append(blocks)
        elif held:
            mgr.free(held.pop())
        mgr.check_invariants()
    for h in held:
        mgr.free(h)
    assert mgr.free_blocks == num_blocks - 1


@given(st.integers(1, 1000), st.integers(1, 64))
def test_blocks_for_tokens(n_tokens, block_size):
    mgr = BlockManager(num_blocks=4, block_size=block_size)
    n = mgr.blocks_for_tokens(n_tokens)
    assert (n - 1) * block_size < n_tokens <= n * block_size
