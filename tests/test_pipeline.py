"""End-to-end pipeline integration: train tiny LM -> sample -> verify ->
scorer -> engine. Kept small (runs in ~2 min on CPU)."""
import random

import jax
import numpy as np
import pytest

from repro.configs.registry import serving_config
from repro.core.pipeline import (balance_traces, collect_boundary_hiddens,
                                 generate_batch, sample_traces)
from repro.data.arithmetic import gen_problem, make_prompt, verify
from repro.data.tokenizer import get_tokenizer
from repro.models.init import init_params


@pytest.fixture(scope="module")
def model():
    cfg = serving_config()
    params = init_params(cfg, jax.random.PRNGKey(0))
    return params, cfg


def test_generate_batch_shapes(model):
    params, cfg = model
    tok = get_tokenizer()
    prompts = [tok.encode("3+5=", add_bos=True),
               tok.encode("1+2-4=", add_bos=True)]
    comps = generate_batch(params, cfg, prompts, max_new=24,
                           rng=jax.random.PRNGKey(1))
    assert len(comps) == 2
    for c in comps:
        assert 1 <= len(c) <= 24
        assert all(0 <= t < cfg.vocab_size for t in c)


def test_sample_traces_verified(model):
    params, cfg = model
    rng = random.Random(0)
    problems = [gen_problem(rng) for _ in range(2)]
    traces = sample_traces(params, cfg, problems, n_samples=2, max_new=32)
    assert len(traces) == 4
    for t in traces:
        ans, ok = verify(t.problem, t.text)
        assert ok == t.correct


def test_balance_traces():
    class T:
        def __init__(self, c):
            self.correct = c
    traces = [T(True)] * 10 + [T(False)] * 3
    sel = balance_traces(traces, per_class=5)
    assert sum(t.correct for t in sel) == 3
    assert sum(not t.correct for t in sel) == 3


def test_collect_boundary_hiddens_labels(model):
    """Boundary states carry the trace label (pseudo-label propagation)."""
    params, cfg = model
    tok = get_tokenizer()
    from repro.core.pipeline import SampledTrace
    from repro.data.arithmetic import Problem
    p = Problem(operands=[3, 5], ops=["+"])
    text = "<think>3+5=8\n\n</think>boxed{8}"
    ids = tok.encode(make_prompt(p), add_bos=True) + tok.encode(
        text, add_eos=True)
    tr = SampledTrace(problem=p, token_ids=ids,
                      prompt_len=len(tok.encode(make_prompt(p),
                                                add_bos=True)),
                      text=text, answer="8", correct=True)
    h, y, tid = collect_boundary_hiddens(params, cfg, [tr])
    assert h.shape[0] == 1  # exactly one "\n\n" inside <think>
    assert y[0] == 1
    assert h.shape[1] == cfg.d_model
    assert np.all(np.isfinite(h))
