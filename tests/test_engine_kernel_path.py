"""The Pallas kernel path as the engine's production attention path.

Pins the tentpole contract: engine output — tokens, step scores, token
confidences, prune decisions, statuses — is IDENTICAL with
``use_kernel=True`` (multi-query paged kernels, interpret mode on CPU)
vs ``False`` (dense jnp fallbacks) under a fixed RNG, across the
decode-horizon, chunked-prefill and tight-pool (memory-pressure pruning)
configurations. Both paths follow the same numerics contract (f32
accumulation, zeros for empty rows), so the only residual difference is
online-vs-flat softmax reduction order — which the bf16 activation casts
absorb at serving scale.

Also covers ``use_kernel="auto"`` resolution (kernel on TPU, dense on
CPU, dense fallback on uncovered meshes) and the ``REPRO_USE_KERNEL``
env override the CI kernel lane uses.

Under a quantized pool (``REPRO_KV_DTYPE=int8``/``fp8`` — the CI
kv-quant lane) the identity contract narrows to what the paper's
pruning decisions actually consume: tokens, prune counts, statuses and
the answer stay EXACTLY equal, while step scores / token confidences
are held to a tight drift bound instead of bitwise equality. The
decode face stays bit-identical even quantized (bf16-grid scales keep
``code * scale`` exact in f32), but the chunked-prefill face's
online-softmax rescale is only bitwise-equal to the dense one-shot
softmax when the pooled prefix holds the row max — quantization noise
can flip near-ties, surfacing reduction-order ulps in confidences.
"""
import dataclasses

import jax
import pytest

from repro.configs.registry import serving_config
from repro.core.pruning import make_policy
from repro.core.scorer import init_scorer
from repro.data.tokenizer import get_tokenizer
from repro.models.init import init_params
from repro.models import kv_quant
from repro.serving import (Engine, EngineConfig, Request, SamplingParams,
                           resolve_use_kernel)
from repro.serving.engine import _default_use_kernel

MAX_NEW = 24

# CI's kv-quant lane re-runs this file under REPRO_KV_DTYPE=int8
_QUANTIZED = kv_quant.is_quantized(EngineConfig().kv_dtype)
_DRIFT = 1e-3


@pytest.fixture(scope="module")
def setup():
    cfg = serving_config()
    params = init_params(cfg, jax.random.PRNGKey(0))
    scorer = init_scorer(jax.random.PRNGKey(1), cfg.d_model)
    tok = get_tokenizer()
    return cfg, params, scorer, tok


def _ecfg(use_kernel, K=1, chunk=None, num_blocks=64, temperature=0.8,
          max_new=MAX_NEW):
    return EngineConfig(
        max_batch=8, num_blocks=num_blocks, capacity=128,
        max_new_tokens=max_new,
        sampling=SamplingParams(
            temperature=temperature,
            top_k=0 if temperature == 0.0 else 20,
            top_p=1.0 if temperature == 0.0 else 0.95,
            max_new_tokens=max_new),
        prefill_chunk_size=chunk, decode_horizon=K,
        use_kernel=use_kernel)


def _serve(setup, use_kernel, prompt_text, n_traces, seed, **ecfg_kw):
    cfg, params, scorer, tok = setup
    eng = Engine(params, cfg, _ecfg(use_kernel, **ecfg_kw),
                 make_policy("step"), scorer_params=scorer)
    eng._rng = jax.random.PRNGKey(seed)
    res = eng.serve(tok.encode(prompt_text, add_bos=True), n_traces)
    assert eng.pool_drained()
    eng.block_mgr.check_invariants()
    return res


def _close(xs, ys):
    return len(xs) == len(ys) and all(
        len(x) == len(y) and all(abs(u - v) <= _DRIFT for u, v in zip(x, y))
        for x, y in zip(xs, ys))


def _assert_identical(a, b):
    assert [t.output_tokens for t in a.traces] \
        == [t.output_tokens for t in b.traces]
    sa = [t.step_scores for t in a.traces]
    sb = [t.step_scores for t in b.traces]
    ca = [t.token_confidences for t in a.traces]
    cb = [t.token_confidences for t in b.traces]
    if _QUANTIZED:  # bounded drift, see module docstring
        assert _close(sa, sb)
        assert _close(ca, cb)
    else:
        assert sa == sb
        assert ca == cb
    assert [t.status for t in a.traces] == [t.status for t in b.traces]
    assert a.num_pruned == b.num_pruned
    assert a.answer == b.answer


@pytest.mark.parametrize("K,chunk,blocks,temperature", [
    (1, None, 64, 0.0),    # greedy baseline
    (4, None, 64, 0.8),    # fused decode horizon
    (1, 4, 64, 0.8),       # chunked prefill (prompt > chunk)
    (1, None, 12, 0.8),    # tight pool: memory-pressure pruning
    (4, 4, 12, 0.8),       # all three at once
])
def test_engine_kernel_vs_dense_identical(setup, K, chunk, blocks,
                                          temperature):
    kw = dict(K=K, chunk=chunk, num_blocks=blocks, temperature=temperature)
    res_d = _serve(setup, False, "3+5-2=", 6, seed=7, **kw)
    res_k = _serve(setup, True, "3+5-2=", 6, seed=7, **kw)
    _assert_identical(res_d, res_k)


def test_engine_kernel_vs_dense_multi_request(setup):
    cfg, params, scorer, tok = setup
    results = {}
    for uk in (False, True):
        eng = Engine(params, cfg, _ecfg(uk, K=2), make_policy("step"),
                     scorer_params=scorer)
        eng._rng = jax.random.PRNGKey(42)
        results[uk] = eng.serve_batch([
            Request(request_id=0,
                    prompt_tokens=tok.encode("7*2+1=", add_bos=True),
                    n_traces=3, policy=make_policy("step")),
            Request(request_id=1,
                    prompt_tokens=tok.encode("9-4+6=", add_bos=True),
                    n_traces=3, policy=make_policy("step")),
        ])
    for a, b in zip(results[False], results[True]):
        _assert_identical(a, b)


# ---------------------------------------------------------------------------
# use_kernel resolution
# ---------------------------------------------------------------------------

def test_resolve_use_kernel_auto_cpu_is_dense():
    """On a CPU host "auto" keeps the dense XLA path (the kernels would
    only run in slow interpret mode); explicit True forces interpret."""
    cfg = serving_config()
    assert jax.default_backend() == "cpu"
    assert resolve_use_kernel("auto", cfg) is False
    assert resolve_use_kernel(True, cfg) is True
    assert resolve_use_kernel(False, cfg) is False


def test_resolve_use_kernel_rejects_mla():
    cfg = dataclasses.replace(serving_config(), use_mla=True)
    with pytest.raises(NotImplementedError, match="MLA"):
        resolve_use_kernel(True, cfg)
    assert resolve_use_kernel("auto", cfg) is False


def test_resolve_use_kernel_rejects_garbage():
    with pytest.raises(ValueError, match="use_kernel"):
        resolve_use_kernel("yes please", serving_config())


def test_env_override_flips_default(monkeypatch):
    monkeypatch.setenv("REPRO_USE_KERNEL", "1")
    assert _default_use_kernel() is True
    assert EngineConfig().use_kernel is True
    monkeypatch.setenv("REPRO_USE_KERNEL", "auto")
    assert EngineConfig().use_kernel == "auto"
    monkeypatch.delenv("REPRO_USE_KERNEL")
    assert EngineConfig().use_kernel is False
