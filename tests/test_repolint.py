"""repolint: seeded-corpus detection, suppressions, baseline
round-trip, and the repo-tree-is-clean acceptance pin.

Every rule has one minimal positive (``*_bad``) and one near-miss
negative (``*_ok``) under ``tests/analysis_corpus/``; the expected
finding sets below are exact — a pass that stops detecting its seeded
violation, or starts flagging the blessed idiom next to it, fails here
before it ever reaches CI.
"""
import json
import os
import subprocess
import sys

from tools.repolint.core import (Baseline, Context, load_py_files,
                                 run_passes)
from tools.repolint.passes import all_passes

ROOT = os.path.dirname(os.path.dirname(os.path.abspath(__file__)))
CORPUS = os.path.join(ROOT, "tests", "analysis_corpus")

# surface override pointing the config-surface pass at the fixture
# mini-trees (same shape as the real repo layout)
DRIFT_SURFACE = {
    "engine": "engine.py",
    "readme": "README.md",
    "ci": "ci.yml",
    "serve": "serve.py",
    "tests_dir": "tests",
    "src_dirs": ["."],
    "kv_quant": "kv_quant.py",
    "docs_support": "docs/SUPPORT_MATRIX.md",
    "docs_benchmarks": "docs/BENCHMARKS.md",
}


def lint(root, paths, surface=None, select=None):
    files, parse = load_py_files(root, paths)
    ctx = Context(root=root, py_files=files, surface=surface)
    return run_passes(ctx, all_passes(), select=select,
                      parse_findings=parse)


def rule_lines(findings):
    return sorted((f.rule, f.line) for f in findings)


# ---------------------------------------------------------------------------
# per-rule corpus: exact positive sets, empty negative sets
# ---------------------------------------------------------------------------

EXPECTED = {
    "rng001_bad.py": [("RNG001", 6), ("RNG001", 8)],
    "rng002_bad.py": [("RNG002", 7), ("RNG002", 14)],
    "don001_bad.py": [("DON001", 14)],
    "trc001_bad.py": [("TRC001", 8)],
    "trc002_bad.py": [("TRC002", 8), ("TRC002", 9), ("TRC002", 10)],
    "plk001_bad.py": [("PLK001", 15), ("PLK001", 17)],
    "plk002_bad.py": [("PLK002", 7)],
    "plk003_bad.py": [("PLK003", 16)],
    "sup001_bad.py": [("SUP001", 7)],
    "parse_bad.py": [("PARSE", 2)],
}


def test_corpus_positives_exact():
    for name, want in sorted(EXPECTED.items()):
        got = rule_lines(lint(CORPUS, [name]))
        assert got == sorted(want), (
            f"{name}: expected exactly {sorted(want)}, got {got}")


def test_corpus_negatives_clean():
    ok_files = sorted(f for f in os.listdir(CORPUS)
                      if f.endswith("_ok.py"))
    assert len(ok_files) >= 9  # one near-miss per AST rule
    for name in ok_files:
        got = rule_lines(lint(CORPUS, [name]))
        assert got == [], f"{name}: near-miss flagged: {got}"


def test_config_drift_corpus_exact():
    root = os.path.join(CORPUS, "config_drift_bad")
    got = sorted((f.rule, f.path, f.line)
                 for f in lint(root, ["."], surface=DRIFT_SURFACE))
    assert got == sorted([
        ("CFG001", "engine.py", 21),
        ("CFG002", "README.md", 9),
        ("CFG003", "README.md", 6),     # floor drift
        ("CFG003", "engine.py", 34),    # nonexistent field
        ("CFG004", "ci.yml", 7),
        ("CFG005", "serve.py", 1),
        ("CFG006", "engine.py", 30),    # prefix_cache unguarded
        ("CFG007", "docs/BENCHMARKS.md", 3),
        ("CFG007", "docs/SUPPORT_MATRIX.md", 3),
    ])
    ok_root = os.path.join(CORPUS, "config_drift_ok")
    assert lint(ok_root, ["."], surface=DRIFT_SURFACE) == []


def test_doc_links_corpus():
    bad = lint(os.path.join(CORPUS, "doclinks_bad"), [])
    assert sorted((f.rule, f.detail) for f in bad) == [
        ("DOC001", "docs/nope.md"), ("DOC001", "missing.md")]
    assert lint(os.path.join(CORPUS, "doclinks_ok"), []) == []


# ---------------------------------------------------------------------------
# suppressions
# ---------------------------------------------------------------------------

def test_used_suppression_silences_finding_without_sup001():
    # sup001_ok seeds a real RNG002 and suppresses it: no findings at
    # all (the suppression is used, so SUP001 stays quiet)
    assert lint(CORPUS, ["sup001_ok.py"]) == []


def test_select_restricts_rules():
    got = lint(CORPUS, ["rng001_bad.py", "rng002_bad.py"],
               select={"RNG002"})
    assert sorted(f.rule for f in got) == ["RNG002", "RNG002"]


def test_unused_suppression_not_reported_when_rule_unselected():
    # with RNG002 not running, its suppression comment can't be judged
    got = lint(CORPUS, ["sup001_bad.py"], select={"RNG001"})
    assert got == []


# ---------------------------------------------------------------------------
# baseline round-trip + staleness
# ---------------------------------------------------------------------------

def test_baseline_round_trip_and_stale(tmp_path):
    findings = lint(CORPUS, ["rng002_bad.py"])
    assert len(findings) == 2
    path = str(tmp_path / "baseline.json")
    Baseline.from_findings(findings, reason="seeded corpus").save(path)

    loaded = Baseline.load(path)
    new, baselined, stale = loaded.apply(findings)
    assert new == [] and len(baselined) == 2 and stale == []
    # fingerprints are line-free: the entry survives an edit that only
    # moves the finding
    entry_fps = {e["fingerprint"] for e in loaded.entries}
    assert entry_fps == {"RNG002::rng002_bad.py::key",
                         "RNG002::rng002_bad.py::key@loop"}
    assert all(f.fingerprint in entry_fps for f in findings)

    # against a clean file every entry is stale
    new2, base2, stale2 = loaded.apply(lint(CORPUS, ["rng002_ok.py"]))
    assert new2 == [] and base2 == [] and len(stale2) == 2
    assert all(e["reason"] == "seeded corpus" for e in stale2)


def test_baseline_entries_require_reason(tmp_path):
    path = tmp_path / "bad.json"
    path.write_text(json.dumps(
        {"entries": [{"fingerprint": "RNG002::x.py::key"}]}))
    try:
        Baseline.load(str(path))
    except ValueError as e:
        assert "reason" in str(e)
    else:
        raise AssertionError("baseline without reason loaded")


# ---------------------------------------------------------------------------
# acceptance: the real tree is clean, through the real CLI
# ---------------------------------------------------------------------------

def _run_cli(*args, cwd=ROOT):
    return subprocess.run(
        [sys.executable, "-m", "tools.repolint", *args],
        cwd=cwd, capture_output=True, text=True)


def test_repo_src_is_clean_via_cli(tmp_path):
    out = str(tmp_path / "repolint.json")
    r = _run_cli("src/", "--out", out)
    assert r.returncode == 0, r.stdout + r.stderr
    report = json.loads(open(out).read())
    assert report["counts"]["new"] == 0
    assert report["counts"]["stale_baseline"] == 0
    assert "RNG001" in report["rules"]


def test_cli_reports_corpus_findings_nonzero():
    r = _run_cli("tests/analysis_corpus/rng001_bad.py", "--no-baseline")
    assert r.returncode == 1
    assert "RNG001" in r.stdout


def test_cli_list_rules_covers_every_pass():
    r = _run_cli("--list-rules")
    assert r.returncode == 0
    for code in ("RNG001", "RNG002", "DON001", "TRC001", "TRC002",
                 "PLK001", "PLK002", "PLK003", "CFG001", "CFG007",
                 "DOC001", "SUP001", "PARSE"):
        assert code in r.stdout, f"{code} missing from --list-rules"


def test_cli_bad_path_is_usage_error():
    r = _run_cli("no/such/dir")
    assert r.returncode == 2


def test_doc_links_shim_still_works():
    r = subprocess.run(
        [sys.executable, os.path.join("tools", "check_doc_links.py")],
        cwd=ROOT, capture_output=True, text=True)
    assert r.returncode == 0, r.stdout + r.stderr
