"""Per-kernel allclose validation vs the pure-jnp oracles, swept over
shapes and dtypes (interpret=True executes the kernel bodies on CPU)."""
import math

import jax
import jax.numpy as jnp
import numpy as np
import pytest
from hypothesis import given, settings
from hypothesis import strategies as st

from repro.kernels import ops, ref
from repro.kernels.flash_attention import flash_attention
from repro.kernels.paged_attention import (paged_attention,
                                           paged_attention_prefill)
from repro.kernels.ssd_scan import ssd_scan
from repro.kernels.step_score import step_score
from repro.models.layers import paged_attention_decode


def _tol(dtype):
    return dict(rtol=2e-2, atol=2e-2) if dtype == jnp.bfloat16 \
        else dict(rtol=2e-5, atol=2e-5)


# ---------------------------------------------------------------------------
# flash attention
# ---------------------------------------------------------------------------

@pytest.mark.parametrize("dtype", [jnp.float32, jnp.bfloat16])
@pytest.mark.parametrize("B,H,S,hd,blk", [
    (1, 1, 128, 64, 64),
    (2, 3, 256, 64, 64),
    (1, 2, 256, 128, 128),
    (2, 1, 512, 32, 128),
])
def test_flash_attention_causal(B, H, S, hd, blk, dtype):
    ks = jax.random.split(jax.random.PRNGKey(0), 3)
    q, k, v = (jax.random.normal(kk, (B, H, S, hd), dtype) for kk in ks)
    out = flash_attention(q, k, v, blk_q=blk, blk_k=blk, interpret=True)
    want = ref.mha_ref(q.astype(jnp.float32), k.astype(jnp.float32),
                       v.astype(jnp.float32))
    np.testing.assert_allclose(np.asarray(out, np.float32),
                               np.asarray(want, np.float32), **_tol(dtype))


@pytest.mark.parametrize("window", [32, 100, 256])
def test_flash_attention_sliding_window(window):
    B, H, S, hd = 1, 2, 256, 64
    ks = jax.random.split(jax.random.PRNGKey(1), 3)
    q, k, v = (jax.random.normal(kk, (B, H, S, hd)) for kk in ks)
    out = flash_attention(q, k, v, window=window, blk_q=64, blk_k=64,
                          interpret=True)
    want = ref.mha_ref(q, k, v, window=window)
    np.testing.assert_allclose(np.asarray(out), np.asarray(want),
                               rtol=2e-5, atol=2e-5)


def test_flash_attention_noncausal():
    B, H, S, hd = 1, 1, 128, 64
    ks = jax.random.split(jax.random.PRNGKey(2), 3)
    q, k, v = (jax.random.normal(kk, (B, H, S, hd)) for kk in ks)
    out = flash_attention(q, k, v, causal=False, blk_q=64, blk_k=64,
                          interpret=True)
    want = ref.mha_ref(q, k, v, causal=False)
    np.testing.assert_allclose(np.asarray(out), np.asarray(want),
                               rtol=2e-5, atol=2e-5)


# ---------------------------------------------------------------------------
# paged attention
# ---------------------------------------------------------------------------

@pytest.mark.parametrize("dtype", [jnp.float32, jnp.bfloat16])
@pytest.mark.parametrize("B,H,KVH,hd,page,bp", [
    (1, 4, 1, 64, 16, 3),     # MQA (granite-style kv=1)
    (3, 8, 2, 64, 16, 4),     # GQA
    (2, 4, 4, 128, 32, 2),    # MHA
    (2, 16, 8, 64, 64, 5),
])
def test_paged_attention(B, H, KVH, hd, page, bp, dtype):
    NB = B * bp + 2
    ks = jax.random.split(jax.random.PRNGKey(0), 4)
    q = jax.random.normal(ks[0], (B, H, hd), dtype)
    k_pool = jax.random.normal(ks[1], (NB, page, KVH, hd), dtype)
    v_pool = jax.random.normal(ks[2], (NB, page, KVH, hd), dtype)
    bt = jax.random.permutation(ks[3], NB)[:B * bp] \
        .reshape(B, bp).astype(jnp.int32)
    lens = jnp.asarray(
        np.random.RandomState(0).randint(1, page * bp + 1, B), jnp.int32)
    scale = 1.0 / math.sqrt(hd)
    out = paged_attention(q, k_pool, v_pool, bt, lens, scale=scale,
                          interpret=True)
    want = ref.paged_attention_ref(
        q.astype(jnp.float32), k_pool.astype(jnp.float32),
        v_pool.astype(jnp.float32), bt, lens, scale=scale)
    np.testing.assert_allclose(np.asarray(out, np.float32),
                               np.asarray(want, np.float32), **_tol(dtype))


def test_paged_attention_single_token_cache():
    """cache_len=1 edge: only one valid slot."""
    B, H, KVH, hd, page, bp = 1, 2, 1, 32, 16, 2
    ks = jax.random.split(jax.random.PRNGKey(7), 3)
    q = jax.random.normal(ks[0], (B, H, hd))
    k_pool = jax.random.normal(ks[1], (4, page, KVH, hd))
    v_pool = jax.random.normal(ks[2], (4, page, KVH, hd))
    bt = jnp.array([[1, 2]], jnp.int32)
    lens = jnp.array([1], jnp.int32)
    out = paged_attention(q, k_pool, v_pool, bt, lens, scale=0.2,
                          interpret=True)
    want = ref.paged_attention_ref(q, k_pool, v_pool, bt, lens, scale=0.2)
    np.testing.assert_allclose(np.asarray(out), np.asarray(want),
                               rtol=2e-5, atol=2e-5)


def test_paged_attention_empty_cache_emits_zeros():
    """The pinned ``cache_len == 0`` convention, identical across the
    kernel, the dense fallback and the oracle: ZEROS. (Previously the
    dense path softmaxed a row of -1e30 fill into a uniform average
    over garbage KV while the kernel emitted zeros — a silent
    use_kernel=True/False divergence for dead decode slots.)"""
    B, H, KVH, hd, page, bp = 2, 4, 2, 32, 16, 2
    ks = jax.random.split(jax.random.PRNGKey(3), 3)
    q = jax.random.normal(ks[0], (B, H, hd))
    k_pool = jax.random.normal(ks[1], (6, page, KVH, hd))
    v_pool = jax.random.normal(ks[2], (6, page, KVH, hd))
    bt = jnp.array([[1, 2], [3, 4]], jnp.int32)
    lens = jnp.array([0, 7], jnp.int32)
    outs = [
        paged_attention(q, k_pool, v_pool, bt, lens, scale=0.2,
                        interpret=True),
        paged_attention_decode(k_pool, v_pool, q, bt, lens, scale=0.2),
        ref.paged_attention_ref(k_pool=k_pool, v_pool=v_pool, q=q,
                                block_tables=bt, cache_lens=lens,
                                scale=0.2),
    ]
    for out in outs:
        out = np.asarray(out, np.float32)
        assert np.all(np.isfinite(out))
        np.testing.assert_array_equal(out[0], 0.0)  # empty row -> zeros
        assert np.any(out[1] != 0.0)
    for out in outs[1:]:  # the live row agrees across all three paths
        np.testing.assert_allclose(np.asarray(out, np.float32)[1],
                                   np.asarray(outs[0], np.float32)[1],
                                   rtol=2e-5, atol=2e-5)


def test_dense_decode_f32_accumulation_matches_kernel():
    """bf16 pools: the dense fallback accumulates the PV contraction in
    f32 (it used to cast probs to bf16 first), so use_kernel=True/False
    agree to reduction-order noise — far inside bf16's own rounding."""
    B, H, KVH, hd, page, bp = 2, 8, 2, 64, 16, 3
    ks = jax.random.split(jax.random.PRNGKey(11), 3)
    q = jax.random.normal(ks[0], (B, H, hd), jnp.bfloat16)
    k_pool = jax.random.normal(ks[1], (B * bp + 1, page, KVH, hd),
                               jnp.bfloat16)
    v_pool = jax.random.normal(ks[2], (B * bp + 1, page, KVH, hd),
                               jnp.bfloat16)
    bt = jnp.arange(1, B * bp + 1, dtype=jnp.int32).reshape(B, bp)
    lens = jnp.array([page * bp, 11], jnp.int32)
    scale = 1.0 / math.sqrt(hd)
    kern = paged_attention(q, k_pool, v_pool, bt, lens, scale=scale,
                           interpret=True)
    dense = paged_attention_decode(k_pool, v_pool, q, bt, lens, scale=scale)
    np.testing.assert_allclose(np.asarray(kern, np.float32),
                               np.asarray(dense, np.float32),
                               rtol=2e-3, atol=2e-3)


@settings(max_examples=25, deadline=None)
@given(st.integers(1, 3), st.sampled_from((1, 2, 4)),
       st.sampled_from((1, 2, 4)), st.sampled_from((8, 16)),
       st.integers(1, 4), st.integers(0, 10 ** 6))
def test_paged_decode_kernel_vs_dense_property(B, KVH, G, page, bp, seed):
    """Kernel == dense fallback over ragged cache_lens (including empty
    and exactly-full rows — the slot = pos %% window_len wraparound
    regime fills every slot) and GQA group sizes."""
    H = KVH * G
    hd = 32
    NB = B * bp + 2
    ks = jax.random.split(jax.random.PRNGKey(seed % (2 ** 31)), 4)
    q = jax.random.normal(ks[0], (B, H, hd))
    k_pool = jax.random.normal(ks[1], (NB, page, KVH, hd))
    v_pool = jax.random.normal(ks[2], (NB, page, KVH, hd))
    bt = jax.random.permutation(ks[3], NB)[:B * bp] \
        .reshape(B, bp).astype(jnp.int32)
    # ragged: 0 (empty), full (wrapped rolling window), and in-between
    lens = jnp.asarray(
        np.random.RandomState(seed % 2 ** 31).randint(0, page * bp + 1, B),
        jnp.int32)
    scale = 1.0 / math.sqrt(hd)
    kern = paged_attention(q, k_pool, v_pool, bt, lens, scale=scale,
                           interpret=True)
    dense = paged_attention_decode(k_pool, v_pool, q, bt, lens, scale=scale)
    np.testing.assert_allclose(np.asarray(kern, np.float32),
                               np.asarray(dense, np.float32),
                               rtol=2e-5, atol=2e-5)


def test_decode_layer_kernel_matches_dense_after_wraparound():
    """Full decode layer at a position past the window: slot =
    pos %% window_len wraps into low blocks; kernel and dense read the
    same rolling window."""
    from repro.configs.registry import serving_config
    from repro.models.init import init_params
    from repro.models.layers import gqa_attention_decode

    cfg = serving_config()
    params = init_params(cfg, jax.random.PRNGKey(0))
    lp = jax.tree.map(lambda x: x[0], params["layers"])["attn"]
    B, window_len, bs = 2, 32, cfg.kv_block_size
    bp = window_len // bs
    x = 0.1 * jax.random.normal(jax.random.PRNGKey(1),
                                (B, 1, cfg.d_model)).astype(jnp.bfloat16)
    positions = jnp.array([window_len + 5, window_len * 2 + 1], jnp.int32)
    pools = {}
    for name, key in (("k_pool", 2), ("v_pool", 3)):
        pools[name] = jax.random.normal(
            jax.random.PRNGKey(key),
            (B * bp + 1, bs, cfg.num_kv_heads, cfg.head_dim),
            jnp.bfloat16)
    bt = jnp.arange(1, B * bp + 1, dtype=jnp.int32).reshape(B, bp)
    outs = {}
    for uk in (False, True):
        cache = {**pools, "block_tables": bt, "window_len": window_len,
                 "use_kernel": uk}
        out, _ = gqa_attention_decode(lp, cfg, x, positions, cache, 0)
        outs[uk] = np.asarray(out, np.float32)
    np.testing.assert_allclose(outs[True], outs[False],
                               rtol=2e-2, atol=2e-2)


# ---------------------------------------------------------------------------
# multi-query paged attention (chunked prefill)
# ---------------------------------------------------------------------------

def _prefill_case(B, C, KVH, G, page, bp, seed, starts, nvalid):
    H = KVH * G
    hd = 32
    NB = B * bp + 2
    ks = jax.random.split(jax.random.PRNGKey(seed), 5)
    q = jax.random.normal(ks[0], (B, C, H, hd))
    k_pool = jax.random.normal(ks[1], (NB, page, KVH, hd))
    v_pool = jax.random.normal(ks[2], (NB, page, KVH, hd))
    bt = jax.random.permutation(ks[4], NB)[:B * bp] \
        .reshape(B, bp).astype(jnp.int32)
    own_k = jax.random.normal(ks[3], (B, C, KVH, hd))
    own_v = jax.random.normal(jax.random.PRNGKey(seed + 1),
                              (B, C, KVH, hd))
    return (q, k_pool, v_pool, bt, jnp.asarray(starts, jnp.int32),
            jnp.asarray(nvalid, jnp.int32), own_k, own_v)


@pytest.mark.parametrize("window", [None, 9])
def test_paged_prefill_kernel_vs_oracle(window):
    """Chunk boundaries landing mid-page (starts not multiples of the
    page size), ragged validity, first chunk (empty pooled prefix)."""
    B, C, KVH, G, page, bp = 3, 6, 2, 2, 8, 3
    args = _prefill_case(B, C, KVH, G, page, bp, 17,
                         starts=[13, 0, 8], nvalid=[6, 4, 1])
    scale = 1.0 / math.sqrt(32)
    out = paged_attention_prefill(*args, scale=scale, window=window,
                                  interpret=True)
    want = ref.paged_attention_prefill_ref(*args, scale=scale,
                                           window=window)
    np.testing.assert_allclose(np.asarray(out, np.float32),
                               np.asarray(want, np.float32),
                               rtol=2e-5, atol=2e-5)


@settings(max_examples=20, deadline=None)
@given(st.integers(1, 2), st.sampled_from((3, 4, 8)),
       st.sampled_from((1, 2)), st.sampled_from((1, 4)),
       st.integers(1, 3), st.sampled_from((None, 5)),
       st.integers(0, 10 ** 6))
def test_paged_prefill_kernel_vs_oracle_property(B, C, KVH, G, bp, window,
                                                 seed):
    """Kernel == oracle over random chunk starts (mid-page boundaries),
    ragged num_valid (incl. fully-padded rows) and sliding windows."""
    page = 8
    rs = np.random.RandomState(seed % 2 ** 31)
    max_start = page * bp - 1
    starts = rs.randint(0, max_start + 1, B)
    nvalid = rs.randint(0, C + 1, B)
    args = _prefill_case(B, C, KVH, G, page, bp, seed % 2 ** 31,
                         starts=starts, nvalid=nvalid)
    scale = 1.0 / math.sqrt(32)
    out = paged_attention_prefill(*args, scale=scale, window=window,
                                  interpret=True)
    want = ref.paged_attention_prefill_ref(*args, scale=scale,
                                           window=window)
    np.testing.assert_allclose(np.asarray(out, np.float32),
                               np.asarray(want, np.float32),
                               rtol=2e-5, atol=2e-5)


def test_prefill_chunk_layer_kernel_matches_dense():
    """The full chunk-prefill layer (KV scatter + attention + output
    projection) agrees between the kernel and dense paths, at a chunk
    boundary landing mid-page."""
    from repro.configs.registry import serving_config
    from repro.models.init import init_params
    from repro.models.layers import gqa_attention_prefill_chunk

    cfg = serving_config()
    params = init_params(cfg, jax.random.PRNGKey(0))
    lp = jax.tree.map(lambda x: x[0], params["layers"])["attn"]
    B, C, cap, bs = 1, 5, 64, cfg.kv_block_size
    bp = cap // bs
    start = bs + 3  # mid-page boundary
    x = 0.1 * jax.random.normal(jax.random.PRNGKey(2),
                                (B, C, cfg.d_model)).astype(jnp.bfloat16)
    positions = start + jnp.arange(C, dtype=jnp.int32)[None, :]
    valid = (jnp.arange(C)[None, :] < 4)
    pools = {
        name: 0.5 * jax.random.normal(
            jax.random.PRNGKey(k),
            (bp + 1, bs, cfg.num_kv_heads, cfg.head_dim)).astype(
                jnp.bfloat16)
        for name, k in (("k", 3), ("v", 4))}
    bt = jnp.arange(1, bp + 1, dtype=jnp.int32)[None, :]
    outs, kps = {}, {}
    for uk in (False, True):
        out, nk, nv = gqa_attention_prefill_chunk(
            lp, cfg, x, positions, valid, pools["k"], pools["v"], bt,
            cap, use_kernel=uk)
        outs[uk] = np.asarray(out[:, :4], np.float32)  # valid region
        kps[uk] = (np.asarray(nk, np.float32), np.asarray(nv, np.float32))
    np.testing.assert_allclose(outs[True], outs[False],
                               rtol=2e-2, atol=2e-2)
    # the pool scatter is path-independent (same written KV bytes)
    for a, b in zip(kps[True], kps[False]):
        np.testing.assert_array_equal(a, b)


# ---------------------------------------------------------------------------
# ssd scan
# ---------------------------------------------------------------------------

@pytest.mark.parametrize("B,S,H,P,N,chunk,g", [
    (1, 64, 2, 8, 16, 16, 1),
    (2, 128, 6, 16, 32, 32, 3),
    (1, 256, 4, 32, 64, 128, 4),
    (2, 96, 5, 16, 32, 32, 4),   # head_group not dividing H -> fallback
])
def test_ssd_scan(B, S, H, P, N, chunk, g):
    ks = jax.random.split(jax.random.PRNGKey(0), 5)
    x = jax.random.normal(ks[0], (B, S, H, P))
    dt = jax.nn.softplus(jax.random.normal(ks[1], (B, S, H)))
    A = -jnp.exp(jax.random.normal(ks[2], (H,)))
    Bm = jax.random.normal(ks[3], (B, S, N))
    Cm = jax.random.normal(ks[4], (B, S, N))
    y, h = ssd_scan(x, dt, A, Bm, Cm, chunk=chunk, head_group=g,
                    interpret=True)
    yr, hr = ref.ssd_scan_ref(x, dt, A, Bm, Cm)
    np.testing.assert_allclose(np.asarray(y), np.asarray(yr),
                               rtol=2e-3, atol=2e-3)
    np.testing.assert_allclose(np.asarray(h), np.asarray(hr),
                               rtol=2e-3, atol=2e-3)


def test_ssd_scan_matches_layer_path():
    """Kernel output == the jnp chunked implementation used by models."""
    from repro.models.layers import ssd_chunked
    B, S, H, P, N = 1, 128, 4, 16, 32
    ks = jax.random.split(jax.random.PRNGKey(3), 5)
    x = jax.random.normal(ks[0], (B, S, H, P))
    dt = jax.nn.softplus(jax.random.normal(ks[1], (B, S, H)))
    A = -jnp.exp(jax.random.normal(ks[2], (H,)))
    Bm = jax.random.normal(ks[3], (B, S, N))
    Cm = jax.random.normal(ks[4], (B, S, N))
    y_k, h_k = ssd_scan(x, dt, A, Bm, Cm, chunk=32, interpret=True)
    y_j, h_j = ssd_chunked(x, dt, A, Bm, Cm, chunk=32)
    np.testing.assert_allclose(np.asarray(y_k), np.asarray(y_j),
                               rtol=2e-3, atol=2e-3)
    np.testing.assert_allclose(np.asarray(h_k), np.asarray(h_j),
                               rtol=2e-3, atol=2e-3)


# ---------------------------------------------------------------------------
# step scorer kernel
# ---------------------------------------------------------------------------

@pytest.mark.parametrize("B,D", [(1, 64), (8, 256), (130, 512), (64, 2560)])
def test_step_score(B, D):
    ks = jax.random.split(jax.random.PRNGKey(0), 5)
    h = jax.random.normal(ks[0], (B, D))
    w1 = jax.random.normal(ks[1], (D, 512)) * 0.05
    b1 = jax.random.normal(ks[2], (512,)) * 0.1
    w2 = jax.random.normal(ks[3], (512, 1)) * 0.05
    b2 = jax.random.normal(ks[4], (1,)) * 0.1
    out = step_score(h, w1, b1, w2, b2, blk_b=64, interpret=True)
    want = ref.step_score_ref(h, w1, b1, w2, b2)
    np.testing.assert_allclose(np.asarray(out), np.asarray(want),
                               rtol=2e-5, atol=2e-5)


def test_step_score_matches_scorer_module():
    """Kernel == core.scorer.scorer_score (the engine's fused path)."""
    from repro.core.scorer import init_scorer, scorer_score
    p = init_scorer(jax.random.PRNGKey(1), 128)
    h = jax.random.normal(jax.random.PRNGKey(2), (16, 128))
    out = step_score(h, p["w1"], p["b1"], p["w2"], p["b2"], interpret=True)
    want = scorer_score(p, h)
    np.testing.assert_allclose(np.asarray(out), np.asarray(want),
                               rtol=2e-5, atol=2e-5)


# ---------------------------------------------------------------------------
# ops wrappers (CPU => interpret) usable inside the model path
# ---------------------------------------------------------------------------

def test_ops_interpret_on_cpu():
    assert jax.default_backend() == "cpu"
    B, H, S, hd = 1, 1, 128, 64
    ks = jax.random.split(jax.random.PRNGKey(0), 3)
    q, k, v = (jax.random.normal(kk, (B, H, S, hd)) for kk in ks)
    out = ops.flash_attention(q, k, v)
    want = ref.mha_ref(q, k, v)
    np.testing.assert_allclose(np.asarray(out), np.asarray(want),
                               rtol=2e-5, atol=2e-5)
