"""Per-kernel allclose validation vs the pure-jnp oracles, swept over
shapes and dtypes (interpret=True executes the kernel bodies on CPU)."""
import math

import jax
import jax.numpy as jnp
import numpy as np
import pytest

from repro.kernels import ops, ref
from repro.kernels.flash_attention import flash_attention
from repro.kernels.paged_attention import paged_attention
from repro.kernels.ssd_scan import ssd_scan
from repro.kernels.step_score import step_score


def _tol(dtype):
    return dict(rtol=2e-2, atol=2e-2) if dtype == jnp.bfloat16 \
        else dict(rtol=2e-5, atol=2e-5)


# ---------------------------------------------------------------------------
# flash attention
# ---------------------------------------------------------------------------

@pytest.mark.parametrize("dtype", [jnp.float32, jnp.bfloat16])
@pytest.mark.parametrize("B,H,S,hd,blk", [
    (1, 1, 128, 64, 64),
    (2, 3, 256, 64, 64),
    (1, 2, 256, 128, 128),
    (2, 1, 512, 32, 128),
])
def test_flash_attention_causal(B, H, S, hd, blk, dtype):
    ks = jax.random.split(jax.random.PRNGKey(0), 3)
    q, k, v = (jax.random.normal(kk, (B, H, S, hd), dtype) for kk in ks)
    out = flash_attention(q, k, v, blk_q=blk, blk_k=blk, interpret=True)
    want = ref.mha_ref(q.astype(jnp.float32), k.astype(jnp.float32),
                       v.astype(jnp.float32))
    np.testing.assert_allclose(np.asarray(out, np.float32),
                               np.asarray(want, np.float32), **_tol(dtype))


@pytest.mark.parametrize("window", [32, 100, 256])
def test_flash_attention_sliding_window(window):
    B, H, S, hd = 1, 2, 256, 64
    ks = jax.random.split(jax.random.PRNGKey(1), 3)
    q, k, v = (jax.random.normal(kk, (B, H, S, hd)) for kk in ks)
    out = flash_attention(q, k, v, window=window, blk_q=64, blk_k=64,
                          interpret=True)
    want = ref.mha_ref(q, k, v, window=window)
    np.testing.assert_allclose(np.asarray(out), np.asarray(want),
                               rtol=2e-5, atol=2e-5)


def test_flash_attention_noncausal():
    B, H, S, hd = 1, 1, 128, 64
    ks = jax.random.split(jax.random.PRNGKey(2), 3)
    q, k, v = (jax.random.normal(kk, (B, H, S, hd)) for kk in ks)
    out = flash_attention(q, k, v, causal=False, blk_q=64, blk_k=64,
                          interpret=True)
    want = ref.mha_ref(q, k, v, causal=False)
    np.testing.assert_allclose(np.asarray(out), np.asarray(want),
                               rtol=2e-5, atol=2e-5)


# ---------------------------------------------------------------------------
# paged attention
# ---------------------------------------------------------------------------

@pytest.mark.parametrize("dtype", [jnp.float32, jnp.bfloat16])
@pytest.mark.parametrize("B,H,KVH,hd,page,bp", [
    (1, 4, 1, 64, 16, 3),     # MQA (granite-style kv=1)
    (3, 8, 2, 64, 16, 4),     # GQA
    (2, 4, 4, 128, 32, 2),    # MHA
    (2, 16, 8, 64, 64, 5),
])
def test_paged_attention(B, H, KVH, hd, page, bp, dtype):
    NB = B * bp + 2
    ks = jax.random.split(jax.random.PRNGKey(0), 4)
    q = jax.random.normal(ks[0], (B, H, hd), dtype)
    k_pool = jax.random.normal(ks[1], (NB, page, KVH, hd), dtype)
    v_pool = jax.random.normal(ks[2], (NB, page, KVH, hd), dtype)
    bt = jax.random.permutation(ks[3], NB)[:B * bp] \
        .reshape(B, bp).astype(jnp.int32)
    lens = jnp.asarray(
        np.random.RandomState(0).randint(1, page * bp + 1, B), jnp.int32)
    scale = 1.0 / math.sqrt(hd)
    out = paged_attention(q, k_pool, v_pool, bt, lens, scale=scale,
                          interpret=True)
    want = ref.paged_attention_ref(
        q.astype(jnp.float32), k_pool.astype(jnp.float32),
        v_pool.astype(jnp.float32), bt, lens, scale=scale)
    np.testing.assert_allclose(np.asarray(out, np.float32),
                               np.asarray(want, np.float32), **_tol(dtype))


def test_paged_attention_single_token_cache():
    """cache_len=1 edge: only one valid slot."""
    B, H, KVH, hd, page, bp = 1, 2, 1, 32, 16, 2
    ks = jax.random.split(jax.random.PRNGKey(7), 3)
    q = jax.random.normal(ks[0], (B, H, hd))
    k_pool = jax.random.normal(ks[1], (4, page, KVH, hd))
    v_pool = jax.random.normal(ks[2], (4, page, KVH, hd))
    bt = jnp.array([[1, 2]], jnp.int32)
    lens = jnp.array([1], jnp.int32)
    out = paged_attention(q, k_pool, v_pool, bt, lens, scale=0.2,
                          interpret=True)
    want = ref.paged_attention_ref(q, k_pool, v_pool, bt, lens, scale=0.2)
    np.testing.assert_allclose(np.asarray(out), np.asarray(want),
                               rtol=2e-5, atol=2e-5)


# ---------------------------------------------------------------------------
# ssd scan
# ---------------------------------------------------------------------------

@pytest.mark.parametrize("B,S,H,P,N,chunk,g", [
    (1, 64, 2, 8, 16, 16, 1),
    (2, 128, 6, 16, 32, 32, 3),
    (1, 256, 4, 32, 64, 128, 4),
    (2, 96, 5, 16, 32, 32, 4),   # head_group not dividing H -> fallback
])
def test_ssd_scan(B, S, H, P, N, chunk, g):
    ks = jax.random.split(jax.random.PRNGKey(0), 5)
    x = jax.random.normal(ks[0], (B, S, H, P))
    dt = jax.nn.softplus(jax.random.normal(ks[1], (B, S, H)))
    A = -jnp.exp(jax.random.normal(ks[2], (H,)))
    Bm = jax.random.normal(ks[3], (B, S, N))
    Cm = jax.random.normal(ks[4], (B, S, N))
    y, h = ssd_scan(x, dt, A, Bm, Cm, chunk=chunk, head_group=g,
                    interpret=True)
    yr, hr = ref.ssd_scan_ref(x, dt, A, Bm, Cm)
    np.testing.assert_allclose(np.asarray(y), np.asarray(yr),
                               rtol=2e-3, atol=2e-3)
    np.testing.assert_allclose(np.asarray(h), np.asarray(hr),
                               rtol=2e-3, atol=2e-3)


def test_ssd_scan_matches_layer_path():
    """Kernel output == the jnp chunked implementation used by models."""
    from repro.models.layers import ssd_chunked
    B, S, H, P, N = 1, 128, 4, 16, 32
    ks = jax.random.split(jax.random.PRNGKey(3), 5)
    x = jax.random.normal(ks[0], (B, S, H, P))
    dt = jax.nn.softplus(jax.random.normal(ks[1], (B, S, H)))
    A = -jnp.exp(jax.random.normal(ks[2], (H,)))
    Bm = jax.random.normal(ks[3], (B, S, N))
    Cm = jax.random.normal(ks[4], (B, S, N))
    y_k, h_k = ssd_scan(x, dt, A, Bm, Cm, chunk=32, interpret=True)
    y_j, h_j = ssd_chunked(x, dt, A, Bm, Cm, chunk=32)
    np.testing.assert_allclose(np.asarray(y_k), np.asarray(y_j),
                               rtol=2e-3, atol=2e-3)
    np.testing.assert_allclose(np.asarray(h_k), np.asarray(h_j),
                               rtol=2e-3, atol=2e-3)


# ---------------------------------------------------------------------------
# step scorer kernel
# ---------------------------------------------------------------------------

@pytest.mark.parametrize("B,D", [(1, 64), (8, 256), (130, 512), (64, 2560)])
def test_step_score(B, D):
    ks = jax.random.split(jax.random.PRNGKey(0), 5)
    h = jax.random.normal(ks[0], (B, D))
    w1 = jax.random.normal(ks[1], (D, 512)) * 0.05
    b1 = jax.random.normal(ks[2], (512,)) * 0.1
    w2 = jax.random.normal(ks[3], (512, 1)) * 0.05
    b2 = jax.random.normal(ks[4], (1,)) * 0.1
    out = step_score(h, w1, b1, w2, b2, blk_b=64, interpret=True)
    want = ref.step_score_ref(h, w1, b1, w2, b2)
    np.testing.assert_allclose(np.asarray(out), np.asarray(want),
                               rtol=2e-5, atol=2e-5)


def test_step_score_matches_scorer_module():
    """Kernel == core.scorer.scorer_score (the engine's fused path)."""
    from repro.core.scorer import init_scorer, scorer_score
    p = init_scorer(jax.random.PRNGKey(1), 128)
    h = jax.random.normal(jax.random.PRNGKey(2), (16, 128))
    out = step_score(h, p["w1"], p["b1"], p["w2"], p["b2"], interpret=True)
    want = scorer_score(p, h)
    np.testing.assert_allclose(np.asarray(out), np.asarray(want),
                               rtol=2e-5, atol=2e-5)


# ---------------------------------------------------------------------------
# ops wrappers (CPU => interpret) usable inside the model path
# ---------------------------------------------------------------------------

def test_ops_interpret_on_cpu():
    assert jax.default_backend() == "cpu"
    B, H, S, hd = 1, 1, 128, 64
    ks = jax.random.split(jax.random.PRNGKey(0), 3)
    q, k, v = (jax.random.normal(kk, (B, H, S, hd)) for kk in ks)
    out = ops.flash_attention(q, k, v)
    want = ref.mha_ref(q, k, v)
    np.testing.assert_allclose(np.asarray(out), np.asarray(want),
                               rtol=2e-5, atol=2e-5)
