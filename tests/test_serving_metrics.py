"""Serving-metrics unit tests: TTFT/TPOT/e2e derivation, percentile
interpolation, and the BENCH_serving.json summary payload."""
import pytest

from repro.serving.metrics import RequestMetrics, percentiles, summarize


def _m(rid=0, arrival=0.0, first=1.0, finish=3.0, out_tokens=21, **kw):
    return RequestMetrics(
        request_id=rid, arrival_s=arrival, admitted_s=arrival + 0.1,
        first_token_s=first, finished_s=finish, prompt_tokens=7,
        output_tokens=out_tokens, n_traces=3, **kw)


def test_derived_latencies():
    m = _m(arrival=0.5, first=1.5, finish=3.5, out_tokens=21)
    assert m.ttft_s == pytest.approx(1.0)
    assert m.e2e_s == pytest.approx(3.0)
    assert m.tpot_s == pytest.approx(2.0 / 20)  # finish-first over n-1


def test_unfinished_request_has_none_latencies():
    m = RequestMetrics(request_id=1, arrival_s=0.0, admitted_s=None,
                       first_token_s=None, finished_s=None)
    assert m.ttft_s is None and m.tpot_s is None and m.e2e_s is None
    # empty aggregates surface as None (JSON null), never NaN — NaN
    # compares unequal to itself and would slip through regression diffs
    assert summarize([m])["mean_ttft_s"] is None


def test_single_token_tpot_does_not_divide_by_zero():
    m = _m(out_tokens=1)
    assert m.tpot_s == pytest.approx(2.0)  # denominator floored at 1


def test_percentiles_interpolate():
    xs = [1.0, 2.0, 3.0, 4.0]
    p = percentiles(xs, ps=(50, 90, 99, 100))
    assert p["p50"] == pytest.approx(2.5)
    assert p["p100"] == pytest.approx(4.0)
    assert p["p90"] == pytest.approx(3.7)
    assert percentiles([5.0])["p99"] == 5.0
    assert percentiles([])["p50"] is None


def test_summarize_payload():
    ms = [_m(rid=0, arrival=0.0, first=0.5, finish=2.0, out_tokens=10),
          _m(rid=1, arrival=1.0, first=1.5, finish=4.0, out_tokens=30,
             num_pruned=2, wait_s=0.25)]
    s = summarize(ms)
    assert s["num_requests"] == 2 and s["num_completed"] == 2
    assert s["total_output_tokens"] == 40
    assert s["makespan_s"] == pytest.approx(4.0)
    assert s["throughput_tok_per_s"] == pytest.approx(10.0)
    assert s["ttft_s"]["p50"] == pytest.approx(0.5)
    assert s["e2e_s"]["p99"] == pytest.approx(
        2.0 + 0.99 * 1.0)  # interpolated between 2.0 and 3.0
    assert s["num_pruned"] == 2
    assert s["total_wait_s"] == pytest.approx(0.25)
    assert s["mean_ttft_s"] == pytest.approx(0.5)


def test_to_dict_round_trip():
    d = _m().to_dict()
    assert d["ttft_s"] == pytest.approx(1.0)
    assert d["output_tokens"] == 21
    assert d["request_id"] == 0
