"""Sharded serving: the Engine over a (data, model) device mesh.

Pins the tentpole equivalence: a 4-device ``(data=2, model=2)`` mesh run
of ``serve_batch`` is token-identical AND step-score-identical to the
single-device engine under a fixed RNG — including COW forks, chunked
prefill, ``decode_horizon>1``, tight-pool pruning, and multi-request
batches. The engine-level tests need 4 devices and run under the
``test-multidevice`` CI lane
(``XLA_FLAGS=--xla_force_host_platform_device_count=4``); the mesh
factory and sharding-rule tests are pure and run everywhere.

Exactness rests on two properties the engine arranges (see
docs/ENGINE.md "Sharded serving"):

  * ``serving_param_specs``: only column-parallel weights shard over
    "model", so no contraction ever crosses a shard boundary — every
    collective is an all-gather, never a float reduction;
  * partitionable threefry (flipped on by mesh engines), whose random
    bits are invariant to how the sampled-over array is sharded.
"""
import dataclasses

import jax
import numpy as np
import pytest
from hypothesis import given, settings
from hypothesis import strategies as st
from jax.sharding import AbstractMesh, PartitionSpec as P

from repro.configs.registry import serving_config
from repro.core.pruning import make_policy
from repro.core.scorer import init_scorer
from repro.data.tokenizer import get_tokenizer
from repro.launch import shardings as shd
from repro.launch.mesh import make_host_mesh, resolve_host_mesh_shape
from repro.models.init import init_params
from repro.serving import Engine, EngineConfig, Request, SamplingParams

needs4 = pytest.mark.skipif(
    jax.device_count() < 4,
    reason="needs XLA_FLAGS=--xla_force_host_platform_device_count=4")

MAX_NEW = 24
BATCH = 8


# ---------------------------------------------------------------------------
# mesh factory (pure / single-device)
# ---------------------------------------------------------------------------

def test_resolve_host_mesh_shape_adapts():
    assert resolve_host_mesh_shape(device_count=4) == (4, 1)
    assert resolve_host_mesh_shape(2, None, device_count=4) == (2, 2)
    assert resolve_host_mesh_shape(None, 2, device_count=4) == (2, 2)
    assert resolve_host_mesh_shape(1, 1, device_count=1) == (1, 1)
    assert resolve_host_mesh_shape(device_count=1) == (1, 1)


def test_resolve_host_mesh_shape_validates():
    with pytest.raises(ValueError, match="does not divide"):
        resolve_host_mesh_shape(3, None, device_count=4)
    with pytest.raises(ValueError, match="device"):
        resolve_host_mesh_shape(2, 4, device_count=4)
    with pytest.raises(ValueError, match=">= 1"):
        resolve_host_mesh_shape(0, 2, device_count=4)


def test_make_host_mesh_matches_device_count():
    mesh = make_host_mesh()
    assert mesh.axis_names == ("data", "model")
    assert mesh.shape["data"] * mesh.shape["model"] == jax.device_count()


# ---------------------------------------------------------------------------
# serving sharding rules (AbstractMesh / single-device)
# ---------------------------------------------------------------------------

def _abstract_mesh(sizes, names):
    import inspect
    params = inspect.signature(AbstractMesh.__init__).parameters
    if "shape_tuple" in params:
        return AbstractMesh(tuple(zip(names, sizes)))
    return AbstractMesh(tuple(sizes), tuple(names))


def _param_shapes(cfg):
    return jax.eval_shape(lambda: init_params(cfg, jax.random.PRNGKey(0)))


def test_serving_param_specs_exactness_layout():
    """Column-parallel weights shard over model; everything touched by a
    contraction or a norm reduction stays replicated."""
    cfg = serving_config()
    mesh = _abstract_mesh((2, 2), ("data", "model"))
    specs = shd.serving_param_specs(cfg, mesh, _param_shapes(cfg))
    lyr = specs["layers"]
    assert lyr["attn"]["wq"][-1] == "model"
    assert lyr["mlp"]["w_gate"][-1] == "model"
    # row-parallel set replicated: a sharded contraction would psum
    assert all(e is None for e in lyr["attn"]["wo"])
    assert all(e is None for e in lyr["mlp"]["w_down"])
    # stacked per-layer norm scales [L, D] must NOT fall into the
    # generic 2-D shard-last-dim rule (a D-sharded norm weight makes
    # every following QKV/MLP contraction a partial-sum)
    assert all(e is None for e in lyr["ln1"])
    assert all(e is None for e in lyr["ln2"])
    assert all(e is None for e in specs["final_norm"])


def test_serving_cache_specs_paged_pool_layout():
    cfg = serving_config()  # num_kv_heads=2: divides model=2
    mesh = _abstract_mesh((2, 2), ("data", "model"))
    specs = shd.serving_cache_specs(cfg, mesh)
    assert specs["k_pool"] == P(None, None, None, "model", None)
    assert specs["v_pool"] == P(None, None, None, "model", None)
    # heads that don't divide the model axis: replicate, never shard hd
    mesh16 = _abstract_mesh((2, 16), ("data", "model"))
    specs = shd.serving_cache_specs(cfg, mesh16)
    assert specs["k_pool"] == P(None, None, None, None, None)


def test_serving_step_shardings_cover_cache():
    cfg = serving_config()
    mesh = make_host_mesh()  # whatever this session has
    ss = shd.serving_step_shardings(cfg, mesh)
    assert set(ss["pools"]) == {"k_pool", "v_pool"}
    assert set(ss["layer_pool"]) == {"k_pool", "v_pool"}
    for key in ("lane", "table", "hidden", "act", "prefill_act",
                "replicated"):
        assert key in ss


# ---------------------------------------------------------------------------
# engine over a mesh (4 simulated devices)
# ---------------------------------------------------------------------------

_STATE: dict = {}


def _setup():
    if "cfg" not in _STATE:
        # both engines of every comparison must sample from the same
        # threefry implementation; mesh engines flip this flag anyway,
        # flip it eagerly so engine build order can't matter
        jax.config.update("jax_threefry_partitionable", True)
        cfg = serving_config()
        _STATE["cfg"] = cfg
        _STATE["params"] = init_params(cfg, jax.random.PRNGKey(0))
        _STATE["scorer"] = init_scorer(jax.random.PRNGKey(1), cfg.d_model)
        tok = get_tokenizer()
        _STATE["tok"] = tok
        _STATE["prompts"] = [tok.encode(p, add_bos=True)
                             for p in ("3+5-2=", "7*2+1=", "9-4+6=")]
    return (_STATE["cfg"], _STATE["params"], _STATE["scorer"],
            _STATE["tok"], _STATE["prompts"])


def _ecfg(K=1, temperature=0.8, num_blocks=64, chunk=None,
          max_new=MAX_NEW):
    return EngineConfig(
        max_batch=BATCH, num_blocks=num_blocks, capacity=128,
        max_new_tokens=max_new,
        sampling=SamplingParams(temperature=temperature,
                                top_k=0 if temperature == 0.0 else 20,
                                top_p=1.0 if temperature == 0.0 else 0.95,
                                max_new_tokens=max_new),
        prefill_chunk_size=chunk,
        decode_horizon=K)


def _engine_pair(key):
    """(single-device, mesh) engines compiled once per config, reused
    across property examples (the per-example reset is the RNG key)."""
    cfg, params, scorer, _, _ = _setup()
    pairs = _STATE.setdefault("pairs", {})
    if key not in pairs:
        K, temp, blocks, chunk, mesh_shape = key
        ecfg = _ecfg(K, temp, blocks, chunk)
        single = Engine(params, cfg, ecfg, make_policy("step"),
                        scorer_params=scorer)
        mesh = make_host_mesh(*mesh_shape)
        sharded = Engine(params, cfg, ecfg, make_policy("step"),
                         scorer_params=scorer, mesh=mesh)
        pairs[key] = (single, sharded)
    return pairs[key]


def _serve(eng, requests, rng_seed):
    eng._rng = jax.random.PRNGKey(rng_seed)
    results = eng.serve_batch(
        [Request(request_id=r.request_id,
                 prompt_tokens=list(r.prompt_tokens),
                 n_traces=r.n_traces, policy=make_policy("step"))
         for r in requests])
    assert eng.pool_drained()
    eng.block_mgr.check_invariants()
    return results


def _assert_identical(res_a, res_b):
    for a, b in zip(res_a, res_b):
        assert [t.output_tokens for t in a.traces] \
            == [t.output_tokens for t in b.traces]
        # scores are float32 sigmoids of bit-identical hidden states:
        # exact equality is the claim, not a tolerance
        assert [t.step_scores for t in a.traces] \
            == [t.step_scores for t in b.traces]
        assert [t.token_confidences for t in a.traces] \
            == [t.token_confidences for t in b.traces]
        assert [t.status for t in a.traces] == [t.status for t in b.traces]
        assert a.num_pruned == b.num_pruned
        assert a.answer == b.answer


@needs4
@settings(max_examples=6, deadline=None)
@given(st.sampled_from((1, 4)), st.sampled_from((None, 8)),
       st.integers(0, 2), st.integers(2, 6), st.booleans(),
       st.integers(0, 10 ** 6))
def test_mesh_token_identical(K, chunk, prompt_idx, n_traces, greedy,
                              rng_seed):
    """(data=2, model=2) serve_batch == single-device serve_batch:
    same tokens, same step scores, same confidences, same statuses —
    across decode horizons, chunked prefill, and sampling modes (the
    shared-prefix default means every example exercises COW forks)."""
    _, _, _, _, prompts = _setup()
    temp = 0.0 if greedy else 0.8
    single, sharded = _engine_pair((K, temp, 64, chunk, (2, 2)))
    reqs = [Request(request_id=0, prompt_tokens=prompts[prompt_idx],
                    n_traces=n_traces)]
    _assert_identical(_serve(single, reqs, rng_seed),
                      _serve(sharded, reqs, rng_seed))


@needs4
@pytest.mark.parametrize("mesh_shape", [(4, 1), (1, 4)])
def test_mesh_axis_extremes(mesh_shape):
    """Pure data-parallel (4,1) and pure tensor-parallel (1,4) meshes
    are also token-identical (kv heads don't divide model=4: the pool
    replicates, params still shard where divisible)."""
    _, _, _, _, prompts = _setup()
    single, sharded = _engine_pair((1, 0.8, 64, None, mesh_shape))
    reqs = [Request(request_id=0, prompt_tokens=prompts[0], n_traces=4)]
    _assert_identical(_serve(single, reqs, 123),
                      _serve(sharded, reqs, 123))


@needs4
def test_mesh_tight_pool_pruning_identical():
    """Memory pressure: COW forks + STEP pruning decisions land on the
    same traces at the same ticks on the mesh."""
    _, _, _, _, prompts = _setup()
    single, sharded = _engine_pair((1, 0.8, 12, None, (2, 2)))
    reqs = [Request(request_id=0, prompt_tokens=prompts[1], n_traces=6)]
    res_a = _serve(single, reqs, 77)
    res_b = _serve(sharded, reqs, 77)
    _assert_identical(res_a, res_b)


@needs4
def test_mesh_chunked_prefill_identical():
    """Chunked prompt prefill (reservation take/commit, paged chunk
    attention) composes with the mesh."""
    _, _, _, tok, _ = _setup()
    long_prompt = tok.encode("1+2-3+4-5+6-7+8=", add_bos=True)
    single, sharded = _engine_pair((1, 0.8, 64, 8, (2, 2)))
    reqs = [Request(request_id=0, prompt_tokens=long_prompt, n_traces=3)]
    _assert_identical(_serve(single, reqs, 5), _serve(sharded, reqs, 5))


@needs4
def test_mesh_multi_request_horizon_identical():
    """Cross-request contention + fused decode horizon on the mesh."""
    _, _, _, _, prompts = _setup()
    single, sharded = _engine_pair((4, 0.8, 64, None, (2, 2)))
    reqs = [Request(request_id=0, prompt_tokens=prompts[0], n_traces=3),
            Request(request_id=1, prompt_tokens=prompts[2], n_traces=3)]
    _assert_identical(_serve(single, reqs, 42), _serve(sharded, reqs, 42))


@needs4
def test_mesh_rejects_indivisible_batch():
    cfg, params, scorer, _, _ = _setup()
    mesh = make_host_mesh(4, 1)
    ecfg = dataclasses.replace(_ecfg(), max_batch=6)  # 6 % 4 != 0
    with pytest.raises(ValueError, match="max_batch"):
        Engine(params, cfg, ecfg, make_policy("step"),
               scorer_params=scorer, mesh=mesh)


@needs4
def test_mesh_rejects_uncovered_archs():
    """The bit-identity contract is enforced, not assumed: archs whose
    reductions the exactness layout doesn't constrain are refused."""
    cfg, params, scorer, _, _ = _setup()
    mesh = make_host_mesh(2, 2)
    # pin a float pool: under REPRO_KV_DTYPE=int8 (the kv-quant CI
    # lane) resolve_kv_dtype rejects these archs first with its own
    # NotImplementedError — this test asserts the MESH rejection
    # message; the quantized gating has its own pin in test_kv_quant.py
    ecfg = dataclasses.replace(_ecfg(), kv_dtype="bf16")
    ssm_cfg = dataclasses.replace(cfg, arch_type="ssm")
    with pytest.raises(NotImplementedError, match="paged-attention"):
        Engine(params, ssm_cfg, ecfg, make_policy("step"),
               scorer_params=scorer, mesh=mesh)
    mla_cfg = dataclasses.replace(cfg, use_mla=True)
    with pytest.raises(NotImplementedError, match="MLA/MoE"):
        Engine(params, mla_cfg, ecfg, make_policy("step"),
               scorer_params=scorer, mesh=mesh)


@needs4
def test_mesh_use_kernel_validated_at_construction():
    """Engine(mesh=..., use_kernel=True) is never silently unvalidated:
    layouts the shard_map kernel path doesn't cover raise a clear
    NotImplementedError at construction, and "auto" falls back to the
    dense path instead."""
    cfg, params, scorer, _, _ = _setup()
    # (1, 4): num_kv_heads=2 doesn't divide model=4 -> uncovered
    mesh = make_host_mesh(1, 4)
    with pytest.raises(NotImplementedError, match="shard_map"):
        Engine(params, cfg, dataclasses.replace(_ecfg(), use_kernel=True),
               make_policy("step"), scorer_params=scorer, mesh=mesh)
    eng = Engine(params, cfg,
                 dataclasses.replace(_ecfg(), use_kernel="auto"),
                 make_policy("step"), scorer_params=scorer, mesh=mesh)
    assert eng.use_kernel is False  # auto: dense fallback, same tokens


@needs4
def test_mesh_use_kernel_token_identical():
    """The covered layout (heads divide "model") routes the paged
    kernels through shard_map: lanes on "data", pool KV heads computed
    shard-locally on "model". Grid cells are independent, so the mesh
    kernel engine is token- and score-identical to the single-device
    kernel engine."""
    cfg, params, scorer, _, prompts = _setup()
    ecfg = dataclasses.replace(_ecfg(K=2, max_new=16), use_kernel=True)
    single = Engine(params, cfg, ecfg, make_policy("step"),
                    scorer_params=scorer)
    sharded = Engine(params, cfg, ecfg, make_policy("step"),
                     scorer_params=scorer, mesh=make_host_mesh(2, 2))
    assert sharded.use_kernel is True
    reqs = [Request(request_id=0, prompt_tokens=prompts[0], n_traces=4)]
    _assert_identical(_serve(single, reqs, 99), _serve(sharded, reqs, 99))


@needs4
def test_mesh_use_kernel_chunked_prefill_identical():
    """Chunked prefill through the multi-query kernel (batch-1 chunk
    jobs: "model"-sharded heads, data-replicated tiles) composes with
    the mesh and stays identical to the single-device kernel engine."""
    cfg, params, scorer, tok, _ = _setup()
    long_prompt = tok.encode("1+2-3+4-5+6-7+8=", add_bos=True)
    ecfg = dataclasses.replace(_ecfg(chunk=8, max_new=16),
                               use_kernel=True)
    single = Engine(params, cfg, ecfg, make_policy("step"),
                    scorer_params=scorer)
    sharded = Engine(params, cfg, ecfg, make_policy("step"),
                     scorer_params=scorer, mesh=make_host_mesh(2, 2))
    reqs = [Request(request_id=0, prompt_tokens=long_prompt, n_traces=3)]
    _assert_identical(_serve(single, reqs, 5), _serve(sharded, reqs, 5))


@needs4
def test_mesh_params_actually_sharded():
    """The mesh engine's params really live distributed: a wq shard on
    one device holds 1/model of the columns."""
    _, _, _, _, prompts = _setup()
    _, sharded = _engine_pair((1, 0.0, 64, None, (2, 2)))
    wq = sharded.params["layers"]["attn"]["wq"]
    assert len(wq.sharding.device_set) == 4
    shard = wq.addressable_shards[0]
    assert shard.data.shape[-1] == wq.shape[-1] // 2  # model=2
    np.testing.assert_array_equal(
        np.asarray(shard.data, np.float32),
        np.asarray(wq[..., :wq.shape[-1] // 2], np.float32))
