"""serve_decode_step (distributed contiguous-cache path) must match
forward_full exactly like the engine's paged decode_step does, and the
chunked attention / grouped MoE paths must match their naive versions."""
import jax
import jax.numpy as jnp
import numpy as np
import pytest

from repro.configs.registry import ALL_ARCHS, get_config
from repro.models import layers as L
from repro.models.init import init_params
from repro.models.model import (build_cross_cache, encode, forward_full,
                                serve_decode_step)

S = 33
B = 2
CAP = 64


def _setup(arch):
    cfg = get_config(arch, smoke=True)
    rng = jax.random.PRNGKey(42)
    params = init_params(cfg, rng)
    tokens = jax.random.randint(rng, (B, S + 2), 0, cfg.vocab_size)
    kw = {}
    if cfg.modality == "vision":
        kw["modality_embeds"] = 0.02 * jax.random.normal(
            rng, (B, cfg.num_modality_tokens, cfg.d_model)).astype(jnp.bfloat16)
    if cfg.is_encoder_decoder:
        kw["encoder_embeds"] = 0.02 * jax.random.normal(
            rng, (B, cfg.encoder_seq_len, cfg.d_model)).astype(jnp.bfloat16)
    return cfg, params, tokens, kw


def _init_contiguous_cache(cfg, batch, cap):
    attn = cfg.attention_layer_ids()
    dt = jnp.bfloat16
    cache = {}
    if attn:
        la = len(attn)
        if cfg.use_mla:
            cache["kv_cache"] = jnp.zeros(
                (la, batch, cap, cfg.kv_lora_rank + cfg.qk_rope_head_dim), dt)
        else:
            cache["k_cache"] = jnp.zeros(
                (la, batch, cap, cfg.num_kv_heads, cfg.head_dim), dt)
            cache["v_cache"] = jnp.zeros(
                (la, batch, cap, cfg.num_kv_heads, cfg.head_dim), dt)
    if cfg.arch_type in ("ssm", "hybrid"):
        cache["ssm_state"] = jnp.zeros(
            (cfg.num_layers, batch, cfg.ssm_heads, cfg.ssm_head_dim,
             cfg.ssm_state_size), jnp.float32)
        cache["conv_state"] = jnp.zeros(
            (cfg.num_layers, batch, cfg.ssm_conv_width - 1,
             cfg.d_inner + 2 * cfg.ssm_state_size), dt)
    return cache


def _write_prefill_contiguous(cfg, cache, kvs, seq_len):
    cache = dict(cache)

    def put(tree_k, k):
        # k [L*, B, S, KVH, hd] -> cache [L*, B, cap, KVH, hd]
        return tree_k.at[:, :, :k.shape[2]].set(k)

    if cfg.arch_type == "ssm":
        ss, cs = kvs
        cache["ssm_state"], cache["conv_state"] = ss, cs
    elif cfg.arch_type == "hybrid":
        (ss, cs), (k, v) = kvs
        cache["ssm_state"] = ss.reshape(-1, *ss.shape[2:])
        cache["conv_state"] = cs.reshape(-1, *cs.shape[2:])
        cache["k_cache"] = put(cache["k_cache"], k)
        cache["v_cache"] = put(cache["v_cache"], v)
    elif cfg.use_mla:
        cache["kv_cache"] = cache["kv_cache"].at[:, :, :kvs.shape[2]].set(kvs)
    else:
        k, v = kvs
        cache["k_cache"] = put(cache["k_cache"], k)
        cache["v_cache"] = put(cache["v_cache"], v)
    return cache


@pytest.mark.parametrize("arch", ALL_ARCHS)
def test_serve_decode_matches_full_forward(arch):
    cfg, params, tokens, kw = _setup(arch)
    ref = forward_full(params, cfg, tokens[:, :S + 1], **kw)
    ref_logits = np.asarray(ref["logits"][:, S].astype(jnp.float32))

    out = forward_full(params, cfg, tokens[:, :S], return_kv=True, **kw)
    cache = _init_contiguous_cache(cfg, B, CAP)
    cache = _write_prefill_contiguous(cfg, cache, out["kvs"], S)
    if cfg.is_encoder_decoder:
        enc_out = encode(params, cfg, kw["encoder_embeds"])
        cache["cross_k"], cache["cross_v"] = build_cross_cache(
            params, cfg, enc_out)

    step = serve_decode_step(params, cfg, tokens[:, S:S + 1],
                             jnp.full((B,), S, jnp.int32), cache)
    got = np.asarray(step["logits"].astype(jnp.float32))
    np.testing.assert_allclose(got, ref_logits, rtol=0.08, atol=0.08)
    assert np.all(np.isfinite(got))


# ---------------------------------------------------------------------------
# chunked attention == naive attention at the switch boundary
# ---------------------------------------------------------------------------

def test_chunked_mha_matches_naive():
    B_, H, S_, hd = 2, 4, 256, 64
    ks = jax.random.split(jax.random.PRNGKey(0), 3)
    q, k, v = (jax.random.normal(kk, (B_, H, S_, hd)) for kk in ks)
    from repro.kernels.ref import mha_ref
    got = L.chunked_mha(q * hd ** -0.5, k, v, chunk=64)
    want = mha_ref(q, k, v)
    np.testing.assert_allclose(np.asarray(got), np.asarray(want),
                               rtol=2e-5, atol=2e-5)


def test_chunked_mha_window():
    B_, H, S_, hd = 1, 2, 256, 32
    ks = jax.random.split(jax.random.PRNGKey(1), 3)
    q, k, v = (jax.random.normal(kk, (B_, H, S_, hd)) for kk in ks)
    from repro.kernels.ref import mha_ref
    got = L.chunked_mha(q * hd ** -0.5, k, v, chunk=64, window=100)
    want = mha_ref(q, k, v, window=100)
    np.testing.assert_allclose(np.asarray(got), np.asarray(want),
                               rtol=2e-5, atol=2e-5)


@pytest.mark.parametrize("arch", ["qwen3-1.7b", "deepseek-v2-236b"])
def test_long_forward_uses_chunked_path(arch):
    """S > threshold forward (chunked) == short-stitched reference by
    running the same weights at S=128 naive vs chunked_mha directly."""
    cfg = get_config(arch, smoke=True)
    params = init_params(cfg, jax.random.PRNGKey(0))
    toks = jax.random.randint(jax.random.PRNGKey(1), (1, 1536), 0,
                              cfg.vocab_size)
    out = forward_full(params, cfg, toks)  # S=1536 > 1024 -> chunked
    assert np.all(np.isfinite(np.asarray(out["logits"], np.float32)))


def test_remat_forward_matches():
    cfg = get_config("qwen3-1.7b", smoke=True)
    params = init_params(cfg, jax.random.PRNGKey(0))
    toks = jax.random.randint(jax.random.PRNGKey(1), (2, 64), 0,
                              cfg.vocab_size)
    a = forward_full(params, cfg, toks, remat=False)["logits"]
    b = forward_full(params, cfg, toks, remat=True)["logits"]
    np.testing.assert_allclose(np.asarray(a, np.float32),
                               np.asarray(b, np.float32), rtol=1e-5, atol=1e-5)


# ---------------------------------------------------------------------------
# grouped MoE dispatch == ungrouped when capacity is no-drop
# ---------------------------------------------------------------------------

def test_moe_group_invariance():
    cfg = get_config("mixtral-8x7b", smoke=True)
    params = init_params(cfg, jax.random.PRNGKey(0))
    lp = jax.tree.map(lambda x: x[0], params["layers"])  # layer 0
    x = jax.random.normal(jax.random.PRNGKey(2), (2, 64, cfg.d_model)
                          ).astype(jnp.bfloat16)
    # no-drop capacity: grouping must not change the result
    out1, _ = L.moe_layer(lp["moe"], cfg, x, capacity_factor=8.0)
    x2 = x.reshape(1, 128, cfg.d_model)  # different T -> different grouping
    out2, _ = L.moe_layer(lp["moe"], cfg, x2, capacity_factor=8.0)
    np.testing.assert_allclose(
        np.asarray(out1.reshape(-1, cfg.d_model), np.float32),
        np.asarray(out2.reshape(-1, cfg.d_model), np.float32),
        rtol=2e-2, atol=2e-2)
