"""Per-architecture smoke tests (deliverable f).

For each assigned architecture: instantiate the REDUCED same-family variant
(<=2 layers, d_model<=512, <=4 experts) and run one forward + one train
step on CPU, asserting output shapes and no NaNs.
"""
import jax
import jax.numpy as jnp
import numpy as np
import pytest

from repro.configs.registry import ALL_ARCHS, ASSIGNED_ARCHS, get_config
from repro.models.init import init_params, padded_vocab, count_params
from repro.models.model import forward_full, lm_loss
from repro.training.optimizer import AdamW

B, S = 2, 64


def _inputs(cfg, rng):
    tokens = jax.random.randint(rng, (B, S), 0, cfg.vocab_size)
    kw = {}
    if cfg.modality == "vision":
        kw["modality_embeds"] = 0.02 * jax.random.normal(
            rng, (B, cfg.num_modality_tokens, cfg.d_model)
        ).astype(jnp.bfloat16)
    if cfg.is_encoder_decoder:
        kw["encoder_embeds"] = 0.02 * jax.random.normal(
            rng, (B, cfg.encoder_seq_len, cfg.d_model)
        ).astype(jnp.bfloat16)
    return tokens, kw


@pytest.mark.parametrize("arch", ALL_ARCHS)
def test_smoke_config_limits(arch):
    cfg = get_config(arch, smoke=True)
    assert cfg.num_layers <= 2 or (
        cfg.arch_type in ("ssm", "hybrid") and cfg.num_layers <= 4
    ), f"{arch}: smoke num_layers={cfg.num_layers}"
    assert cfg.d_model <= 512
    assert cfg.num_experts <= 4


@pytest.mark.parametrize("arch", ALL_ARCHS)
def test_full_config_matches_assignment(arch):
    cfg = get_config(arch, smoke=False)
    expected = {
        "granite-20b": (52, 6144, 48, 1, 24576, 49152),
        "internvl2-2b": (24, 2048, 16, 8, 8192, 92553),
        "qwen3-1.7b": (28, 2048, 16, 8, 6144, 151936),
        "mixtral-8x7b": (32, 4096, 32, 8, 14336, 32000),
        "zamba2-2.7b": (54, 2560, 32, 32, 10240, 32000),
        "deepseek-v2-236b": (60, 5120, 128, 128, 1536, 102400),
        "phi4-mini-3.8b": (32, 3072, 24, 8, 8192, 200064),
        "mamba2-2.7b": (64, 2560, 0, 0, 0, 50280),
        "starcoder2-3b": (30, 3072, 24, 2, 12288, 49152),
        "seamless-m4t-large-v2": (24, 1024, 16, 16, 8192, 256206),
    }
    if arch not in expected:
        pytest.skip("paper-model config, not an assigned arch")
    L, D, H, KVH, FF, V = expected[arch]
    assert cfg.num_layers == L
    assert cfg.d_model == D
    assert cfg.num_heads == H
    assert cfg.num_kv_heads == KVH
    if arch == "deepseek-v2-236b":
        assert cfg.moe_d_ff == FF
        assert cfg.num_experts == 160 and cfg.num_experts_per_tok == 6
        assert cfg.kv_lora_rank == 512 and cfg.num_shared_experts == 2
    elif arch == "mixtral-8x7b":
        assert cfg.moe_d_ff == FF
        assert cfg.num_experts == 8 and cfg.num_experts_per_tok == 2
    elif arch == "mamba2-2.7b":
        assert cfg.ssm_state_size == 128
    else:
        assert cfg.d_ff == FF
    assert cfg.vocab_size == V
    if arch == "zamba2-2.7b":
        assert cfg.ssm_state_size == 64


@pytest.mark.parametrize("arch", ALL_ARCHS)
def test_forward_smoke(arch):
    cfg = get_config(arch, smoke=True)
    rng = jax.random.PRNGKey(0)
    params = init_params(cfg, rng)
    tokens, kw = _inputs(cfg, rng)
    out = forward_full(params, cfg, tokens, **kw)
    V = padded_vocab(cfg)
    assert out["logits"].shape == (B, S, V)
    assert out["hidden"].shape == (B, S, cfg.d_model)
    assert np.all(np.isfinite(np.asarray(out["logits"], np.float32)))
    assert np.all(np.isfinite(np.asarray(out["hidden"], np.float32)))


@pytest.mark.parametrize("arch", ALL_ARCHS)
def test_train_step_smoke(arch):
    """One optimizer step; loss finite and decreases over 3 steps."""
    cfg = get_config(arch, smoke=True)
    rng = jax.random.PRNGKey(1)
    params = init_params(cfg, rng)
    tokens, kw = _inputs(cfg, rng)
    labels = jnp.roll(tokens, -1, axis=1)

    opt = AdamW(learning_rate=1e-3)
    opt_state = opt.init(params)

    def loss_fn(p):
        return lm_loss(p, cfg, tokens, labels, **kw)

    first = None
    for _ in range(3):
        loss, grads = jax.value_and_grad(loss_fn)(params)
        assert np.isfinite(float(loss)), f"{arch}: loss not finite"
        if first is None:
            first = float(loss)
        params, opt_state = opt.update(grads, opt_state, params)
    final = float(loss_fn(params))
    assert final < first, f"{arch}: loss did not decrease ({first}->{final})"


@pytest.mark.parametrize("arch", ASSIGNED_ARCHS)
def test_param_count_sane(arch):
    """Smoke param count is small enough for CPU and nonzero."""
    cfg = get_config(arch, smoke=True)
    params = init_params(cfg, jax.random.PRNGKey(0))
    n = count_params(params)
    assert 1e4 < n < 2e8, f"{arch}: {n} params"
