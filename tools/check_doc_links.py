"""Docs link checker — back-compat shim over the repolint ``doc-links``
pass (``tools/repolint/passes/doc_links.py``), which is where the logic
now lives. Prefer the one front door:

    python -m tools.repolint src/          # doc-links runs with the rest
    python -m tools.repolint --select DOC001

This script keeps the old CLI and output contract (``[BROKEN] doc:
broken reference -> rel`` lines, exit 1 on any break) for anything
still invoking it directly.
"""
from __future__ import annotations

import os
import sys

sys.path.insert(0, os.path.dirname(os.path.dirname(os.path.abspath(__file__))))

from tools.repolint.passes.doc_links import broken_references, doc_files

ROOT = os.path.dirname(os.path.dirname(os.path.abspath(__file__)))


def main() -> int:
    docs = doc_files(ROOT)
    findings = broken_references(ROOT, docs)
    for f in findings:
        print(f"[BROKEN] {f.path}: {f.message}")
    if not findings:
        print(f"checked {len(docs)} docs: all repo-path "
              f"references resolve")
    return 1 if findings else 0


if __name__ == "__main__":
    sys.exit(main())
