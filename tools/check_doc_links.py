"""Docs link checker: every repo-relative path referenced from the
markdown docs must exist, so renames/moves can't silently strand the
documentation (the CI lint job runs this).

    python tools/check_doc_links.py

Checked references:
  * markdown links ``[text](target)`` with non-URL targets;
  * backticked repo paths like ``docs/ENGINE.md``, ``benchmarks/foo.py``
    or ``tests/test_x.py::test_y`` (the ``::test`` suffix and brace
    expansions like ``serving/{engine,queue}.py`` are resolved).

Anchors (``#section``) and external URLs are not validated.
"""
from __future__ import annotations

import itertools
import os
import re
import sys

ROOT = os.path.dirname(os.path.dirname(os.path.abspath(__file__)))
DOC_FILES = ["README.md", "ROADMAP.md",
             *(os.path.join("docs", f)
               for f in sorted(os.listdir(os.path.join(ROOT, "docs")))
               if f.endswith(".md"))]

_MD_LINK = re.compile(r"\[[^\]]*\]\(([^)#][^)]*)\)")
# backticked tokens that look like repo paths: start with a known
# top-level dir and contain a slash or end in a known file extension
_TICKED = re.compile(r"`([A-Za-z0-9_./{},:*-]+)`")
_TOP_DIRS = ("src/", "tests/", "benchmarks/", "docs/", "tools/",
             "examples/", ".github/")
_TOP_FILES = ("README.md", "ROADMAP.md", "PAPER.md", "PAPERS.md",
              "CHANGES.md", "pyproject.toml")


def _expand_braces(path: str) -> list[str]:
    m = re.search(r"\{([^}]*)\}", path)
    if not m:
        return [path]
    pre, post = path[: m.start()], path[m.end():]
    return list(itertools.chain.from_iterable(
        _expand_braces(pre + alt + post) for alt in m.group(1).split(",")))


def _candidates(token: str) -> list[str]:
    """Paths a backticked token implies, or [] if it isn't a path."""
    token = token.split("::")[0]  # pytest node ids
    if token in _TOP_FILES:
        return [token]
    if not token.startswith(_TOP_DIRS):
        return []
    if "*" in token:
        return []  # glob-style mentions (BENCH_*.json) aren't paths
    paths = _expand_braces(token)
    # `serving/engine` style module mentions get a .py fallback
    return [p for p in paths]


def _exists(rel: str) -> bool:
    p = os.path.join(ROOT, rel)
    return os.path.exists(p) or os.path.exists(p + ".py")


def main() -> int:
    missing = []
    for doc in DOC_FILES:
        text = open(os.path.join(ROOT, doc), encoding="utf-8").read()
        refs = set()
        for m in _MD_LINK.finditer(text):
            target = m.group(1).strip()
            if "://" in target or target.startswith("mailto:"):
                continue
            # md links resolve relative to the doc's directory
            base = os.path.dirname(doc)
            refs.add(os.path.normpath(os.path.join(base, target)))
        for m in _TICKED.finditer(text):
            refs.update(_candidates(m.group(1)))
        for rel in sorted(refs):
            if not _exists(rel):
                missing.append(f"{doc}: broken reference -> {rel}")
    for line in missing:
        print(f"[BROKEN] {line}")
    if not missing:
        print(f"checked {len(DOC_FILES)} docs: all repo-path "
              f"references resolve")
    return 1 if missing else 0


if __name__ == "__main__":
    sys.exit(main())
