"""repolint — repo-specific static analysis for the identity pins.

The serving engine's correctness story rests on invariants that generic
linters cannot see: RNG key discipline (the bit-identity pins assume
every key is consumed exactly once), donation safety (``donate_argnums``
buffers must never be read after the call that consumed them), tracing
safety (no host control flow on traced values inside jitted bodies),
Pallas kernel shape agreement, and a configuration surface
(``EngineConfig`` <-> ``REPRO_*`` env vars <-> README table <-> CI lanes
<-> ``launch/serve.py`` flags) that must stay in sync by construction.

``python -m tools.repolint src/`` runs every registered pass; see
``docs/ANALYSIS.md`` for the rule catalogue, the suppression and
baseline workflow, and how to add a pass.
"""
from tools.repolint.core import (Baseline, Context, Finding, LintPass,
                                 load_py_files, run_passes)
from tools.repolint.passes import all_passes

__all__ = ["Baseline", "Context", "Finding", "LintPass", "all_passes",
           "load_py_files", "run_passes"]
