"""Shared AST helpers for repolint passes: import-alias resolution,
stable expression identifiers, and literal folding."""
from __future__ import annotations

import ast
from typing import Dict, Iterator, List, Optional, Union

FunctionNode = Union[ast.FunctionDef, ast.AsyncFunctionDef]
FUNC_NODES = (ast.FunctionDef, ast.AsyncFunctionDef)
SCOPE_NODES = FUNC_NODES + (ast.ClassDef,)


def import_map(tree: ast.AST) -> Dict[str, str]:
    """Local name -> dotted module path, from every import statement in
    the file (module- or function-level)."""
    out: Dict[str, str] = {}
    for node in ast.walk(tree):
        if isinstance(node, ast.Import):
            for alias in node.names:
                out[alias.asname or alias.name.split(".")[0]] = (
                    alias.name if alias.asname else
                    alias.name.split(".")[0])
        elif isinstance(node, ast.ImportFrom) and node.module:
            for alias in node.names:
                out[alias.asname or alias.name] = (
                    f"{node.module}.{alias.name}")
    return out


def dotted(node: ast.AST) -> Optional[str]:
    """``a.b.c`` as a string for pure Name/Attribute chains."""
    parts: List[str] = []
    while isinstance(node, ast.Attribute):
        parts.append(node.attr)
        node = node.value
    if isinstance(node, ast.Name):
        parts.append(node.id)
        return ".".join(reversed(parts))
    return None


def resolve(node: ast.AST, imports: Dict[str, str]) -> Optional[str]:
    """Dotted path with the leading alias expanded through the import
    map (``jr.split`` -> ``jax.random.split``)."""
    path = dotted(node)
    if path is None:
        return None
    head, _, rest = path.partition(".")
    base = imports.get(head, head)
    return f"{base}.{rest}" if rest else base


def expr_id(node: ast.AST) -> Optional[str]:
    """A stable textual identity for simple value expressions: names,
    attribute chains (``self._rng``) and constant-indexed subscripts
    (``ks[0]``). None for anything fancier."""
    if isinstance(node, ast.Name):
        return node.id
    if isinstance(node, ast.Attribute):
        base = expr_id(node.value)
        return f"{base}.{node.attr}" if base else None
    if isinstance(node, ast.Subscript):
        base = expr_id(node.value)
        sl = node.slice
        if base and isinstance(sl, ast.Constant):
            return f"{base}[{sl.value!r}]"
        return None
    return None


def target_ids(node: ast.AST) -> List[str]:
    """Textual ids bound by an assignment target (tuples flattened).
    ``x[i] = ...`` binds the base name (the container mutates)."""
    if isinstance(node, (ast.Tuple, ast.List)):
        out: List[str] = []
        for elt in node.elts:
            out.extend(target_ids(elt))
        return out
    if isinstance(node, ast.Starred):
        return target_ids(node.value)
    if isinstance(node, ast.Subscript):
        base = expr_id(node.value)
        return [base] if base else []
    eid = expr_id(node)
    return [eid] if eid else []


def stmt_targets(stmt: ast.stmt) -> List[str]:
    """Ids (re)bound by this statement."""
    if isinstance(stmt, ast.Assign):
        out: List[str] = []
        for t in stmt.targets:
            out.extend(target_ids(t))
        return out
    if isinstance(stmt, (ast.AnnAssign, ast.AugAssign)):
        return target_ids(stmt.target)
    if isinstance(stmt, (ast.For, ast.AsyncFor)):
        return target_ids(stmt.target)
    if isinstance(stmt, ast.With):
        out = []
        for item in stmt.items:
            if item.optional_vars is not None:
                out.extend(target_ids(item.optional_vars))
        return out
    return []


def const_int(node: ast.AST, env: Dict[str, int]) -> Optional[int]:
    """Fold an int literal / resolvable name / simple arithmetic."""
    if isinstance(node, ast.Constant) and isinstance(node.value, int) \
            and not isinstance(node.value, bool):
        return node.value
    if isinstance(node, ast.Name):
        return env.get(node.id)
    if isinstance(node, ast.BinOp):
        lo, hi = const_int(node.left, env), const_int(node.right, env)
        if lo is None or hi is None:
            return None
        if isinstance(node.op, ast.Mult):
            return lo * hi
        if isinstance(node.op, ast.Add):
            return lo + hi
        if isinstance(node.op, ast.Sub):
            return lo - hi
        if isinstance(node.op, ast.FloorDiv) and hi != 0:
            return lo // hi
        return None
    if isinstance(node, ast.UnaryOp) and isinstance(node.op, ast.USub):
        v = const_int(node.operand, env)
        return -v if v is not None else None
    return None


def const_env(tree: ast.AST) -> Dict[str, int]:
    """Module/function-level ``NAME = <int literal>`` bindings (a name
    assigned more than once is dropped — its value is not static)."""
    env: Dict[str, int] = {}
    seen_twice = set()
    for node in ast.walk(tree):
        if isinstance(node, ast.Assign) and len(node.targets) == 1 \
                and isinstance(node.targets[0], ast.Name):
            name = node.targets[0].id
            val = const_int(node.value, {})
            if name in env or name in seen_twice:
                env.pop(name, None)
                seen_twice.add(name)
            elif val is not None:
                env[name] = val
    return env


def functions(tree: ast.AST) -> Iterator[FunctionNode]:
    """Every function/method definition in the file, at any depth."""
    for node in ast.walk(tree):
        if isinstance(node, FUNC_NODES):
            yield node


def body_statements(fn: FunctionNode) -> Iterator[ast.stmt]:
    """The function's statements in source order, descending into
    control-flow blocks but NOT into nested function/class scopes."""
    def walk(body: List[ast.stmt]) -> Iterator[ast.stmt]:
        for stmt in body:
            yield stmt
            if isinstance(stmt, SCOPE_NODES):
                continue
            for block in _child_blocks(stmt):
                yield from walk(block)
    yield from walk(fn.body)


def _child_blocks(stmt: ast.stmt) -> List[List[ast.stmt]]:
    blocks = []
    for field in ("body", "orelse", "finalbody"):
        block = getattr(stmt, field, None)
        if block:
            blocks.append(block)
    for handler in getattr(stmt, "handlers", []) or []:
        blocks.append(handler.body)
    return blocks


def _stmt_expr_nodes(stmt: ast.stmt) -> Iterator[ast.AST]:
    """Every expression node belonging to THIS statement: child
    *statements* (compound-statement bodies, nested defs' bodies) are
    skipped — ``body_statements`` visits those on their own — while
    lambdas are included (they execute, possibly, as part of the
    statement). Decorator/default expressions of a nested def do run in
    the enclosing scope and are included."""
    stack: List[ast.AST] = [stmt]
    while stack:
        node = stack.pop()
        if node is not stmt and isinstance(node, ast.stmt):
            continue
        yield node
        stack.extend(ast.iter_child_nodes(node))


def stmt_calls(stmt: ast.stmt) -> Iterator[ast.Call]:
    """Call nodes evaluated by this statement (see ``_stmt_expr_nodes``
    for the scoping rules)."""
    for node in _stmt_expr_nodes(stmt):
        if isinstance(node, ast.Call):
            yield node


def stmt_loads(stmt: ast.stmt) -> Iterator[ast.AST]:
    """Name/Attribute/Subscript nodes in Load context evaluated by this
    statement. Chains are yielded at every level (``self.cache['k']``
    yields the subscript, the attribute and the name) so callers can
    match at whichever granularity they track."""
    for node in _stmt_expr_nodes(stmt):
        if isinstance(node, (ast.Name, ast.Attribute, ast.Subscript)) \
                and isinstance(getattr(node, "ctx", None), ast.Load):
            yield node
