"""Tracing safety (rules TRC001/TRC002).

Inside a jitted/scanned body every array is a tracer: Python ``if`` /
``while`` / ``assert`` on one either raises ``TracerBoolConversionError``
at trace time or — worse — silently bakes the first-trace branch into
the compiled program, and host escapes (``.item()``, ``float()``,
``np.*``) force a device sync that breaks the async dispatch pipeline
the scheduler's deadline accounting relies on.

Traced bodies are found structurally, not by execution:

* defs decorated with ``jax.jit`` / ``partial(jax.jit, ...)``;
* functions passed (directly, or wrapped in ``functools.partial``) to
  ``jax.jit``, ``jax.lax.scan`` / ``while_loop`` / ``fori_loop`` /
  ``cond`` / ``switch``, ``jax.shard_map``, or ``pl.pallas_call``;
* lambdas passed to those same combinators;
* defs nested inside any of the above.

* **TRC001** — ``if`` / ``while`` / ``assert`` whose test *evaluates
  array code* (a ``jnp.*`` / ``lax.*`` call, or an ``.any()/.all()/
  .sum()/.item()``-style reduction) inside a traced body. Plain
  predicates on static Python values (``if ss is not None``,
  ``if has_own:``) are deliberately NOT flagged — closures over Python
  bools are how the engine specializes compiled variants.
* **TRC002** — ``.item()`` / ``float()/int()/bool()`` on a non-literal /
  ``np.*`` (host numpy) calls inside a traced body.
"""
from __future__ import annotations

import ast
from typing import Dict, Iterable, List, Optional, Set

from tools.repolint import astutil
from tools.repolint.core import Context, Finding, LintPass, PyFile

# dotted-path consumers whose function arguments get traced
_TRACING_CONSUMERS = {
    "jax.jit", "jax.api.jit",
    "jax.lax.scan", "lax.scan",
    "jax.lax.while_loop", "lax.while_loop",
    "jax.lax.fori_loop", "lax.fori_loop",
    "jax.lax.cond", "lax.cond",
    "jax.lax.switch", "lax.switch",
    "jax.lax.map", "lax.map",
    "jax.shard_map", "jax.experimental.shard_map.shard_map",
    "jax.experimental.pallas.pallas_call", "pl.pallas_call",
    "jax.checkpoint", "jax.remat", "jax.grad", "jax.value_and_grad",
    "jax.vmap", "jax.pmap",
}
_ARRAY_METHODS = {"any", "all", "sum", "max", "min", "mean", "prod",
                  "item", "astype", "argmax", "argmin"}
_ARRAY_MODULES = ("jax.numpy.", "jnp.", "jax.lax.", "lax.",
                  "jax.random.")
_HOST_CASTS = {"float", "int", "bool"}


def _is_array_expr(node: ast.AST, imports: Dict[str, str]) -> bool:
    """Does evaluating ``node`` run jax array code?"""
    for sub in ast.walk(node):
        if not isinstance(sub, ast.Call):
            continue
        path = astutil.resolve(sub.func, imports)
        if path and (path.startswith(_ARRAY_MODULES)
                     or path.startswith("jax.numpy")):
            return True
        if isinstance(sub.func, ast.Attribute) \
                and sub.func.attr in _ARRAY_METHODS:
            return True
    return False


def _partial_target(call: ast.Call, imports: Dict[str, str]
                    ) -> Optional[str]:
    """Name wrapped by ``[functools.]partial(name, ...)``."""
    path = astutil.resolve(call.func, imports)
    if path in ("functools.partial", "partial") and call.args \
            and isinstance(call.args[0], ast.Name):
        return call.args[0].id
    return None


def _collect_traced(pf: PyFile, imports: Dict[str, str]
                    ) -> List[astutil.FunctionNode]:
    """Function defs whose bodies run under trace."""
    traced_names: Set[str] = set()
    traced_lambdas: List[ast.Lambda] = []
    # partial wrappers: local name -> wrapped fn name
    partial_of: Dict[str, str] = {}
    for node in ast.walk(pf.tree):
        if isinstance(node, ast.Assign) and len(node.targets) == 1 \
                and isinstance(node.targets[0], ast.Name) \
                and isinstance(node.value, ast.Call):
            tgt = _partial_target(node.value, imports)
            if tgt:
                partial_of[node.targets[0].id] = tgt
            # name = jax.jit(fn, ...)
            path = astutil.resolve(node.value.func, imports)
            if path in _TRACING_CONSUMERS:
                pass  # handled below with every consumer call

    for node in ast.walk(pf.tree):
        if not isinstance(node, ast.Call):
            continue
        path = astutil.resolve(node.func, imports)
        if path not in _TRACING_CONSUMERS:
            continue
        for arg in list(node.args) + [kw.value for kw in node.keywords]:
            if isinstance(arg, ast.Name):
                traced_names.add(partial_of.get(arg.id, arg.id))
            elif isinstance(arg, ast.Lambda):
                traced_lambdas.append(arg)
            elif isinstance(arg, ast.Call):
                tgt = _partial_target(arg, imports)
                if tgt:
                    traced_names.add(tgt)

    roots: List[astutil.FunctionNode] = []
    for fn in astutil.functions(pf.tree):
        if fn.name in traced_names:
            roots.append(fn)
            continue
        for dec in fn.decorator_list:
            d = dec.func if isinstance(dec, ast.Call) else dec
            path = astutil.resolve(d, imports)
            if path in _TRACING_CONSUMERS:
                roots.append(fn)
                break
            if isinstance(dec, ast.Call):
                inner = astutil.resolve(
                    dec.args[0] if dec.args else ast.Constant(None),
                    imports)
                p = astutil.resolve(dec.func, imports)
                if p in ("functools.partial", "partial") \
                        and inner in _TRACING_CONSUMERS:
                    roots.append(fn)
                    break
    # nested defs inside traced roots are traced too
    out: List[astutil.FunctionNode] = []
    seen: Set[int] = set()
    stack = list(roots)
    while stack:
        fn = stack.pop()
        if id(fn) in seen:
            continue
        seen.add(id(fn))
        out.append(fn)
        for sub in ast.walk(fn):
            if sub is not fn and isinstance(sub, astutil.FUNC_NODES):
                stack.append(sub)
    # traced lambdas get checked for host escapes only (a lambda body
    # cannot contain if/while/assert statements)
    return out, traced_lambdas


class TracingPass(LintPass):
    name = "tracing"
    rules = {
        "TRC001": "host control flow (if/while/assert) on a traced value",
        "TRC002": "host escape (.item()/float()/np.*) inside a traced "
                  "body",
    }

    def run(self, ctx: Context) -> Iterable[Finding]:
        for pf in ctx.py_files:
            imports = astutil.import_map(pf.tree)
            if not any(v.startswith("jax") for v in imports.values()):
                continue
            traced_fns, traced_lambdas = _collect_traced(pf, imports)
            for fn in traced_fns:
                yield from self._check_body(pf, imports, fn, fn.name)
            for lam in traced_lambdas:
                yield from self._host_escapes(pf, imports, lam,
                                              "<lambda>")

    def _check_body(self, pf: PyFile, imports: Dict[str, str],
                    fn: astutil.FunctionNode, where: str
                    ) -> Iterable[Finding]:
        for stmt in astutil.body_statements(fn):
            if isinstance(stmt, astutil.SCOPE_NODES):
                continue
            test = None
            kind = None
            if isinstance(stmt, ast.If):
                test, kind = stmt.test, "if"
            elif isinstance(stmt, ast.While):
                test, kind = stmt.test, "while"
            elif isinstance(stmt, ast.Assert):
                test, kind = stmt.test, "assert"
            if test is not None and _is_array_expr(test, imports):
                yield Finding(
                    "TRC001", pf.path, stmt.lineno,
                    f"Python `{kind}` on a traced array value inside "
                    f"jitted body {where!r}; use lax.cond/lax.select "
                    f"or checkify instead",
                    detail=f"{kind}@{where}")
            yield from self._host_escapes(pf, imports, stmt, where)

    def _host_escapes(self, pf: PyFile, imports: Dict[str, str],
                      node: ast.AST, where: str) -> Iterable[Finding]:
        nodes = astutil._stmt_expr_nodes(node) \
            if isinstance(node, ast.stmt) else ast.walk(node)
        for sub in nodes:
            if not isinstance(sub, ast.Call):
                continue
            if isinstance(sub.func, ast.Attribute) \
                    and sub.func.attr == "item":
                yield Finding(
                    "TRC002", pf.path, sub.lineno,
                    f".item() forces a host sync inside traced body "
                    f"{where!r}", detail=f"item@{where}")
                continue
            path = astutil.resolve(sub.func, imports)
            if path and (path.startswith("numpy.")
                         or path == "numpy"):
                yield Finding(
                    "TRC002", pf.path, sub.lineno,
                    f"host numpy call `{path}` inside traced body "
                    f"{where!r}; use jax.numpy",
                    detail=f"{path}@{where}")
                continue
            if isinstance(sub.func, ast.Name) \
                    and sub.func.id in _HOST_CASTS and sub.args \
                    and not isinstance(sub.args[0], ast.Constant):
                # int(x) on a literal is fine; on a traced value it
                # syncs. We can't see types — flag non-constant args
                # only when the arg mentions a call or subscript (most
                # static shapes are plain names: int(x.shape[0]) is
                # still static, so exempt .shape chains).
                arg = sub.args[0]
                txt = ast.dump(arg)
                if "attr='shape'" in txt or "attr='ndim'" in txt \
                        or "attr='size'" in txt:
                    continue
                yield Finding(
                    "TRC002", pf.path, sub.lineno,
                    f"{sub.func.id}() on a possibly-traced value "
                    f"inside traced body {where!r} forces a host sync",
                    detail=f"{sub.func.id}@{where}")
