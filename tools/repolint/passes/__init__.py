"""Pass registry. ``all_passes()`` returns one instance of every
registered pass, in deterministic order. Adding a pass = writing a
``LintPass`` subclass and listing it here (see docs/ANALYSIS.md)."""
from __future__ import annotations

from typing import List

from tools.repolint.core import LintPass
from tools.repolint.passes.config_surface import ConfigSurfacePass
from tools.repolint.passes.doc_links import DocLinksPass
from tools.repolint.passes.donation import DonationPass
from tools.repolint.passes.pallas import PallasPass
from tools.repolint.passes.rng import RngPass
from tools.repolint.passes.tracing import TracingPass

_REGISTRY = [RngPass, DonationPass, TracingPass, PallasPass,
             ConfigSurfacePass, DocLinksPass]

# framework-level rules that belong to no pass but must be documented
# and selectable like any other
FRAMEWORK_RULES = {
    "SUP001": "suppression comment matches no finding",
    "PARSE": "file failed to parse",
}


def all_passes() -> List[LintPass]:
    return [cls() for cls in _REGISTRY]
