"""Pallas kernel lint (rules PLK001/PLK002/PLK003).

The TPU Pallas kernels are the highest-blast-radius code in the repo:
a BlockSpec whose index_map arity or return rank disagrees with the
grid compiles into silently-wrong slab addressing, a Python loop over a
traced dimension unrolls into megabytes of HLO, and a scratch
allocation that overflows VMEM (~16 MB/core) fails only on real
hardware — which CI doesn't have. All three are statically visible.

* **PLK001** — grid/BlockSpec disagreement: an ``index_map`` lambda
  whose positional-arg count can't absorb the grid (named args must be
  the grid rank, or grid rank + ``num_scalar_prefetch`` when a
  ``PrefetchScalarGridSpec`` passes the prefetch refs along — a
  trailing ``*_`` vararg absorbs those too), or an index_map returning
  a tuple whose length differs from the ``block_shape`` rank.
* **PLK002** — a Python ``for``/``while`` in the kernel body whose
  bound reads a kernel ref (``for i in range(lens_ref[0])``): traced at
  kernel build, this unrolls or fails; use ``lax.fori_loop``.
* **PLK003** — static VMEM scratch estimate over budget: when every
  ``pltpu.VMEM(shape, dtype)`` dim folds to a literal (module
  constants included), the summed bytes must fit
  ``options["vmem_budget"]`` (default 16 MiB). Unresolvable dims are
  skipped — the rule under-reports rather than guesses.
"""
from __future__ import annotations

import ast
from typing import Dict, Iterable, List, Optional, Tuple

from tools.repolint import astutil
from tools.repolint.core import Context, Finding, LintPass, PyFile

_PALLAS_CALL = ("jax.experimental.pallas.pallas_call", "pl.pallas_call")
_GRID_SPECS = ("jax.experimental.pallas.tpu.PrefetchScalarGridSpec",
               "pltpu.PrefetchScalarGridSpec",
               "jax.experimental.pallas.GridSpec", "pl.GridSpec")
_VMEM = ("jax.experimental.pallas.tpu.VMEM", "pltpu.VMEM")
_BLOCKSPEC = ("jax.experimental.pallas.BlockSpec", "pl.BlockSpec")
_DTYPE_BYTES = {
    "float32": 4, "f32": 4, "int32": 4, "uint32": 4,
    "bfloat16": 2, "float16": 2, "int16": 2,
    "int8": 1, "uint8": 1, "float8_e4m3fn": 1, "float8_e5m2": 1,
    "bool_": 1, "float64": 8, "int64": 8,
}
_DEFAULT_VMEM_BUDGET = 16 * 1024 * 1024


def _kw(call: ast.Call, name: str) -> Optional[ast.AST]:
    for kw in call.keywords:
        if kw.arg == name:
            return kw.value
    return None


def _tuple_elts(node: Optional[ast.AST]) -> Optional[List[ast.AST]]:
    if isinstance(node, (ast.Tuple, ast.List)):
        return list(node.elts)
    return None


def _name_assignment(tree: ast.AST, name: str) -> Optional[ast.AST]:
    """Last ``name = <expr>`` assignment in the file (linear scan is
    fine at lint granularity)."""
    found = None
    for node in ast.walk(tree):
        if isinstance(node, ast.Assign) and len(node.targets) == 1 \
                and isinstance(node.targets[0], ast.Name) \
                and node.targets[0].id == name:
            found = node.value
    return found


class _CallSite:
    """One pallas_call with its resolved grid/specs."""

    def __init__(self) -> None:
        self.call: Optional[ast.Call] = None
        self.grid_rank: Optional[int] = None
        self.grid_dims: List[Optional[int]] = []
        self.num_prefetch: int = 0
        self.block_specs: List[ast.AST] = []
        self.scratch_shapes: List[ast.AST] = []
        self.kernel_name: Optional[str] = None


def _resolve_site(pf: PyFile, imports: Dict[str, str], call: ast.Call,
                  env: Dict[str, int]) -> _CallSite:
    site = _CallSite()
    site.call = call

    # kernel: first positional arg, unwrapped through functools.partial
    if call.args:
        k = call.args[0]
        if isinstance(k, ast.Call):
            p = astutil.resolve(k.func, imports)
            if p in ("functools.partial", "partial") and k.args \
                    and isinstance(k.args[0], ast.Name):
                site.kernel_name = k.args[0].id
        elif isinstance(k, ast.Name):
            site.kernel_name = k.id
        elif isinstance(k, ast.Attribute):
            site.kernel_name = k.attr

    def absorb_specs(container: Optional[ast.AST]) -> None:
        elts = _tuple_elts(container)
        if elts is None and container is not None:
            elts = [container]
        for e in elts or []:
            site.block_specs.append(e)

    grid = _kw(call, "grid")
    spec = _kw(call, "grid_spec")
    if spec is not None:
        if isinstance(spec, ast.Name):
            spec = _name_assignment(pf.tree, spec.id)
        if isinstance(spec, ast.Call) \
                and astutil.resolve(spec.func, imports) in _GRID_SPECS:
            grid = _kw(spec, "grid")
            npf = _kw(spec, "num_scalar_prefetch")
            v = astutil.const_int(npf, env) if npf is not None else None
            site.num_prefetch = v if v is not None else 0
            absorb_specs(_kw(spec, "in_specs"))
            absorb_specs(_kw(spec, "out_specs"))
            site.scratch_shapes.extend(
                _tuple_elts(_kw(spec, "scratch_shapes")) or [])
    else:
        absorb_specs(_kw(call, "in_specs"))
        absorb_specs(_kw(call, "out_specs"))
        site.scratch_shapes.extend(
            _tuple_elts(_kw(call, "scratch_shapes")) or [])

    dims = _tuple_elts(grid)
    if dims is None and grid is not None:
        dims = [grid]  # grid=(n,) written as grid=n
    if dims is not None:
        site.grid_rank = len(dims)
        site.grid_dims = [astutil.const_int(d, env) for d in dims]
    return site


def _lambda_arity(lam: ast.Lambda) -> Tuple[int, bool]:
    """(named positional count, has-vararg)."""
    a = lam.args
    return len(a.args) + len(a.posonlyargs), a.vararg is not None


class PallasPass(LintPass):
    name = "pallas"
    rules = {
        "PLK001": "grid / BlockSpec rank disagreement",
        "PLK002": "Python loop over a traced dimension in a kernel body",
        "PLK003": "static VMEM scratch estimate exceeds budget",
    }

    def run(self, ctx: Context) -> Iterable[Finding]:
        budget = ctx.options.get("vmem_budget", _DEFAULT_VMEM_BUDGET)
        for pf in ctx.py_files:
            imports = astutil.import_map(pf.tree)
            if not any(v.startswith("jax") for v in imports.values()):
                continue
            env = astutil.const_env(pf.tree)
            kernels_used: Dict[str, _CallSite] = {}
            for node in ast.walk(pf.tree):
                if not isinstance(node, ast.Call):
                    continue
                if astutil.resolve(node.func, imports) not in _PALLAS_CALL:
                    continue
                site = _resolve_site(pf, imports, node, env)
                yield from self._check_specs(pf, imports, site, env)
                yield from self._check_vmem(pf, imports, site, env,
                                            budget)
                if site.kernel_name:
                    kernels_used[site.kernel_name] = site
            if kernels_used:
                for fn in astutil.functions(pf.tree):
                    if fn.name in kernels_used:
                        yield from self._check_kernel_body(pf, fn)

    # -- PLK001 -----------------------------------------------------------
    def _check_specs(self, pf: PyFile, imports: Dict[str, str],
                     site: _CallSite, env: Dict[str, int]
                     ) -> Iterable[Finding]:
        if site.grid_rank is None:
            return
        rank, npf = site.grid_rank, site.num_prefetch
        for spec in site.block_specs:
            if not (isinstance(spec, ast.Call) and astutil.resolve(
                    spec.func, imports) in _BLOCKSPEC):
                continue
            if not spec.args and _kw(spec, "memory_space") is not None:
                continue  # whole-ref spec: no block shape to check
            shape = spec.args[0] if spec.args else _kw(spec, "block_shape")
            index_map = spec.args[1] if len(spec.args) > 1 \
                else _kw(spec, "index_map")
            shape_elts = _tuple_elts(shape)
            if isinstance(index_map, ast.Lambda):
                named, vararg = _lambda_arity(index_map)
                ok = named == rank or named == rank + npf \
                    or (vararg and named <= rank + npf)
                if not ok:
                    yield Finding(
                        "PLK001", pf.path, index_map.lineno,
                        f"index_map takes {named} positional args but "
                        f"the grid has rank {rank}"
                        + (f" (+{npf} scalar-prefetch refs)" if npf
                           else "")
                        + "; each grid axis feeds one index_map arg",
                        detail=f"arity@{site.kernel_name or '?'}:"
                               f"{index_map.lineno}")
                ret = index_map.body
                ret_elts = _tuple_elts(ret)
                if ret_elts is not None and shape_elts is not None \
                        and len(ret_elts) != len(shape_elts):
                    yield Finding(
                        "PLK001", pf.path, index_map.lineno,
                        f"index_map returns {len(ret_elts)} indices "
                        f"but block_shape has rank {len(shape_elts)}",
                        detail=f"rank@{site.kernel_name or '?'}:"
                               f"{index_map.lineno}")

    # -- PLK002 -----------------------------------------------------------
    def _check_kernel_body(self, pf: PyFile, fn: astutil.FunctionNode
                           ) -> Iterable[Finding]:
        ref_params = {a.arg for a in fn.args.args + fn.args.posonlyargs}
        for stmt in astutil.body_statements(fn):
            bound = None
            if isinstance(stmt, (ast.For, ast.AsyncFor)):
                bound = stmt.iter
            elif isinstance(stmt, ast.While):
                bound = stmt.test
            if bound is None:
                continue
            for sub in ast.walk(bound):
                if isinstance(sub, ast.Subscript) \
                        and isinstance(sub.value, ast.Name) \
                        and sub.value.id in ref_params:
                    yield Finding(
                        "PLK002", pf.path, stmt.lineno,
                        f"Python loop bound reads kernel ref "
                        f"{sub.value.id!r} in {fn.name!r} — traced "
                        f"values can't drive Python loops; use "
                        f"lax.fori_loop / jnp.where masking",
                        detail=f"{fn.name}:{sub.value.id}")
                    break

    # -- PLK003 -----------------------------------------------------------
    def _check_vmem(self, pf: PyFile, imports: Dict[str, str],
                    site: _CallSite, env: Dict[str, int],
                    budget: int) -> Iterable[Finding]:
        total = 0
        resolved_any = False
        for scratch in site.scratch_shapes:
            if not (isinstance(scratch, ast.Call) and astutil.resolve(
                    scratch.func, imports) in _VMEM):
                continue
            shape = scratch.args[0] if scratch.args else None
            dims = _tuple_elts(shape)
            if dims is None:
                continue
            size = 1
            ok = True
            for d in dims:
                v = astutil.const_int(d, env)
                if v is None:
                    ok = False
                    break
                size *= v
            if not ok:
                continue
            dtype_name = None
            if len(scratch.args) > 1:
                dt = astutil.resolve(scratch.args[1], imports)
                if dt:
                    dtype_name = dt.split(".")[-1]
            nbytes = size * _DTYPE_BYTES.get(dtype_name or "float32", 4)
            total += nbytes
            resolved_any = True
        if resolved_any and total > budget:
            yield Finding(
                "PLK003", pf.path, site.call.lineno,
                f"VMEM scratch estimate {total} bytes exceeds the "
                f"{budget}-byte budget for this pallas_call; shrink "
                f"block shapes or spill to ANY/HBM",
                detail=f"vmem@{site.kernel_name or '?'}")
