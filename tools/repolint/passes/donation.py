"""Donation safety (rule DON001).

``jax.jit(donate_argnums=...)`` hands the argument's device buffer to
the compiled computation — the engine's decode/prefill/COW steps all
donate the paged KV pools so XLA can update them in place. After the
call, the donated buffer is DELETED: a host read returns garbage or
raises, and (worse) a second device use aliases memory the step is
concurrently overwriting. This is exactly the bug class the
fault-recovery path's emergency drain exists to contain; the lint
catches it before it ships.

The pass resolves donating callables **repo-wide** in two steps:

1. collect every function whose definition declares a literal
   ``donate_argnums``: ``@jax.jit(...)`` / ``@partial(jax.jit, ...)``
   decorators, ``name = jax.jit(fn, donate_argnums=...)`` assignments,
   and attribute bindings (``self._copy_block = jax.jit(...)`` or
   ``self._write_kv = write_kv`` forwarding a known donating local);
2. at every call site matching a collected name (bare or as the final
   attribute, so ``eng._copy_block(...)`` matches), the arguments in
   donated positions are *dead* after the statement — unless the same
   statement rebinds them (``cache = step(cache, ...)``, the blessed
   swap idiom). Any later read of a dead name before a rebinding is
   **DON001**.

Matching is by name, statement-granular and intraprocedural — a
heuristic, not a proof; findings that are deliberate go in the baseline
with a reason.
"""
from __future__ import annotations

import ast
from typing import Dict, Iterable, List, Optional, Set, Tuple

from tools.repolint import astutil
from tools.repolint.core import Context, Finding, LintPass, PyFile


def _donate_positions(call: ast.Call,
                      imports: Dict[str, str]) -> Optional[Tuple[int, ...]]:
    """Literal ``donate_argnums`` of a ``jax.jit`` call, else None."""
    path = astutil.resolve(call.func, imports)
    if path not in ("jax.jit", "jax.api.jit"):
        return None
    for kw in call.keywords:
        if kw.arg == "donate_argnums":
            v = kw.value
            if isinstance(v, ast.Constant) and isinstance(v.value, int):
                return (v.value,)
            if isinstance(v, (ast.Tuple, ast.List)):
                out = []
                for elt in v.elts:
                    if not (isinstance(elt, ast.Constant)
                            and isinstance(elt.value, int)):
                        return None
                    out.append(elt.value)
                return tuple(out)
            return None
    return None


def _jit_call_in(node: ast.AST, imports: Dict[str, str]
                 ) -> Optional[Tuple[int, ...]]:
    """donate_argnums found on ``jax.jit(...)`` or
    ``[functools.]partial(jax.jit, ...)`` expressions."""
    if not isinstance(node, ast.Call):
        return None
    pos = _donate_positions(node, imports)
    if pos is not None:
        return pos
    path = astutil.resolve(node.func, imports)
    if path in ("functools.partial", "partial") and node.args:
        inner = astutil.resolve(node.args[0], imports)
        if inner in ("jax.jit", "jax.api.jit"):
            for kw in node.keywords:
                if kw.arg == "donate_argnums":
                    fake = ast.Call(func=node.args[0], args=[],
                                    keywords=[kw])
                    return _donate_positions(fake, imports)
    return None


def collect_donating(py_files: List[PyFile]) -> Dict[str, Tuple[int, ...]]:
    """Map callable name (bare or attribute tail) -> donated positions,
    across the whole analyzed file set."""
    donating: Dict[str, Set[int]] = {}

    def note(name: str, pos: Tuple[int, ...]) -> None:
        donating.setdefault(name, set()).update(pos)

    for pf in py_files:
        imports = astutil.import_map(pf.tree)
        local_defs: Dict[str, Tuple[int, ...]] = {}
        # decorated defs
        for fn in astutil.functions(pf.tree):
            for dec in fn.decorator_list:
                pos = _jit_call_in(dec, imports)
                if pos is not None:
                    note(fn.name, pos)
                    local_defs[fn.name] = pos
        # name/attr = jax.jit(..., donate_argnums=...) and forwarding
        for node in ast.walk(pf.tree):
            if not isinstance(node, ast.Assign) or len(node.targets) != 1:
                continue
            target = node.targets[0]
            tail = None
            if isinstance(target, ast.Name):
                tail = target.id
            elif isinstance(target, ast.Attribute):
                tail = target.attr
            if tail is None:
                continue
            pos = _jit_call_in(node.value, imports)
            if pos is not None:
                note(tail, pos)
                local_defs[tail] = pos
            elif isinstance(node.value, ast.Name) \
                    and node.value.id in local_defs:
                # self._write_kv = write_kv (a decorated local)
                note(tail, local_defs[node.value.id])
    return {k: tuple(sorted(v)) for k, v in donating.items()}


def _call_tail(call: ast.Call) -> Optional[str]:
    if isinstance(call.func, ast.Name):
        return call.func.id
    if isinstance(call.func, ast.Attribute):
        return call.func.attr
    return None


class DonationPass(LintPass):
    name = "donation"
    rules = {
        "DON001": "buffer read after being donated to a jitted call",
    }

    def run(self, ctx: Context) -> Iterable[Finding]:
        donating = collect_donating(ctx.py_files)
        if not donating:
            return
        for pf in ctx.py_files:
            for fn in astutil.functions(pf.tree):
                yield from self._check_fn(pf, fn, donating)

    def _check_fn(self, pf: PyFile, fn: astutil.FunctionNode,
                  donating: Dict[str, Tuple[int, ...]]
                  ) -> Iterable[Finding]:
        # dead id -> (donated-to name, line)
        dead: Dict[str, Tuple[str, int]] = {}
        for stmt in astutil.body_statements(fn):
            if isinstance(stmt, astutil.SCOPE_NODES):
                continue
            # 1) reads of currently-dead ids (loads evaluated by this
            #    statement, including chains rooted at a dead id)
            if dead:
                for load in astutil.stmt_loads(stmt):
                    lid = astutil.expr_id(load)
                    if lid is None:
                        continue
                    for did, (fname, dline) in dead.items():
                        if lid == did or lid.startswith((did + ".",
                                                         did + "[")):
                            yield Finding(
                                "DON001", pf.path, load.lineno,
                                f"{did!r} was donated to {fname!r} at "
                                f"line {dline} and must not be read "
                                f"afterwards (the device buffer is "
                                f"deleted); rebind it from the call's "
                                f"result instead", detail=did)
                            dead.pop(did, None)
                            break
            # 2) new donations by this statement
            newly_dead: List[Tuple[str, str, int]] = []
            for call in astutil.stmt_calls(stmt):
                tail = _call_tail(call)
                if tail not in donating:
                    continue
                for p in donating[tail]:
                    if p < len(call.args):
                        aid = astutil.expr_id(call.args[p])
                        if aid is not None:
                            newly_dead.append((aid, tail, call.lineno))
            # 3) rebindings by this statement resurrect ids (the
            #    cache-swap idiom rebinds in the same statement)
            stored = set(astutil.stmt_targets(stmt))
            for sid in stored:
                dead.pop(sid, None)
            for aid, tail, line in newly_dead:
                if aid not in stored:
                    dead[aid] = (tail, line)
