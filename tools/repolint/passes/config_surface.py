"""Config-surface drift (rules CFG001–CFG007).

The ``REPRO_*`` environment surface is the contract between four
artifacts that have no compiler keeping them honest: the engine's
``_ENV_FIELDS`` table + default factories, the README env table, the CI
workflow lanes that pin vars, and ``launch/serve.py``'s flag help. The
identity-pin lanes in CI only mean something if every toggle they flip
is real, documented, and exercised by at least one test. This pass
cross-checks all of them:

* **CFG001** — a ``REPRO_*`` var is read in code but missing from the
  README env table (undocumented knob).
* **CFG002** — a README env-table row names a var no code reads (stale
  doc row).
* **CFG003** — ``_ENV_FIELDS`` names a field ``EngineConfig`` doesn't
  have, or its floor disagrees with the README row's ``int >= N``.
* **CFG004** — CI sets a ``REPRO_*`` var no code reads (dead lane
  plumbing).
* **CFG005** — ``launch/serve.py`` help text mentions a ``REPRO_*``
  var no code reads.
* **CFG006** — a boolean/enum engine flag (the identity-pin toggles)
  is referenced by no test file: the lane could silently stop testing
  what it claims.
* **CFG007** — fp8 KV-dtype bench-gate status drift: while ``fp8`` is
  in ``KV_DTYPES``, both ``docs/SUPPORT_MATRIX.md`` and
  ``docs/BENCHMARKS.md`` must mark its bench-gate status
  *informational* (token identity is exact; the logit-MAE gate is
  advisory), and they must say the same thing.

File locations come from ``ctx.surface`` when set (tests point it at
fixture trees) and default to the real repo layout.
"""
from __future__ import annotations

import ast
import os
import re
from typing import Dict, Iterable, List, Optional, Tuple

from tools.repolint.core import Context, Finding, LintPass

_DEFAULT_SURFACE = {
    "engine": "src/repro/serving/engine.py",
    "readme": "README.md",
    "ci": ".github/workflows/ci.yml",
    "serve": "src/repro/launch/serve.py",
    "tests_dir": "tests",
    "src_dirs": ["src", "benchmarks"],
    "kv_quant": "src/repro/models/kv_quant.py",
    "docs_support": "docs/SUPPORT_MATRIX.md",
    "docs_benchmarks": "docs/BENCHMARKS.md",
}

_ENV_VAR_RE = re.compile(r"REPRO_[A-Z][A-Z0-9_]*")
_ENV_READ_RE = re.compile(
    r"environ(?:\.get)?\s*[\(\[]\s*[\"'](REPRO_[A-Z][A-Z0-9_]*)[\"']")
# the values column may contain escaped pipes (`f32` \| `bf16`), so it
# captures to end-of-line and strips the closing bar itself
_README_ROW_RE = re.compile(r"^\|\s*`(REPRO_[A-Z][A-Z0-9_]*)`\s*\|"
                            r"([^|]*)\|\s*(.*?)\s*\|?\s*$")
_FLOOR_RE = re.compile(r"int\s*>=\s*(\d+)")
_SETS_FIELD_RE = re.compile(r"EngineConfig\.([a-z_]+)")


def _read(root: str, rel: Optional[str]) -> Optional[str]:
    if not rel:
        return None
    path = os.path.join(root, rel)
    if not os.path.isfile(path):
        return None
    with open(path, encoding="utf-8") as fh:
        return fh.read()


def _first_line_of(text: str, needle: str) -> int:
    for i, line in enumerate(text.splitlines(), start=1):
        if needle in line:
            return i
    return 1


def _code_env_reads(root: str, src_dirs: List[str]
                    ) -> Dict[str, Tuple[str, int]]:
    """env var -> (repo-relative file, line) of its first read."""
    reads: Dict[str, Tuple[str, int]] = {}
    for d in src_dirs:
        base = os.path.join(root, d)
        if not os.path.isdir(base):
            continue
        for dirpath, dirnames, filenames in os.walk(base):
            dirnames[:] = sorted(x for x in dirnames
                                 if x != "__pycache__"
                                 and not x.startswith("."))
            for fn in sorted(filenames):
                if not fn.endswith(".py"):
                    continue
                rel = os.path.relpath(os.path.join(dirpath, fn),
                                      root).replace(os.sep, "/")
                text = _read(root, rel) or ""
                for i, line in enumerate(text.splitlines(), start=1):
                    for m in _ENV_READ_RE.finditer(line):
                        reads.setdefault(m.group(1), (rel, i))
    return reads


def _readme_rows(text: str) -> Dict[str, Tuple[int, str, str]]:
    """env var -> (line, sets-column, values-column)."""
    rows: Dict[str, Tuple[int, str, str]] = {}
    for i, line in enumerate(text.splitlines(), start=1):
        m = _README_ROW_RE.match(line.strip())
        if m:
            rows[m.group(1)] = (i, m.group(2).strip(), m.group(3).strip())
    return rows


def _engine_model(text: str) -> Tuple[Dict[str, str],
                                      Dict[str, Tuple[str, Optional[int],
                                                      int]]]:
    """(EngineConfig field -> annotation source,
    _ENV_FIELDS env var -> (field, floor, line))."""
    fields: Dict[str, str] = {}
    env_fields: Dict[str, Tuple[str, Optional[int], int]] = {}
    try:
        tree = ast.parse(text)
    except SyntaxError:
        return fields, env_fields
    for node in ast.walk(tree):
        if not (isinstance(node, ast.ClassDef)
                and node.name == "EngineConfig"):
            continue
        for stmt in node.body:
            if isinstance(stmt, ast.AnnAssign) \
                    and isinstance(stmt.target, ast.Name):
                try:
                    ann = ast.unparse(stmt.annotation)
                except Exception:
                    ann = ""
                fields[stmt.target.id] = ann
            elif isinstance(stmt, ast.Assign) \
                    and len(stmt.targets) == 1 \
                    and isinstance(stmt.targets[0], ast.Name) \
                    and stmt.targets[0].id == "_ENV_FIELDS" \
                    and isinstance(stmt.value, ast.Dict):
                for k, v in zip(stmt.value.keys, stmt.value.values):
                    if not (isinstance(k, ast.Constant)
                            and isinstance(v, ast.Tuple)
                            and len(v.elts) >= 1):
                        continue
                    fname = (v.elts[0].value
                             if isinstance(v.elts[0], ast.Constant)
                             else None)
                    floor = (v.elts[2].value
                             if len(v.elts) > 2
                             and isinstance(v.elts[2], ast.Constant)
                             and isinstance(v.elts[2].value, int)
                             else None)
                    if fname:
                        env_fields[k.value] = (fname, floor, k.lineno)
    return fields, env_fields


def _tests_text(root: str, tests_dir: str) -> str:
    chunks: List[str] = []
    base = os.path.join(root, tests_dir)
    if not os.path.isdir(base):
        return ""
    for dirpath, dirnames, filenames in os.walk(base):
        dirnames[:] = sorted(x for x in dirnames if x != "__pycache__"
                             and not x.startswith("."))
        for fn in sorted(filenames):
            if fn.endswith(".py"):
                chunks.append(_read(
                    root, os.path.relpath(os.path.join(dirpath, fn),
                                          root)) or "")
    return "\n".join(chunks)


class ConfigSurfacePass(LintPass):
    name = "config-surface"
    rules = {
        "CFG001": "env var read in code but missing from README table",
        "CFG002": "README env-table row names a var no code reads",
        "CFG003": "_ENV_FIELDS entry disagrees with EngineConfig/README",
        "CFG004": "CI sets an env var no code reads",
        "CFG005": "serve.py help mentions an env var no code reads",
        "CFG006": "engine flag referenced by no test",
        "CFG007": "fp8 bench-gate status drifts between docs",
    }

    def run(self, ctx: Context) -> Iterable[Finding]:
        s = dict(_DEFAULT_SURFACE)
        s.update(ctx.surface or {})
        root = ctx.root

        reads = _code_env_reads(root, s["src_dirs"])
        readme_text = _read(root, s["readme"])
        readme = _readme_rows(readme_text) if readme_text else {}
        engine_text = _read(root, s["engine"])
        fields, env_fields = (_engine_model(engine_text)
                              if engine_text else ({}, {}))
        # _ENV_FIELDS entries are read dynamically (from_env loops over
        # the table), invisible to the literal-read scan — count them
        for var, (_fname, _floor, line) in env_fields.items():
            reads.setdefault(var, (s["engine"], line))

        # CFG001 / CFG002: code reads <-> README rows
        if readme_text is not None:
            for var, (rel, line) in sorted(reads.items()):
                if var not in readme:
                    yield Finding(
                        "CFG001", rel, line,
                        f"{var} is read here but has no row in the "
                        f"README env table — document the knob",
                        detail=var)
            for var, (line, _sets, _vals) in sorted(readme.items()):
                if var not in reads:
                    yield Finding(
                        "CFG002", s["readme"], line,
                        f"README documents {var} but no code under "
                        f"{'/'.join(s['src_dirs'])} reads it — stale "
                        f"row (or the read moved out of the scanned "
                        f"tree)", detail=var)

        # CFG003: _ENV_FIELDS vs EngineConfig fields vs README floors
        for var, (fname, floor, line) in sorted(env_fields.items()):
            if fields and fname not in fields:
                yield Finding(
                    "CFG003", s["engine"], line,
                    f"_ENV_FIELDS maps {var} to EngineConfig."
                    f"{fname}, which is not a field",
                    detail=f"{var}:field")
            row = readme.get(var)
            if row and floor is not None:
                m = _FLOOR_RE.search(row[2])
                if m and int(m.group(1)) != floor:
                    yield Finding(
                        "CFG003", s["readme"], row[0],
                        f"README says {var} floor is int >= "
                        f"{m.group(1)} but _ENV_FIELDS enforces >= "
                        f"{floor}", detail=f"{var}:floor")

        # CFG004: CI-pinned vars must be read somewhere
        ci_text = _read(root, s["ci"])
        if ci_text is not None:
            seen = set()
            for i, line in enumerate(ci_text.splitlines(), start=1):
                for m in _ENV_VAR_RE.finditer(line):
                    var = m.group(0)
                    if var in seen:
                        continue
                    seen.add(var)
                    if var not in reads:
                        yield Finding(
                            "CFG004", s["ci"], i,
                            f"CI sets {var} but no code reads it — "
                            f"the lane pins nothing", detail=var)

        # CFG005: serve.py help text mentions only real vars
        serve_text = _read(root, s["serve"])
        if serve_text is not None:
            seen = set()
            for i, line in enumerate(serve_text.splitlines(), start=1):
                for m in _ENV_VAR_RE.finditer(line):
                    var = m.group(0)
                    if var in seen:
                        continue
                    seen.add(var)
                    if var not in reads:
                        yield Finding(
                            "CFG005", s["serve"], i,
                            f"serve.py mentions {var} but no code "
                            f"reads it — stale help text", detail=var)

        # CFG006: every boolean/enum engine flag is pinned by >= 1 test
        if fields:
            tests = _tests_text(root, s["tests_dir"])
            enum_fields = set()
            for var, (line, sets_col, vals_col) in readme.items():
                if "|" in vals_col:
                    fm = _SETS_FIELD_RE.search(sets_col)
                    if fm:
                        enum_fields.add(fm.group(1))
            for fname, ann in sorted(fields.items()):
                if "bool" not in ann and fname not in enum_fields:
                    continue
                if not re.search(rf"\b{re.escape(fname)}\b", tests):
                    yield Finding(
                        "CFG006", s["engine"],
                        _first_line_of(engine_text or "",
                                       f"{fname}:"),
                        f"engine flag {fname!r} is referenced by no "
                        f"test under {s['tests_dir']}/ — its identity "
                        f"pin is unguarded", detail=fname)

        # CFG007: fp8 bench-gate status must agree across docs
        kv_text = _read(root, s["kv_quant"])
        if kv_text and re.search(r"KV_DTYPES\s*=.*fp8", kv_text):
            for key in ("docs_support", "docs_benchmarks"):
                doc = _read(root, s[key])
                if doc is None:
                    continue
                # prose wraps: accept "informational" within two lines
                # of an fp8 mention
                lines = doc.splitlines()
                ok = any(
                    "fp8" in ln and any(
                        "informational" in lines[j].lower()
                        for j in range(max(0, i - 2),
                                       min(len(lines), i + 3)))
                    for i, ln in enumerate(lines))
                if not ok:
                    yield Finding(
                        "CFG007", s[key],
                        _first_line_of(doc, "fp8"),
                        "fp8 is a supported KV dtype but this doc "
                        "does not mark its bench-gate status as "
                        "informational — token identity is exact, "
                        "the logit-MAE gate is advisory; docs must "
                        "agree", detail="fp8-status")
