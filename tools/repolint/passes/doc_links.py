"""Doc-link integrity (rule DOC001) — the former standalone
``tools/check_doc_links.py``, folded into the framework so docs and
code drift are reported through one CLI / one CI step.

Every repo-relative path referenced from the markdown docs must exist:

* markdown links ``[text](target)`` with non-URL targets (resolved
  relative to the doc's directory);
* backticked repo paths like ``docs/ENGINE.md``, ``benchmarks/foo.py``
  or ``tests/test_x.py::test_y`` (the ``::test`` suffix and brace
  expansions like ``serving/{engine,queue}.py`` are resolved; ``*``
  glob mentions are skipped; bare module mentions get a ``.py``
  fallback).

Anchors (``#section``) and external URLs are not validated. The doc
set is README.md, ROADMAP.md and every ``docs/*.md``, overridable via
``ctx.surface["doc_files"]``.
"""
from __future__ import annotations

import itertools
import os
import re
from typing import Iterable, List

from tools.repolint.core import Context, Finding, LintPass

_MD_LINK = re.compile(r"\[[^\]]*\]\(([^)#][^)]*)\)")
# backticked tokens that look like repo paths: start with a known
# top-level dir and contain a slash or end in a known file extension
_TICKED = re.compile(r"`([A-Za-z0-9_./{},:*-]+)`")
_TOP_DIRS = ("src/", "tests/", "benchmarks/", "docs/", "tools/",
             "examples/", ".github/")
_TOP_FILES = ("README.md", "ROADMAP.md", "PAPER.md", "PAPERS.md",
              "CHANGES.md", "pyproject.toml")


def _expand_braces(path: str) -> List[str]:
    m = re.search(r"\{([^}]*)\}", path)
    if not m:
        return [path]
    pre, post = path[: m.start()], path[m.end():]
    return list(itertools.chain.from_iterable(
        _expand_braces(pre + alt + post)
        for alt in m.group(1).split(",")))


def _candidates(token: str) -> List[str]:
    """Paths a backticked token implies, or [] if it isn't a path."""
    token = token.split("::")[0]  # pytest node ids
    if token in _TOP_FILES:
        return [token]
    if not token.startswith(_TOP_DIRS):
        return []
    if "*" in token:
        return []  # glob-style mentions (BENCH_*.json) aren't paths
    return _expand_braces(token)


def _exists(root: str, rel: str) -> bool:
    p = os.path.join(root, rel)
    return os.path.exists(p) or os.path.exists(p + ".py")


def doc_files(root: str) -> List[str]:
    docs_dir = os.path.join(root, "docs")
    extra = []
    if os.path.isdir(docs_dir):
        extra = [f"docs/{f}" for f in sorted(os.listdir(docs_dir))
                 if f.endswith(".md")]
    return [d for d in ["README.md", "ROADMAP.md", *extra]
            if os.path.isfile(os.path.join(root, d))]


def broken_references(root: str, docs: List[str]
                      ) -> List[Finding]:
    findings: List[Finding] = []
    for doc in docs:
        with open(os.path.join(root, doc), encoding="utf-8") as fh:
            text = fh.read()
        # reference -> first line it appears on
        refs = {}
        for i, line in enumerate(text.splitlines(), start=1):
            for m in _MD_LINK.finditer(line):
                target = m.group(1).strip()
                if "://" in target or target.startswith("mailto:"):
                    continue
                # md links resolve relative to the doc's directory
                base = os.path.dirname(doc)
                rel = os.path.normpath(os.path.join(base, target))
                refs.setdefault(rel.replace(os.sep, "/"), i)
            for m in _TICKED.finditer(line):
                for rel in _candidates(m.group(1)):
                    refs.setdefault(rel, i)
        for rel in sorted(refs):
            if not _exists(root, rel):
                findings.append(Finding(
                    "DOC001", doc, refs[rel],
                    f"broken reference -> {rel}", detail=rel))
    return findings


class DocLinksPass(LintPass):
    name = "doc-links"
    rules = {"DOC001": "doc references a repo path that does not exist"}

    def run(self, ctx: Context) -> Iterable[Finding]:
        surface = ctx.surface or {}
        docs = surface.get("doc_files") or doc_files(ctx.root)
        yield from broken_references(ctx.root, docs)
