"""RNG key discipline (rules RNG001/RNG002).

The engine's bit-identity pins (sharded == single-device, horizon K ==
1, kernel == dense) assume the PRNG key stream is consumed in exactly
one order: every ``jax.random`` sampler eats a key derived by ``split``
/ ``fold_in``, and no key value is consumed twice. A reused key silently
correlates samples — the traces still *look* random, but the identity
contracts (and the paper's reproducible pruning decisions) are gone.

* **RNG001** — a sampler consumes a raw ``PRNGKey(...)`` result
  (inline, or a variable bound from ``PRNGKey`` with no intervening
  ``split``). Raw seeds are for deriving streams, not for sampling.
* **RNG002** — the same key value is consumed twice: two samplers (or
  ``split`` calls) eat one key variable without a rebinding in between,
  or a key bound outside a loop is consumed inside it without being
  rebound each iteration.

``fold_in(key, data)`` is exempt from double-consumption: deriving many
streams from one key with varying ``data`` is the blessed pattern (the
engine does exactly this per decode iteration).
"""
from __future__ import annotations

import ast
import dataclasses
from typing import Dict, Iterable, List, Optional, Set

from tools.repolint import astutil
from tools.repolint.core import Context, Finding, LintPass, PyFile

# jax.random attributes that derive/construct rather than consume
_CREATORS = {"PRNGKey", "key"}
_DERIVERS = {"split", "clone"}
_EXEMPT = {"fold_in", "key_data", "wrap_key_data", "key_impl",
           "bits"} | _CREATORS
_LOOP_NODES = (ast.For, ast.AsyncFor, ast.While)


def _random_fn(call: ast.Call, imports: Dict[str, str]) -> Optional[str]:
    """The ``jax.random`` function name for this call, else None."""
    path = astutil.resolve(call.func, imports)
    if path and path.startswith("jax.random."):
        return path.split(".")[-1]
    return None


@dataclasses.dataclass
class _KeyState:
    origin: str = "unknown"          # "prngkey" | "derived" | "unknown"
    consumed_at: List[int] = dataclasses.field(default_factory=list)


class _FnAnalyzer:
    def __init__(self, pf: PyFile, imports: Dict[str, str]):
        self.pf = pf
        self.imports = imports
        self.state: Dict[str, _KeyState] = {}
        self.findings: List[Finding] = []
        # stack of (stored-ids, consumed-ids) for enclosing loops
        self.loop_stack: List[Dict[str, Set[str]]] = []

    # -- helpers ---------------------------------------------------------
    def _consume(self, key_id: str, line: int, fn_name: str) -> None:
        st = self.state.setdefault(key_id, _KeyState())
        if st.consumed_at:
            self.findings.append(Finding(
                "RNG002", self.pf.path, line,
                f"key {key_id!r} consumed again by jax.random."
                f"{fn_name} (already consumed at line "
                f"{st.consumed_at[0]}); derive a fresh key with "
                f"split/fold_in", detail=key_id))
        st.consumed_at.append(line)
        for frame in self.loop_stack:
            frame["consumed"].add(key_id)

    def _store(self, key_id: str, origin: str) -> None:
        self.state[key_id] = _KeyState(origin=origin)
        for frame in self.loop_stack:
            frame["stored"].add(key_id)

    def _rhs_origin(self, value: ast.AST) -> str:
        if isinstance(value, ast.Call):
            fn = _random_fn(value, self.imports)
            if fn in _CREATORS:
                return "prngkey"
            if fn in _DERIVERS or fn == "fold_in":
                return "derived"
        return "unknown"

    # -- statement processing -------------------------------------------
    def process_calls(self, stmt: ast.stmt) -> None:
        for call in astutil.stmt_calls(stmt):
            fn = _random_fn(call, self.imports)
            if fn is None or fn in _EXEMPT or not call.args:
                continue
            key_arg = call.args[0]
            line = call.lineno
            # inline raw key: jax.random.normal(jax.random.PRNGKey(0), ..)
            if fn not in _DERIVERS and isinstance(key_arg, ast.Call) \
                    and _random_fn(key_arg, self.imports) in _CREATORS:
                self.findings.append(Finding(
                    "RNG001", self.pf.path, line,
                    f"jax.random.{fn} consumes a raw PRNGKey directly; "
                    f"derive a per-use key with split/fold_in",
                    detail=f"inline@{fn}"))
                continue
            key_id = astutil.expr_id(key_arg)
            if key_id is None:
                continue
            st = self.state.get(key_id)
            if fn not in _DERIVERS and st is not None \
                    and st.origin == "prngkey":
                self.findings.append(Finding(
                    "RNG001", self.pf.path, line,
                    f"jax.random.{fn} consumes {key_id!r}, a raw "
                    f"PRNGKey; derive a per-use key with "
                    f"split/fold_in", detail=key_id))
            self._consume(key_id, line, fn)

    def process_stores(self, stmt: ast.stmt) -> None:
        if isinstance(stmt, ast.Assign):
            origin = self._rhs_origin(stmt.value)
            for tid in astutil.stmt_targets(stmt):
                self._store(tid, origin)
        elif isinstance(stmt, (ast.AnnAssign, ast.AugAssign)):
            origin = "unknown"
            if getattr(stmt, "value", None) is not None:
                origin = self._rhs_origin(stmt.value)
            for tid in astutil.stmt_targets(stmt):
                self._store(tid, origin)
        elif isinstance(stmt, (ast.For, ast.AsyncFor, ast.With)):
            for tid in astutil.stmt_targets(stmt):
                self._store(tid, "unknown")

    def run_block(self, body: List[ast.stmt]) -> None:
        for stmt in body:
            if isinstance(stmt, astutil.SCOPE_NODES):
                # nested scope: analyzed on its own; its decorator and
                # default expressions do run here though
                self.process_calls(stmt)
                continue
            # loads (calls) before stores: `rng, k = split(rng)` is a
            # legal consume-then-rebind in one statement
            self.process_calls(stmt)
            self.process_stores(stmt)
            if isinstance(stmt, _LOOP_NODES):
                self._run_loop(stmt)
            elif isinstance(stmt, ast.If):
                self._run_branches([stmt.body, stmt.orelse])
            elif isinstance(stmt, ast.Try):
                blocks = [stmt.body + (stmt.orelse or [])]
                blocks += [h.body for h in stmt.handlers]
                if stmt.finalbody:
                    blocks = [b + stmt.finalbody for b in blocks]
                self._run_branches(blocks)
            else:
                for block in astutil._child_blocks(stmt):
                    self.run_block(block)

    def _run_branches(self, blocks: List[List[ast.stmt]]) -> None:
        """Process exclusive branches against snapshots and merge by
        worst case per key, so `if/else` arms each consuming a key once
        don't add up to a false double-consumption."""
        base = {k: dataclasses.replace(
            v, consumed_at=list(v.consumed_at))
            for k, v in self.state.items()}
        merged: Dict[str, _KeyState] = {}
        for block in blocks:
            self.state = {k: dataclasses.replace(
                v, consumed_at=list(v.consumed_at))
                for k, v in base.items()}
            self.run_block(block)
            for k, v in self.state.items():
                cur = merged.get(k)
                if cur is None or len(v.consumed_at) > len(
                        cur.consumed_at):
                    merged[k] = v
        self.state = merged

    def _run_loop(self, stmt: ast.stmt) -> None:
        frame: Dict[str, Set[str]] = {"stored": set(), "consumed": set()}
        self.loop_stack.append(frame)
        self.run_block(stmt.body)
        self.run_block(getattr(stmt, "orelse", []) or [])
        self.loop_stack.pop()
        # a key consumed in the body but never rebound there is eaten
        # again by every iteration (params and closures included)
        for key_id in sorted(frame["consumed"] - frame["stored"]):
            st = self.state.get(key_id)
            line = st.consumed_at[-1] if st and st.consumed_at \
                else stmt.lineno
            self.findings.append(Finding(
                "RNG002", self.pf.path, line,
                f"key {key_id!r} is consumed inside a loop without "
                f"being rebound each iteration — every pass reuses "
                f"the same key", detail=f"{key_id}@loop"))
        for frame_outer in self.loop_stack:
            frame_outer["stored"].update(frame["stored"])
            frame_outer["consumed"].update(frame["consumed"])


class RngPass(LintPass):
    name = "rng"
    rules = {
        "RNG001": "sampler consumes a raw PRNGKey (no split/fold_in)",
        "RNG002": "PRNG key value consumed more than once",
    }

    def run(self, ctx: Context) -> Iterable[Finding]:
        for pf in ctx.py_files:
            imports = astutil.import_map(pf.tree)
            if not any(v.startswith("jax") for v in imports.values()):
                continue
            scopes: List[List[ast.stmt]] = [pf.tree.body]
            scopes += [fn.body for fn in astutil.functions(pf.tree)]
            for body in scopes:
                an = _FnAnalyzer(pf, imports)
                an.run_block(body)
                yield from an.findings
