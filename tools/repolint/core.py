"""repolint framework: findings, pass registry plumbing, suppressions,
baseline handling and reporters.

Contracts (pinned by ``tests/test_repolint.py``):

* a finding is ``(rule, path, line, message, detail)``; its *fingerprint*
  ``rule::path::detail-or-message`` is line-number-free so baselines
  survive unrelated edits;
* ``# repolint: disable=RULE[,RULE...]`` on a finding's line (or the
  line directly above it) suppresses it; ``# repolint:
  disable-file=RULE`` anywhere in the first 10 lines suppresses the rule
  for the whole file. Suppressions that match no finding are themselves
  findings (``SUP001``) so dead annotations can't accumulate;
* the baseline file grandfathers findings by fingerprint, each entry
  carrying a human ``reason``; baseline entries that no longer match any
  finding are *stale* and fail the run (CI's stale-baseline check);
* exit codes: 0 = clean (every finding suppressed or baselined, no stale
  baseline entries), 1 = findings or stale baseline, 2 = usage/internal
  error.
"""
from __future__ import annotations

import ast
import dataclasses
import json
import os
import re
from typing import Dict, Iterable, List, Optional, Sequence, Set, Tuple

_SUPPRESS_RE = re.compile(r"#\s*repolint:\s*disable=([A-Za-z0-9_,\s]+)")
_SUPPRESS_FILE_RE = re.compile(
    r"#\s*repolint:\s*disable-file=([A-Za-z0-9_,\s]+)")
_FILE_SUPPRESS_SCAN_LINES = 10


@dataclasses.dataclass(frozen=True)
class Finding:
    """One diagnostic: ``rule`` code, repo-relative ``path``, 1-based
    ``line``, human ``message``, and an optional stable ``detail`` token
    (a symbol / env-var name) used for line-free fingerprinting."""
    rule: str
    path: str
    line: int
    message: str
    detail: str = ""

    @property
    def fingerprint(self) -> str:
        return f"{self.rule}::{self.path}::{self.detail or self.message}"

    def render(self) -> str:
        return f"{self.path}:{self.line}: {self.rule} {self.message}"

    def to_json(self) -> dict:
        return {"rule": self.rule, "path": self.path, "line": self.line,
                "message": self.message, "fingerprint": self.fingerprint}


@dataclasses.dataclass
class PyFile:
    """A parsed Python source file plus its suppression annotations."""
    path: str                    # repo-relative, posix separators
    source: str
    tree: ast.AST
    lines: List[str]
    # line (1-based) -> rule codes disabled on that line
    suppressions: Dict[int, Set[str]]
    file_suppressions: Set[str]

    def suppressed(self, rule: str, line: int) -> Optional[int]:
        """The annotation line that suppresses ``rule`` at ``line``
        (same line or the line directly above), or None."""
        if rule in self.file_suppressions:
            return 0
        for cand in (line, line - 1):
            if rule in self.suppressions.get(cand, set()):
                return cand
        return None


def parse_py_file(root: str, rel_path: str) -> Tuple[Optional[PyFile],
                                                     Optional[Finding]]:
    """Parse one file; a syntax error becomes a ``PARSE`` finding
    instead of crashing the whole run."""
    abs_path = os.path.join(root, rel_path)
    with open(abs_path, encoding="utf-8") as fh:
        source = fh.read()
    try:
        tree = ast.parse(source, filename=rel_path)
    except SyntaxError as e:
        return None, Finding("PARSE", rel_path, e.lineno or 1,
                             f"syntax error: {e.msg}")
    lines = source.splitlines()
    suppressions: Dict[int, Set[str]] = {}
    file_suppressions: Set[str] = set()
    for i, text in enumerate(lines, start=1):
        m = _SUPPRESS_FILE_RE.search(text)
        if m and i <= _FILE_SUPPRESS_SCAN_LINES:
            file_suppressions.update(
                r.strip() for r in m.group(1).split(",") if r.strip())
            continue
        m = _SUPPRESS_RE.search(text)
        if m:
            suppressions.setdefault(i, set()).update(
                r.strip() for r in m.group(1).split(",") if r.strip())
    return PyFile(rel_path, source, tree, lines, suppressions,
                  file_suppressions), None


def load_py_files(root: str, paths: Sequence[str]
                  ) -> Tuple[List[PyFile], List[Finding]]:
    """Collect and parse every ``.py`` under ``paths`` (repo-relative
    files or directories), skipping ``__pycache__``."""
    rels: List[str] = []
    for p in paths:
        abs_p = os.path.join(root, p)
        if os.path.isfile(abs_p):
            rels.append(os.path.relpath(abs_p, root))
            continue
        for dirpath, dirnames, filenames in os.walk(abs_p):
            dirnames[:] = sorted(d for d in dirnames
                                 if d != "__pycache__"
                                 and not d.startswith("."))
            for fn in sorted(filenames):
                if fn.endswith(".py"):
                    rels.append(os.path.relpath(
                        os.path.join(dirpath, fn), root))
    files, findings = [], []
    for rel in sorted(set(rels)):
        rel = rel.replace(os.sep, "/")
        pf, err = parse_py_file(root, rel)
        if err is not None:
            findings.append(err)
        else:
            files.append(pf)
    return files, findings


@dataclasses.dataclass
class Context:
    """Everything a pass may look at. Repo-level passes (config-surface,
    doc-links) read ``root`` directly; per-file passes iterate
    ``py_files``. ``surface`` overrides the config-surface file layout
    (tests point it at fixture trees); ``options`` carries tunables
    (``vmem_budget`` bytes for PLK003)."""
    root: str
    py_files: List[PyFile] = dataclasses.field(default_factory=list)
    surface: Optional[dict] = None
    options: dict = dataclasses.field(default_factory=dict)


class LintPass:
    """Base class: subclasses set ``name``, ``rules`` (code -> one-line
    description) and implement ``run``."""
    name: str = ""
    rules: Dict[str, str] = {}

    def run(self, ctx: Context) -> Iterable[Finding]:  # pragma: no cover
        raise NotImplementedError


FRAMEWORK_RULES = ("SUP001", "PARSE")


def _selected_rules(passes: Sequence[LintPass],
                    select: Optional[Set[str]]) -> Set[str]:
    known = {code for p in passes for code in p.rules}
    known.update(FRAMEWORK_RULES)
    return known if not select else known & select


def run_passes(ctx: Context, passes: Sequence[LintPass],
               select: Optional[Set[str]] = None,
               parse_findings: Sequence[Finding] = (),
               ) -> List[Finding]:
    """Run ``passes``, apply suppressions, and append ``SUP001`` for
    annotations that suppressed nothing. ``select`` restricts to a set
    of rule codes (pass-level: a pass runs if any of its rules is
    selected)."""
    selected = _selected_rules(passes, select)
    raw: List[Finding] = [f for f in parse_findings
                          if not select or f.rule in select]
    for p in passes:
        if not any(code in selected for code in p.rules):
            continue
        for f in p.run(ctx):
            if f.rule in selected:
                raw.append(f)

    by_path = {pf.path: pf for pf in ctx.py_files}
    kept: List[Finding] = []
    # (path, annotation line or 0, rule) -> used?
    used: Set[Tuple[str, int, str]] = set()
    for f in raw:
        pf = by_path.get(f.path)
        if pf is None:
            kept.append(f)
            continue
        at = pf.suppressed(f.rule, f.line)
        if at is None:
            kept.append(f)
        else:
            used.add((f.path, at, f.rule))
    if "SUP001" in selected:
        for pf in ctx.py_files:
            ann = [(line, rule) for line, rules in pf.suppressions.items()
                   for rule in sorted(rules)]
            ann += [(0, rule) for rule in sorted(pf.file_suppressions)]
            for line, rule in sorted(ann):
                if rule not in selected or rule == "SUP001":
                    continue  # rule didn't run -> can't judge the comment
                if (pf.path, line, rule) not in used:
                    kept.append(Finding(
                        "SUP001", pf.path, max(line, 1),
                        f"unused suppression: no {rule} finding is "
                        f"silenced by this comment",
                        detail=f"{rule}@{line}"))
    kept.sort(key=lambda f: (f.path, f.line, f.rule, f.message))
    # branch-merging walkers may report one defect twice (e.g. a Try
    # finalbody shared across merge arms); reports are de-duplicated
    uniq, seen = [], set()
    for f in kept:
        key = (f.rule, f.path, f.line, f.message)
        if key not in seen:
            seen.add(key)
            uniq.append(f)
    return uniq


# ---------------------------------------------------------------------------
# baseline
# ---------------------------------------------------------------------------

class Baseline:
    """Checked-in grandfather list keyed by finding fingerprint. Every
    entry must carry a ``reason`` saying why the finding is deliberately
    kept rather than fixed."""

    def __init__(self, entries: Optional[List[dict]] = None):
        self.entries = entries or []

    @classmethod
    def load(cls, path: str) -> "Baseline":
        if not os.path.exists(path):
            return cls([])
        with open(path, encoding="utf-8") as fh:
            data = json.load(fh)
        entries = data.get("entries", [])
        for e in entries:
            if "fingerprint" not in e or "reason" not in e:
                raise ValueError(
                    f"{path}: every baseline entry needs 'fingerprint' "
                    f"and 'reason', got {e!r}")
        return cls(entries)

    def save(self, path: str) -> None:
        payload = {
            "comment": ("repolint baseline: grandfathered findings by "
                        "fingerprint. Entries must carry a reason; stale "
                        "entries (matching no current finding) fail the "
                        "run — delete them when the finding is fixed."),
            "entries": self.entries,
        }
        with open(path, "w", encoding="utf-8") as fh:
            json.dump(payload, fh, indent=2, sort_keys=True)
            fh.write("\n")

    def apply(self, findings: Sequence[Finding]
              ) -> Tuple[List[Finding], List[Finding], List[dict]]:
        """Split into (new, baselined) findings and stale entries."""
        fps = {e["fingerprint"] for e in self.entries}
        new = [f for f in findings if f.fingerprint not in fps]
        baselined = [f for f in findings if f.fingerprint in fps]
        seen = {f.fingerprint for f in findings}
        stale = [e for e in self.entries if e["fingerprint"] not in seen]
        return new, baselined, stale

    @classmethod
    def from_findings(cls, findings: Sequence[Finding],
                      reason: str) -> "Baseline":
        entries = [{"fingerprint": f.fingerprint, "reason": reason,
                    "rule": f.rule, "path": f.path}
                   for f in findings]
        # dedupe identical fingerprints (e.g. one drift reported per
        # surface) while keeping deterministic order
        uniq: Dict[str, dict] = {}
        for e in entries:
            uniq.setdefault(e["fingerprint"], e)
        return cls(sorted(uniq.values(), key=lambda e: e["fingerprint"]))


# ---------------------------------------------------------------------------
# reporting
# ---------------------------------------------------------------------------

def render_human(new: Sequence[Finding], baselined: Sequence[Finding],
                 stale: Sequence[dict]) -> str:
    out = []
    for f in new:
        out.append(f.render())
    for e in stale:
        out.append(f"baseline: stale entry {e['fingerprint']!r} "
                   f"matches no current finding — delete it "
                   f"(reason was: {e['reason']})")
    if not out:
        n = len(baselined)
        out.append("repolint: clean"
                   + (f" ({n} baselined finding{'s' * (n != 1)})"
                      if n else ""))
    return "\n".join(out)


def render_json(new: Sequence[Finding], baselined: Sequence[Finding],
                stale: Sequence[dict],
                passes: Sequence[LintPass]) -> dict:
    return {
        "version": 1,
        "findings": [f.to_json() for f in new],
        "baselined": [f.to_json() for f in baselined],
        "stale_baseline": list(stale),
        "rules": {code: desc for p in passes
                  for code, desc in sorted(p.rules.items())},
        "counts": {"new": len(new), "baselined": len(baselined),
                   "stale_baseline": len(stale)},
    }
