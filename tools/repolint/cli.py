"""repolint command line.

    python -m tools.repolint [paths...]            # lint (default: src/)
    python -m tools.repolint --list-rules          # rule inventory
    python -m tools.repolint src/ --format json --out repolint.json
    python -m tools.repolint src/ --select RNG001,RNG002
    python -m tools.repolint src/ --update-baseline --reason "..."

Exit codes: 0 clean (every finding suppressed or baselined, no stale
baseline entries), 1 findings or stale baseline entries, 2 usage or
internal error. ``--out`` always writes the JSON report (CI uploads it
as an artifact) regardless of ``--format``.
"""
from __future__ import annotations

import argparse
import json
import os
import sys
from typing import List, Optional

from tools.repolint.core import (Baseline, Context, load_py_files,
                                 render_human, render_json, run_passes)
from tools.repolint.passes import FRAMEWORK_RULES, all_passes

_DEFAULT_BASELINE = os.path.join("tools", "repolint", "baseline.json")


def _find_root(start: str) -> str:
    """Nearest ancestor containing pyproject.toml (else ``start``)."""
    cur = os.path.abspath(start)
    while True:
        if os.path.isfile(os.path.join(cur, "pyproject.toml")):
            return cur
        parent = os.path.dirname(cur)
        if parent == cur:
            return os.path.abspath(start)
        cur = parent


def build_parser() -> argparse.ArgumentParser:
    p = argparse.ArgumentParser(
        prog="python -m tools.repolint",
        description="repo-specific static analysis: RNG discipline, "
                    "donation safety, tracing safety, Pallas kernel "
                    "lint, config-surface drift, doc links")
    p.add_argument("paths", nargs="*", default=None,
                   help="files/directories to lint (default: src/)")
    p.add_argument("--root", default=None,
                   help="repo root (default: nearest ancestor with "
                        "pyproject.toml)")
    p.add_argument("--format", choices=("human", "json"),
                   default="human", dest="fmt")
    p.add_argument("--out", default=None, metavar="FILE",
                   help="also write the JSON report to FILE")
    p.add_argument("--baseline", default=None, metavar="FILE",
                   help=f"baseline file (default: {_DEFAULT_BASELINE} "
                        f"under the root; missing file = empty)")
    p.add_argument("--no-baseline", action="store_true",
                   help="ignore the baseline: report every finding")
    p.add_argument("--no-stale-check", action="store_true",
                   help="don't fail on baseline entries that match no "
                        "current finding")
    p.add_argument("--select", default=None, metavar="RULES",
                   help="comma-separated rule codes to run "
                        "(e.g. RNG001,DON001)")
    p.add_argument("--list-rules", action="store_true",
                   help="print every rule with its pass and exit")
    p.add_argument("--update-baseline", action="store_true",
                   help="rewrite the baseline from the current "
                        "findings (requires --reason)")
    p.add_argument("--reason", default=None,
                   help="reason recorded on --update-baseline entries")
    p.add_argument("--vmem-budget", type=int, default=None,
                   metavar="BYTES",
                   help="per-pallas_call VMEM scratch budget for "
                        "PLK003 (default 16 MiB)")
    return p


def _list_rules() -> str:
    lines = []
    for ps in all_passes():
        for code, desc in sorted(ps.rules.items()):
            lines.append(f"{code:8s} [{ps.name}] {desc}")
    for code, desc in sorted(FRAMEWORK_RULES.items()):
        lines.append(f"{code:8s} [framework] {desc}")
    return "\n".join(lines)


def main(argv: Optional[List[str]] = None) -> int:
    args = build_parser().parse_args(argv)
    if args.list_rules:
        print(_list_rules())
        return 0
    if args.update_baseline and not args.reason:
        print("repolint: --update-baseline requires --reason",
              file=sys.stderr)
        return 2

    root = os.path.abspath(args.root) if args.root \
        else _find_root(os.getcwd())
    paths = args.paths or ["src"]
    for p in paths:
        if not os.path.exists(os.path.join(root, p)):
            print(f"repolint: no such path under {root}: {p}",
                  file=sys.stderr)
            return 2

    select = None
    if args.select:
        select = {r.strip() for r in args.select.split(",")
                  if r.strip()}

    options = {}
    if args.vmem_budget is not None:
        options["vmem_budget"] = args.vmem_budget

    try:
        py_files, parse_findings = load_py_files(root, paths)
        ctx = Context(root=root, py_files=py_files, options=options)
        passes = all_passes()
        findings = run_passes(ctx, passes, select=select,
                              parse_findings=parse_findings)
    except Exception as e:  # internal error -> exit 2, not a crash
        print(f"repolint: internal error: {type(e).__name__}: {e}",
              file=sys.stderr)
        return 2

    baseline_path = os.path.join(
        root, args.baseline or _DEFAULT_BASELINE)
    if args.no_baseline:
        baseline = Baseline([])
    else:
        try:
            baseline = Baseline.load(baseline_path)
        except (ValueError, json.JSONDecodeError) as e:
            print(f"repolint: bad baseline: {e}", file=sys.stderr)
            return 2

    if args.update_baseline:
        Baseline.from_findings(findings, args.reason).save(
            baseline_path)
        print(f"repolint: wrote {len(findings)} entr"
              f"{'y' if len(findings) == 1 else 'ies'} to "
              f"{os.path.relpath(baseline_path, root)}")
        return 0

    new, baselined, stale = baseline.apply(findings)
    if args.no_stale_check:
        stale = []

    if args.out:
        report = render_json(new, baselined, stale, all_passes())
        with open(os.path.join(root, args.out) if not
                  os.path.isabs(args.out) else args.out,
                  "w", encoding="utf-8") as fh:
            json.dump(report, fh, indent=2)
            fh.write("\n")

    if args.fmt == "json":
        print(json.dumps(render_json(new, baselined, stale,
                                     all_passes()), indent=2))
    else:
        print(render_human(new, baselined, stale))
    return 1 if (new or stale) else 0
