"""``python -m tools.repolint`` entry point."""
import sys

from tools.repolint.cli import main

if __name__ == "__main__":
    sys.exit(main())
