"""Roofline-term extraction from compiled (post-SPMD, per-device) HLO.

XLA's ``compiled.cost_analysis()`` counts while-loop bodies ONCE — for a
layer-scanned transformer that under-counts FLOPs by ~num_layers (we
verified: a 10-trip scan of a 128^3 matmul reports 4.19e6 flops, the
single-matmul count). So we parse the HLO text ourselves:

  * every computation's instructions are parsed into a symbol table
    (value name -> shape) so operand shapes resolve;
  * the ENTRY computation is walked recursively; ``while`` bodies are
    weighted by their trip count (the constant in the loop condition),
    nested loops multiply;
  * FLOPs: 2 * result_elements * K for every ``dot`` (K = product of the
    lhs contracting dims), including dots inside fusions;
  * HBM bytes: operand + result bytes of every top-level instruction
    (fusion internals are register/VMEM-resident; only boundaries touch
    HBM), excluding shape-only ops (tuple/get-tuple-element/bitcast/...);
  * collective bytes: result bytes of all-gather / all-reduce /
    reduce-scatter / all-to-all / collective-permute.

All numbers are PER DEVICE (the module is the per-device SPMD program).
"""
from __future__ import annotations

import math
import re
from typing import Dict, List, Optional, Tuple

# v5e hardware constants (per chip)
PEAK_FLOPS = 197e12          # bf16 TFLOP/s
HBM_BW = 819e9               # bytes/s
ICI_BW = 50e9                # bytes/s per link

_DTYPE_BYTES = {
    "f64": 8, "f32": 4, "f16": 2, "bf16": 2,
    "f8e4m3fn": 1, "f8e5m2": 1,
    "s64": 8, "s32": 4, "s16": 2, "s8": 1,
    "u64": 8, "u32": 4, "u16": 2, "u8": 1, "pred": 1,
    "c64": 8, "c128": 16,
}

_COLLECTIVES = ("all-gather", "all-reduce", "reduce-scatter", "all-to-all",
                "collective-permute")

# ops that move no data themselves
_SHAPE_ONLY = {"tuple", "get-tuple-element", "bitcast", "parameter",
               "constant", "after-all", "iota", "partition-id",
               "replica-id", "reshape"}

_SHAPE_RE = re.compile(r"(\w[\w.]*)\[([\d,]*)\]")
_INSTR_RE = re.compile(
    r"^\s*(?:ROOT\s+)?%([\w\.\-]+)\s*=\s*(.+?)\s+([\w\-]+)\(")
_COMP_HDR_RE = re.compile(r"^\s*(?:ENTRY\s+)?%?([\w\.\-]+)\s*\(")
_WHILE_RE = re.compile(r"body=%?([\w\.\-]+)")
_COND_RE = re.compile(r"condition=%?([\w\.\-]+)")
_CALLS_RE = re.compile(r"(?:calls|to_apply)=%?([\w\.\-]+)")
_CONST_RE = re.compile(r"constant\((\d+)\)")
_CONTRACT_RE = re.compile(r"lhs_contracting_dims=\{([\d,]*)\}")
_NAME_RE = re.compile(r"%([\w\.\-]+)")


def _shape_bytes_and_dims(type_str: str):
    """Parse 'f32[128,128]{1,0}' or a tuple '(s32[], f32[2,4])'.
    Returns (total_bytes, dims_of_first_array)."""
    total = 0
    first_dims: Optional[List[int]] = None
    for m in _SHAPE_RE.finditer(type_str):
        dtype, dims_s = m.group(1), m.group(2)
        if dtype not in _DTYPE_BYTES:
            continue
        dims = [int(d) for d in dims_s.split(",")] if dims_s else []
        total += math.prod(dims) * _DTYPE_BYTES[dtype] if dims \
            else _DTYPE_BYTES[dtype]
        if first_dims is None:
            first_dims = dims
    return total, (first_dims or [])


class _Instr:
    __slots__ = ("name", "op", "type_str", "result_bytes", "result_dims",
                 "operands", "line")

    def __init__(self, name, op, type_str, operands, line):
        self.name = name
        self.op = op
        self.type_str = type_str
        self.result_bytes, self.result_dims = _shape_bytes_and_dims(type_str)
        self.operands = operands
        self.line = line


def _parse_computations(hlo: str) -> Dict[str, List[_Instr]]:
    comps: Dict[str, List[_Instr]] = {}
    cur: Optional[str] = None
    for line in hlo.splitlines():
        stripped = line.strip()
        if cur is None:
            if stripped.endswith("{") and ("(" in stripped):
                m = _COMP_HDR_RE.match(line)
                if m:
                    cur = m.group(1)
                    comps[cur] = []
            continue
        if stripped == "}":
            cur = None
            continue
        m = _INSTR_RE.match(line)
        if not m:
            continue
        name, type_str, op = m.group(1), m.group(2), m.group(3)
        # operand names: %refs inside the first paren group only
        start = line.find(op + "(") + len(op) + 1
        depth = 1
        i = start
        while i < len(line) and depth:
            if line[i] == "(":
                depth += 1
            elif line[i] == ")":
                depth -= 1
            i += 1
        operands = _NAME_RE.findall(line[start:i - 1])
        comps[cur].append(_Instr(name, op, type_str, operands, line))
    return comps


def _trip_count(instrs: List[_Instr]) -> int:
    consts = []
    for ins in instrs:
        consts += [int(m.group(1)) for m in _CONST_RE.finditer(ins.line)]
    return max(consts) if consts else 1


class HloCost:
    """Trip-count-weighted per-device cost extracted from HLO text.

    ``score_seq_len``: when set, bytes of attention-SCORE-shaped buffers
    (result dims [..., score_seq_len, chunk<=score_seq_len]) are tallied
    separately in ``score_bytes``. These are the [B, H, S, chunk] online-
    softmax temporaries that only exist because the XLA fallback spills
    them to HBM; the Pallas flash kernel (kernels/flash_attention.py)
    keeps them VMEM-resident, so ``bytes - score_bytes`` is the measured
    projection of running the same program with the kernel.
    """

    def __init__(self, hlo: str, score_seq_len: Optional[int] = None):
        self.comps = _parse_computations(hlo)
        self.score_seq_len = score_seq_len
        self.score_bytes = 0.0
        self._memo: Dict[str, Tuple[float, float, Dict[str, float]]] = {}
        m = re.search(r"ENTRY\s+%?([\w\.\-]+)", hlo)
        self.entry = m.group(1) if m else next(iter(self.comps), None)
        if self.entry is not None and self.entry in self.comps:
            self.flops, self.bytes, self.coll, self.score_bytes = \
                self._walk(self.entry)
        else:
            self.flops, self.bytes, self.coll = 0.0, 0.0, {}

    def _is_score_like(self, dims) -> bool:
        S = self.score_seq_len
        if S is None or len(dims) < 2:
            return False
        return dims[-2] == S and 0 < dims[-1] <= S

    # ------------------------------------------------------------------
    def _symtab(self, name: str) -> Dict[str, _Instr]:
        return {ins.name: ins for ins in self.comps.get(name, [])}

    def _operand_bytes(self, ins: _Instr, tab: Dict[str, _Instr]) -> int:
        total = 0
        for op_name in ins.operands:
            ref = tab.get(op_name)
            if ref is not None:
                total += ref.result_bytes
        return total

    def _fusion_bytes(self, ins: _Instr, tab: Dict[str, _Instr],
                      callee: str) -> float:
        """HBM traffic of one fusion: operands that the fused body only
        dynamic-slices contribute the SLICE bytes, not the full buffer
        (XLA fuses the loop-carried cache slice into its consumers; the
        full [L, B, cap, ...] operand is never streamed). A fusion whose
        root dynamic-update-slices a parameter writes only the update."""
        body = self.comps.get(callee, [])
        btab = self._symtab(callee)
        # parameter index -> body value name
        param_of: Dict[str, int] = {}
        for b in body:
            if b.op == "parameter":
                m = re.search(r"parameter\((\d+)\)", b.line)
                if m:
                    param_of[b.name] = int(m.group(1))
        sliced_bytes: Dict[int, float] = {}
        fully_read: set = set()
        dus_write: float = -1.0
        for b in body:
            for oi, op_name in enumerate(b.operands):
                if op_name not in param_of:
                    continue
                idx = param_of[op_name]
                if b.op in ("dynamic-slice", "slice") and oi == 0:
                    sliced_bytes[idx] = sliced_bytes.get(idx, 0.0) \
                        + 2 * b.result_bytes
                elif b.op == "dynamic-update-slice" and oi == 0:
                    upd = btab.get(b.operands[1]) \
                        if len(b.operands) > 1 else None
                    w = 2 * (upd.result_bytes if upd else 0)
                    sliced_bytes[idx] = sliced_bytes.get(idx, 0.0) + w
                    if "ROOT" in b.line:
                        dus_write = max(dus_write, float(w))
                elif b.op == "gather" and oi == 0:
                    sliced_bytes[idx] = sliced_bytes.get(idx, 0.0) \
                        + 2 * b.result_bytes
                else:
                    fully_read.add(idx)
        total = 0.0
        for oi, op_name in enumerate(ins.operands):
            ref = tab.get(op_name)
            if ref is None:
                continue
            if oi in sliced_bytes and oi not in fully_read:
                total += sliced_bytes[oi]
            else:
                total += ref.result_bytes
        total += dus_write if dus_write >= 0 else ins.result_bytes
        return total

    def _dot_flops(self, ins: _Instr, tab: Dict[str, _Instr]) -> float:
        res_el = math.prod(ins.result_dims) if ins.result_dims else 1
        m = _CONTRACT_RE.search(ins.line)
        if not m or not ins.operands:
            return 0.0
        lhs = tab.get(ins.operands[0])
        if lhs is None:
            return 0.0
        k = 1
        for d in (int(x) for x in m.group(1).split(",") if x):
            if d < len(lhs.result_dims):
                k *= lhs.result_dims[d]
        return 2.0 * res_el * k

    # ------------------------------------------------------------------
    def _walk(self, name: str, depth: int = 0):
        if name in self._memo:
            return self._memo[name]
        if depth > 60 or name not in self.comps:
            return 0.0, 0.0, {}, 0.0
        tab = self._symtab(name)
        flops = 0.0
        bts = 0.0
        sb = 0.0  # attention-score-shaped traffic (flash-eliminable)
        coll: Dict[str, float] = {}

        def score_part(ins_, total):
            # split: bytes touching score-shaped buffers (result or
            # operands) count toward the flash-eliminable pool
            if self._is_score_like(ins_.result_dims):
                return total
            for opn in ins_.operands:
                ref = tab.get(opn)
                if ref is not None and self._is_score_like(ref.result_dims):
                    return total
            return 0.0

        for ins in self.comps[name]:
            op = ins.op
            if op == "dot":
                flops += self._dot_flops(ins, tab)
                b = ins.result_bytes + self._operand_bytes(ins, tab)
                bts += b
                sb += score_part(ins, b)
            elif any(op.startswith(c) for c in _COLLECTIVES):
                base = next(c for c in _COLLECTIVES if op.startswith(c))
                coll[base] = coll.get(base, 0.0) + ins.result_bytes
                bts += ins.result_bytes + self._operand_bytes(ins, tab)
            elif op == "fusion":
                cm = _CALLS_RE.search(ins.line)
                if cm and cm.group(1) in self.comps:
                    b = self._fusion_bytes(ins, tab, cm.group(1))
                    bts += b
                    sb += score_part(ins, b)
                    ftab = self._symtab(cm.group(1))
                    for fins in self.comps[cm.group(1)]:
                        if fins.op == "dot":
                            flops += self._dot_flops(fins, ftab)
                else:
                    bts += ins.result_bytes + self._operand_bytes(ins, tab)
            elif op == "while":
                bm = _WHILE_RE.search(ins.line)
                cm = _COND_RE.search(ins.line)
                trips = _trip_count(
                    self.comps.get(cm.group(1), [])) if cm else 1
                if bm and bm.group(1) != name:
                    f, b, c, s_ = self._walk(bm.group(1), depth + 1)
                    flops += f * trips
                    bts += b * trips
                    sb += s_ * trips
                    for k, v in c.items():
                        coll[k] = coll.get(k, 0.0) + v * trips
            elif op in ("call", "conditional", "custom-call", "async-start"):
                bts += ins.result_bytes + self._operand_bytes(ins, tab)
                for cm in _CALLS_RE.finditer(ins.line):
                    callee = cm.group(1)
                    if callee in self.comps and callee != name:
                        f, b, c, s_ = self._walk(callee, depth + 1)
                        flops += f
                        bts += b
                        sb += s_
                        for k, v in c.items():
                            coll[k] = coll.get(k, 0.0) + v
            elif op in _SHAPE_ONLY:
                continue
            elif op in ("dynamic-slice", "slice"):
                # touches only the sliced region (read) + result (write)
                bts += 2 * ins.result_bytes
            elif op == "dynamic-update-slice":
                # in-place: reads+writes only the update region
                if len(ins.operands) >= 2:
                    upd = tab.get(ins.operands[1])
                    bts += 2 * (upd.result_bytes if upd else 0)
            elif op == "gather":
                idx = tab.get(ins.operands[1]) if len(ins.operands) > 1 \
                    else None
                bts += 2 * ins.result_bytes + (idx.result_bytes if idx
                                               else 0)
            elif op == "scatter":
                # in-place on the big operand: traffic = updates + indices
                upd = tab.get(ins.operands[2]) if len(ins.operands) > 2 \
                    else None
                idx = tab.get(ins.operands[1]) if len(ins.operands) > 1 \
                    else None
                bts += 2 * (upd.result_bytes if upd else 0) \
                    + (idx.result_bytes if idx else 0)
            elif op == "broadcast":
                bts += ins.result_bytes + self._operand_bytes(ins, tab)
            else:
                # reduce / copy / convert / transpose / pad / ...
                b = ins.result_bytes + self._operand_bytes(ins, tab)
                bts += b
                sb += score_part(ins, b)
        self._memo[name] = (flops, bts, coll, sb)
        return self._memo[name]


def hlo_cost(hlo: str, score_seq_len: Optional[int] = None
             ) -> Dict[str, float]:
    hc = HloCost(hlo, score_seq_len=score_seq_len)
    return {"flops": hc.flops, "bytes": hc.bytes,
            "score_bytes": hc.score_bytes,
            "collective_breakdown": hc.coll,
            "collective_bytes": float(sum(hc.coll.values()))}


def collective_bytes(hlo: str) -> Dict[str, float]:
    """Per-kind collective result bytes (trip-count weighted)."""
    return HloCost(hlo).coll


def roofline_terms(flops: float, hbm_bytes: float, coll_bytes: float,
                   chips: int = 1) -> Dict[str, float]:
    """The three roofline times (seconds) + dominant term. Pass PER-DEVICE
    numbers with chips=1 (the HLO module is the per-device program)."""
    t_compute = flops / (chips * PEAK_FLOPS)
    t_memory = hbm_bytes / (chips * HBM_BW)
    t_coll = coll_bytes / (chips * ICI_BW)
    dominant = max(
        (("compute", t_compute), ("memory", t_memory),
         ("collective", t_coll)), key=lambda kv: kv[1])[0]
    return {"t_compute_s": t_compute, "t_memory_s": t_memory,
            "t_collective_s": t_coll, "dominant": dominant}
