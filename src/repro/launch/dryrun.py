import os
os.environ["XLA_FLAGS"] = ("--xla_force_host_platform_device_count=512 "
                           + os.environ.get("XLA_FLAGS", ""))

"""Multi-pod dry-run: lower + compile every (architecture x input shape)
on the production meshes, proving the distribution config is coherent
without hardware, and extract the roofline terms from the compiled HLO.

    PYTHONPATH=src python -m repro.launch.dryrun --arch qwen3-1.7b \
        --shape decode_32k [--multi-pod]
    PYTHONPATH=src python -m repro.launch.dryrun --all [--multi-pod]

Results land in experiments/dryrun/<arch>__<shape>__<mesh>.json and feed
benchmarks/roofline.py and EXPERIMENTS.md §Dry-run / §Roofline.
"""
import argparse
import json
import time
import traceback

import jax
import numpy as np

from repro.configs.base import SHAPES, input_specs, kv_cache_specs
from repro.configs.registry import ASSIGNED_ARCHS, get_config
from repro.launch import shardings as shd
from repro.launch.hlo_analysis import hlo_cost, roofline_terms
from repro.launch.mesh import make_production_mesh
from repro.launch.steps import make_decode_step, make_prefill_step, \
    make_train_step
from repro.models.init import init_params

OUT_DIR = os.path.join(os.path.dirname(__file__), "..", "..", "..",
                       "experiments", "dryrun")

# Gradient-accumulation factors chosen so peak train_4k HBM fits the
# 16 GiB v5e budget (measured via compiled.memory_analysis; see
# EXPERIMENTS.md §Dry-run). Archs not listed run the full batch at once.
TRAIN_MICROBATCHES = {
    "granite-20b": 2,
    "mixtral-8x7b": 2,
    "deepseek-v2-236b": 16,
    "seamless-m4t-large-v2": 2,
    "zamba2-2.7b": 2,
}

# deepseek-v2-236b: fp32 Adam moments are 1.9 TB — more than 7 GB/chip on
# a 256-chip pod before any activation. Stored bf16 (update math fp32);
# the gradient accumulator is likewise bf16 (every add is computed fp32).
TRAIN_MOMENT_DTYPE = {
    "deepseek-v2-236b": "bfloat16",
}
TRAIN_ACCUM_DTYPE = {
    "deepseek-v2-236b": "bfloat16",
}


def model_flops(cfg, shape) -> float:
    """MODEL_FLOPS = 6*N*D tokens (dense) / 6*N_active*D (MoE), where N
    counts ACTIVE non-embedding params and D = tokens processed."""
    from repro.models.init import padded_vocab

    # active params per token
    D = cfg.d_model
    n = 0
    if cfg.arch_type in ("ssm", "hybrid"):
        di = cfg.d_inner
        Nn = cfg.ssm_state_size
        per_mamba = D * (2 * di + 2 * Nn + cfg.ssm_heads) + di * D \
            + cfg.ssm_conv_width * (di + 2 * Nn)
        n += cfg.num_layers * per_mamba
        if cfg.arch_type == "hybrid":
            attn = D * cfg.num_heads * cfg.head_dim * 2 \
                + 2 * D * cfg.num_kv_heads * cfg.head_dim \
                + 3 * D * cfg.d_ff
            n += (cfg.num_layers // cfg.hybrid_attn_every) * attn
    else:
        if cfg.use_mla:
            attn = (D * cfg.q_lora_rank
                    + cfg.q_lora_rank * cfg.num_heads
                    * (cfg.qk_nope_head_dim + cfg.qk_rope_head_dim)
                    + D * (cfg.kv_lora_rank + cfg.qk_rope_head_dim)
                    + cfg.kv_lora_rank * cfg.num_heads
                    * (cfg.qk_nope_head_dim + cfg.v_head_dim)
                    + cfg.num_heads * cfg.v_head_dim * D)
        else:
            attn = D * cfg.num_heads * cfg.head_dim \
                + 2 * D * cfg.num_kv_heads * cfg.head_dim \
                + cfg.num_heads * cfg.head_dim * D
        if cfg.uses_moe:
            ff = 3 * D * cfg.moe_d_ff * (cfg.num_experts_per_tok
                                         + cfg.num_shared_experts)
        else:
            ff = 3 * D * cfg.d_ff
        n += cfg.num_layers * (attn + ff)
        if cfg.is_encoder_decoder:
            enc = cfg.num_encoder_layers * (attn + 3 * D * cfg.d_ff)
            n += enc + cfg.num_layers * attn  # cross attention
    n += D * padded_vocab(cfg)  # lm head
    if shape.kind == "train":
        tokens = shape.global_batch * shape.seq_len
        return 6.0 * n * tokens
    if shape.kind == "prefill":
        tokens = shape.global_batch * shape.seq_len
        return 2.0 * n * tokens
    return 2.0 * n * shape.global_batch  # decode: one token per sequence


def build_lowerable(cfg, shape_name, mesh, out=None):
    """Returns (jitted_fn, arg_shapedtypes) for this cfg x shape.

    ``out`` (optional dict) receives side info, e.g. the per-device bf16
    parameter bytes used for the CPU-upcast HBM adjustment.
    """
    shape = SHAPES[shape_name]
    in_specs = input_specs(cfg, shape_name)
    batch_specs = shd.partition_inputs(cfg, mesh, shape_name)
    batch_shardings = {k: jax.NamedSharding(mesh, batch_specs[k])
                       for k in in_specs}

    params_shapes = jax.eval_shape(
        lambda: init_params(cfg, jax.random.PRNGKey(0)))

    if shape.kind == "train":
        pspecs = shd.partition_params(cfg, mesh, params_shapes, fsdp=True)
        psh = shd.to_named(mesh, pspecs)
        from jax.sharding import PartitionSpec as P
        dp = ("pod", "data") if "pod" in mesh.axis_names else ("data",)
        act_spec = P(dp, None, "model")
        base_name = cfg.name.split("-smoke")[0]
        # NOTE: no moe_experts hoist at train time — measured on mixtral
        # train_4k it converts per-chunk weight all-gathers into per-layer
        # weight-grad all-reduces and makes the collective term WORSE
        # (115 s -> 139 s). See EXPERIMENTS.md #Perf iteration 1.
        step, opt = make_train_step(
            cfg, act_spec=act_spec,
            microbatches=TRAIN_MICROBATCHES.get(base_name, 1),
            moment_dtype=TRAIN_MOMENT_DTYPE.get(base_name, "float32"),
            accum_dtype=TRAIN_ACCUM_DTYPE.get(base_name, "float32"))
        opt_shapes = jax.eval_shape(opt.init, params_shapes)
        ospecs = shd.partition_opt_state(cfg, mesh, opt_shapes, pspecs)
        osh = shd.to_named(mesh, ospecs)
        if out is not None:
            import jax.numpy as jnp
            out["bf16_param_bytes_dev"] = shd.sharded_bytes_per_device(
                params_shapes, pspecs, mesh, dtype_filter=jnp.bfloat16)
        fn = jax.jit(step, in_shardings=(psh, osh, batch_shardings),
                     out_shardings=(psh, osh,
                                    jax.NamedSharding(mesh, P())),
                     donate_argnums=(0, 1))
        args = (params_shapes, opt_shapes, in_specs)
        return fn, args

    from jax.sharding import PartitionSpec as P2
    dp = ("pod", "data") if "pod" in mesh.axis_names else ("data",)
    b = shape.global_batch
    total_dp = 1
    for a in dp:
        total_dp *= mesh.shape[a]
    dp_ok = dp if b % total_dp == 0 else None
    kvsp = shd.kv_partition_specs(cfg, mesh, b)
    # MoE at serving time: move the dispatched activations to the
    # stationary (E-model, D-data)-sharded expert weights; even at
    # prefill the dispatched tokens (64 GB global for deepseek) are far
    # cheaper than per-layer weight gathers (450 GB). #Perf iteration.
    exin = shd.moe_ex_in_spec(cfg, mesh)
    if exin is not None:
        kvsp["moe_ex_in"] = exin

    pspecs = shd.partition_params(cfg, mesh, params_shapes)
    psh = shd.to_named(mesh, pspecs)
    if out is not None:
        import jax.numpy as jnp
        out["bf16_param_bytes_dev"] = shd.sharded_bytes_per_device(
            params_shapes, pspecs, mesh, dtype_filter=jnp.bfloat16)
    if shape.kind == "prefill":
        act_spec = P2(dp_ok, None, "model")
        step = make_prefill_step(cfg, act_spec=act_spec, kv_specs=kvsp)
        fn = jax.jit(step, in_shardings=(psh, batch_shardings))
        return fn, (params_shapes, in_specs)

    # decode: cache out-sharding == in-sharding (steady state, donated)
    cache_shapes = kv_cache_specs(cfg, shape_name)
    cspecs = shd.partition_cache(cfg, mesh, shape_name)
    csh = {k: jax.NamedSharding(mesh, cspecs[k]) for k in cache_shapes}
    step = make_decode_step(cfg, kv_specs=kvsp)
    out_sh = {"next_token": jax.NamedSharding(mesh, P2(dp_ok)),
              "hidden": jax.NamedSharding(mesh, P2(dp_ok, "model")),
              "cache": csh}
    fn = jax.jit(step, in_shardings=(psh, batch_shardings, csh),
                 out_shardings=out_sh, donate_argnums=(2,))
    return fn, (params_shapes, in_specs, cache_shapes)


def run_one(arch: str, shape_name: str, multi_pod: bool,
            save: bool = True) -> dict:
    cfg = get_config(arch)
    shape = SHAPES[shape_name]
    mesh_name = "pod2x16x16" if multi_pod else "pod16x16"
    rec = {"arch": arch, "shape": shape_name, "mesh": mesh_name,
           "status": "skip", "reason": None}

    if not cfg.supports_shape(shape):
        rec["reason"] = "unsupported shape (see DESIGN.md long_500k policy)"
        return rec
    if cfg.is_encoder_decoder and shape.kind == "decode" \
            and shape.name == "long_500k" \
            and cfg.long_context_window is None:
        rec["reason"] = "enc-dec without long-context window"
        return rec

    mesh = make_production_mesh(multi_pod=multi_pod)
    chips = int(np.prod(list(mesh.shape.values())))
    t0 = time.time()
    side = {}
    try:
        fn, args = build_lowerable(cfg, shape_name, mesh, out=side)
        with mesh:
            lowered = fn.lower(*args)
            t_lower = time.time() - t0
            compiled = lowered.compile()
            t_compile = time.time() - t0 - t_lower
        mem = compiled.memory_analysis()
        hlo = compiled.as_text()
        # trip-count-weighted per-device cost parsed from the HLO (XLA's
        # cost_analysis counts while bodies once — see hlo_analysis.py)
        cost = hlo_cost(hlo, score_seq_len=shape.seq_len
                        if shape.kind in ("train", "prefill") else None)
        flops = cost["flops"]            # per device
        hbm_bytes = cost["bytes"]        # per device
        coll_total = cost["collective_bytes"]
        terms = roofline_terms(flops, hbm_bytes, coll_total, chips=1)
        mf = model_flops(cfg, shape)     # global
        rec.update({
            "status": "ok",
            "chips": chips,
            "lower_s": round(t_lower, 2),
            "compile_s": round(t_compile, 2),
            "hlo_flops_per_dev": flops,
            "hlo_bytes_per_dev": hbm_bytes,
            "collective_bytes_per_dev": coll_total,
            "collective_breakdown": cost["collective_breakdown"],
            "model_flops_global": mf,
            "useful_flops_ratio": (mf / (flops * chips)) if flops else None,
            # measured projection: HBM traffic if attention ran as the
            # Pallas flash kernel (score temporaries VMEM-resident)
            "score_bytes_per_dev": cost.get("score_bytes", 0.0),
            "t_memory_flash_proj_s": (hbm_bytes - cost.get("score_bytes",
                                                           0.0)) / 819e9,
            **terms,
        })
        if mem is not None:
            for attr in ("temp_size_in_bytes", "argument_size_in_bytes",
                         "output_size_in_bytes", "generated_code_size_in_bytes"):
                v = getattr(mem, attr, None)
                if v is not None:
                    rec[attr] = int(v)
            args_b = rec.get("argument_size_in_bytes", 0)
            tmp_b = rec.get("temp_size_in_bytes", 0)
            rec["per_device_hbm_gib"] = round((args_b + tmp_b) / 2**30, 3)
            # XLA-CPU has no native bf16 matmul: it materialises fp32
            # copies of every bf16 weight (2x param bytes of pure temp
            # that does NOT exist on TPU, where the MXU consumes bf16
            # directly). Report the TPU-adjusted figure alongside raw.
            upcast = 2 * side.get("bf16_param_bytes_dev", 0)
            rec["cpu_f32_upcast_bytes_est"] = upcast
            rec["per_device_hbm_gib_tpu_adj"] = round(
                (args_b + max(tmp_b - upcast, 0)) / 2**30, 3)
    except Exception as e:  # noqa: BLE001 — a failure here IS the finding
        rec["status"] = "fail"
        rec["reason"] = f"{type(e).__name__}: {e}"
        rec["traceback"] = traceback.format_exc()[-2000:]
    if save:
        os.makedirs(OUT_DIR, exist_ok=True)
        path = os.path.join(OUT_DIR, f"{arch}__{shape_name}__{mesh_name}.json")
        with open(path, "w") as f:
            json.dump(rec, f, indent=2, default=str)
    return rec


def main():
    ap = argparse.ArgumentParser()
    ap.add_argument("--arch", default=None)
    ap.add_argument("--shape", default=None)
    ap.add_argument("--multi-pod", action="store_true")
    ap.add_argument("--all", action="store_true")
    ap.add_argument("--both-meshes", action="store_true")
    args = ap.parse_args()

    archs = list(ASSIGNED_ARCHS) if (args.all or not args.arch) \
        else [args.arch]
    shapes = list(SHAPES) if (args.all or not args.shape) else [args.shape]
    meshes = [False, True] if args.both_meshes else [args.multi_pod]

    results = []
    for mp in meshes:
        for arch in archs:
            for shape in shapes:
                rec = run_one(arch, shape, mp)
                results.append(rec)
                status = rec["status"]
                extra = ""
                if status == "ok":
                    extra = (f"dom={rec['dominant']} "
                             f"hbm={rec.get('per_device_hbm_gib', '?')}GiB "
                             f"compile={rec['compile_s']}s")
                elif rec.get("reason"):
                    extra = rec["reason"][:90]
                print(f"[{rec['mesh']}] {arch:24s} {shape:12s} "
                      f"{status:5s} {extra}", flush=True)
    n_ok = sum(r["status"] == "ok" for r in results)
    n_fail = sum(r["status"] == "fail" for r in results)
    n_skip = sum(r["status"] == "skip" for r in results)
    print(f"\ndry-run: {n_ok} ok, {n_fail} fail, {n_skip} skip "
          f"of {len(results)}")
    return 1 if n_fail else 0


if __name__ == "__main__":
    raise SystemExit(main())
