"""Training launcher.

Smoke scale (this container, executes for real):
    PYTHONPATH=src python -m repro.launch.train --arch qwen3-1.7b \
        --smoke --steps 50 --batch 8 --seq 128

Production scale lowers through the same make_train_step; use
``repro.launch.dryrun`` for the no-hardware 256/512-chip compile.
"""
from __future__ import annotations

import argparse
import time

import jax
import jax.numpy as jnp

from repro.configs.registry import get_config, serving_config
from repro.data.dataset import lm_batches
from repro.launch.steps import make_train_step
from repro.models.init import count_params, init_params
from repro.training.checkpoint import save_pytree


def main():
    ap = argparse.ArgumentParser()
    ap.add_argument("--arch", default="qwen3-4b-thinking")
    ap.add_argument("--smoke", action="store_true")
    ap.add_argument("--serving-vocab", action="store_true",
                    help="wire the smoke config to the task tokenizer")
    ap.add_argument("--steps", type=int, default=100)
    ap.add_argument("--batch", type=int, default=8)
    ap.add_argument("--seq", type=int, default=128)
    ap.add_argument("--lr", type=float, default=1e-3)
    ap.add_argument("--microbatches", type=int, default=1)
    ap.add_argument("--save", default=None, help="checkpoint path (.npz)")
    args = ap.parse_args()

    cfg = serving_config(args.arch) if args.serving_vocab \
        else get_config(args.arch, smoke=args.smoke)
    params = init_params(cfg, jax.random.PRNGKey(0))
    print(f"[train] arch={cfg.name} params={count_params(params):,}")

    step_fn, opt = make_train_step(cfg, lr=args.lr,
                                   microbatches=args.microbatches)
    opt_state = opt.init(params)
    step_fn = jax.jit(step_fn, donate_argnums=(0, 1))

    batches = lm_batches(args.seq, args.batch)
    t0 = time.time()
    for step in range(args.steps):
        arr = next(batches)
        batch = {"tokens": jnp.asarray(arr[:, :-1]),
                 "labels": jnp.asarray(arr[:, 1:])}
        params, opt_state, loss = step_fn(params, opt_state, batch)
        if step % 10 == 0 or step == args.steps - 1:
            print(f"  step {step:5d} loss {float(loss):.4f} "
                  f"({time.time() - t0:.1f}s)")
    if args.save:
        save_pytree(args.save, params)
        print(f"[train] saved to {args.save}")


if __name__ == "__main__":
    main()
