"""Serving launcher: run the STEP engine (or a baseline) over a batch of
synthetic reasoning requests with the trained artifacts.

    PYTHONPATH=src python -m repro.launch.serve --method step \
        --problems 8 --traces 16 [--blocks 64]
"""
from __future__ import annotations

import argparse

from repro.serving import (EngineConfig, SamplingParams, evaluate_method,
                           evaluate_method_batched, make_problems)


def main():
    ap = argparse.ArgumentParser()
    ap.add_argument("--method", default="step",
                    choices=["cot", "sc", "slimsc", "deepconf", "step"])
    ap.add_argument("--problems", type=int, default=4)
    ap.add_argument("--traces", type=int, default=16)
    ap.add_argument("--blocks", type=int, default=48,
                    help="paged KV pool size (the 'GPU memory')")
    ap.add_argument("--max-new", type=int, default=96)
    ap.add_argument("--difficulty", type=int, nargs=2, default=(5, 8),
                    metavar=("MIN", "MAX"), help="ops per problem")
    ap.add_argument("--seed", type=int, default=1234)
    ap.add_argument("--batched", action="store_true",
                    help="submit all problems to ONE engine as a "
                         "request queue (cross-request contention)")
    args = ap.parse_args()

    from benchmarks.common import load_artifacts
    params, scorer, cfg = load_artifacts()

    ecfg = EngineConfig(
        max_batch=args.traces, num_blocks=args.blocks, capacity=256,
        max_new_tokens=args.max_new,
        sampling=SamplingParams(max_new_tokens=args.max_new))
    problems = make_problems(args.problems, seed=args.seed,
                             n_steps=tuple(args.difficulty))
    pkw = {"warmup": max(2, args.traces // 4)} \
        if args.method == "deepconf" else {}
    eval_fn = evaluate_method_batched if args.batched else evaluate_method
    res = eval_fn(args.method, params, cfg, problems, args.traces,
                  ecfg, scorer_params=scorer, policy_kwargs=pkw,
                  verbose=True)
    print(f"\n[{args.method}] acc={res.accuracy:.2f} "
          f"tokens={res.avg_tokens:.0f} latency={res.avg_latency_s:.2f}s "
          f"wait={res.total_wait_s:.2f}s pruned={res.num_pruned} "
          f"preempt={res.num_preemptions}")


if __name__ == "__main__":
    main()
