"""Serving launcher: run the STEP engine (or a baseline) over a batch of
synthetic reasoning requests with the trained artifacts.

    PYTHONPATH=src python -m repro.launch.serve --method step \
        --problems 8 --traces 16 [--blocks 64]

Online serving (continuous batching): replay a Poisson arrival trace,
stream per-request completions, and print the TTFT/TPOT/e2e summary:

    python -m repro.launch.serve --method step --batched \
        --arrival-rate 2.0 --chunk 32 --max-tokens-per-step 64 --stream

Sharded serving: run the engine over a (data, model) device mesh.
``--mesh 2,2`` asks for data=2, model=2; ``--mesh auto`` adapts to
``jax.device_count()``. Simulate devices on a CPU host with
``XLA_FLAGS=--xla_force_host_platform_device_count=4``:

    XLA_FLAGS=--xla_force_host_platform_device_count=4 \
        python -m repro.launch.serve --method step --mesh 2,2
"""
from __future__ import annotations

import argparse
from typing import Optional

from repro.serving import (SLO, EngineConfig, SamplingParams,
                           TenantScheduler, evaluate_method,
                           evaluate_method_batched, make_problems,
                           parse_tenant_weights, poisson_arrivals)


def parse_mesh(spec: Optional[str]):
    """``None``/"none" -> no mesh; "auto" -> all devices on data;
    "D,M" -> explicit (data=D, model=M), validated against the device
    count with a clear error."""
    if spec is None or spec.lower() == "none":
        return None
    from repro.launch.mesh import make_host_mesh
    if spec.lower() == "auto":
        return make_host_mesh()
    try:
        data_s, model_s = spec.split(",")
        data, model = int(data_s), int(model_s)
    except ValueError:
        raise SystemExit(f"--mesh expects 'auto' or 'DATA,MODEL' "
                         f"(e.g. 2,2), got {spec!r}")
    return make_host_mesh(data, model)


def main():
    ap = argparse.ArgumentParser()
    ap.add_argument("--method", default="step",
                    choices=["cot", "sc", "slimsc", "deepconf", "step"])
    ap.add_argument("--problems", type=int, default=4)
    ap.add_argument("--traces", type=int, default=16)
    ap.add_argument("--blocks", type=int, default=48,
                    help="paged KV pool size (the 'GPU memory')")
    ap.add_argument("--max-new", type=int, default=96)
    ap.add_argument("--difficulty", type=int, nargs=2, default=(5, 8),
                    metavar=("MIN", "MAX"), help="ops per problem")
    ap.add_argument("--seed", type=int, default=1234)
    ap.add_argument("--batched", action="store_true",
                    help="submit all problems to ONE engine as a "
                         "request queue (cross-request contention)")
    ap.add_argument("--arrival-rate", type=float, default=0.0,
                    help="Poisson arrival rate (req/s); 0 = everything "
                         "at t=0 (offline batch). Implies --batched.")
    ap.add_argument("--chunk", type=int, default=0,
                    help="prefill chunk size in tokens (0 = one-shot "
                         "prefill)")
    ap.add_argument("--max-tokens-per-step", type=int, default=0,
                    help="per-tick token budget shared by decode and "
                         "prefill (0 = unlimited)")
    ap.add_argument("--decode-horizon", type=int, default=1,
                    help="fused decode horizon: K decode iterations per "
                         "jitted device call (1 = one token per tick)")
    ap.add_argument("--use-kernel", default="auto",
                    choices=["auto", "on", "off"],
                    help="Pallas paged-attention path for decode + "
                         "chunked prefill: 'auto' compiles the kernels "
                         "on TPU and keeps the dense XLA path on CPU "
                         "hosts; 'on' forces the kernels (interpret "
                         "mode on CPU — a correctness harness, not a "
                         "fast path there)")
    ap.add_argument("--kv-dtype", default=None,
                    choices=["f32", "bf16", "int8", "fp8"],
                    help="paged KV pool storage dtype: bf16 (default) "
                         "or f32 float pools, or int8/fp8 quantized "
                         "pools with per-page scales — ~4x (int8 vs "
                         "f32) more KV blocks in the same HBM budget, "
                         "dequantized inside the attention kernel "
                         "(default: bf16, or the REPRO_KV_DTYPE env "
                         "override; see docs/SUPPORT_MATRIX.md)")
    ap.add_argument("--prefix-cache", default=None,
                    choices=["on", "off"],
                    help="cross-request prefix caching: park completed "
                         "prompts' KV blocks in a radix tree and serve "
                         "matching prefixes of later requests with zero "
                         "recompute (default: on, or the "
                         "REPRO_PREFIX_CACHE env override)")
    ap.add_argument("--tenant-weights", default=None,
                    metavar="NAME:W,NAME:W",
                    help="multi-tenant serving: run the weighted-fair "
                         "TenantScheduler with these per-tenant weights "
                         "(e.g. 'premium:3,batch:1') and assign requests "
                         "to the named tenants round-robin. Implies "
                         "--batched. Default: single-tenant FIFO (or the "
                         "REPRO_SCHED env override).")
    ap.add_argument("--slo", default=None, metavar="TTFT[,TPOT]",
                    help="attach a per-request SLO (seconds): TTFT "
                         "target, optional TPOT target. The tenant "
                         "scheduler degrades a request's n_traces when "
                         "its projected TTFT would miss the target.")
    ap.add_argument("--deadline", type=float, default=None,
                    metavar="SECONDS",
                    help="per-request completion deadline (seconds from "
                         "serve start, same clock as arrivals): a "
                         "request still running past its deadline is "
                         "cancelled and reported with status "
                         "'deadline_exceeded'. Implies --batched.")
    ap.add_argument("--faults", default=None, metavar="SPEC",
                    help="deterministic fault-injection plan, e.g. "
                         "'step@2x3,alloc@5,nan@7:slot=1' — simulated "
                         "device-step failures (retried with backoff, "
                         "then degraded), allocation stalls, and NaN "
                         "logit poisoning (lane quarantined). Overrides "
                         "the REPRO_FAULTS env var. Implies --batched.")
    ap.add_argument("--stream", action="store_true",
                    help="print each request's result as it completes")
    ap.add_argument("--mesh", default=None, metavar="DATA,MODEL",
                    help="serve over a device mesh: 'auto' (all devices "
                         "on the data axis) or explicit sizes like "
                         "'2,2'; default: single-device engine")
    args = ap.parse_args()
    mesh = parse_mesh(args.mesh)

    from benchmarks.common import load_artifacts
    params, scorer, cfg = load_artifacts()

    # CLI flags override REPRO_* env vars, which override the dataclass
    # defaults (EngineConfig.from_env resolves env < explicit overrides).
    ecfg = EngineConfig.from_env(
        max_batch=args.traces, num_blocks=args.blocks, capacity=256,
        max_new_tokens=args.max_new,
        sampling=SamplingParams(max_new_tokens=args.max_new),
        prefill_chunk_size=args.chunk or None,
        max_tokens_per_step=args.max_tokens_per_step or None,
        decode_horizon=args.decode_horizon,
        use_kernel={"auto": "auto", "on": True, "off": False}[
            args.use_kernel],
        **({} if args.prefix_cache is None
           else {"prefix_cache": args.prefix_cache == "on"}),
        **({} if args.kv_dtype is None else {"kv_dtype": args.kv_dtype}),
        **({} if args.faults is None else {"faults": args.faults}))
    problems = make_problems(args.problems, seed=args.seed,
                             n_steps=tuple(args.difficulty))
    pkw = {"warmup": max(2, args.traces // 4)} \
        if args.method == "deepconf" else {}

    slo = None
    if args.slo is not None:
        parts = [float(x) for x in args.slo.split(",")]
        slo = SLO(ttft_s=parts[0],
                  tpot_s=parts[1] if len(parts) > 1 else None)
    scheduler = None
    overrides = None
    if args.tenant_weights is not None:
        weights = parse_tenant_weights(args.tenant_weights)
        scheduler = TenantScheduler(weights=weights)
        tenants = list(weights)
        overrides = [{"tenant": tenants[i % len(tenants)], "slo": slo}
                     for i in range(len(problems))]
    elif slo is not None:
        overrides = [{"slo": slo}] * len(problems)
    if args.deadline is not None:
        if overrides is None:
            overrides = [{} for _ in problems]
        overrides = [dict(o, deadline=args.deadline) for o in overrides]

    batched = args.batched or args.arrival_rate > 0 \
        or args.tenant_weights is not None \
        or args.deadline is not None or args.faults is not None
    if batched:
        arrivals = poisson_arrivals(len(problems), args.arrival_rate,
                                    seed=args.seed)

        def on_result(r):
            if not args.stream:
                return
            m = r.metrics
            if r.status != "completed" or m.ttft_s is None:
                # cancelled / deadline_exceeded / failed requests may
                # never have produced a first token
                print(f"  << q{r.request_id} {r.status}: "
                      f"tok={r.total_tokens}")
                return
            print(f"  << q{r.request_id} done: ans={r.answer} "
                  f"ttft={m.ttft_s:.2f}s tpot={m.tpot_s * 1e3:.0f}ms "
                  f"e2e={m.e2e_s:.2f}s tok={r.total_tokens}")

        res = evaluate_method_batched(
            args.method, params, cfg, problems, args.traces, ecfg,
            scorer_params=scorer, policy_kwargs=pkw,
            arrival_times=arrivals, on_result=on_result, mesh=mesh,
            scheduler=scheduler, request_overrides=overrides,
            verbose=not args.stream)
    else:
        res = evaluate_method(args.method, params, cfg, problems,
                              args.traces, ecfg, scorer_params=scorer,
                              policy_kwargs=pkw, mesh=mesh, verbose=True)

    print(f"\n[{args.method}] acc={res.accuracy:.2f} "
          f"tokens={res.avg_tokens:.0f} latency={res.avg_latency_s:.2f}s "
          f"wait={res.total_wait_s:.2f}s pruned={res.num_pruned} "
          f"preempt={res.num_preemptions}")
    if res.serving is not None:
        s = res.serving
        ended_early = (s["num_cancelled"] + s["num_deadline_exceeded"]
                       + s["num_failed"])
        if ended_early:
            print(f"[faults] cancelled={s['num_cancelled']} "
                  f"deadline_exceeded={s['num_deadline_exceeded']} "
                  f"failed={s['num_failed']} "
                  f"failed_traces={s['failed_traces']}")
    if res.serving is not None and res.serving["ttft_s"]["p50"] is not None:
        s = res.serving
        print(f"[serving] ttft p50={s['ttft_s']['p50']:.2f}s "
              f"p99={s['ttft_s']['p99']:.2f}s | "
              f"tpot p50={s['tpot_s']['p50'] * 1e3:.0f}ms | "
              f"e2e p50={s['e2e_s']['p50']:.2f}s "
              f"p99={s['e2e_s']['p99']:.2f}s | "
              f"throughput={s['throughput_tok_per_s']:.1f} tok/s")
        if s.get("slo", {}).get("requests_with_slo"):
            slo_s = s["slo"]
            att = {k: ("n/a" if slo_s[k] is None else f"{slo_s[k]:.2f}")
                   for k in ("ttft_attainment", "tpot_attainment")}
            print(f"[slo] requests={slo_s['requests_with_slo']} "
                  f"ttft_attainment={att['ttft_attainment']} "
                  f"tpot_attainment={att['tpot_attainment']} "
                  f"degraded_traces={s['degraded_traces']}")


if __name__ == "__main__":
    main()
