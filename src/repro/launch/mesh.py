"""Production meshes (TPU v5e).

Single pod: 256 chips as (data=16, model=16).
Multi-pod:  2 pods = 512 chips as (pod=2, data=16, model=16); the "pod"
axis carries only batch (data-parallel) sharding — gradients all-reduce
over ("pod", "data") — so the slow inter-pod DCI links never see tensor-
parallel collectives.

Defined as functions (never module-level constants) so importing this
module touches no jax device state.
"""
from __future__ import annotations

import jax


def make_production_mesh(*, multi_pod: bool = False):
    shape = (2, 16, 16) if multi_pod else (16, 16)
    axes = ("pod", "data", "model") if multi_pod else ("data", "model")
    return jax.make_mesh(shape, axes)


def make_host_mesh():
    """1-device mesh (CPU smoke paths) with the same axis names."""
    return jax.make_mesh((1, 1), ("data", "model"))


def data_axes(mesh) -> tuple:
    """The batch-sharding axes for this mesh."""
    return ("pod", "data") if "pod" in mesh.axis_names else ("data",)


def axis_size(mesh, name: str) -> int:
    if name not in mesh.axis_names:
        return 1
    return mesh.shape[name]
