"""Production meshes (TPU v5e) and host meshes (CPU, simulated devices).

Single pod: 256 chips as (data=16, model=16).
Multi-pod:  2 pods = 512 chips as (pod=2, data=16, model=16); the "pod"
axis carries only batch (data-parallel) sharding — gradients all-reduce
over ("pod", "data") — so the slow inter-pod DCI links never see tensor-
parallel collectives.

Host meshes adapt to however many host devices exist —
``XLA_FLAGS=--xla_force_host_platform_device_count=N`` simulates an
N-device CPU mesh, which is what the multi-device CI lane and the
sharded-serving tests run on.

Defined as functions (never module-level constants) so importing this
module touches no jax device state.
"""
from __future__ import annotations

from typing import Optional, Tuple

import jax


def make_production_mesh(*, multi_pod: bool = False):
    shape = (2, 16, 16) if multi_pod else (16, 16)
    axes = ("pod", "data", "model") if multi_pod else ("data", "model")
    return jax.make_mesh(shape, axes)


def resolve_host_mesh_shape(data: Optional[int] = None,
                            model: Optional[int] = None,
                            device_count: Optional[int] = None
                            ) -> Tuple[int, int]:
    """Resolve a ``(data, model)`` host-mesh shape against the available
    devices. ``None`` axes adapt: a missing ``model`` (or both) soaks up
    whatever ``data`` leaves, a missing ``data`` fills
    ``devices / model``. Requested sizes are validated with a clear
    error instead of jax's opaque "devices cannot be reshaped".
    """
    n = jax.device_count() if device_count is None else device_count

    def _check(name: str, val: int) -> None:
        if val < 1:
            raise ValueError(f"mesh axis {name!r} must be >= 1, got {val}")
        if n % val != 0 or val > n:
            raise ValueError(
                f"mesh axis {name}={val} does not divide the {n} available "
                f"device(s); run with XLA_FLAGS="
                f"--xla_force_host_platform_device_count=<N> to simulate "
                f"more CPU devices")

    if data is None and model is None:
        data, model = n, 1
    elif data is None:
        _check("model", model)
        data = n // model
    elif model is None:
        _check("data", data)
        model = n // data
    _check("data", data)
    _check("model", model)
    if data * model != n:
        raise ValueError(
            f"mesh (data={data}, model={model}) needs {data * model} "
            f"devices but {n} are available")
    return data, model


def make_host_mesh(data: Optional[int] = None, model: Optional[int] = None):
    """Host-device mesh with the production axis names.

    With no arguments this adapts to ``jax.device_count()`` (all devices
    on the data axis) — the old hard-coded ``(1, 1)`` only ever matched
    a single-device process. Explicit sizes are validated against the
    available devices; ``None`` axes are inferred (see
    ``resolve_host_mesh_shape``).
    """
    data, model = resolve_host_mesh_shape(data, model)
    return jax.make_mesh((data, model), ("data", "model"))


def data_axes(mesh) -> tuple:
    """The batch-sharding axes for this mesh."""
    return ("pod", "data") if "pod" in mesh.axis_names else ("data",)


def axis_size(mesh, name: str) -> int:
    if name not in mesh.axis_names:
        return 1
    return mesh.shape[name]
