"""Launchers: production mesh, sharding rules, step functions, dry-run.

NOTE: repro.launch.dryrun sets XLA_FLAGS for 512 host devices as its very
first statement — import it only in a dedicated process, never from tests
or benchmarks that need the real single-device CPU backend.
"""
