"""Sharding rules: parameters, optimizer state, inputs, decode caches.

Baseline layout (the paper-faithful production config):

  * batch over ("pod", "data") — the pod axis carries ONLY data
    parallelism, keeping tensor-parallel collectives on intra-pod ICI;
  * tensor parallel over "model": column-parallel for up-projections
    (wq/wk/wv/w_gate/w_up/router/...), row-parallel for down-projections
    (wo/w_down/w_out) — the Megatron pairing, so each attention/ffn block
    costs one all-reduce;
  * FSDP over "data" on a second weight dim when the tensor-parallel
    shard alone would not fit HBM (always on for training, where optimizer
    state is 6x params; adaptive for serving).

Everything is expressed as PartitionSpec trees over jax.eval_shape
pytrees — nothing here touches real devices.
"""
from __future__ import annotations

from typing import Optional, Tuple

import jax
import numpy as np
from jax.sharding import NamedSharding, PartitionSpec as P

from repro.configs.base import ModelConfig, SHAPES

# weight-name -> which dim the "model" axis shards
_MODEL_LAST = {"wq", "wk", "wv", "w_gate", "w_up", "wq_a", "wq_b", "wkv_a",
               "wk_b", "wv_b", "router", "w_in", "lm_head", "conv_w"}
_MODEL_CONTRACT = {"wo", "w_down", "w_out"}  # row-parallel (second-to-last)
_REPLICATE = {"A_log", "D", "dt_bias", "b1", "b2"}

# serving: add FSDP over "data" only when the TP shard would exceed this
SERVE_FSDP_THRESHOLD_BYTES = 8 * 2 ** 30  # 8 GiB of the 16 GiB v5e HBM


def _leaf_name(path) -> str:
    for entry in reversed(path):
        if hasattr(entry, "key"):
            return str(entry.key)
    return ""


def _pick_dim(shape, axis_size, used, prefer=None) -> Optional[int]:
    """First dim (preference order) divisible by axis_size and unused."""
    order = list(prefer) if prefer else []
    order += [d for d in range(len(shape)) if d not in order]
    for d in order:
        if d in used:
            continue
        if shape[d] % axis_size == 0 and shape[d] >= axis_size:
            return d
    return None


def param_spec(path, shape, mesh, fsdp: bool) -> P:
    """PartitionSpec for one parameter leaf."""
    name = _leaf_name(path)
    ndim = len(shape)
    model_n = mesh.shape["model"]
    data_n = mesh.shape["data"]
    spec = [None] * ndim
    used = set()

    if name in _REPLICATE or ndim < 2:
        return P(*spec) if ndim else P()

    in_experts = any(getattr(e, "key", None) == "experts" for e in path)

    if in_experts and ndim >= 3 and shape[-3] % model_n == 0:
        # EXPERT PARALLELISM: when the expert count divides the model
        # axis (deepseek: 160 experts / 16), shard experts across it —
        # the per-token dispatch stays [tokens, E/16] local and the
        # [G, E, C, D] dispatch buffers shard with the weights. MoE archs
        # whose E is small (mixtral: 8) fall through to tensor parallel.
        model_dim = ndim - 3  # the E dim of [L, E, D, F] / [L, E, F, D]
    elif name == "embed":
        model_dim = 0 if shape[0] % model_n == 0 else None
    elif name in _MODEL_CONTRACT:
        model_dim = _pick_dim(shape, model_n, used, prefer=[ndim - 2])
    elif name in _MODEL_LAST:
        model_dim = _pick_dim(shape, model_n, used, prefer=[ndim - 1])
    else:  # generic 2D+ tensor: prefer last dim
        model_dim = _pick_dim(shape, model_n, used, prefer=[ndim - 1])
    if model_dim is not None:
        spec[model_dim] = "model"
        used.add(model_dim)

    if fsdp:
        # never FSDP the layer-stack dim 0 of stacked layers (it scans);
        # prefer the largest remaining divisible dim
        sizes = [(shape[d], d) for d in range(1 if ndim > 2 else 0, ndim)
                 if d not in used]
        sizes.sort(reverse=True)
        for _, d in sizes:
            if shape[d] % data_n == 0 and shape[d] >= data_n:
                spec[d] = "data"
                break
    return P(*spec)


def param_bytes(shapes_tree) -> int:
    leaves = jax.tree_util.tree_leaves(shapes_tree)
    return sum(int(np.prod(l.shape)) * l.dtype.itemsize for l in leaves)


def sharded_bytes_per_device(shapes_tree, spec_tree, mesh,
                             dtype_filter=None) -> int:
    """Per-device bytes of a pytree under its PartitionSpec tree."""
    total = 0
    leaves, _ = jax.tree_util.tree_flatten(shapes_tree)
    specs, _ = jax.tree_util.tree_flatten(
        spec_tree, is_leaf=lambda x: isinstance(x, P))
    for leaf, spec in zip(leaves, specs):
        if dtype_filter is not None and leaf.dtype != dtype_filter:
            continue
        factor = 1
        for entry in spec:
            if entry is None:
                continue
            axes = entry if isinstance(entry, tuple) else (entry,)
            for a in axes:
                factor *= mesh.shape[a]
        total += int(np.prod(leaf.shape)) * leaf.dtype.itemsize // factor
    return total


def partition_params(cfg: ModelConfig, mesh, shapes_tree,
                     fsdp: Optional[bool] = None):
    """PartitionSpec tree for a param (or optimizer-moment) pytree."""
    if fsdp is None:
        model_n = mesh.shape["model"]
        fsdp = param_bytes(shapes_tree) / model_n > SERVE_FSDP_THRESHOLD_BYTES

    def one(path, leaf):
        if leaf.ndim == 0:
            return P()
        return param_spec(path, leaf.shape, mesh, fsdp)

    return jax.tree_util.tree_map_with_path(one, shapes_tree)


def partition_opt_state(cfg: ModelConfig, mesh, opt_shapes, param_specs):
    """Optimizer state mirrors the param sharding (mu/nu per leaf)."""
    def one(path, leaf):
        if leaf.ndim == 0:
            return P()
        # paths look like (.mu, <param path...>) — reuse param rules
        return param_spec(path, leaf.shape, mesh, fsdp=True)

    return jax.tree_util.tree_map_with_path(one, opt_shapes)


def _dp(mesh) -> Tuple[str, ...]:
    return ("pod", "data") if "pod" in mesh.axis_names else ("data",)


def _batch_axes(mesh, batch: int):
    dp = _dp(mesh)
    total = 1
    for a in dp:
        total *= mesh.shape[a]
    return dp if batch % total == 0 else None


def partition_inputs(cfg: ModelConfig, mesh, shape_name: str) -> dict:
    """PartitionSpecs matching input_specs(cfg, shape_name) keys."""
    shape = SHAPES[shape_name]
    b = shape.global_batch
    dp = _batch_axes(mesh, b)
    specs: dict = {}
    if shape.kind == "train":
        specs["tokens"] = P(dp, None)
        specs["labels"] = P(dp, None)
    elif shape.kind == "prefill":
        specs["tokens"] = P(dp, None)
    else:
        specs["tokens"] = P(dp, None)
        specs["positions"] = P(dp)
    if cfg.modality == "vision" and shape.kind in ("train", "prefill"):
        specs["modality_embeds"] = P(dp, None, None)
    if cfg.is_encoder_decoder and shape.kind in ("train", "prefill"):
        specs["encoder_embeds"] = P(dp, None, None)
    return specs


def _model_dim_for_cache(shape, mesh, candidates):
    model_n = mesh.shape["model"]
    for d in candidates:
        if shape[d] % model_n == 0 and shape[d] >= model_n:
            return d
    return None


def partition_cache(cfg: ModelConfig, mesh, shape_name: str) -> dict:
    """PartitionSpecs matching kv_cache_specs keys (contiguous layout).

    Batch over data axes; heads (or the latent/head_dim when heads don't
    divide) over model. The per-sequence contiguous layout means no
    cross-shard gathers: each data shard's sequences live entirely on it.
    """
    from repro.configs.base import kv_cache_specs
    specs = kv_cache_specs(cfg, shape_name)
    b = SHAPES[shape_name].global_batch
    dp = _batch_axes(mesh, b)
    out: dict = {}
    for key, sds in specs.items():
        nd = len(sds.shape)
        spec = [None] * nd
        if key in ("k_cache", "v_cache"):
            spec[1] = dp
            # heads when they divide; otherwise sequence-shard the cache
            # length (see kv_partition_specs) — never the head_dim
            md = _model_dim_for_cache(sds.shape, mesh, (3, 2))
            if md is not None:
                spec[md] = "model"
        elif key == "kv_cache":
            spec[1] = dp
            md = _model_dim_for_cache(sds.shape, mesh, (2,))  # cap
            if md is not None:
                spec[md] = "model"
        elif key == "ssm_state":
            spec[1] = dp
            md = _model_dim_for_cache(sds.shape, mesh, (2, 4))  # H, N
            if md is not None:
                spec[md] = "model"
        elif key == "conv_state":
            spec[1] = dp
            md = _model_dim_for_cache(sds.shape, mesh, (3,))
            if md is not None:
                spec[md] = "model"
        elif key in ("cross_k", "cross_v"):
            spec[1] = dp
            md = _model_dim_for_cache(sds.shape, mesh, (3, 4))
            if md is not None:
                spec[md] = "model"
        out[key] = P(*spec)
    return out


def kv_partition_specs(cfg: ModelConfig, mesh, batch: int) -> dict:
    """PartitionSpecs for the PER-LAYER (unstacked) KV/state tensors the
    model emits at prefill and carries at decode:

      kv   [B, S|cap, KVH, hd]     mla  [B, S|cap, lora+rope]
      ssm  [B, H, P, N]            conv [B, W-1, C]

    Batch over data axes; heads (falling back to head_dim / latent dims /
    state when heads don't divide) over model. Threaded into forward_full
    and serve_decode_step as with_sharding_constraints so GSPMD never
    replicates the caches (the dominant serving bytes) over model.
    """
    model_n = mesh.shape["model"]
    dp = _batch_axes(mesh, batch)

    def div(n):
        return n % model_n == 0 and n >= model_n

    out = {}
    if cfg.num_kv_heads and cfg.head_dim:
        if div(cfg.num_kv_heads):
            out["kv"] = P(dp, None, "model", None)
        else:
            # SEQUENCE-SHARDED cache (flash-decoding style). Sharding the
            # head_dim instead forces GSPMD to all-gather the whole cache
            # every step (scores contract hd): measured 30.6 GB/device/
            # step for qwen3 decode_32k. Sharding the cache-length dim
            # keeps all reads local; the softmax renormalisation costs
            # only tiny [B,KVH,G] all-reduces.
            out["kv"] = P(dp, "model", None, None)
    if cfg.use_mla:
        # same reasoning: scores contract the latent dim — shard cap
        out["mla"] = P(dp, "model", None)
    if cfg.arch_type in ("ssm", "hybrid"):
        if div(cfg.ssm_heads):
            out["ssm"] = P(dp, "model", None, None)
        elif div(cfg.ssm_state_size):
            out["ssm"] = P(dp, None, None, "model")
        else:
            out["ssm"] = P(dp, None, None, None)
        C = cfg.d_inner + 2 * cfg.ssm_state_size
        out["conv"] = P(dp, None, "model" if div(C) else None)
    return out


def moe_expert_specs(cfg: ModelConfig, mesh) -> Optional[dict]:
    """FSDP-free PartitionSpecs for the UNSTACKED per-layer expert weights
    ([E, D, F] / [E, F, D]). Constraining the weights to these before the
    MoE group-chunk scan hoists the FSDP all-gather out of the loop
    (otherwise it repeats per chunk — the dominant collective term for
    mixtral train_4k)."""
    if not cfg.uses_moe:
        return None
    model_n = mesh.shape["model"]
    E, D, F = cfg.num_experts, cfg.d_model, cfg.moe_d_ff

    def div(n):
        return n % model_n == 0 and n >= model_n

    if div(E):  # expert parallel
        return {"w_gate": P("model", None, None),
                "w_up": P("model", None, None),
                "w_down": P("model", None, None)}
    if div(F):  # tensor parallel on the ffn dim
        return {"w_gate": P(None, None, "model"),
                "w_up": P(None, None, "model"),
                "w_down": P(None, "model", None)}
    return {"w_gate": P(None, None, None),
            "w_up": P(None, None, None),
            "w_down": P(None, None, None)}


def moe_ex_in_spec(cfg: ModelConfig, mesh) -> Optional[P]:
    """Decode-time layout for the dispatched expert inputs [G, E, C, D]:
    E over model (matching expert-parallel weights), D over data
    (matching the weights' FSDP dim) — forces activation movement
    instead of per-step weight all-gathers."""
    if not cfg.uses_moe:
        return None
    model_n = mesh.shape["model"]
    data_n = mesh.shape["data"]
    e = "model" if cfg.num_experts % model_n == 0 else None
    d = "data" if cfg.d_model % data_n == 0 else None
    return P(None, e, None, d)


def to_named(mesh, spec_tree):
    return jax.tree.map(lambda s: NamedSharding(mesh, s), spec_tree,
                        is_leaf=lambda x: isinstance(x, P))


# ---------------------------------------------------------------------------
# serving: Engine-over-a-mesh layout (docs/ENGINE.md "Sharded serving")
# ---------------------------------------------------------------------------
# The serving engine's mesh layout trades a third of the tensor-parallel
# memory win for EXACTNESS: column-parallel weights shard over "model"
# as usual, but the row-parallel contraction set (wo / w_down / w_out)
# is replicated, so GSPMD's only cross-shard collectives are
# all-gathers of activations — pure data movement, never a
# floating-point reduction. A bf16 psum from a row-parallel contraction
# rounds partial sums differently than the single-device matmul and
# flips near-tie samples; with this layout the sharded engine's logits
# are bit-identical to the single-device engine's, which is what lets
# CI pin token-identity across device counts.


def _div(n: int, axis_n: int) -> bool:
    return n >= axis_n and n % axis_n == 0


def serving_param_specs(cfg: ModelConfig, mesh, shapes_tree):
    """PartitionSpec tree for serving params (exactness-preserving TP).

    Only the column-parallel matmul set (``_MODEL_LAST``, on its OUTPUT
    dim) and the embedding's vocab dim shard over "model", and only
    when that exact dim divides the axis. EVERYTHING else is
    replicated: the row-parallel contraction weights (a sharded
    contraction psums), the stacked per-layer norm scales ``[L, D]``
    (which the training layout's generic 2-D rule would shard on D,
    turning every downstream QKV/MLP contraction into a partial-sum),
    and — unlike ``param_spec`` — there is no fallback to *other* dims:
    ``_pick_dim``'s fallback could land the "model" axis on a
    contraction or layer-stack dim when the output dim doesn't divide,
    silently breaking the bit-identity contract.

    No FSDP: serving carries no optimizer state, and the decode path
    re-reads every weight each step — "data" is reserved for the
    trace batch.
    """
    model_n = mesh.shape["model"]

    def one(path, leaf):
        ndim = leaf.ndim
        if ndim == 0:
            return P()
        spec = [None] * ndim
        name = _leaf_name(path)
        if name == "embed" and _div(leaf.shape[0], model_n):
            spec[0] = "model"  # vocab dim: gather + D-contraction, exact
        elif name in _MODEL_LAST and ndim >= 2 \
                and _div(leaf.shape[-1], model_n):
            spec[-1] = "model"  # column-parallel output dim
        return P(*spec)

    return jax.tree_util.tree_map_with_path(one, shapes_tree)


def serving_cache_specs(cfg: ModelConfig, mesh,
                        kv_dtype: str = "bf16") -> dict:
    """PartitionSpecs for the engine's paged decode cache
    (``init_decode_cache`` keys; ``block_tables`` excluded — the tables
    are host-side scheduler state, uploaded data-sharded per tick).

    Paged pools ``[L*, NB, bs, KVH, hd]``: KV heads over "model" when
    they divide it (each shard holds its heads' slice of EVERY block);
    the block dim stays replicated over "data" so any lane reads any
    block locally — the host allocator stays global and per-tick writes
    move only ``[B, KVH, hd]`` activations, never cache bytes. Per-slot
    recurrent state and cross-attention caches shard their batch dim
    over "data" with the lanes that own them. MLA's fused latent pool
    is replicated (the latent dim is contracted by every head).

    Quantized pools (``kv_dtype`` int8/fp8) add per-slot scale arrays
    ``k_scale``/``v_scale`` ``[L*, NB, bs, KVH]`` sharded exactly like
    the pools they describe: KV heads over "model", blocks replicated.

    The MLA/ssm/hybrid/enc-dec branches record the INTENDED layout for
    archs ``Engine._place_on_mesh`` still refuses (NotImplementedError)
    — unreachable from the engine today, kept so lifting the guard is a
    constraint-audit, not a design task.
    """
    model_n = mesh.shape["model"]
    out: dict = {}
    if cfg.attention_layer_ids():
        if cfg.use_mla:
            out["kv_pool"] = P(None, None, None, None)
        else:
            kvh = "model" if _div(cfg.num_kv_heads, model_n) else None
            out["k_pool"] = P(None, None, None, kvh, None)
            out["v_pool"] = P(None, None, None, kvh, None)
            from repro.models.kv_quant import is_quantized
            if is_quantized(kv_dtype):
                out["k_scale"] = P(None, None, None, kvh)
                out["v_scale"] = P(None, None, None, kvh)
    if cfg.arch_type in ("ssm", "hybrid"):
        out["ssm_state"] = P(None, "data", None, None, None)
        out["conv_state"] = P(None, "data", None, None)
    if cfg.is_encoder_decoder:
        kvh = "model" if _div(cfg.num_kv_heads, model_n) else None
        out["cross_k"] = P(None, "data", None, kvh, None)
        out["cross_v"] = P(None, "data", None, kvh, None)
    return out


def serving_prefill_kv_specs(cfg: ModelConfig, mesh) -> dict:
    """NamedShardings for the PER-LAYER prefill KV/state tensors
    (``forward_full(return_kv=True)``'s ``kv_specs`` hook) on the
    serving mesh. Prefill runs per request at batch 1, so only head
    dims shard; keeping the emitted KV head-aligned with the pool
    specs means the pool scatter never reshards cache bytes."""
    model_n = mesh.shape["model"]
    out = {}
    if cfg.num_kv_heads and cfg.head_dim:
        kvh = "model" if _div(cfg.num_kv_heads, model_n) else None
        out["kv"] = P(None, None, kvh, None)
    if cfg.use_mla:
        out["mla"] = P(None, None, None)
    if cfg.arch_type in ("ssm", "hybrid"):
        out["ssm"] = P(None, None, None, None)
        out["conv"] = P(None, None, None)
    return {k: NamedSharding(mesh, s) for k, s in out.items()}


def serving_step_shardings(cfg: ModelConfig, mesh,
                           kv_dtype: str = "bf16") -> dict:
    """The NamedSharding bundle the engine threads through its jitted
    steps (``Engine._build_steps``) and into
    ``multi_decode_step(shard_specs=...)``:

      lane        [B]        trace-batch state over "data"
      table       [B, ...]   block tables / per-lane [B, K] outputs
      hidden      [B, D]     last hidden state — data-sharded, so the
                             step scorer is a shard-local matmul
                             (score capture without cross-device
                             gathers)
      act         [B, 1, *]  decode attention/MLP outputs right before
                             their row contraction (exact-TP gather
                             point, see serving_param_specs)
      prefill_act [1, S, *]  same gather point for batch-1 prefills
      pools       stacked-cache dict (serving_cache_specs)
      layer_pool  per-layer pool slices inside the layer scan
      replicated  RNG keys, scorer params, batch-1 prefill logits
    """
    cache = serving_cache_specs(cfg, mesh, kv_dtype)
    return {
        "lane": NamedSharding(mesh, P("data")),
        "table": NamedSharding(mesh, P("data", None)),
        "hidden": NamedSharding(mesh, P("data", None)),
        "act": NamedSharding(mesh, P("data", None, None)),
        "prefill_act": NamedSharding(mesh, P(None, None, None)),
        "pools": {k: NamedSharding(mesh, s) for k, s in cache.items()},
        "layer_pool": {k: NamedSharding(mesh, P(*s[1:]))
                       for k, s in cache.items()},
        "replicated": NamedSharding(mesh, P()),
    }
