"""Step functions the launcher lowers: train / prefill / serve-decode.

These are the production entry points — the same model code paths the
engine and trainer exercise, wrapped for pjit lowering on the big meshes.
"""
from __future__ import annotations

from typing import Callable, Tuple

import jax
import jax.numpy as jnp

from repro.configs.base import ModelConfig
from repro.models.model import forward_full, lm_loss, serve_decode_step
from repro.training.optimizer import AdamW


def make_train_step(cfg: ModelConfig, lr: float = 1e-4,
                    act_spec=None,
                    microbatches: int = 1,
                    moment_dtype: str = "float32",
                    accum_dtype: str = "float32",
                    kv_specs=None
                    ) -> Tuple[Callable, AdamW]:
    """Full training step: fwd (remat + sharded residual stream) + bwd +
    AdamW update.

    ``microbatches > 1`` enables gradient accumulation: the global batch
    is split into k sequential microbatches whose fp32 gradients
    accumulate before one optimizer update — the lever that bounds peak
    activation memory for the largest train_4k configs.
    """
    opt = AdamW(learning_rate=lr, weight_decay=0.01,
                moment_dtype=moment_dtype)

    def loss_fn(p, batch):
        return lm_loss(p, cfg, batch["tokens"], batch["labels"],
                       remat=True, act_spec=act_spec, kv_specs=kv_specs,
                       modality_embeds=batch.get("modality_embeds"),
                       encoder_embeds=batch.get("encoder_embeds"))

    def train_step(params, opt_state, batch):
        if microbatches == 1:
            loss, grads = jax.value_and_grad(loss_fn)(params, batch)
        else:
            k = microbatches

            def split(x):
                return jnp.moveaxis(
                    x.reshape(k, x.shape[0] // k, *x.shape[1:]), 0, 0)

            mbs = {key: split(v) for key, v in batch.items()}

            adt = jnp.dtype(accum_dtype)

            def body(acc, mb):
                l, g = jax.value_and_grad(loss_fn)(params, mb)
                acc = jax.tree.map(
                    lambda a, b: (a.astype(jnp.float32)
                                  + b.astype(jnp.float32)).astype(adt),
                    acc, g)
                return acc, l

            gacc0 = jax.tree.map(
                lambda p: jnp.zeros(p.shape, adt), params)
            gacc, losses = jax.lax.scan(body, gacc0, mbs)
            grads = jax.tree.map(lambda g: g.astype(jnp.float32) / k, gacc)
            loss = jnp.mean(losses)
        params, opt_state = opt.update(grads, opt_state, params)
        return params, opt_state, loss

    return train_step, opt


def make_prefill_step(cfg: ModelConfig, act_spec=None,
                      kv_specs=None) -> Callable:
    """Prefill: full forward over the prompt, returning the last-position
    logits (to sample the first token) and the KV/state to seed decode."""

    def prefill_step(params, batch):
        out = forward_full(params, cfg, batch["tokens"], return_kv=True,
                           act_spec=act_spec, kv_specs=kv_specs,
                           modality_embeds=batch.get("modality_embeds"),
                           encoder_embeds=batch.get("encoder_embeds"))
        return {"next_logits": out["logits"][:, -1], "kvs": out["kvs"]}

    return prefill_step


def make_decode_step(cfg: ModelConfig, kv_specs=None) -> Callable:
    """One decode step over the distributed contiguous cache; returns the
    greedy next token, the STEP-scorer hidden state, and the new cache."""

    def decode_fn(params, batch, cache):
        out = serve_decode_step(params, cfg, batch["tokens"],
                                batch["positions"], cache,
                                kv_specs=kv_specs)
        next_tok = jnp.argmax(out["logits"], axis=-1).astype(jnp.int32)
        return {"next_token": next_tok, "hidden": out["hidden"],
                "cache": out["cache"]}

    return decode_fn
