"""Architecture-dispatching forward passes.

Three entry points, shared by training, serving and the dry-run launcher:

  forward_full(...)  — full-sequence forward (train / prefill), scan over
                       stacked layer params.
  decode_step(...)   — one-token decode against the paged KV cache; also
                       returns the last hidden state so the STEP scorer can
                       run fused with generation.
  encode(...)        — encoder stack for enc-dec archs (stub frontend
                       embeddings in, memory out).
"""
from __future__ import annotations

from typing import Optional

import jax
import jax.numpy as jnp

from repro.configs.base import ModelConfig
from repro.models import kv_quant
from repro.models import layers as L
from repro.models.init import padded_vocab


def _embed(params, cfg, tokens):
    return params["embed"][tokens]


def _wsc_kv(kv_specs, key, x):
    # Constrain a per-layer KV/state tensor to its launcher-provided
    # PartitionSpec (no-op outside the distributed launch path).
    if kv_specs is None or key not in kv_specs or x is None:
        return x
    return jax.lax.with_sharding_constraint(x, kv_specs[key])


def _logits(params, cfg, h):
    if cfg.tie_embeddings:
        return h @ params["embed"].T
    return h @ params["lm_head"]


# ---------------------------------------------------------------------------
# encoder (enc-dec archs; consumes stub frontend embeddings)
# ---------------------------------------------------------------------------

def encode(params: dict, cfg: ModelConfig, encoder_embeds: jax.Array,
           remat: bool = False, act_spec=None) -> jax.Array:
    h = encoder_embeds
    B, T, D = h.shape
    positions = jnp.broadcast_to(jnp.arange(T)[None], (B, T))

    def wsc(x):
        if act_spec is None:
            return x
        return jax.lax.with_sharding_constraint(x, act_spec)

    def body(h, lp):
        a = L.gqa_attention_full(lp["attn"], cfg,
                                 L.rms_norm(h, lp["ln1"], cfg.norm_eps),
                                 positions, window=None)
        h = h + a
        m = L.swiglu(lp["mlp"], L.rms_norm(h, lp["ln2"], cfg.norm_eps))
        return wsc(h + m), None

    if remat:
        body = jax.checkpoint(body)
    h, _ = jax.lax.scan(body, wsc(h), params["encoder"])
    return L.rms_norm(h, params["encoder_norm"], cfg.norm_eps)


# ---------------------------------------------------------------------------
# full-sequence forward (train / prefill)
# ---------------------------------------------------------------------------

def forward_full(params: dict, cfg: ModelConfig, tokens: jax.Array,
                 modality_embeds: Optional[jax.Array] = None,
                 encoder_embeds: Optional[jax.Array] = None,
                 use_kernel: bool = False,
                 return_kv: bool = False,
                 remat: bool = False,
                 act_spec=None,
                 kv_specs=None,
                 tp_act_spec=None) -> dict:
    """Returns {logits, hidden, aux_loss[, kvs]}.

    ``tp_act_spec`` (serving mesh prefill): the sharding the
    attention/MLP activations are constrained to around their row
    contractions, so the exactness-preserving tensor-parallel layout
    never partial-sums (see ``layers.swiglu``).

    ``remat=True`` checkpoints each layer body (save only the residual
    stream per layer; recompute attention/ffn intermediates in backward) —
    required for the train_4k activations to fit HBM at full scale.

    ``act_spec`` (PartitionSpec for [B, S, D]) pins the residual-stream
    sharding between layers: the rematerialised per-layer carries are the
    dominant training activation term, and without an explicit constraint
    GSPMD leaves them replicated over the model axis (16x the bytes)."""
    B, S = tokens.shape

    def wsc(x):
        if act_spec is None:
            return x
        return jax.lax.with_sharding_constraint(x, act_spec)

    h = wsc(_embed(params, cfg, tokens))
    positions = jnp.broadcast_to(jnp.arange(S)[None], (B, S))
    aux_total = jnp.zeros((), jnp.float32)

    if cfg.modality == "vision" and modality_embeds is not None:
        n = modality_embeds.shape[1]
        h = jnp.concatenate(
            [modality_embeds.astype(h.dtype), h[:, n:]], axis=1)

    window = cfg.sliding_window

    if cfg.arch_type == "ssm":
        def body(h, lp):
            x_in = L.rms_norm(h, lp["norm"], cfg.norm_eps)
            if return_kv:
                out, ss, cs = L.mamba2_mixer_full(
                    lp["mixer"], cfg, x_in, use_kernel=use_kernel,
                    return_state=True)
                return wsc(h + out), (_wsc_kv(kv_specs, "ssm", ss),
                                      _wsc_kv(kv_specs, "conv", cs))
            out = L.mamba2_mixer_full(lp["mixer"], cfg, x_in,
                                      use_kernel=use_kernel)
            return wsc(h + out), None
        if remat:
            body = jax.checkpoint(body)
        h, kvs = jax.lax.scan(body, h, params["layers"])

    elif cfg.arch_type == "hybrid":
        sa = params["shared_attn"]

        def group_body(h, gp):
            def layer_body(h, lp):
                x_in = L.rms_norm(h, lp["norm"], cfg.norm_eps)
                if return_kv:
                    out, ss, cs = L.mamba2_mixer_full(
                        lp["mixer"], cfg, x_in, use_kernel=use_kernel,
                        return_state=True)
                    return h + out, (_wsc_kv(kv_specs, "ssm", ss),
                                     _wsc_kv(kv_specs, "conv", cs))
                out = L.mamba2_mixer_full(lp["mixer"], cfg, x_in,
                                          use_kernel=use_kernel)
                return h + out, None
            if remat:
                layer_body = jax.checkpoint(layer_body)
            h, states = jax.lax.scan(layer_body, h, gp)
            a_in = L.rms_norm(h, sa["ln1"], cfg.norm_eps)
            if return_kv:
                a, kv = L.gqa_attention_full(sa["attn"], cfg, a_in, positions,
                                             window=window, return_kv=True,
                                             use_kernel=use_kernel)
                kv = (_wsc_kv(kv_specs, "kv", kv[0]),
                      _wsc_kv(kv_specs, "kv", kv[1]))
            else:
                a = L.gqa_attention_full(sa["attn"], cfg, a_in, positions,
                                         window=window,
                                         use_kernel=use_kernel)
                kv = None
            h = h + a
            h = h + L.swiglu(sa["mlp"], L.rms_norm(h, sa["ln2"], cfg.norm_eps))
            return wsc(h), (states, kv) if return_kv else None

        if remat:
            group_body = jax.checkpoint(group_body)
        h, kvs = jax.lax.scan(group_body, h, params["layers"])

    else:  # dense / moe / vlm / enc-dec decoder
        enc_kv = None
        if cfg.is_encoder_decoder:
            assert encoder_embeds is not None
            enc_out = encode(params, cfg, encoder_embeds,
                             remat=remat, act_spec=act_spec)

        def body(carry, lp):
            h, aux = carry
            a_in = L.rms_norm(h, lp["ln1"], cfg.norm_eps)
            if cfg.use_mla and return_kv:
                a, kv = L.mla_attention_full(lp["attn"], cfg, a_in, positions,
                                             return_kv=True,
                                             act_spec=tp_act_spec)
                kv = _wsc_kv(kv_specs, "mla", kv)
            elif cfg.use_mla:
                a = L.mla_attention_full(lp["attn"], cfg, a_in, positions,
                                         act_spec=tp_act_spec)
                kv = None
            elif return_kv:
                a, kv = L.gqa_attention_full(lp["attn"], cfg, a_in, positions,
                                             window=window, return_kv=True,
                                             use_kernel=use_kernel,
                                             act_spec=tp_act_spec)
                kv = (_wsc_kv(kv_specs, "kv", kv[0]),
                      _wsc_kv(kv_specs, "kv", kv[1]))
            else:
                a = L.gqa_attention_full(lp["attn"], cfg, a_in, positions,
                                         window=window,
                                         use_kernel=use_kernel,
                                         act_spec=tp_act_spec)
                kv = None
            h = h + a
            if cfg.is_encoder_decoder:
                c = L.cross_attention(
                    lp["cross"], cfg,
                    L.rms_norm(h, lp["ln_cross"], cfg.norm_eps),
                    *L.cross_kv(lp["cross"], cfg, enc_out))
                h = h + c
            m_in = L.rms_norm(h, lp["ln2"], cfg.norm_eps)
            if cfg.uses_moe:
                m, aux_l = L.moe_layer(
                    lp["moe"], cfg, m_in,
                    expert_weight_spec=None if kv_specs is None
                    else kv_specs.get("moe_experts"))
                aux = aux + aux_l
            else:
                m = L.swiglu(lp["mlp"], m_in, act_spec=tp_act_spec)
            return (wsc(h + m), aux), kv

        if remat:
            body = jax.checkpoint(body)
        (h, aux_total), kvs = jax.lax.scan(
            body, (h, aux_total), params["layers"])

    hidden = L.rms_norm(h, params["final_norm"], cfg.norm_eps)
    logits = _logits(params, cfg, hidden)
    out = {"logits": logits, "hidden": hidden, "aux_loss": aux_total}
    if return_kv:
        out["kvs"] = kvs
    return out


# ---------------------------------------------------------------------------
# one-token decode against the paged cache
# ---------------------------------------------------------------------------

def decode_step(params: dict, cfg: ModelConfig, tokens: jax.Array,
                positions: jax.Array, cache: dict, window_len: int,
                use_kernel: bool = False, shard_specs=None) -> dict:
    """tokens [B,1]; positions [B]; cache per kv_cache_specs.

    window_len: static cache capacity in tokens (rolling buffer when the
    sequence outgrows it). Returns {logits [B,V], hidden [B,D], cache}.

    ``shard_specs`` (launch/shardings.serving_step_shardings) makes the
    step mesh-aware: per-layer pool updates are pinned to the serving
    cache layout and the last hidden state is constrained to the
    data-sharded lane layout, so a step scorer consuming it
    (``multi_decode_step``'s ``score_fn``) runs shard-local — no
    cross-device gather per scored token.
    """
    B = tokens.shape[0]
    h = _embed(params, cfg, tokens)  # [B,1,D]
    new_cache = dict(cache)
    layer_pool = {} if shard_specs is None else shard_specs["layer_pool"]
    act = None if shard_specs is None else shard_specs["act"]
    # mesh + kernel: route the paged-attention kernel through shard_map
    # (lanes on "data", pool KV heads on "model", computed shard-local)
    kmesh = (shard_specs["lane"].mesh
             if use_kernel and shard_specs is not None else None)

    def wsc_h(x):
        # pin the residual stream AND the norm outputs feeding the
        # column-parallel projections to the lane layout: left
        # unconstrained, GSPMD may shard them on D inside the layer
        # scan, turning the QKV/MLP contractions over D into
        # cross-shard partial sums (inexact rounding)
        if act is None:
            return x
        return jax.lax.with_sharding_constraint(x, act)

    h = wsc_h(h)

    if cfg.arch_type == "ssm":
        def body(h, xs):
            lp, sstate, cstate = xs
            out, ns, nc = L.mamba2_mixer_decode(
                lp["mixer"], cfg,
                L.rms_norm(h, lp["norm"], cfg.norm_eps), sstate, cstate)
            return h + out, (ns, nc)
        h, (ns, ncv) = jax.lax.scan(
            body, h, (params["layers"], cache["ssm_state"],
                      cache["conv_state"]))
        new_cache["ssm_state"], new_cache["conv_state"] = ns, ncv

    elif cfg.arch_type == "hybrid":
        sa = params["shared_attn"]

        def group_body(h, xs):
            gp, sstate, cstate, k_pool, v_pool = xs

            def layer_body(h, lxs):
                lp, ss, cs = lxs
                out, ns, nc = L.mamba2_mixer_decode(
                    lp["mixer"], cfg,
                    L.rms_norm(h, lp["norm"], cfg.norm_eps), ss, cs)
                return h + out, (ns, nc)
            h, (ns, ncv) = jax.lax.scan(layer_body, h, (gp, sstate, cstate))
            a_in = L.rms_norm(h, sa["ln1"], cfg.norm_eps)
            a, (nk, nv) = L.gqa_attention_decode(
                sa["attn"], cfg, a_in, positions,
                {"k_pool": k_pool, "v_pool": v_pool,
                 "block_tables": cache["block_tables"],
                 "window_len": window_len, "use_kernel": use_kernel,
                 "kernel_mesh": kmesh,
                 "pool_spec": layer_pool.get("k_pool"),
                 "act_spec": act}, 0)
            h = h + a
            h = h + L.swiglu(sa["mlp"], L.rms_norm(h, sa["ln2"], cfg.norm_eps))
            return h, (ns, ncv, nk, nv)

        # ssm_state is stacked [n_ssm, ...] = [G*per, ...]; regroup
        G = cfg.num_layers // cfg.hybrid_attn_every
        per = cfg.hybrid_attn_every
        ss = cache["ssm_state"].reshape(G, per, *cache["ssm_state"].shape[1:])
        cs = cache["conv_state"].reshape(G, per, *cache["conv_state"].shape[1:])
        h, (ns, ncv, nk, nv) = jax.lax.scan(
            group_body, h,
            (params["layers"], ss, cs, cache["k_pool"], cache["v_pool"]))
        new_cache["ssm_state"] = ns.reshape(-1, *ns.shape[2:])
        new_cache["conv_state"] = ncv.reshape(-1, *ncv.shape[2:])
        new_cache["k_pool"], new_cache["v_pool"] = nk, nv

    else:  # dense / moe / vlm / enc-dec decoder
        has_cross = cfg.is_encoder_decoder
        quant = "k_scale" in cache  # quantized paged pool (int8/fp8)

        def body(h, xs):
            if cfg.use_mla:
                lp, kv_pool = xs[0], xs[1]
                cross = xs[2:] if has_cross else None
            else:
                lp, k_pool, v_pool = xs[0], xs[1], xs[2]
                ksc, vsc = (xs[3], xs[4]) if quant else (None, None)
                cross = xs[3 + 2 * quant:] if has_cross else None
            a_in = L.rms_norm(h, lp["ln1"], cfg.norm_eps)
            if cfg.use_mla:
                a, new_pool = L.mla_attention_decode(
                    lp["attn"], cfg, a_in, positions,
                    {"kv_pool": kv_pool,
                     "block_tables": cache["block_tables"],
                     "window_len": window_len,
                     "pool_spec": layer_pool.get("kv_pool"),
                     "act_spec": act})
                out_pools = (new_pool,)
            else:
                a, out_pools = L.gqa_attention_decode(
                    lp["attn"], cfg, a_in, positions,
                    {"k_pool": k_pool, "v_pool": v_pool,
                     "k_scale": ksc, "v_scale": vsc,
                     "block_tables": cache["block_tables"],
                     "window_len": window_len, "use_kernel": use_kernel,
                     "kernel_mesh": kmesh,
                     "pool_spec": layer_pool.get("k_pool"),
                     "scale_spec": layer_pool.get("k_scale"),
                     "act_spec": act}, 0)
            h = h + a
            if has_cross:
                ck, cv = cross
                c = L.cross_attention(
                    lp["cross"], cfg,
                    L.rms_norm(h, lp["ln_cross"], cfg.norm_eps), ck, cv)
                h = h + c
            m_in = L.rms_norm(h, lp["ln2"], cfg.norm_eps)
            if cfg.uses_moe:
                m, _ = L.moe_layer(lp["moe"], cfg, m_in)
            else:
                m = L.swiglu(lp["mlp"], m_in, act_spec=act)
            return wsc_h(h + m), out_pools

        if cfg.use_mla:
            xs = (params["layers"], cache["kv_pool"])
        else:
            xs = (params["layers"], cache["k_pool"], cache["v_pool"])
            if quant:
                xs = xs + (cache["k_scale"], cache["v_scale"])
        if has_cross:
            xs = xs + (cache["cross_k"], cache["cross_v"])
        h, out_pools = jax.lax.scan(body, h, xs)
        if cfg.use_mla:
            new_cache["kv_pool"] = out_pools[0]
        else:
            new_cache["k_pool"], new_cache["v_pool"] = out_pools[:2]
            if quant:
                new_cache["k_scale"], new_cache["v_scale"] = out_pools[2:4]

    hidden = L.rms_norm(h[:, 0], params["final_norm"], cfg.norm_eps)  # [B,D]
    if shard_specs is not None:
        hidden = jax.lax.with_sharding_constraint(hidden,
                                                  shard_specs["hidden"])
    logits = _logits(params, cfg, hidden)
    return {"logits": logits, "hidden": hidden, "cache": new_cache}


# ---------------------------------------------------------------------------
# fused multi-token decode horizon: K decode iterations per device call
# ---------------------------------------------------------------------------

def multi_decode_step(params: dict, cfg: ModelConfig, tokens: jax.Array,
                      positions: jax.Array, limits: jax.Array, cache: dict,
                      *, window_len: int, horizon: int, rng_keys: jax.Array,
                      sample_fn, eos_id: int, step_id: int,
                      score_fn=None, scratch_block: int = 0,
                      use_kernel: bool = False, shard_specs=None) -> dict:
    """Run ``horizon`` decode iterations inside one ``lax.scan``.

    The host consumes tokens/confidences/step-scores once per K tokens
    instead of paying a device->host round trip per token — the decode
    horizon behind ``EngineConfig.decode_horizon``.

    Inputs (all fixed-shape over the decode batch B):
      tokens    [B]   previous sampled token per lane (the decode input)
      positions [B]   absolute write position of that token
      limits    [B]   per-lane iteration cap (<= horizon): lanes stop
                      after ``limits`` emitted tokens (remaining
                      max-new-token allowance / secured frontier blocks);
                      0 marks a dead slot that never runs
      rng_keys  [K, 2] one PRNG key per iteration, shared by all lanes —
                      the same key stream K successive single-token
                      ticks would consume, so horizon=K reproduces
                      horizon=1 token-for-token under a fixed RNG as
                      long as scheduling stays aligned (a lane
                      shortened below the full horizon by memory
                      contention falls behind the shared key stream —
                      but in that regime horizon=1 makes different
                      pruning decisions anyway; greedy sampling is
                      key-free and only subject to the scheduling-level
                      divergence)
      sample_fn (key, logits [B, Vp]) -> (tokens [B], conf [B]); applies
                      vocab masking + temperature/top-k/top-p
      score_fn  optional (hidden [B, D]) -> [B] step scorer, evaluated
                      every iteration and validity-masked to step
                      boundaries (input token == ``step_id``)

    Lane lifecycle inside the scan: a lane is *active* until it emits
    ``eos_id`` or exhausts its limit. Inactive lanes keep decoding (the
    batch shape is fixed) but their block-table row is repointed at
    ``scratch_block`` (the allocator's dead-slot block) so their KV
    writes land in scratch, their positions freeze, and their outputs
    are validity-masked — exactly the host scheduler's dead-slot
    convention.

    Returns {tokens [B, K], confidences [B, K], scores [B, K],
    token_valid [B, K], score_valid [B, K], final_tokens [B],
    positions [B], cache} where ``token_valid`` marks a contiguous
    emitted prefix per lane and ``score_valid`` the step-boundary subset.
    ``cache`` excludes ``block_tables`` (the in-scan copy is scratch-
    masked and not meaningful to the caller).

    ``shard_specs`` (launch/shardings.serving_step_shardings) runs the
    scan over a device mesh: the scan carry (pools, per-lane state,
    block tables) is constrained to the serving layout every iteration
    so the carry sharding is a stable fixpoint, and the per-iteration
    step scorer consumes the data-sharded hidden state locally.
    """
    B = tokens.shape[0]
    active0 = limits > 0
    bt0 = jnp.where(active0[:, None], cache["block_tables"], scratch_block)
    pools = {k: v for k, v in cache.items() if k != "block_tables"}

    def wsc(x, key):
        if shard_specs is None:
            return x
        return jax.lax.with_sharding_constraint(x, shard_specs[key])

    bt0 = wsc(bt0, "table")

    def body(carry, xs):
        pools, ct, pos, active, bt = carry
        key, k = xs
        c = dict(pools)
        c["block_tables"] = bt
        out = decode_step(params, cfg, ct[:, None], pos, c,
                          window_len=window_len, use_kernel=use_kernel,
                          shard_specs=shard_specs)
        nt, conf = sample_fn(key, out["logits"])
        if score_fn is not None:
            scores = score_fn(out["hidden"])
        else:
            scores = jnp.zeros((B,), jnp.float32)
        scores = wsc(scores, "lane")
        token_valid = active
        # the hidden state belongs to the input token; boundary => the
        # previous token closed a reasoning step
        score_valid = active & (ct == step_id)
        nt = jnp.where(active, nt, ct)  # frozen lanes re-feed their token
        nt, conf = wsc(nt, "lane"), wsc(conf, "lane")
        new_active = wsc(active & (nt != eos_id) & (k + 1 < limits), "lane")
        new_pos = wsc(pos + active.astype(pos.dtype), "lane")
        new_bt = wsc(jnp.where(new_active[:, None], bt, scratch_block),
                     "table")
        new_pools = out["cache"]
        new_pools.pop("block_tables", None)
        if shard_specs is not None:
            new_pools = {
                k_: jax.lax.with_sharding_constraint(
                    v, shard_specs["pools"][k_])
                for k_, v in new_pools.items()}
        return ((new_pools, nt, new_pos, new_active, new_bt),
                (nt, conf, scores, token_valid, score_valid))

    carry0 = (pools, tokens, positions, active0, bt0)
    (pools, ct, pos, _, _), ys = jax.lax.scan(
        body, carry0, (rng_keys, jnp.arange(horizon)))
    toks, confs, scores, tok_valid, score_valid = ys
    return {
        "tokens": toks.T, "confidences": confs.T, "scores": scores.T,
        "token_valid": tok_valid.T, "score_valid": score_valid.T,
        "final_tokens": ct, "positions": pos, "cache": pools,
    }


# ---------------------------------------------------------------------------
# chunked prefill against the paged cache (continuous-batching engine)
# ---------------------------------------------------------------------------

def supports_chunked_prefill(cfg: ModelConfig) -> bool:
    """Chunked prefill is implemented for the paged-attention dense/MoE
    stack. Recurrent (SSM/hybrid) archs would need the mixer to accept an
    initial state per chunk, MLA a latent-pool chunk path, and enc-dec the
    cross cache — those fall back to one-shot prefill in the engine."""
    return (cfg.arch_type not in ("ssm", "hybrid")
            and not cfg.use_mla and not cfg.is_encoder_decoder)


def prefill_chunk_step(params: dict, cfg: ModelConfig, tokens: jax.Array,
                       positions: jax.Array, valid: jax.Array, cache: dict,
                       window_len: int, use_kernel: bool = False,
                       shard_specs=None) -> dict:
    """Prefill one prompt chunk into the paged KV cache.

    tokens [B, C] (right-padded to the static chunk width); positions
    [B, C] absolute prompt positions; valid [B, C] marks real tokens.
    Earlier chunks' KV must already be in the pool (previous calls).
    Returns {logits [B, C, V], cache} — the caller samples from the
    logits at the prompt's last valid position of the final chunk.

    ``use_kernel`` routes the chunk attention through the multi-query
    Pallas paged kernel instead of materializing the dense
    [B, KVH, G, C, bp*bs + C] score tensor per layer.
    """
    assert supports_chunked_prefill(cfg), cfg.arch_type
    new_cache = dict(cache)
    window = cfg.sliding_window
    quant = "k_scale" in cache  # quantized paged pool (int8/fp8)
    pool_spec = (None if shard_specs is None
                 else shard_specs["layer_pool"].get("k_pool"))
    scale_spec = (None if shard_specs is None
                  else shard_specs["layer_pool"].get("k_scale"))
    act = None if shard_specs is None else shard_specs["prefill_act"]
    kmesh = (shard_specs["lane"].mesh
             if use_kernel and shard_specs is not None else None)

    def wsc_h(x):  # see decode_step: keep the residual carry pinned
        if act is None:
            return x
        return jax.lax.with_sharding_constraint(x, act)

    h = wsc_h(_embed(params, cfg, tokens))  # [B, C, D]

    def body(h, xs):
        if quant:
            lp, k_pool, v_pool, ksc, vsc = xs
        else:
            (lp, k_pool, v_pool), ksc, vsc = xs, None, None
        a_in = L.rms_norm(h, lp["ln1"], cfg.norm_eps)
        res = L.gqa_attention_prefill_chunk(
            lp["attn"], cfg, a_in, positions, valid, k_pool, v_pool,
            cache["block_tables"], window_len, window=window,
            use_kernel=use_kernel, kernel_mesh=kmesh,
            pool_spec=pool_spec, act_spec=act,
            k_scale=ksc, v_scale=vsc, scale_spec=scale_spec)
        a, pools = res[0], res[1:]
        h = h + a
        m_in = L.rms_norm(h, lp["ln2"], cfg.norm_eps)
        if cfg.uses_moe:
            m, _ = L.moe_layer(lp["moe"], cfg, m_in)
        else:
            m = L.swiglu(lp["mlp"], m_in, act_spec=act)
        return wsc_h(h + m), pools

    xs = (params["layers"], cache["k_pool"], cache["v_pool"])
    if quant:
        xs = xs + (cache["k_scale"], cache["v_scale"])
    h, pools = jax.lax.scan(body, h, xs)
    new_cache["k_pool"], new_cache["v_pool"] = pools[:2]
    if quant:
        new_cache["k_scale"], new_cache["v_scale"] = pools[2:4]
    hidden = L.rms_norm(h, params["final_norm"], cfg.norm_eps)
    logits = _logits(params, cfg, hidden)
    return {"logits": logits, "hidden": hidden, "cache": new_cache}


# ---------------------------------------------------------------------------
# distributed serve step — contiguous per-sequence caches (see layers.py:
# "contiguous-cache decode attention"); this is the step the multi-pod
# dry-run lowers for the decode shapes.
# ---------------------------------------------------------------------------

def serve_decode_step(params: dict, cfg: ModelConfig, tokens: jax.Array,
                      positions: jax.Array, cache: dict,
                      kv_specs=None) -> dict:
    """tokens [B,1]; positions [B]; cache per kv_cache_specs (contiguous):
      k_cache/v_cache [L*, B, cap, KVH, hd]  (or kv_cache for MLA)
      ssm_state/conv_state as in decode_step; cross_k/cross_v for enc-dec.
    Returns {logits [B,V], hidden [B,D], cache}.
    """
    B = tokens.shape[0]
    h = _embed(params, cfg, tokens)
    new_cache = dict(cache)

    if cfg.arch_type == "ssm":
        def body(h, xs):
            lp, sstate, cstate = xs
            out, ns, nc = L.mamba2_mixer_decode(
                lp["mixer"], cfg,
                L.rms_norm(h, lp["norm"], cfg.norm_eps), sstate, cstate)
            return h + out, (_wsc_kv(kv_specs, "ssm", ns),
                             _wsc_kv(kv_specs, "conv", nc))
        h, (ns, ncv) = jax.lax.scan(
            body, h, (params["layers"], cache["ssm_state"],
                      cache["conv_state"]))
        new_cache["ssm_state"], new_cache["conv_state"] = ns, ncv

    elif cfg.arch_type == "hybrid":
        sa = params["shared_attn"]

        def group_body(h, xs):
            gp, sstate, cstate, kc, vc = xs

            def layer_body(h, lxs):
                lp, ss, cs = lxs
                out, ns, nc = L.mamba2_mixer_decode(
                    lp["mixer"], cfg,
                    L.rms_norm(h, lp["norm"], cfg.norm_eps), ss, cs)
                return h + out, (_wsc_kv(kv_specs, "ssm", ns),
                                 _wsc_kv(kv_specs, "conv", nc))
            h, (ns, ncv) = jax.lax.scan(layer_body, h, (gp, sstate, cstate))
            a_in = L.rms_norm(h, sa["ln1"], cfg.norm_eps)
            a, nk, nv = L.gqa_attention_decode_contiguous(
                sa["attn"], cfg, a_in, positions, kc, vc,
                window_len=kc.shape[1])
            nk = _wsc_kv(kv_specs, "kv", nk)
            nv = _wsc_kv(kv_specs, "kv", nv)
            h = h + a
            h = h + L.swiglu(sa["mlp"], L.rms_norm(h, sa["ln2"], cfg.norm_eps))
            return h, (ns, ncv, nk, nv)

        G = cfg.num_layers // cfg.hybrid_attn_every
        per = cfg.hybrid_attn_every
        ss = cache["ssm_state"].reshape(G, per, *cache["ssm_state"].shape[1:])
        cs = cache["conv_state"].reshape(G, per, *cache["conv_state"].shape[1:])
        h, (ns, ncv, nk, nv) = jax.lax.scan(
            group_body, h,
            (params["layers"], ss, cs, cache["k_cache"], cache["v_cache"]))
        new_cache["ssm_state"] = ns.reshape(-1, *ns.shape[2:])
        new_cache["conv_state"] = ncv.reshape(-1, *ncv.shape[2:])
        new_cache["k_cache"], new_cache["v_cache"] = nk, nv

    else:  # dense / moe / vlm / enc-dec decoder
        has_cross = cfg.is_encoder_decoder

        def body(h, xs):
            if cfg.use_mla:
                lp, kv_cache = xs[0], xs[1]
                cross = xs[2:] if has_cross else None
            else:
                lp, kc, vc = xs[0], xs[1], xs[2]
                cross = xs[3:] if has_cross else None
            a_in = L.rms_norm(h, lp["ln1"], cfg.norm_eps)
            if cfg.use_mla:
                a, new_kv = L.mla_attention_decode_contiguous(
                    lp["attn"], cfg, a_in, positions, kv_cache)
                out_caches = (_wsc_kv(kv_specs, "mla", new_kv),)
            else:
                a, nk, nv = L.gqa_attention_decode_contiguous(
                    lp["attn"], cfg, a_in, positions, kc, vc,
                    window_len=kc.shape[1])
                out_caches = (_wsc_kv(kv_specs, "kv", nk),
                              _wsc_kv(kv_specs, "kv", nv))
            h = h + a
            if has_cross:
                ck, cv = cross
                c = L.cross_attention(
                    lp["cross"], cfg,
                    L.rms_norm(h, lp["ln_cross"], cfg.norm_eps), ck, cv)
                h = h + c
            m_in = L.rms_norm(h, lp["ln2"], cfg.norm_eps)
            if cfg.uses_moe:
                m, _ = L.moe_layer(
                    lp["moe"], cfg, m_in,
                    expert_weight_spec=None if kv_specs is None
                    else kv_specs.get("moe_experts"),
                    ex_in_spec=None if kv_specs is None
                    else kv_specs.get("moe_ex_in"))
            else:
                m = L.swiglu(lp["mlp"], m_in)
            return h + m, out_caches

        if cfg.use_mla:
            xs = (params["layers"], cache["kv_cache"])
        else:
            xs = (params["layers"], cache["k_cache"], cache["v_cache"])
        if has_cross:
            xs = xs + (cache["cross_k"], cache["cross_v"])
        h, out_caches = jax.lax.scan(body, h, xs)
        if cfg.use_mla:
            new_cache["kv_cache"] = out_caches[0]
        else:
            new_cache["k_cache"], new_cache["v_cache"] = out_caches

    hidden = L.rms_norm(h[:, 0], params["final_norm"], cfg.norm_eps)
    logits = _logits(params, cfg, hidden)
    return {"logits": logits, "hidden": hidden, "cache": new_cache}


# ---------------------------------------------------------------------------
# cache construction / prefill population (serving engine path)
# ---------------------------------------------------------------------------

def init_decode_cache(cfg: ModelConfig, batch: int, capacity: int,
                      num_blocks: Optional[int] = None,
                      encoder_len: Optional[int] = None,
                      kv_dtype: str = "bf16") -> dict:
    """Zeroed decode cache. ``capacity`` = per-sequence token capacity
    (the window). ``num_blocks`` sizes the shared pool; defaults to
    batch * blocks_per_seq (dedicated blocks). ``kv_dtype`` selects the
    paged-pool storage (``f32|bf16|int8|fp8``; see ``models.kv_quant``);
    quantized dtypes add ``k_scale``/``v_scale`` entries with one f32
    scale per (layer, page, KV head). Recurrent and cross-attention
    state always stays full precision."""
    bs = cfg.kv_block_size
    bp = -(-capacity // bs)
    nb = num_blocks if num_blocks is not None else batch * bp
    attn = cfg.attention_layer_ids()
    dt = jnp.bfloat16
    pool_dt = kv_quant.kv_pool_dtype(kv_dtype)
    cache: dict = {}
    if attn:
        la = len(attn)
        if cfg.use_mla:
            # MLA latent pool: f32/bf16 only (quantized dtypes are
            # rejected upstream by kv_quant.resolve_kv_dtype)
            cache["kv_pool"] = jnp.zeros(
                (la, nb, bs, cfg.kv_lora_rank + cfg.qk_rope_head_dim),
                pool_dt if kv_dtype in ("f32", "bf16") else dt)
        else:
            cache["k_pool"] = jnp.zeros(
                (la, nb, bs, cfg.num_kv_heads, cfg.head_dim), pool_dt)
            cache["v_pool"] = jnp.zeros(
                (la, nb, bs, cfg.num_kv_heads, cfg.head_dim), pool_dt)
            scales = kv_quant.init_scales(cfg, nb, kv_dtype)
            if scales is not None:
                # distinct buffers: the jitted steps donate the whole
                # cache dict, and XLA rejects donating one buffer twice
                cache["k_scale"] = scales
                cache["v_scale"] = scales + 0.0
        # default: sequence b owns blocks [b*bp, (b+1)*bp)
        cache["block_tables"] = (
            jnp.arange(batch * bp, dtype=jnp.int32).reshape(batch, bp)
            % max(nb, 1))
    if cfg.arch_type in ("ssm", "hybrid"):
        cache["ssm_state"] = jnp.zeros(
            (cfg.num_layers, batch, cfg.ssm_heads, cfg.ssm_head_dim,
             cfg.ssm_state_size), jnp.float32)
        cache["conv_state"] = jnp.zeros(
            (cfg.num_layers, batch, cfg.ssm_conv_width - 1,
             cfg.d_inner + 2 * cfg.ssm_state_size), dt)
    if cfg.is_encoder_decoder:
        T = encoder_len or cfg.encoder_seq_len or 1024
        la = len(attn)
        cache["cross_k"] = jnp.zeros(
            (la, batch, T, cfg.num_kv_heads, cfg.head_dim), dt)
        cache["cross_v"] = jnp.zeros(
            (la, batch, T, cfg.num_kv_heads, cfg.head_dim), dt)
    return cache


def build_cross_cache(params: dict, cfg: ModelConfig, enc_out: jax.Array):
    """Compute per-decoder-layer cross-attention K/V from encoder output."""
    def body(_, lp):
        k, v = L.cross_kv(lp["cross"], cfg, enc_out)
        return None, (k, v)
    _, (ck, cv) = jax.lax.scan(body, None, params["layers"])
    return ck, cv


def write_prefill_kv(cfg: ModelConfig, cache: dict, kvs,
                     seq_lens: jax.Array) -> dict:
    """Scatter prefill K/V (from forward_full(return_kv=True)) into the
    paged pools. Assumes prompt_len <= capacity (slot = position)."""
    cache = dict(cache)
    bt = cache.get("block_tables")
    bs = cfg.kv_block_size

    def scatter(pool, values):
        # pool [L*, NB, bs, ...]; values [L*, B, S, ...]
        Bn, S = values.shape[1], values.shape[2]
        pos = jnp.broadcast_to(jnp.arange(S)[None], (Bn, S))
        block_ids = jnp.take_along_axis(bt, pos // bs, axis=1)  # [B,S]
        offs = pos % bs
        valid = pos < seq_lens[:, None]
        # route invalid writes to a scratch copy of position 0 write? use
        # where on values and clamp ids; overwriting beyond len is harmless
        # because attention masks by cache_lens.
        vals = jnp.moveaxis(values, 0, 2)  # [B,S,L*,...] -> scatter per B,S
        pool_t = jnp.moveaxis(pool, 0, 2)  # [NB,bs,L*,...]
        pool_t = pool_t.at[block_ids, offs].set(vals)
        return jnp.moveaxis(pool_t, 2, 0)

    if cfg.arch_type == "ssm":
        ss, cs = kvs
        cache["ssm_state"], cache["conv_state"] = ss, cs
        return cache
    if cfg.arch_type == "hybrid":
        (ss, cs), (k, v) = kvs
        cache["ssm_state"] = ss.reshape(-1, *ss.shape[2:])
        cache["conv_state"] = cs.reshape(-1, *cs.shape[2:])
        cache["k_pool"] = scatter(cache["k_pool"], k)
        cache["v_pool"] = scatter(cache["v_pool"], v)
        return cache
    if cfg.use_mla:
        cache["kv_pool"] = scatter(cache["kv_pool"][:, :, :, None, :],
                                   kvs[:, :, :, None, :])[:, :, :, 0, :]
        return cache
    k, v = kvs
    if "k_scale" in cache:
        # quantized pool: each token quantizes against its own per-head
        # absmax (kv_quant.quantize_pages), then codes and scales
        # scatter through the same indexing — the one-shot write is
        # slot-for-slot identical to the chunked/decode write paths.
        qd = cache["k_pool"].dtype
        qk, sk = kv_quant.quantize_pages(k, qd)  # [L*,B,S,KVH,hd]/[...,KVH]
        qv, sv = kv_quant.quantize_pages(v, qd)
        cache["k_pool"] = scatter(cache["k_pool"], qk)
        cache["k_scale"] = scatter(cache["k_scale"], sk)
        cache["v_pool"] = scatter(cache["v_pool"], qv)
        cache["v_scale"] = scatter(cache["v_scale"], sv)
        return cache
    cache["k_pool"] = scatter(cache["k_pool"], k)
    cache["v_pool"] = scatter(cache["v_pool"], v)
    return cache


def copy_kv_block(cfg: ModelConfig, cache: dict, src: jax.Array,
                  dst: jax.Array) -> dict:
    """Device-side copy of one paged KV block: pool[:, dst] = pool[:, src].

    The copy-on-write step behind prefix sharing: when the engine must
    write into a block whose refcount is > 1, it allocates ``dst``, copies
    the shared contents, and repoints the writer's block table. Only the
    paged attention pools are touched; per-slot recurrent state (SSM/conv)
    is not block-addressed and needs no COW. ``src``/``dst`` may be traced
    scalars so a single jitted instance serves every block pair.
    """
    cache = dict(cache)
    # per-page quant scales are block-addressed too: they ride the COW
    # copy verbatim (the copied page's codes stay valid under its scale)
    for key in ("k_pool", "v_pool", "kv_pool", "k_scale", "v_scale"):
        if key in cache:
            pool = cache[key]
            cache[key] = pool.at[:, dst].set(pool[:, src])
    return cache


# ---------------------------------------------------------------------------
# loss
# ---------------------------------------------------------------------------

# Above this token count the [B, S, V] fp32 logits (plus softmax
# temporaries) dominate training HBM — e.g. 622 GB global for qwen3's
# 152k vocab at train_4k. The loss then switches to a sequence-chunked
# rematerialised cross-entropy: per-chunk logits are recomputed in the
# backward pass, so only the [B, S, D] hidden survives.
CHUNKED_CE_THRESHOLD = 1024
CE_CHUNK = 256


def _chunked_ce(hidden: jax.Array, w: jax.Array, labels: jax.Array,
                valid: jax.Array, chunk: int) -> tuple:
    """hidden [B,S,D]; w [D,V]; labels/valid [B,S]. Returns (nll_sum, n)."""
    B, S, D = hidden.shape
    chunk = min(chunk, S)
    assert S % chunk == 0
    nc = S // chunk
    hs = jnp.moveaxis(hidden.reshape(B, nc, chunk, D), 1, 0)
    ys = jnp.moveaxis(labels.reshape(B, nc, chunk), 1, 0)
    ms = jnp.moveaxis(valid.reshape(B, nc, chunk), 1, 0)

    @jax.checkpoint
    def body(acc, inp):
        h_c, y_c, m_c = inp
        logits = (h_c @ w).astype(jnp.float32)
        lse = jax.nn.logsumexp(logits, axis=-1)
        safe = jnp.where(m_c, y_c, 0)
        gold = jnp.take_along_axis(logits, safe[..., None], axis=-1)[..., 0]
        nll = (lse - gold) * m_c
        return acc + jnp.sum(nll), None

    total, _ = jax.lax.scan(body, jnp.zeros((), jnp.float32), (hs, ys, ms))
    return total


def lm_loss(params: dict, cfg: ModelConfig, tokens: jax.Array,
            labels: jax.Array, aux_weight: float = 0.01,
            use_kernel: bool = False,
            modality_embeds: Optional[jax.Array] = None,
            encoder_embeds: Optional[jax.Array] = None,
            remat: bool = False, act_spec=None,
            kv_specs=None) -> jax.Array:
    out = forward_full(params, cfg, tokens, use_kernel=use_kernel,
                       modality_embeds=modality_embeds,
                       encoder_embeds=encoder_embeds, remat=remat,
                       act_spec=act_spec, kv_specs=kv_specs)
    valid = (labels >= 0) & (labels < cfg.vocab_size)
    S = tokens.shape[1]
    if S > CHUNKED_CE_THRESHOLD:
        w = params["embed"].T if cfg.tie_embeddings else params["lm_head"]
        nll_sum = _chunked_ce(out["hidden"], w, labels, valid, CE_CHUNK)
        loss = nll_sum / jnp.maximum(jnp.sum(valid), 1)
        return loss + aux_weight * out["aux_loss"]
    logits = out["logits"].astype(jnp.float32)
    logp = jax.nn.log_softmax(logits, axis=-1)
    safe = jnp.where(valid, labels, 0)
    nll = -jnp.take_along_axis(logp, safe[..., None], axis=-1)[..., 0]
    loss = jnp.sum(nll * valid) / jnp.maximum(jnp.sum(valid), 1)
    return loss + aux_weight * out["aux_loss"]
