"""Parameter initialization for every architecture family.

Layer params are STACKED along a leading [num_layers] axis so the forward
pass can ``lax.scan`` over layers — essential for compile time at 512
devices. Hybrid (zamba2) stacks as [groups, layers_per_group, ...] with a
single shared attention block.
"""
from __future__ import annotations

import math

import jax
import jax.numpy as jnp

from repro.configs.base import ModelConfig

DTYPE = jnp.bfloat16


def padded_vocab(cfg: ModelConfig) -> int:
    """Vocab padded to a multiple of 256 so 16-way sharding is even."""
    return -(-cfg.vocab_size // 256) * 256


def _norm(key, *shape):
    del key
    return jnp.ones(shape, DTYPE)


def _dense(key, fan_in, *shape, scale=None):
    scale = scale if scale is not None else 1.0 / math.sqrt(fan_in)
    return (jax.random.normal(key, shape, jnp.float32) * scale).astype(DTYPE)


def _attn_params(key, cfg: ModelConfig, stack=()) -> dict:
    D, H, KVH, hd = cfg.d_model, cfg.num_heads, cfg.num_kv_heads, cfg.head_dim
    ks = jax.random.split(key, 6)
    out_scale = 1.0 / math.sqrt(H * hd) / math.sqrt(2 * max(cfg.num_layers, 1))
    p = {
        "wq": _dense(ks[0], D, *stack, D, H * hd),
        "wk": _dense(ks[1], D, *stack, D, KVH * hd),
        "wv": _dense(ks[2], D, *stack, D, KVH * hd),
        "wo": _dense(ks[3], H * hd, *stack, H * hd, D, scale=out_scale),
    }
    if cfg.qk_norm:
        p["q_norm"] = jnp.ones((*stack, hd), DTYPE)
        p["k_norm"] = jnp.ones((*stack, hd), DTYPE)
    return p


def _mla_params(key, cfg: ModelConfig, stack=()) -> dict:
    D, H = cfg.d_model, cfg.num_heads
    nd, rd, vd = cfg.qk_nope_head_dim, cfg.qk_rope_head_dim, cfg.v_head_dim
    L, QL = cfg.kv_lora_rank, cfg.q_lora_rank
    ks = jax.random.split(key, 6)
    out_scale = 1.0 / math.sqrt(H * vd) / math.sqrt(2 * cfg.num_layers)
    return {
        "wq_a": _dense(ks[0], D, *stack, D, QL),
        "wq_b": _dense(ks[1], QL, *stack, QL, H * (nd + rd)),
        "wkv_a": _dense(ks[2], D, *stack, D, L + rd),
        "wk_b": _dense(ks[3], L, *stack, L, H * nd),
        "wv_b": _dense(ks[4], L, *stack, L, H * vd),
        "wo": _dense(ks[5], H * vd, *stack, H * vd, D, scale=out_scale),
        "q_a_norm": jnp.ones((*stack, QL), DTYPE),
        "kv_a_norm": jnp.ones((*stack, L), DTYPE),
    }


def _mlp_params(key, cfg: ModelConfig, d_ff=None, stack=()) -> dict:
    D = cfg.d_model
    F = d_ff or cfg.d_ff
    ks = jax.random.split(key, 3)
    down_scale = 1.0 / math.sqrt(F) / math.sqrt(2 * max(cfg.num_layers, 1))
    return {
        "w_gate": _dense(ks[0], D, *stack, D, F),
        "w_up": _dense(ks[1], D, *stack, D, F),
        "w_down": _dense(ks[2], F, *stack, F, D, scale=down_scale),
    }


def _moe_params(key, cfg: ModelConfig, stack=()) -> dict:
    D, E, F = cfg.d_model, cfg.num_experts, cfg.moe_d_ff
    ks = jax.random.split(key, 5)
    down_scale = 1.0 / math.sqrt(F) / math.sqrt(2 * cfg.num_layers)
    p = {
        "router": _dense(ks[0], D, *stack, D, E),
        "experts": {
            "w_gate": _dense(ks[1], D, *stack, E, D, F),
            "w_up": _dense(ks[2], D, *stack, E, D, F),
            "w_down": _dense(ks[3], F, *stack, E, F, D, scale=down_scale),
        },
    }
    if cfg.num_shared_experts:
        p["shared"] = _mlp_params(ks[4], cfg,
                                  d_ff=F * cfg.num_shared_experts, stack=stack)
    return p


def _mamba_params(key, cfg: ModelConfig, stack=()) -> dict:
    D, di, N, H = cfg.d_model, cfg.d_inner, cfg.ssm_state_size, cfg.ssm_heads
    conv_ch = di + 2 * N
    in_dim = 2 * di + 2 * N + H
    ks = jax.random.split(key, 4)
    out_scale = 1.0 / math.sqrt(di) / math.sqrt(2 * cfg.num_layers)
    return {
        "w_in": _dense(ks[0], D, *stack, D, in_dim),
        "conv_w": _dense(ks[1], cfg.ssm_conv_width,
                         *stack, cfg.ssm_conv_width, conv_ch),
        "conv_b": jnp.zeros((*stack, conv_ch), DTYPE),
        "A_log": jnp.broadcast_to(
            jnp.log(jnp.arange(1, H + 1, dtype=jnp.float32)), (*stack, H)
        ).astype(jnp.float32),
        "D": jnp.ones((*stack, H), jnp.float32),
        "dt_bias": jnp.broadcast_to(
            jnp.log(jnp.expm1(jnp.full((H,), 0.01, jnp.float32))),
            (*stack, H)).astype(jnp.float32),
        "norm_w": jnp.ones((*stack, di), DTYPE),
        "w_out": _dense(ks[2], di, *stack, di, D, scale=out_scale),
    }


def _decoder_layer(key, cfg: ModelConfig, stack=(), cross=False) -> dict:
    ks = jax.random.split(key, 4)
    if cfg.use_mla:
        attn = _mla_params(ks[0], cfg, stack)
    else:
        attn = _attn_params(ks[0], cfg, stack)
    p = {"ln1": jnp.ones((*stack, cfg.d_model), DTYPE), "attn": attn,
         "ln2": jnp.ones((*stack, cfg.d_model), DTYPE)}
    if cfg.uses_moe:
        p["moe"] = _moe_params(ks[1], cfg, stack)
    else:
        p["mlp"] = _mlp_params(ks[1], cfg, stack=stack)
    if cross:
        p["ln_cross"] = jnp.ones((*stack, cfg.d_model), DTYPE)
        p["cross"] = _attn_params(ks[2], cfg, stack)
    return p


def init_params(cfg: ModelConfig, rng: jax.Array) -> dict:
    V = padded_vocab(cfg)
    D = cfg.d_model
    ks = jax.random.split(rng, 8)
    params: dict = {
        "embed": _dense(ks[0], D, V, D, scale=0.02),
        "final_norm": jnp.ones((D,), DTYPE),
    }
    if not cfg.tie_embeddings:
        params["lm_head"] = _dense(ks[1], D, D, V)

    if cfg.arch_type == "ssm":
        L = cfg.num_layers
        params["layers"] = {
            "norm": jnp.ones((L, D), DTYPE),
            "mixer": _mamba_params(ks[2], cfg, stack=(L,)),
        }
    elif cfg.arch_type == "hybrid":
        assert cfg.num_layers % cfg.hybrid_attn_every == 0
        G = cfg.num_layers // cfg.hybrid_attn_every
        per = cfg.hybrid_attn_every
        params["layers"] = {
            "norm": jnp.ones((G, per, D), DTYPE),
            "mixer": _mamba_params(ks[2], cfg, stack=(G, per)),
        }
        params["shared_attn"] = _decoder_layer(ks[3], cfg)  # single block
    elif cfg.is_encoder_decoder:
        Le, Ld = cfg.num_encoder_layers, cfg.num_layers
        enc = {"ln1": jnp.ones((Le, D), DTYPE),
               "attn": _attn_params(ks[2], cfg, (Le,)),
               "ln2": jnp.ones((Le, D), DTYPE),
               "mlp": _mlp_params(ks[3], cfg, stack=(Le,))}
        params["encoder"] = enc
        params["encoder_norm"] = jnp.ones((D,), DTYPE)
        params["layers"] = _decoder_layer(ks[4], cfg, (Ld,), cross=True)
    else:  # dense / moe / vlm
        L = cfg.num_layers
        params["layers"] = _decoder_layer(ks[2], cfg, (L,))
    return params


def count_params(params) -> int:
    return sum(int(x.size) for x in jax.tree_util.tree_leaves(params))
