"""Quantized paged-KV pool support: dtype registry, per-page per-KV-head
scale quantization, and byte accounting.

The paged pools (``k_pool``/``v_pool``: ``[layers, blocks, block_size,
kv_heads, head_dim]``) can be stored in four dtypes, selected by
``EngineConfig.kv_dtype`` (env ``REPRO_KV_DTYPE``, CLI ``--kv-dtype``):

- ``f32`` / ``bf16`` — plain floating-point pools, no scales. ``bf16``
  is the default (and the historical hardcoded pool dtype), and is
  pinned token/score/prune-identical to ``f32`` at engine scale.
- ``int8`` / ``fp8`` — quantized pools with one f32 scale per
  (page, slot, KV head), stored as extra cache entries ``k_scale``/
  ``v_scale`` of shape ``[layers, blocks, block_size, kv_heads]``.
  Dequantization is ``q.astype(f32) * scale``; the scale is
  ``absmax / qmax`` over the token's ``head_dim`` vector. ``fp8`` uses
  ``float8_e4m3fn`` and is gated on the installed jax exposing it.

The scale granularity is per SLOT, not per page, and that choice is
load-bearing: each cached token quantizes independently from its own
absmax, so a slot's stored code is a pure function of the token value
written there. Every write path — one-shot prefill scatter, chunked
prefill, per-token decode appends, COW block copies — therefore
produces bit-identical pool content for the same tokens, and recycled
blocks carry no history (a stale neighbour cannot leak into a fresh
token's scale). This is what keeps the engine's scheduling-transparency
pins (prefix-cache on/off, chunked-vs-one-shot prefill, warm-vs-cold
pool) EXACT under quantization, where a per-page absmax would have to
re-round earlier tokens on every append. The cost is one extra f32 per
(slot, kv head) — ``1/head_dim`` of the int8 pool bytes, ~1.5% at
``head_dim=64`` — which ``pool_block_bytes`` accounts for.

Both the dense-math attention fallback and the Pallas multi-query kernel
apply the *same* dequant (cast to f32, multiply by the slot scale), so
the two read paths stay numerically aligned — the kernel-vs-dense
identity pins hold under every ``kv_dtype``.
"""
from __future__ import annotations

from typing import Optional, Tuple

import jax.numpy as jnp

from repro.configs.base import ModelConfig

KV_DTYPES = ("f32", "bf16", "int8", "fp8")

# Largest representable magnitude per quantized dtype: int8 uses the
# symmetric range [-127, 127]; float8_e4m3fn tops out at 448.
_QMAX_INT8 = 127.0
_QMAX_FP8 = 448.0


def fp8_dtype():
    """The fp8 storage dtype, or ``None`` when this jax lacks float8."""
    return getattr(jnp, "float8_e4m3fn", None)


def kv_pool_dtype(kv_dtype: str):
    """Map a ``kv_dtype`` setting to the pool storage jnp dtype."""
    if kv_dtype == "f32":
        return jnp.float32
    if kv_dtype == "bf16":
        return jnp.bfloat16
    if kv_dtype == "int8":
        return jnp.int8
    if kv_dtype == "fp8":
        dt = fp8_dtype()
        if dt is None:
            raise NotImplementedError(
                "kv_dtype='fp8' needs a jax build exposing float8_e4m3fn")
        return dt
    raise ValueError(
        f"unknown kv_dtype {kv_dtype!r}; expected one of {KV_DTYPES}")


def is_quantized(kv_dtype: str) -> bool:
    return kv_dtype in ("int8", "fp8")


def kv_bytes_per_scalar(kv_dtype: str) -> int:
    """Pool storage bytes per cached scalar (excluding scale overhead)."""
    return {"f32": 4, "bf16": 2, "int8": 1, "fp8": 1}[kv_dtype]


def _qmax(qdtype) -> float:
    return _QMAX_INT8 if jnp.dtype(qdtype) == jnp.dtype(jnp.int8) \
        else _QMAX_FP8


def quantize_pages(x: jnp.ndarray, qdtype) -> Tuple[jnp.ndarray, jnp.ndarray]:
    """Quantize f32 KV values ``[..., head_dim]`` to ``qdtype`` with a
    fresh absmax scale per leading index (one scale per token vector —
    the per-slot granularity that makes writes order-independent, see
    the module docstring).

    Returns ``(q, scale)`` where ``scale`` has shape ``x.shape[:-1]``.
    All-zero vectors get scale 1.0 so dequantization stays exact and
    division is well-defined.

    Scales are stored as f32 but rounded to the bf16 grid. This keeps
    ``code * scale`` EXACT in f32 (8-bit code mantissa x 8-bit scale
    mantissa fits f32's 24), which is what lets the Pallas kernel's
    per-page online-softmax accumulation stay bit-identical to the
    dense fallback's one-shot contraction — the same mechanism that
    makes the bf16 pool's kernel/dense identity exact. A full-precision
    scale would make every dequantized product carry rounding noise,
    and the two read paths' different summation orders would surface
    it as ulp-level logit drift.
    """
    xf = x.astype(jnp.float32)
    absmax = jnp.max(jnp.abs(xf), axis=-1)
    scale = jnp.where(absmax > 0.0, absmax / _qmax(qdtype), 1.0)
    scale = scale.astype(jnp.bfloat16).astype(jnp.float32)
    y = xf / scale[..., None]
    if jnp.dtype(qdtype) == jnp.dtype(jnp.int8):
        q = jnp.clip(jnp.round(y), -_QMAX_INT8, _QMAX_INT8).astype(jnp.int8)
    else:
        q = jnp.clip(y, -_QMAX_FP8, _QMAX_FP8).astype(qdtype)
    return q, scale


def dequantize_pages(q: jnp.ndarray, scale: jnp.ndarray) -> jnp.ndarray:
    """Inverse of :func:`quantize_pages`: ``q [..., hd]`` with
    ``scale [...]`` back to f32. Also used on dtype-gathered pool
    slices (``pool[block_tables]`` with ``scale[block_tables]``) —
    any leading batch axes broadcast."""
    return q.astype(jnp.float32) * scale[..., None]


def resolve_kv_dtype(setting: str, cfg: ModelConfig,
                     chunk_supported: bool) -> str:
    """Validate a ``kv_dtype`` setting against the model architecture.

    Quantized pools cover the dense-GQA paged-attention paths (the same
    family the chunked-prefill scatter serves); MLA / SSM / hybrid /
    encoder-decoder caches keep full-precision pools and raise here, so
    users hit one clear error at engine construction instead of a shape
    error mid-serve. ``f32``/``bf16`` only re-type the pools and are
    accepted everywhere.
    """
    if setting not in KV_DTYPES:
        raise ValueError(
            f"unknown kv_dtype {setting!r}; expected one of {KV_DTYPES}")
    if setting == "fp8" and fp8_dtype() is None:
        raise NotImplementedError(
            "kv_dtype='fp8' needs a jax build exposing float8_e4m3fn")
    if is_quantized(setting) and not chunk_supported:
        raise NotImplementedError(
            f"kv_dtype={setting!r} is only supported for dense GQA "
            f"architectures (arch_type={cfg.arch_type!r}, "
            f"use_mla={cfg.use_mla}); see docs/SUPPORT_MATRIX.md")
    return setting


def pool_block_bytes(cfg: ModelConfig, kv_dtype: str) -> int:
    """HBM bytes one KV block occupies across all attention layers —
    pool storage plus (for quantized dtypes) the per-page f32 scales.
    This is what `AdmissionPressure` byte accounting reports per block.
    """
    la = len(cfg.attention_layer_ids())
    per_token = cfg.kv_cache_dims_per_token
    n = la * cfg.kv_block_size * per_token * kv_bytes_per_scalar(kv_dtype)
    if is_quantized(kv_dtype):
        # one f32 scale per (layer, page, slot, kv_head), for K and V
        n += la * 2 * cfg.kv_block_size * cfg.num_kv_heads * 4
    return n


def init_scales(cfg: ModelConfig, num_blocks: int,
                kv_dtype: str) -> Optional[jnp.ndarray]:
    """Fresh unit scales ``[attn_layers, num_blocks, block_size,
    kv_heads]`` for a quantized pool (zero-filled pools dequantize to
    exact zeros), or ``None`` for float pools."""
    if not is_quantized(kv_dtype):
        return None
    la = len(cfg.attention_layer_ids())
    return jnp.ones((la, num_blocks, cfg.kv_block_size, cfg.num_kv_heads),
                    jnp.float32)
