"""Model-zoo primitive layers (pure functions, params-as-pytrees).

All matmul weights are 2-D ``[in, out]`` so tensor-parallel sharding happens
on fused dims (always divisible by the 16-way model axis); head reshapes are
internal. Norms/softmax accumulate in fp32; weights/activations are bf16.
"""
from __future__ import annotations

import math
from typing import Optional

import jax
import jax.numpy as jnp

from repro.configs.base import ModelConfig
from repro.models import kv_quant as KQ


# ---------------------------------------------------------------------------
# norms / rope
# ---------------------------------------------------------------------------

def rms_norm(x: jax.Array, weight: jax.Array, eps: float = 1e-6) -> jax.Array:
    xf = x.astype(jnp.float32)
    var = jnp.mean(jnp.square(xf), axis=-1, keepdims=True)
    out = xf * jax.lax.rsqrt(var + eps)
    return (out * weight.astype(jnp.float32)).astype(x.dtype)


def rope_cos_sin(positions: jax.Array, dim: int, theta: float):
    """positions [...]; returns cos/sin [..., dim/2] in fp32."""
    inv_freq = 1.0 / (theta ** (jnp.arange(0, dim, 2, dtype=jnp.float32) / dim))
    angles = positions.astype(jnp.float32)[..., None] * inv_freq
    return jnp.cos(angles), jnp.sin(angles)


def apply_rope(x: jax.Array, cos: jax.Array, sin: jax.Array) -> jax.Array:
    """x [..., H, hd]; cos/sin broadcastable [..., 1, hd/2]."""
    half = x.shape[-1] // 2
    x1, x2 = x[..., :half], x[..., half:]
    x1f, x2f = x1.astype(jnp.float32), x2.astype(jnp.float32)
    out = jnp.concatenate([x1f * cos - x2f * sin, x2f * cos + x1f * sin], axis=-1)
    return out.astype(x.dtype)


# ---------------------------------------------------------------------------
# feed-forward
# ---------------------------------------------------------------------------

def swiglu(p: dict, x: jax.Array, act_spec=None) -> jax.Array:
    """p: {w_gate [D,F], w_up [D,F], w_down [F,D]}

    ``act_spec`` (serving mesh): the column-parallel gate/up outputs are
    model-sharded on F, and contracting that sharded F against the
    replicated ``w_down`` would make GSPMD partial-sum across shards —
    a float reduction whose rounding differs from the single-device
    matmul. Constraining the activation un-sharded on F first turns the
    collective into an exact all-gather and keeps the contraction
    bit-identical to one device. The input is pinned the same way: an
    unconstrained norm output feeding the column-parallel gate/up
    matmuls could get D-sharded by GSPMD, partial-summing THEIR
    contraction instead.
    """
    if act_spec is not None:
        x = jax.lax.with_sharding_constraint(x, act_spec)
    g = x @ p["w_gate"]
    u = x @ p["w_up"]
    act = jax.nn.silu(g.astype(jnp.float32)).astype(x.dtype) * u
    if act_spec is not None:
        act = jax.lax.with_sharding_constraint(act, act_spec)
    return act @ p["w_down"]


# ---------------------------------------------------------------------------
# dense / GQA attention — full sequence (train & prefill)
# ---------------------------------------------------------------------------

# Above this sequence length the naive S^2 score tensor cannot be
# materialised (824 TB for granite-20b at train_4k); attention switches to
# the chunked online-softmax path (flash semantics in plain XLA) which is
# what actually lowers for the 32k/500k dry-run shapes.
CHUNKED_ATTN_THRESHOLD = 1024
ATTN_CHUNK = 512


def chunked_mha(q: jax.Array, k: jax.Array, v: jax.Array,
                window: Optional[int] = None,
                chunk: int = ATTN_CHUNK,
                causal: bool = True) -> jax.Array:
    """Blockwise online-softmax attention, O(S * chunk) memory.

    q/k/v [B, H, S, hd] (kv heads pre-broadcast), scaled q expected.
    The chunk body is rematerialised (jax.checkpoint) so the backward pass
    recomputes probabilities flash-attention-style instead of saving the
    [S, S] probability tensor.
    """
    B, H, S, hd = q.shape
    chunk = min(chunk, S)
    assert S % chunk == 0, (S, chunk)
    nk = S // chunk
    kc = k.reshape(B, H, nk, chunk, k.shape[-1])
    vc = v.reshape(B, H, nk, chunk, v.shape[-1])
    q_pos = jnp.arange(S)

    @jax.checkpoint
    def body(carry, inp):
        m_prev, l_prev, acc = carry
        k_blk, v_blk, blk_idx = inp  # [B,H,chunk,hd] x2, scalar
        s = jnp.einsum("bhqd,bhkd->bhqk", q, k_blk,
                       preferred_element_type=jnp.float32)  # [B,H,S,chunk]
        k_pos = blk_idx * chunk + jnp.arange(chunk)
        mask = jnp.ones((S, chunk), bool)
        if causal:
            mask &= k_pos[None, :] <= q_pos[:, None]
        if window is not None:
            mask &= k_pos[None, :] > (q_pos[:, None] - window)
        s = jnp.where(mask[None, None], s, -1e30)
        m_cur = jnp.max(s, axis=-1, keepdims=True)
        m_new = jnp.maximum(m_prev, m_cur)
        p = jnp.where(mask[None, None], jnp.exp(s - m_new), 0.0)
        alpha = jnp.exp(m_prev - m_new)
        l_new = alpha * l_prev + jnp.sum(p, axis=-1, keepdims=True)
        acc_new = acc * alpha + jnp.einsum(
            "bhqk,bhkd->bhqd", p.astype(v_blk.dtype), v_blk,
            preferred_element_type=jnp.float32)
        return (m_new, l_new, acc_new), None

    init = (jnp.full((B, H, S, 1), -1e30, jnp.float32),
            jnp.zeros((B, H, S, 1), jnp.float32),
            jnp.zeros((B, H, S, v.shape[-1]), jnp.float32))
    (m, l, acc), _ = jax.lax.scan(
        body, init,
        (jnp.moveaxis(kc, 2, 0), jnp.moveaxis(vc, 2, 0), jnp.arange(nk)))
    l = jnp.where(l == 0.0, 1.0, l)
    return (acc / l).astype(q.dtype)


def _attn_mask(q_len: int, kv_len: int, window: Optional[int]) -> jax.Array:
    """Causal (optionally sliding-window) boolean mask [q_len, kv_len]."""
    q_pos = jnp.arange(q_len)[:, None] + (kv_len - q_len)
    k_pos = jnp.arange(kv_len)[None, :]
    mask = k_pos <= q_pos
    if window is not None:
        mask &= k_pos > (q_pos - window)
    return mask


def gqa_attention_full(p: dict, cfg: ModelConfig, x: jax.Array,
                       positions: jax.Array,
                       window: Optional[int] = None,
                       return_kv: bool = False,
                       use_kernel: bool = False,
                       act_spec=None):
    """Full-sequence GQA attention.

    p: {wq [D, H*hd], wk [D, KVH*hd], wv [D, KVH*hd], wo [H*hd, D],
        (qk_norm) q_norm [hd], k_norm [hd]}
    x: [B, S, D]; positions: [B, S] absolute positions.
    """
    B, S, D = x.shape
    H, KVH, hd = cfg.num_heads, cfg.num_kv_heads, cfg.head_dim
    if act_spec is not None:  # exact TP: see swiglu
        x = jax.lax.with_sharding_constraint(x, act_spec)
    q = (x @ p["wq"]).reshape(B, S, H, hd)
    k = (x @ p["wk"]).reshape(B, S, KVH, hd)
    v = (x @ p["wv"]).reshape(B, S, KVH, hd)
    if cfg.qk_norm:
        q = rms_norm(q, p["q_norm"], cfg.norm_eps)
        k = rms_norm(k, p["k_norm"], cfg.norm_eps)
    cos, sin = rope_cos_sin(positions, hd, cfg.rope_theta)  # [B,S,hd/2]
    cos, sin = cos[:, :, None, :], sin[:, :, None, :]
    q = apply_rope(q, cos, sin)
    k = apply_rope(k, cos, sin)

    if use_kernel:
        from repro.kernels import ops as kops
        group = H // KVH
        kb = jnp.repeat(k, group, axis=2)  # broadcast kv heads
        vb = jnp.repeat(v, group, axis=2)
        out = kops.flash_attention(
            q.transpose(0, 2, 1, 3), kb.transpose(0, 2, 1, 3),
            vb.transpose(0, 2, 1, 3), window=window,
            scale=1.0 / math.sqrt(hd))
        out = out.transpose(0, 2, 1, 3).reshape(B, S, H * hd)
    elif S > CHUNKED_ATTN_THRESHOLD:
        group = H // KVH
        kb = jnp.repeat(k, group, axis=2)
        vb = jnp.repeat(v, group, axis=2)
        out = chunked_mha(
            q.transpose(0, 2, 1, 3) * (1.0 / math.sqrt(hd)),
            kb.transpose(0, 2, 1, 3), vb.transpose(0, 2, 1, 3),
            window=window, chunk=ATTN_CHUNK)
        out = out.transpose(0, 2, 1, 3).reshape(B, S, H * hd)
    else:
        group = H // KVH
        qg = q.reshape(B, S, KVH, group, hd)
        scores = jnp.einsum("bqkgh,bskh->bkgqs", qg, k,
                            preferred_element_type=jnp.float32)
        scores *= 1.0 / math.sqrt(hd)
        mask = _attn_mask(S, S, window)
        out = _masked_softmax_pv(scores, mask[None, None, None], v,
                                 "bkgqs,bskh->bqkgh")
        out = out.astype(x.dtype).reshape(B, S, H * hd)
    if act_spec is not None:  # exact TP: gather heads before the wo
        out = jax.lax.with_sharding_constraint(out, act_spec)  # contraction
    out = out @ p["wo"]
    if return_kv:
        return out, (k, v)
    return out


# ---------------------------------------------------------------------------
# paged GQA attention — decode (one new token per sequence)
# ---------------------------------------------------------------------------

def paged_kv_update(pool: jax.Array, block_tables: jax.Array,
                    slot_positions: jax.Array, new_kv: jax.Array) -> jax.Array:
    """Write one token's K or V per sequence into the paged pool.

    pool [N_blocks, bs, KVH, hd]; block_tables [B, bp];
    slot_positions [B] (position within the cache window);
    new_kv [B, KVH, hd].
    """
    bs = pool.shape[1]
    block_idx = slot_positions // bs
    offset = slot_positions % bs
    block_ids = jnp.take_along_axis(block_tables, block_idx[:, None], axis=1)[:, 0]
    return pool.at[block_ids, offset].set(new_kv)


def paged_kv_update_quant(pool: jax.Array, scale: jax.Array,
                          block_tables: jax.Array,
                          slot_positions: jax.Array,
                          new_kv: jax.Array) -> tuple:
    """Quantized-pool variant of :func:`paged_kv_update`.

    pool [N_blocks, bs, KVH, hd] in int8/fp8 with per-(page, slot,
    KV-head) f32 ``scale`` [N_blocks, bs, KVH]. The new token [B, KVH,
    hd] is quantized against its own per-head absmax and its codes +
    scales scattered into the written slot — no other slot is touched,
    so the stored value is a pure function of the token (write paths
    commute; see ``kv_quant``). Returns (new_pool, new_scale). Dead
    lanes share the scratch block, whose content is never read
    un-masked.
    """
    bs = pool.shape[1]
    block_idx = slot_positions // bs
    offset = slot_positions % bs
    block_ids = jnp.take_along_axis(
        block_tables, block_idx[:, None], axis=1)[:, 0]
    q, ns = KQ.quantize_pages(new_kv, pool.dtype)  # [B,KVH,hd] / [B,KVH]
    return (pool.at[block_ids, offset].set(q),
            scale.at[block_ids, offset].set(ns))


def paged_chunk_update_quant(pool: jax.Array, scale: jax.Array,
                             block_tables: jax.Array, slot: jax.Array,
                             valid: jax.Array, new_vals: jax.Array) -> tuple:
    """Quantized-pool scatter for one prefill chunk.

    pool [N_blocks, bs, KVH, hd] int8/fp8; scale [N_blocks, bs, KVH]
    f32; slot [B, C]; valid [B, C]; new_vals [B, C, KVH, hd]. Each
    chunk token is quantized against its own per-head absmax and its
    codes + scales scattered into its slot (padded entries keep the old
    content, mirroring :func:`paged_chunk_update`) — earlier chunks'
    slots are never re-rounded, so chunked and one-shot prefill write
    bit-identical pool content. Returns (new_pool, new_scale).
    """
    bs = pool.shape[1]
    B, C = slot.shape
    blk, offs = slot // bs, slot % bs
    b_idx = jnp.broadcast_to(jnp.arange(B)[:, None], (B, C))
    q, ns = KQ.quantize_pages(new_vals, pool.dtype)  # [B,C,KVH,*]
    old_q = pool[block_tables][b_idx, blk, offs]     # [B, C, KVH, hd]
    old_s = scale[block_tables][b_idx, blk, offs]    # [B, C, KVH]
    q = jnp.where(valid[..., None, None], q, old_q)
    ns = jnp.where(valid[..., None], ns, old_s)
    bid = jnp.take_along_axis(block_tables, blk, axis=1)  # [B, C]
    return (pool.at[bid, offs].set(q),
            scale.at[bid, offs].set(ns))


def _masked_softmax_pv(scores: jax.Array, mask: jax.Array,
                       v: jax.Array, pv_einsum: str) -> jax.Array:
    """Masked softmax + PV contraction, accumulated in f32, with the
    kernel's empty-row convention: rows whose mask is all-False (e.g.
    ``cache_len == 0`` dead slots) emit ZEROS instead of softmaxing the
    -1e30 fill into a uniform average over garbage KV. This is the
    numerics contract the Pallas paged kernels follow, so the dense
    fallbacks and ``use_kernel=True`` agree within reduction-order
    noise. Returns f32."""
    scores = jnp.where(mask, scores, -1e30)
    m = jnp.max(scores, axis=-1, keepdims=True)
    p = jnp.where(mask, jnp.exp(scores - m), 0.0)
    l = jnp.sum(p, axis=-1, keepdims=True)
    p = p / jnp.where(l == 0.0, 1.0, l)
    return jnp.einsum(pv_einsum, p, v.astype(jnp.float32),
                      preferred_element_type=jnp.float32)


def paged_attention_decode(pool_k: jax.Array, pool_v: jax.Array,
                           q: jax.Array, block_tables: jax.Array,
                           cache_lens: jax.Array, scale: float,
                           use_kernel: bool = False,
                           kernel_mesh=None, k_scale=None,
                           v_scale=None) -> jax.Array:
    """Decode attention over the paged pool.

    q [B, H, hd]; pools [N_blocks, bs, KVH, hd]; block_tables [B, bp];
    cache_lens [B] number of valid tokens. Returns [B, H, hd].

    ``kernel_mesh`` (with ``use_kernel``) routes through the shard_map
    wrapper: lanes shard over "data", the pool's KV heads over "model",
    each computed shard-locally (see ``kernels.ops``).

    ``k_scale``/``v_scale`` [N_blocks, KVH] mark a quantized pool: the
    gathered pages are dequantized (f32 cast then per-page scale) before
    the score matmul — the same multiply the Pallas kernel applies in
    its online-softmax loop, keeping both read paths aligned.
    """
    if use_kernel:
        from repro.kernels import ops as kops
        if kernel_mesh is not None:
            return kops.paged_attention_sharded(
                kernel_mesh, q, pool_k, pool_v, block_tables, cache_lens,
                scale=scale, k_scale=k_scale, v_scale=v_scale)
        return kops.paged_attention(q, pool_k, pool_v, block_tables,
                                    cache_lens, scale=scale,
                                    k_scale=k_scale, v_scale=v_scale)
    B, H, hd = q.shape
    bs = pool_k.shape[1]
    KVH = pool_k.shape[2]
    bp = block_tables.shape[1]
    # gather this sequence's blocks: [B, bp, bs, KVH, hd] -> [B, S, KVH, hd]
    k = pool_k[block_tables]
    v = pool_v[block_tables]
    if k_scale is not None:
        k = KQ.dequantize_pages(k, k_scale[block_tables])
        v = KQ.dequantize_pages(v, v_scale[block_tables])
    k = k.reshape(B, bp * bs, KVH, hd)
    v = v.reshape(B, bp * bs, KVH, hd)
    group = H // KVH
    qg = q.reshape(B, KVH, group, hd)
    scores = jnp.einsum("bkgh,bskh->bkgs", qg, k,
                        preferred_element_type=jnp.float32) * scale
    valid = jnp.arange(bp * bs)[None, :] < cache_lens[:, None]
    out = _masked_softmax_pv(scores, valid[:, None, None, :], v,
                             "bkgs,bskh->bkgh")
    return out.astype(q.dtype).reshape(B, H, hd)


def gqa_attention_decode(p: dict, cfg: ModelConfig, x: jax.Array,
                         positions: jax.Array, cache: dict, layer_slot: int
                         ) -> tuple:
    """One-token decode step with paged KV cache for one layer.

    x [B, 1, D]; positions [B]; cache holds k_pool/v_pool slices for THIS
    layer plus block_tables, cache_lens, window metadata. When the cache
    carries ``k_scale``/``v_scale`` the pool is quantized: writes go
    through the page-requantize path and the attention read dequantizes.
    Returns (out [B,1,D], (new_k_pool, new_v_pool)) — with the new
    scales appended to the pool tuple on the quantized path.
    """
    B, _, D = x.shape
    H, KVH, hd = cfg.num_heads, cfg.num_kv_heads, cfg.head_dim
    act_spec = cache.get("act_spec")
    if act_spec is not None:  # exact TP: see swiglu
        x = jax.lax.with_sharding_constraint(x, act_spec)
    q = (x @ p["wq"]).reshape(B, H, hd)
    k = (x @ p["wk"]).reshape(B, KVH, hd)
    v = (x @ p["wv"]).reshape(B, KVH, hd)
    if cfg.qk_norm:
        q = rms_norm(q, p["q_norm"], cfg.norm_eps)
        k = rms_norm(k, p["k_norm"], cfg.norm_eps)
    cos, sin = rope_cos_sin(positions, hd, cfg.rope_theta)  # [B, hd/2]
    cos, sin = cos[:, None, :], sin[:, None, :]
    q = apply_rope(q, cos, sin)
    k = apply_rope(k, cos, sin)  # rope applied at write time

    window_len = cache["window_len"]  # python int: cache capacity (tokens)
    slot = jnp.where(window_len > 0, positions % window_len, positions)
    k_scale, v_scale = cache.get("k_scale"), cache.get("v_scale")
    if k_scale is None:
        pool_k = paged_kv_update(cache["k_pool"], cache["block_tables"],
                                 slot, k)
        pool_v = paged_kv_update(cache["v_pool"], cache["block_tables"],
                                 slot, v)
    else:
        pool_k, k_scale = paged_kv_update_quant(
            cache["k_pool"], k_scale, cache["block_tables"], slot, k)
        pool_v, v_scale = paged_kv_update_quant(
            cache["v_pool"], v_scale, cache["block_tables"], slot, v)
    pool_spec = cache.get("pool_spec")
    if pool_spec is not None:
        # pin the updated per-layer pools to the serving-mesh layout so
        # the layer scan's stacked outputs keep the canonical sharding
        # (otherwise GSPMD may re-layout the dominant cache bytes around
        # the scatter and drag an all-gather into every tick)
        pool_k = jax.lax.with_sharding_constraint(pool_k, pool_spec)
        pool_v = jax.lax.with_sharding_constraint(pool_v, pool_spec)
        scale_spec = cache.get("scale_spec")
        if k_scale is not None and scale_spec is not None:
            k_scale = jax.lax.with_sharding_constraint(k_scale, scale_spec)
            v_scale = jax.lax.with_sharding_constraint(v_scale, scale_spec)
    new_lens = jnp.minimum(positions + 1, window_len) if window_len > 0 \
        else positions + 1
    out = paged_attention_decode(
        pool_k, pool_v, q, cache["block_tables"], new_lens,
        scale=1.0 / math.sqrt(hd), use_kernel=cache.get("use_kernel", False),
        kernel_mesh=cache.get("kernel_mesh"),
        k_scale=k_scale, v_scale=v_scale)
    out = out.reshape(B, 1, H * hd)
    if act_spec is not None:  # exact TP (see swiglu): gather heads first
        out = jax.lax.with_sharding_constraint(out, act_spec)
    out = out @ p["wo"]
    if k_scale is None:
        return out, (pool_k, pool_v)
    return out, (pool_k, pool_v, k_scale, v_scale)


def gqa_attention_prefill_chunk(p: dict, cfg: ModelConfig, x: jax.Array,
                                positions: jax.Array, valid: jax.Array,
                                k_pool: jax.Array, v_pool: jax.Array,
                                block_tables: jax.Array, window_len: int,
                                window: Optional[int] = None,
                                use_kernel: bool = False,
                                kernel_mesh=None,
                                pool_spec=None, act_spec=None,
                                k_scale=None, v_scale=None,
                                scale_spec=None) -> tuple:
    """Prefill one chunk of a prompt against the paged KV cache.

    The continuous-batching engine splits long prompts into fixed-size
    chunks so prefill interleaves with decode steps instead of stalling
    the running batch. Earlier chunks' KV already sits in the paged pool
    (written by previous calls); this layer writes the chunk's own KV
    into the pool, then attends the chunk's queries over the pooled
    prefix *plus* the exact (un-roundtripped) chunk KV.

    x [B, C, D]; positions [B, C] absolute prompt positions (contiguous
    across the chunk, padding included); valid [B, C] marks real tokens
    (the final chunk is right-padded to the static chunk width — padded
    slots write to the scratch block and their outputs are discarded by
    the caller). Assumes prompt_len <= window_len so slot == position
    (no wraparound during prefill; the engine gates chunked prefill on
    this).

    ``use_kernel`` runs the attention itself through the multi-query
    Pallas paged kernel (``kernels.paged_attention_prefill``): no dense
    [B, KVH, G, C, bp*bs + C] score tensor, dead pool pages skipped.
    ``kernel_mesh`` adds the shard_map routing for mesh engines.

    ``k_scale``/``v_scale`` [N_blocks, KVH] mark a quantized pool: the
    chunk's KV is written through the page-requantize scatter and the
    pooled-prefix read dequantizes (the chunk's own KV stays exact in
    both cases). Returns (out [B, C, D], new_k_pool, new_v_pool), with
    (new_k_scale, new_v_scale) appended on the quantized path.
    """
    B, C, D = x.shape
    H, KVH, hd = cfg.num_heads, cfg.num_kv_heads, cfg.head_dim
    bs = k_pool.shape[1]
    bp = block_tables.shape[1]
    if act_spec is not None:  # exact TP: see swiglu
        x = jax.lax.with_sharding_constraint(x, act_spec)
    q = (x @ p["wq"]).reshape(B, C, H, hd)
    k = (x @ p["wk"]).reshape(B, C, KVH, hd)
    v = (x @ p["wv"]).reshape(B, C, KVH, hd)
    if cfg.qk_norm:
        q = rms_norm(q, p["q_norm"], cfg.norm_eps)
        k = rms_norm(k, p["k_norm"], cfg.norm_eps)
    cos, sin = rope_cos_sin(positions, hd, cfg.rope_theta)  # [B,C,hd/2]
    cos, sin = cos[:, :, None, :], sin[:, :, None, :]
    q = apply_rope(q, cos, sin)
    k = apply_rope(k, cos, sin)

    # scatter the chunk's KV into the pool (padded slots -> scratch 0)
    slot = positions % window_len                      # [B, C] == positions
    if k_scale is None:
        block_ids = jnp.take_along_axis(block_tables, slot // bs, axis=1)
        block_ids = jnp.where(valid, block_ids, 0)
        offs = slot % bs
        new_k_pool = k_pool.at[block_ids, offs].set(k)
        new_v_pool = v_pool.at[block_ids, offs].set(v)
        new_k_scale = new_v_scale = None
    else:
        new_k_pool, new_k_scale = paged_chunk_update_quant(
            k_pool, k_scale, block_tables, slot, valid, k)
        new_v_pool, new_v_scale = paged_chunk_update_quant(
            v_pool, v_scale, block_tables, slot, valid, v)
    if pool_spec is not None:  # serving mesh: keep the pool layout pinned
        new_k_pool = jax.lax.with_sharding_constraint(new_k_pool, pool_spec)
        new_v_pool = jax.lax.with_sharding_constraint(new_v_pool, pool_spec)
        if new_k_scale is not None and scale_spec is not None:
            new_k_scale = jax.lax.with_sharding_constraint(
                new_k_scale, scale_spec)
            new_v_scale = jax.lax.with_sharding_constraint(
                new_v_scale, scale_spec)

    if use_kernel:
        from repro.kernels import ops as kops
        # positions are contiguous across the chunk (engine contract),
        # so the chunk start doubles as the pooled-prefix length and the
        # valid prefix length is a per-row count
        prefix_lens = positions[:, 0].astype(jnp.int32)
        num_valid = jnp.sum(valid.astype(jnp.int32), axis=1)
        args = (q, new_k_pool, new_v_pool, block_tables, prefix_lens,
                num_valid, k, v)
        kw = dict(scale=1.0 / math.sqrt(hd), window=window,
                  k_scale=new_k_scale, v_scale=new_v_scale)
        if kernel_mesh is not None:
            out = kops.paged_attention_prefill_sharded(kernel_mesh, *args,
                                                       **kw)
        else:
            out = kops.paged_attention_prefill(*args, **kw)
        out = out.reshape(B, C, H * hd)
    else:
        # keys/values = [pooled prefix (earlier chunks) ++ exact own
        # chunk]. The pool side is masked to positions strictly before
        # this chunk, so within-chunk attention never round-trips
        # through the (bf16 or quantized) pool — only the cross-chunk
        # prefix does, exactly as decode reads it later.
        kc = new_k_pool[block_tables]
        vc = new_v_pool[block_tables]
        if new_k_scale is not None:
            kc = KQ.dequantize_pages(kc, new_k_scale[block_tables])
            vc = KQ.dequantize_pages(vc, new_v_scale[block_tables])
        kc = kc.reshape(B, bp * bs, KVH, hd)
        vc = vc.reshape(B, bp * bs, KVH, hd)
        keys = jnp.concatenate([kc, k.astype(kc.dtype)], axis=1)
        vals = jnp.concatenate([vc, v.astype(vc.dtype)], axis=1)

        q_pos = positions[:, :, None]                      # [B, C, 1]
        chunk_start = positions[:, :1, None]               # [B, 1, 1]
        pool_pos = jnp.arange(bp * bs)[None, None, :]      # pool slot == pos
        pool_mask = pool_pos < chunk_start                 # earlier chunks
        own_pos = positions[:, None, :]                    # [B, 1, C]
        own_mask = (own_pos <= q_pos) & valid[:, None, :]  # causal + no pad
        mask = jnp.concatenate(
            [jnp.broadcast_to(pool_mask, (B, C, bp * bs)),
             jnp.broadcast_to(own_mask, (B, C, C))], axis=2)
        if window is not None:
            all_pos = jnp.concatenate(
                [jnp.broadcast_to(pool_pos, (B, 1, bp * bs)),
                 jnp.broadcast_to(own_pos, (B, 1, C))], axis=2)
            mask &= all_pos > (q_pos - window)
        # padded queries fully masked -> zeros, the kernel's convention
        mask &= valid[:, :, None]

        group = H // KVH
        qg = q.reshape(B, C, KVH, group, hd)
        scores = jnp.einsum("bqkgh,bskh->bkgqs", qg, keys,
                            preferred_element_type=jnp.float32)
        scores *= 1.0 / math.sqrt(hd)
        out = _masked_softmax_pv(scores, mask[:, None, None], vals,
                                 "bkgqs,bskh->bqkgh")
        out = out.astype(x.dtype).reshape(B, C, H * hd)
    if act_spec is not None:  # exact TP (see swiglu): gather heads first
        out = jax.lax.with_sharding_constraint(out, act_spec)
    out = out @ p["wo"]
    if new_k_scale is None:
        return out, new_k_pool, new_v_pool
    return out, new_k_pool, new_v_pool, new_k_scale, new_v_scale


# ---------------------------------------------------------------------------
# contiguous-cache decode attention — the DISTRIBUTED serving layout
# ---------------------------------------------------------------------------
# On the production mesh each data shard owns its sequences' caches as a
# dense [B_local, capacity, ...] ring buffer: block tables are a host-side
# per-shard allocator concern (exactly what the engine's BlockManager is),
# while the device-side step sees a contiguous buffer. This avoids the
# cross-shard gather a flat global pool would force GSPMD to emit.
# Semantics (rolling window via slot = pos % capacity) are identical to
# the flat-pool path — tests assert both against forward_full.


def contiguous_kv_update(cache: jax.Array, slot: jax.Array,
                         new: jax.Array) -> jax.Array:
    """cache [B, cap, ...]; slot [B]; new [B, ...] -> updated cache."""
    B = cache.shape[0]
    return cache.at[jnp.arange(B), slot].set(new)


def gqa_attention_decode_contiguous(p: dict, cfg: ModelConfig, x: jax.Array,
                                    positions: jax.Array, k_cache: jax.Array,
                                    v_cache: jax.Array, window_len: int
                                    ) -> tuple:
    """One-token decode with contiguous per-sequence caches.

    x [B,1,D]; k/v_cache [B, cap, KVH, hd]. Returns (out, new_k, new_v).
    """
    B, _, D = x.shape
    H, KVH, hd = cfg.num_heads, cfg.num_kv_heads, cfg.head_dim
    cap = k_cache.shape[1]
    q = (x @ p["wq"]).reshape(B, H, hd)
    k = (x @ p["wk"]).reshape(B, KVH, hd)
    v = (x @ p["wv"]).reshape(B, KVH, hd)
    if cfg.qk_norm:
        q = rms_norm(q, p["q_norm"], cfg.norm_eps)
        k = rms_norm(k, p["k_norm"], cfg.norm_eps)
    cos, sin = rope_cos_sin(positions, hd, cfg.rope_theta)
    cos, sin = cos[:, None, :], sin[:, None, :]
    q = apply_rope(q, cos, sin)
    k = apply_rope(k, cos, sin)

    slot = positions % cap
    k_cache = contiguous_kv_update(k_cache, slot, k)
    v_cache = contiguous_kv_update(v_cache, slot, v)
    lens = jnp.minimum(positions + 1, cap)

    group = H // KVH
    qg = q.reshape(B, KVH, group, hd)
    scores = jnp.einsum("bkgh,bskh->bkgs", qg, k_cache,
                        preferred_element_type=jnp.float32)
    scores *= 1.0 / math.sqrt(hd)
    valid = jnp.arange(cap)[None, :] < lens[:, None]
    out = _masked_softmax_pv(scores, valid[:, None, None, :], v_cache,
                             "bkgs,bskh->bkgh")
    out = out.astype(x.dtype).reshape(B, 1, H * hd) @ p["wo"]
    return out, k_cache, v_cache


def mla_attention_decode_contiguous(p: dict, cfg: ModelConfig, x: jax.Array,
                                    positions: jax.Array, kv_cache: jax.Array
                                    ) -> tuple:
    """Absorbed MLA decode over a contiguous latent cache [B, cap, L+rd]."""
    B, _, D = x.shape
    H = cfg.num_heads
    nd, rd, vd = cfg.qk_nope_head_dim, cfg.qk_rope_head_dim, cfg.v_head_dim
    L = cfg.kv_lora_rank
    cap = kv_cache.shape[1]

    q_lat = rms_norm(x @ p["wq_a"], p["q_a_norm"], cfg.norm_eps)
    q = (q_lat @ p["wq_b"]).reshape(B, H, nd + rd)
    q_nope, q_rope = q[..., :nd], q[..., nd:]

    kv_a = (x @ p["wkv_a"]).reshape(B, L + rd)
    c_kv = rms_norm(kv_a[..., :L], p["kv_a_norm"], cfg.norm_eps)
    k_rope = kv_a[..., L:]
    cos, sin = rope_cos_sin(positions, rd, cfg.rope_theta)
    q_rope = apply_rope(q_rope, cos[:, None], sin[:, None])
    k_rope = apply_rope(k_rope[:, None, :], cos[:, None], sin[:, None])[:, 0]

    slot = positions % cap
    entry = jnp.concatenate([c_kv, k_rope], axis=-1)
    kv_cache = contiguous_kv_update(kv_cache, slot, entry)
    lens = jnp.minimum(positions + 1, cap)

    wk_b = p["wk_b"].reshape(L, H, nd)
    q_abs = jnp.einsum("bhd,lhd->bhl", q_nope, wk_b)
    c_seq, kr_seq = kv_cache[..., :L], kv_cache[..., L:]
    scores = (jnp.einsum("bhl,bsl->bhs", q_abs, c_seq,
                         preferred_element_type=jnp.float32)
              + jnp.einsum("bhd,bsd->bhs", q_rope, kr_seq,
                           preferred_element_type=jnp.float32))
    scores *= 1.0 / math.sqrt(nd + rd)
    valid = jnp.arange(cap)[None, :] < lens[:, None]
    scores = jnp.where(valid[:, None, :], scores, -1e30)
    probs = jax.nn.softmax(scores, axis=-1).astype(x.dtype)
    o_lat = jnp.einsum("bhs,bsl->bhl", probs, c_seq)
    wv_b = p["wv_b"].reshape(L, H, vd)
    out = jnp.einsum("bhl,lhd->bhd", o_lat, wv_b).reshape(B, 1, H * vd)
    return out @ p["wo"], kv_cache


# ---------------------------------------------------------------------------
# cross attention (enc-dec)
# ---------------------------------------------------------------------------

def cross_attention(p: dict, cfg: ModelConfig, x: jax.Array,
                    enc_k: jax.Array, enc_v: jax.Array) -> jax.Array:
    """x [B, S, D]; enc_k/enc_v [B, T, KVH, hd] precomputed at prefill."""
    B, S, D = x.shape
    H, KVH, hd = cfg.num_heads, cfg.num_kv_heads, cfg.head_dim
    q = (x @ p["wq"]).reshape(B, S, H, hd)
    group = H // KVH
    qg = q.reshape(B, S, KVH, group, hd)
    scores = jnp.einsum("bqkgh,btkh->bkgqt", qg, enc_k,
                        preferred_element_type=jnp.float32)
    scores *= 1.0 / math.sqrt(hd)
    probs = jax.nn.softmax(scores, axis=-1).astype(x.dtype)
    out = jnp.einsum("bkgqt,btkh->bqkgh", probs, enc_v).reshape(B, S, H * hd)
    return out @ p["wo"]


def cross_kv(p: dict, cfg: ModelConfig, enc_out: jax.Array):
    B, T, D = enc_out.shape
    KVH, hd = cfg.num_kv_heads, cfg.head_dim
    k = (enc_out @ p["wk"]).reshape(B, T, KVH, hd)
    v = (enc_out @ p["wv"]).reshape(B, T, KVH, hd)
    return k, v


# ---------------------------------------------------------------------------
# MLA (DeepSeek-V2 multi-head latent attention)
# ---------------------------------------------------------------------------

def mla_attention_full(p: dict, cfg: ModelConfig, x: jax.Array,
                       positions: jax.Array, return_kv: bool = False,
                       act_spec=None):
    """Full-sequence MLA (train / prefill).

    p: {wq_a [D, q_lora], wq_b [q_lora, H*(nope+rope)],
        wkv_a [D, kv_lora + rope], wk_b [kv_lora, H*nope],
        wv_b [kv_lora, H*v], wo [H*v, D],
        q_a_norm [q_lora], kv_a_norm [kv_lora]}
    """
    B, S, D = x.shape
    H = cfg.num_heads
    nd, rd, vd = cfg.qk_nope_head_dim, cfg.qk_rope_head_dim, cfg.v_head_dim
    if act_spec is not None:  # exact TP: see swiglu
        x = jax.lax.with_sharding_constraint(x, act_spec)
    q_lat = rms_norm(x @ p["wq_a"], p["q_a_norm"], cfg.norm_eps)
    q = (q_lat @ p["wq_b"]).reshape(B, S, H, nd + rd)
    q_nope, q_rope = q[..., :nd], q[..., nd:]

    kv_a = x @ p["wkv_a"]  # [B,S,kv_lora+rd]
    c_kv = rms_norm(kv_a[..., :cfg.kv_lora_rank], p["kv_a_norm"], cfg.norm_eps)
    k_rope = kv_a[..., cfg.kv_lora_rank:]  # [B,S,rd] shared across heads

    cos, sin = rope_cos_sin(positions, rd, cfg.rope_theta)
    q_rope = apply_rope(q_rope, cos[:, :, None], sin[:, :, None])
    k_rope = apply_rope(k_rope[:, :, None, :], cos[:, :, None],
                        sin[:, :, None])[:, :, 0]

    k_nope = (c_kv @ p["wk_b"]).reshape(B, S, H, nd)
    v = (c_kv @ p["wv_b"]).reshape(B, S, H, vd)

    scale = 1.0 / math.sqrt(nd + rd)
    if S > CHUNKED_ATTN_THRESHOLD:
        # fold the shared roped key into per-head keys and run the
        # chunked online-softmax path (what lowers at 32k)
        qh = jnp.concatenate([q_nope, q_rope], axis=-1) * scale
        kh = jnp.concatenate(
            [k_nope, jnp.broadcast_to(k_rope[:, :, None], (B, S, H, rd))],
            axis=-1)
        out = chunked_mha(qh.transpose(0, 2, 1, 3),
                          kh.transpose(0, 2, 1, 3),
                          v.transpose(0, 2, 1, 3), chunk=ATTN_CHUNK)
        out = out.transpose(0, 2, 1, 3).reshape(B, S, H * vd)
    else:
        s_nope = jnp.einsum("bqhd,bshd->bhqs", q_nope, k_nope,
                            preferred_element_type=jnp.float32)
        s_rope = jnp.einsum("bqhd,bsd->bhqs", q_rope, k_rope,
                            preferred_element_type=jnp.float32)
        scores = (s_nope + s_rope) * scale
        mask = _attn_mask(S, S, None)
        scores = jnp.where(mask[None, None], scores, -1e30)
        probs = jax.nn.softmax(scores, axis=-1).astype(x.dtype)
        out = jnp.einsum("bhqs,bshd->bqhd", probs, v).reshape(B, S, H * vd)
    if act_spec is not None:  # exact TP (see swiglu): gather heads first
        out = jax.lax.with_sharding_constraint(out, act_spec)
    out = out @ p["wo"]
    if return_kv:
        # paged-cache entry = [compressed latent | roped shared key]
        return out, jnp.concatenate([c_kv, k_rope], axis=-1)
    return out


def mla_attention_decode(p: dict, cfg: ModelConfig, x: jax.Array,
                         positions: jax.Array, cache: dict) -> tuple:
    """Absorbed-weight MLA decode over the paged latent cache.

    Cache stores [latent (kv_lora) | roped k (rd)] per token:
    kv_pool [N_blocks, bs, kv_lora + rd].

    The absorption trick (beyond-paper TPU adaptation, also used by
    DeepSeek's own inference): fold W_uk into q and W_uv into the output
    so attention runs directly in the latent space — no per-head K/V
    materialisation at 32k/500k context.
    """
    B, _, D = x.shape
    H = cfg.num_heads
    nd, rd, vd = cfg.qk_nope_head_dim, cfg.qk_rope_head_dim, cfg.v_head_dim
    L = cfg.kv_lora_rank

    act_spec = cache.get("act_spec")
    if act_spec is not None:  # exact TP: see swiglu
        x = jax.lax.with_sharding_constraint(x, act_spec)
    q_lat = rms_norm(x @ p["wq_a"], p["q_a_norm"], cfg.norm_eps)
    q = (q_lat @ p["wq_b"]).reshape(B, H, nd + rd)
    q_nope, q_rope = q[..., :nd], q[..., nd:]

    kv_a = (x @ p["wkv_a"]).reshape(B, L + rd)
    c_kv = rms_norm(kv_a[..., :L], p["kv_a_norm"], cfg.norm_eps)
    k_rope = kv_a[..., L:]
    cos, sin = rope_cos_sin(positions, rd, cfg.rope_theta)
    q_rope = apply_rope(q_rope, cos[:, None], sin[:, None])
    k_rope = apply_rope(k_rope[:, None, :], cos[:, None], sin[:, None])[:, 0]

    window_len = cache["window_len"]
    slot = jnp.where(window_len > 0, positions % window_len, positions)
    new_entry = jnp.concatenate([c_kv, k_rope], axis=-1)  # [B, L+rd]
    pool = paged_kv_update(cache["kv_pool"][:, :, None, :],
                           cache["block_tables"], slot,
                           new_entry[:, None, :])[:, :, 0, :]
    if cache.get("pool_spec") is not None:
        pool = jax.lax.with_sharding_constraint(pool, cache["pool_spec"])
    new_lens = jnp.minimum(positions + 1, window_len) if window_len > 0 \
        else positions + 1

    # absorb W_uk: q_abs [B,H,L]
    wk_b = p["wk_b"].reshape(L, H, nd)
    q_abs = jnp.einsum("bhd,lhd->bhl", q_nope, wk_b)

    bs = pool.shape[1]
    bp = cache["block_tables"].shape[1]
    entries = pool[cache["block_tables"]].reshape(B, bp * bs, L + rd)
    c_seq, kr_seq = entries[..., :L], entries[..., L:]
    scores = (jnp.einsum("bhl,bsl->bhs", q_abs, c_seq,
                         preferred_element_type=jnp.float32)
              + jnp.einsum("bhd,bsd->bhs", q_rope, kr_seq,
                           preferred_element_type=jnp.float32))
    scores *= 1.0 / math.sqrt(nd + rd)
    valid = jnp.arange(bp * bs)[None, :] < new_lens[:, None]
    scores = jnp.where(valid[:, None, :], scores, -1e30)
    probs = jax.nn.softmax(scores, axis=-1).astype(x.dtype)
    o_lat = jnp.einsum("bhs,bsl->bhl", probs, c_seq)  # [B,H,L]
    wv_b = p["wv_b"].reshape(L, H, vd)
    out = jnp.einsum("bhl,lhd->bhd", o_lat, wv_b).reshape(B, 1, H * vd)
    if act_spec is not None:  # exact TP (see swiglu): gather heads first
        out = jax.lax.with_sharding_constraint(out, act_spec)
    return out @ p["wo"], pool


# ---------------------------------------------------------------------------
# MoE (top-k router, capacity-based dispatch/combine)
# ---------------------------------------------------------------------------

MOE_CHUNK_TOKENS = 524288


def _moe_group_size(T: int, E: int) -> int:
    """GShard-style dispatch groups: the [G, Tg, E, C] one-hot dispatch
    tensor is quadratic in group size, so production configs use many
    small groups. Tg ~ 256 keeps the tensor O(GB) even at E=160,
    T=1M (train_4k); tiny inputs use a single group."""
    target = 256 if E >= 32 else 1024
    gs = min(T, target)
    while T % gs:
        gs -= 1
    return gs


def moe_layer(p: dict, cfg: ModelConfig, x: jax.Array,
              capacity_factor: float = None,
              expert_weight_spec=None,
              ex_in_spec=None) -> tuple:
    """Top-k MoE with shared experts (DeepSeek-style when configured),
    group-wise capacity dispatch (GShard/Switch semantics).

    p: {router [D, E],
        experts {w_gate [E, D, F], w_up [E, D, F], w_down [E, F, D]},
        (optional) shared {w_gate [D, F*n_sh], w_up, w_down}}
    Returns (out, aux_loss).

    ``expert_weight_spec``: optional PartitionSpec the expert weights are
    constrained to BEFORE the group-chunk scan. Under FSDP the weights
    arrive data-sharded; without this hoist, GSPMD re-all-gathers them on
    EVERY chunk iteration (measured: 4.9 TB/device/step for mixtral
    train_4k — the dominant collective term). Constraining to the
    fsdp-free spec materialises one gathered copy per layer instead.
    """
    B, S, D = x.shape
    E, K = cfg.num_experts, cfg.num_experts_per_tok
    T = B * S
    xt = x.reshape(T, D)
    if capacity_factor is None:
        capacity_factor = cfg.moe_capacity_factor
    if expert_weight_spec is not None:
        p = dict(p)
        p["experts"] = {
            k: jax.lax.with_sharding_constraint(v, expert_weight_spec[k])
            for k, v in p["experts"].items()
        }

    gates = jax.nn.softmax((xt @ p["router"]).astype(jnp.float32), axis=-1)
    weights, sel = jax.lax.top_k(gates, K)  # [T,K]
    weights = weights / (jnp.sum(weights, axis=-1, keepdims=True) + 1e-9)

    # load-balancing aux loss (Switch-style)
    me = jnp.mean(gates, axis=0)
    ce = jnp.mean(
        jnp.sum(jax.nn.one_hot(sel, E, dtype=jnp.float32), axis=1), axis=0)
    aux_loss = E * jnp.sum(me * ce)

    gs = _moe_group_size(T, E)
    G = T // gs
    capacity = max(1, int(capacity_factor * gs * K / E))
    xg = xt.reshape(G, gs, D)
    sel_g = sel.reshape(G, gs, K)
    w_g = weights.reshape(G, gs, K)
    pe = p["experts"]

    def groups_block(xg_c, sel_c, w_c):
        """Dispatch+expert-ffn+combine for a slice of groups.

        Bounds the live [Gc, E, C, *] dispatch buffers — at 1M tokens the
        full-G expert intermediates are tens of GB per layer.
        """
        # position of each (token, k) within its expert queue, per group
        sel_onehot = jax.nn.one_hot(sel_c, E, dtype=jnp.int32)  # [Gc,gs,K,E]
        Gc = sel_c.shape[0]
        flat = sel_onehot.reshape(Gc, gs * K, E)
        pos_in_expert = (jnp.cumsum(flat, axis=1) - flat) \
            .reshape(Gc, gs, K, E)
        pos = jnp.sum(pos_in_expert * sel_onehot, axis=-1)  # [Gc,gs,K]
        keep = pos < capacity

        disp = (sel_onehot.astype(jnp.bool_)
                & keep[..., None]).astype(xt.dtype)  # [Gc,gs,K,E]
        pos_oh = jax.nn.one_hot(jnp.where(keep, pos, capacity),
                                capacity + 1,
                                dtype=xt.dtype)[..., :capacity]
        dispatch = jnp.einsum("gtke,gtkc->gtec", disp, pos_oh)
        combine = jnp.einsum("gtke,gtkc,gtk->gtec", disp, pos_oh,
                             w_c.astype(xt.dtype))

        ex_in = jnp.einsum("gtec,gtd->gecd", dispatch, xg_c)  # [Gc,E,C,D]
        if ex_in_spec is not None:
            # DECODE expert parallelism: dispatched activations are tiny
            # (tokens*topk*D ~ MB) while FSDP-sharded expert weights are
            # tens of GB; resharding ex_in to the weights' (E-model,
            # D-data) layout makes GSPMD move activations and leave the
            # weights stationary (partial-sum matmul + small all-reduce)
            # instead of all-gathering the weights every step.
            ex_in = jax.lax.with_sharding_constraint(ex_in, ex_in_spec)
        g_ = jnp.einsum("gecd,edf->gecf", ex_in, pe["w_gate"])
        u = jnp.einsum("gecd,edf->gecf", ex_in, pe["w_up"])
        act = jax.nn.silu(g_.astype(jnp.float32)).astype(xt.dtype) * u
        ex_out = jnp.einsum("gecf,efd->gecd", act, pe["w_down"])
        return jnp.einsum("gtec,gecd->gtd", combine, ex_out)

    # tokens of expert compute live at once; larger chunks amortise the
    # FSDP weight all-gather inside the chunk scan (iteration 2 of the
    # mixtral train_4k hillclimb: 64k -> 256k cut collective time 2.4x)
    chunk_groups = max(1, (MOE_CHUNK_TOKENS + gs - 1) // gs)
    if G > chunk_groups:
        while G % chunk_groups:
            chunk_groups -= 1
        nc = G // chunk_groups

        @jax.checkpoint
        def body(_, inp):
            xg_c, sel_c, w_c = inp
            return None, groups_block(xg_c, sel_c, w_c)

        _, out = jax.lax.scan(
            body, None,
            (xg.reshape(nc, chunk_groups, gs, D),
             sel_g.reshape(nc, chunk_groups, gs, K),
             w_g.reshape(nc, chunk_groups, gs, K)))
        out = out.reshape(T, D)
    else:
        out = groups_block(xg, sel_g, w_g).reshape(T, D)

    if cfg.num_shared_experts:
        out = out + swiglu(p["shared"], xt)
    return out.reshape(B, S, D), aux_loss


# ---------------------------------------------------------------------------
# Mamba2 (SSD) — chunked full-sequence + single-step decode
# ---------------------------------------------------------------------------

def _segsum(a: jax.Array) -> jax.Array:
    """Stable segment-sum: out[..., i, j] = sum_{j<k<=i} a[..., k]."""
    T = a.shape[-1]
    cs = jnp.cumsum(a, axis=-1)
    out = cs[..., :, None] - cs[..., None, :]
    mask = jnp.tril(jnp.ones((T, T), dtype=bool), 0)
    return jnp.where(mask, out, -jnp.inf)


def ssd_chunked(x: jax.Array, dt: jax.Array, A: jax.Array,
                Bm: jax.Array, Cm: jax.Array, chunk: int,
                initial_state: Optional[jax.Array] = None,
                use_kernel: bool = False):
    """SSD (state-space duality) scan, chunked.

    x  [B, S, H, P]   (P = head dim)
    dt [B, S, H]      (softplus'd step sizes)
    A  [H]            (negative decay rates)
    Bm [B, S, N], Cm [B, S, N]  (shared across heads, ngroups=1)
    Returns (y [B,S,H,P], final_state [B,H,P,N]).
    """
    if use_kernel:
        from repro.kernels import ops as kops
        return kops.ssd_scan(x, dt, A, Bm, Cm, chunk=chunk,
                             initial_state=initial_state)
    B, S, H, P = x.shape
    N = Bm.shape[-1]
    nc = S // chunk
    xc = x.reshape(B, nc, chunk, H, P)
    dtc = dt.reshape(B, nc, chunk, H)
    Bc = Bm.reshape(B, nc, chunk, N)
    Cc = Cm.reshape(B, nc, chunk, N)

    dA = dtc * A[None, None, None, :]  # [B,nc,l,H]
    dA = jnp.moveaxis(dA, -1, 2)  # [B,nc,H,l]
    dA_cumsum = jnp.cumsum(dA, axis=-1)

    # 1. intra-chunk (quadratic within chunk)
    L = jnp.exp(_segsum(dA))  # [B,nc,H,l,l]
    scores = jnp.einsum("bcln,bcsn->bcls", Cc, Bc)[:, :, None] * L
    y_diag = jnp.einsum("bchls,bcsh,bcshp->bclhp", scores, dtc, xc)

    # 2. chunk states
    decay_states = jnp.exp(dA_cumsum[..., -1:] - dA_cumsum)  # [B,nc,H,l]
    states = jnp.einsum("bcln,bchl,bclh,bclhp->bchpn",
                        Bc, decay_states, dtc, xc)

    # 3. inter-chunk recurrence (scan over chunks)
    chunk_decay = jnp.exp(dA_cumsum[..., -1])  # [B,nc,H]
    if initial_state is None:
        initial_state = jnp.zeros((B, H, P, N), dtype=states.dtype)

    def step(h, inp):
        st, dec = inp
        h_new = h * dec[..., None, None] + st
        return h_new, h

    final_state, h_prev = jax.lax.scan(
        step, initial_state,
        (jnp.moveaxis(states, 1, 0), jnp.moveaxis(chunk_decay, 1, 0)))
    h_prev = jnp.moveaxis(h_prev, 0, 1)  # [B,nc,H,P,N] state BEFORE chunk

    # 4. state -> output contribution
    state_decay = jnp.exp(dA_cumsum)  # [B,nc,H,l]
    y_off = jnp.einsum("bcln,bchpn,bchl->bclhp", Cc, h_prev, state_decay)

    y = (y_diag + y_off).reshape(B, S, H, P)
    return y, final_state


def ssd_decode_step(state: jax.Array, x: jax.Array, dt: jax.Array,
                    A: jax.Array, Bm: jax.Array, Cm: jax.Array):
    """Single-token SSD recurrence.

    state [B,H,P,N]; x [B,H,P]; dt [B,H]; A [H]; Bm/Cm [B,N].
    Returns (y [B,H,P], new_state).
    """
    dA = jnp.exp(dt * A[None, :])  # [B,H]
    dBx = jnp.einsum("bh,bn,bhp->bhpn", dt, Bm, x)
    new_state = state * dA[..., None, None] + dBx
    y = jnp.einsum("bhpn,bn->bhp", new_state, Cm)
    return y, new_state


def causal_conv1d(x: jax.Array, w: jax.Array, b: jax.Array) -> jax.Array:
    """Depthwise causal conv. x [B,S,C]; w [W,C]; b [C]."""
    W = w.shape[0]
    xp = jnp.pad(x, ((0, 0), (W - 1, 0), (0, 0)))
    out = sum(xp[:, i:i + x.shape[1], :] * w[i][None, None, :]
              for i in range(W))
    return out + b[None, None, :]


def causal_conv1d_step(conv_state: jax.Array, x_t: jax.Array,
                       w: jax.Array, b: jax.Array):
    """conv_state [B, W-1, C]; x_t [B, C]. Returns (y [B,C], new_state)."""
    full = jnp.concatenate([conv_state, x_t[:, None, :]], axis=1)  # [B,W,C]
    y = jnp.einsum("bwc,wc->bc", full, w) + b[None, :]
    return y, full[:, 1:, :]


def mamba2_mixer_full(p: dict, cfg: ModelConfig, x: jax.Array,
                      use_kernel: bool = False, return_state: bool = False):
    """Full-sequence Mamba2 mixer.

    p: {w_in [D, d_inner*2 + 2N + H], conv_w [W, d_inner+2N], conv_b,
        A_log [H], D [H], dt_bias [H], norm_w [d_inner], w_out [d_inner, D]}
    With return_state, also returns (ssm_state [B,H,P,N],
    conv_state [B,W-1,di+2N]) for decode continuation.
    """
    B, S, D = x.shape
    di, N, H = cfg.d_inner, cfg.ssm_state_size, cfg.ssm_heads
    P = cfg.ssm_head_dim
    zxbcdt = x @ p["w_in"]
    z = zxbcdt[..., :di]
    xbc_raw = zxbcdt[..., di:di + di + 2 * N]
    dt = zxbcdt[..., di + di + 2 * N:]  # [B,S,H]
    xbc = jax.nn.silu(causal_conv1d(xbc_raw, p["conv_w"], p["conv_b"])
                      .astype(jnp.float32)).astype(x.dtype)
    xs = xbc[..., :di].reshape(B, S, H, P)
    Bm = xbc[..., di:di + N]
    Cm = xbc[..., di + N:]
    dt = jax.nn.softplus(dt.astype(jnp.float32)
                         + p["dt_bias"].astype(jnp.float32))
    A = -jnp.exp(p["A_log"].astype(jnp.float32))

    chunk = min(cfg.ssm_chunk_size, S)
    pad = (-S) % chunk
    xs_f = xs.astype(jnp.float32)
    Bm_f, Cm_f = Bm.astype(jnp.float32), Cm.astype(jnp.float32)
    if pad:
        # dt=0 on padding keeps the state exactly (decay 1, input 0)
        xs_f = jnp.pad(xs_f, ((0, 0), (0, pad), (0, 0), (0, 0)))
        dt_p = jnp.pad(dt, ((0, 0), (0, pad), (0, 0)))
        Bm_f = jnp.pad(Bm_f, ((0, 0), (0, pad), (0, 0)))
        Cm_f = jnp.pad(Cm_f, ((0, 0), (0, pad), (0, 0)))
    else:
        dt_p = dt
    y, final_state = ssd_chunked(xs_f, dt_p, A, Bm_f, Cm_f, chunk=chunk,
                                 use_kernel=use_kernel)
    y = y[:, :S]
    y = y + xs.astype(jnp.float32) * p["D"].astype(jnp.float32)[None, None, :, None]
    y = y.reshape(B, S, di).astype(x.dtype)
    y = rms_norm(y * jax.nn.silu(z.astype(jnp.float32)).astype(x.dtype),
                 p["norm_w"], cfg.norm_eps)
    out = y @ p["w_out"]
    if return_state:
        W = cfg.ssm_conv_width
        conv_state = xbc_raw[:, -(W - 1):, :] if S >= W - 1 else jnp.pad(
            xbc_raw, ((0, 0), (W - 1 - S, 0), (0, 0)))
        return out, final_state, conv_state.astype(x.dtype)
    return out


def mamba2_mixer_decode(p: dict, cfg: ModelConfig, x: jax.Array,
                        ssm_state: jax.Array, conv_state: jax.Array):
    """One-token Mamba2 step. x [B,1,D]. Returns (out, new_ssm, new_conv)."""
    B, _, D = x.shape
    di, N, H = cfg.d_inner, cfg.ssm_state_size, cfg.ssm_heads
    P = cfg.ssm_head_dim
    zxbcdt = (x[:, 0] @ p["w_in"])
    z = zxbcdt[..., :di]
    xbc = zxbcdt[..., di:di + di + 2 * N]
    dt = zxbcdt[..., di + di + 2 * N:]
    xbc, new_conv = causal_conv1d_step(conv_state, xbc, p["conv_w"], p["conv_b"])
    xbc = jax.nn.silu(xbc.astype(jnp.float32)).astype(x.dtype)
    xs = xbc[..., :di].reshape(B, H, P)
    Bm = xbc[..., di:di + N]
    Cm = xbc[..., di + N:]
    dt = jax.nn.softplus(dt.astype(jnp.float32)
                         + p["dt_bias"].astype(jnp.float32))
    A = -jnp.exp(p["A_log"].astype(jnp.float32))
    y, new_state = ssd_decode_step(
        ssm_state, xs.astype(jnp.float32), dt, A,
        Bm.astype(jnp.float32), Cm.astype(jnp.float32))
    y = y + xs.astype(jnp.float32) * p["D"].astype(jnp.float32)[None, :, None]
    y = y.reshape(B, di).astype(x.dtype)
    y = rms_norm(y * jax.nn.silu(z.astype(jnp.float32)).astype(x.dtype),
                 p["norm_w"], cfg.norm_eps)
    return (y @ p["w_out"])[:, None, :], new_state, new_conv
