"""Synthetic verifiable reasoning task: chained modular arithmetic.

Stands in for the paper's HMMT training problems: every problem has a
deterministic, rule-based-verifiable answer, traces have step structure
("\n\n"-delimited <think> steps), and corrupted traces give labeled
incorrect examples — mirroring the paper's 5,000-correct/5,000-incorrect
scorer dataset construction (Appendix A.2).

Problem:  "3+5-2+7="  — evaluate left-to-right, every intermediate taken
mod 10. The gold trace writes one step per operation:

  <think>3+5=8\n\n8-2=6\n\n6+7=3\n\n</think>boxed{3}<eos>
"""
from __future__ import annotations

import dataclasses
import random
import re
from typing import List, Optional, Tuple

MOD = 10
OPS = "+-*"


@dataclasses.dataclass
class Problem:
    operands: List[int]
    ops: List[str]

    @property
    def text(self) -> str:
        s = str(self.operands[0])
        for op, x in zip(self.ops, self.operands[1:]):
            s += op + str(x)
        return s + "="

    def intermediates(self) -> List[int]:
        acc = self.operands[0] % MOD
        out = []
        for op, x in zip(self.ops, self.operands[1:]):
            if op == "+":
                acc = (acc + x) % MOD
            elif op == "-":
                acc = (acc - x) % MOD
            else:
                acc = (acc * x) % MOD
            out.append(acc)
        return out

    @property
    def answer(self) -> int:
        return self.intermediates()[-1]


def gen_problem(rng: random.Random, n_steps: Tuple[int, int] = (3, 6)
                ) -> Problem:
    k = rng.randint(*n_steps)
    return Problem(operands=[rng.randint(0, 9) for _ in range(k + 1)],
                   ops=[rng.choice(OPS) for _ in range(k)])


def render_trace(p: Problem, corrupt_from: Optional[int] = None,
                 rng: Optional[random.Random] = None) -> Tuple[str, bool]:
    """Gold reasoning trace; if ``corrupt_from`` is a step index, inject an
    arithmetic error there and propagate it (an incorrect trace whose
    prefix is still valid — exactly the early-signal structure the scorer
    must learn). Returns (trace_text, is_correct)."""
    inter = p.intermediates()
    acc = p.operands[0] % MOD
    steps = []
    corrupted = False
    for i, (op, x) in enumerate(zip(p.ops, p.operands[1:])):
        if op == "+":
            nxt = (acc + x) % MOD
        elif op == "-":
            nxt = (acc - x) % MOD
        else:
            nxt = (acc * x) % MOD
        if corrupt_from is not None and i >= corrupt_from and not corrupted:
            assert rng is not None
            nxt = (nxt + rng.randint(1, MOD - 1)) % MOD
            corrupted = True
        steps.append(f"{acc}{op}{x}={nxt}")
        acc = nxt
    body = "\n\n".join(steps) + "\n\n"
    text = f"<think>{body}</think>boxed{{{acc}}}"
    return text, acc == inter[-1]


def make_prompt(p: Problem) -> str:
    return p.text


_BOX_RE = re.compile(r"boxed\{(\d)")


def verify(p: Problem, completion: str) -> Tuple[Optional[str], bool]:
    """Deterministic rule-based verifier (the paper adapts Qwen2.5-Math's).
    Returns (extracted_answer, is_correct)."""
    m = _BOX_RE.search(completion)
    if not m:
        return None, False
    ans = m.group(1)
    return ans, int(ans) == p.answer


def extract_answer(completion: str) -> Optional[str]:
    m = _BOX_RE.search(completion)
    return m.group(1) if m else None
