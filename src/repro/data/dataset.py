"""Data pipeline: LM pretraining batches + scorer hidden-state datasets."""
from __future__ import annotations

import random
from typing import Iterator, List, Tuple

import jax
import jax.numpy as jnp
import numpy as np

from repro.configs.base import ModelConfig
from repro.data.arithmetic import (Problem, gen_problem, make_prompt,
                                   render_trace)
from repro.data.tokenizer import get_tokenizer


def render_example(p: Problem, corrupt_prob: float,
                   rng: random.Random) -> Tuple[List[int], bool]:
    corrupt_from = None
    if rng.random() < corrupt_prob:
        corrupt_from = rng.randint(0, len(p.ops) - 1)
    trace, ok = render_trace(p, corrupt_from, rng)
    tok = get_tokenizer()
    ids = tok.encode(make_prompt(p), add_bos=True) \
        + tok.encode(trace, add_eos=True)
    return ids, ok


def lm_batches(seq_len: int, batch_size: int, seed: int = 0,
               corrupt_prob: float = 0.0,
               n_steps=(3, 9)) -> Iterator[np.ndarray]:
    """Packed LM batches [B, seq_len+1] of concatenated gold traces.
    ``n_steps`` spans the benchmark difficulty range so the served model
    is in-distribution for the evaluation problems."""
    rng = random.Random(seed)
    tok = get_tokenizer()
    buf: List[int] = []
    need = batch_size * (seq_len + 1)
    while True:
        while len(buf) < need:
            ids, _ = render_example(gen_problem(rng, n_steps),
                                    corrupt_prob, rng)
            buf.extend(ids)
        arr = np.array(buf[:need], np.int32).reshape(batch_size, seq_len + 1)
        buf = buf[need:]
        yield arr


def scorer_dataset(params, cfg: ModelConfig, forward_fn,
                   num_traces: int = 512, seed: int = 0,
                   batch: int = 32, max_len: int = 160
                   ) -> Tuple[np.ndarray, np.ndarray, np.ndarray]:
    """Build the step-scorer training set the paper's way (Appendix A.2):
    balanced correct/incorrect traces, hidden states at every "\n\n"
    boundary token, trace label propagated to all steps.

    forward_fn(params, tokens [B,S]) -> hidden [B,S,D]
    Returns (hiddens [M,D] fp32, labels [M], trace_ids [M]).
    """
    rng = random.Random(seed)
    tok = get_tokenizer()
    rows, labels, lens = [], [], []
    half = num_traces // 2
    n_pos = n_neg = 0
    while n_pos < half or n_neg < num_traces - half:
        p = gen_problem(rng)
        want_neg = n_neg < num_traces - half and (n_pos >= half
                                                  or rng.random() < 0.5)
        ids, ok = render_example(p, corrupt_prob=1.0 if want_neg else 0.0,
                                 rng=rng)
        if ok and n_pos >= half:
            continue
        if not ok and n_neg >= num_traces - half:
            continue
        n_pos, n_neg = n_pos + ok, n_neg + (not ok)
        ids = ids[:max_len]
        rows.append(ids)
        labels.append(int(ok))
        lens.append(len(ids))

    S = max(lens)
    toks = np.full((len(rows), S), tok.pad_id, np.int32)
    for i, ids in enumerate(rows):
        toks[i, :len(ids)] = ids

    hid_rows, y_rows, tid_rows = [], [], []
    for i in range(0, len(rows), batch):
        tb = jnp.asarray(toks[i:i + batch])
        hidden = np.asarray(forward_fn(params, tb), np.float32)  # [b,S,D]
        for j in range(tb.shape[0]):
            ids = rows[i + j]
            for pos, t in enumerate(ids):
                if t == tok.step_id:
                    hid_rows.append(hidden[j, pos])
                    y_rows.append(labels[i + j])
                    tid_rows.append(i + j)
    return (np.stack(hid_rows), np.array(y_rows, np.int32),
            np.array(tid_rows, np.int32))
