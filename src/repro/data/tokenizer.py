"""Tiny deterministic tokenizer for the synthetic reasoning task.

Character-level over digits/operators plus the special reasoning markers the
STEP paper keys on: <think>, </think> and the step delimiter "\n\n" (a
single token, so the boundary detector fires exactly at step ends).
"""
from __future__ import annotations

from typing import List

SPECIALS = ["<pad>", "<bos>", "<eos>", "<think>", "</think>", "\n\n",
            "boxed{", "}"]
CHARS = list("0123456789+-*=() ")


class ReasonTokenizer:
    def __init__(self):
        self.vocab: List[str] = SPECIALS + CHARS
        self.tok2id = {t: i for i, t in enumerate(self.vocab)}
        self.pad_id = self.tok2id["<pad>"]
        self.bos_id = self.tok2id["<bos>"]
        self.eos_id = self.tok2id["<eos>"]
        self.think_open_id = self.tok2id["<think>"]
        self.think_close_id = self.tok2id["</think>"]
        self.step_id = self.tok2id["\n\n"]       # the "\n\n" boundary token
        self.boxed_id = self.tok2id["boxed{"]
        self.close_id = self.tok2id["}"]

    @property
    def vocab_size(self) -> int:
        return len(self.vocab)

    def encode(self, text: str, add_bos: bool = False,
               add_eos: bool = False) -> List[int]:
        ids: List[int] = [self.bos_id] if add_bos else []
        i = 0
        while i < len(text):
            for sp in SPECIALS[3:]:  # multi-char specials
                if text.startswith(sp, i):
                    ids.append(self.tok2id[sp])
                    i += len(sp)
                    break
            else:
                ch = text[i]
                if ch in self.tok2id:
                    ids.append(self.tok2id[ch])
                i += 1
        if add_eos:
            ids.append(self.eos_id)
        return ids

    def decode(self, ids: List[int]) -> str:
        return "".join(self.vocab[i] for i in ids
                       if 0 <= i < len(self.vocab)
                       and i not in (self.pad_id, self.bos_id, self.eos_id))


_TOKENIZER = None


def get_tokenizer() -> ReasonTokenizer:
    global _TOKENIZER
    if _TOKENIZER is None:
        _TOKENIZER = ReasonTokenizer()
    return _TOKENIZER
