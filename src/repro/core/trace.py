"""Trace state: running step-score aggregation (paper §4.3).

score_t = (1/n) * sum_i y_hat_i — the running mean over step scores, chosen
over the latest-step score because it "captures the evolution of reasoning
quality across steps and is less sensitive to individual step variance".
"""
from __future__ import annotations

import dataclasses
import enum
from typing import List, Optional, Sequence


class TraceStatus(enum.Enum):
    WAITING = "waiting"        # queued, not yet prefilled
    RUNNING = "running"
    PREEMPTED = "preempted"    # baseline engines: KV freed, awaiting resume
    PRUNED = "pruned"          # STEP: terminated by policy
    FINISHED = "finished"
    CANCELLED = "cancelled"    # released by Engine.cancel / deadline
    FAILED = "failed"          # quarantined (NaN burst) or fatal fault


@dataclasses.dataclass
class Trace:
    trace_id: int
    request_id: int
    prompt_tokens: List[int]
    status: TraceStatus = TraceStatus.WAITING
    output_tokens: List[int] = dataclasses.field(default_factory=list)
    step_scores: List[float] = dataclasses.field(default_factory=list)
    # token-level confidence (DeepConf baseline signal)
    token_confidences: List[float] = dataclasses.field(default_factory=list)
    answer: Optional[str] = None
    # engine bookkeeping
    batch_slot: int = -1
    blocks: List[int] = dataclasses.field(default_factory=list)
    # latency accounting (seconds)
    wait_time: float = 0.0
    decode_time: float = 0.0
    prefill_count: int = 0     # >1 means preemption-induced recompute
    runnable_since: float = 0.0  # timestamp when last became schedulable

    def add_step_score(self, s: float) -> None:
        self.step_scores.append(float(s))

    def add_step_scores(self, scores: Sequence[float]) -> None:
        """Burst append: one scheduler tick may close several reasoning
        steps when the engine decodes a multi-token horizon."""
        self.step_scores.extend(float(s) for s in scores)

    def extend_output(self, tokens: Sequence[int],
                      confidences: Sequence[float]) -> None:
        """Burst append of decoded tokens + their confidences (one call
        per scheduler tick instead of one per token)."""
        assert len(tokens) == len(confidences)
        self.output_tokens.extend(int(t) for t in tokens)
        self.token_confidences.extend(float(c) for c in confidences)

    @property
    def score(self) -> float:
        """Running mean of step scores; 0.5 (uninformative) before the
        first boundary so fresh traces are not unfairly pruned."""
        if not self.step_scores:
            return 0.5
        return sum(self.step_scores) / len(self.step_scores)

    @property
    def confidence(self) -> float:
        if not self.token_confidences:
            return 1.0
        return sum(self.token_confidences) / len(self.token_confidences)

    @property
    def num_tokens(self) -> int:
        return len(self.output_tokens)

    @property
    def total_len(self) -> int:
        return len(self.prompt_tokens) + len(self.output_tokens)

    @property
    def alive(self) -> bool:
        return self.status in (TraceStatus.WAITING, TraceStatus.RUNNING,
                               TraceStatus.PREEMPTED)
