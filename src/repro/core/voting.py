"""Answer aggregation (paper §4.3 and Table 2).

  majority_vote        — self-consistency baseline.
  weighted_vote        — STEP: trace-score-weighted majority.
  confidence_vote      — DeepConf: confidence-weighted majority.
"""
from __future__ import annotations

from collections import defaultdict
from typing import Dict, List, Optional, Sequence, Tuple


def _tally(answers: Sequence[Optional[str]],
           weights: Sequence[float]) -> Dict[str, float]:
    votes: Dict[str, float] = defaultdict(float)
    for a, w in zip(answers, weights):
        if a is not None and a != "":
            votes[a] += w
    return votes


def majority_vote(answers: Sequence[Optional[str]]) -> Optional[str]:
    votes = _tally(answers, [1.0] * len(answers))
    return max(votes, key=votes.get) if votes else None


def weighted_vote(answers: Sequence[Optional[str]],
                  weights: Sequence[float]) -> Optional[str]:
    votes = _tally(answers, weights)
    return max(votes, key=votes.get) if votes else None


def vote_breakdown(answers: Sequence[Optional[str]],
                   weights: Sequence[float]) -> List[Tuple[str, float]]:
    votes = _tally(answers, weights)
    return sorted(votes.items(), key=lambda kv: -kv[1])
