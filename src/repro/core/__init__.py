"""STEP core: the paper's contribution.

  scorer        — hidden-state step scorer (2-layer MLP, weighted BCE)
  segmentation  — step-boundary detection ("\\n\\n" tokens in <think>)
  trace         — running trace-score aggregation
  pruning       — memory-aware STEP policy + SC / Slim-SC / DeepConf
  voting        — majority / score-weighted / confidence-weighted votes
"""
from repro.core.pruning import (DeepConfPolicy, PruningPolicy,  # noqa: F401
                                SelfConsistency, SingleTrace, SlimSCPolicy,
                                StepPolicy, make_policy)
from repro.core.scorer import (init_scorer, rank_accuracy,  # noqa: F401
                               scorer_logits, scorer_score, train_scorer,
                               ScorerTrainConfig)
from repro.core.segmentation import (StepBoundaryDetector,  # noqa: F401
                                     extract_think, split_steps)
from repro.core.trace import Trace, TraceStatus  # noqa: F401
from repro.core.voting import (majority_vote, vote_breakdown,  # noqa: F401
                               weighted_vote)
