"""Pruning policies: STEP (ours) + the paper's baselines (§5.1).

The engine consults the active policy at three points each scheduler
step:

  * ``observe_pressure(pressure)``     — once per scheduler tick, the
    engine publishes the current admission pressure (queued requests,
    runnable-but-unadmitted traces, pool occupancy). Policies may use it
    to modulate pruning; the base implementation just records it.
  * ``traces_to_terminate(running)``   — signal-triggered early stopping
    (DeepConf confidence threshold, Slim-SC similarity pruning, STEP's
    optional proactive pruning under admission pressure);
  * ``on_memory_full(running, pressure=...)`` — invoked when the paged
    KV pool cannot schedule the next decode step. STEP returns the
    lowest-scored trace to prune (freeing its blocks immediately — the
    waiting queue never forms); baselines return None, which makes the
    engine PREEMPT a trace vLLM-style (free blocks, re-enqueue,
    recompute later).
"""
from __future__ import annotations

import dataclasses
import random
from typing import List, Mapping, Optional, Sequence

import numpy as np

from repro.core.trace import Trace
from repro.core.voting import majority_vote, weighted_vote


@dataclasses.dataclass(frozen=True)
class AdmissionPressure:
    """What the scheduler can tell a policy about contention right now.

    Published once per tick (continuous batching: arrivals land while
    earlier requests still decode, so pruning decisions can react to how
    much work is knocking on the door, not just to the instant the pool
    runs dry — the online regime ReProbe / Tracing-the-Traces evaluate).
    """

    waiting_traces: int = 0     # runnable traces with no decode slot/blocks
    queued_requests: int = 0    # arrived requests not yet started
    free_blocks: int = 0
    total_blocks: int = 0
    # prefix-cache occupancy (0 with the cache off). Parked blocks are
    # NOT live-trace memory: the engine evicts them before consulting any
    # pruning policy (evict-before-prune), so policies must count
    # evictable cache blocks as headroom — otherwise cache occupancy
    # would trigger proactive pruning the cache-off engine never does.
    cached_blocks: int = 0      # blocks parked in the prefix-cache trie
    evictable_blocks: int = 0   # parked blocks only the cache references
    # multi-tenant view (None under the default FIFO scheduling policy):
    # waiting traces per tenant, and each tenant's remaining weighted
    # fair-share token deficit — a policy can prune harder for tenants
    # that are over budget (negative deficit) before the scheduler
    # preempts them.
    demand_by_tenant: Optional[Mapping[str, int]] = None
    deficit_by_tenant: Optional[Mapping[str, float]] = None
    # fault-degraded serving: True while the engine runs a persistent-
    # fault degrade rung (kernel->dense, horizon pin, fan-out shed). A
    # policy may prune more conservatively — degraded capacity is
    # transient, not a demand signal.
    degraded: bool = False
    # HBM bytes per KV block (pool storage + quantization scales; see
    # kv_quant.pool_block_bytes). 0 when the publisher didn't wire byte
    # accounting — the byte properties then report 0 and policies fall
    # back to block counts. With quantized pools the same block budget
    # costs ~4x fewer bytes, so byte-aware policies see the real HBM
    # picture instead of a dtype-blind block tally.
    bytes_per_block: int = 0

    @property
    def memory_utilization(self) -> float:
        if self.total_blocks <= 0:
            return 0.0
        return 1.0 - self.free_blocks / self.total_blocks

    @property
    def reclaimable_blocks(self) -> int:
        """Headroom the scheduler can produce without touching a live
        trace: the free list plus evict-before-prune cache blocks."""
        return self.free_blocks + self.evictable_blocks

    @property
    def demand(self) -> int:
        """Units of work contending for admission."""
        return self.waiting_traces + self.queued_requests

    @property
    def free_bytes(self) -> int:
        """Free-list HBM bytes (0 when byte accounting is unwired)."""
        return self.free_blocks * self.bytes_per_block

    @property
    def total_bytes(self) -> int:
        """Allocatable pool HBM bytes (excludes the scratch block)."""
        return self.total_blocks * self.bytes_per_block

    @property
    def reclaimable_bytes(self) -> int:
        """Byte view of :attr:`reclaimable_blocks`."""
        return self.reclaimable_blocks * self.bytes_per_block


class PruningPolicy:
    """Base: self-consistency behaviour (no pruning, preemption on OOM)."""

    name = "sc"
    uses_scorer = False
    last_pressure: Optional[AdmissionPressure] = None

    def observe_pressure(self, pressure: AdmissionPressure) -> None:
        """Scheduler-tick hook: record the current admission pressure."""
        self.last_pressure = pressure

    def observe_decode_burst(self, trace: Trace, tokens: Sequence[int],
                             confidences: Sequence[float],
                             step_scores: Sequence[float]) -> None:
        """Per-trace per-tick burst hook (decode horizon).

        With ``EngineConfig.decode_horizon`` K > 1 the engine emits up to
        K tokens per trace per scheduler tick; the burst (already
        appended to ``trace``) is handed over in one call instead of K
        one-at-a-time appends. Termination sweeps
        (``traces_to_terminate``) therefore run at horizon granularity:
        a policy reacting to a signal inside the burst can terminate the
        trace at the next sweep, at most K-1 tokens late. The base
        implementation records nothing; stateful policies may override
        to update incremental signal aggregates.
        """

    def traces_to_terminate(self, running: Sequence[Trace]) -> List[Trace]:
        return []

    def on_memory_full(self, running: Sequence[Trace],
                       pressure: Optional[AdmissionPressure] = None
                       ) -> Optional[Trace]:
        return None  # => engine preempts (waiting queue forms)

    def vote(self, traces: Sequence[Trace]) -> Optional[str]:
        return majority_vote([t.answer for t in traces])


class SelfConsistency(PruningPolicy):
    name = "sc"


class SingleTrace(PruningPolicy):
    """CoT baseline — the engine simply launches one trace."""
    name = "cot"


@dataclasses.dataclass
class StepPolicy(PruningPolicy):
    """STEP (ours): hidden-state step scores + memory-aware pruning +
    score-weighted voting.

    ``proactive_free_blocks`` (default 0 = off, the paper's setting):
    under continuous batching, prune the lowest-scored running trace
    *before* the pool actually runs dry — whenever admission pressure
    exists (waiting traces or queued requests) and the free pool has
    fallen below the margin. This trades a little trace budget for TTFT
    of queued arrivals; keep it 0 to reproduce the paper's reactive
    behaviour exactly. A trace is only judged proactively once it shows
    a step score or has decoded ``proactive_min_tokens`` tokens.
    """

    proactive_free_blocks: int = 0
    proactive_min_tokens: int = 16

    name = "step"
    uses_scorer = True

    def traces_to_terminate(self, running: Sequence[Trace]) -> List[Trace]:
        p = self.last_pressure
        if (self.proactive_free_blocks <= 0 or p is None
                or p.demand == 0
                or p.reclaimable_blocks >= self.proactive_free_blocks):
            return []
        cands = [t for t in running if t.alive
                 and (t.step_scores
                      or t.num_tokens >= self.proactive_min_tokens)]
        if len(cands) <= 1:
            return []
        return [min(cands, key=lambda t: t.score)]

    def on_memory_full(self, running: Sequence[Trace],
                       pressure: Optional[AdmissionPressure] = None
                       ) -> Optional[Trace]:
        candidates = [t for t in running if t.alive]
        if not candidates:
            return None
        return min(candidates, key=lambda t: t.score)

    def vote(self, traces: Sequence[Trace]) -> Optional[str]:
        return weighted_vote([t.answer for t in traces],
                             [t.score for t in traces])


@dataclasses.dataclass
class DeepConfPolicy(PruningPolicy):
    """DeepConf-low (Fu et al., 2025): warmup N_init traces offline, set the
    confidence threshold retaining the top ``keep_pct`` traces, terminate
    later traces falling below it; confidence-weighted vote."""

    warmup: int = 16
    keep_pct: float = 0.10
    min_tokens: int = 32  # don't judge traces before any signal exists

    name = "deepconf"
    uses_scorer = False

    def __post_init__(self):
        self.threshold: Optional[float] = None
        self._warmup_confs: List[float] = []

    def record_warmup(self, traces: Sequence[Trace]) -> None:
        self._warmup_confs = [t.confidence for t in traces]
        if self._warmup_confs:
            self.threshold = float(np.quantile(
                self._warmup_confs, 1.0 - self.keep_pct))

    def traces_to_terminate(self, running: Sequence[Trace]) -> List[Trace]:
        if self.threshold is None:
            return []
        return [t for t in running
                if t.num_tokens >= self.min_tokens
                and t.confidence < self.threshold]

    def vote(self, traces: Sequence[Trace]) -> Optional[str]:
        return weighted_vote([t.answer for t in traces],
                             [t.confidence for t in traces])


@dataclasses.dataclass
class SlimSCPolicy(PruningPolicy):
    """Slim-SC (Hong et al., 2025), Random-Pruning variant: periodically
    measure inter-trace similarity at the thought level and prune one of
    any pair above the threshold."""

    threshold: float = 0.95
    check_every: int = 64   # tokens between similarity sweeps
    ngram: int = 4
    seed: int = 0

    name = "slimsc"
    uses_scorer = False

    def __post_init__(self):
        self._rng = random.Random(self.seed)
        self._last_check: dict = {}

    @staticmethod
    def _ngrams(tokens: List[int], n: int) -> set:
        return {tuple(tokens[i:i + n]) for i in range(len(tokens) - n + 1)}

    def similarity(self, a: Trace, b: Trace) -> float:
        ga = self._ngrams(a.output_tokens, self.ngram)
        gb = self._ngrams(b.output_tokens, self.ngram)
        if not ga or not gb:
            return 0.0
        return len(ga & gb) / len(ga | gb)

    def traces_to_terminate(self, running: Sequence[Trace]) -> List[Trace]:
        live = [t for t in running if t.alive and t.num_tokens
                >= self.check_every]
        due = [t for t in live if t.num_tokens
               - self._last_check.get(t.trace_id, 0) >= self.check_every]
        if not due:
            return []
        for t in live:
            self._last_check[t.trace_id] = t.num_tokens
        doomed: List[Trace] = []
        for i, a in enumerate(live):
            for b in live[i + 1:]:
                if a in doomed or b in doomed:
                    continue
                if self.similarity(a, b) > self.threshold:
                    doomed.append(self._rng.choice((a, b)))
        return doomed


def make_policy(name: str, **kw) -> PruningPolicy:
    table = {
        "cot": SingleTrace,
        "sc": SelfConsistency,
        "step": StepPolicy,
        "deepconf": DeepConfPolicy,
        "slimsc": SlimSCPolicy,
    }
    return table[name](**kw)
