"""Reasoning-step segmentation (paper §4.1, "Step Representation").

The paper extracts content between <think> and </think> and segments into
steps at tokens whose text contains "\n\n". We mirror that at both levels:

  * string level  — split_steps(text) for dataset/label construction;
  * token level   — StepBoundaryDetector marks boundary token ids so the
    engine can invoke the scorer exactly when a step-end token is emitted.
"""
from __future__ import annotations

import dataclasses
from typing import List, Sequence, Set

THINK_OPEN = "<think>"
THINK_CLOSE = "</think>"
STEP_DELIM = "\n\n"


def extract_think(text: str) -> str:
    """Content between <think> and </think> (whole text if no markers)."""
    start = text.find(THINK_OPEN)
    if start < 0:
        body = text
    else:
        body = text[start + len(THINK_OPEN):]
    end = body.find(THINK_CLOSE)
    return body if end < 0 else body[:end]


def split_steps(text: str) -> List[str]:
    """Segment reasoning content into steps at "\n\n" (paper footnote 1)."""
    steps = [s for s in extract_think(text).split(STEP_DELIM) if s.strip()]
    return steps


@dataclasses.dataclass
class StepBoundaryDetector:
    """Token-level boundary detection for online scoring.

    boundary_ids: ids of tokens whose text contains "\n\n" (paper: "any
    token whose text contains \\n\\n").
    think_close_id: emission of </think> ends the scored region.
    """
    boundary_ids: Set[int]
    think_close_id: int = -1

    def __post_init__(self):
        self.boundary_ids = set(self.boundary_ids)
        self._in_think: dict = {}

    def is_boundary(self, token_id: int) -> bool:
        return token_id in self.boundary_ids

    def boundaries(self, token_ids: Sequence[int]) -> List[int]:
        """Indices of step-end tokens within the thinking region."""
        out = []
        for i, t in enumerate(token_ids):
            if t == self.think_close_id:
                break
            if t in self.boundary_ids:
                out.append(i)
        return out
