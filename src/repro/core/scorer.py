"""The STEP step scorer (paper §4.1, Appendix A).

A 2-layer MLP ``d_model -> 512 (ReLU) -> 1`` over last-layer hidden states
at reasoning-step boundaries, trained with class-balanced weighted BCE
(alpha = K^- / K^+) on trace-level correctness pseudo-labels propagated to
every step.
"""
from __future__ import annotations

import dataclasses
from typing import Optional, Tuple

import jax
import jax.numpy as jnp
import numpy as np

from repro.training.optimizer import AdamW


SCORER_HIDDEN = 512  # paper Appendix A: Input -> 512 (ReLU) -> 1


def init_scorer(rng: jax.Array, d_model: int,
                hidden: int = SCORER_HIDDEN) -> dict:
    k1, k2 = jax.random.split(rng)
    return {
        "w1": jax.random.normal(k1, (d_model, hidden), jnp.float32)
        * (2.0 / d_model) ** 0.5,
        "b1": jnp.zeros((hidden,), jnp.float32),
        "w2": jax.random.normal(k2, (hidden, 1), jnp.float32)
        * (1.0 / hidden) ** 0.5,
        "b2": jnp.zeros((1,), jnp.float32),
    }


def scorer_logits(params: dict, h: jax.Array) -> jax.Array:
    """h [..., D] -> pre-sigmoid logits [...]."""
    z = jax.nn.relu(h.astype(jnp.float32) @ params["w1"] + params["b1"])
    return (z @ params["w2"] + params["b2"])[..., 0]


def scorer_score(params: dict, h: jax.Array) -> jax.Array:
    """Correctness probability in [0, 1]."""
    return jax.nn.sigmoid(scorer_logits(params, h))


def weighted_bce_loss(params: dict, h: jax.Array, y: jax.Array,
                      alpha: float) -> jax.Array:
    """Paper Eq. (loss): -(1/N) sum alpha*y*log p + (1-y)*log(1-p),
    numerically stable logits form (BCEWithLogits)."""
    logits = scorer_logits(params, h)
    yf = y.astype(jnp.float32)
    log_p = jax.nn.log_sigmoid(logits)
    log_1mp = jax.nn.log_sigmoid(-logits)
    return -jnp.mean(alpha * yf * log_p + (1 - yf) * log_1mp)


@dataclasses.dataclass
class ScorerTrainConfig:
    """Paper Table 5 hyper-parameters."""
    batch_size: int = 128
    max_epochs: int = 20
    patience: int = 5
    learning_rate: float = 1e-4
    weight_decay: float = 1e-5
    val_fraction: float = 0.1
    seed: int = 0


def train_scorer(hiddens: np.ndarray, labels: np.ndarray,
                 cfg: Optional[ScorerTrainConfig] = None,
                 params: Optional[dict] = None,
                 verbose: bool = False) -> Tuple[dict, dict]:
    """Train the step scorer. hiddens [M, D]; labels [M] in {0,1}
    (step pseudo-labels = trace correctness). Returns (params, info)."""
    cfg = cfg or ScorerTrainConfig()
    rng = np.random.RandomState(cfg.seed)
    M, D = hiddens.shape
    perm = rng.permutation(M)
    hiddens, labels = hiddens[perm], labels[perm]
    n_val = max(1, int(M * cfg.val_fraction))
    hv, yv = jnp.asarray(hiddens[:n_val]), jnp.asarray(labels[:n_val])
    ht, yt = hiddens[n_val:], labels[n_val:]

    k_pos = max(int((yt == 1).sum()), 1)
    k_neg = max(int((yt == 0).sum()), 1)
    alpha = k_neg / k_pos  # paper: ratio of negative to positive samples

    if params is None:
        params = init_scorer(jax.random.PRNGKey(cfg.seed), D)
    opt = AdamW(learning_rate=cfg.learning_rate,
                weight_decay=cfg.weight_decay, grad_clip=None)
    opt_state = opt.init(params)

    @jax.jit
    def step(params, opt_state, hb, yb):
        loss, grads = jax.value_and_grad(weighted_bce_loss)(
            params, hb, yb, alpha)
        params, opt_state = opt.update(grads, opt_state, params)
        return params, opt_state, loss

    @jax.jit
    def val_loss(params):
        return weighted_bce_loss(params, hv, yv, alpha)

    best_val, best_params, bad_epochs = np.inf, params, 0
    history = []
    n_train = len(ht)
    for epoch in range(cfg.max_epochs):
        order = rng.permutation(n_train)
        losses = []
        for i in range(0, n_train, cfg.batch_size):
            idx = order[i:i + cfg.batch_size]
            params, opt_state, loss = step(
                params, opt_state, jnp.asarray(ht[idx]), jnp.asarray(yt[idx]))
            losses.append(float(loss))
        vl = float(val_loss(params))
        history.append({"epoch": epoch, "train_loss": float(np.mean(losses)),
                        "val_loss": vl})
        if verbose:
            print(f"scorer epoch {epoch}: train={np.mean(losses):.4f} "
                  f"val={vl:.4f}")
        if vl < best_val - 1e-5:
            best_val, best_params, bad_epochs = vl, params, 0
        else:
            bad_epochs += 1
            if bad_epochs >= cfg.patience:  # early stopping (paper: 5)
                break
    info = {"alpha": alpha, "best_val_loss": best_val, "history": history}
    return best_params, info


def rank_accuracy(scores_pos: np.ndarray, scores_neg: np.ndarray) -> float:
    """Pairwise RankAcc (paper §5.3.2): P[s(p) > s(n)] over all pairs."""
    if len(scores_pos) == 0 or len(scores_neg) == 0:
        return float("nan")
    sp = scores_pos[:, None]
    sn = scores_neg[None, :]
    return float(np.mean((sp > sn) + 0.5 * (sp == sn)))
