"""The full STEP pipeline, end to end (paper §5.1 "Implementation Details"):

  1. train a reasoning LM on the synthetic verifiable task;
  2. sample N solutions per training problem from THAT model;
  3. verify each with the deterministic rule-based verifier;
  4. balance correct/incorrect traces, extract last-layer hidden states at
     every "\n\n" step boundary, propagate the trace label to all steps;
  5. train the 2-layer-MLP step scorer with class-weighted BCE.

Hidden states are collected teacher-forced (one forward over the sampled
trace). By the decode==full-forward invariant (tests/test_decode_
consistency.py) these are bit-compatible with what the engine's fused
scorer sees at decode time.
"""
from __future__ import annotations

import dataclasses
import random
from typing import List, Optional, Sequence, Tuple

import jax
import jax.numpy as jnp
import numpy as np

from repro.configs.base import ModelConfig
from repro.core.scorer import ScorerTrainConfig, train_scorer
from repro.data.arithmetic import Problem, gen_problem, make_prompt, verify
from repro.data.tokenizer import get_tokenizer
from repro.models.model import forward_full
from repro.serving.sampling import sample_tokens, SamplingParams


@dataclasses.dataclass
class SampledTrace:
    problem: Problem
    token_ids: List[int]     # prompt + completion
    prompt_len: int
    text: str                # decoded completion
    answer: Optional[str]
    correct: bool


def generate_batch(params: dict, cfg: ModelConfig,
                   prompts: Sequence[List[int]], max_new: int,
                   rng: jax.Array,
                   sp: Optional[SamplingParams] = None) -> List[List[int]]:
    """Free-running batched sampling with a dense (non-paged) KV cache.

    Used by the data pipeline, where throughput matters more than the
    paged-pool semantics the engine exists to study.
    """
    from repro.models.model import decode_step, init_decode_cache, \
        write_prefill_kv

    sp = sp or SamplingParams()
    tok = get_tokenizer()
    B = len(prompts)
    plen = max(len(p) for p in prompts)
    toks = np.full((B, plen), tok.pad_id, np.int32)
    for i, p in enumerate(prompts):
        toks[i, plen - len(p):] = p  # left-pad so last position aligns
    capacity = plen + max_new
    cache = init_decode_cache(cfg, B, capacity)
    out = forward_full(params, cfg, jnp.asarray(toks), return_kv=True)
    cache = write_prefill_kv(cfg, cache, out["kvs"],
                             jnp.full((B,), plen, jnp.int32))
    V = cfg.vocab_size
    logits = out["logits"][:, -1].at[:, V:].set(-jnp.inf)
    rng, k = jax.random.split(rng)
    cur, _ = sample_tokens(k, logits, temperature=sp.temperature,
                           top_k=sp.top_k, top_p=sp.top_p)
    completions = [[int(cur[i])] for i in range(B)]
    positions = np.full((B,), plen, np.int32)
    done = np.zeros((B,), bool)

    from functools import partial

    @partial(jax.jit, donate_argnums=(1,))
    def step(params, cache, cur, positions, k):
        o = decode_step(params, cfg, cur[:, None], positions, cache,
                        window_len=capacity)
        lg = o["logits"].at[:, V:].set(-jnp.inf)
        nt, _ = sample_tokens(k, lg, temperature=sp.temperature,
                              top_k=sp.top_k, top_p=sp.top_p)
        return nt, o["cache"]

    for _ in range(max_new - 1):
        rng, k = jax.random.split(rng)
        cur, cache = step(params, cache, jnp.asarray(cur),
                          jnp.asarray(positions), k)
        positions += 1
        curn = np.asarray(cur)
        for i in range(B):
            if not done[i]:
                completions[i].append(int(curn[i]))
                if int(curn[i]) == tok.eos_id:
                    done[i] = True
        if done.all():
            break
    # trim at eos
    trimmed = []
    for comp in completions:
        if tok.eos_id in comp:
            comp = comp[:comp.index(tok.eos_id) + 1]
        trimmed.append(comp)
    return trimmed


def sample_traces(params: dict, cfg: ModelConfig, problems: List[Problem],
                  n_samples: int, max_new: int = 96, seed: int = 0,
                  batch: int = 32) -> List[SampledTrace]:
    """Sample ``n_samples`` solutions per problem and verify each."""
    tok = get_tokenizer()
    rng = jax.random.PRNGKey(seed)
    jobs = [(p, tok.encode(make_prompt(p), add_bos=True))
            for p in problems for _ in range(n_samples)]
    out: List[SampledTrace] = []
    for i in range(0, len(jobs), batch):
        chunk = jobs[i:i + batch]
        rng, k = jax.random.split(rng)
        comps = generate_batch(params, cfg, [c[1] for c in chunk],
                               max_new, k)
        for (p, prompt), comp in zip(chunk, comps):
            text = tok.decode(comp)
            ans, ok = verify(p, text)
            out.append(SampledTrace(
                problem=p, token_ids=prompt + comp, prompt_len=len(prompt),
                text=text, answer=ans, correct=ok))
    return out


def balance_traces(traces: List[SampledTrace], per_class: int,
                   seed: int = 0) -> List[SampledTrace]:
    """Paper A.2: randomly select equal numbers of correct/incorrect."""
    rng = random.Random(seed)
    pos = [t for t in traces if t.correct]
    neg = [t for t in traces if not t.correct]
    rng.shuffle(pos)
    rng.shuffle(neg)
    n = min(per_class, len(pos), len(neg))
    sel = pos[:n] + neg[:n]
    rng.shuffle(sel)
    return sel


def collect_boundary_hiddens(params: dict, cfg: ModelConfig,
                             traces: List[SampledTrace], batch: int = 16
                             ) -> Tuple[np.ndarray, np.ndarray, np.ndarray]:
    """Last-layer hidden state of every "\n\n" boundary token, with the
    trace label propagated to every step (paper Label Construction)."""
    tok = get_tokenizer()
    if not traces:
        return (np.zeros((0, cfg.d_model), np.float32),
                np.zeros((0,), np.int32), np.zeros((0,), np.int32))
    S = max(len(t.token_ids) for t in traces)
    hs, ys, tids = [], [], []
    for i in range(0, len(traces), batch):
        chunk = traces[i:i + batch]
        toks = np.full((len(chunk), S), tok.pad_id, np.int32)
        for j, t in enumerate(chunk):
            toks[j, :len(t.token_ids)] = t.token_ids
        out = forward_full(params, cfg, jnp.asarray(toks))
        hidden = np.asarray(out["hidden"], np.float32)
        for j, t in enumerate(chunk):
            stop = len(t.token_ids)
            ids = t.token_ids
            if tok.think_close_id in ids:
                stop = ids.index(tok.think_close_id)
            for pos in range(t.prompt_len, stop):
                if ids[pos] == tok.step_id:
                    hs.append(hidden[j, pos])
                    ys.append(int(t.correct))
                    tids.append(i + j)
    if not hs:
        return (np.zeros((0, cfg.d_model), np.float32),
                np.zeros((0,), np.int32), np.zeros((0,), np.int32))
    return np.stack(hs), np.array(ys, np.int32), np.array(tids, np.int32)


def build_step_scorer(params: dict, cfg: ModelConfig,
                      n_problems: int = 48, n_samples: int = 8,
                      per_class: int = 64, seed: int = 0,
                      scfg: Optional[ScorerTrainConfig] = None,
                      n_steps=(5, 9),
                      verbose: bool = False):
    """Run pipeline steps 2-5. Returns (scorer_params, info).
    ``n_steps`` matches the benchmark difficulty (paper trains the scorer
    on the same competition distribution it serves)."""
    rng = random.Random(seed)
    problems = [gen_problem(rng, n_steps) for _ in range(n_problems)]
    traces = sample_traces(params, cfg, problems, n_samples, seed=seed)
    n_pos = sum(t.correct for t in traces)
    sel = balance_traces(traces, per_class, seed=seed)
    if verbose:
        print(f"  sampled {len(traces)} traces: {n_pos} correct, "
              f"{len(traces) - n_pos} incorrect; training on {len(sel)}")
    h, y, tid = collect_boundary_hiddens(params, cfg, sel)
    if len(h) < 8 or len(set(y.tolist())) < 2:
        # model too weak/strong to give both classes: fall back to rendered
        # corrupted traces (documented deviation, keeps the pipeline total)
        from repro.data.dataset import scorer_dataset
        h, y, tid = scorer_dataset(
            params, cfg,
            lambda p, t: forward_full(p, cfg, t)["hidden"],
            num_traces=4 * per_class, seed=seed)
        fallback = True
    else:
        fallback = False
    scorer_params, info = train_scorer(h, y, scfg, verbose=verbose)
    info.update(num_steps=len(h), pos_rate=float(np.mean(y)),
                sampled_correct_rate=n_pos / max(len(traces), 1),
                fallback_rendered=fallback)
    return scorer_params, info
