"""Token sampling (temperature / top-k / top-p) + confidence extraction.

``sample_logits`` is the scan-compatible core: a plain traceable function
(no ``jax.jit`` wrapper, no device sync) so the fused multi-token decode
horizon can call it inside a ``lax.scan`` body once per iteration.
``sample_tokens`` is the jitted convenience wrapper the host-side code
paths (prefill first-token sampling) keep using; both produce bit-identical
samples for the same key.
"""
from __future__ import annotations

import dataclasses
from functools import partial

import jax
import jax.numpy as jnp


@dataclasses.dataclass(frozen=True)
class SamplingParams:
    temperature: float = 0.8
    top_k: int = 20
    top_p: float = 0.95
    max_new_tokens: int = 160


def sample_logits(rng: jax.Array, logits: jax.Array, *,
                  temperature: float = 0.8, top_k: int = 20,
                  top_p: float = 0.95):
    """logits [B, V] -> (tokens [B], confidence [B]); scan-compatible.

    Confidence = probability the model assigned to the sampled token under
    the UNtempered distribution (the DeepConf-style signal).
    ``temperature`` / ``top_k`` / ``top_p`` must be Python scalars (they
    select the lowered graph, not traced values).

    ``temperature <= 0`` is exact greedy: a deterministic argmax that
    ignores the key entirely. (Scaling logits by 1/eps and sampling
    would break exact logit ties by the per-call gumbel noise, making
    "greedy" outputs depend on how many keys the caller consumed — e.g.
    on the decode horizon.)
    """
    logits_f = logits.astype(jnp.float32)
    base_logp = jax.nn.log_softmax(logits_f, axis=-1)

    if temperature <= 0.0:
        tokens = jnp.argmax(logits_f, axis=-1)
        conf = jnp.exp(jnp.take_along_axis(base_logp, tokens[:, None],
                                           axis=1))[:, 0]
        return tokens.astype(jnp.int32), conf

    scaled = logits_f / jnp.maximum(temperature, 1e-6)
    if top_k > 0 and top_k < logits.shape[-1]:
        kth = jnp.sort(scaled, axis=-1)[:, -top_k][:, None]
        scaled = jnp.where(scaled < kth, -jnp.inf, scaled)
    if 0.0 < top_p < 1.0:
        sorted_logits = jnp.sort(scaled, axis=-1)[:, ::-1]
        probs = jax.nn.softmax(sorted_logits, axis=-1)
        cum = jnp.cumsum(probs, axis=-1)
        cutoff_idx = jnp.sum(cum < top_p, axis=-1)
        cutoff = jnp.take_along_axis(sorted_logits, cutoff_idx[:, None],
                                     axis=1)
        scaled = jnp.where(scaled < cutoff, -jnp.inf, scaled)

    tokens = jax.random.categorical(rng, scaled, axis=-1)
    conf = jnp.exp(jnp.take_along_axis(base_logp, tokens[:, None],
                                       axis=1))[:, 0]
    return tokens.astype(jnp.int32), conf


@partial(jax.jit, static_argnames=("temperature", "top_k", "top_p"))
def sample_tokens(rng: jax.Array, logits: jax.Array, *,
                  temperature: float = 0.8, top_k: int = 20,
                  top_p: float = 0.95):
    """Jitted wrapper over ``sample_logits`` (host-side call sites)."""
    return sample_logits(rng, logits, temperature=temperature,
                         top_k=top_k, top_p=top_p)


def sample_logits_lanes(rng: jax.Array, logits: jax.Array,
                        temperature: jax.Array, top_k: jax.Array,
                        top_p: jax.Array):
    """Lane-wise ``sample_logits``: per-row sampling params as TRACED
    [B] arrays (the per-request ``SamplingParams`` override path — one
    jit instance serves every parameter mix).

    The math mirrors the scalar path op-for-op per lane — same
    softmax/sort/cumsum order, same cutoff comparisons — so a lane
    whose (temperature, top_k, top_p) equal the scalar call's values
    draws the identical token for the same key. Greedy lanes
    (``temperature <= 0``) are an exact argmax that ignores the key,
    matching the scalar contract; disabled filters (``top_k <= 0`` or
    ``>= V``, ``top_p`` outside (0, 1)) pass logits through unmasked.
    """
    B, V = logits.shape
    logits_f = logits.astype(jnp.float32)
    base_logp = jax.nn.log_softmax(logits_f, axis=-1)
    temperature = temperature.astype(jnp.float32)[:, None]
    top_p = top_p.astype(jnp.float32)[:, None]

    scaled = logits_f / jnp.maximum(temperature, 1e-6)

    # top-k: k-th largest value per lane via an ascending sort (the
    # scalar path's sort[:, -k]); lanes with the filter disabled keep
    # their logits (cutoff -inf)
    sorted_asc = jnp.sort(scaled, axis=-1)
    k_idx = jnp.clip(V - top_k, 0, V - 1).astype(jnp.int32)
    kth = jnp.take_along_axis(sorted_asc, k_idx[:, None], axis=1)
    k_on = ((top_k > 0) & (top_k < V))[:, None]
    kth = jnp.where(k_on, kth, -jnp.inf)
    scaled = jnp.where(scaled < kth, -jnp.inf, scaled)

    # top-p: smallest prefix of the descending-sorted distribution with
    # cumulative mass >= top_p (same cumsum-cutoff as the scalar path)
    sorted_desc = sorted_asc[:, ::-1]
    sorted_desc = jnp.where(sorted_desc < kth, -jnp.inf, sorted_desc)
    probs = jax.nn.softmax(sorted_desc, axis=-1)
    cum = jnp.cumsum(probs, axis=-1)
    cutoff_idx = jnp.sum(cum < top_p, axis=-1).astype(jnp.int32)
    cutoff = jnp.take_along_axis(sorted_desc, cutoff_idx[:, None], axis=1)
    p_on = (top_p > 0.0) & (top_p < 1.0)
    cutoff = jnp.where(p_on, cutoff, -jnp.inf)
    scaled = jnp.where(scaled < cutoff, -jnp.inf, scaled)

    sampled = jax.random.categorical(rng, scaled, axis=-1)
    greedy = jnp.argmax(logits_f, axis=-1)
    tokens = jnp.where(temperature[:, 0] <= 0.0, greedy, sampled)
    conf = jnp.exp(jnp.take_along_axis(base_logp, tokens[:, None],
                                       axis=1))[:, 0]
    return tokens.astype(jnp.int32), conf


@jax.jit
def sample_tokens_lanes(rng: jax.Array, logits: jax.Array,
                        temperature: jax.Array, top_k: jax.Array,
                        top_p: jax.Array):
    """Jitted wrapper over ``sample_logits_lanes``."""
    return sample_logits_lanes(rng, logits, temperature, top_k, top_p)
