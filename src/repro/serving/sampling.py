"""Token sampling (temperature / top-k / top-p) + confidence extraction."""
from __future__ import annotations

import dataclasses
from functools import partial

import jax
import jax.numpy as jnp


@dataclasses.dataclass(frozen=True)
class SamplingParams:
    temperature: float = 0.8
    top_k: int = 20
    top_p: float = 0.95
    max_new_tokens: int = 160


@partial(jax.jit, static_argnames=("temperature", "top_k", "top_p"))
def sample_tokens(rng: jax.Array, logits: jax.Array, *,
                  temperature: float = 0.8, top_k: int = 20,
                  top_p: float = 0.95):
    """logits [B, V] -> (tokens [B], confidence [B]).

    Confidence = probability the model assigned to the sampled token under
    the UNtempered distribution (the DeepConf-style signal).
    """
    logits_f = logits.astype(jnp.float32)
    base_logp = jax.nn.log_softmax(logits_f, axis=-1)

    scaled = logits_f / jnp.maximum(temperature, 1e-6)
    if top_k > 0 and top_k < logits.shape[-1]:
        kth = jnp.sort(scaled, axis=-1)[:, -top_k][:, None]
        scaled = jnp.where(scaled < kth, -jnp.inf, scaled)
    if 0.0 < top_p < 1.0:
        sorted_logits = jnp.sort(scaled, axis=-1)[:, ::-1]
        probs = jax.nn.softmax(sorted_logits, axis=-1)
        cum = jnp.cumsum(probs, axis=-1)
        cutoff_idx = jnp.sum(cum < top_p, axis=-1)
        cutoff = jnp.take_along_axis(sorted_logits, cutoff_idx[:, None],
                                     axis=1)
        scaled = jnp.where(scaled < cutoff, -jnp.inf, scaled)

    tokens = jax.random.categorical(rng, scaled, axis=-1)
    conf = jnp.exp(jnp.take_along_axis(base_logp, tokens[:, None],
                                       axis=1))[:, 0]
    return tokens.astype(jnp.int32), conf
