"""Online request queue for the continuous-batching scheduler.

Requests carry an ``arrival_time`` (seconds relative to the start of the
serve loop). The queue releases a request to the scheduler only once the
engine clock passes its arrival time, which is what turns ``serve_batch``
from an offline batch runner into an online-serving simulation: the
scheduler admits work wave by wave as it arrives, decode keeps running
between waves, and time-to-first-token is measured against the arrival
instant rather than the batch start.

Ordering: requests are released in (arrival_time, submission index)
order, so two requests arriving at the same instant keep their
submission order — with every arrival at t=0 the scheduler sees exactly
the PR-1 ``serve_batch`` admission sequence.
"""
from __future__ import annotations

from typing import List, Optional, Sequence


def _arrival(request) -> float:
    """A request's arrival time; missing/None means immediately."""
    return getattr(request, "arrival_time", 0.0) or 0.0


class RequestQueue:
    """Arrival-ordered queue of not-yet-started requests."""

    def __init__(self, requests: Sequence = ()):
        # stable sort on arrival time alone: requests sharing an arrival
        # instant keep their submission order
        self._pending: List = sorted(requests, key=_arrival)

    def __len__(self) -> int:
        return len(self._pending)

    def __bool__(self) -> bool:
        return bool(self._pending)

    def push(self, request) -> None:
        """Insert a late submission, keeping arrival order."""
        at = _arrival(request)
        i = 0
        while i < len(self._pending) and _arrival(self._pending[i]) <= at:
            i += 1
        self._pending.insert(i, request)

    def next_arrival(self) -> Optional[float]:
        """Arrival time of the earliest pending request (None if empty)."""
        if not self._pending:
            return None
        return _arrival(self._pending[0])

    def pop_arrived(self, now: float) -> List:
        """Release every request whose arrival time has passed."""
        out: List = []
        while self._pending and _arrival(self._pending[0]) <= now:
            out.append(self._pending.pop(0))
        return out
