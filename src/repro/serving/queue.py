"""Online request queue for the continuous-batching scheduler.

Requests carry an ``arrival_time`` (seconds relative to the start of the
serve loop). The queue releases a request to the scheduler only once the
engine clock passes its arrival time, which is what turns ``serve_batch``
from an offline batch runner into an online-serving simulation: the
scheduler admits work wave by wave as it arrives, decode keeps running
between waves, and time-to-first-token is measured against the arrival
instant rather than the batch start.

Ordering: requests are released in (arrival_time, submission index)
order, so two requests arriving at the same instant keep their
submission order — with every arrival at t=0 the scheduler sees exactly
the PR-1 ``serve_batch`` admission sequence.

Implementation: a binary heap keyed on (arrival_time, submission index).
``push`` and ``pop_arrived`` are O(log n) per request; the previous
sorted-list implementation paid O(n) per ``push`` (insertion scan) and
per pop (``list.pop(0)`` shifts the tail), which the 10-100x larger load
scenarios turned into measurable scheduler overhead. The submission
index in the key is what preserves the stable-ordering contract above —
heaps are not otherwise stable (pinned by
``tests/test_continuous_batching.py::test_request_queue_ordering``).
"""
from __future__ import annotations

import heapq
import itertools
from typing import List, Optional, Sequence


def _arrival(request) -> float:
    """A request's arrival time; missing/None means immediately."""
    return getattr(request, "arrival_time", 0.0) or 0.0


class RequestQueue:
    """Arrival-ordered queue of not-yet-started requests."""

    def __init__(self, requests: Sequence = ()):
        self._count = itertools.count()  # submission index (tie-break)
        self._heap: List = [(_arrival(r), next(self._count), r)
                            for r in requests]
        heapq.heapify(self._heap)

    def __len__(self) -> int:
        return len(self._heap)

    def __bool__(self) -> bool:
        return bool(self._heap)

    def push(self, request) -> None:
        """Insert a late submission, keeping arrival order."""
        heapq.heappush(self._heap,
                       (_arrival(request), next(self._count), request))

    def next_arrival(self) -> Optional[float]:
        """Arrival time of the earliest pending request (None if empty)."""
        if not self._heap:
            return None
        return self._heap[0][0]

    def pop_arrived(self, now: float) -> List:
        """Release every request whose arrival time has passed."""
        out: List = []
        while self._heap and self._heap[0][0] <= now:
            out.append(heapq.heappop(self._heap)[2])
        return out

    def remove(self, request_id) -> Optional[object]:
        """Withdraw a not-yet-arrived request (cancellation before
        admission). O(n) scan + re-heapify — cancellation is rare.
        Returns the removed request, or None if absent."""
        for i, (_, _, req) in enumerate(self._heap):
            if getattr(req, "request_id", None) == request_id:
                entry = self._heap[i]
                self._heap[i] = self._heap[-1]
                self._heap.pop()
                heapq.heapify(self._heap)
                return entry[2]
        return None
