"""Deterministic fault injection + recovery policy for the serving stack.

The engine's determinism pins (kernel==dense, K==1, cache on==off, mesh==
single) are also its recovery levers: a degraded engine produces the SAME
tokens, so fault handling can be tested bit-exactly. This module supplies
the *plan* (what to inject, when), the *policy* (how many retries, when to
degrade or abort), and the *ledger* (what happened); the scheduler core
owns the actual retry/degrade control flow.

Fault taxonomy (see docs/ENGINE.md "Failure handling"):

  * ``step``  — a simulated device-step failure (kernel dispatch error).
    Raised BEFORE the device call so no RNG is consumed and the donated
    KV cache is untouched; a retry is therefore bit-identical. Transient
    runs are absorbed by capped-backoff retries; persistent runs walk
    the degrade ladder (kernel→dense, decode_horizon K→1) and finally
    abort the serve.
  * ``alloc`` — the block allocator reports "full" for a window of
    scheduler rounds. The core stalls the round (no admission, no
    decode) rather than invoking memory-pressure pruning, so transient
    shortages leave surviving lanes bit-identical; persistent shortages
    shed trace fan-out via the SLO degrade machinery and finally abort.
  * ``nan``   — one lane's host-synced confidences are poisoned with NaN
    after the device call (device state untouched). The quarantine path
    in ``_on_burst_done`` terminates the lane with ``TraceStatus.FAILED``
    and the other lanes never see it.

Plans are seeded and replayable: ``FaultPlan.reset()`` re-arms every spec,
and the scheduler core calls it at the start of each serve, so the same
plan perturbs every serve of an engine identically.

Spec-string grammar (``--faults`` / ``REPRO_FAULTS``)::

    plan  := spec ("," spec)*
    spec  := kind "@" tick ["x" count] [":" key "=" value]
    kind  := "step" | "alloc" | "nan"
    key   := "req" | "slot"

Examples: ``step@3`` (one step fault at tick >= 3), ``step@3x5`` (five
consecutive failed attempts — enough to exhaust retries and trigger one
degrade rung), ``alloc@4x2`` (allocator reports full during ticks 4-5),
``nan@6:req=1`` (poison request 1's first running lane at tick 6).
"""
from __future__ import annotations

import dataclasses
import random
from typing import List, Optional, Sequence


class DeviceStepFault(RuntimeError):
    """An injected, retryable device-step failure."""


class FatalFaultError(RuntimeError):
    """Recovery exhausted: retries and every degrade rung failed."""


_KINDS = ("step", "alloc", "nan")


@dataclasses.dataclass
class FaultSpec:
    """One injection trigger.

    ``tick`` arms the spec once the scheduler clock reaches it; ``count``
    is the number of firings (``step``/``nan``) or the width of the
    blocked-tick window (``alloc``). ``slot``/``request_id`` narrow a
    ``nan`` fault to a victim lane; with neither, the plan's seeded RNG
    picks among the running lanes.
    """

    kind: str
    tick: int
    count: int = 1
    slot: Optional[int] = None
    request_id: Optional[int] = None

    def __post_init__(self):
        if self.kind not in _KINDS:
            raise ValueError(f"unknown fault kind {self.kind!r}; "
                             f"expected one of {_KINDS}")
        if self.tick < 0 or self.count < 1:
            raise ValueError(f"fault spec needs tick >= 0 and count >= 1, "
                             f"got tick={self.tick} count={self.count}")


def _parse_spec(text: str) -> FaultSpec:
    head, _, opt = text.partition(":")
    kind, at, when = head.partition("@")
    if not at:
        raise ValueError(f"bad fault spec {text!r}: expected kind@tick")
    when, _, mult = when.partition("x")
    try:
        tick = int(when)
        count = int(mult) if mult else 1
    except ValueError:
        raise ValueError(f"bad fault spec {text!r}: tick/count must be "
                         f"integers") from None
    slot = request_id = None
    if opt:
        key, eq, val = opt.partition("=")
        if not eq or key not in ("req", "slot"):
            raise ValueError(f"bad fault spec {text!r}: option must be "
                             f"req=<id> or slot=<n>")
        try:
            if key == "req":
                request_id = int(val)
            else:
                slot = int(val)
        except ValueError:
            raise ValueError(f"bad fault spec {text!r}: {key} must be an "
                             f"integer") from None
    return FaultSpec(kind=kind.strip(), tick=tick, count=count,
                     slot=slot, request_id=request_id)


@dataclasses.dataclass
class RecoveryConfig:
    """Retry/degrade/abort policy knobs (engine defaults)."""

    retry_limit: int = 3          # failed attempts absorbed per ladder rung
    backoff_base_s: float = 0.001
    backoff_cap_s: float = 0.02
    shed_after: int = 2           # consecutive alloc-stalled rounds -> shed
    abort_after: int = 8          # consecutive alloc-stalled rounds -> abort

    def backoff(self, attempt: int) -> float:
        """Capped exponential backoff for the ``attempt``-th failure."""
        return min(self.backoff_base_s * (2 ** max(attempt - 1, 0)),
                   self.backoff_cap_s)


@dataclasses.dataclass
class FaultStats:
    """Ledger of injections and recovery actions over an engine lifetime."""

    step_faults: int = 0          # injected step failures observed
    step_retries: int = 0         # retries issued (<= step_faults)
    recovered_steps: int = 0      # device calls that succeeded after >=1 fault
    degraded_to_dense: int = 0    # kernel -> dense ladder rung taken
    degraded_horizon: int = 0     # decode_horizon K -> 1 rung taken
    alloc_faults: int = 0         # rounds stalled by injected alloc failure
    shed_traces: int = 0          # fan-out shed by the persistent-alloc rung
    nan_quarantined: int = 0      # lanes terminated by NaN/Inf quarantine
    cancelled: int = 0            # requests released via Engine.cancel
    deadline_exceeded: int = 0    # requests released via Request.deadline
    aborted: int = 0              # serves aborted after recovery exhaustion
    integrity_audits: int = 0     # check_integrity sweeps run

    def snapshot(self) -> dict:
        return dataclasses.asdict(self)


class FaultPlan:
    """A seeded, replayable schedule of fault injections.

    The plan is consulted by the scheduler core at its device boundaries:
    ``maybe_step_fault`` before each prefill/chunk-prefill/decode call,
    ``alloc_blocked`` at the top of each budget round, ``nan_victims``
    after each decode burst's host sync. ``reset`` re-arms everything so
    the identical perturbation replays on the next serve.
    """

    def __init__(self, specs: Sequence[FaultSpec], seed: int = 0):
        self.specs: List[FaultSpec] = list(specs)
        self.seed = seed
        self.reset()

    @classmethod
    def parse(cls, text: str, seed: int = 0) -> "FaultPlan":
        """Build a plan from the ``--faults``/``REPRO_FAULTS`` grammar."""
        specs = [_parse_spec(part.strip())
                 for part in text.split(",") if part.strip()]
        if not specs:
            raise ValueError(f"empty fault plan {text!r}")
        return cls(specs, seed=seed)

    def reset(self) -> None:
        """Re-arm every spec (called at the start of each serve)."""
        self._remaining: List[int] = [s.count for s in self.specs]
        self._rng = random.Random(self.seed)

    # -- step faults ---------------------------------------------------
    def maybe_step_fault(self, tick: int) -> None:
        """Raise ``DeviceStepFault`` if an armed step spec covers ``tick``.

        Armed specs fire on every query from ``spec.tick`` onward until
        their count drains, which keeps multi-failure runs contiguous
        even when the scheduler clock skips ticks.
        """
        for i, spec in enumerate(self.specs):
            if (spec.kind == "step" and self._remaining[i] > 0
                    and tick >= spec.tick):
                self._remaining[i] -= 1
                raise DeviceStepFault(
                    f"injected device-step fault (spec {spec.kind}@"
                    f"{spec.tick}, {self._remaining[i]} left)")

    # -- allocation faults ---------------------------------------------
    def alloc_blocked(self, tick: int) -> bool:
        """True while ``tick`` falls in an alloc spec's blocked window."""
        return any(s.kind == "alloc" and s.tick <= tick < s.tick + s.count
                   for s in self.specs)

    # -- NaN poisoning -------------------------------------------------
    def nan_victims(self, tick: int, running: Sequence[tuple]) -> List[int]:
        """Slots to poison this burst. ``running`` is a list of
        ``(slot, request_id)`` pairs for the live lanes, in slot order;
        each armed nan spec picks at most one victim per burst."""
        victims: List[int] = []
        for i, spec in enumerate(self.specs):
            if (spec.kind != "nan" or self._remaining[i] <= 0
                    or tick < spec.tick):
                continue
            pool = [s for s, rid in running
                    if (spec.slot is None or s == spec.slot)
                    and (spec.request_id is None or rid == spec.request_id)]
            if not pool:
                continue  # victim not running yet; stay armed
            self._remaining[i] -= 1
            victims.append(pool[0] if (spec.slot is not None or
                                       spec.request_id is not None)
                           else self._rng.choice(pool))
        return victims

    def __repr__(self) -> str:
        parts = []
        for s in self.specs:
            p = f"{s.kind}@{s.tick}"
            if s.count != 1:
                p += f"x{s.count}"
            if s.request_id is not None:
                p += f":req={s.request_id}"
            if s.slot is not None:
                p += f":slot={s.slot}"
            parts.append(p)
        return f"FaultPlan({','.join(parts)!r}, seed={self.seed})"
