"""Event-driven scheduler core + pluggable scheduling policies.

This module is the host-side brain of ``Engine.serve_batch``: the
PR-2..6 monolithic tick loop, refactored into an explicit event loop
(``SchedulerCore``) over pluggable ``SchedulingPolicy`` objects, fully
decoupled from the device-step execution that stays in ``engine.py``
(the jitted prefill / chunked-prefill / fused-decode / COW-copy steps,
which the core drives through the engine handle it is constructed
with).

Event loop
----------

Every state transition happens in an event handler; ``run`` is only the
pump that synthesizes the next event when the queue drains:

  * ``Arrival``          — the request queue released a request (its
    arrival time passed): its traces enter the waiting pool.
  * ``BudgetReplenish``  — a scheduling round begins: per-tick token
    budgets are replenished (weighted deficit round-robin under a
    tenant policy), DeepConf gates update, ``AdmissionPressure`` is
    published to every active pruning policy, and the admission wave
    runs (SLO admission control, chunked prefills, prefix forks).
  * ``ChunkDone``        — one chunked-prefill chunk landed on device.
  * ``BurstDone``        — the fused decode burst for this round
    synced back to the host: per-trace outputs/scores are folded in,
    EOS/limit lanes finish, signal-triggered termination sweeps run.
  * ``Completion``       — a request's last trace finished/pruned: its
    ``RequestResult`` is streamed to the ``on_complete`` callback.

Events are delivered FIFO and synchronously (the loop is
single-threaded and deterministic); with the default FIFO policy the
handler cascade executes the exact operation sequence of the old tick
loop, so the event core is token/score/prune-identical to it under a
fixed RNG (pinned in tests/test_scheduler.py).

Scheduling policies
-------------------

``FIFOPolicy`` (the default) reproduces the single-queue behaviour:
arrival-ordered admission, one global per-tick token budget
(``EngineConfig.max_tokens_per_step``), last-arrived preemption
victims, no SLO admission control.

``TenantScheduler`` adds SLO-aware multi-tenant serving on top of the
same core:

  * **weighted fair token budgets** — the per-tick token pool is dealt
    to tenants by weighted deficit round-robin (``DeficitRoundRobin``):
    every round each *active* tenant's deficit counter grows by its
    weight share of the pool, decode/prefill tokens are charged to the
    owning tenant, and admission stalls for tenants whose deficit ran
    dry. A lone tenant always holds the whole pool, so the policy
    degenerates to ``FIFOPolicy`` exactly.
  * **priority admission** — waiting traces are picked by
    ``(priority, deficit)`` (stable within a class, so equal-priority
    single-tenant batches keep FIFO order).
  * **SLO admission control** — when a request's projected TTFT
    (elapsed wait + prefill backlog over the observed token rate)
    violates its ``SLO``, the policy *degrades* its trace fan-out
    (sheds ``n_traces`` down to ``SLO.min_traces`` — STEP's
    test-time-scaling quality dial) or, with ``SLO.shed`` set, rejects
    the request outright.
  * **over-budget preemption** — when the pool is exhausted and the
    pruning policy declines (baselines), the preemption victim is the
    last-arrived running trace of the *most over-budget* tenant
    (lowest deficit), routed through the existing preempt/recompute
    and evict-before-prune machinery.
"""
from __future__ import annotations

import dataclasses
import math
import os
import time
from collections import deque
from typing import (TYPE_CHECKING, Callable, Dict, List, Mapping, Optional,
                    Sequence, Tuple)

import jax
import jax.numpy as jnp
import numpy as np

from repro.core.pruning import AdmissionPressure, DeepConfPolicy
from repro.core.trace import Trace, TraceStatus
from repro.data.arithmetic import extract_answer
from repro.serving.faults import DeviceStepFault, FatalFaultError
from repro.serving.queue import RequestQueue

if TYPE_CHECKING:  # engine imports scheduler; never the reverse at runtime
    from repro.serving.engine import Engine, Request


@dataclasses.dataclass
class SharedPrefix:
    """Per-request artifact of the shared prompt prefill."""
    blocks: List[int]           # holder's own references (freed at req end)
    seq_len: int
    last_logits: jax.Array      # [1, Vp] vocab-masked last-position logits
    slot_state: Optional[tuple]  # (ssm, conv) end state for ssm/hybrid


# ---------------------------------------------------------------------------
# SLO + events
# ---------------------------------------------------------------------------

@dataclasses.dataclass(frozen=True)
class SLO:
    """Per-request service-level objective.

    ``ttft_s`` drives admission control under a ``TenantScheduler``:
    when the projected time-to-first-token exceeds it, the request's
    trace fan-out is degraded towards ``min_traces`` (quality for
    latency — the paper's dial), and with ``shed`` set a projection
    beyond ``shed_factor * ttft_s`` rejects the request outright (all
    traces shed, answer ``None``). ``tpot_s`` is an attainment target
    only (reported per tenant by ``metrics.summarize_by_tenant``, never
    enforced mid-decode).
    """

    ttft_s: Optional[float] = None
    tpot_s: Optional[float] = None
    min_traces: int = 1
    shed: bool = False
    shed_factor: float = 4.0


@dataclasses.dataclass
class Event:
    """Base scheduler event (``t`` is seconds since the serve-loop
    start)."""
    t: float


@dataclasses.dataclass
class Arrival(Event):
    request_id: int
    n_traces: int


@dataclasses.dataclass
class BudgetReplenish(Event):
    tick: int
    budget_limit: Optional[int]   # None = unlimited


@dataclasses.dataclass
class ChunkDone(Event):
    request_id: int
    pos: int          # prompt tokens prefilled so far
    total: int        # prompt length
    chunk_tokens: int


@dataclasses.dataclass
class BurstDone(Event):
    tick: int
    n_lanes: int
    tokens: int       # emitted tokens across lanes this burst


@dataclasses.dataclass
class Completion(Event):
    request_id: int


@dataclasses.dataclass
class Cancelled(Event):
    """A request left the scheduler before finishing: released by
    ``Engine.cancel`` or by its ``Request.deadline`` expiring."""
    request_id: int
    reason: str       # "cancelled" | "deadline_exceeded"


# ---------------------------------------------------------------------------
# token budgets
# ---------------------------------------------------------------------------

class TokenBudget:
    """Per-round token budget (``EngineConfig.max_tokens_per_step``).

    Decode consumes one token per running trace per horizon iteration
    before prefill work is scheduled; ``spend`` charges prefill tokens
    when they are computed. ``force`` lets ``can`` approve the round's
    first prefill even beyond the limit when nothing is decoding —
    otherwise a prompt longer than the budget could never start.
    ``tenant`` is accepted (and ignored) so tenant-aware subclasses can
    charge per-tenant deficits through the same call sites.
    """

    def __init__(self, limit: Optional[int]):
        self.left = limit  # None = unlimited
        self.spent_any = False

    def can(self, n_tokens: int, force: bool = False,
            tenant: Optional[str] = None) -> bool:
        if self.left is None or self.left >= n_tokens:
            return True
        return force and not self.spent_any

    def spend(self, n_tokens: int, tenant: Optional[str] = None) -> None:
        self.spent_any = True
        if self.left is not None:
            self.left = max(self.left - n_tokens, 0)


class DeficitRoundRobin:
    """Weighted deficit round-robin over a shared token pool.

    Each ``replenish(active, pool)`` round deals ``pool`` tokens to the
    active tenants proportionally to their weights; ``charge`` spends a
    tenant's deficit (it may go negative when the core force-approves
    work, the standard DRR debt convention). Deficits are capped at
    ``burst_rounds`` full rounds of that tenant's quantum so an idle
    tenant cannot hoard unbounded credit.
    """

    def __init__(self, weights: Optional[Mapping[str, float]] = None,
                 default_weight: float = 1.0, burst_rounds: float = 4.0):
        self.weights = dict(weights or {})
        self.default_weight = float(default_weight)
        self.burst_rounds = float(burst_rounds)
        self.deficit: Dict[str, float] = {}

    def weight(self, tenant: str) -> float:
        return float(self.weights.get(tenant, self.default_weight))

    def reset(self) -> None:
        self.deficit.clear()

    def replenish(self, active: Sequence[str], pool: float) -> None:
        active = list(dict.fromkeys(active))  # de-dup, keep order
        total_w = sum(self.weight(t) for t in active)
        if total_w <= 0:
            return
        for t in active:
            quantum = pool * self.weight(t) / total_w
            cap = self.burst_rounds * max(quantum, pool / max(len(active), 1))
            self.deficit[t] = min(self.deficit.get(t, 0.0) + quantum, cap)

    def charge(self, tenant: str, n_tokens: float) -> None:
        self.deficit[tenant] = self.deficit.get(tenant, 0.0) - n_tokens

    def balance(self, tenant: str) -> float:
        return self.deficit.get(tenant, 0.0)


class WeightedTokenBudget(TokenBudget):
    """Global per-round budget + per-tenant DRR deficits.

    A spend is approved only when both the global pool and the owning
    tenant's deficit cover it (``force`` keeps the first-prefill escape
    hatch of the base class and may drive a deficit negative — DRR
    debt that later rounds repay)."""

    def __init__(self, limit: Optional[int], drr: DeficitRoundRobin):
        super().__init__(limit)
        self.drr = drr

    def can(self, n_tokens: int, force: bool = False,
            tenant: Optional[str] = None) -> bool:
        globally = self.left is None or self.left >= n_tokens
        fairly = tenant is None or self.drr.balance(tenant) >= n_tokens
        if globally and fairly:
            return True
        return force and not self.spent_any

    def spend(self, n_tokens: int, tenant: Optional[str] = None) -> None:
        super().spend(n_tokens)
        if tenant is not None:
            self.drr.charge(tenant, n_tokens)


# ---------------------------------------------------------------------------
# scheduling policies
# ---------------------------------------------------------------------------

class SchedulingPolicy:
    """Pluggable scheduler brain: admission order, per-round token
    budgets, SLO admission control and preemption victim selection.

    The base class IS the FIFO policy: its defaults reproduce the
    pre-refactor tick loop exactly (arrival-ordered admission, one
    global token budget, last-arrived preemption, no shedding), which
    is what pins the event core token-identical to it.
    """

    name = "fifo"

    def reset(self) -> None:
        """Called once per ``serve_batch`` run before any event."""

    def on_event(self, event: Event) -> None:
        """Observer hook: every scheduler event passes through here."""

    def tick_budget(self, core: "SchedulerCore") -> TokenBudget:
        """Budget for one scheduling round. Decode may emit up to
        ``decode_horizon`` tokens per running trace this round; they
        are charged pessimistically up front."""
        mts = core.ecfg.max_tokens_per_step
        limit = (None if mts is None
                 else max(mts - len(core.running) * core.K_cfg, 0))
        return TokenBudget(limit)

    def pick(self, core: "SchedulerCore", skipped: set) -> Optional[Trace]:
        """Next waiting trace to consider for admission (None = wave
        over). FIFO: first admissible trace in arrival order."""
        return next(
            (t for t in core.waiting
             if t.request_id not in skipped
             and core.by_req[t.request_id].admissible(t)), None)

    def target_traces(self, core: "SchedulerCore", st) -> int:
        """SLO admission control: how many traces this request may fan
        out into (checked once, at its first admission attempt).
        FIFO never sheds."""
        return len(st.traces)

    def preempt_victim(self, core: "SchedulerCore",
                       needy: Optional[Trace]) -> Optional[Trace]:
        """Running trace to preempt when memory is full and the pruning
        policy declined. ``None`` means the needy trace is the lone
        runner and must truncate-finish instead. FIFO/vLLM: the
        last-arrived running trace."""
        running = core.running
        victim = running[-1]
        if victim is needy:
            if len(running) == 1:
                return None
            victim = running[-2]
        return victim

    def pressure_extras(self, core: "SchedulerCore") -> dict:
        """Extra ``AdmissionPressure`` fields (tenant demand/deficits
        under a tenant policy; nothing for FIFO)."""
        return {}


class FIFOPolicy(SchedulingPolicy):
    """Alias of the base policy, for explicit construction."""


class TenantScheduler(SchedulingPolicy):
    """SLO-aware multi-tenant scheduling policy (see module docstring).

    ``weights`` maps tenant name -> fair-share weight (unknown tenants
    get ``default_weight``). With a single tenant, equal priorities and
    no SLOs this policy is behaviour-identical to ``FIFOPolicy`` —
    pinned by tests and by the ``REPRO_SCHED=tenant`` CI lane, which
    runs the whole engine suite through it.
    """

    name = "tenant"

    def __init__(self, weights: Optional[Mapping[str, float]] = None,
                 default_weight: float = 1.0, burst_rounds: float = 4.0):
        self.drr = DeficitRoundRobin(weights, default_weight=default_weight,
                                     burst_rounds=burst_rounds)

    def reset(self) -> None:
        self.drr.reset()

    # -- weighted fair budgets -------------------------------------------
    def tick_budget(self, core: "SchedulerCore") -> TokenBudget:
        mts = core.ecfg.max_tokens_per_step
        if mts is None:
            # unlimited pool: fairness acts through admission order only.
            # A plain unlimited budget (not a weighted one): deficits are
            # never replenished without a per-step pool, so gating on
            # them would starve every non-forced admission and diverge
            # from FIFO — the reduction contract this policy pins.
            return TokenBudget(None)
        self.drr.replenish(core.active_tenants(), mts)
        for trace in core.running:   # pessimistic decode charge
            self.drr.charge(core.tenant_of(trace.request_id), core.K_cfg)
        limit = max(mts - len(core.running) * core.K_cfg, 0)
        return WeightedTokenBudget(limit, self.drr)

    # -- priority + deficit admission order ------------------------------
    def pick(self, core: "SchedulerCore", skipped: set) -> Optional[Trace]:
        best, best_key = None, None
        for t in core.waiting:
            if t.request_id in skipped:
                continue
            st = core.by_req[t.request_id]
            if not st.admissible(t):
                continue
            key = (getattr(st.req, "priority", 0),
                   self.drr.balance(core.tenant_of(t.request_id)))
            if best is None or key > best_key:  # stable: first wins ties
                best, best_key = t, key
        return best

    # -- SLO admission control --------------------------------------------
    def target_traces(self, core: "SchedulerCore", st) -> int:
        n = len(st.traces)
        slo: Optional[SLO] = getattr(st.req, "slo", None)
        if slo is None or slo.ttft_s is None:
            return n
        now_rel = time.perf_counter() - core.t_start
        waited = max(now_rel - st.req.arrival_time, 0.0)
        rate = core.token_rate()
        backlog = core.prefill_backlog_tokens() + len(st.req.prompt_tokens)
        projected = waited + (backlog / rate if rate > 0 else 0.0)
        if projected <= slo.ttft_s:
            return n
        if slo.shed and projected > slo.shed_factor * max(slo.ttft_s, 1e-9):
            return 0
        frac = slo.ttft_s / projected if projected > 0 else 0.0
        return max(min(slo.min_traces, n), int(n * frac))

    # -- over-budget preemption -------------------------------------------
    def preempt_victim(self, core: "SchedulerCore",
                       needy: Optional[Trace]) -> Optional[Trace]:
        candidates = [t for t in core.running if t is not needy]
        if not candidates:
            return None  # lone needy runner: truncate-finish
        # most over-budget tenant first (lowest deficit), last-arrived
        # within it (>= keeps the latest trace on ties — the FIFO victim)
        victim = candidates[0]
        victim_bal = self.drr.balance(core.tenant_of(victim.request_id))
        for t in candidates[1:]:
            bal = self.drr.balance(core.tenant_of(t.request_id))
            if bal <= victim_bal:
                victim, victim_bal = t, bal
        return victim

    def pressure_extras(self, core: "SchedulerCore") -> dict:
        demand: Dict[str, int] = {}
        for t in core.waiting:
            tenant = core.tenant_of(t.request_id)
            demand[tenant] = demand.get(tenant, 0) + 1
        return {"demand_by_tenant": demand,
                "deficit_by_tenant": dict(self.drr.deficit)}


def parse_tenant_weights(spec: str) -> Dict[str, float]:
    """Parse ``name:weight,name:weight`` (the ``--tenant-weights`` CLI
    syntax) into a weights mapping. A bare ``name`` means weight 1.0;
    malformed entries and non-positive weights raise ``ValueError``
    rather than silently becoming weight-1 tenants."""
    weights: Dict[str, float] = {}
    for part in spec.split(","):
        part = part.strip()
        if not part:
            continue
        name, sep, w = part.partition(":")
        name = name.strip()
        if not name or "=" in name:
            raise ValueError(
                f"bad tenant spec {part!r}: expected NAME[:WEIGHT]")
        weight = float(w) if sep else 1.0  # float('') -> ValueError
        if weight <= 0:
            raise ValueError(f"tenant {name!r}: weight must be > 0, "
                             f"got {weight}")
        weights[name] = weight
    if not weights:
        raise ValueError(f"empty tenant-weights spec {spec!r}")
    return weights


def default_scheduler() -> Optional[SchedulingPolicy]:
    """Scheduler from the ``REPRO_SCHED`` env var: unset/"fifo" ->
    None (the engine builds a FIFOPolicy per run), "tenant" -> a
    TenantScheduler with default weights. The CI ``test-scheduler``
    lane sets ``REPRO_SCHED=tenant`` to run the whole engine suite
    through the tenant policy's FIFO-reduction path."""
    val = os.environ.get("REPRO_SCHED", "").strip().lower()
    if val in ("", "fifo", "none"):
        return None
    if val == "tenant":
        return TenantScheduler()
    raise ValueError(f"REPRO_SCHED must be 'fifo' or 'tenant', got {val!r}")


# ---------------------------------------------------------------------------
# per-request scheduler state
# ---------------------------------------------------------------------------

class ReqState:
    """Scheduler-side bookkeeping for one in-flight request."""

    def __init__(self, req: "Request", policy, traces: List[Trace],
                 sampling=None, max_new_tokens: Optional[int] = None):
        self.req = req
        self.policy = policy
        self.traces = traces
        # effective per-request generation knobs (engine defaults filled
        # in by serve_batch; None only until then)
        self.sampling = sampling
        self.max_new = max_new_tokens
        self.prefix: Optional[SharedPrefix] = None
        self.prefill_s = 0.0
        self.decode_s = 0.0
        self.t_done: Optional[float] = None
        self.warmup_recorded = not isinstance(policy, DeepConfPolicy)
        # prefix-cache accounting: one probe per request; a hit holds
        # forked block references until a PrefillJob takes them over
        self.cache_probed = False
        self.cache_hit: Optional[Tuple[List[int], int]] = None
        self.cached_tokens = 0
        # SLO admission control: checked once, at first admission attempt
        self.slo_checked = False
        self.degraded_traces = 0
        # online-serving timestamps (absolute perf_counter seconds)
        self.arrived = False
        self.admit_t: Optional[float] = None
        self.first_token_t: Optional[float] = None
        self.result = None           # Optional[RequestResult]
        # lifecycle outcome, copied onto RequestResult/RequestMetrics:
        # "completed" | "cancelled" | "deadline_exceeded" | "failed"
        self.final_status = "completed"

    @property
    def request_id(self) -> int:
        return self.req.request_id

    @property
    def tenant(self) -> str:
        return getattr(self.req, "tenant", "default") or "default"

    def note_first_token(self) -> None:
        if self.first_token_t is None:
            self.first_token_t = time.perf_counter()

    def admissible(self, trace: Trace) -> bool:
        """DeepConf online: traces beyond the warmup set wait until the
        warmup traces finished and the threshold exists."""
        if self.warmup_recorded:
            return True
        return trace.trace_id < self.policy.warmup

    def update_gate(self) -> None:
        if self.warmup_recorded:
            return
        warm = self.traces[:self.policy.warmup]
        if all(not t.alive for t in warm):
            self.policy.record_warmup(
                [t for t in warm if t.status == TraceStatus.FINISHED])
            self.warmup_recorded = True

    def done(self) -> bool:
        return all(not t.alive for t in self.traces)


class PrefillJob:
    """An in-flight chunked prompt prefill (shared-prefix path).

    Holds a chunk-granular block reservation: blocks already taken carry
    completed chunks' KV; the job draws more as chunks land and commits
    the full set into the request's ``SharedPrefix`` when the prompt is
    exhausted. ``abort`` (memory pressure) returns every block; the
    prefill restarts from scratch on the next admission attempt.

    A prefix-cache hit seeds the job with ``base_blocks`` (forked cached
    blocks covering the first ``base_tokens`` prompt tokens): the prefill
    starts at ``pos = base_tokens`` and only computes the suffix. Chunk
    boundaries stay on the absolute ``chunk``-token grid so the suffix
    chunks are the exact chunks a cold prefill would have run. ``eager``
    jobs (cache hit on an engine configured for one-shot prefill) run
    all their chunks in one round instead of interleaving with decode.
    """

    def __init__(self, st: ReqState, reservation, blocks_per_seq: int,
                 chunk: int, base_blocks: Sequence[int] = (),
                 base_tokens: int = 0, eager: bool = False):
        self.st = st
        self.tokens: List[int] = list(st.req.prompt_tokens)
        self.pos = base_tokens
        self.chunk = chunk
        self.eager = eager
        self.base: List[int] = list(base_blocks)
        self.res = reservation
        self.row = np.zeros((blocks_per_seq,), np.int32)
        self.row[:len(self.base)] = self.base
        self.last_logits = None

    @property
    def request_id(self) -> int:
        return self.st.request_id

    @property
    def done(self) -> bool:
        return self.pos >= len(self.tokens)

    def abort(self) -> None:
        self.res.abort()
        if self.base:
            # drop the forked cache references; the cached blocks stay
            # parked in the trie. The restart prefills from scratch, so
            # the request's hit accounting is rolled back too.
            self.res.mgr.free(self.base)
            self.base = []
            self.st.cached_tokens = 0


# ---------------------------------------------------------------------------
# the event-driven core
# ---------------------------------------------------------------------------

class SchedulerCore:
    """One ``serve_batch`` run: event pump + handlers over the engine's
    device steps.

    The core owns all scheduling state (queues, slots, block tables,
    budgets); the engine owns the device state (params, KV pools, jitted
    steps, the RNG) and exposes it through the handle passed here —
    ``eng._prefill`` / ``eng._chunk_prefill`` / ``eng.decode_fn`` /
    ``eng._copy_block`` / ``eng.sample_host(_lanes)`` plus the block
    manager and prefix cache. The split is what makes scheduling
    policies pluggable without touching jitted code.
    """

    def __init__(self, eng: "Engine", states: List[ReqState],
                 t_start: float,
                 on_complete: Optional[Callable] = None,
                 sched: Optional[SchedulingPolicy] = None):
        self.eng = eng
        self.ecfg = eng.ecfg
        self.cfg = eng.cfg
        self.tok = eng.tok
        self.states = states
        self.t_start = t_start
        self.on_complete = on_complete
        self.sched = sched if sched is not None else FIFOPolicy()
        self.sched.reset()

        ecfg, cfg = self.ecfg, self.cfg
        self.B = ecfg.max_batch
        self.bs = cfg.kv_block_size
        self.cap = ecfg.capacity
        self.share = ecfg.share_prompt_prefix
        self.chunk = (ecfg.prefill_chunk_size
                      if eng._chunk_supported else None)
        self.mgr = eng.block_mgr
        self.pcache = eng.prefix_cache
        self.K_cfg = ecfg.decode_horizon

        self.by_req: Dict[int, ReqState] = {st.request_id: st
                                            for st in states}
        assert len(self.by_req) == len(states), \
            "duplicate request_id in batch"
        self.pending = RequestQueue([st.req for st in states])
        self.started: List[ReqState] = []

        B, bps = self.B, eng.blocks_per_seq
        self.block_tables = np.zeros((B, bps), np.int32)
        self.positions = np.zeros((B,), np.int32)
        self.cur_tokens = np.zeros((B,), np.int32)
        # Device-resident mirrors of the decode-state arrays. The host
        # copies above stay authoritative for scheduling math; the
        # device copies are re-uploaded only when a host-side event
        # (admission, COW/frontier repoint, release) dirties them.
        self.dev = {"tokens": None, "positions": None, "block_tables": None}
        self.dirty = {"tokens": True, "positions": True,
                      "block_tables": True}
        # per-lane sampling params: only uploaded (and only consumed by
        # the lane-wise decode step) when any request in the batch
        # overrides the engine-global SamplingParams
        sp = ecfg.sampling
        self.mixed_sampling = any(st.sampling != sp for st in states)
        self.samp = {
            "temperature": np.full((B,), sp.temperature, np.float32),
            "top_k": np.full((B,), sp.top_k, np.int32),
            "top_p": np.full((B,), sp.top_p, np.float32),
        }
        self.samp_dev = None
        self.samp_dirty = True

        self.free_slots = list(range(B))
        self.running: List[Trace] = []
        self.waiting: List[Trace] = []
        self.jobs: Dict[int, PrefillJob] = {}  # request_id -> prefill

        self.cache = eng._take_kv_cache()
        self.peak_blocks = 0
        self.idle_ticks = 0   # consecutive no-progress rounds
        self.tick = 0
        self._tokens_done = 0  # prefill + decode tokens (rate estimate)

        # fault tolerance: the engine's injection plan (None = no
        # injection), recovery policy, and cumulative stats ledger
        self.plan = eng.fault_plan
        self.recovery = eng.recovery
        self.stats = eng.fault_stats
        self._alloc_stalls = 0    # consecutive allocator-stalled rounds
        self._fanout_shed = False  # persistent-alloc shed rung taken

        self.events: deque = deque()
        self.event_log: deque = deque(maxlen=4096)

    # -- policy-facing views ------------------------------------------------
    def tenant_of(self, request_id: int) -> str:
        return self.by_req[request_id].tenant

    def active_tenants(self) -> List[str]:
        return [st.tenant for st in self.started if not st.done()]

    def token_rate(self) -> float:
        """Observed engine token rate (prefill + decode tokens per
        second since the loop started); 0.0 before any signal exists so
        SLO projections never act on a cold estimate."""
        if self._tokens_done < 1:
            return 0.0
        elapsed = time.perf_counter() - self.t_start
        return self._tokens_done / max(elapsed, 1e-6)

    def prefill_backlog_tokens(self) -> int:
        """Prompt tokens arrived but not yet prefilled (SLO projection
        input)."""
        total = 0
        for st in self.started:
            if st.done() or st.prefix is not None:
                continue
            pos = (self.jobs[st.request_id].pos
                   if st.request_id in self.jobs else 0)
            total += max(len(st.req.prompt_tokens) - pos, 0)
        return total

    # -- event plumbing -----------------------------------------------------
    def emit(self, event: Event) -> None:
        self.events.append(event)

    def _notify(self, event: Event) -> None:
        self.event_log.append(event)
        self.sched.on_event(event)

    def _now_rel(self) -> float:
        return time.perf_counter() - self.t_start

    def has_work(self) -> bool:
        return bool(self.pending or self.waiting or self.running
                    or self.jobs)

    # ------------------------------------------------------------------
    # the pump
    # ------------------------------------------------------------------
    def run(self) -> int:
        """Pump events until every request completes. Returns the
        pool-wide peak block usage."""
        handlers = {
            Arrival: self._on_arrival,
            BudgetReplenish: self._on_budget_replenish,
            ChunkDone: self._on_chunk_done,
            BurstDone: self._on_burst_done,
            Completion: self._on_completion,
            Cancelled: self._on_cancelled,
        }
        while True:
            if not self.events:
                if not self.has_work():
                    break
                self._pump()
                continue
            event = self.events.popleft()
            self._notify(event)
            try:
                handlers[type(event)](event)
            except FatalFaultError:
                # retries and every degrade rung exhausted: fail the
                # remaining requests, drain the pool, keep the engine
                # usable — the loop then exits with no work left
                self.abort_serve()

        for job in list(self.jobs.values()):  # defensive: no job survives
            job.abort()
        self.jobs.clear()
        for st in self.states:  # defensive: no prefix may outlive its batch
            self.release_prefix(st)
        self.eng._stash_kv_cache(self.cache)
        return self.peak_blocks

    def _pump(self) -> None:
        """Synthesize the next event: released arrivals first, then a
        scheduling round if anything is runnable, otherwise sleep until
        the next arrival is due."""
        now_rel = self._now_rel()
        if self._sweep_cancellations(now_rel):
            return
        arrived = self.pending.pop_arrived(now_rel)
        if arrived:
            for req in arrived:
                self.emit(Arrival(t=now_rel, request_id=req.request_id,
                                  n_traces=req.n_traces))
            return
        if self.waiting or self.running or self.jobs:
            mts = self.ecfg.max_tokens_per_step
            self.tick += 1
            self.emit(BudgetReplenish(t=now_rel, tick=self.tick,
                                      budget_limit=mts))
            return
        nxt = self.pending.next_arrival()
        if nxt is not None:
            time.sleep(min(max(nxt - now_rel, 0.0), 0.02) + 1e-4)

    # ------------------------------------------------------------------
    # handlers
    # ------------------------------------------------------------------
    def _on_arrival(self, ev: Arrival) -> None:
        st = self.by_req[ev.request_id]
        st.arrived = True
        self.started.append(st)
        for t in st.traces:
            t.status = TraceStatus.WAITING
            # wait_time counts only MEMORY-induced waiting (paper
            # Table 3): the clock starts at preemption or at a
            # memory-blocked admission attempt, not at arrival.
            t.runnable_since = -1.0
        self.waiting.extend(st.traces)

    def _on_completion(self, ev: Completion) -> None:
        st = self.by_req[ev.request_id]
        if self.on_complete is not None and st.result is not None:
            self.on_complete(st.result)

    def _on_chunk_done(self, ev: ChunkDone) -> None:
        self._tokens_done += ev.chunk_tokens

    def _on_budget_replenish(self, ev: BudgetReplenish) -> None:
        """One scheduling round: gates -> pressure -> admission wave ->
        write-block assurance -> decode dispatch."""
        if self.plan is not None and self.plan.alloc_blocked(ev.tick):
            # injected allocator outage: STALL the whole round (no
            # admission, no decode) instead of reaching the memory-
            # pressure machinery — a transient outage must not shift
            # prune/preempt decisions, so survivors stay bit-identical.
            # Persistent outages degrade (shed fan-out) and then abort.
            self.stats.alloc_faults += 1
            self._alloc_stalls += 1
            if self._alloc_stalls == self.recovery.shed_after:
                self.shed_fanout()
                self.audit()
            if self._alloc_stalls >= self.recovery.abort_after:
                raise FatalFaultError(
                    f"allocator unavailable for {self._alloc_stalls} "
                    f"consecutive rounds")
            time.sleep(self.recovery.backoff(self._alloc_stalls))
            return
        self._alloc_stalls = 0
        for st in self.started:
            st.update_gate()
        pressure = self.current_pressure()
        for st in self.started:
            if not st.done():
                st.policy.observe_pressure(pressure)

        budget = self.sched.tick_budget(self)
        progressed = self.try_admit(budget)
        if not self.running:
            if not (self.waiting or self.jobs or self.pending):
                return
            if progressed:
                self.idle_ticks = 0
                return
            if self.pending:
                # arrivals still due: wait for them (not a deadlock)
                nxt = self.pending.next_arrival()
                now_rel = self._now_rel()
                if nxt is not None and nxt > now_rel:
                    time.sleep(min(nxt - now_rel, 0.02) + 1e-4)
                return
            self.idle_ticks += 1
            if self.idle_ticks >= 3:
                raise RuntimeError("no trace schedulable")
            return
        self.idle_ticks = 0
        self._dispatch_decode(ev)

    # ------------------------------------------------------------------
    # pool accounting + memory pressure (ported from the tick loop)
    # ------------------------------------------------------------------
    def note_peak(self) -> None:
        self.peak_blocks = max(self.peak_blocks, self.mgr.used_blocks)

    def release_prefix(self, st: ReqState, park: bool = True) -> None:
        """Drop the request's shared-prefix holder references. With
        the prefix cache on, the prompt's full blocks are parked in
        the trie for cross-request reuse instead of freed; the
        partial tail block (written by this request's own prefill)
        is never shared and always returns to the pool. ``park=False``
        (memory reclaim) frees everything outright."""
        if st.prefix is None:
            return
        blocks, n_tok = st.prefix.blocks, st.prefix.seq_len
        st.prefix = None
        if park and self.pcache is not None and n_tok >= self.bs:
            n_full = n_tok // self.bs
            self.pcache.insert(st.req.prompt_tokens, blocks[:n_full])
            if blocks[n_full:]:
                self.mgr.free(blocks[n_full:])
        else:
            self.mgr.free(blocks)

    def evict_for(self, n: int) -> bool:
        """Free-list headroom for ``n`` blocks, reclaiming LRU
        prefix-cache blocks on demand — parked KV is the cheapest
        memory in the pool (a reuse opportunity, not live compute),
        so it always goes before any trace is pruned/preempted."""
        if self.mgr.can_allocate(n):
            return True
        if self.pcache is not None:
            self.pcache.evict(n - self.mgr.free_blocks)
        return self.mgr.can_allocate(n)

    def release(self, trace: Trace, status: TraceStatus) -> None:
        if trace.blocks:
            self.mgr.free(trace.blocks)
            trace.blocks = []
        if trace.batch_slot >= 0:
            s = trace.batch_slot
            self.block_tables[s, :] = self.mgr.scratch_block
            self.positions[s] = 0
            self.dirty["block_tables"] = self.dirty["positions"] = True
            self.cache = self.eng._clear_slot_state(self.cache, s)
            self.free_slots.append(s)
            trace.batch_slot = -1
        trace.status = status
        if trace in self.running:
            self.running.remove(trace)
        st = self.by_req[trace.request_id]
        if st.done():
            self.release_prefix(st)
            if st.t_done is None:
                st.t_done = time.perf_counter()
            if st.result is None:
                st.result = self.eng._finalize(st, self.t_start, st.t_done,
                                               self.peak_blocks)
                self.emit(Completion(t=self._now_rel(),
                                     request_id=st.request_id))

    def reclaim_idle_prefix(self, skip_rid: int) -> bool:
        """Free shared-prefix blocks of requests with no running
        trace (their waiting traces recompute on readmission). Never
        touches ``skip_rid``: freeing the needy request's own prefix
        would report progress while undoing its admission work (an
        admit/prefill livelock)."""
        before = self.mgr.free_blocks
        live = {t.request_id for t in self.running}
        live.add(skip_rid)
        for st in self.started:
            if st.prefix is not None and st.request_id not in live:
                # reclaim must FREE, not park: parking would report
                # no free-list progress and fall through to
                # preemption with reusable blocks still held
                self.release_prefix(st, park=False)
        return self.mgr.free_blocks > before

    def abort_other_jobs(self, skip_rid: int) -> bool:
        """Cancel other requests' in-flight chunked prefills, freeing
        their partially-reserved blocks (they restart later). Only
        the decode path calls this — admission-time aborts could
        livelock two prefilling requests against each other."""
        freed = False
        for rid in list(self.jobs):
            if rid != skip_rid and self.jobs[rid].res.num_taken > 0:
                self.jobs.pop(rid).abort()
                freed = True
        return freed

    def current_pressure(self) -> AdmissionPressure:
        pcache = self.pcache
        return AdmissionPressure(
            waiting_traces=len(self.waiting),
            queued_requests=len(self.pending),
            free_blocks=self.mgr.free_blocks,
            total_blocks=self.ecfg.num_blocks - 1,
            cached_blocks=(pcache.cached_blocks
                           if pcache is not None else 0),
            evictable_blocks=(pcache.evictable_blocks
                              if pcache is not None else 0),
            degraded=(self.eng.force_horizon1 or self._fanout_shed
                      or self.stats.degraded_to_dense > 0),
            bytes_per_block=self.mgr.bytes_per_block,
            **self.sched.pressure_extras(self))

    def handle_memory_full(self, needy: Optional[Trace], rid: int,
                           at_admission: bool = False) -> bool:
        """Pool has no free block. Returns True if progress was made.

        STEP: the needy request's policy prunes its lowest-scored
        running trace, freeing its blocks — the waiting queue never
        forms.
        Baselines: at admission the new trace simply WAITS (vLLM does
        not evict running work for new arrivals); mid-decode the
        scheduling policy picks a running victim to PREEMPT
        (discard-and-recompute) into the waiting queue — last-arrived
        under FIFO, the most over-budget tenant's last trace under a
        TenantScheduler.
        """
        # evict-before-prune: LRU cache-only blocks are reclaimed
        # before any live trace is touched. This ordering is what
        # keeps cache-on scheduling a superset of cache-off headroom
        # (the cache can only ADD free-able memory, never displace a
        # trace that would have run with the cache off).
        if self.pcache is not None and self.pcache.evict(1):
            return True
        st = self.by_req[rid]
        own_running = [t for t in self.running if t.request_id == rid]
        victim = st.policy.on_memory_full(own_running,
                                          pressure=self.current_pressure())
        if victim is not None:  # STEP prune
            if len(own_running) <= 1 and needy is victim:
                # sole survivor: finish (truncate) instead of self-prune
                self.finish(victim)
                return True
            self.release(victim, TraceStatus.PRUNED)
            return True
        if self.reclaim_idle_prefix(skip_rid=rid):
            return True
        if at_admission or not self.running:
            return False  # baseline: queue the arrival, keep decoding
        if self.abort_other_jobs(skip_rid=rid):
            return True
        victim = self.sched.preempt_victim(self, needy)
        if victim is None:
            # lone trace cannot be preempted to help itself: truncate
            self.finish(needy)
            return True
        self.release(victim, TraceStatus.PREEMPTED)
        victim.runnable_since = time.perf_counter()
        self.waiting.append(victim)
        return True

    def finish(self, trace: Trace) -> None:
        text = self.tok.decode(trace.output_tokens)
        trace.answer = extract_answer(text)
        self.release(trace, TraceStatus.FINISHED)

    # ------------------------------------------------------------------
    # fault tolerance: cancellation, retry/degrade, recovery
    # ------------------------------------------------------------------
    def _sweep_cancellations(self, now_rel: float) -> bool:
        """Fire ``Cancelled`` events for requests flagged by
        ``Engine.cancel`` and for requests past their deadline (arrived
        or still pending). Runs at the top of every pump iteration;
        returns True if anything was emitted so the events are handled
        before the next scheduling round."""
        fired = False
        for rid in list(self.eng._cancel_requests):
            self.eng._cancel_requests.discard(rid)
            st = self.by_req.get(rid)
            if st is not None and st.result is None:
                self.emit(Cancelled(t=now_rel, request_id=rid,
                                    reason="cancelled"))
                fired = True
        for st in self.states:
            ddl = getattr(st.req, "deadline", None)
            if ddl is not None and st.result is None and now_rel >= ddl:
                self.emit(Cancelled(t=now_rel, request_id=st.request_id,
                                    reason="deadline_exceeded"))
                fired = True
        return fired

    def _on_cancelled(self, ev: Cancelled) -> None:
        st = self.by_req[ev.request_id]
        if st.result is not None:
            return  # finished between the sweep and delivery
        if ev.reason == "deadline_exceeded":
            self.stats.deadline_exceeded += 1
        else:
            self.stats.cancelled += 1
        self.release_request(st, ev.reason)
        self.audit()

    def release_request(self, st: ReqState, status: str,
                        trace_status: TraceStatus = TraceStatus.CANCELLED
                        ) -> None:
        """The single release path for cancellation/deadline/failure:
        the request's traces, decode slots, prefill reservation,
        cache-hit forks and prefix references all return to the pool,
        and its result is finalized with ``status``. Traces already
        FINISHED keep their output (a deadline'd request still votes
        over whatever completed in time)."""
        st.final_status = status
        if not st.arrived:
            # still pending: withdraw from the arrival queue and
            # finalize immediately (no pool state exists yet)
            self.pending.remove(st.request_id)
            st.arrived = True
            for t in st.traces:
                t.status = trace_status
            st.t_done = time.perf_counter()
            st.result = self.eng._finalize(st, self.t_start, st.t_done,
                                           self.peak_blocks)
            self.emit(Completion(t=self._now_rel(),
                                 request_id=st.request_id))
            return
        job = self.jobs.pop(st.request_id, None)
        if job is not None:
            job.abort()
        if st.cache_hit is not None:
            self.mgr.free(st.cache_hit[0])
            st.cache_hit = None
        for t in list(st.traces):
            if not t.alive:
                continue
            if t in self.waiting:
                self.waiting.remove(t)
            self.release(t, trace_status)

    def shed_fanout(self) -> None:
        """Persistent-alloc degrade rung: shed WAITING trace fan-out
        down to each request's SLO floor (``slo.min_traces``, else 1).
        Mirrors ``apply_slo_admission`` — running lanes are never
        touched, so survivors stay bit-identical."""
        self._fanout_shed = True
        for st in self.started:
            if st.done():
                continue
            slo = getattr(st.req, "slo", None)
            keep = max(slo.min_traces if slo is not None else 1, 1)
            excess = sum(1 for t in st.traces if t.alive) - keep
            for t in reversed(st.traces):
                if excess <= 0:
                    break
                if t.status == TraceStatus.WAITING and t in self.waiting:
                    self.waiting.remove(t)
                    self.release(t, TraceStatus.PRUNED)
                    st.degraded_traces += 1
                    self.stats.shed_traces += 1
                    excess -= 1

    def abort_serve(self) -> None:
        """Recovery exhausted: fail every unfinished request through
        the normal release path, leaving the pool drained and the
        engine reusable. The event loop exits cleanly afterwards."""
        self.stats.aborted += 1
        for st in self.states:
            if st.result is None:
                self.release_request(st, "failed",
                                     trace_status=TraceStatus.FAILED)
        self.waiting.clear()
        self.audit()

    def emergency_drain(self) -> None:
        """Mid-serve crash cleanup (``serve_batch`` re-raises after):
        abort reservations, free every live trace's blocks and prefix
        holders, and drop the device pool — a crash mid-device-call may
        leave donated buffers dead, so parked KV cannot be trusted.
        The next serve starts from a freshly initialized, drained pool.
        """
        for job in list(self.jobs.values()):
            job.abort()
        self.jobs.clear()
        for st in self.states:
            if st.cache_hit is not None:
                self.mgr.free(st.cache_hit[0])
                st.cache_hit = None
            for t in st.traces:
                if not t.alive:
                    continue
                if t.blocks:
                    self.mgr.free(t.blocks)
                    t.blocks = []
                if t.batch_slot >= 0:
                    self.free_slots.append(t.batch_slot)
                    t.batch_slot = -1
                t.status = TraceStatus.FAILED
            if st.result is None:
                st.final_status = "failed"
            if st.prefix is not None:
                self.mgr.free(st.prefix.blocks)
                st.prefix = None
        self.running.clear()
        self.waiting.clear()
        if self.pcache is not None:
            self.pcache.clear()   # parked KV may point into a dead pool
        self.eng._kv_cache = None  # next serve re-inits the device pool

    def audit(self) -> None:
        """Invariant audit after a fault/cancel path: allocator
        refcount conservation and no reservations open beyond the
        in-flight prefill jobs' own."""
        self.eng.check_integrity(expect_open_reservations=len(self.jobs))

    def degrade_step(self) -> bool:
        """Take the next persistent-step-fault degrade rung. Every rung
        is token-identical by the engine's equivalence pins: kernel ==
        dense (PR 5) first, then decode_horizon K == 1 (PR 3). Returns
        False when the ladder is exhausted."""
        if self.eng.degrade_to_dense():
            return True
        if self.K_cfg > 1 and not self.eng.force_horizon1:
            self.eng.force_horizon1 = True
            self.stats.degraded_horizon += 1
            return True
        return False

    def device_call(self, thunk: Callable):
        """Run one device step under the fault plan's step injection and
        the retry/degrade recovery policy.

        Injected ``DeviceStepFault``s are raised BEFORE the device call,
        so no RNG is consumed and the donated KV pool is untouched — a
        retry is bit-identical to the un-faulted call. ``thunk`` is
        zero-arg and re-resolves the engine's jitted step on each
        attempt, so a mid-ladder degrade (kernel->dense rebuild, the
        horizon pin) takes effect on the very next retry. Real
        exceptions propagate immediately (buffer donation makes a blind
        retry unsafe); recovery exhaustion raises ``FatalFaultError``.
        """
        attempts = 0
        faulted = False
        while True:
            try:
                if self.plan is not None:
                    self.plan.maybe_step_fault(self.tick)
                out = thunk()
            except DeviceStepFault:
                faulted = True
                attempts += 1
                self.stats.step_faults += 1
                if attempts <= self.recovery.retry_limit:
                    self.stats.step_retries += 1
                    time.sleep(self.recovery.backoff(attempts))
                    continue
                if self.degrade_step():
                    attempts = 0
                    continue
                raise FatalFaultError(
                    "device step still failing after retries and every "
                    "degrade rung") from None
            if faulted:
                self.stats.recovered_steps += 1
            return out

    # ------------------------------------------------------------------
    # write-block assurance (COW / frontier)
    # ------------------------------------------------------------------
    def owns_write_block(self, trace: Trace, bidx: int) -> bool:
        return (bidx < len(trace.blocks)
                and not self.mgr.is_shared(trace.blocks[bidx]))

    def claim_write_block(self, trace: Trace, bidx: int) -> None:
        """Make ``trace`` the exclusive owner of its write block at
        ``bidx``: a fresh block at the growth frontier, or a COW
        copy of a still-shared (prompt) block — the first private
        write, or a window wrap re-entering shared blocks. The
        caller has ensured a free block exists."""
        blk = self.mgr.allocate(1)
        self.note_peak()
        if bidx < len(trace.blocks):
            old = trace.blocks[bidx]
            try:
                self.cache = self.eng._copy_block(self.cache, old, blk[0])
            except BaseException:
                self.mgr.free(blk)   # the fresh block must not leak
                raise
            self.mgr.free([old])
            trace.blocks[bidx] = blk[0]
        else:
            trace.blocks.extend(blk)
        self.block_tables[trace.batch_slot, bidx] = blk[0]
        self.dirty["block_tables"] = True

    def max_new(self, trace: Trace) -> int:
        """Per-request max-new-tokens override (engine default when the
        request does not set one)."""
        return self.by_req[trace.request_id].max_new

    def frontier_walk(self, trace: Trace, k_tick: int):
        """Yield (token offset j, block index) over ``trace``'s
        next-``k_tick``-token write window, beyond the next token
        (whose block the COW/grow pass already guarantees)."""
        p = int(self.positions[trace.batch_slot])
        want = min(k_tick,
                   max(self.max_new(trace) - trace.num_tokens, 1))
        for j in range(1, want):
            yield j, ((p + j) % self.cap) // self.bs

    def extend_frontier(self, trace: Trace, k_tick: int) -> int:
        """Secure exclusively-owned write blocks for up to
        ``k_tick`` upcoming tokens of one trace. Best-effort: a
        short free list shortens the lane's horizon, it never
        triggers pruning/preemption."""
        secured = 1
        for j, bidx in self.frontier_walk(trace, k_tick):
            if not self.owns_write_block(trace, bidx):
                if not self.evict_for(1):
                    break
                self.claim_write_block(trace, bidx)
            secured = j + 1
        return secured

    def start_wait_clock(self, st: ReqState) -> None:
        """Memory-blocked before admission: start the WAIT clock of
        the request's next admissible trace (mirrors the one-shot
        path, which stamps the admitting trace)."""
        for t in st.traces:
            if t.status == TraceStatus.WAITING and t in self.waiting:
                if t.runnable_since < 0:
                    t.runnable_since = time.perf_counter()
                return

    # ------------------------------------------------------------------
    # admission (chunked prefill jobs, shared prefix, private path)
    # ------------------------------------------------------------------
    def advance_job(self, job: PrefillJob, budget: TokenBudget) -> str:
        """Run prefill chunks for one job within the round budget.

        Returns "ready" (prefix complete), "budget" (round budget or
        interleave cap reached), or "memory" (blocked on blocks with
        no reclaimable progress).
        """
        eng = self.eng
        st = job.st
        tenant = st.tenant
        L = len(job.tokens)
        C = job.chunk
        base_n = len(job.base)
        while not job.done:
            # stay on the absolute C-token chunk grid: a cache-hit
            # suffix (pos starts at base_tokens) runs the exact
            # chunks a cold prefill of this prompt would have run
            c = min(C - job.pos % C, L - job.pos)
            if not budget.can(c, force=not self.running, tenant=tenant):
                return "budget"
            need_total = self.mgr.blocks_for_tokens(job.pos + c)
            need_new = need_total - base_n - job.res.num_taken
            while need_new > 0:
                got = job.res.take(need_new)
                if got is not None:
                    self.note_peak()
                    start = base_n + job.res.num_taken - len(got)
                    job.row[start : base_n + job.res.num_taken] = got
                    break
                self.start_wait_clock(st)
                if not self.handle_memory_full(None, st.request_id,
                                               at_admission=True):
                    return "memory"
            t_pf = time.perf_counter()
            toks = np.zeros((1, C), np.int32)
            toks[0, :c] = job.tokens[job.pos : job.pos + c]
            pos_arr = job.pos + np.arange(C, dtype=np.int32)[None, :]
            valid = (np.arange(C, dtype=np.int32)[None, :] < c)
            logits, self.cache = self.device_call(
                lambda: eng._chunk_prefill(
                    eng.params, self.cache, jnp.asarray(toks),
                    jnp.asarray(pos_arr), jnp.asarray(valid),
                    jnp.asarray(job.row[None, :], jnp.int32)))
            job.last_logits = logits[:, c - 1]
            job.pos += c
            budget.spend(c, tenant=tenant)
            st.prefill_s += time.perf_counter() - t_pf
            self.emit(ChunkDone(t=self._now_rel(),
                                request_id=st.request_id,
                                pos=job.pos, total=L, chunk_tokens=c))
            if self.running and not job.eager:
                # interleave: while traces decode, at most one chunk
                # per round so prefill never stalls the decode batch
                break
        if job.done:
            base, job.base = job.base, []
            st.prefix = SharedPrefix(
                blocks=base + job.res.commit(), seq_len=L,
                last_logits=job.last_logits, slot_state=None)
            self.jobs.pop(st.request_id, None)
            return "ready"
        return "budget"

    def ensure_prefix(self, st: ReqState, trace: Trace,
                      budget: TokenBudget) -> Optional[bool]:
        """Build the request's shared prompt prefill on demand
        (one-shot path; the chunked path goes through PrefillJob).

        True: prefix ready. False: memory action made progress, retry
        admission. None: memory full and nothing to free — queue.
        """
        eng = self.eng
        if st.prefix is not None:
            return True
        seq_len = len(trace.prompt_tokens)
        need = self.mgr.blocks_for_tokens(seq_len)
        # need + 1: the admitting trace's first private (COW) block
        # must fit too, or the headroom check right after us fails
        # and the just-computed prefill is wasted (worst case: an
        # endless build/reclaim/rebuild cycle)
        if not self.evict_for(need + 1):
            if trace.runnable_since < 0:
                trace.runnable_since = time.perf_counter()
            if not self.handle_memory_full(None, st.request_id,
                                           at_admission=True):
                return None
            return False
        budget.spend(seq_len, tenant=st.tenant)
        blocks = self.mgr.allocate(need)
        self.note_peak()
        row = np.zeros((eng.blocks_per_seq,), np.int32)
        row[:len(blocks)] = blocks
        t_pf = time.perf_counter()
        ids_arr = jnp.asarray(
            np.array(trace.prompt_tokens, np.int32)[None, :])
        try:
            logits, kvs = self.device_call(
                lambda: eng._prefill(eng.params, ids_arr))
        except BaseException:
            self.mgr.free(blocks)   # the local holding must not leak
            raise
        attn_kvs, slot_state = eng._split_prefill_kvs(kvs)
        self.cache = eng._write_prefix_kv(self.cache, attn_kvs, row,
                                          seq_len)
        st.prefix = SharedPrefix(blocks=blocks, seq_len=seq_len,
                                 last_logits=logits[:, -1],
                                 slot_state=slot_state)
        st.prefill_s += time.perf_counter() - t_pf
        self._tokens_done += seq_len
        return True

    def admit_shared(self, trace: Trace, st: ReqState,
                     wave: List[Trace]) -> None:
        """Fork the request's prompt blocks into a fresh trace."""
        prefix = st.prefix
        self.waiting.remove(trace)
        slot = self.free_slots.pop(0)
        if trace.runnable_since >= 0:
            trace.wait_time += time.perf_counter() - trace.runnable_since
            trace.runnable_since = -1.0
        trace.blocks = self.mgr.fork(prefix.blocks)
        trace.batch_slot = slot
        trace.status = TraceStatus.RUNNING
        trace.prefill_count += 1
        self.running.append(trace)
        if st.admit_t is None:
            st.admit_t = time.perf_counter()
        row = np.zeros((self.eng.blocks_per_seq,), np.int32)
        row[:len(trace.blocks)] = trace.blocks
        self.block_tables[slot] = row
        self.positions[slot] = prefix.seq_len
        self.dirty["block_tables"] = self.dirty["positions"] = True
        self._set_slot_sampling(slot, st)
        if prefix.slot_state is not None:
            self.cache = self.eng._write_slot_state(self.cache,
                                                    prefix.slot_state, slot)
        wave.append(trace)

    def admit_private(self, trace: Trace, st: ReqState) -> None:
        """Original per-trace path: full prefill into private blocks
        (flag off, prompt > capacity, or preempted-trace recompute)."""
        eng = self.eng
        ids = trace.prompt_tokens + trace.output_tokens
        need = self.mgr.blocks_for_tokens(min(len(ids) + 1, self.cap))
        self.waiting.remove(trace)
        blocks = self.mgr.allocate(need)
        self.note_peak()
        slot = self.free_slots.pop(0)
        if trace.runnable_since >= 0:
            trace.wait_time += time.perf_counter() - trace.runnable_since
            trace.runnable_since = -1.0
        trace.blocks = blocks
        trace.batch_slot = slot
        trace.status = TraceStatus.RUNNING
        trace.prefill_count += 1
        self.running.append(trace)
        if st.admit_t is None:
            st.admit_t = time.perf_counter()

        row = np.zeros((eng.blocks_per_seq,), np.int32)
        row[:len(blocks)] = blocks
        self.block_tables[slot] = row
        t_pf = time.perf_counter()
        ids_arr = jnp.asarray(np.array(ids, np.int32)[None, :])
        # trace.blocks/slot are already registered, so a fatal abort
        # from here releases them through the normal trace path
        logits, kvs = self.device_call(
            lambda: eng._prefill(eng.params, ids_arr))
        cache_new = eng._write_prefill(self.cache, kvs, slot, row, len(ids))
        # next token continues from the last prefill logit
        self.positions[slot] = len(ids)
        self.dirty["block_tables"] = self.dirty["positions"] = True
        self.dirty["tokens"] = True
        self._set_slot_sampling(slot, st)
        nt, conf = eng.sample_host(logits[:, -1], st.sampling)
        self.cur_tokens[slot] = int(nt[0])
        trace.output_tokens.append(int(nt[0]))
        trace.token_confidences.append(float(conf[0]))
        st.note_first_token()
        self.cache = cache_new
        st.prefill_s += time.perf_counter() - t_pf
        self._tokens_done += len(ids)

    def _set_slot_sampling(self, slot: int, st: ReqState) -> None:
        if not self.mixed_sampling:
            return
        sp = st.sampling
        self.samp["temperature"][slot] = sp.temperature
        self.samp["top_k"][slot] = sp.top_k
        self.samp["top_p"][slot] = sp.top_p
        self.samp_dirty = True

    def flush_first_tokens(self, wave: List[Trace]) -> None:
        """Batch the first-token sampling for every trace admitted via
        prefix forking in this admission wave (one device call)."""
        live = [t for t in wave if t.status == TraceStatus.RUNNING]
        if not live:
            return
        logits = jnp.concatenate(
            [self.by_req[t.request_id].prefix.last_logits for t in live],
            axis=0)  # [m, Vp]
        if self.mixed_sampling:
            sps = [self.by_req[t.request_id].sampling for t in live]
            nt, conf = self.eng.sample_host_lanes(logits, sps)
        else:
            nt, conf = self.eng.sample_host(logits, self.ecfg.sampling)
        nt = np.asarray(nt).tolist()
        conf = np.asarray(conf).tolist()
        self.dirty["tokens"] = True
        for i, trace in enumerate(live):
            self.cur_tokens[trace.batch_slot] = nt[i]
            trace.output_tokens.append(nt[i])
            trace.token_confidences.append(conf[i])
            self.by_req[trace.request_id].note_first_token()

    def apply_slo_admission(self, st: ReqState) -> bool:
        """SLO admission control, once per request at its first
        admission attempt: the scheduling policy may degrade the trace
        fan-out (shed waiting traces — STEP's quality dial) or reject
        the request outright. Returns True if any trace was shed."""
        if st.slo_checked:
            return False
        st.slo_checked = True
        target = self.sched.target_traces(self, st)
        own_waiting = [t for t in st.traces
                       if t.status == TraceStatus.WAITING
                       and t in self.waiting]
        excess = own_waiting[max(target, 0):]
        if not excess:
            return False
        st.degraded_traces = len(excess)
        for t in excess:
            self.waiting.remove(t)
            self.release(t, TraceStatus.PRUNED)
        return True

    def try_admit(self, budget: TokenBudget) -> bool:
        """One admission wave. Returns True if anything was admitted
        or any prefill chunk advanced."""
        wave: List[Trace] = []
        advanced = False
        # in-flight chunked prefills advance first (oldest work)
        for rid in list(self.jobs):
            job = self.jobs.get(rid)
            if job is None:
                continue
            before = job.pos
            status = self.advance_job(job, budget)
            if status == "ready" or job.pos > before:
                advanced = True
        skipped: set = set()
        while self.free_slots:
            trace = self.sched.pick(self, skipped)
            if trace is None:
                break
            st = self.by_req[trace.request_id]
            if self.apply_slo_admission(st):
                advanced = True
                continue  # re-pick: the shed may have emptied the queue
            tenant = st.tenant
            # sharing needs prompt blocks + one private block to ever
            # fit the pool; pathologically small pools fall back to
            # the per-trace path (which can truncate-finish)
            prefix_fits = (self.mgr.blocks_for_tokens(
                len(trace.prompt_tokens)) + 1 <= self.ecfg.num_blocks - 1)
            fresh = (self.share and not trace.output_tokens
                     and len(trace.prompt_tokens) <= self.cap
                     and prefix_fits)
            if fresh:
                L = len(trace.prompt_tokens)
                if (st.prefix is None and self.pcache is not None
                        and not st.cache_probed):
                    # probe the prefix cache exactly once per request
                    # (stats stay deterministic across re-picks) and
                    # pin the hit immediately: the fork's refcounts
                    # protect the matched blocks from eviction while
                    # the request waits for a slot or budget
                    st.cache_probed = True
                    hit_blocks, hit_tokens = self.pcache.match(
                        trace.prompt_tokens)
                    if hit_blocks:
                        st.cache_hit = (self.mgr.fork(hit_blocks),
                                        hit_tokens)
                        st.cached_tokens = hit_tokens
                use_job = st.prefix is None and (
                    st.request_id in self.jobs
                    or st.cache_hit is not None
                    or (self.chunk is not None and L > self.chunk))
                if use_job:
                    # chunked path: open/advance the prefill job; the
                    # trace admits once the prefix completes. Cache
                    # hits always take this path — the suffix runs as
                    # block-size chunks (a fixed jit shape) even on
                    # engines configured for one-shot prefill.
                    job = self.jobs.get(st.request_id)
                    if job is None:
                        base, base_tokens = st.cache_hit or ([], 0)
                        st.cache_hit = None
                        job = PrefillJob(
                            st,
                            self.mgr.reserve(self.mgr.blocks_for_tokens(L)
                                             - len(base)),
                            self.eng.blocks_per_seq,
                            chunk=(self.chunk if self.chunk is not None
                                   else self.bs),
                            base_blocks=base, base_tokens=base_tokens,
                            eager=self.chunk is None)
                        self.jobs[st.request_id] = job
                    before = job.pos
                    status = self.advance_job(job, budget)
                    if status == "ready":
                        advanced = True
                        continue  # re-pick: prefix now exists
                    if job.pos > before:
                        advanced = True
                    if status == "memory":
                        break
                    skipped.add(st.request_id)
                    continue
                if st.prefix is None and not budget.can(
                        L, force=not self.running, tenant=tenant):
                    skipped.add(st.request_id)
                    continue
                ok = self.ensure_prefix(st, trace, budget)
                if ok is None:
                    break
                if ok is False:
                    continue
                # the admitted trace decodes THIS round — up to a
                # full horizon of tokens: charge them pessimistically
                # so a round never exceeds the budget
                if not budget.can(self.K_cfg,
                                  force=not self.running and not wave,
                                  tenant=tenant):
                    skipped.add(st.request_id)
                    continue
                # headroom for this trace's first private block (the
                # COW copy of the prompt's tail block, or a fresh
                # block when the prompt ends exactly on a boundary)
                if not self.evict_for(1):
                    if trace.runnable_since < 0:
                        trace.runnable_since = time.perf_counter()
                    if not self.handle_memory_full(None, st.request_id,
                                                   at_admission=True):
                        break
                    continue
                budget.spend(self.K_cfg, tenant=tenant)
                self.admit_shared(trace, st, wave)
            else:
                ids_len = (len(trace.prompt_tokens)
                           + len(trace.output_tokens))
                # prefill cost + this round's decode horizon
                if not budget.can(ids_len + self.K_cfg,
                                  force=not self.running, tenant=tenant):
                    skipped.add(trace.request_id)
                    continue
                need = self.mgr.blocks_for_tokens(
                    min(ids_len + 1, self.cap))
                if not self.evict_for(need):
                    # memory full at admission: STEP prunes,
                    # baselines wait
                    if trace.runnable_since < 0:
                        trace.runnable_since = time.perf_counter()
                    if not self.handle_memory_full(None, st.request_id,
                                                   at_admission=True):
                        break
                    if not self.evict_for(need):
                        break
                    continue
                budget.spend(ids_len + self.K_cfg, tenant=tenant)
                self.admit_private(trace, st)
        self.flush_first_tokens(wave)
        return advanced or bool(wave)

    # ------------------------------------------------------------------
    # decode dispatch + burst processing
    # ------------------------------------------------------------------
    def _dispatch_decode(self, ev: BudgetReplenish) -> None:
        """Write-block assurance, horizon selection, ONE fused device
        call, then a ``BurstDone`` event carrying the host-synced
        results."""
        eng = self.eng
        # ensure every running trace exclusively owns the block its
        # next token's KV will be written into: allocate fresh blocks
        # at the growth frontier, copy-on-write still-shared (prompt)
        # blocks
        progress = True
        for trace in list(self.running):
            if trace.status != TraceStatus.RUNNING:
                # released (pruned/preempted) as an earlier trace's
                # memory-full victim within this very loop: it no
                # longer needs a write block, and raising pressure
                # on its behalf would evict a live trace for nothing
                continue
            pos = int(self.positions[trace.batch_slot])
            bidx = (pos % self.cap) // self.bs  # writes land at pos % window
            if self.owns_write_block(trace, bidx):
                continue
            while not self.evict_for(1):
                if not self.handle_memory_full(trace, trace.request_id):
                    progress = False
                    break
                if trace.status != TraceStatus.RUNNING:
                    break  # the needy trace itself was pruned/preempted
            if trace.status != TraceStatus.RUNNING or not progress:
                continue
            self.claim_write_block(trace, bidx)
        if not self.running:
            return

        # decode horizon: how many tokens may this round fuse?
        K_cfg = self.K_cfg
        K_tick = K_cfg
        if K_cfg > 1 and self.waiting:
            # Admission pressure: count the blocks a full-horizon
            # frontier would actually ALLOCATE (most rounds the write
            # block has unwritten slots left and the answer is 0 —
            # the horizon is free). If extending would drain the
            # free list to the last block, pre-allocation could
            # starve waiting admissions and shift memory-triggered
            # pruning decisions away from their horizon=1 points:
            # fall back to a single-token round until the contention
            # clears.
            needed_new = 0
            for trace in self.running:
                needed_new += len(
                    {bidx for _, bidx in self.frontier_walk(trace, K_cfg)
                     if not self.owns_write_block(trace, bidx)})
            if needed_new and not self.evict_for(needed_new + 1):
                eng.horizon_fallbacks += 1
                K_tick = 1
        if eng.force_horizon1:
            # persistent-fault degrade rung: every burst runs at K=1
            # (token-identical by the K==1 equivalence pin)
            K_tick = 1

        B = self.B
        limits = np.zeros((B,), np.int32)
        for trace in self.running:
            limits[trace.batch_slot] = (
                1 if K_tick == 1 else self.extend_frontier(trace, K_tick))

        # one fixed-shape fused decode call: K_tick iterations of
        # decode + on-device sampling + step-boundary score capture
        n_by_req: Dict[int, int] = {}
        for t in self.running:
            n_by_req[t.request_id] = n_by_req.get(t.request_id, 0) + 1
        t_dec = time.perf_counter()
        ss = eng._ss
        for name, arr in (("tokens", self.cur_tokens),
                          ("positions", self.positions),
                          ("block_tables", self.block_tables)):
            if self.dirty[name] or self.dev[name] is None:
                if ss is None:
                    self.dev[name] = jnp.asarray(arr)
                else:  # upload straight into the mesh layout
                    up = "table" if name == "block_tables" else "lane"
                    self.dev[name] = jax.device_put(arr, ss[up])
                self.dirty[name] = False
        limits_dev = (jnp.asarray(limits) if ss is None
                      else jax.device_put(limits, ss["lane"]))
        extra = ()
        if self.mixed_sampling:
            if self.samp_dirty or self.samp_dev is None:
                put = ((lambda a: jnp.asarray(a)) if ss is None else
                       (lambda a: jax.device_put(a, ss["replicated"])))
                self.samp_dev = tuple(put(self.samp[k]) for k in
                                      ("temperature", "top_k", "top_p"))
                self.samp_dirty = False
            extra = self.samp_dev

        def decode_thunk():
            # re-resolve the step each attempt: a mid-retry degrade
            # (kernel->dense rebuild, horizon pin) must take effect on
            # the next attempt. A K>1 limits row is valid for a K=1
            # step — the lane simply emits one token.
            K_eff = 1 if eng.force_horizon1 else K_tick
            fn = eng.decode_fn(K_eff if K_eff == K_cfg else 1,
                               lanewise=self.mixed_sampling)
            return fn(eng.params, self.cache, self.dev["tokens"],
                      self.dev["positions"], limits_dev,
                      self.dev["block_tables"], eng._rng,
                      eng.scorer_params, *extra)

        (toks_d, confs_d, scores_d, tv_d, sv_d, fin_tok, fin_pos,
         self.cache, eng._rng) = self.device_call(decode_thunk)
        # single host sync per round; .tolist() batches the per-trace
        # float()/int() conversions of the old per-token loop
        toks_h, confs_h, scores_h, tv_h, sv_h, ft_h, fp_h = (
            x.tolist() for x in jax.device_get(
                (toks_d, confs_d, scores_d, tv_d, sv_d,
                 fin_tok, fin_pos)))
        if self.plan is not None:
            # NaN injection poisons the victim lane's HOST-synced
            # confidences only — device state is untouched, so the
            # other lanes are trivially unperturbed. The quarantine
            # path in _on_burst_done detects and terminates the lane.
            lanes = sorted((t.batch_slot, t.request_id)
                           for t in self.running)
            for slot in self.plan.nan_victims(ev.tick, lanes):
                confs_h[slot] = [float("nan")] * len(confs_h[slot])
        self.dev["tokens"], self.dev["positions"] = fin_tok, fin_pos
        self.cur_tokens[:] = ft_h
        self.positions[:] = fp_h
        dt = time.perf_counter() - t_dec
        tot = sum(n_by_req.values())
        for rid, n in n_by_req.items():
            self.by_req[rid].decode_s += dt * n / tot

        self._burst = (toks_h, confs_h, scores_h, tv_h, sv_h)
        self.emit(BurstDone(t=self._now_rel(), tick=ev.tick,
                            n_lanes=len(self.running), tokens=0))

    def _on_burst_done(self, ev: BurstDone) -> None:
        """Fold the synced burst into traces: outputs, scores, EOS /
        max-new-token finishes, then the signal-triggered termination
        sweep (DeepConf / Slim-SC / STEP proactive pruning)."""
        toks_h, confs_h, scores_h, tv_h, sv_h = self._burst
        emitted = 0
        quarantined = False
        for trace in list(self.running):
            st = self.by_req[trace.request_id]
            slot = trace.batch_slot
            valid_row = tv_h[slot]
            n_emit = 0
            for v in valid_row:
                if not v:
                    break
                n_emit += 1
            # NaN/Inf quarantine: a poisoned burst (injected or a real
            # numerical blow-up) must never fold into trace state —
            # terminate the lane with a distinct status; the other
            # lanes' device state never saw it
            bad = any(not math.isfinite(c) for c in confs_h[slot][:n_emit])
            if not bad and st.policy.uses_scorer:
                bad = any(not math.isfinite(scores_h[slot][i])
                          for i in range(n_emit) if sv_h[slot][i])
            if bad:
                self.stats.nan_quarantined += 1
                quarantined = True
                self.release(trace, TraceStatus.FAILED)
                continue
            # scores belong to the hidden states of the iteration
            # INPUT tokens; score_valid marks the step boundaries
            # (input token == step_id) inside the emitted prefix
            if st.policy.uses_scorer:
                burst_scores = [scores_h[slot][i]
                                for i in range(n_emit) if sv_h[slot][i]]
                if burst_scores:
                    trace.add_step_scores(burst_scores)
            else:
                burst_scores = []
            burst_toks = toks_h[slot][:n_emit]
            burst_confs = confs_h[slot][:n_emit]
            trace.extend_output(burst_toks, burst_confs)
            emitted += n_emit
            st.policy.observe_decode_burst(trace, burst_toks,
                                           burst_confs, burst_scores)
            if n_emit and (burst_toks[-1] == self.tok.eos_id
                           or trace.num_tokens >= st.max_new):
                self.finish(trace)
        ev.tokens = emitted
        self._tokens_done += emitted
        if quarantined:
            self.audit()

        # signal-triggered termination (DeepConf / Slim-SC / STEP
        # proactive pruning under admission pressure)
        for st in self.started:
            own = [t for t in self.running
                   if t.request_id == st.request_id]
            if not own:
                continue
            for trace in st.policy.traces_to_terminate(own):
                if trace.status == TraceStatus.RUNNING:
                    self.release(trace, TraceStatus.PRUNED)
