"""End-to-end evaluation harness: run problems through the engine under a
method (cot / sc / slimsc / deepconf / step) and report the paper's three
metrics — accuracy, avg output tokens, latency — plus the Table 3 phase
breakdown (wait / decode / prefill) and, for the continuous-batching
path, the online-serving summary (TTFT / TPOT / e2e percentiles).
"""
from __future__ import annotations

import dataclasses
import random
from typing import Callable, List, Optional, Sequence

import numpy as np

from repro.configs.base import ModelConfig
from repro.core.pruning import make_policy
from repro.data.arithmetic import Problem, gen_problem, make_prompt
from repro.data.tokenizer import get_tokenizer
from repro.serving.engine import Engine, EngineConfig, Request, RequestResult
from repro.serving.metrics import summarize


@dataclasses.dataclass
class EvalResult:
    method: str
    n_traces: int
    accuracy: float
    avg_tokens: float
    avg_latency_s: float
    total_wait_s: float
    total_decode_s: float
    total_prefill_s: float
    num_pruned: int
    num_preemptions: int
    per_problem: List[dict]
    # online-serving summary (metrics.summarize) — batched path only
    serving: Optional[dict] = None


def make_problems(n: int, seed: int = 1234,
                  n_steps=(3, 6)) -> List[Problem]:
    rng = random.Random(seed)
    return [gen_problem(rng, n_steps) for _ in range(n)]


def poisson_arrivals(n: int, rate_per_s: float, seed: int = 0) -> List[float]:
    """Arrival offsets (seconds) for a Poisson process of ``rate_per_s``.

    The benchmark's open-loop load model: exponential inter-arrival
    gaps, cumulative. rate <= 0 degenerates to everything at t=0 (the
    offline batch)."""
    if rate_per_s <= 0:
        return [0.0] * n
    rng = np.random.default_rng(seed)
    gaps = rng.exponential(1.0 / rate_per_s, size=n)
    return list(np.cumsum(gaps))


def _aggregate(method: str, n_traces: int, problems: List[Problem],
               results: Sequence[RequestResult], verbose: bool = False,
               with_serving: bool = False) -> EvalResult:
    """Fold per-request RequestResults into the paper's three metrics."""
    records = []
    totals = dict(wait=0.0, decode=0.0, prefill=0.0, pruned=0, preempt=0)
    correct = 0
    for p, res in zip(problems, results):
        ok = res.answer is not None and int(res.answer) == p.answer
        correct += ok
        totals["wait"] += res.wait_s
        totals["decode"] += res.decode_s
        totals["prefill"] += res.prefill_s
        totals["pruned"] += res.num_pruned
        totals["preempt"] += res.num_preemptions
        rec = {
            "qid": res.request_id, "answer": res.answer, "gold": p.answer,
            "correct": bool(ok), "tokens": res.total_tokens,
            "latency_s": res.latency_s, "wait_s": res.wait_s,
            "decode_s": res.decode_s, "prefill_s": res.prefill_s,
            "pruned": res.num_pruned, "preemptions": res.num_preemptions,
        }
        if res.metrics is not None:
            rec["ttft_s"] = res.metrics.ttft_s
            rec["tpot_s"] = res.metrics.tpot_s
            rec["e2e_s"] = res.metrics.e2e_s
        records.append(rec)
        if verbose:
            print(f"  [{method}] q{res.request_id}: ans={res.answer} "
                  f"gold={p.answer} ok={ok} tok={res.total_tokens} "
                  f"lat={res.latency_s:.2f}s wait={res.wait_s:.2f}s")
    n = max(len(problems), 1)
    serving = None
    if with_serving:
        ms = [res.metrics for res in results if res.metrics is not None]
        serving = summarize(ms) if ms else None
    return EvalResult(
        method=method, n_traces=n_traces,
        accuracy=correct / n,
        avg_tokens=float(np.mean([r["tokens"] for r in records])),
        avg_latency_s=float(np.mean([r["latency_s"] for r in records])),
        total_wait_s=totals["wait"], total_decode_s=totals["decode"],
        total_prefill_s=totals["prefill"],
        num_pruned=totals["pruned"], num_preemptions=totals["preempt"],
        per_problem=records, serving=serving)


def evaluate_method(method: str, params: dict, cfg: ModelConfig,
                    problems: List[Problem], n_traces: int,
                    ecfg: Optional[EngineConfig] = None,
                    scorer_params: Optional[dict] = None,
                    policy_kwargs: Optional[dict] = None,
                    mesh=None,
                    verbose: bool = False) -> EvalResult:
    """One engine + one request at a time — the paper's serial setting.

    ``ecfg=None`` builds the engine config from the ``REPRO_*``
    environment (``EngineConfig.from_env()``)."""
    tok = get_tokenizer()
    if ecfg is None:
        ecfg = EngineConfig.from_env()
    policy_kwargs = dict(policy_kwargs or {})
    if method == "cot":
        n_traces = 1
    results = []
    for qid, p in enumerate(problems):
        policy = make_policy(method, **policy_kwargs)
        engine = Engine(params, cfg, ecfg, policy,
                        scorer_params=scorer_params
                        if policy.uses_scorer else None,
                        mesh=mesh)
        prompt = tok.encode(make_prompt(p), add_bos=True)
        results.append(engine.serve(prompt, n_traces, request_id=qid))
    return _aggregate(method, n_traces, problems, results, verbose=verbose)


def evaluate_method_batched(method: str, params: dict, cfg: ModelConfig,
                            problems: List[Problem], n_traces: int,
                            ecfg: Optional[EngineConfig] = None,
                            scorer_params: Optional[dict] = None,
                            policy_kwargs: Optional[dict] = None,
                            arrival_times: Optional[Sequence[float]] = None,
                            on_result: Optional[
                                Callable[[RequestResult], None]] = None,
                            mesh=None,
                            scheduler=None,
                            request_overrides: Optional[
                                Sequence[dict]] = None,
                            verbose: bool = False) -> EvalResult:
    """All problems submitted to ONE engine as a request queue: traces of
    different requests co-exist in the decode batch and contend for the
    shared block pool (the multi-request serving scenario). Each request
    gets a fresh policy instance so stateful policies (DeepConf warmup
    threshold, Slim-SC cursors) don't leak across concurrent requests.

    ``arrival_times`` (seconds, per problem) turns the batch into an
    online arrival trace (continuous batching); ``on_result`` streams
    each request's ``RequestResult`` the moment it completes.

    ``ecfg=None`` builds the engine config from the ``REPRO_*``
    environment (``EngineConfig.from_env()``). ``scheduler`` selects the
    engine's scheduling policy (e.g. ``serving.TenantScheduler`` for
    weighted fair multi-tenant budgets); ``request_overrides`` supplies
    per-request ``Request`` kwargs — ``tenant``/``priority``/``slo`` and
    the per-request ``sampling``/``max_new_tokens`` overrides.
    """
    tok = get_tokenizer()
    if ecfg is None:
        ecfg = EngineConfig.from_env()
    policy_kwargs = dict(policy_kwargs or {})
    if method == "cot":
        n_traces = 1
    if arrival_times is None:
        arrival_times = [0.0] * len(problems)
    assert len(arrival_times) == len(problems)
    if request_overrides is None:
        request_overrides = [{}] * len(problems)
    assert len(request_overrides) == len(problems)
    requests = [
        Request(request_id=qid,
                prompt_tokens=tok.encode(make_prompt(p), add_bos=True),
                n_traces=n_traces,
                policy=make_policy(method, **policy_kwargs),
                arrival_time=float(at),
                **extra)
        for qid, (p, at, extra) in enumerate(
            zip(problems, arrival_times, request_overrides))
    ]
    default_policy = make_policy(method, **policy_kwargs)
    engine = Engine(params, cfg, ecfg, default_policy,
                    scorer_params=scorer_params
                    if default_policy.uses_scorer else None,
                    mesh=mesh, scheduler=scheduler)
    results = engine.serve_batch(requests, on_complete=on_result)
    return _aggregate(method, n_traces, problems, results, verbose=verbose,
                      with_serving=True)
