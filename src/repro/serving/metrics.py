"""Per-request serving metrics for the continuous-batching engine.

The paper's Table 3 accounts engine seconds (prefill / decode / wait);
online serving additionally needs the request-facing latencies every
serving system reports:

  * TTFT — time to first token: first generated token of ANY of the
    request's traces, measured from the request's *arrival* (not from
    batch start). Queueing before admission, shared-prompt prefill and
    chunked-prefill interleaving all land in TTFT.
  * TPOT — time per output token after the first: steady-state decode
    pace as the request experienced it, including scheduler stalls,
    preemption-induced recompute and cross-request contention. For a
    request fanning into N traces the denominator is the total new
    tokens across traces minus one (the batch generates N tokens per
    engine step, so TPOT is a *request-level* pace, not a per-trace one).
  * e2e latency — arrival to completion (all traces finished/pruned).

``summarize`` folds a set of ``RequestMetrics`` into the percentile
table the load benchmark writes to ``BENCH_serving.json``.
"""
from __future__ import annotations

import dataclasses
from typing import Dict, Optional, Sequence

import numpy as np


@dataclasses.dataclass
class RequestMetrics:
    """Wall-clock serving metrics for one request (times in seconds,
    relative to the engine's serve-loop start)."""

    request_id: int
    arrival_s: float                 # when the request entered the queue
    admitted_s: Optional[float]      # first trace admitted to a slot
    first_token_s: Optional[float]   # first generated token (any trace)
    finished_s: Optional[float]      # all traces finished/pruned
    prompt_tokens: int = 0
    output_tokens: int = 0           # total new tokens across traces
    n_traces: int = 0
    num_pruned: int = 0
    num_preemptions: int = 0
    wait_s: float = 0.0              # memory-induced waiting (Table 3)
    prefill_s: float = 0.0
    decode_s: float = 0.0
    cached_tokens: int = 0           # prompt tokens served from the
    #                                  prefix cache (no prefill compute)
    # multi-tenant serving (scheduler.TenantScheduler)
    tenant: str = "default"
    priority: int = 0
    degraded_traces: int = 0         # traces shed by SLO admission
    slo_ttft_s: Optional[float] = None   # the request's SLO targets
    slo_tpot_s: Optional[float] = None   # (None = no objective attached)
    # fault-tolerant serving: how the request ended ("completed" |
    # "cancelled" | "deadline_exceeded" | "failed") and how many of its
    # traces were quarantined/aborted by fault recovery.
    status: str = "completed"
    failed_traces: int = 0

    @property
    def ttft_s(self) -> Optional[float]:
        if self.first_token_s is None:
            return None
        return self.first_token_s - self.arrival_s

    @property
    def tpot_s(self) -> Optional[float]:
        if self.first_token_s is None or self.finished_s is None:
            return None
        n_after_first = max(self.output_tokens - 1, 1)
        return (self.finished_s - self.first_token_s) / n_after_first

    @property
    def e2e_s(self) -> Optional[float]:
        if self.finished_s is None:
            return None
        return self.finished_s - self.arrival_s

    @property
    def ttft_attained(self) -> Optional[bool]:
        """Whether the request met its TTFT objective (None = no SLO)."""
        if self.slo_ttft_s is None:
            return None
        return self.ttft_s is not None and self.ttft_s <= self.slo_ttft_s

    @property
    def tpot_attained(self) -> Optional[bool]:
        if self.slo_tpot_s is None:
            return None
        return self.tpot_s is not None and self.tpot_s <= self.slo_tpot_s

    def to_dict(self) -> dict:
        d = dataclasses.asdict(self)
        d["ttft_s"] = self.ttft_s
        d["tpot_s"] = self.tpot_s
        d["e2e_s"] = self.e2e_s
        return d


def percentiles(xs: Sequence[float],
                ps: Sequence[float] = (50, 90, 99)
                ) -> Dict[str, Optional[float]]:
    """Linear-interpolated percentiles as {"p50": ..., "p90": ...}.

    An empty input yields ``None`` values (JSON ``null``), never NaN:
    NaN survives a round-trip through ``json`` as the non-standard token
    ``NaN`` and — worse — compares unequal to itself, so a regression
    gate diffing two NaN-bearing payloads would silently pass. ``None``
    fails loudly instead."""
    if not xs:
        return {f"p{_fmt(p)}": None for p in ps}
    vals = np.percentile([float(x) for x in xs], list(ps))
    return {f"p{_fmt(p)}": float(v) for p, v in zip(ps, vals)}


def _fmt(p: float) -> str:
    return str(int(p)) if float(p).is_integer() else str(p)


def summarize(metrics: Sequence[RequestMetrics],
              ps: Sequence[float] = (50, 90, 99)) -> dict:
    """Aggregate request metrics into the BENCH_serving.json payload."""
    done = [m for m in metrics if m.finished_s is not None]
    ttfts = [m.ttft_s for m in done if m.ttft_s is not None]
    tpots = [m.tpot_s for m in done if m.tpot_s is not None]
    e2es = [m.e2e_s for m in done]
    span = (max((m.finished_s for m in done), default=0.0)
            - min((m.arrival_s for m in metrics), default=0.0))
    total_tokens = sum(m.output_tokens for m in done)
    total_prompt = sum(m.prompt_tokens for m in metrics)
    total_cached = sum(m.cached_tokens for m in metrics)
    return {
        "num_requests": len(metrics),
        "num_completed": len(done),
        "total_output_tokens": total_tokens,
        "makespan_s": span,
        "throughput_tok_per_s": total_tokens / span if span > 0 else 0.0,
        "throughput_req_per_s": len(done) / span if span > 0 else 0.0,
        "ttft_s": percentiles(ttfts, ps),
        "tpot_s": percentiles(tpots, ps),
        "e2e_s": percentiles(e2es, ps),
        "mean_ttft_s": _mean(ttfts),
        "mean_tpot_s": _mean(tpots),
        "mean_e2e_s": _mean(e2es),
        "total_wait_s": sum(m.wait_s for m in metrics),
        "total_prefill_s": sum(m.prefill_s for m in metrics),
        "total_decode_s": sum(m.decode_s for m in metrics),
        "num_pruned": sum(m.num_pruned for m in metrics),
        "num_preemptions": sum(m.num_preemptions for m in metrics),
        "total_prompt_tokens": total_prompt,
        "total_cached_tokens": total_cached,
        "prefix_hit_rate": (total_cached / total_prompt
                            if total_prompt > 0 else 0.0),
        "requests_with_prefix_hit": sum(
            m.cached_tokens > 0 for m in metrics),
        "degraded_traces": sum(m.degraded_traces for m in metrics),
        "num_cancelled": sum(m.status == "cancelled" for m in metrics),
        "num_deadline_exceeded": sum(
            m.status == "deadline_exceeded" for m in metrics),
        "num_failed": sum(m.status == "failed" for m in metrics),
        "failed_traces": sum(m.failed_traces for m in metrics),
        "slo": _slo_attainment(metrics),
    }


def _slo_attainment(metrics: Sequence[RequestMetrics]) -> dict:
    """SLO attainment over the requests that carry an objective. A shed
    request (every trace dropped by admission control, so it never
    produced a first token) counts as a TTFT miss — shedding is a
    capacity decision, not an excuse."""
    ttft_j = [m.ttft_attained for m in metrics
              if m.ttft_attained is not None]
    tpot_j = [m.tpot_attained for m in metrics
              if m.tpot_attained is not None]
    return {
        "requests_with_slo": sum(
            m.slo_ttft_s is not None or m.slo_tpot_s is not None
            for m in metrics),
        "ttft_attainment": (sum(ttft_j) / len(ttft_j)
                            if ttft_j else None),
        "tpot_attainment": (sum(tpot_j) / len(tpot_j)
                            if tpot_j else None),
    }


def summarize_by_tenant(metrics: Sequence[RequestMetrics],
                        ps: Sequence[float] = (50, 90, 99)) -> dict:
    """Per-tenant breakdown of ``summarize`` (the BENCH_slo.json
    payload): tenants are compared on the same percentile table, plus
    their SLO attainment."""
    tenants: Dict[str, list] = {}
    for m in metrics:
        tenants.setdefault(m.tenant, []).append(m)
    return {name: summarize(ms, ps) for name, ms in sorted(tenants.items())}


def _mean(xs: Sequence[float]) -> Optional[float]:
    """Mean, or ``None`` for an empty input (same NaN-avoidance
    rationale as ``percentiles``)."""
    return sum(xs) / len(xs) if xs else None
