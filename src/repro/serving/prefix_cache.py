"""Cross-request prefix cache: a radix tree over block-aligned token
prefixes, parking completed prompts' KV blocks for zero-recompute reuse.

The paper's bottleneck is KV pressure: traces are pruned when the paged
pool saturates. Every byte of KV reused ACROSS requests (system prompts,
few-shot templates, multi-turn conversation prefixes) is pruning
pressure avoided, so the engine keeps a trie keyed by ``block_size``
token chunks on top of the refcounted ``BlockManager``:

  * On request arrival the engine walks the trie for the longest cached
    block-aligned strict prefix of the prompt (``match``), forks the
    matched blocks via the existing COW path (refcount++, zero device
    work) and chunk-prefills only the suffix.
  * On request completion the prompt's FULL blocks are inserted into the
    trie instead of freed (``insert``): the cache takes over the
    holder's references, so the blocks stay live at refcount >= 1 and
    pristine (the holder never writes; traces always COW before their
    first private write).
  * Under memory pressure the engine reclaims least-recently-used
    cache-only blocks (``evict``) BEFORE consulting the pruning policy:
    evict-before-prune, because a cached block is a reuse opportunity
    while a live trace is paid-for compute.

Partial tail blocks are never cached: ``match`` stops at
``(len(prompt) - 1) // block_size`` chunks (at least one prompt token is
always left to prefill — its logits seed the first sampled token) and
``insert`` parks only ``len(prompt) // block_size`` full blocks. A tail
block holds fewer than ``block_size`` valid KV rows and is written by
the request's own prefill, so sharing it would serve stale rows.

The cache never touches device memory; like the allocator it only moves
ownership. A cached block's KV bytes were written by a completed prefill
of the identical token prefix, which is why a hit is bit-identical to
recomputing the prefix (pinned in tests/test_prefix_cache.py).
"""
from __future__ import annotations

import dataclasses
from typing import Dict, Iterator, List, Optional, Sequence, Tuple

from repro.serving.kv_manager import BlockManager


class _Node:
    """One trie edge/node: ``key`` is the block's token chunk, ``block``
    the physical block id (the cache holds exactly one reference)."""

    __slots__ = ("key", "block", "parent", "children", "last_used")

    def __init__(self, key: Optional[tuple], block: Optional[int],
                 parent: Optional["_Node"]):
        self.key = key
        self.block = block
        self.parent = parent
        self.children: Dict[tuple, _Node] = {}
        self.last_used = 0


@dataclasses.dataclass
class CacheStats:
    """Cumulative hit/occupancy counters (engine lifetime)."""

    lookups: int = 0
    hits: int = 0            # lookups matching >= 1 block
    misses: int = 0
    hit_tokens: int = 0      # prompt tokens served straight from cache
    inserted_blocks: int = 0
    evicted_blocks: int = 0


class PrefixCache:
    """Radix-tree index of parked prompt KV blocks over a BlockManager.

    LRU bookkeeping uses a deterministic monotonic clock (not wall
    time), so eviction order — and therefore scheduling — is a pure
    function of the operation history.
    """

    def __init__(self, mgr: BlockManager):
        self.mgr = mgr
        self.block_size = mgr.block_size
        self.root = _Node(None, None, None)
        self.stats = CacheStats()
        self._clock = 0
        self._num_blocks = 0

    # ------------------------------------------------------------------
    # occupancy
    # ------------------------------------------------------------------
    @property
    def cached_blocks(self) -> int:
        """Blocks currently parked in the trie."""
        return self._num_blocks

    @property
    def evictable_blocks(self) -> int:
        """Parked blocks only the cache references (refcount 1): the
        amount ``evict`` could return to the free list right now."""
        return sum(1 for n in self._nodes()
                   if self.mgr.ref_count(n.block) == 1)

    def blocks(self) -> Iterator[int]:
        """Physical block ids currently parked in the trie."""
        for node in self._nodes():
            yield node.block

    def _nodes(self) -> Iterator[_Node]:
        stack = list(self.root.children.values())
        while stack:
            node = stack.pop()
            stack.extend(node.children.values())
            yield node

    def _chunks(self, tokens: Sequence[int], n: int) -> List[tuple]:
        bs = self.block_size
        return [tuple(tokens[i * bs:(i + 1) * bs]) for i in range(n)]

    # ------------------------------------------------------------------
    # lookup / insert
    # ------------------------------------------------------------------
    def match(self, tokens: Sequence[int]) -> Tuple[List[int], int]:
        """Longest cached block-aligned strict prefix of ``tokens``.

        Returns ``(blocks, n_tokens)``. The match is capped at
        ``(len(tokens) - 1) // block_size`` chunks so at least one
        prompt token always remains to prefill (its logits seed the
        first sampled token). The caller must ``mgr.fork`` the returned
        blocks before using them; until then they are only pinned by the
        cache's own reference.
        """
        limit = max(len(tokens) - 1, 0) // self.block_size
        self._clock += 1
        node, blocks = self.root, []
        for key in self._chunks(tokens, limit):
            child = node.children.get(key)
            if child is None:
                break
            child.last_used = self._clock  # stamp the whole matched path
            blocks.append(child.block)
            node = child
        self.stats.lookups += 1
        if blocks:
            self.stats.hits += 1
            self.stats.hit_tokens += len(blocks) * self.block_size
        else:
            self.stats.misses += 1
        return blocks, len(blocks) * self.block_size

    def insert(self, tokens: Sequence[int], blocks: Sequence[int]) -> int:
        """Park a completed prompt's full-block KV in the trie.

        ``blocks`` are the holder's references covering the prompt's
        full blocks in order (the partial tail block must NOT be
        passed). Ownership transfer per chunk: a chunk with no trie node
        yet moves the caller's reference into the cache; a chunk already
        cached (same or different physical block) drops the caller's
        duplicate reference via ``mgr.free``. Either way the caller owns
        nothing afterwards. Returns the number of newly parked blocks.
        """
        n = min(len(tokens) // self.block_size, len(blocks))
        self._clock += 1
        node, new = self.root, 0
        for i, key in enumerate(self._chunks(tokens, n)):
            child = node.children.get(key)
            if child is None:
                child = _Node(key, blocks[i], node)
                node.children[key] = child
                self._num_blocks += 1
                new += 1
            else:
                # duplicate coverage of this chunk: the cache keeps its
                # existing block, the caller's reference is dropped
                self.mgr.free([blocks[i]])
            child.last_used = self._clock
            node = child
        self.stats.inserted_blocks += new
        return new

    # ------------------------------------------------------------------
    # eviction
    # ------------------------------------------------------------------
    def evict(self, n_blocks: int) -> int:
        """Return up to ``n_blocks`` LRU cache-only blocks to the free
        list (leaf-first, so a cold subtree unwinds bottom-up). Blocks
        some request still references (refcount > 1) are pinned and
        skipped. Returns the number of blocks actually freed."""
        freed = 0
        while freed < n_blocks:
            victim = None
            for node in self._nodes():
                if node.children or self.mgr.ref_count(node.block) != 1:
                    continue
                if victim is None or node.last_used < victim.last_used:
                    victim = node
            if victim is None:
                break
            self.mgr.free([victim.block])
            del victim.parent.children[victim.key]
            self._num_blocks -= 1
            freed += 1
        self.stats.evicted_blocks += freed
        return freed

    def clear(self) -> int:
        """Drop every parked block (benchmark warmup isolation). Blocks
        still referenced elsewhere survive with the other references;
        cache-only blocks return to the free list."""
        dropped = 0
        for node in list(self._nodes()):
            self.mgr.free([node.block])
            dropped += 1
        self.root.children.clear()
        self._num_blocks = 0
        return dropped

    # ------------------------------------------------------------------
    def check_integrity(self) -> None:
        """Trie-side invariants, in the spirit of
        ``BlockManager.check_invariants``."""
        seen = 0
        block_ids = set()
        for node in self._nodes():
            assert node.key is not None and len(node.key) == self.block_size
            assert self.mgr.ref_count(node.block) >= 1, \
                f"cached block {node.block} is dead"
            assert node.parent.children.get(node.key) is node
            assert node.block not in block_ids, \
                f"block {node.block} parked under two trie nodes"
            block_ids.add(node.block)
            seen += 1
        assert seen == self._num_blocks, \
            f"cached_blocks={self._num_blocks} but trie holds {seen}"
