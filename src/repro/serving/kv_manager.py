"""Paged KV block manager (vLLM-style, host-side allocator).

The device-side pool is a statically allocated JAX array sized to the HBM
budget; this manager hands out block ids. "GPU memory full" in the paper
== "free list empty at schedule time" here (see DESIGN.md §3).

Block 0 is reserved as a scratch block: dead decode slots point their
block tables at it so a fixed-shape batched decode step can run without
corrupting live sequences.

Prefix sharing (vLLM-style copy-on-write): every live block carries a
reference count. ``fork(blocks)`` hands the same physical blocks to a
second logical sequence by incrementing the counts; ``free`` decrements
and only returns a block to the free list when its count reaches zero.
A writer must hold a block exclusively — the engine checks
``is_shared`` before the next token's KV write and, if the block is
shared, allocates a fresh block, device-copies the contents, and drops
its reference on the original (the COW step). The allocator itself
never touches device memory; it only tracks ownership.

Chunk-granular reservation (continuous batching / chunked prefill): an
in-flight prompt prefill draws its blocks chunk by chunk through a
``Reservation`` instead of allocating the whole prompt up front. Blocks
already taken hold completed chunks' KV; ``take`` extends the holding as
later chunks are computed; ``abort`` returns everything to the pool if
the prefill is cancelled under memory pressure; ``commit`` transfers
ownership of the full set to the caller (the shared-prefix holder).

Cross-request prefix caching (``serving/prefix_cache.py``) layers on the
same refcounts: a completed request's full prompt blocks are PARKED —
the cache keeps one reference per block instead of freeing it — so a
later request with the same token prefix forks them (refcount++) with
zero recompute. Parked blocks at refcount 1 are reclaimed LRU-first
under memory pressure, before any live trace is pruned or preempted.
The allocator needs no new machinery for this; the cache is just
another reference holder.
"""
from __future__ import annotations

import dataclasses
from typing import Callable, Dict, List, Optional, Set


@dataclasses.dataclass
class BlockManager:
    num_blocks: int
    block_size: int
    # HBM bytes one block occupies on device (pool storage across all
    # attention layers, plus per-page scales for quantized pools). 0 =
    # unknown; the engine passes kv_quant.pool_block_bytes so pressure
    # snapshots can report real bytes, not just block counts.
    bytes_per_block: int = 0

    def __post_init__(self):
        assert self.num_blocks >= 2
        self._free: List[int] = list(range(1, self.num_blocks))  # 0=scratch
        self._free_set = set(self._free)  # O(1) membership / double-free check
        self._refcounts: Dict[int, int] = {}  # block id -> refs (live only)
        self._open_reservations: Set["Reservation"] = set()  # not yet closed
        # fault injection: when set, a True return vetoes the allocation
        # (the allocator reports "full" without touching state)
        self.fault_hook: Optional[Callable[[int], bool]] = None

    @property
    def scratch_block(self) -> int:
        return 0

    @property
    def free_blocks(self) -> int:
        return len(self._free)

    @property
    def used_blocks(self) -> int:
        return (self.num_blocks - 1) - len(self._free)

    @property
    def utilization(self) -> float:
        return self.used_blocks / (self.num_blocks - 1)

    @property
    def free_bytes(self) -> int:
        return self.free_blocks * self.bytes_per_block

    @property
    def used_bytes(self) -> int:
        return self.used_blocks * self.bytes_per_block

    def blocks_for_tokens(self, n_tokens: int) -> int:
        return -(-n_tokens // self.block_size)

    def can_allocate(self, n_blocks: int) -> bool:
        if self.fault_hook is not None and self.fault_hook(n_blocks):
            return False
        return len(self._free) >= n_blocks

    def allocate(self, n_blocks: int) -> Optional[List[int]]:
        if not self.can_allocate(n_blocks):
            return None
        out = self._free[:n_blocks]
        del self._free[:n_blocks]
        for b in out:
            self._free_set.discard(b)
            self._refcounts[b] = 1
        return out

    def fork(self, blocks: List[int]) -> List[int]:
        """Share ``blocks`` with one more logical sequence (refcount += 1).

        Returns a fresh list of the same physical block ids; the caller
        owns one reference per id and releases it through ``free``.
        """
        for b in blocks:
            assert self._refcounts.get(b, 0) > 0, f"fork of dead block {b}"
            self._refcounts[b] += 1
        return list(blocks)

    def ref_count(self, block: int) -> int:
        return self._refcounts.get(block, 0)

    def is_shared(self, block: int) -> bool:
        return self._refcounts.get(block, 0) > 1

    def free(self, blocks: List[int]) -> None:
        """Drop one reference per block; release at refcount zero."""
        for b in blocks:
            assert b != 0 and b not in self._free_set, f"double free of {b}"
            refs = self._refcounts.get(b, 0)
            assert refs > 0, f"free of unallocated block {b}"
            if refs > 1:
                self._refcounts[b] = refs - 1
            else:
                del self._refcounts[b]
                self._free.append(b)
                self._free_set.add(b)

    def reserve(self, total_blocks: int) -> "Reservation":
        """Open a chunk-granular reservation for ``total_blocks`` blocks.

        Nothing is allocated yet; the caller draws blocks incrementally
        with ``Reservation.take`` as prefill chunks complete.
        """
        return Reservation(self, total_blocks)

    def check_invariants(self) -> None:
        assert len(set(self._free)) == len(self._free)
        assert self._free_set == set(self._free)
        assert all(1 <= b < self.num_blocks for b in self._free)
        assert all(r > 0 for r in self._refcounts.values())
        # every non-scratch block is exactly one of {free, live}
        assert not (self._free_set & self._refcounts.keys())
        assert len(self._free) + len(self._refcounts) == self.num_blocks - 1

    @property
    def open_reservations(self) -> int:
        return len(self._open_reservations)

    def check_integrity(self, expect_open_reservations: int = 0) -> None:
        """Post-fault invariant audit: refcount conservation plus no
        orphaned (never-closed) reservations. Cheap enough to run after
        every fault/cancel path."""
        self.check_invariants()
        assert len(self._open_reservations) == expect_open_reservations, \
            (f"{len(self._open_reservations)} reservation(s) left open "
             f"(expected {expect_open_reservations}) — an exception path "
             f"skipped commit/abort")


class Reservation:
    """Incremental block holding for an in-flight (chunked) prefill.

    Lifecycle: ``take`` zero or more times (each call either allocates
    the requested blocks or, when the free list is short, takes nothing
    and returns None so the caller can apply memory pressure), then
    exactly one of ``commit`` (ownership moves to the caller) or
    ``abort`` (blocks return to the pool). A reservation never holds
    more than ``total_blocks``.
    """

    def __init__(self, mgr: BlockManager, total_blocks: int):
        assert total_blocks >= 0
        self.mgr = mgr
        self.total_blocks = total_blocks
        self.taken: List[int] = []
        self._closed = False
        mgr._open_reservations.add(self)

    @property
    def num_taken(self) -> int:
        return len(self.taken)

    @property
    def remaining(self) -> int:
        return self.total_blocks - len(self.taken)

    def take(self, n_blocks: int) -> Optional[List[int]]:
        """Draw ``n_blocks`` more blocks; all-or-nothing per call."""
        assert not self._closed, "take on a closed reservation"
        assert n_blocks <= self.remaining, "reservation overdraw"
        if n_blocks == 0:
            return []
        got = self.mgr.allocate(n_blocks)
        if got is None:
            return None
        self.taken.extend(got)
        return got

    def commit(self) -> List[int]:
        """Close the reservation; the caller now owns the taken blocks."""
        assert not self._closed
        self._closed = True
        self.mgr._open_reservations.discard(self)
        out = self.taken
        self.taken = []
        return out

    def abort(self) -> None:
        """Cancel: return every taken block to the pool."""
        assert not self._closed
        self._closed = True
        self.mgr._open_reservations.discard(self)
        if self.taken:
            self.mgr.free(self.taken)
            self.taken = []
