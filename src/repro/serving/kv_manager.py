"""Paged KV block manager (vLLM-style, host-side allocator).

The device-side pool is a statically allocated JAX array sized to the HBM
budget; this manager hands out block ids. "GPU memory full" in the paper
== "free list empty at schedule time" here (see DESIGN.md §3).

Block 0 is reserved as a scratch block: dead decode slots point their
block tables at it so a fixed-shape batched decode step can run without
corrupting live sequences.
"""
from __future__ import annotations

import dataclasses
from typing import List, Optional


@dataclasses.dataclass
class BlockManager:
    num_blocks: int
    block_size: int

    def __post_init__(self):
        assert self.num_blocks >= 2
        self._free: List[int] = list(range(1, self.num_blocks))  # 0=scratch
        self._allocated = 0

    @property
    def scratch_block(self) -> int:
        return 0

    @property
    def free_blocks(self) -> int:
        return len(self._free)

    @property
    def used_blocks(self) -> int:
        return (self.num_blocks - 1) - len(self._free)

    @property
    def utilization(self) -> float:
        return self.used_blocks / (self.num_blocks - 1)

    def blocks_for_tokens(self, n_tokens: int) -> int:
        return -(-n_tokens // self.block_size)

    def can_allocate(self, n_blocks: int) -> bool:
        return len(self._free) >= n_blocks

    def allocate(self, n_blocks: int) -> Optional[List[int]]:
        if not self.can_allocate(n_blocks):
            return None
        out = self._free[:n_blocks]
        del self._free[:n_blocks]
        return out

    def free(self, blocks: List[int]) -> None:
        for b in blocks:
            assert b != 0 and b not in self._free, f"double free of block {b}"
            self._free.append(b)

    def check_invariants(self) -> None:
        assert len(set(self._free)) == len(self._free)
        assert all(1 <= b < self.num_blocks for b in self._free)
