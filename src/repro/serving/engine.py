"""The serving engine: vLLM-V1-style continuous batching in JAX.

This is the system layer the STEP paper modifies. One engine instance holds
a statically allocated paged KV pool (the per-device HBM budget), a block
manager (the allocator whose free list defines "GPU memory full"), and a
fixed-shape jitted decode step over ``max_batch`` slots.

Scheduling semantics (paper §3, §4.2):

  * baseline engines (SC / CoT / Slim-SC / DeepConf): when the next decode
    step cannot be scheduled because the pool has no free block, a running
    trace is PREEMPTED vLLM-style — its blocks are freed and it re-enters
    the waiting queue; on resume its KV cache is RECOMPUTED (discard-and-
    recompute). The waiting queue is where the paper's 40% latency goes.
  * STEP: the policy returns the lowest-scored trace; the engine PRUNES it
    and immediately reuses its blocks. The waiting queue never forms.

Prefix sharing (``EngineConfig.share_prompt_prefix``, default on): all N
traces of a request decode from the *same* prompt, so the prompt KV is
computed once per request, written into shared paged blocks, and forked
into each trace's block table with refcounting. The first time a trace
writes into a still-shared block (its first generated token lands in the
prompt's partial tail block) the engine copy-on-writes that block. With
the flag off the engine reproduces the original per-trace prefill path
(N sequential prompt prefills), which is the accounting baseline for
Table 3.

Multi-request scheduling: ``serve_batch`` admits traces from a queue of
requests into one shared decode batch; traces from different requests
co-exist in the fixed-shape decode step, contend for the same block pool,
and are aggregated into per-request ``RequestResult``s. Policies act per
request: the needy trace's own request's policy decides what to prune;
baseline preemption (last-arrived running trace) is global, like vLLM's
latest-arrival eviction.

Latency accounting mirrors the paper's Table 3: every wall-clock second of
the engine loop is attributed to {prefill, decode, overhead}; every second
a trace spends runnable-but-not-running (queued after preemption, or
queued at admission because memory was full) is WAIT. Decode seconds of
the shared batched step are attributed to requests proportionally to
their running traces. Waiting for a free decode *slot* (queue longer than
``max_batch``) is not memory-induced and is not counted as WAIT.
"""
from __future__ import annotations

import copy
import dataclasses
import time
from functools import partial
from typing import Dict, List, Optional, Sequence, Tuple

import jax
import jax.numpy as jnp
import numpy as np

from repro.configs.base import ModelConfig
from repro.core.pruning import DeepConfPolicy, PruningPolicy
from repro.data.arithmetic import extract_answer
from repro.core.scorer import scorer_score
from repro.core.trace import Trace, TraceStatus
from repro.data.tokenizer import get_tokenizer
from repro.models.model import (copy_kv_block, decode_step, forward_full,
                                init_decode_cache, write_prefill_kv)
from repro.serving.kv_manager import BlockManager
from repro.serving.sampling import SamplingParams, sample_tokens


@dataclasses.dataclass
class EngineConfig:
    """Static engine resources (the 'GPU')."""
    max_batch: int = 64            # decode slots (>= trace budget N)
    num_blocks: int = 128          # paged pool blocks incl. scratch
    capacity: int = 512            # per-sequence token capacity (window)
    max_new_tokens: int = 160
    sampling: SamplingParams = dataclasses.field(default_factory=SamplingParams)
    use_kernel: bool = False
    seed: int = 0
    # Prefill the prompt once per request and fork its blocks into every
    # trace (COW on first trace-private write). False restores the
    # original per-trace prefill path (the Table-3 accounting baseline).
    share_prompt_prefix: bool = True


@dataclasses.dataclass
class Request:
    """One unit of work for the scheduler: a prompt and a trace budget.

    ``policy`` overrides the engine-level policy for this request; pass a
    fresh instance per request when the policy is stateful (DeepConf's
    warmup threshold, Slim-SC's check cursor) and requests run
    concurrently. When left None in a multi-request batch, the engine
    deep-copies its default policy per request for the same reason.
    """
    request_id: int
    prompt_tokens: List[int]
    n_traces: int
    policy: Optional[PruningPolicy] = None


@dataclasses.dataclass
class RequestResult:
    request_id: int
    answer: Optional[str]
    traces: List[Trace]
    latency_s: float
    total_tokens: int
    wait_s: float
    decode_s: float
    prefill_s: float
    num_pruned: int
    num_preemptions: int
    peak_blocks_used: int = 0  # pool-wide peak during this request's batch


@dataclasses.dataclass
class _SharedPrefix:
    """Per-request artifact of the one-shot prompt prefill."""
    blocks: List[int]           # holder's own references (freed at req end)
    seq_len: int
    last_logits: jax.Array      # [1, Vp] vocab-masked last-position logits
    slot_state: Optional[tuple]  # (ssm, conv) end state for ssm/hybrid


class _ReqState:
    """Scheduler-side bookkeeping for one in-flight request."""

    def __init__(self, req: Request, policy: PruningPolicy,
                 traces: List[Trace]):
        self.req = req
        self.policy = policy
        self.traces = traces
        self.prefix: Optional[_SharedPrefix] = None
        self.prefill_s = 0.0
        self.decode_s = 0.0
        self.t_done: Optional[float] = None
        self.warmup_recorded = not isinstance(policy, DeepConfPolicy)

    @property
    def request_id(self) -> int:
        return self.req.request_id

    def admissible(self, trace: Trace) -> bool:
        """DeepConf online: traces beyond the warmup set wait until the
        warmup traces finished and the threshold exists."""
        if self.warmup_recorded:
            return True
        return trace.trace_id < self.policy.warmup

    def update_gate(self) -> None:
        if self.warmup_recorded:
            return
        warm = self.traces[:self.policy.warmup]
        if all(not t.alive for t in warm):
            self.policy.record_warmup(
                [t for t in warm if t.status == TraceStatus.FINISHED])
            self.warmup_recorded = True

    def done(self) -> bool:
        return all(not t.alive for t in self.traces)


class Engine:
    """Continuous-batching engine over a queue of requests, each fanning
    out into N parallel traces (the paper's setting: one problem, N=64
    traces — ``serve``; cross-request contention — ``serve_batch``)."""

    def __init__(self, params: dict, cfg: ModelConfig, ecfg: EngineConfig,
                 policy: PruningPolicy,
                 scorer_params: Optional[dict] = None):
        self.params = params
        self.cfg = cfg
        self.ecfg = ecfg
        self.policy = policy
        self.scorer_params = scorer_params
        self.tok = get_tokenizer()
        bs = cfg.kv_block_size
        self.blocks_per_seq = -(-ecfg.capacity // bs)
        self.block_mgr = BlockManager(ecfg.num_blocks, bs)
        self._rng = jax.random.PRNGKey(ecfg.seed)
        self._build_steps()

    # ------------------------------------------------------------------
    # jitted steps
    # ------------------------------------------------------------------
    def _build_steps(self):
        cfg, ecfg = self.cfg, self.ecfg
        has_scorer = self.scorer_params is not None
        sp = ecfg.sampling

        V = cfg.vocab_size  # mask vocab padding out of the sampler

        @partial(jax.jit, donate_argnums=(1,))
        def batched_decode(params, cache, tokens, positions, block_tables,
                           rng, scorer_params):
            cache = dict(cache)
            cache["block_tables"] = block_tables
            out = decode_step(params, cfg, tokens, positions, cache,
                              window_len=ecfg.capacity,
                              use_kernel=ecfg.use_kernel)
            logits = out["logits"].at[:, V:].set(-jnp.inf)
            new_tokens, conf = sample_tokens(
                rng, logits, temperature=sp.temperature,
                top_k=sp.top_k, top_p=sp.top_p)
            if has_scorer:
                scores = scorer_score(scorer_params, out["hidden"])
            else:
                scores = jnp.zeros((tokens.shape[0],), jnp.float32)
            new_cache = out["cache"]
            new_cache.pop("block_tables", None)
            return new_tokens, conf, scores, new_cache

        self._decode = batched_decode

        @jax.jit
        def prefill(params, tokens):
            out = forward_full(params, cfg, tokens, return_kv=True)
            logits = out["logits"].at[..., V:].set(-jnp.inf)
            return logits, out["kvs"]

        self._prefill = prefill

        # COW block copy: pool[:, dst] = pool[:, src], one jitted instance
        # for all block pairs (src/dst are traced scalars).
        self._copy_block = jax.jit(partial(copy_kv_block, cfg),
                                   donate_argnums=(0,))

    # ------------------------------------------------------------------
    # cache plumbing
    # ------------------------------------------------------------------
    def _init_cache(self):
        """Shared pool sized to the engine budget (not per-sequence)."""
        cache = init_decode_cache(
            self.cfg, self.ecfg.max_batch, self.ecfg.capacity,
            num_blocks=self.ecfg.num_blocks)
        cache.pop("block_tables", None)
        return cache

    def _split_prefill_kvs(self, kvs) -> Tuple[Optional[tuple],
                                               Optional[tuple]]:
        """Split forward_full(return_kv=True) output for a batch-1 prefill
        into (paged attention KV | None, per-slot recurrent state | None).
        """
        cfg = self.cfg
        if cfg.arch_type == "ssm":
            ss, cs = kvs
            return None, (ss[:, 0], cs[:, 0])
        if cfg.arch_type == "hybrid":
            (ss, cs), (k, v) = kvs
            ssf = ss.reshape(-1, *ss.shape[2:])
            csf = cs.reshape(-1, *cs.shape[2:])
            return (k[:, :1], v[:, :1]), (ssf[:, 0], csf[:, 0])
        if cfg.use_mla:
            return kvs[:, :1], None
        k, v = kvs
        return (k[:, :1], v[:, :1]), None

    def _write_prefix_kv(self, cache: dict, attn_kvs, block_row: np.ndarray,
                         seq_len: int) -> dict:
        """Write prompt KV into the paged pools ONCE for a block row.

        With prefix sharing this runs once per request; every trace then
        reads these blocks through its forked block table.
        """
        if attn_kvs is None:
            return cache
        cfg = self.cfg
        bt = jnp.asarray(block_row[None, :], jnp.int32)  # [1, bp]
        lens = jnp.full((1,), seq_len, jnp.int32)
        if cfg.use_mla:
            sub = {"kv_pool": cache["kv_pool"], "block_tables": bt}
            sub = write_prefill_kv(cfg, sub, attn_kvs, lens)
            cache["kv_pool"] = sub["kv_pool"]
            return cache
        k, v = attn_kvs
        sub = {"k_pool": cache["k_pool"], "v_pool": cache["v_pool"],
               "block_tables": bt}
        sub = write_prefill_kv(cfg, sub, (k, v), lens)
        cache["k_pool"], cache["v_pool"] = sub["k_pool"], sub["v_pool"]
        return cache

    def _write_slot_state(self, cache: dict, slot_state, slot: int) -> dict:
        """Scatter recurrent (SSM/conv) prefill end-state into one slot."""
        if slot_state is None:
            return cache
        ss, cs = slot_state
        cache["ssm_state"] = cache["ssm_state"].at[:, slot].set(ss)
        cache["conv_state"] = cache["conv_state"].at[:, slot].set(cs)
        return cache

    def _write_prefill(self, cache: dict, kvs, slot: int,
                       block_row: np.ndarray, seq_len: int) -> dict:
        """Scatter one trace's prefill KV/state into the shared pool."""
        attn_kvs, slot_state = self._split_prefill_kvs(kvs)
        cache = self._write_prefix_kv(cache, attn_kvs, block_row, seq_len)
        return self._write_slot_state(cache, slot_state, slot)

    def _clear_slot_state(self, cache: dict, slot: int) -> dict:
        if "ssm_state" in cache:
            cache["ssm_state"] = cache["ssm_state"].at[:, slot].set(0.0)
            cache["conv_state"] = cache["conv_state"].at[:, slot].set(0.0)
        return cache

    # ------------------------------------------------------------------
    # request serving
    # ------------------------------------------------------------------
    def serve(self, prompt_tokens: List[int], n_traces: int,
              request_id: int = 0) -> RequestResult:
        """Generate ``n_traces`` parallel traces for one prompt."""
        assert n_traces <= self.ecfg.max_batch, "engine sized per trace budget"
        req = Request(request_id=request_id,
                      prompt_tokens=list(prompt_tokens),
                      n_traces=n_traces, policy=self.policy)
        return self.serve_batch([req])[0]

    def serve_batch(self, requests: Sequence[Request]) -> List[RequestResult]:
        """Serve a queue of requests through one shared decode batch.

        Total traces may exceed ``max_batch``: surplus traces wait for a
        free decode slot. Block-pool contention is cross-request; each
        request's own policy governs pruning of its traces.
        """
        t_start = time.perf_counter()
        states: List[_ReqState] = []
        for req in requests:
            if req.policy is not None:
                policy = req.policy
            elif len(requests) == 1:
                policy = self.policy
            else:
                # stateful policies (DeepConf threshold, Slim-SC cursors)
                # must not leak between concurrent requests: give each
                # request its own copy of the engine-level default
                policy = copy.deepcopy(self.policy)
            if isinstance(policy, DeepConfPolicy):
                policy.threshold = None  # fresh threshold per request
            traces = [Trace(trace_id=i, request_id=req.request_id,
                            prompt_tokens=list(req.prompt_tokens))
                      for i in range(req.n_traces)]
            states.append(_ReqState(req, policy, traces))

        peak_blocks = self._run_scheduler(states)

        t_end = time.perf_counter()
        results = []
        for st in states:
            finished = [t for t in st.traces
                        if t.status == TraceStatus.FINISHED]
            answer = st.policy.vote(finished) if finished else None
            done = st.t_done if st.t_done is not None else t_end
            results.append(RequestResult(
                request_id=st.request_id, answer=answer, traces=st.traces,
                latency_s=done - t_start,
                total_tokens=sum(t.num_tokens for t in st.traces),
                wait_s=sum(t.wait_time for t in st.traces),
                decode_s=st.decode_s, prefill_s=st.prefill_s,
                num_pruned=sum(t.status == TraceStatus.PRUNED
                               for t in st.traces),
                num_preemptions=sum(max(t.prefill_count - 1, 0)
                                    for t in st.traces),
                peak_blocks_used=peak_blocks,
            ))
        return results

    # ------------------------------------------------------------------
    def _run_scheduler(self, states: List[_ReqState]) -> int:
        """Run every request's traces to completion/pruning. Returns the
        pool-wide peak block usage."""
        ecfg, cfg, tok = self.ecfg, self.cfg, self.tok
        B = ecfg.max_batch
        bs = cfg.kv_block_size
        cap = ecfg.capacity
        share = ecfg.share_prompt_prefix
        mgr = self.block_mgr
        cache = self._init_cache()
        by_req: Dict[int, _ReqState] = {st.request_id: st for st in states}
        assert len(by_req) == len(states), "duplicate request_id in batch"

        block_tables = np.zeros((B, self.blocks_per_seq), np.int32)
        positions = np.zeros((B,), np.int32)
        cur_tokens = np.zeros((B,), np.int32)
        free_slots = list(range(B))
        running: List[Trace] = []
        waiting: List[Trace] = []
        for st in states:
            for t in st.traces:
                t.status = TraceStatus.WAITING
                # wait_time counts only MEMORY-induced waiting (paper
                # Table 3): the clock starts at preemption or at a
                # memory-blocked admission attempt, not at submission.
                t.runnable_since = -1.0
            waiting.extend(st.traces)

        peak_blocks = 0

        def note_peak():
            nonlocal peak_blocks
            peak_blocks = max(peak_blocks, mgr.used_blocks)

        def release_prefix(st: _ReqState):
            if st.prefix is not None:
                mgr.free(st.prefix.blocks)
                st.prefix = None

        def release(trace: Trace, status: TraceStatus):
            nonlocal cache
            if trace.blocks:
                mgr.free(trace.blocks)
                trace.blocks = []
            if trace.batch_slot >= 0:
                s = trace.batch_slot
                block_tables[s, :] = mgr.scratch_block
                positions[s] = 0
                cache = self._clear_slot_state(cache, s)
                free_slots.append(s)
                trace.batch_slot = -1
            trace.status = status
            if trace in running:
                running.remove(trace)
            st = by_req[trace.request_id]
            if st.done():
                release_prefix(st)
                if st.t_done is None:
                    st.t_done = time.perf_counter()

        def reclaim_idle_prefix(skip_rid: int) -> bool:
            """Free shared-prefix blocks of requests with no running
            trace (their waiting traces recompute on readmission). Never
            touches ``skip_rid``: freeing the needy request's own prefix
            would report progress while undoing its admission work (an
            admit/prefill livelock)."""
            before = mgr.free_blocks
            live = {t.request_id for t in running}
            live.add(skip_rid)
            for st in states:
                if st.prefix is not None and st.request_id not in live:
                    release_prefix(st)
            return mgr.free_blocks > before

        def handle_memory_full(needy: Optional[Trace], rid: int,
                               at_admission: bool = False) -> bool:
            """Pool has no free block. Returns True if progress was made.

            STEP: the needy request's policy prunes its lowest-scored
            running trace, freeing its blocks — the waiting queue never
            forms.
            Baselines: at admission the new trace simply WAITS (vLLM does
            not evict running work for new arrivals); mid-decode, the
            last-arrived running trace (any request) is PREEMPTED
            (discard-and-recompute) into the waiting queue.
            """
            st = by_req[rid]
            own_running = [t for t in running if t.request_id == rid]
            victim = st.policy.on_memory_full(own_running)
            if victim is not None:  # STEP prune
                if len(own_running) <= 1 and needy is victim:
                    # sole survivor: finish (truncate) instead of self-prune
                    finish(victim)
                    return True
                release(victim, TraceStatus.PRUNED)
                return True
            if reclaim_idle_prefix(skip_rid=rid):
                return True
            if at_admission or not running:
                return False  # baseline: queue the arrival, keep decoding
            # vLLM preemption: lowest-priority = last-arrived running trace
            victim = running[-1]
            if victim is needy and len(running) == 1:
                # lone trace cannot be preempted to help itself: truncate
                finish(victim)
                return True
            if victim is needy:
                victim = running[-2]
            release(victim, TraceStatus.PREEMPTED)
            victim.runnable_since = time.perf_counter()
            waiting.append(victim)
            return True

        def finish(trace: Trace):
            text = tok.decode(trace.output_tokens)
            trace.answer = extract_answer(text)
            release(trace, TraceStatus.FINISHED)

        def ensure_prefix(st: _ReqState, trace: Trace) -> Optional[bool]:
            """Build the request's shared prompt prefill on demand.

            True: prefix ready. False: memory action made progress, retry
            admission. None: memory full and nothing to free — queue.
            """
            nonlocal cache
            if st.prefix is not None:
                return True
            seq_len = len(trace.prompt_tokens)
            need = mgr.blocks_for_tokens(seq_len)
            # need + 1: the admitting trace's first private (COW) block
            # must fit too, or the headroom check right after us fails
            # and the just-computed prefill is wasted (worst case: an
            # endless build/reclaim/rebuild cycle)
            if not mgr.can_allocate(need + 1):
                if trace.runnable_since < 0:
                    trace.runnable_since = time.perf_counter()
                if not handle_memory_full(None, st.request_id,
                                          at_admission=True):
                    return None
                return False
            blocks = mgr.allocate(need)
            note_peak()
            row = np.zeros((self.blocks_per_seq,), np.int32)
            row[:len(blocks)] = blocks
            t_pf = time.perf_counter()
            ids_arr = jnp.asarray(
                np.array(trace.prompt_tokens, np.int32)[None, :])
            logits, kvs = self._prefill(self.params, ids_arr)
            attn_kvs, slot_state = self._split_prefill_kvs(kvs)
            cache = self._write_prefix_kv(cache, attn_kvs, row, seq_len)
            st.prefix = _SharedPrefix(blocks=blocks, seq_len=seq_len,
                                      last_logits=logits[:, -1],
                                      slot_state=slot_state)
            st.prefill_s += time.perf_counter() - t_pf
            return True

        def admit_shared(trace: Trace, st: _ReqState,
                         pending: List[Trace]) -> None:
            """Fork the request's prompt blocks into a fresh trace."""
            nonlocal cache
            prefix = st.prefix
            waiting.remove(trace)
            slot = free_slots.pop(0)
            if trace.runnable_since >= 0:
                trace.wait_time += time.perf_counter() - trace.runnable_since
                trace.runnable_since = -1.0
            trace.blocks = mgr.fork(prefix.blocks)
            trace.batch_slot = slot
            trace.status = TraceStatus.RUNNING
            trace.prefill_count += 1
            running.append(trace)
            row = np.zeros((self.blocks_per_seq,), np.int32)
            row[:len(trace.blocks)] = trace.blocks
            block_tables[slot] = row
            positions[slot] = prefix.seq_len
            if prefix.slot_state is not None:
                cache = self._write_slot_state(cache, prefix.slot_state, slot)
            pending.append(trace)

        def admit_private(trace: Trace, st: _ReqState) -> None:
            """Original per-trace path: full prefill into private blocks
            (flag off, prompt > capacity, or preempted-trace recompute)."""
            nonlocal cache
            ids = trace.prompt_tokens + trace.output_tokens
            need = mgr.blocks_for_tokens(min(len(ids) + 1, cap))
            waiting.remove(trace)
            blocks = mgr.allocate(need)
            note_peak()
            slot = free_slots.pop(0)
            if trace.runnable_since >= 0:
                trace.wait_time += time.perf_counter() - trace.runnable_since
                trace.runnable_since = -1.0
            trace.blocks = blocks
            trace.batch_slot = slot
            trace.status = TraceStatus.RUNNING
            trace.prefill_count += 1
            running.append(trace)

            row = np.zeros((self.blocks_per_seq,), np.int32)
            row[:len(blocks)] = blocks
            block_tables[slot] = row
            t_pf = time.perf_counter()
            ids_arr = jnp.asarray(np.array(ids, np.int32)[None, :])
            logits, kvs = self._prefill(self.params, ids_arr)
            cache_new = self._write_prefill(cache, kvs, slot, row, len(ids))
            # next token continues from the last prefill logit
            positions[slot] = len(ids)
            self._rng, k = jax.random.split(self._rng)
            sp = ecfg.sampling
            nt, conf = sample_tokens(
                k, logits[:, -1], temperature=sp.temperature,
                top_k=sp.top_k, top_p=sp.top_p)
            cur_tokens[slot] = int(nt[0])
            trace.output_tokens.append(int(nt[0]))
            trace.token_confidences.append(float(conf[0]))
            cache = cache_new
            st.prefill_s += time.perf_counter() - t_pf

        def flush_first_tokens(pending: List[Trace]) -> None:
            """Batch the first-token sampling for every trace admitted via
            prefix forking in this admission wave (one device call)."""
            live = [t for t in pending if t.status == TraceStatus.RUNNING]
            if not live:
                return
            logits = jnp.concatenate(
                [by_req[t.request_id].prefix.last_logits for t in live],
                axis=0)  # [m, Vp]
            self._rng, k = jax.random.split(self._rng)
            sp = ecfg.sampling
            nt, conf = sample_tokens(
                k, logits, temperature=sp.temperature,
                top_k=sp.top_k, top_p=sp.top_p)
            nt = np.asarray(nt)
            conf = np.asarray(conf)
            for i, trace in enumerate(live):
                cur_tokens[trace.batch_slot] = int(nt[i])
                trace.output_tokens.append(int(nt[i]))
                trace.token_confidences.append(float(conf[i]))

        def try_admit() -> None:
            pending: List[Trace] = []
            while free_slots:
                trace = next((t for t in waiting
                              if by_req[t.request_id].admissible(t)), None)
                if trace is None:
                    break
                st = by_req[trace.request_id]
                # sharing needs prompt blocks + one private block to ever
                # fit the pool; pathologically small pools fall back to
                # the per-trace path (which can truncate-finish)
                prefix_fits = (mgr.blocks_for_tokens(
                    len(trace.prompt_tokens)) + 1 <= ecfg.num_blocks - 1)
                fresh = (share and not trace.output_tokens
                         and len(trace.prompt_tokens) <= cap
                         and prefix_fits)
                if fresh:
                    ok = ensure_prefix(st, trace)
                    if ok is None:
                        break
                    if ok is False:
                        continue
                    # headroom for this trace's first private block (the
                    # COW copy of the prompt's tail block, or a fresh
                    # block when the prompt ends exactly on a boundary)
                    if not mgr.can_allocate(1):
                        if trace.runnable_since < 0:
                            trace.runnable_since = time.perf_counter()
                        if not handle_memory_full(None, st.request_id,
                                                  at_admission=True):
                            break
                        continue
                    admit_shared(trace, st, pending)
                else:
                    ids_len = len(trace.prompt_tokens) + \
                        len(trace.output_tokens)
                    need = mgr.blocks_for_tokens(min(ids_len + 1, cap))
                    if not mgr.can_allocate(need):
                        # memory full at admission: STEP prunes,
                        # baselines wait
                        if trace.runnable_since < 0:
                            trace.runnable_since = time.perf_counter()
                        if not handle_memory_full(None, st.request_id,
                                                  at_admission=True):
                            break
                        if not mgr.can_allocate(need):
                            break
                        continue
                    admit_private(trace, st)
            flush_first_tokens(pending)

        # ------------------------------------------------------------
        # main loop
        # ------------------------------------------------------------
        while waiting or running:
            for st in states:
                st.update_gate()
            try_admit()
            if not running:
                if waiting:  # deadlocked on memory: should not happen
                    raise RuntimeError("no trace schedulable")
                break

            # ensure every running trace exclusively owns the block its
            # next token's KV will be written into: allocate fresh blocks
            # at the growth frontier, copy-on-write still-shared (prompt)
            # blocks
            progress = True
            for trace in list(running):
                slot = trace.batch_slot
                pos = int(positions[slot])
                widx = pos % cap  # decode writes at positions % window
                bidx = widx // bs
                if bidx < len(trace.blocks) and \
                        not mgr.is_shared(trace.blocks[bidx]):
                    continue
                while not mgr.can_allocate(1):
                    if not handle_memory_full(trace, trace.request_id):
                        progress = False
                        break
                    if trace.status != TraceStatus.RUNNING:
                        break  # the needy trace itself was pruned/preempted
                if trace.status != TraceStatus.RUNNING or not progress:
                    continue
                blk = mgr.allocate(1)
                note_peak()
                if bidx < len(trace.blocks):
                    # COW: first write into a shared prompt block
                    old = trace.blocks[bidx]
                    cache = self._copy_block(cache, old, blk[0])
                    mgr.free([old])
                    trace.blocks[bidx] = blk[0]
                else:
                    trace.blocks.extend(blk)
                block_tables[slot, bidx] = blk[0]
            if not running:
                continue

            # one fixed-shape batched decode step
            n_by_req: Dict[int, int] = {}
            for t in running:
                n_by_req[t.request_id] = n_by_req.get(t.request_id, 0) + 1
            t_dec = time.perf_counter()
            self._rng, k = jax.random.split(self._rng)
            new_tokens, conf, scores, cache = self._decode(
                self.params, cache,
                jnp.asarray(cur_tokens[:, None]),
                jnp.asarray(positions),
                jnp.asarray(block_tables), k,
                self.scorer_params)
            new_tokens = np.asarray(new_tokens)
            conf = np.asarray(conf)
            scores = np.asarray(scores)
            dt = time.perf_counter() - t_dec
            tot = sum(n_by_req.values())
            for rid, n in n_by_req.items():
                by_req[rid].decode_s += dt * n / tot

            for trace in list(running):
                st = by_req[trace.request_id]
                slot = trace.batch_slot
                prev_token = int(cur_tokens[slot])
                nt = int(new_tokens[slot])
                # the score is for the hidden state of prev_token (the one
                # just consumed by this step); boundary => step end
                if prev_token == tok.step_id and st.policy.uses_scorer:
                    trace.add_step_score(float(scores[slot]))
                trace.output_tokens.append(nt)
                trace.token_confidences.append(float(conf[slot]))
                positions[slot] += 1
                cur_tokens[slot] = nt
                if nt == tok.eos_id or trace.num_tokens >= ecfg.max_new_tokens:
                    finish(trace)

            # signal-triggered termination (DeepConf / Slim-SC)
            for st in states:
                own = [t for t in running if t.request_id == st.request_id]
                if not own:
                    continue
                for trace in st.policy.traces_to_terminate(own):
                    if trace.status == TraceStatus.RUNNING:
                        release(trace, TraceStatus.PRUNED)

        for st in states:  # defensive: no prefix may outlive its batch
            release_prefix(st)
        return peak_blocks
