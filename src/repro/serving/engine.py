"""The serving engine: vLLM-V1-style continuous batching in JAX.

This is the system layer the STEP paper modifies. One engine instance holds
a statically allocated paged KV pool (the per-device HBM budget), a block
manager (the allocator whose free list defines "GPU memory full"), and a
fixed-shape jitted decode step over ``max_batch`` slots.

Scheduling semantics (paper §3, §4.2):

  * baseline engines (SC / CoT / Slim-SC / DeepConf): when the next decode
    step cannot be scheduled because the pool has no free block, a running
    trace is PREEMPTED vLLM-style — its blocks are freed and it re-enters
    the waiting queue; on resume its KV cache is RECOMPUTED (discard-and-
    recompute). The waiting queue is where the paper's 40% latency goes.
  * STEP: the policy returns the lowest-scored trace; the engine PRUNES it
    and immediately reuses its blocks. The waiting queue never forms.

Latency accounting mirrors the paper's Table 3: every wall-clock second of
the engine loop is attributed to {prefill, decode, overhead}; every second
a trace spends runnable-but-not-running (queued after preemption, or
queued at admission because memory was full) is WAIT.
"""
from __future__ import annotations

import dataclasses
import time
from functools import partial
from typing import Dict, List, Optional, Sequence, Tuple

import jax
import jax.numpy as jnp
import numpy as np

from repro.configs.base import ModelConfig
from repro.core.pruning import DeepConfPolicy, PruningPolicy
from repro.data.arithmetic import extract_answer
from repro.core.scorer import scorer_score
from repro.core.trace import Trace, TraceStatus
from repro.data.tokenizer import get_tokenizer
from repro.models.model import (decode_step, forward_full, init_decode_cache,
                                write_prefill_kv)
from repro.serving.kv_manager import BlockManager
from repro.serving.sampling import SamplingParams, sample_tokens


@dataclasses.dataclass
class EngineConfig:
    """Static engine resources (the 'GPU')."""
    max_batch: int = 64            # decode slots (>= trace budget N)
    num_blocks: int = 128          # paged pool blocks incl. scratch
    capacity: int = 512            # per-sequence token capacity (window)
    max_new_tokens: int = 160
    sampling: SamplingParams = dataclasses.field(default_factory=SamplingParams)
    use_kernel: bool = False
    seed: int = 0


@dataclasses.dataclass
class RequestResult:
    request_id: int
    answer: Optional[str]
    traces: List[Trace]
    latency_s: float
    total_tokens: int
    wait_s: float
    decode_s: float
    prefill_s: float
    num_pruned: int
    num_preemptions: int


class Engine:
    """Continuous-batching engine serving one request (N parallel traces)
    at a time — the paper's setting (one problem, N=64 traces)."""

    def __init__(self, params: dict, cfg: ModelConfig, ecfg: EngineConfig,
                 policy: PruningPolicy,
                 scorer_params: Optional[dict] = None):
        self.params = params
        self.cfg = cfg
        self.ecfg = ecfg
        self.policy = policy
        self.scorer_params = scorer_params
        self.tok = get_tokenizer()
        bs = cfg.kv_block_size
        self.blocks_per_seq = -(-ecfg.capacity // bs)
        self.block_mgr = BlockManager(ecfg.num_blocks, bs)
        self._rng = jax.random.PRNGKey(ecfg.seed)
        self._build_steps()

    # ------------------------------------------------------------------
    # jitted steps
    # ------------------------------------------------------------------
    def _build_steps(self):
        cfg, ecfg = self.cfg, self.ecfg
        has_scorer = self.scorer_params is not None
        sp = ecfg.sampling

        V = cfg.vocab_size  # mask vocab padding out of the sampler

        @partial(jax.jit, donate_argnums=(1,))
        def batched_decode(params, cache, tokens, positions, block_tables,
                           rng, scorer_params):
            cache = dict(cache)
            cache["block_tables"] = block_tables
            out = decode_step(params, cfg, tokens, positions, cache,
                              window_len=ecfg.capacity,
                              use_kernel=ecfg.use_kernel)
            logits = out["logits"].at[:, V:].set(-jnp.inf)
            new_tokens, conf = sample_tokens(
                rng, logits, temperature=sp.temperature,
                top_k=sp.top_k, top_p=sp.top_p)
            if has_scorer:
                scores = scorer_score(scorer_params, out["hidden"])
            else:
                scores = jnp.zeros((tokens.shape[0],), jnp.float32)
            new_cache = out["cache"]
            new_cache.pop("block_tables", None)
            return new_tokens, conf, scores, new_cache

        self._decode = batched_decode

        @jax.jit
        def prefill(params, tokens):
            out = forward_full(params, cfg, tokens, return_kv=True)
            logits = out["logits"].at[..., V:].set(-jnp.inf)
            return logits, out["kvs"]

        self._prefill = prefill

    # ------------------------------------------------------------------
    # cache plumbing
    # ------------------------------------------------------------------
    def _init_cache(self):
        """Shared pool sized to the engine budget (not per-sequence)."""
        cache = init_decode_cache(
            self.cfg, self.ecfg.max_batch, self.ecfg.capacity,
            num_blocks=self.ecfg.num_blocks)
        cache.pop("block_tables", None)
        return cache

    def _write_prefill(self, cache: dict, kvs, slot: int,
                       block_row: np.ndarray, seq_len: int) -> dict:
        """Scatter one trace's prefill KV/state into the shared pool."""
        cfg = self.cfg
        bt = jnp.asarray(block_row[None, :], jnp.int32)  # [1, bp]

        def one(tree):
            return jax.tree.map(lambda x: x[:, :1] if x.ndim > 1 else x, tree)

        if cfg.arch_type == "ssm":
            ss, cs = kvs
            cache["ssm_state"] = cache["ssm_state"].at[:, slot].set(ss[:, 0])
            cache["conv_state"] = cache["conv_state"].at[:, slot].set(cs[:, 0])
            return cache
        if cfg.arch_type == "hybrid":
            (ss, cs), (k, v) = kvs
            ssf = ss.reshape(-1, *ss.shape[2:])
            csf = cs.reshape(-1, *cs.shape[2:])
            cache["ssm_state"] = cache["ssm_state"].at[:, slot].set(ssf[:, 0])
            cache["conv_state"] = cache["conv_state"].at[:, slot].set(csf[:, 0])
            sub = {"k_pool": cache["k_pool"], "v_pool": cache["v_pool"],
                   "block_tables": bt}
            sub = write_prefill_kv(
                cfg, sub, (k[:, :1], v[:, :1]),
                jnp.full((1,), seq_len, jnp.int32))
            cache["k_pool"], cache["v_pool"] = sub["k_pool"], sub["v_pool"]
            return cache
        if cfg.use_mla:
            sub = {"kv_pool": cache["kv_pool"], "block_tables": bt}
            sub = write_prefill_kv(cfg, sub, kvs[:, :1],
                                   jnp.full((1,), seq_len, jnp.int32))
            cache["kv_pool"] = sub["kv_pool"]
            return cache
        k, v = kvs
        sub = {"k_pool": cache["k_pool"], "v_pool": cache["v_pool"],
               "block_tables": bt}
        sub = write_prefill_kv(cfg, sub, (k[:, :1], v[:, :1]),
                               jnp.full((1,), seq_len, jnp.int32))
        cache["k_pool"], cache["v_pool"] = sub["k_pool"], sub["v_pool"]
        return cache

    def _clear_slot_state(self, cache: dict, slot: int) -> dict:
        if "ssm_state" in cache:
            cache["ssm_state"] = cache["ssm_state"].at[:, slot].set(0.0)
            cache["conv_state"] = cache["conv_state"].at[:, slot].set(0.0)
        return cache

    # ------------------------------------------------------------------
    # request serving
    # ------------------------------------------------------------------
    def serve(self, prompt_tokens: List[int], n_traces: int,
              request_id: int = 0) -> RequestResult:
        """Generate ``n_traces`` parallel traces for one prompt."""
        ecfg = self.ecfg
        assert n_traces <= ecfg.max_batch, "engine sized per trace budget"
        t_start = time.perf_counter()

        traces = [Trace(trace_id=i, request_id=request_id,
                        prompt_tokens=list(prompt_tokens))
                  for i in range(n_traces)]
        waiting: List[Trace] = list(traces)
        # DeepConf online: first `warmup` traces run as a closed warmup set
        if isinstance(self.policy, DeepConfPolicy):
            self.policy.threshold = None  # fresh threshold per request
            head = waiting[:self.policy.warmup]
            tail = waiting[self.policy.warmup:]
            res_head = self._run_pass(head, t_start)
            self.policy.record_warmup(
                [t for t in head if t.status == TraceStatus.FINISHED])
            if tail:
                res_tail = self._run_pass(tail, time.perf_counter())
            else:
                res_tail = {k: 0.0 for k in res_head}
            stats = {k: res_head[k] + res_tail[k] for k in res_head}
        else:
            stats = self._run_pass(waiting, t_start)

        finished = [t for t in traces if t.status == TraceStatus.FINISHED]
        answer = self.policy.vote(finished) if finished else None
        latency = time.perf_counter() - t_start
        return RequestResult(
            request_id=request_id, answer=answer, traces=traces,
            latency_s=latency,
            total_tokens=sum(t.num_tokens for t in traces),
            wait_s=sum(t.wait_time for t in traces),
            decode_s=stats["decode_s"], prefill_s=stats["prefill_s"],
            num_pruned=sum(t.status == TraceStatus.PRUNED for t in traces),
            num_preemptions=sum(max(t.prefill_count - 1, 0) for t in traces),
        )

    # ------------------------------------------------------------------
    def _run_pass(self, waiting: List[Trace], t0: float) -> Dict[str, float]:
        """Run one closed set of traces to completion/pruning."""
        ecfg, cfg, tok = self.ecfg, self.cfg, self.tok
        B = ecfg.max_batch
        bs = cfg.kv_block_size
        cache = self._init_cache()

        block_tables = np.zeros((B, self.blocks_per_seq), np.int32)
        positions = np.zeros((B,), np.int32)
        cur_tokens = np.zeros((B,), np.int32)
        slot_of: Dict[int, int] = {}
        free_slots = list(range(B))
        running: List[Trace] = []
        waiting = list(waiting)
        for t in waiting:
            t.status = TraceStatus.WAITING
            # wait_time counts only MEMORY-induced waiting (paper Table 3):
            # the clock starts at preemption or at a memory-blocked
            # admission attempt, not at submission.
            t.runnable_since = -1.0

        prefill_s = decode_s = 0.0

        def release(trace: Trace, status: TraceStatus):
            nonlocal cache
            if trace.blocks:
                self.block_mgr.free(trace.blocks)
                trace.blocks = []
            if trace.batch_slot >= 0:
                s = trace.batch_slot
                block_tables[s, :] = self.block_mgr.scratch_block
                positions[s] = 0
                cache = self._clear_slot_state(cache, s)
                free_slots.append(s)
                slot_of.pop(trace.trace_id, None)
                trace.batch_slot = -1
            trace.status = status
            if trace in running:
                running.remove(trace)

        def handle_memory_full(needy: Optional[Trace],
                               at_admission: bool = False) -> bool:
            """Pool has no free block. Returns True if progress was made.

            STEP: prune the lowest-scored running trace, free its blocks —
            the waiting queue never forms.
            Baselines: at admission the new trace simply WAITS (vLLM does
            not evict running work for new arrivals); mid-decode, the
            last-arrived running trace is PREEMPTED (discard-and-recompute)
            into the waiting queue.
            """
            victim = self.policy.on_memory_full(running)
            if victim is not None:  # STEP prune
                if len(running) <= 1 and needy is victim:
                    # sole survivor: finish (truncate) instead of self-prune
                    finish(victim)
                    return True
                release(victim, TraceStatus.PRUNED)
                return True
            if at_admission or not running:
                return False  # baseline: queue the arrival, keep decoding
            # vLLM preemption: lowest-priority = last-arrived running trace
            victim = running[-1]
            if victim is needy and len(running) == 1:
                # lone trace cannot be preempted to help itself: truncate
                finish(victim)
                return True
            if victim is needy:
                victim = running[-2]
            release(victim, TraceStatus.PREEMPTED)
            victim.runnable_since = time.perf_counter()
            waiting.append(victim)
            return True

        def finish(trace: Trace):
            text = tok.decode(trace.output_tokens)
            trace.answer = extract_answer(text)
            release(trace, TraceStatus.FINISHED)

        def try_admit() -> None:
            nonlocal cache, prefill_s
            while waiting and free_slots:
                trace = waiting[0]
                ids = trace.prompt_tokens + trace.output_tokens
                need = self.block_mgr.blocks_for_tokens(
                    min(len(ids) + 1, ecfg.capacity))
                if not self.block_mgr.can_allocate(need):
                    # memory full at admission: STEP prunes, baselines wait
                    if trace.runnable_since < 0:
                        trace.runnable_since = time.perf_counter()
                    if not handle_memory_full(None, at_admission=True):
                        return
                    if not self.block_mgr.can_allocate(need):
                        return
                    continue
                waiting.pop(0)
                blocks = self.block_mgr.allocate(need)
                slot = free_slots.pop(0)
                if trace.runnable_since >= 0:
                    trace.wait_time += time.perf_counter() - trace.runnable_since
                    trace.runnable_since = -1.0
                trace.blocks = blocks
                trace.batch_slot = slot
                trace.status = TraceStatus.RUNNING
                trace.prefill_count += 1
                slot_of[trace.trace_id] = slot
                running.append(trace)

                row = np.full((self.blocks_per_seq,), 0, np.int32)
                row[:len(blocks)] = blocks
                block_tables[slot] = row
                t_pf = time.perf_counter()
                ids_arr = jnp.asarray(np.array(ids, np.int32)[None, :])
                logits, kvs = self._prefill(self.params, ids_arr)
                cache_new = self._write_prefill(cache, kvs, slot, row,
                                                len(ids))
                # next token continues from the last prefill logit
                positions[slot] = len(ids)
                cur_tokens[slot] = int(jnp.argmax(logits[0, -1]))
                # sample the first new token properly
                self._rng, k = jax.random.split(self._rng)
                sp = ecfg.sampling
                nt, conf = sample_tokens(
                    k, logits[:, -1], temperature=sp.temperature,
                    top_k=sp.top_k, top_p=sp.top_p)
                cur_tokens[slot] = int(nt[0])
                trace.output_tokens.append(int(nt[0]))
                trace.token_confidences.append(float(conf[0]))
                cache = cache_new
                prefill_s += time.perf_counter() - t_pf

        # ------------------------------------------------------------
        # main loop
        # ------------------------------------------------------------
        while waiting or running:
            try_admit()
            if not running:
                if waiting:  # deadlocked on memory: should not happen
                    raise RuntimeError("no trace schedulable")
                break

            # ensure every running trace owns the block for its next token
            progress = True
            for trace in list(running):
                slot = trace.batch_slot
                pos = int(positions[slot])
                if pos >= ecfg.capacity:
                    continue  # rolling window, block already owned
                bidx = pos // bs
                if bidx < len(trace.blocks):
                    continue
                while not self.block_mgr.can_allocate(1):
                    if not handle_memory_full(trace):
                        progress = False
                        break
                    if trace.status != TraceStatus.RUNNING:
                        break  # the needy trace itself was pruned/preempted
                if trace.status != TraceStatus.RUNNING or not progress:
                    continue
                blk = self.block_mgr.allocate(1)
                trace.blocks.extend(blk)
                block_tables[trace.batch_slot, bidx] = blk[0]
            if not running:
                continue

            # one fixed-shape batched decode step
            t_dec = time.perf_counter()
            self._rng, k = jax.random.split(self._rng)
            new_tokens, conf, scores, cache = self._decode(
                self.params, cache,
                jnp.asarray(cur_tokens[:, None]),
                jnp.asarray(positions),
                jnp.asarray(block_tables), k,
                self.scorer_params)
            new_tokens = np.asarray(new_tokens)
            conf = np.asarray(conf)
            scores = np.asarray(scores)
            decode_s += time.perf_counter() - t_dec

            for trace in list(running):
                slot = trace.batch_slot
                prev_token = int(cur_tokens[slot])
                nt = int(new_tokens[slot])
                # the score is for the hidden state of prev_token (the one
                # just consumed by this step); boundary => step end
                if prev_token == tok.step_id and self.policy.uses_scorer:
                    trace.add_step_score(float(scores[slot]))
                trace.output_tokens.append(nt)
                trace.token_confidences.append(float(conf[slot]))
                positions[slot] += 1
                cur_tokens[slot] = nt
                if nt == tok.eos_id or trace.num_tokens >= ecfg.max_new_tokens:
                    finish(trace)

            # signal-triggered termination (DeepConf / Slim-SC)
            for trace in self.policy.traces_to_terminate(running):
                if trace.status == TraceStatus.RUNNING:
                    release(trace, TraceStatus.PRUNED)

        return {"prefill_s": prefill_s, "decode_s": decode_s}
