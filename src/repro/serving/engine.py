"""The serving engine: vLLM-V1-style continuous batching in JAX.

This is the system layer the STEP paper modifies. One engine instance holds
a statically allocated paged KV pool (the per-device HBM budget), a block
manager (the allocator whose free list defines "GPU memory full"), and a
fixed-shape jitted decode step over ``max_batch`` slots.

Scheduling semantics (paper §3, §4.2):

  * baseline engines (SC / CoT / Slim-SC / DeepConf): when the next decode
    step cannot be scheduled because the pool has no free block, a running
    trace is PREEMPTED vLLM-style — its blocks are freed and it re-enters
    the waiting queue; on resume its KV cache is RECOMPUTED (discard-and-
    recompute). The waiting queue is where the paper's 40% latency goes.
  * STEP: the policy returns the lowest-scored trace; the engine PRUNES it
    and immediately reuses its blocks. The waiting queue never forms.

Continuous batching (online arrivals): ``serve_batch`` runs a scheduler
tick loop over a ``RequestQueue`` with per-request arrival times.
Requests join the waiting pool only once their arrival time passes, so
decode keeps running between admission waves and per-request
time-to-first-token / time-per-output-token are measured against the
arrival instant (``serving/metrics.py``). With every arrival at t=0 the
tick loop degenerates to the offline batch scheduler and reproduces its
outputs token-for-token under greedy sampling.

Chunked prefill (``EngineConfig.prefill_chunk_size``): long prompts are
prefilled in fixed-size chunks against the paged pool
(``prefill_chunk_step``), drawing KV blocks chunk-by-chunk through a
``BlockManager.reserve`` reservation. While traces are decoding, each
in-flight prefill advances at most one chunk per scheduler tick, so a
long prompt no longer stalls the running decode batch; with an idle
batch the prefill runs to completion immediately. A tick's combined
prefill work is budgeted by ``EngineConfig.max_tokens_per_step``
(prefill chunks and decode tokens share the tick's token budget).
Chunking applies to the shared-prefix path of paged-attention archs;
recurrent/MLA/enc-dec archs and per-trace prefills fall back to the
one-shot path.

Prefix sharing (``EngineConfig.share_prompt_prefix``, default on): all N
traces of a request decode from the *same* prompt, so the prompt KV is
computed once per request, written into shared paged blocks, and forked
into each trace's block table with refcounting. The first time a trace
writes into a still-shared block (its first generated token lands in the
prompt's partial tail block) the engine copy-on-writes that block. With
the flag off the engine reproduces the original per-trace prefill path
(N sequential prompt prefills), which is the accounting baseline for
Table 3.

Cross-request prefix cache (``EngineConfig.prefix_cache``, default on):
completed prompts' full KV blocks are parked in a radix tree
(``serving/prefix_cache.py``) instead of freed; a later request whose
prompt shares a block-aligned prefix forks the cached blocks (COW
refcounting, zero recompute) and chunk-prefills only the suffix. Cached
blocks are the lowest-priority memory in the pool: under pressure the
engine evicts LRU cache-only blocks BEFORE pruning or preempting any
live trace (evict-before-prune), so enabling the cache can only add
scheduling headroom. Per-request hit accounting (``cached_tokens``)
lands in ``RequestMetrics``.

Multi-request scheduling: traces from different requests co-exist in the
fixed-shape decode step, contend for the same block pool, and are
aggregated into per-request ``RequestResult``s. Policies act per
request: the needy trace's own request's policy decides what to prune;
baseline preemption (last-arrived running trace) is global, like vLLM's
latest-arrival eviction. Each tick the engine publishes an
``AdmissionPressure`` snapshot to every active policy, so pruning
decisions can react to queued arrivals (``PruningPolicy.observe_pressure``).

Latency accounting mirrors the paper's Table 3: every wall-clock second of
the engine loop is attributed to {prefill, decode, overhead}; every second
a trace spends runnable-but-not-running (queued after preemption, or
queued at admission because memory was full) is WAIT. Decode seconds of
the shared batched step are attributed to requests proportionally to
their running traces. Waiting for a free decode *slot* (queue longer than
``max_batch``) is not memory-induced and is not counted as WAIT.
"""
from __future__ import annotations

import copy
import dataclasses
import os
import time
from functools import partial
from typing import Callable, Dict, List, Optional, Sequence, Tuple

import jax
import jax.numpy as jnp
import numpy as np

from repro.configs.base import ModelConfig
from repro.core.pruning import AdmissionPressure, DeepConfPolicy, PruningPolicy
from repro.data.arithmetic import extract_answer
from repro.core.scorer import scorer_score
from repro.core.trace import Trace, TraceStatus
from repro.data.tokenizer import get_tokenizer
from repro.models.model import (copy_kv_block, forward_full,
                                init_decode_cache, multi_decode_step,
                                prefill_chunk_step, supports_chunked_prefill,
                                write_prefill_kv)
from repro.serving.kv_manager import BlockManager, Reservation
from repro.serving.metrics import RequestMetrics
from repro.serving.prefix_cache import PrefixCache
from repro.serving.queue import RequestQueue
from repro.serving.sampling import (SamplingParams, sample_logits,
                                    sample_tokens)


def _default_use_kernel():
    """``EngineConfig.use_kernel`` default, overridable via the
    ``REPRO_USE_KERNEL`` env var ("1"/"on"/"true" -> True, "auto" ->
    "auto", anything else -> False). This is how the CI kernel lane
    flips the whole engine suite onto the Pallas path (interpret mode
    on CPU) without touching test code."""
    val = os.environ.get("REPRO_USE_KERNEL", "").strip().lower()
    if val in ("1", "on", "true"):
        return True
    if val == "auto":
        return "auto"
    return False


def _default_prefix_cache():
    """``EngineConfig.prefix_cache`` default, overridable via the
    ``REPRO_PREFIX_CACHE`` env var ("0"/"off"/"false" -> off, anything
    else incl. unset -> on). The CI prefix-cache lane pins it to "1" so
    the whole engine suite runs with cross-request KV reuse active."""
    val = os.environ.get("REPRO_PREFIX_CACHE", "").strip().lower()
    return val not in ("0", "off", "false")


def resolve_use_kernel(setting, cfg: ModelConfig, mesh=None) -> bool:
    """Resolve ``EngineConfig.use_kernel`` (False / True / "auto") to the
    bool the jitted steps consume.

    "auto" picks the compiled Pallas kernels on TPU and the dense XLA
    path on CPU hosts — on CPU the kernels only run in interpret mode
    (the kernel body executed as traced jnp), which is a correctness
    harness, not a fast path; pass ``use_kernel=True`` to force it, as
    the CI kernel lane does. On a mesh the kernel path additionally
    needs the attention heads to divide the "model" axis so the
    shard_map routing keeps every (lane, kv head) grid cell shard-local;
    "auto" falls back to the dense path where the layout is not
    covered, an explicit ``True`` raises ``NotImplementedError`` at
    construction (never silently wrong tokens).
    """
    if setting is False or setting is None:
        return False
    if setting not in (True, "auto"):
        raise ValueError(
            f"use_kernel must be True, False or 'auto', got {setting!r}")
    # the paged kernels cover GQA paged attention (the dense/MoE/hybrid
    # attention layers); MLA's absorbed latent decode has no kernel path
    covered = not cfg.use_mla
    why = "MLA's absorbed latent decode has no Pallas kernel path"
    if covered and mesh is not None:
        model_n = mesh.shape["model"]
        covered = (cfg.num_heads % model_n == 0
                   and cfg.num_kv_heads % model_n == 0)
        why = (f"kernel-on-mesh needs num_heads ({cfg.num_heads}) and "
               f"num_kv_heads ({cfg.num_kv_heads}) divisible by the "
               f"'model' axis ({model_n}) so the shard_map paged "
               f"attention stays shard-local; use use_kernel='auto' to "
               f"fall back to the dense path on this mesh")
    if not covered:
        if setting == "auto":
            return False
        raise NotImplementedError(f"use_kernel=True: {why}")
    if setting == "auto":
        return jax.default_backend() == "tpu"
    return True


@dataclasses.dataclass
class EngineConfig:
    """Static engine resources (the 'GPU')."""
    max_batch: int = 64            # decode slots (>= trace budget N)
    num_blocks: int = 128          # paged pool blocks incl. scratch
    capacity: int = 512            # per-sequence token capacity (window)
    max_new_tokens: int = 160
    sampling: SamplingParams = dataclasses.field(default_factory=SamplingParams)
    # Pallas paged-attention path for the engine-facing attention ops
    # (fused decode + chunked prefill). False = dense jnp; True = always
    # kernel (interpret mode on CPU); "auto" = kernel on TPU, dense on
    # CPU, dense fallback on meshes the shard_map layout doesn't cover.
    # Resolved by ``resolve_use_kernel`` at engine construction.
    use_kernel: "bool | str" = dataclasses.field(
        default_factory=_default_use_kernel)
    seed: int = 0
    # Prefill the prompt once per request and fork its blocks into every
    # trace (COW on first trace-private write). False restores the
    # original per-trace prefill path (the Table-3 accounting baseline).
    share_prompt_prefix: bool = True
    # Chunked prefill: split shared-prefix prompt prefills into chunks of
    # this many tokens, interleaved with decode ticks. None = one-shot
    # prefill (the offline-equivalent setting).
    prefill_chunk_size: Optional[int] = None
    # Per-tick token budget shared by decode tokens (one per running
    # trace) and prefill tokens (chunks + one-shot prefills). None =
    # unlimited (admission bounded only by slots and blocks).
    max_tokens_per_step: Optional[int] = None
    # Cross-request prefix cache: park completed prompts' full KV blocks
    # in a radix tree and serve later requests' shared block-aligned
    # prefixes from it (COW fork, zero recompute); LRU-evicted before
    # any trace is pruned/preempted. Needs share_prompt_prefix and a
    # paged-attention arch (chunked prefill computes the suffix);
    # silently inactive otherwise. Default from REPRO_PREFIX_CACHE
    # (unset -> on).
    prefix_cache: bool = dataclasses.field(
        default_factory=_default_prefix_cache)
    # Decode horizon: run K decode iterations inside ONE jitted device
    # call (fused lax.scan with on-device sampling, EOS masking and
    # step-boundary score capture) and sync tokens/confidences/scores to
    # the host once per K tokens. 1 (default) reproduces the one-token-
    # per-tick scheduler exactly; K>1 amortizes the device->host round
    # trip and the Python tick overhead over K tokens, and generates
    # token-identical traces while scheduling stays aligned — i.e.
    # until memory contention shifts prune/preempt decisions, which
    # land at horizon granularity (greedy sampling is additionally
    # key-free, so it never depends on key-stream alignment — see
    # docs/ENGINE.md). Under admission pressure with a short free list
    # the engine falls back to a single-token tick so frontier
    # pre-allocation never starves waiting work.
    decode_horizon: int = 1


@dataclasses.dataclass
class Request:
    """One unit of work for the scheduler: a prompt and a trace budget.

    ``arrival_time`` is in seconds relative to the start of the serve
    loop; the scheduler will not admit the request before it. 0.0 (the
    default) means available immediately, which reproduces the offline
    batch semantics.

    ``policy`` overrides the engine-level policy for this request; pass a
    fresh instance per request when the policy is stateful (DeepConf's
    warmup threshold, Slim-SC's check cursor) and requests run
    concurrently. When left None in a multi-request batch, the engine
    deep-copies its default policy per request for the same reason.
    """
    request_id: int
    prompt_tokens: List[int]
    n_traces: int
    policy: Optional[PruningPolicy] = None
    arrival_time: float = 0.0


@dataclasses.dataclass
class RequestResult:
    request_id: int
    answer: Optional[str]
    traces: List[Trace]
    latency_s: float
    total_tokens: int
    wait_s: float
    decode_s: float
    prefill_s: float
    num_pruned: int
    num_preemptions: int
    # pool-wide peak block usage observed up to this request's completion
    # (stable by the time the streaming on_complete callback sees it)
    peak_blocks_used: int = 0
    metrics: Optional[RequestMetrics] = None


@dataclasses.dataclass
class _SharedPrefix:
    """Per-request artifact of the one-shot prompt prefill."""
    blocks: List[int]           # holder's own references (freed at req end)
    seq_len: int
    last_logits: jax.Array      # [1, Vp] vocab-masked last-position logits
    slot_state: Optional[tuple]  # (ssm, conv) end state for ssm/hybrid


class _ReqState:
    """Scheduler-side bookkeeping for one in-flight request."""

    def __init__(self, req: Request, policy: PruningPolicy,
                 traces: List[Trace]):
        self.req = req
        self.policy = policy
        self.traces = traces
        self.prefix: Optional[_SharedPrefix] = None
        self.prefill_s = 0.0
        self.decode_s = 0.0
        self.t_done: Optional[float] = None
        self.warmup_recorded = not isinstance(policy, DeepConfPolicy)
        # prefix-cache accounting: one probe per request; a hit holds
        # forked block references until a _PrefillJob takes them over
        self.cache_probed = False
        self.cache_hit: Optional[Tuple[List[int], int]] = None
        self.cached_tokens = 0
        # online-serving timestamps (absolute perf_counter seconds)
        self.arrived = False
        self.admit_t: Optional[float] = None
        self.first_token_t: Optional[float] = None
        self.result: Optional[RequestResult] = None

    @property
    def request_id(self) -> int:
        return self.req.request_id

    def note_first_token(self) -> None:
        if self.first_token_t is None:
            self.first_token_t = time.perf_counter()

    def admissible(self, trace: Trace) -> bool:
        """DeepConf online: traces beyond the warmup set wait until the
        warmup traces finished and the threshold exists."""
        if self.warmup_recorded:
            return True
        return trace.trace_id < self.policy.warmup

    def update_gate(self) -> None:
        if self.warmup_recorded:
            return
        warm = self.traces[:self.policy.warmup]
        if all(not t.alive for t in warm):
            self.policy.record_warmup(
                [t for t in warm if t.status == TraceStatus.FINISHED])
            self.warmup_recorded = True

    def done(self) -> bool:
        return all(not t.alive for t in self.traces)


class _PrefillJob:
    """An in-flight chunked prompt prefill (shared-prefix path).

    Holds a chunk-granular block reservation: blocks already taken carry
    completed chunks' KV; the job draws more as chunks land and commits
    the full set into the request's ``_SharedPrefix`` when the prompt is
    exhausted. ``abort`` (memory pressure) returns every block; the
    prefill restarts from scratch on the next admission attempt.

    A prefix-cache hit seeds the job with ``base_blocks`` (forked cached
    blocks covering the first ``base_tokens`` prompt tokens): the prefill
    starts at ``pos = base_tokens`` and only computes the suffix. Chunk
    boundaries stay on the absolute ``chunk``-token grid so the suffix
    chunks are the exact chunks a cold prefill would have run. ``eager``
    jobs (cache hit on an engine configured for one-shot prefill) run
    all their chunks in one tick instead of interleaving with decode.
    """

    def __init__(self, st: _ReqState, reservation: Reservation,
                 blocks_per_seq: int, chunk: int,
                 base_blocks: Sequence[int] = (), base_tokens: int = 0,
                 eager: bool = False):
        self.st = st
        self.tokens: List[int] = list(st.req.prompt_tokens)
        self.pos = base_tokens
        self.chunk = chunk
        self.eager = eager
        self.base: List[int] = list(base_blocks)
        self.res = reservation
        self.row = np.zeros((blocks_per_seq,), np.int32)
        self.row[:len(self.base)] = self.base
        self.last_logits = None

    @property
    def request_id(self) -> int:
        return self.st.request_id

    @property
    def done(self) -> bool:
        return self.pos >= len(self.tokens)

    def abort(self) -> None:
        self.res.abort()
        if self.base:
            # drop the forked cache references; the cached blocks stay
            # parked in the trie. The restart prefills from scratch, so
            # the request's hit accounting is rolled back too.
            self.res.mgr.free(self.base)
            self.base = []
            self.st.cached_tokens = 0


class _TokenBudget:
    """Per-tick token budget (``EngineConfig.max_tokens_per_step``).

    Decode consumes one token per running trace before prefill work is
    scheduled; ``spend`` charges prefill tokens when they are computed.
    ``force`` lets ``can`` approve the tick's first prefill even beyond
    the limit when nothing is decoding — otherwise a prompt longer than
    the budget could never start.
    """

    def __init__(self, limit: Optional[int]):
        self.left = limit  # None = unlimited
        self.spent_any = False

    def can(self, n_tokens: int, force: bool = False) -> bool:
        if self.left is None or self.left >= n_tokens:
            return True
        return force and not self.spent_any

    def spend(self, n_tokens: int) -> None:
        self.spent_any = True
        if self.left is not None:
            self.left = max(self.left - n_tokens, 0)


class Engine:
    """Continuous-batching engine over a queue of requests, each fanning
    out into N parallel traces (the paper's setting: one problem, N=64
    traces — ``serve``; cross-request contention and online arrivals —
    ``serve_batch``).

    ``mesh`` (a ``("data", "model")`` jax mesh, e.g.
    ``launch.mesh.make_host_mesh(2, 2)``) runs the device-resident side
    over a device mesh: params tensor-parallel on "model"
    (``launch/shardings.serving_param_specs`` — the exactness-preserving
    layout whose only collectives are activation all-gathers), the
    paged KV pool head-sharded on "model" with its block dim replicated
    on "data" (``serving_cache_specs``), and the trace batch — tokens,
    positions, block tables, per-lane outputs, step scores — sharded on
    "data". Host-side scheduling (BlockManager, pruning, the queue) is
    untouched: the allocator stays global, and every scheduling decision
    consumes the same host-synced values, so a mesh engine is
    token-identical to the single-device engine under a fixed RNG
    (pinned in tests/test_sharded_engine.py)."""

    def __init__(self, params: dict, cfg: ModelConfig, ecfg: EngineConfig,
                 policy: PruningPolicy,
                 scorer_params: Optional[dict] = None,
                 mesh=None):
        self.params = params
        self.cfg = cfg
        self.ecfg = ecfg
        self.policy = policy
        self.scorer_params = scorer_params
        self.mesh = mesh
        self.tok = get_tokenizer()
        bs = cfg.kv_block_size
        self.blocks_per_seq = -(-ecfg.capacity // bs)
        self.block_mgr = BlockManager(ecfg.num_blocks, bs)
        self._rng = jax.random.PRNGKey(ecfg.seed)
        self._chunk_supported = supports_chunked_prefill(cfg)
        # cross-request prefix cache: needs the shared-prefix holder (the
        # parked blocks ARE a holder that outlives its request) and the
        # chunked-prefill path (the suffix continues from cached KV)
        self.prefix_cache: Optional[PrefixCache] = None
        if (ecfg.prefix_cache and ecfg.share_prompt_prefix
                and self._chunk_supported):
            self.prefix_cache = PrefixCache(self.block_mgr)
        # with the cache on, the device KV pool must outlive a single
        # serve_batch call — parked blocks are worthless if the pool
        # they point into is re-initialized (zeroed) between batches
        self._kv_cache = None
        # resolved kernel routing for the jitted steps (may raise for
        # unsupported explicit-True combinations — never wrong tokens)
        self.use_kernel = resolve_use_kernel(ecfg.use_kernel, cfg, mesh)
        assert ecfg.decode_horizon >= 1, "decode_horizon must be >= 1"
        # ticks where admission pressure forced the horizon down to 1
        # (observable for tests/benchmarks)
        self.horizon_fallbacks = 0
        self._ss = None  # serving step shardings (mesh engines only)
        if mesh is not None:
            self._place_on_mesh()
        self._build_steps()

    def _place_on_mesh(self) -> None:
        """Shard params/scorer onto the mesh and build the NamedSharding
        bundle the jitted steps pin their in/out layouts to."""
        from repro.launch.shardings import (serving_param_specs,
                                            serving_prefill_kv_specs,
                                            serving_step_shardings,
                                            to_named)
        mesh = self.mesh
        for axis in ("data", "model"):
            if axis not in mesh.axis_names:
                raise ValueError(f"serving mesh needs a {axis!r} axis, "
                                 f"got {mesh.axis_names}")
        data_n = mesh.shape["data"]
        if self.ecfg.max_batch % data_n != 0:
            raise ValueError(
                f"max_batch={self.ecfg.max_batch} must be a multiple of "
                f"the mesh's data axis ({data_n}) so decode lanes shard "
                f"evenly")
        if self.cfg.arch_type in ("ssm", "hybrid") \
                or self.cfg.is_encoder_decoder:
            raise NotImplementedError(
                "mesh serving covers the dense paged-attention archs; "
                "recurrent/enc-dec state would need a data-sharded "
                "slot-state story first")
        if self.cfg.use_mla or self.cfg.uses_moe:
            # the bit-identity contract requires every sharded matmul's
            # contractions to stay shard-local; MLA's low-rank norms
            # (rms over a model-sharded lora dim) and the MoE
            # router/dispatch reductions are not constrained yet
            raise NotImplementedError(
                "mesh serving's exactness layout does not cover "
                "MLA/MoE yet; run these archs on a single device")
        # Non-partitionable threefry (the jax<0.5 default) generates
        # DIFFERENT random bits once the logits array is sharded, so
        # temperature sampling on the mesh would silently diverge from
        # the single-device engine. The partitionable implementation is
        # sharding-invariant by construction. NOTE: this is a
        # process-global flag — engines (and any other sampling code)
        # created after this point consume partitionable key streams,
        # which is exactly what makes a later single-device engine
        # comparable to this one (tests pin mesh-vs-single token
        # identity under it), but it does mean constructing a mesh
        # engine changes fixed-seed streams for the rest of the process.
        jax.config.update("jax_threefry_partitionable", True)
        shapes = jax.tree.map(
            lambda x: jax.ShapeDtypeStruct(x.shape, x.dtype), self.params)
        pspecs = serving_param_specs(self.cfg, mesh, shapes)
        self.params = jax.device_put(self.params, to_named(mesh, pspecs))
        self._ss = serving_step_shardings(self.cfg, mesh)
        self._prefill_kv_specs = serving_prefill_kv_specs(self.cfg, mesh)
        if self.scorer_params is not None:
            # the scorer is a tiny MLP: replicate it so step-score
            # capture is a shard-local matmul over the data-sharded
            # hidden states (no gather per scored token)
            self.scorer_params = jax.device_put(self.scorer_params,
                                                self._ss["replicated"])

    # ------------------------------------------------------------------
    # jitted steps
    # ------------------------------------------------------------------
    def _build_steps(self):
        cfg, ecfg = self.cfg, self.ecfg
        has_scorer = self.scorer_params is not None
        sp = ecfg.sampling
        ss = self._ss  # NamedSharding bundle (None on a 1-device engine)

        V = cfg.vocab_size  # mask vocab padding out of the sampler
        eos_id = self.tok.eos_id
        step_id = self.tok.step_id

        def sample_fn(key, logits):
            logits = logits.at[:, V:].set(-jnp.inf)
            if ss is not None:
                # The sampling math must never shard the vocab axis: the
                # top-p cumsum and softmax denominators are float
                # reductions whose cross-shard psum rounds differently
                # than the single-device sum and flips boundary samples.
                # Gathering the [B, Vp] logits (a few KB at decode
                # widths) and sampling replicated reproduces the
                # single-device sampler bit-for-bit.
                logits = jax.lax.with_sharding_constraint(
                    logits, ss["replicated"])
            return sample_logits(key, logits, temperature=sp.temperature,
                                 top_k=sp.top_k, top_p=sp.top_p)

        def make_decode(horizon):
            """Fused K-iteration decode; one jit instance per horizon."""
            jit_kw = {}
            if ss is not None:
                # pin the round-trip layouts: per-lane [B, K] bursts and
                # next-tick state stay data-sharded, pools keep the
                # serving cache layout (donation then reuses the input
                # pool buffers), the key stays replicated
                t, lane = ss["table"], ss["lane"]
                jit_kw["out_shardings"] = (t, t, t, t, t, lane, lane,
                                           ss["pools"], ss["replicated"])

            @partial(jax.jit, donate_argnums=(1,), **jit_kw)
            def batched_decode(params, cache, tokens, positions, limits,
                               block_tables, rng, scorer_params):
                cache = dict(cache)
                cache["block_tables"] = block_tables
                score_fn = ((lambda h: scorer_score(scorer_params, h))
                            if has_scorer else None)
                # derive the per-iteration keys in-graph, exactly as K
                # successive host-side ticks would (rng, k = split(rng)
                # per token) — one device call replaces K split
                # dispatches + a stack per tick
                keys = []
                for _ in range(horizon):
                    rng, k = jax.random.split(rng)
                    keys.append(k)
                out = multi_decode_step(
                    params, cfg, tokens, positions, limits, cache,
                    window_len=ecfg.capacity, horizon=horizon,
                    rng_keys=jnp.stack(keys), sample_fn=sample_fn,
                    eos_id=eos_id, step_id=step_id, score_fn=score_fn,
                    scratch_block=self.block_mgr.scratch_block,
                    use_kernel=self.use_kernel, shard_specs=ss)
                pools = out["cache"]
                pools.pop("block_tables", None)
                return (out["tokens"], out["confidences"], out["scores"],
                        out["token_valid"], out["score_valid"],
                        out["final_tokens"], out["positions"], pools, rng)

            return batched_decode

        self._decode = make_decode(ecfg.decode_horizon)
        # pressure-fallback path: single-token ticks while waiting work
        # contends for a short free list (same instance when K == 1)
        self._decode_single = (self._decode if ecfg.decode_horizon == 1
                               else make_decode(1))

        pf_kv = None if ss is None else self._prefill_kv_specs
        pf_act = None if ss is None else ss["prefill_act"]

        @jax.jit
        def prefill(params, tokens):
            out = forward_full(params, cfg, tokens, return_kv=True,
                               kv_specs=pf_kv, act_spec=pf_act,
                               tp_act_spec=pf_act)
            logits = out["logits"].at[..., V:].set(-jnp.inf)
            if ss is not None:
                # first-token sampling consumes these host-side: gather
                # off the vocab sharding so the sampler's top-p cumsum
                # never reduces over a sharded axis (see sample_fn)
                logits = jax.lax.with_sharding_constraint(
                    logits, ss["prefill_act"])
            return logits, out["kvs"]

        self._prefill = prefill

        # prompt-KV scatter into the paged pools (one-shot prefix path).
        # Jitted so a mesh engine can pin the output pools back to the
        # canonical cache layout right at the write.
        pool_keys = ("kv_pool",) if cfg.use_mla else ("k_pool", "v_pool")
        wkv_kw = {}
        if ss is not None:
            wkv_kw["out_shardings"] = {
                **{k: ss["pools"][k] for k in pool_keys},
                "block_tables": ss["replicated"],  # one batch-1 row
            }

        @partial(jax.jit, donate_argnums=(0,), **wkv_kw)
        def write_kv(sub_cache, kvs, lens):
            return write_prefill_kv(cfg, sub_cache, kvs, lens)

        self._write_kv = write_kv

        if self._chunk_supported:
            cp_kw = {}
            if ss is not None:
                # chunk jobs run one prompt at a time (batch 1): the
                # logits can't batch-shard, but the pools must come out
                # in the serving layout the decode step expects
                cp_kw["out_shardings"] = (
                    ss["replicated"],
                    {k: ss["pools"][k] for k in ("k_pool", "v_pool")})

            @partial(jax.jit, donate_argnums=(1,), **cp_kw)
            def chunk_prefill(params, cache, tokens, positions, valid,
                              block_tables):
                cache = dict(cache)
                cache["block_tables"] = block_tables
                out = prefill_chunk_step(params, cfg, tokens, positions,
                                         valid, cache,
                                         window_len=ecfg.capacity,
                                         use_kernel=self.use_kernel,
                                         shard_specs=ss)
                logits = out["logits"].at[..., V:].set(-jnp.inf)
                new_cache = out["cache"]
                new_cache.pop("block_tables", None)
                return logits, new_cache

            self._chunk_prefill = chunk_prefill

        # COW block copy: pool[:, dst] = pool[:, src], one jitted instance
        # for all block pairs (src/dst are traced scalars).
        cb_kw = {} if ss is None else {"out_shardings": ss["pools"]}
        self._copy_block = jax.jit(partial(copy_kv_block, cfg),
                                   donate_argnums=(0,), **cb_kw)

    # ------------------------------------------------------------------
    # pool accounting
    # ------------------------------------------------------------------
    @property
    def idle_free_blocks(self) -> int:
        """Free-list blocks plus blocks parked in the prefix cache —
        everything reclaimable when no request is live."""
        cached = (self.prefix_cache.cached_blocks
                  if self.prefix_cache is not None else 0)
        return self.block_mgr.free_blocks + cached

    def pool_drained(self) -> bool:
        """True when no live request holds pool memory: every non-free
        block is parked in the prefix cache at refcount exactly 1 (the
        cache's own reference). With the cache off this degenerates to
        ``free_blocks == num_blocks - 1`` — the pre-cache drain check."""
        if self.prefix_cache is not None:
            self.prefix_cache.check_integrity()
            if any(self.block_mgr.ref_count(b) != 1
                   for b in self.prefix_cache.blocks()):
                return False
        return self.idle_free_blocks == self.block_mgr.num_blocks - 1

    # ------------------------------------------------------------------
    # cache plumbing
    # ------------------------------------------------------------------
    def _init_cache(self):
        """Shared pool sized to the engine budget (not per-sequence)."""
        cache = init_decode_cache(
            self.cfg, self.ecfg.max_batch, self.ecfg.capacity,
            num_blocks=self.ecfg.num_blocks)
        cache.pop("block_tables", None)
        if self._ss is not None:
            cache = {k: jax.device_put(v, self._ss["pools"][k])
                     for k, v in cache.items()}
        return cache

    def _split_prefill_kvs(self, kvs) -> Tuple[Optional[tuple],
                                               Optional[tuple]]:
        """Split forward_full(return_kv=True) output for a batch-1 prefill
        into (paged attention KV | None, per-slot recurrent state | None).
        """
        cfg = self.cfg
        if cfg.arch_type == "ssm":
            ss, cs = kvs
            return None, (ss[:, 0], cs[:, 0])
        if cfg.arch_type == "hybrid":
            (ss, cs), (k, v) = kvs
            ssf = ss.reshape(-1, *ss.shape[2:])
            csf = cs.reshape(-1, *cs.shape[2:])
            return (k[:, :1], v[:, :1]), (ssf[:, 0], csf[:, 0])
        if cfg.use_mla:
            return kvs[:, :1], None
        k, v = kvs
        return (k[:, :1], v[:, :1]), None

    def _write_prefix_kv(self, cache: dict, attn_kvs, block_row: np.ndarray,
                         seq_len: int) -> dict:
        """Write prompt KV into the paged pools ONCE for a block row.

        With prefix sharing this runs once per request; every trace then
        reads these blocks through its forked block table.
        """
        if attn_kvs is None:
            return cache
        cfg = self.cfg
        bt = jnp.asarray(block_row[None, :], jnp.int32)  # [1, bp]
        lens = jnp.full((1,), seq_len, jnp.int32)
        if cfg.use_mla:
            sub = {"kv_pool": cache["kv_pool"], "block_tables": bt}
            sub = self._write_kv(sub, attn_kvs, lens)
            cache["kv_pool"] = sub["kv_pool"]
            return cache
        k, v = attn_kvs
        sub = {"k_pool": cache["k_pool"], "v_pool": cache["v_pool"],
               "block_tables": bt}
        sub = self._write_kv(sub, (k, v), lens)
        cache["k_pool"], cache["v_pool"] = sub["k_pool"], sub["v_pool"]
        return cache

    def _write_slot_state(self, cache: dict, slot_state, slot: int) -> dict:
        """Scatter recurrent (SSM/conv) prefill end-state into one slot."""
        if slot_state is None:
            return cache
        ss, cs = slot_state
        cache["ssm_state"] = cache["ssm_state"].at[:, slot].set(ss)
        cache["conv_state"] = cache["conv_state"].at[:, slot].set(cs)
        return cache

    def _write_prefill(self, cache: dict, kvs, slot: int,
                       block_row: np.ndarray, seq_len: int) -> dict:
        """Scatter one trace's prefill KV/state into the shared pool."""
        attn_kvs, slot_state = self._split_prefill_kvs(kvs)
        cache = self._write_prefix_kv(cache, attn_kvs, block_row, seq_len)
        return self._write_slot_state(cache, slot_state, slot)

    def _clear_slot_state(self, cache: dict, slot: int) -> dict:
        if "ssm_state" in cache:
            cache["ssm_state"] = cache["ssm_state"].at[:, slot].set(0.0)
            cache["conv_state"] = cache["conv_state"].at[:, slot].set(0.0)
        return cache

    # ------------------------------------------------------------------
    # request serving
    # ------------------------------------------------------------------
    def serve(self, prompt_tokens: List[int], n_traces: int,
              request_id: int = 0) -> RequestResult:
        """Generate ``n_traces`` parallel traces for one prompt."""
        assert n_traces <= self.ecfg.max_batch, "engine sized per trace budget"
        req = Request(request_id=request_id,
                      prompt_tokens=list(prompt_tokens),
                      n_traces=n_traces, policy=self.policy)
        return self.serve_batch([req])[0]

    def serve_batch(self, requests: Sequence[Request],
                    on_complete: Optional[Callable[[RequestResult], None]]
                    = None) -> List[RequestResult]:
        """Serve a queue of requests through one shared decode batch.

        Requests join the scheduler at their ``arrival_time``; total
        traces may exceed ``max_batch`` (surplus traces wait for a free
        decode slot). Block-pool contention is cross-request; each
        request's own policy governs pruning of its traces.

        ``on_complete`` streams results: it is invoked with a request's
        ``RequestResult`` the moment its last trace finishes, while other
        requests are still decoding. The returned list is in submission
        order, as before.
        """
        t_start = time.perf_counter()
        states: List[_ReqState] = []
        for req in requests:
            if req.policy is not None:
                policy = req.policy
            elif len(requests) == 1:
                policy = self.policy
            else:
                # stateful policies (DeepConf threshold, Slim-SC cursors)
                # must not leak between concurrent requests: give each
                # request its own copy of the engine-level default
                policy = copy.deepcopy(self.policy)
            if isinstance(policy, DeepConfPolicy):
                policy.threshold = None  # fresh threshold per request
            traces = [Trace(trace_id=i, request_id=req.request_id,
                            prompt_tokens=list(req.prompt_tokens))
                      for i in range(req.n_traces)]
            states.append(_ReqState(req, policy, traces))

        peak_blocks = self._run_scheduler(states, t_start, on_complete)

        t_end = time.perf_counter()
        results = []
        for st in states:
            if st.result is None:  # defensive: finalize stragglers
                st.result = self._finalize(st, t_start, t_end, peak_blocks)
            results.append(st.result)
        return results

    def _finalize(self, st: _ReqState, t_start: float, t_end: float,
                  peak_blocks: int) -> RequestResult:
        """Fold one finished request's traces into its RequestResult."""
        finished = [t for t in st.traces if t.status == TraceStatus.FINISHED]
        answer = st.policy.vote(finished) if finished else None
        done = st.t_done if st.t_done is not None else t_end
        total_tokens = sum(t.num_tokens for t in st.traces)
        num_pruned = sum(t.status == TraceStatus.PRUNED for t in st.traces)
        num_preempt = sum(max(t.prefill_count - 1, 0) for t in st.traces)
        wait_s = sum(t.wait_time for t in st.traces)
        metrics = RequestMetrics(
            request_id=st.request_id,
            arrival_s=st.req.arrival_time,
            admitted_s=(st.admit_t - t_start
                        if st.admit_t is not None else None),
            first_token_s=(st.first_token_t - t_start
                           if st.first_token_t is not None else None),
            finished_s=done - t_start,
            prompt_tokens=len(st.req.prompt_tokens),
            output_tokens=total_tokens,
            n_traces=len(st.traces),
            num_pruned=num_pruned,
            num_preemptions=num_preempt,
            wait_s=wait_s, prefill_s=st.prefill_s, decode_s=st.decode_s,
            cached_tokens=st.cached_tokens)
        return RequestResult(
            request_id=st.request_id, answer=answer, traces=st.traces,
            latency_s=done - t_start,
            total_tokens=total_tokens,
            wait_s=wait_s,
            decode_s=st.decode_s, prefill_s=st.prefill_s,
            num_pruned=num_pruned,
            num_preemptions=num_preempt,
            peak_blocks_used=peak_blocks,
            metrics=metrics)

    # ------------------------------------------------------------------
    def _run_scheduler(self, states: List[_ReqState], t_start: float,
                       on_complete: Optional[Callable[[RequestResult], None]]
                       = None) -> int:
        """Tick loop: arrivals -> admission/chunked prefill -> COW/frontier
        block assurance -> batched decode -> prune/preempt. Runs every
        request's traces to completion/pruning. Returns the pool-wide
        peak block usage."""
        ecfg, cfg, tok = self.ecfg, self.cfg, self.tok
        B = ecfg.max_batch
        bs = cfg.kv_block_size
        cap = ecfg.capacity
        share = ecfg.share_prompt_prefix
        chunk = ecfg.prefill_chunk_size if self._chunk_supported else None
        mgr = self.block_mgr
        pcache = self.prefix_cache
        if pcache is not None and self._kv_cache is not None:
            # persistent pool: parked blocks keep their KV across batches.
            # Take ownership — the first jitted step donates the buffers,
            # so no second reference may survive.
            cache, self._kv_cache = self._kv_cache, None
        else:
            cache = self._init_cache()
        by_req: Dict[int, _ReqState] = {st.request_id: st for st in states}
        assert len(by_req) == len(states), "duplicate request_id in batch"

        pending = RequestQueue([st.req for st in states])
        started: List[_ReqState] = []

        block_tables = np.zeros((B, self.blocks_per_seq), np.int32)
        positions = np.zeros((B,), np.int32)
        cur_tokens = np.zeros((B,), np.int32)
        # Device-resident mirrors of the decode-state arrays. The host
        # copies above stay authoritative for scheduling math; the device
        # copies are re-uploaded only when a host-side event (admission,
        # COW/frontier repoint, release) dirties them. In steady-state
        # decode the fused step hands back next-tick tokens/positions as
        # device arrays, so nothing round-trips through jnp.asarray.
        dev = {"tokens": None, "positions": None, "block_tables": None}
        dirty = {"tokens": True, "positions": True, "block_tables": True}
        K_cfg = ecfg.decode_horizon
        free_slots = list(range(B))
        running: List[Trace] = []
        waiting: List[Trace] = []
        jobs: Dict[int, _PrefillJob] = {}  # request_id -> in-flight prefill

        peak_blocks = 0
        idle_ticks = 0  # consecutive no-progress ticks (deadlock guard)

        def note_peak():
            nonlocal peak_blocks
            peak_blocks = max(peak_blocks, mgr.used_blocks)

        def admit_arrivals(now_rel: float):
            for req in pending.pop_arrived(now_rel):
                st = by_req[req.request_id]
                st.arrived = True
                started.append(st)
                for t in st.traces:
                    t.status = TraceStatus.WAITING
                    # wait_time counts only MEMORY-induced waiting (paper
                    # Table 3): the clock starts at preemption or at a
                    # memory-blocked admission attempt, not at arrival.
                    t.runnable_since = -1.0
                waiting.extend(st.traces)

        def release_prefix(st: _ReqState, park: bool = True):
            """Drop the request's shared-prefix holder references. With
            the prefix cache on, the prompt's full blocks are parked in
            the trie for cross-request reuse instead of freed; the
            partial tail block (written by this request's own prefill)
            is never shared and always returns to the pool. ``park=False``
            (memory reclaim) frees everything outright."""
            if st.prefix is None:
                return
            blocks, n_tok = st.prefix.blocks, st.prefix.seq_len
            st.prefix = None
            if park and pcache is not None and n_tok >= bs:
                n_full = n_tok // bs
                pcache.insert(st.req.prompt_tokens, blocks[:n_full])
                if blocks[n_full:]:
                    mgr.free(blocks[n_full:])
            else:
                mgr.free(blocks)

        def evict_for(n: int) -> bool:
            """Free-list headroom for ``n`` blocks, reclaiming LRU
            prefix-cache blocks on demand — parked KV is the cheapest
            memory in the pool (a reuse opportunity, not live compute),
            so it always goes before any trace is pruned/preempted."""
            if mgr.can_allocate(n):
                return True
            if pcache is not None:
                pcache.evict(n - mgr.free_blocks)
            return mgr.can_allocate(n)

        def release(trace: Trace, status: TraceStatus):
            nonlocal cache
            if trace.blocks:
                mgr.free(trace.blocks)
                trace.blocks = []
            if trace.batch_slot >= 0:
                s = trace.batch_slot
                block_tables[s, :] = mgr.scratch_block
                positions[s] = 0
                dirty["block_tables"] = dirty["positions"] = True
                cache = self._clear_slot_state(cache, s)
                free_slots.append(s)
                trace.batch_slot = -1
            trace.status = status
            if trace in running:
                running.remove(trace)
            st = by_req[trace.request_id]
            if st.done():
                release_prefix(st)
                if st.t_done is None:
                    st.t_done = time.perf_counter()
                if st.result is None:
                    st.result = self._finalize(st, t_start,
                                               st.t_done, peak_blocks)
                    if on_complete is not None:
                        on_complete(st.result)

        def reclaim_idle_prefix(skip_rid: int) -> bool:
            """Free shared-prefix blocks of requests with no running
            trace (their waiting traces recompute on readmission). Never
            touches ``skip_rid``: freeing the needy request's own prefix
            would report progress while undoing its admission work (an
            admit/prefill livelock)."""
            before = mgr.free_blocks
            live = {t.request_id for t in running}
            live.add(skip_rid)
            for st in started:
                if st.prefix is not None and st.request_id not in live:
                    # reclaim must FREE, not park: parking would report
                    # no free-list progress and fall through to
                    # preemption with reusable blocks still held
                    release_prefix(st, park=False)
            return mgr.free_blocks > before

        def abort_other_jobs(skip_rid: int) -> bool:
            """Cancel other requests' in-flight chunked prefills, freeing
            their partially-reserved blocks (they restart later). Only
            the decode path calls this — admission-time aborts could
            livelock two prefilling requests against each other."""
            freed = False
            for rid in list(jobs):
                if rid != skip_rid and jobs[rid].res.num_taken > 0:
                    jobs.pop(rid).abort()
                    freed = True
            return freed

        def current_pressure() -> AdmissionPressure:
            return AdmissionPressure(
                waiting_traces=len(waiting),
                queued_requests=len(pending),
                free_blocks=mgr.free_blocks,
                total_blocks=ecfg.num_blocks - 1,
                cached_blocks=(pcache.cached_blocks
                               if pcache is not None else 0),
                evictable_blocks=(pcache.evictable_blocks
                                  if pcache is not None else 0))

        def handle_memory_full(needy: Optional[Trace], rid: int,
                               at_admission: bool = False) -> bool:
            """Pool has no free block. Returns True if progress was made.

            STEP: the needy request's policy prunes its lowest-scored
            running trace, freeing its blocks — the waiting queue never
            forms.
            Baselines: at admission the new trace simply WAITS (vLLM does
            not evict running work for new arrivals); mid-decode, the
            last-arrived running trace (any request) is PREEMPTED
            (discard-and-recompute) into the waiting queue.
            """
            # evict-before-prune: LRU cache-only blocks are reclaimed
            # before any live trace is touched. This ordering is what
            # keeps cache-on scheduling a superset of cache-off headroom
            # (the cache can only ADD free-able memory, never displace a
            # trace that would have run with the cache off).
            if pcache is not None and pcache.evict(1):
                return True
            st = by_req[rid]
            own_running = [t for t in running if t.request_id == rid]
            victim = st.policy.on_memory_full(own_running,
                                              pressure=current_pressure())
            if victim is not None:  # STEP prune
                if len(own_running) <= 1 and needy is victim:
                    # sole survivor: finish (truncate) instead of self-prune
                    finish(victim)
                    return True
                release(victim, TraceStatus.PRUNED)
                return True
            if reclaim_idle_prefix(skip_rid=rid):
                return True
            if at_admission or not running:
                return False  # baseline: queue the arrival, keep decoding
            if abort_other_jobs(skip_rid=rid):
                return True
            # vLLM preemption: lowest-priority = last-arrived running trace
            victim = running[-1]
            if victim is needy and len(running) == 1:
                # lone trace cannot be preempted to help itself: truncate
                finish(victim)
                return True
            if victim is needy:
                victim = running[-2]
            release(victim, TraceStatus.PREEMPTED)
            victim.runnable_since = time.perf_counter()
            waiting.append(victim)
            return True

        def finish(trace: Trace):
            text = tok.decode(trace.output_tokens)
            trace.answer = extract_answer(text)
            release(trace, TraceStatus.FINISHED)

        def owns_write_block(trace: Trace, bidx: int) -> bool:
            return (bidx < len(trace.blocks)
                    and not mgr.is_shared(trace.blocks[bidx]))

        def claim_write_block(trace: Trace, bidx: int) -> None:
            """Make ``trace`` the exclusive owner of its write block at
            ``bidx``: a fresh block at the growth frontier, or a COW
            copy of a still-shared (prompt) block — the first private
            write, or a window wrap re-entering shared blocks. The
            caller has ensured a free block exists."""
            nonlocal cache
            blk = mgr.allocate(1)
            note_peak()
            if bidx < len(trace.blocks):
                old = trace.blocks[bidx]
                cache = self._copy_block(cache, old, blk[0])
                mgr.free([old])
                trace.blocks[bidx] = blk[0]
            else:
                trace.blocks.extend(blk)
            block_tables[trace.batch_slot, bidx] = blk[0]
            dirty["block_tables"] = True

        def frontier_walk(trace: Trace, k_tick: int):
            """Yield (token offset j, block index) over ``trace``'s
            next-``k_tick``-token write window, beyond the next token
            (whose block the COW/grow pass already guarantees)."""
            p = int(positions[trace.batch_slot])
            want = min(k_tick,
                       max(ecfg.max_new_tokens - trace.num_tokens, 1))
            for j in range(1, want):
                yield j, ((p + j) % cap) // bs

        def extend_frontier(trace: Trace, k_tick: int) -> int:
            """Secure exclusively-owned write blocks for up to
            ``k_tick`` upcoming tokens of one trace. Best-effort: a
            short free list shortens the lane's horizon, it never
            triggers pruning/preemption."""
            secured = 1
            for j, bidx in frontier_walk(trace, k_tick):
                if not owns_write_block(trace, bidx):
                    if not evict_for(1):
                        break
                    claim_write_block(trace, bidx)
                secured = j + 1
            return secured

        def start_wait_clock(st: _ReqState):
            """Memory-blocked before admission: start the WAIT clock of
            the request's next admissible trace (mirrors the one-shot
            path, which stamps the admitting trace)."""
            for t in st.traces:
                if t.status == TraceStatus.WAITING and t in waiting:
                    if t.runnable_since < 0:
                        t.runnable_since = time.perf_counter()
                    return

        def advance_job(job: _PrefillJob, budget: _TokenBudget) -> str:
            """Run prefill chunks for one job within the tick budget.

            Returns "ready" (prefix complete), "budget" (tick budget or
            interleave cap reached), or "memory" (blocked on blocks with
            no reclaimable progress).
            """
            nonlocal cache
            st = job.st
            L = len(job.tokens)
            C = job.chunk
            base_n = len(job.base)
            while not job.done:
                # stay on the absolute C-token chunk grid: a cache-hit
                # suffix (pos starts at base_tokens) runs the exact
                # chunks a cold prefill of this prompt would have run
                c = min(C - job.pos % C, L - job.pos)
                if not budget.can(c, force=not running):
                    return "budget"
                need_total = mgr.blocks_for_tokens(job.pos + c)
                need_new = need_total - base_n - job.res.num_taken
                while need_new > 0:
                    got = job.res.take(need_new)
                    if got is not None:
                        note_peak()
                        start = base_n + job.res.num_taken - len(got)
                        job.row[start : base_n + job.res.num_taken] = got
                        break
                    start_wait_clock(st)
                    if not handle_memory_full(None, st.request_id,
                                              at_admission=True):
                        return "memory"
                t_pf = time.perf_counter()
                toks = np.zeros((1, C), np.int32)
                toks[0, :c] = job.tokens[job.pos : job.pos + c]
                pos_arr = job.pos + np.arange(C, dtype=np.int32)[None, :]
                valid = (np.arange(C, dtype=np.int32)[None, :] < c)
                logits, cache = self._chunk_prefill(
                    self.params, cache, jnp.asarray(toks),
                    jnp.asarray(pos_arr), jnp.asarray(valid),
                    jnp.asarray(job.row[None, :], jnp.int32))
                job.last_logits = logits[:, c - 1]
                job.pos += c
                budget.spend(c)
                st.prefill_s += time.perf_counter() - t_pf
                if running and not job.eager:
                    # interleave: while traces decode, at most one chunk
                    # per tick so prefill never stalls the decode batch
                    break
            if job.done:
                base, job.base = job.base, []
                st.prefix = _SharedPrefix(
                    blocks=base + job.res.commit(), seq_len=L,
                    last_logits=job.last_logits, slot_state=None)
                jobs.pop(st.request_id, None)
                return "ready"
            return "budget"

        def ensure_prefix(st: _ReqState, trace: Trace,
                          budget: _TokenBudget) -> Optional[bool]:
            """Build the request's shared prompt prefill on demand
            (one-shot path; the chunked path goes through _PrefillJob).

            True: prefix ready. False: memory action made progress, retry
            admission. None: memory full and nothing to free — queue.
            """
            nonlocal cache
            if st.prefix is not None:
                return True
            seq_len = len(trace.prompt_tokens)
            need = mgr.blocks_for_tokens(seq_len)
            # need + 1: the admitting trace's first private (COW) block
            # must fit too, or the headroom check right after us fails
            # and the just-computed prefill is wasted (worst case: an
            # endless build/reclaim/rebuild cycle)
            if not evict_for(need + 1):
                if trace.runnable_since < 0:
                    trace.runnable_since = time.perf_counter()
                if not handle_memory_full(None, st.request_id,
                                          at_admission=True):
                    return None
                return False
            budget.spend(seq_len)
            blocks = mgr.allocate(need)
            note_peak()
            row = np.zeros((self.blocks_per_seq,), np.int32)
            row[:len(blocks)] = blocks
            t_pf = time.perf_counter()
            ids_arr = jnp.asarray(
                np.array(trace.prompt_tokens, np.int32)[None, :])
            logits, kvs = self._prefill(self.params, ids_arr)
            attn_kvs, slot_state = self._split_prefill_kvs(kvs)
            cache = self._write_prefix_kv(cache, attn_kvs, row, seq_len)
            st.prefix = _SharedPrefix(blocks=blocks, seq_len=seq_len,
                                      last_logits=logits[:, -1],
                                      slot_state=slot_state)
            st.prefill_s += time.perf_counter() - t_pf
            return True

        def admit_shared(trace: Trace, st: _ReqState,
                         wave: List[Trace]) -> None:
            """Fork the request's prompt blocks into a fresh trace."""
            nonlocal cache
            prefix = st.prefix
            waiting.remove(trace)
            slot = free_slots.pop(0)
            if trace.runnable_since >= 0:
                trace.wait_time += time.perf_counter() - trace.runnable_since
                trace.runnable_since = -1.0
            trace.blocks = mgr.fork(prefix.blocks)
            trace.batch_slot = slot
            trace.status = TraceStatus.RUNNING
            trace.prefill_count += 1
            running.append(trace)
            if st.admit_t is None:
                st.admit_t = time.perf_counter()
            row = np.zeros((self.blocks_per_seq,), np.int32)
            row[:len(trace.blocks)] = trace.blocks
            block_tables[slot] = row
            positions[slot] = prefix.seq_len
            dirty["block_tables"] = dirty["positions"] = True
            if prefix.slot_state is not None:
                cache = self._write_slot_state(cache, prefix.slot_state, slot)
            wave.append(trace)

        def admit_private(trace: Trace, st: _ReqState) -> None:
            """Original per-trace path: full prefill into private blocks
            (flag off, prompt > capacity, or preempted-trace recompute)."""
            nonlocal cache
            ids = trace.prompt_tokens + trace.output_tokens
            need = mgr.blocks_for_tokens(min(len(ids) + 1, cap))
            waiting.remove(trace)
            blocks = mgr.allocate(need)
            note_peak()
            slot = free_slots.pop(0)
            if trace.runnable_since >= 0:
                trace.wait_time += time.perf_counter() - trace.runnable_since
                trace.runnable_since = -1.0
            trace.blocks = blocks
            trace.batch_slot = slot
            trace.status = TraceStatus.RUNNING
            trace.prefill_count += 1
            running.append(trace)
            if st.admit_t is None:
                st.admit_t = time.perf_counter()

            row = np.zeros((self.blocks_per_seq,), np.int32)
            row[:len(blocks)] = blocks
            block_tables[slot] = row
            t_pf = time.perf_counter()
            ids_arr = jnp.asarray(np.array(ids, np.int32)[None, :])
            logits, kvs = self._prefill(self.params, ids_arr)
            cache_new = self._write_prefill(cache, kvs, slot, row, len(ids))
            # next token continues from the last prefill logit
            positions[slot] = len(ids)
            dirty["block_tables"] = dirty["positions"] = True
            dirty["tokens"] = True
            self._rng, k = jax.random.split(self._rng)
            sp = ecfg.sampling
            nt, conf = sample_tokens(
                k, logits[:, -1], temperature=sp.temperature,
                top_k=sp.top_k, top_p=sp.top_p)
            cur_tokens[slot] = int(nt[0])
            trace.output_tokens.append(int(nt[0]))
            trace.token_confidences.append(float(conf[0]))
            st.note_first_token()
            cache = cache_new
            st.prefill_s += time.perf_counter() - t_pf

        def flush_first_tokens(wave: List[Trace]) -> None:
            """Batch the first-token sampling for every trace admitted via
            prefix forking in this admission wave (one device call)."""
            live = [t for t in wave if t.status == TraceStatus.RUNNING]
            if not live:
                return
            logits = jnp.concatenate(
                [by_req[t.request_id].prefix.last_logits for t in live],
                axis=0)  # [m, Vp]
            self._rng, k = jax.random.split(self._rng)
            sp = ecfg.sampling
            nt, conf = sample_tokens(
                k, logits, temperature=sp.temperature,
                top_k=sp.top_k, top_p=sp.top_p)
            nt = np.asarray(nt).tolist()
            conf = np.asarray(conf).tolist()
            dirty["tokens"] = True
            for i, trace in enumerate(live):
                cur_tokens[trace.batch_slot] = nt[i]
                trace.output_tokens.append(nt[i])
                trace.token_confidences.append(conf[i])
                by_req[trace.request_id].note_first_token()

        def try_admit(budget: _TokenBudget) -> bool:
            """One admission wave. Returns True if anything was admitted
            or any prefill chunk advanced."""
            wave: List[Trace] = []
            advanced = False
            # in-flight chunked prefills advance first (oldest work)
            for rid in list(jobs):
                job = jobs.get(rid)
                if job is None:
                    continue
                before = job.pos
                status = advance_job(job, budget)
                if status == "ready" or job.pos > before:
                    advanced = True
            skipped: set = set()
            while free_slots:
                trace = next(
                    (t for t in waiting
                     if t.request_id not in skipped
                     and by_req[t.request_id].admissible(t)), None)
                if trace is None:
                    break
                st = by_req[trace.request_id]
                # sharing needs prompt blocks + one private block to ever
                # fit the pool; pathologically small pools fall back to
                # the per-trace path (which can truncate-finish)
                prefix_fits = (mgr.blocks_for_tokens(
                    len(trace.prompt_tokens)) + 1 <= ecfg.num_blocks - 1)
                fresh = (share and not trace.output_tokens
                         and len(trace.prompt_tokens) <= cap
                         and prefix_fits)
                if fresh:
                    L = len(trace.prompt_tokens)
                    if (st.prefix is None and pcache is not None
                            and not st.cache_probed):
                        # probe the prefix cache exactly once per request
                        # (stats stay deterministic across re-picks) and
                        # pin the hit immediately: the fork's refcounts
                        # protect the matched blocks from eviction while
                        # the request waits for a slot or budget
                        st.cache_probed = True
                        hit_blocks, hit_tokens = pcache.match(
                            trace.prompt_tokens)
                        if hit_blocks:
                            st.cache_hit = (mgr.fork(hit_blocks),
                                            hit_tokens)
                            st.cached_tokens = hit_tokens
                    use_job = st.prefix is None and (
                        st.request_id in jobs
                        or st.cache_hit is not None
                        or (chunk is not None and L > chunk))
                    if use_job:
                        # chunked path: open/advance the prefill job; the
                        # trace admits once the prefix completes. Cache
                        # hits always take this path — the suffix runs as
                        # block-size chunks (a fixed jit shape) even on
                        # engines configured for one-shot prefill.
                        job = jobs.get(st.request_id)
                        if job is None:
                            base, base_tokens = st.cache_hit or ([], 0)
                            st.cache_hit = None
                            job = _PrefillJob(
                                st,
                                mgr.reserve(mgr.blocks_for_tokens(L)
                                            - len(base)),
                                self.blocks_per_seq,
                                chunk=chunk if chunk is not None else bs,
                                base_blocks=base, base_tokens=base_tokens,
                                eager=chunk is None)
                            jobs[st.request_id] = job
                        before = job.pos
                        status = advance_job(job, budget)
                        if status == "ready":
                            advanced = True
                            continue  # re-pick: prefix now exists
                        if job.pos > before:
                            advanced = True
                        if status == "memory":
                            break
                        skipped.add(st.request_id)
                        continue
                    if st.prefix is None and not budget.can(
                            L, force=not running):
                        skipped.add(st.request_id)
                        continue
                    ok = ensure_prefix(st, trace, budget)
                    if ok is None:
                        break
                    if ok is False:
                        continue
                    # the admitted trace decodes THIS tick — up to a
                    # full horizon of tokens: charge them pessimistically
                    # so a tick never exceeds the budget
                    if not budget.can(K_cfg,
                                      force=not running and not wave):
                        skipped.add(st.request_id)
                        continue
                    # headroom for this trace's first private block (the
                    # COW copy of the prompt's tail block, or a fresh
                    # block when the prompt ends exactly on a boundary)
                    if not evict_for(1):
                        if trace.runnable_since < 0:
                            trace.runnable_since = time.perf_counter()
                        if not handle_memory_full(None, st.request_id,
                                                  at_admission=True):
                            break
                        continue
                    budget.spend(K_cfg)
                    admit_shared(trace, st, wave)
                else:
                    ids_len = (len(trace.prompt_tokens)
                               + len(trace.output_tokens))
                    # prefill cost + this tick's decode horizon
                    if not budget.can(ids_len + K_cfg, force=not running):
                        skipped.add(trace.request_id)
                        continue
                    need = mgr.blocks_for_tokens(min(ids_len + 1, cap))
                    if not evict_for(need):
                        # memory full at admission: STEP prunes,
                        # baselines wait
                        if trace.runnable_since < 0:
                            trace.runnable_since = time.perf_counter()
                        if not handle_memory_full(None, st.request_id,
                                                  at_admission=True):
                            break
                        if not evict_for(need):
                            break
                        continue
                    budget.spend(ids_len + K_cfg)
                    admit_private(trace, st)
            flush_first_tokens(wave)
            return advanced or bool(wave)

        # ------------------------------------------------------------
        # main tick loop
        # ------------------------------------------------------------
        while pending or waiting or running or jobs:
            now_rel = time.perf_counter() - t_start
            admit_arrivals(now_rel)
            if not (waiting or running or jobs):
                # idle: nothing runnable until the next arrival
                nxt = pending.next_arrival()
                if nxt is not None:
                    time.sleep(min(max(nxt - now_rel, 0.0), 0.02) + 1e-4)
                continue

            for st in started:
                st.update_gate()
            pressure = current_pressure()
            for st in started:
                if not st.done():
                    st.policy.observe_pressure(pressure)

            # decode may emit up to decode_horizon tokens per running
            # trace this tick; charge the budget pessimistically so a
            # tick can never exceed it
            budget = _TokenBudget(
                None if ecfg.max_tokens_per_step is None
                else max(ecfg.max_tokens_per_step - len(running) * K_cfg, 0))
            progressed = try_admit(budget)
            if not running:
                if not (waiting or jobs or pending):
                    break
                if progressed:
                    idle_ticks = 0
                    continue
                if pending:
                    # arrivals still due: wait for them (not a deadlock)
                    nxt = pending.next_arrival()
                    now_rel = time.perf_counter() - t_start
                    if nxt is not None and nxt > now_rel:
                        time.sleep(min(nxt - now_rel, 0.02) + 1e-4)
                    continue
                idle_ticks += 1
                if idle_ticks >= 3:
                    raise RuntimeError("no trace schedulable")
                continue
            idle_ticks = 0

            # ensure every running trace exclusively owns the block its
            # next token's KV will be written into: allocate fresh blocks
            # at the growth frontier, copy-on-write still-shared (prompt)
            # blocks
            progress = True
            for trace in list(running):
                if trace.status != TraceStatus.RUNNING:
                    # released (pruned/preempted) as an earlier trace's
                    # memory-full victim within this very loop: it no
                    # longer needs a write block, and raising pressure
                    # on its behalf would evict a live trace for nothing
                    continue
                pos = int(positions[trace.batch_slot])
                bidx = (pos % cap) // bs  # writes land at pos % window
                if owns_write_block(trace, bidx):
                    continue
                while not evict_for(1):
                    if not handle_memory_full(trace, trace.request_id):
                        progress = False
                        break
                    if trace.status != TraceStatus.RUNNING:
                        break  # the needy trace itself was pruned/preempted
                if trace.status != TraceStatus.RUNNING or not progress:
                    continue
                claim_write_block(trace, bidx)
            if not running:
                continue

            # --------------------------------------------------------
            # decode horizon: how many tokens may this tick fuse?
            # --------------------------------------------------------
            K_tick = K_cfg
            if K_cfg > 1 and waiting:
                # Admission pressure: count the blocks a full-horizon
                # frontier would actually ALLOCATE (most ticks the write
                # block has unwritten slots left and the answer is 0 —
                # the horizon is free). If extending would drain the
                # free list to the last block, pre-allocation could
                # starve waiting admissions and shift memory-triggered
                # pruning decisions away from their horizon=1 points:
                # fall back to a single-token tick until the contention
                # clears.
                needed_new = 0
                for trace in running:
                    needed_new += len(
                        {bidx for _, bidx in frontier_walk(trace, K_cfg)
                         if not owns_write_block(trace, bidx)})
                if needed_new and not evict_for(needed_new + 1):
                    self.horizon_fallbacks += 1
                    K_tick = 1

            limits = np.zeros((B,), np.int32)
            for trace in running:
                limits[trace.batch_slot] = (
                    1 if K_tick == 1 else extend_frontier(trace, K_tick))

            # one fixed-shape fused decode call: K_tick iterations of
            # decode + on-device sampling + step-boundary score capture
            n_by_req: Dict[int, int] = {}
            for t in running:
                n_by_req[t.request_id] = n_by_req.get(t.request_id, 0) + 1
            t_dec = time.perf_counter()
            ss = self._ss
            for name, arr in (("tokens", cur_tokens),
                              ("positions", positions),
                              ("block_tables", block_tables)):
                if dirty[name] or dev[name] is None:
                    if ss is None:
                        dev[name] = jnp.asarray(arr)
                    else:  # upload straight into the mesh layout
                        up = "table" if name == "block_tables" else "lane"
                        dev[name] = jax.device_put(arr, ss[up])
                    dirty[name] = False
            limits_dev = (jnp.asarray(limits) if ss is None
                          else jax.device_put(limits, ss["lane"]))
            decode_fn = (self._decode if K_tick == K_cfg
                         else self._decode_single)
            (toks_d, confs_d, scores_d, tv_d, sv_d, fin_tok, fin_pos,
             cache, self._rng) = decode_fn(
                self.params, cache, dev["tokens"], dev["positions"],
                limits_dev, dev["block_tables"],
                self._rng, self.scorer_params)
            # single host sync per tick; .tolist() batches the per-trace
            # float()/int() conversions of the old per-token loop
            toks_h, confs_h, scores_h, tv_h, sv_h, ft_h, fp_h = (
                x.tolist() for x in jax.device_get(
                    (toks_d, confs_d, scores_d, tv_d, sv_d,
                     fin_tok, fin_pos)))
            dev["tokens"], dev["positions"] = fin_tok, fin_pos
            cur_tokens[:] = ft_h
            positions[:] = fp_h
            dt = time.perf_counter() - t_dec
            tot = sum(n_by_req.values())
            for rid, n in n_by_req.items():
                by_req[rid].decode_s += dt * n / tot

            for trace in list(running):
                st = by_req[trace.request_id]
                slot = trace.batch_slot
                valid_row = tv_h[slot]
                n_emit = 0
                for v in valid_row:
                    if not v:
                        break
                    n_emit += 1
                # scores belong to the hidden states of the iteration
                # INPUT tokens; score_valid marks the step boundaries
                # (input token == step_id) inside the emitted prefix
                if st.policy.uses_scorer:
                    burst_scores = [scores_h[slot][i]
                                    for i in range(n_emit) if sv_h[slot][i]]
                    if burst_scores:
                        trace.add_step_scores(burst_scores)
                else:
                    burst_scores = []
                burst_toks = toks_h[slot][:n_emit]
                burst_confs = confs_h[slot][:n_emit]
                trace.extend_output(burst_toks, burst_confs)
                st.policy.observe_decode_burst(trace, burst_toks,
                                               burst_confs, burst_scores)
                if n_emit and (burst_toks[-1] == tok.eos_id
                               or trace.num_tokens >= ecfg.max_new_tokens):
                    finish(trace)

            # signal-triggered termination (DeepConf / Slim-SC / STEP
            # proactive pruning under admission pressure)
            for st in started:
                own = [t for t in running if t.request_id == st.request_id]
                if not own:
                    continue
                for trace in st.policy.traces_to_terminate(own):
                    if trace.status == TraceStatus.RUNNING:
                        release(trace, TraceStatus.PRUNED)

        for job in list(jobs.values()):  # defensive: no job survives
            job.abort()
        jobs.clear()
        for st in states:  # defensive: no prefix may outlive its batch
            release_prefix(st)
        if pcache is not None:
            self._kv_cache = cache  # keep parked KV live for the next batch
        return peak_blocks
