"""The serving engine: vLLM-V1-style continuous batching in JAX.

This is the system layer the STEP paper modifies. One engine instance holds
a statically allocated paged KV pool (the per-device HBM budget), a block
manager (the allocator whose free list defines "GPU memory full"), and a
fixed-shape jitted decode step over ``max_batch`` slots.

Scheduling semantics (paper §3, §4.2):

  * baseline engines (SC / CoT / Slim-SC / DeepConf): when the next decode
    step cannot be scheduled because the pool has no free block, a running
    trace is PREEMPTED vLLM-style — its blocks are freed and it re-enters
    the waiting queue; on resume its KV cache is RECOMPUTED (discard-and-
    recompute). The waiting queue is where the paper's 40% latency goes.
  * STEP: the policy returns the lowest-scored trace; the engine PRUNES it
    and immediately reuses its blocks. The waiting queue never forms.

Continuous batching (online arrivals): ``serve_batch`` runs a scheduler
tick loop over a ``RequestQueue`` with per-request arrival times.
Requests join the waiting pool only once their arrival time passes, so
decode keeps running between admission waves and per-request
time-to-first-token / time-per-output-token are measured against the
arrival instant (``serving/metrics.py``). With every arrival at t=0 the
tick loop degenerates to the offline batch scheduler and reproduces its
outputs token-for-token under greedy sampling.

Chunked prefill (``EngineConfig.prefill_chunk_size``): long prompts are
prefilled in fixed-size chunks against the paged pool
(``prefill_chunk_step``), drawing KV blocks chunk-by-chunk through a
``BlockManager.reserve`` reservation. While traces are decoding, each
in-flight prefill advances at most one chunk per scheduler tick, so a
long prompt no longer stalls the running decode batch; with an idle
batch the prefill runs to completion immediately. A tick's combined
prefill work is budgeted by ``EngineConfig.max_tokens_per_step``
(prefill chunks and decode tokens share the tick's token budget).
Chunking applies to the shared-prefix path of paged-attention archs;
recurrent/MLA/enc-dec archs and per-trace prefills fall back to the
one-shot path.

Prefix sharing (``EngineConfig.share_prompt_prefix``, default on): all N
traces of a request decode from the *same* prompt, so the prompt KV is
computed once per request, written into shared paged blocks, and forked
into each trace's block table with refcounting. The first time a trace
writes into a still-shared block (its first generated token lands in the
prompt's partial tail block) the engine copy-on-writes that block. With
the flag off the engine reproduces the original per-trace prefill path
(N sequential prompt prefills), which is the accounting baseline for
Table 3.

Cross-request prefix cache (``EngineConfig.prefix_cache``, default on):
completed prompts' full KV blocks are parked in a radix tree
(``serving/prefix_cache.py``) instead of freed; a later request whose
prompt shares a block-aligned prefix forks the cached blocks (COW
refcounting, zero recompute) and chunk-prefills only the suffix. Cached
blocks are the lowest-priority memory in the pool: under pressure the
engine evicts LRU cache-only blocks BEFORE pruning or preempting any
live trace (evict-before-prune), so enabling the cache can only add
scheduling headroom. Per-request hit accounting (``cached_tokens``)
lands in ``RequestMetrics``.

Multi-request scheduling: traces from different requests co-exist in the
fixed-shape decode step, contend for the same block pool, and are
aggregated into per-request ``RequestResult``s. Policies act per
request: the needy trace's own request's policy decides what to prune;
baseline preemption (last-arrived running trace) is global, like vLLM's
latest-arrival eviction. Each tick the engine publishes an
``AdmissionPressure`` snapshot to every active policy, so pruning
decisions can react to queued arrivals (``PruningPolicy.observe_pressure``).

Latency accounting mirrors the paper's Table 3: every wall-clock second of
the engine loop is attributed to {prefill, decode, overhead}; every second
a trace spends runnable-but-not-running (queued after preemption, or
queued at admission because memory was full) is WAIT. Decode seconds of
the shared batched step are attributed to requests proportionally to
their running traces. Waiting for a free decode *slot* (queue longer than
``max_batch``) is not memory-induced and is not counted as WAIT.
"""
from __future__ import annotations

import copy
import dataclasses
import os
import time
from functools import partial
from typing import Callable, Dict, List, Optional, Sequence, Tuple

import jax
import jax.numpy as jnp
import numpy as np

from repro.configs.base import ModelConfig
from repro.core.pruning import AdmissionPressure, DeepConfPolicy, PruningPolicy
from repro.data.arithmetic import extract_answer
from repro.core.scorer import scorer_score
from repro.core.trace import Trace, TraceStatus
from repro.data.tokenizer import get_tokenizer
from repro.kernels import ops as kops
from repro.models import kv_quant
from repro.models.model import (copy_kv_block, forward_full,
                                init_decode_cache, multi_decode_step,
                                prefill_chunk_step, supports_chunked_prefill,
                                write_prefill_kv)
from repro.serving.faults import (FaultPlan, FaultStats, RecoveryConfig)
from repro.serving.kv_manager import BlockManager
from repro.serving.metrics import RequestMetrics
from repro.serving.prefix_cache import PrefixCache
from repro.serving.sampling import (SamplingParams, sample_logits,
                                    sample_logits_lanes, sample_tokens,
                                    sample_tokens_lanes)
from repro.serving.scheduler import (SLO, ReqState, SchedulerCore,
                                     SchedulingPolicy, SharedPrefix,
                                     default_scheduler)

# Back-compat aliases: these lived here before the scheduler split.
_SharedPrefix = SharedPrefix
_ReqState = ReqState


def _default_use_kernel():
    """``EngineConfig.use_kernel`` default, overridable via the
    ``REPRO_USE_KERNEL`` env var ("1"/"on"/"true" -> True, "auto" ->
    "auto", anything else -> False). This is how the CI kernel lane
    flips the whole engine suite onto the Pallas path (interpret mode
    on CPU) without touching test code."""
    val = os.environ.get("REPRO_USE_KERNEL", "").strip().lower()
    if val in ("1", "on", "true"):
        return True
    if val == "auto":
        return "auto"
    return False


def _default_prefix_cache():
    """``EngineConfig.prefix_cache`` default, overridable via the
    ``REPRO_PREFIX_CACHE`` env var ("0"/"off"/"false" -> off, anything
    else incl. unset -> on). The CI prefix-cache lane pins it to "1" so
    the whole engine suite runs with cross-request KV reuse active."""
    val = os.environ.get("REPRO_PREFIX_CACHE", "").strip().lower()
    return val not in ("0", "off", "false")


def _default_faults():
    """``EngineConfig.faults`` default, overridable via the
    ``REPRO_FAULTS`` env var (a fault-plan spec string, e.g.
    ``"step@2,alloc@5"`` — see ``serving/faults.py`` for the grammar;
    unset/empty -> no injection). The CI ``test-faults`` chaos lane sets
    it to run whole suites under a recoverable fault plan without
    touching test code."""
    val = os.environ.get("REPRO_FAULTS", "").strip()
    return val or None


def _default_kv_dtype():
    """``EngineConfig.kv_dtype`` default, overridable via the
    ``REPRO_KV_DTYPE`` env var (``f32|bf16|int8|fp8``; unset/empty ->
    "bf16", the historical pool dtype). The CI ``test-kv-quant`` lane
    sets it to "int8" to run the whole engine suite on quantized pools
    without touching test code. Validated against the model arch by
    ``kv_quant.resolve_kv_dtype`` at engine construction."""
    return os.environ.get("REPRO_KV_DTYPE", "").strip().lower() or "bf16"


def resolve_use_kernel(setting, cfg: ModelConfig, mesh=None) -> bool:
    """Resolve ``EngineConfig.use_kernel`` (False / True / "auto") to the
    bool the jitted steps consume.

    "auto" picks the compiled Pallas kernels on TPU and the dense XLA
    path on CPU hosts — on CPU the kernels only run in interpret mode
    (the kernel body executed as traced jnp), which is a correctness
    harness, not a fast path; pass ``use_kernel=True`` to force it, as
    the CI kernel lane does. On a mesh the kernel path additionally
    needs the attention heads to divide the "model" axis so the
    shard_map routing keeps every (lane, kv head) grid cell shard-local;
    "auto" falls back to the dense path where the layout is not
    covered, an explicit ``True`` raises ``NotImplementedError`` at
    construction (never silently wrong tokens).
    """
    if setting is False or setting is None:
        return False
    if setting not in (True, "auto"):
        raise ValueError(
            f"use_kernel must be True, False or 'auto', got {setting!r}")
    # the paged kernels cover GQA paged attention (the dense/MoE/hybrid
    # attention layers); MLA's absorbed latent decode has no kernel path
    covered = not cfg.use_mla
    why = "MLA's absorbed latent decode has no Pallas kernel path"
    if covered and mesh is not None:
        model_n = mesh.shape["model"]
        covered = (cfg.num_heads % model_n == 0
                   and cfg.num_kv_heads % model_n == 0)
        why = (f"kernel-on-mesh needs num_heads ({cfg.num_heads}) and "
               f"num_kv_heads ({cfg.num_kv_heads}) divisible by the "
               f"'model' axis ({model_n}) so the shard_map paged "
               f"attention stays shard-local; use use_kernel='auto' to "
               f"fall back to the dense path on this mesh")
    if not covered:
        if setting == "auto":
            return False
        raise NotImplementedError(f"use_kernel=True: {why}")
    if setting == "auto":
        return jax.default_backend() == "tpu"
    return True


@dataclasses.dataclass
class EngineConfig:
    """Static engine resources (the 'GPU')."""
    max_batch: int = 64            # decode slots (>= trace budget N)
    num_blocks: int = 128          # paged pool blocks incl. scratch
    capacity: int = 512            # per-sequence token capacity (window)
    max_new_tokens: int = 160
    sampling: SamplingParams = dataclasses.field(default_factory=SamplingParams)
    # Pallas paged-attention path for the engine-facing attention ops
    # (fused decode + chunked prefill). False = dense jnp; True = always
    # kernel (interpret mode on CPU); "auto" = kernel on TPU, dense on
    # CPU, dense fallback on meshes the shard_map layout doesn't cover.
    # Resolved by ``resolve_use_kernel`` at engine construction.
    use_kernel: "bool | str" = dataclasses.field(
        default_factory=_default_use_kernel)
    seed: int = 0
    # Prefill the prompt once per request and fork its blocks into every
    # trace (COW on first trace-private write). False restores the
    # original per-trace prefill path (the Table-3 accounting baseline).
    share_prompt_prefix: bool = True
    # Chunked prefill: split shared-prefix prompt prefills into chunks of
    # this many tokens, interleaved with decode ticks. None = one-shot
    # prefill (the offline-equivalent setting).
    prefill_chunk_size: Optional[int] = None
    # Per-tick token budget shared by decode tokens (one per running
    # trace) and prefill tokens (chunks + one-shot prefills). None =
    # unlimited (admission bounded only by slots and blocks).
    max_tokens_per_step: Optional[int] = None
    # Cross-request prefix cache: park completed prompts' full KV blocks
    # in a radix tree and serve later requests' shared block-aligned
    # prefixes from it (COW fork, zero recompute); LRU-evicted before
    # any trace is pruned/preempted. Needs share_prompt_prefix and a
    # paged-attention arch (chunked prefill computes the suffix);
    # silently inactive otherwise. Default from REPRO_PREFIX_CACHE
    # (unset -> on).
    prefix_cache: bool = dataclasses.field(
        default_factory=_default_prefix_cache)
    # Decode horizon: run K decode iterations inside ONE jitted device
    # call (fused lax.scan with on-device sampling, EOS masking and
    # step-boundary score capture) and sync tokens/confidences/scores to
    # the host once per K tokens. 1 (default) reproduces the one-token-
    # per-tick scheduler exactly; K>1 amortizes the device->host round
    # trip and the Python tick overhead over K tokens, and generates
    # token-identical traces while scheduling stays aligned — i.e.
    # until memory contention shifts prune/preempt decisions, which
    # land at horizon granularity (greedy sampling is additionally
    # key-free, so it never depends on key-stream alignment — see
    # docs/ENGINE.md). Under admission pressure with a short free list
    # the engine falls back to a single-token tick so frontier
    # pre-allocation never starves waiting work.
    decode_horizon: int = 1
    # Deterministic fault injection: a FaultPlan spec string (see
    # serving/faults.py for the grammar, e.g. "step@2,alloc@5"), parsed
    # at engine construction and seeded with ``seed``. None = no
    # injection. Default from REPRO_FAULTS so the CI chaos lane can flip
    # whole test suites onto a fault plan without touching call sites.
    faults: Optional[str] = dataclasses.field(default_factory=_default_faults)
    # Paged-pool storage dtype: "f32" | "bf16" (default, the historical
    # pool dtype — pinned token/score/prune-identical to f32) | "int8" |
    # "fp8" (quantized: per-page per-KV-head f32 scales, quantize on
    # write, dequantize inside the attention read — dense and Pallas
    # paths apply identical math; see models/kv_quant.py and
    # docs/ENGINE.md "Quantized KV pool"). Quantized dtypes shrink
    # bytes-per-block ~4x/2x vs f32/bf16, so the same HBM sustains
    # proportionally more traces before the pruning policy fires.
    # Default from REPRO_KV_DTYPE (the CI test-kv-quant lane sets int8).
    kv_dtype: str = dataclasses.field(default_factory=_default_kv_dtype)

    # env var -> (field, parser, minimum); the single documented source
    # of truth for engine configuration from the environment
    # (REPRO_USE_KERNEL, REPRO_PREFIX_CACHE and REPRO_FAULTS
    # additionally act as dataclass defaults so the CI lanes flip whole
    # test suites without touching call sites).
    _ENV_FIELDS = {
        "REPRO_MAX_BATCH": ("max_batch", int, 1),
        "REPRO_NUM_BLOCKS": ("num_blocks", int, 2),
        "REPRO_CAPACITY": ("capacity", int, 1),
        "REPRO_MAX_NEW_TOKENS": ("max_new_tokens", int, 1),
        "REPRO_SEED": ("seed", int, 0),
        "REPRO_PREFILL_CHUNK": ("prefill_chunk_size", int, 1),
        "REPRO_MAX_TOKENS_PER_STEP": ("max_tokens_per_step", int, 1),
        "REPRO_DECODE_HORIZON": ("decode_horizon", int, 1),
    }

    @classmethod
    def from_env(cls, **overrides) -> "EngineConfig":
        """Build an ``EngineConfig`` from ``REPRO_*`` environment
        variables, with explicit keyword ``overrides`` taking
        precedence over the environment, which takes precedence over
        the dataclass defaults.

        Scalar fields read ``REPRO_MAX_BATCH``, ``REPRO_NUM_BLOCKS``,
        ``REPRO_CAPACITY``, ``REPRO_MAX_NEW_TOKENS``, ``REPRO_SEED``,
        ``REPRO_PREFILL_CHUNK``, ``REPRO_MAX_TOKENS_PER_STEP`` and
        ``REPRO_DECODE_HORIZON``; ``REPRO_USE_KERNEL`` /
        ``REPRO_PREFIX_CACHE`` / ``REPRO_FAULTS`` / ``REPRO_KV_DTYPE``
        keep their existing semantics (they are the dataclass default
        factories, so they apply to plain ``EngineConfig()``
        construction too). This is what
        ``launch/serve.py``, ``evaluate_method(_batched)`` and the
        benchmarks build their configs through — one documented source
        of truth instead of scattered ``os.environ`` reads.
        """
        kwargs = {}
        for env_name, (field, parse, lo) in cls._ENV_FIELDS.items():
            raw = os.environ.get(env_name, "").strip()
            if not raw:
                continue
            try:
                val = parse(raw)
            except ValueError:
                raise ValueError(
                    f"{env_name}={raw!r}: expected an integer >= {lo}"
                ) from None
            if val < lo:
                raise ValueError(
                    f"{env_name}={raw!r}: expected an integer >= {lo}")
            kwargs[field] = val
        kwargs.update(overrides)
        return cls(**kwargs)


@dataclasses.dataclass
class Request:
    """One unit of work for the scheduler: a prompt and a trace budget.

    ``arrival_time`` is in seconds relative to the start of the serve
    loop; the scheduler will not admit the request before it. 0.0 (the
    default) means available immediately, which reproduces the offline
    batch semantics.

    ``policy`` overrides the engine-level policy for this request; pass a
    fresh instance per request when the policy is stateful (DeepConf's
    warmup threshold, Slim-SC's check cursor) and requests run
    concurrently. When left None in a multi-request batch, the engine
    deep-copies its default policy per request for the same reason.

    Per-request generation overrides: ``sampling`` (a
    ``SamplingParams``) and ``max_new_tokens`` replace the engine-global
    ``EngineConfig.sampling`` / ``EngineConfig.max_new_tokens`` for this
    request only; ``None`` (the default) inherits the engine values, so
    existing callers are untouched. A batch where every request inherits
    the engine sampling runs the scalar decode path unchanged; any
    override flips that serve call onto the lane-wise sampling path
    (identical math per lane — see ``sampling.sample_logits_lanes``).

    Multi-tenant serving (consumed by ``scheduler.TenantScheduler``;
    inert under the default FIFO policy): ``tenant`` names the fair-share
    account the request's tokens are charged to, ``priority`` orders
    admission across tenants (higher first), and ``slo`` attaches a
    per-request ``scheduler.SLO`` — admission may degrade ``n_traces``
    toward ``slo.min_traces`` (test-time-scaling quality as the latency
    dial) or shed the request when its projected TTFT violates the
    objective.
    """
    request_id: int
    prompt_tokens: List[int]
    n_traces: int
    policy: Optional[PruningPolicy] = None
    arrival_time: float = 0.0
    sampling: Optional[SamplingParams] = None
    max_new_tokens: Optional[int] = None
    tenant: str = "default"
    priority: int = 0
    slo: Optional[SLO] = None
    # wall-clock budget in seconds relative to the serve start (same
    # clock as ``arrival_time``). Once exceeded the request is released
    # with status "deadline_exceeded"; traces already FINISHED keep
    # their output, so the vote runs over whatever completed in time.
    deadline: Optional[float] = None


@dataclasses.dataclass
class RequestResult:
    request_id: int
    answer: Optional[str]
    traces: List[Trace]
    latency_s: float
    total_tokens: int
    wait_s: float
    decode_s: float
    prefill_s: float
    num_pruned: int
    num_preemptions: int
    # pool-wide peak block usage observed up to this request's completion
    # (stable by the time the streaming on_complete callback sees it)
    peak_blocks_used: int = 0
    metrics: Optional[RequestMetrics] = None
    # "completed" | "cancelled" | "deadline_exceeded" | "failed"
    status: str = "completed"



class Engine:
    """Continuous-batching engine over a queue of requests, each fanning
    out into N parallel traces (the paper's setting: one problem, N=64
    traces — ``serve``; cross-request contention and online arrivals —
    ``serve_batch``).

    ``mesh`` (a ``("data", "model")`` jax mesh, e.g.
    ``launch.mesh.make_host_mesh(2, 2)``) runs the device-resident side
    over a device mesh: params tensor-parallel on "model"
    (``launch/shardings.serving_param_specs`` — the exactness-preserving
    layout whose only collectives are activation all-gathers), the
    paged KV pool head-sharded on "model" with its block dim replicated
    on "data" (``serving_cache_specs``), and the trace batch — tokens,
    positions, block tables, per-lane outputs, step scores — sharded on
    "data". Host-side scheduling (BlockManager, pruning, the queue) is
    untouched: the allocator stays global, and every scheduling decision
    consumes the same host-synced values, so a mesh engine is
    token-identical to the single-device engine under a fixed RNG
    (pinned in tests/test_sharded_engine.py)."""

    def __init__(self, params: dict, cfg: ModelConfig, ecfg: EngineConfig,
                 policy: PruningPolicy,
                 scorer_params: Optional[dict] = None,
                 mesh=None,
                 scheduler: Optional[SchedulingPolicy] = None):
        self.params = params
        self.cfg = cfg
        self.ecfg = ecfg
        self.policy = policy
        self.scorer_params = scorer_params
        self.mesh = mesh
        # scheduling policy (admission order, token budgets, SLO
        # admission, preemption victims). None -> REPRO_SCHED env
        # default (unset = the FIFO policy, which reproduces the
        # pre-scheduler-core tick loop exactly).
        self.scheduler = (scheduler if scheduler is not None
                          else default_scheduler())
        self.tok = get_tokenizer()
        bs = cfg.kv_block_size
        self.blocks_per_seq = -(-ecfg.capacity // bs)
        self._chunk_supported = supports_chunked_prefill(cfg)
        # pool storage dtype: validated against the arch up front so an
        # unsupported quantized setting fails at construction, and the
        # per-block HBM cost flows into the allocator's byte accounting
        # (AdmissionPressure reports real bytes, not just block counts)
        kv_quant.resolve_kv_dtype(ecfg.kv_dtype, cfg, self._chunk_supported)
        self.kv_block_bytes = kv_quant.pool_block_bytes(cfg, ecfg.kv_dtype)
        self.block_mgr = BlockManager(ecfg.num_blocks, bs,
                                      bytes_per_block=self.kv_block_bytes)
        self._rng = jax.random.PRNGKey(ecfg.seed)
        # cross-request prefix cache: needs the shared-prefix holder (the
        # parked blocks ARE a holder that outlives its request) and the
        # chunked-prefill path (the suffix continues from cached KV)
        self.prefix_cache: Optional[PrefixCache] = None
        if (ecfg.prefix_cache and ecfg.share_prompt_prefix
                and self._chunk_supported):
            self.prefix_cache = PrefixCache(self.block_mgr)
        # with the cache on, the device KV pool must outlive a single
        # serve_batch call — parked blocks are worthless if the pool
        # they point into is re-initialized (zeroed) between batches
        self._kv_cache = None
        # resolved kernel routing for the jitted steps (may raise for
        # unsupported explicit-True combinations — never wrong tokens)
        self.use_kernel = resolve_use_kernel(ecfg.use_kernel, cfg, mesh)
        assert ecfg.decode_horizon >= 1, "decode_horizon must be >= 1"
        # ticks where admission pressure forced the horizon down to 1
        # (observable for tests/benchmarks)
        self.horizon_fallbacks = 0
        # fault tolerance: the injection plan (re-armed per serve so the
        # same perturbation replays), the recovery policy knobs, and the
        # cumulative ledger of injections/recoveries
        self.fault_plan: Optional[FaultPlan] = (
            FaultPlan.parse(ecfg.faults, seed=ecfg.seed)
            if ecfg.faults else None)
        self.recovery = RecoveryConfig()
        self.fault_stats = FaultStats()
        # persistent-fault degrade rung: pins every decode burst to
        # horizon 1 (token-identical by the K==1 equivalence pin)
        self.force_horizon1 = False
        # request ids flagged by Engine.cancel, consumed by the
        # scheduler core's cancellation sweep each pump iteration
        self._cancel_requests: set = set()
        # tail of the last serve_batch's scheduler event stream
        self.last_event_log: list = []
        self._ss = None  # serving step shardings (mesh engines only)
        if mesh is not None:
            self._place_on_mesh()
        self._build_steps()

    def _place_on_mesh(self) -> None:
        """Shard params/scorer onto the mesh and build the NamedSharding
        bundle the jitted steps pin their in/out layouts to."""
        from repro.launch.shardings import (serving_param_specs,
                                            serving_prefill_kv_specs,
                                            serving_step_shardings,
                                            to_named)
        mesh = self.mesh
        for axis in ("data", "model"):
            if axis not in mesh.axis_names:
                raise ValueError(f"serving mesh needs a {axis!r} axis, "
                                 f"got {mesh.axis_names}")
        data_n = mesh.shape["data"]
        if self.ecfg.max_batch % data_n != 0:
            raise ValueError(
                f"max_batch={self.ecfg.max_batch} must be a multiple of "
                f"the mesh's data axis ({data_n}) so decode lanes shard "
                f"evenly")
        if self.cfg.arch_type in ("ssm", "hybrid") \
                or self.cfg.is_encoder_decoder:
            raise NotImplementedError(
                "mesh serving covers the dense paged-attention archs; "
                "recurrent/enc-dec state would need a data-sharded "
                "slot-state story first")
        if self.cfg.use_mla or self.cfg.uses_moe:
            # the bit-identity contract requires every sharded matmul's
            # contractions to stay shard-local; MLA's low-rank norms
            # (rms over a model-sharded lora dim) and the MoE
            # router/dispatch reductions are not constrained yet
            raise NotImplementedError(
                "mesh serving's exactness layout does not cover "
                "MLA/MoE yet; run these archs on a single device")
        # Non-partitionable threefry (the jax<0.5 default) generates
        # DIFFERENT random bits once the logits array is sharded, so
        # temperature sampling on the mesh would silently diverge from
        # the single-device engine. The partitionable implementation is
        # sharding-invariant by construction. NOTE: this is a
        # process-global flag — engines (and any other sampling code)
        # created after this point consume partitionable key streams,
        # which is exactly what makes a later single-device engine
        # comparable to this one (tests pin mesh-vs-single token
        # identity under it), but it does mean constructing a mesh
        # engine changes fixed-seed streams for the rest of the process.
        jax.config.update("jax_threefry_partitionable", True)
        shapes = jax.tree.map(
            lambda x: jax.ShapeDtypeStruct(x.shape, x.dtype), self.params)
        pspecs = serving_param_specs(self.cfg, mesh, shapes)
        self.params = jax.device_put(self.params, to_named(mesh, pspecs))
        self._ss = serving_step_shardings(self.cfg, mesh,
                                          self.ecfg.kv_dtype)
        self._prefill_kv_specs = serving_prefill_kv_specs(self.cfg, mesh)
        if self.scorer_params is not None:
            # the scorer is a tiny MLP: replicate it so step-score
            # capture is a shard-local matmul over the data-sharded
            # hidden states (no gather per scored token)
            self.scorer_params = jax.device_put(self.scorer_params,
                                                self._ss["replicated"])

    # ------------------------------------------------------------------
    # jitted steps
    # ------------------------------------------------------------------
    def _build_steps(self):
        cfg, ecfg = self.cfg, self.ecfg
        has_scorer = self.scorer_params is not None
        sp = ecfg.sampling
        ss = self._ss  # NamedSharding bundle (None on a 1-device engine)

        V = cfg.vocab_size  # mask vocab padding out of the sampler
        eos_id = self.tok.eos_id
        # Fused step scorer: on the kernel path the scorer MLP runs as
        # the Pallas step_score kernel inside the decode burst, so the
        # [B, D] step-boundary hiddens feed the two matmuls from VMEM
        # instead of round-tripping through a separate dense pass. The
        # kernel computes the exact scorer_score graph (f32 matmuls,
        # ReLU, sigmoid) — pinned score-identical in tests. Mesh engines
        # keep the dense scorer: it is a shard-local matmul over the
        # data-sharded hiddens, and a pallas_call under GSPMD would need
        # its own shard_map plumbing for zero benefit at [B, D] sizes.
        self.fused_scorer = bool(has_scorer and self.use_kernel
                                 and ss is None)
        step_id = self.tok.step_id

        def mask_and_gather(logits):
            logits = logits.at[:, V:].set(-jnp.inf)
            if ss is not None:
                # The sampling math must never shard the vocab axis: the
                # top-p cumsum and softmax denominators are float
                # reductions whose cross-shard psum rounds differently
                # than the single-device sum and flips boundary samples.
                # Gathering the [B, Vp] logits (a few KB at decode
                # widths) and sampling replicated reproduces the
                # single-device sampler bit-for-bit.
                logits = jax.lax.with_sharding_constraint(
                    logits, ss["replicated"])
            return logits

        def sample_fn(key, logits):
            logits = mask_and_gather(logits)
            return sample_logits(key, logits, temperature=sp.temperature,
                                 top_k=sp.top_k, top_p=sp.top_p)

        def make_decode(horizon, lanewise=False):
            """Fused K-iteration decode; one jit instance per (horizon,
            lanewise). The lane-wise variant takes per-lane
            temperature/top-k/top-p arrays as traced arguments (the
            per-request sampling path); the scalar variant bakes the
            engine-global ``SamplingParams`` into the graph and is the
            only one built for batches with no overrides."""
            jit_kw = {}
            if ss is not None:
                # pin the round-trip layouts: per-lane [B, K] bursts and
                # next-tick state stay data-sharded, pools keep the
                # serving cache layout (donation then reuses the input
                # pool buffers), the key stays replicated
                t, lane = ss["table"], ss["lane"]
                jit_kw["out_shardings"] = (t, t, t, t, t, lane, lane,
                                           ss["pools"], ss["replicated"])

            @partial(jax.jit, donate_argnums=(1,), **jit_kw)
            def batched_decode(params, cache, tokens, positions, limits,
                               block_tables, rng, scorer_params, *samp):
                cache = dict(cache)
                cache["block_tables"] = block_tables
                if not has_scorer:
                    score_fn = None
                elif self.fused_scorer:
                    score_fn = (lambda h:
                                kops.step_score_params(h, scorer_params))
                else:
                    score_fn = (lambda h: scorer_score(scorer_params, h))
                if lanewise:
                    temps, topks, topps = samp

                    def lane_sample_fn(key, logits):
                        logits = mask_and_gather(logits)
                        return sample_logits_lanes(key, logits, temps,
                                                   topks, topps)

                    step_sample_fn = lane_sample_fn
                else:
                    step_sample_fn = sample_fn
                # derive the per-iteration keys in-graph, exactly as K
                # successive host-side ticks would (rng, k = split(rng)
                # per token) — one device call replaces K split
                # dispatches + a stack per tick
                keys = []
                for _ in range(horizon):
                    rng, k = jax.random.split(rng)
                    keys.append(k)
                out = multi_decode_step(
                    params, cfg, tokens, positions, limits, cache,
                    window_len=ecfg.capacity, horizon=horizon,
                    rng_keys=jnp.stack(keys), sample_fn=step_sample_fn,
                    eos_id=eos_id, step_id=step_id, score_fn=score_fn,
                    scratch_block=self.block_mgr.scratch_block,
                    use_kernel=self.use_kernel, shard_specs=ss)
                pools = out["cache"]
                pools.pop("block_tables", None)
                return (out["tokens"], out["confidences"], out["scores"],
                        out["token_valid"], out["score_valid"],
                        out["final_tokens"], out["positions"], pools, rng)

            return batched_decode

        self._make_decode = make_decode
        self._decode_fns: Dict[Tuple[int, bool], Callable] = {}
        self._decode_fns[(ecfg.decode_horizon, False)] = make_decode(
            ecfg.decode_horizon)
        # pressure-fallback path: single-token ticks while waiting work
        # contends for a short free list (same instance when K == 1)
        if (1, False) not in self._decode_fns:
            self._decode_fns[(1, False)] = make_decode(1)

        pf_kv = None if ss is None else self._prefill_kv_specs
        pf_act = None if ss is None else ss["prefill_act"]

        @jax.jit
        def prefill(params, tokens):
            out = forward_full(params, cfg, tokens, return_kv=True,
                               kv_specs=pf_kv, act_spec=pf_act,
                               tp_act_spec=pf_act)
            logits = out["logits"].at[..., V:].set(-jnp.inf)
            if ss is not None:
                # first-token sampling consumes these host-side: gather
                # off the vocab sharding so the sampler's top-p cumsum
                # never reduces over a sharded axis (see sample_fn)
                logits = jax.lax.with_sharding_constraint(
                    logits, ss["prefill_act"])
            return logits, out["kvs"]

        self._prefill = prefill

        # prompt-KV scatter into the paged pools (one-shot prefix path).
        # Jitted so a mesh engine can pin the output pools back to the
        # canonical cache layout right at the write.
        pool_keys = ("kv_pool",) if cfg.use_mla else ("k_pool", "v_pool")
        if kv_quant.is_quantized(ecfg.kv_dtype):
            pool_keys += ("k_scale", "v_scale")
        wkv_kw = {}
        if ss is not None:
            wkv_kw["out_shardings"] = {
                **{k: ss["pools"][k] for k in pool_keys},
                "block_tables": ss["replicated"],  # one batch-1 row
            }

        @partial(jax.jit, donate_argnums=(0,), **wkv_kw)
        def write_kv(sub_cache, kvs, lens):
            return write_prefill_kv(cfg, sub_cache, kvs, lens)

        self._write_kv = write_kv

        if self._chunk_supported:
            cp_kw = {}
            if ss is not None:
                # chunk jobs run one prompt at a time (batch 1): the
                # logits can't batch-shard, but the pools must come out
                # in the serving layout the decode step expects
                chunk_keys = ("k_pool", "v_pool")
                if kv_quant.is_quantized(ecfg.kv_dtype):
                    chunk_keys += ("k_scale", "v_scale")
                cp_kw["out_shardings"] = (
                    ss["replicated"],
                    {k: ss["pools"][k] for k in chunk_keys})

            @partial(jax.jit, donate_argnums=(1,), **cp_kw)
            def chunk_prefill(params, cache, tokens, positions, valid,
                              block_tables):
                cache = dict(cache)
                cache["block_tables"] = block_tables
                out = prefill_chunk_step(params, cfg, tokens, positions,
                                         valid, cache,
                                         window_len=ecfg.capacity,
                                         use_kernel=self.use_kernel,
                                         shard_specs=ss)
                logits = out["logits"].at[..., V:].set(-jnp.inf)
                new_cache = out["cache"]
                new_cache.pop("block_tables", None)
                return logits, new_cache

            self._chunk_prefill = chunk_prefill

        # COW block copy: pool[:, dst] = pool[:, src], one jitted instance
        # for all block pairs (src/dst are traced scalars).
        cb_kw = {} if ss is None else {"out_shardings": ss["pools"]}
        self._copy_block = jax.jit(partial(copy_kv_block, cfg),
                                   donate_argnums=(0,), **cb_kw)

    def decode_fn(self, horizon: int, lanewise: bool = False) -> Callable:
        """The fused decode step for ``(horizon, lanewise)``. Scalar
        instances for the configured horizon (and its K=1 pressure
        fallback) are built at construction; lane-wise instances (the
        per-request sampling path) compile lazily on the first serve
        call whose batch carries a sampling override."""
        key = (horizon, lanewise)
        fn = self._decode_fns.get(key)
        if fn is None:
            fn = self._decode_fns[key] = self._make_decode(horizon,
                                                           lanewise)
        return fn

    # ------------------------------------------------------------------
    # host-side sampling (prefill first tokens)
    # ------------------------------------------------------------------
    def sample_host(self, logits, sp: SamplingParams):
        """Sample one token per row of ``logits`` with scalar params,
        consuming one split of the engine RNG stream (exactly what the
        pre-refactor tick loop did — the identity pins depend on this
        key-consumption order)."""
        self._rng, k = jax.random.split(self._rng)
        return sample_tokens(k, logits, temperature=sp.temperature,
                             top_k=sp.top_k, top_p=sp.top_p)

    def sample_host_lanes(self, logits, sps: Sequence[SamplingParams]):
        """Per-row sampling params (mixed-sampling admission waves);
        same single RNG split as ``sample_host``."""
        self._rng, k = jax.random.split(self._rng)
        temps = jnp.asarray([s.temperature for s in sps], jnp.float32)
        topks = jnp.asarray([s.top_k for s in sps], jnp.int32)
        topps = jnp.asarray([s.top_p for s in sps], jnp.float32)
        return sample_tokens_lanes(k, logits, temps, topks, topps)

    # ------------------------------------------------------------------
    # KV pool handoff (scheduler core <-> persistent prefix-cache pool)
    # ------------------------------------------------------------------
    def _take_kv_cache(self) -> dict:
        """Hand the device KV pool to a scheduler run. With the prefix
        cache on, the pool persists across serve calls (parked blocks
        keep their KV); ownership transfers because the first jitted
        step donates the buffers, so no second reference may survive."""
        if self.prefix_cache is not None and self._kv_cache is not None:
            cache, self._kv_cache = self._kv_cache, None
            return cache
        return self._init_cache()

    def _stash_kv_cache(self, cache: dict) -> None:
        """Keep parked KV live for the next serve call (cache on)."""
        if self.prefix_cache is not None:
            self._kv_cache = cache

    # ------------------------------------------------------------------
    # pool accounting
    # ------------------------------------------------------------------
    @property
    def idle_free_blocks(self) -> int:
        """Free-list blocks plus blocks parked in the prefix cache —
        everything reclaimable when no request is live."""
        cached = (self.prefix_cache.cached_blocks
                  if self.prefix_cache is not None else 0)
        return self.block_mgr.free_blocks + cached

    def pool_drained(self) -> bool:
        """True when no live request holds pool memory: every non-free
        block is parked in the prefix cache at refcount exactly 1 (the
        cache's own reference). With the cache off this degenerates to
        ``free_blocks == num_blocks - 1`` — the pre-cache drain check."""
        if self.prefix_cache is not None:
            self.prefix_cache.check_integrity()
            if any(self.block_mgr.ref_count(b) != 1
                   for b in self.prefix_cache.blocks()):
                return False
        return self.idle_free_blocks == self.block_mgr.num_blocks - 1

    # ------------------------------------------------------------------
    # fault tolerance
    # ------------------------------------------------------------------
    def cancel(self, request_id: int) -> None:
        """Flag a request for mid-flight cancellation. Safe to call from
        an ``on_complete`` callback (or any code running inside the
        serve loop): the scheduler core's sweep releases the request's
        traces, reservations and prefix-cache refs at the next pump
        iteration and stamps its result ``status="cancelled"``. Unknown
        or already-finished ids are ignored."""
        self._cancel_requests.add(request_id)

    def degrade_to_dense(self) -> bool:
        """Persistent-fault ladder rung: drop the Pallas kernel path and
        rebuild the jitted steps on dense XLA. Token-identical — the
        kernel/dense equivalence is pinned by the kernel CI lane.
        Returns False when already dense (rung unavailable)."""
        if not self.use_kernel:
            return False
        self.use_kernel = False
        self._build_steps()
        self.fault_stats.degraded_to_dense += 1
        return True

    def check_integrity(self, expect_open_reservations: int = 0) -> None:
        """Pool-wide invariant audit: allocator refcount conservation,
        no orphaned reservations, prefix-trie consistency. Cheap enough
        that the scheduler core runs it after every fault/cancel path;
        tests call it at any quiesced point."""
        self.block_mgr.check_integrity(expect_open_reservations)
        if self.prefix_cache is not None:
            self.prefix_cache.check_integrity()
        self.fault_stats.integrity_audits += 1

    # ------------------------------------------------------------------
    # cache plumbing
    # ------------------------------------------------------------------
    def _init_cache(self):
        """Shared pool sized to the engine budget (not per-sequence)."""
        cache = init_decode_cache(
            self.cfg, self.ecfg.max_batch, self.ecfg.capacity,
            num_blocks=self.ecfg.num_blocks,
            kv_dtype=self.ecfg.kv_dtype)
        cache.pop("block_tables", None)
        if self._ss is not None:
            cache = {k: jax.device_put(v, self._ss["pools"][k])
                     for k, v in cache.items()}
        return cache

    def _split_prefill_kvs(self, kvs) -> Tuple[Optional[tuple],
                                               Optional[tuple]]:
        """Split forward_full(return_kv=True) output for a batch-1 prefill
        into (paged attention KV | None, per-slot recurrent state | None).
        """
        cfg = self.cfg
        if cfg.arch_type == "ssm":
            ss, cs = kvs
            return None, (ss[:, 0], cs[:, 0])
        if cfg.arch_type == "hybrid":
            (ss, cs), (k, v) = kvs
            ssf = ss.reshape(-1, *ss.shape[2:])
            csf = cs.reshape(-1, *cs.shape[2:])
            return (k[:, :1], v[:, :1]), (ssf[:, 0], csf[:, 0])
        if cfg.use_mla:
            return kvs[:, :1], None
        k, v = kvs
        return (k[:, :1], v[:, :1]), None

    def _write_prefix_kv(self, cache: dict, attn_kvs, block_row: np.ndarray,
                         seq_len: int) -> dict:
        """Write prompt KV into the paged pools ONCE for a block row.

        With prefix sharing this runs once per request; every trace then
        reads these blocks through its forked block table.
        """
        if attn_kvs is None:
            return cache
        cfg = self.cfg
        bt = jnp.asarray(block_row[None, :], jnp.int32)  # [1, bp]
        lens = jnp.full((1,), seq_len, jnp.int32)
        if cfg.use_mla:
            sub = {"kv_pool": cache["kv_pool"], "block_tables": bt}
            sub = self._write_kv(sub, attn_kvs, lens)
            cache["kv_pool"] = sub["kv_pool"]
            return cache
        k, v = attn_kvs
        sub = {"k_pool": cache["k_pool"], "v_pool": cache["v_pool"],
               "block_tables": bt}
        if "k_scale" in cache:  # quantized pools: scales ride along
            sub["k_scale"] = cache["k_scale"]
            sub["v_scale"] = cache["v_scale"]
        sub = self._write_kv(sub, (k, v), lens)
        cache["k_pool"], cache["v_pool"] = sub["k_pool"], sub["v_pool"]
        if "k_scale" in sub:
            cache["k_scale"] = sub["k_scale"]
            cache["v_scale"] = sub["v_scale"]
        return cache

    def _write_slot_state(self, cache: dict, slot_state, slot: int) -> dict:
        """Scatter recurrent (SSM/conv) prefill end-state into one slot."""
        if slot_state is None:
            return cache
        ss, cs = slot_state
        cache["ssm_state"] = cache["ssm_state"].at[:, slot].set(ss)
        cache["conv_state"] = cache["conv_state"].at[:, slot].set(cs)
        return cache

    def _write_prefill(self, cache: dict, kvs, slot: int,
                       block_row: np.ndarray, seq_len: int) -> dict:
        """Scatter one trace's prefill KV/state into the shared pool."""
        attn_kvs, slot_state = self._split_prefill_kvs(kvs)
        cache = self._write_prefix_kv(cache, attn_kvs, block_row, seq_len)
        return self._write_slot_state(cache, slot_state, slot)

    def _clear_slot_state(self, cache: dict, slot: int) -> dict:
        if "ssm_state" in cache:
            cache["ssm_state"] = cache["ssm_state"].at[:, slot].set(0.0)
            cache["conv_state"] = cache["conv_state"].at[:, slot].set(0.0)
        return cache

    # ------------------------------------------------------------------
    # request serving
    # ------------------------------------------------------------------
    def serve(self, prompt_tokens: List[int], n_traces: int,
              request_id: int = 0) -> RequestResult:
        """Generate ``n_traces`` parallel traces for one prompt."""
        assert n_traces <= self.ecfg.max_batch, "engine sized per trace budget"
        req = Request(request_id=request_id,
                      prompt_tokens=list(prompt_tokens),
                      n_traces=n_traces, policy=self.policy)
        return self.serve_batch([req])[0]

    def serve_batch(self, requests: Sequence[Request],
                    on_complete: Optional[Callable[[RequestResult], None]]
                    = None) -> List[RequestResult]:
        """Serve a queue of requests through one shared decode batch.

        Requests join the scheduler at their ``arrival_time``; total
        traces may exceed ``max_batch`` (surplus traces wait for a free
        decode slot). Block-pool contention is cross-request; each
        request's own policy governs pruning of its traces.

        ``on_complete`` streams results: it is invoked with a request's
        ``RequestResult`` the moment its last trace finishes, while other
        requests are still decoding. The returned list is in submission
        order, as before.
        """
        t_start = time.perf_counter()
        states: List[ReqState] = []
        for req in requests:
            if req.policy is not None:
                policy = req.policy
            elif len(requests) == 1:
                policy = self.policy
            else:
                # stateful policies (DeepConf threshold, Slim-SC cursors)
                # must not leak between concurrent requests: give each
                # request its own copy of the engine-level default
                policy = copy.deepcopy(self.policy)
            if isinstance(policy, DeepConfPolicy):
                policy.threshold = None  # fresh threshold per request
            traces = [Trace(trace_id=i, request_id=req.request_id,
                            prompt_tokens=list(req.prompt_tokens))
                      for i in range(req.n_traces)]
            states.append(ReqState(
                req, policy, traces,
                sampling=(req.sampling if req.sampling is not None
                          else self.ecfg.sampling),
                max_new_tokens=(req.max_new_tokens
                                if req.max_new_tokens is not None
                                else self.ecfg.max_new_tokens)))

        if self.fault_plan is not None:
            self.fault_plan.reset()  # replay the identical plan per serve
        core = SchedulerCore(self, states, t_start, on_complete,
                             sched=self.scheduler)
        try:
            peak_blocks = core.run()
        except BaseException:
            # mid-serve crash: drain everything the run still held so
            # the pool is clean and the engine reusable, then re-raise
            core.emergency_drain()
            self.last_event_log = list(core.event_log)
            raise
        # tail of the event stream (bounded deque), for observability
        # and the event-ordering tests
        self.last_event_log = list(core.event_log)

        t_end = time.perf_counter()
        results = []
        for st in states:
            if st.result is None:  # defensive: finalize stragglers
                st.result = self._finalize(st, t_start, t_end, peak_blocks)
            results.append(st.result)
        return results

    def _finalize(self, st: _ReqState, t_start: float, t_end: float,
                  peak_blocks: int) -> RequestResult:
        """Fold one finished request's traces into its RequestResult."""
        finished = [t for t in st.traces if t.status == TraceStatus.FINISHED]
        answer = st.policy.vote(finished) if finished else None
        done = st.t_done if st.t_done is not None else t_end
        total_tokens = sum(t.num_tokens for t in st.traces)
        num_pruned = sum(t.status == TraceStatus.PRUNED for t in st.traces)
        num_failed = sum(t.status == TraceStatus.FAILED for t in st.traces)
        num_preempt = sum(max(t.prefill_count - 1, 0) for t in st.traces)
        wait_s = sum(t.wait_time for t in st.traces)
        metrics = RequestMetrics(
            request_id=st.request_id,
            arrival_s=st.req.arrival_time,
            admitted_s=(st.admit_t - t_start
                        if st.admit_t is not None else None),
            first_token_s=(st.first_token_t - t_start
                           if st.first_token_t is not None else None),
            finished_s=done - t_start,
            prompt_tokens=len(st.req.prompt_tokens),
            output_tokens=total_tokens,
            n_traces=len(st.traces),
            num_pruned=num_pruned,
            num_preemptions=num_preempt,
            wait_s=wait_s, prefill_s=st.prefill_s, decode_s=st.decode_s,
            cached_tokens=st.cached_tokens,
            tenant=getattr(st.req, "tenant", "default"),
            priority=getattr(st.req, "priority", 0),
            degraded_traces=st.degraded_traces,
            slo_ttft_s=(st.req.slo.ttft_s if st.req.slo is not None
                        else None),
            slo_tpot_s=(st.req.slo.tpot_s if st.req.slo is not None
                        else None),
            status=st.final_status,
            failed_traces=num_failed)
        return RequestResult(
            request_id=st.request_id, answer=answer, traces=st.traces,
            latency_s=done - t_start,
            total_tokens=total_tokens,
            wait_s=wait_s,
            decode_s=st.decode_s, prefill_s=st.prefill_s,
            num_pruned=num_pruned,
            num_preemptions=num_preempt,
            peak_blocks_used=peak_blocks,
            metrics=metrics,
            status=st.final_status)

